"""Train a ~100M-parameter LM for a few hundred steps on the synthetic
pipeline, with checkpointing — then kill and resume to demonstrate the
fault-tolerance path (the loss curve continues exactly).

  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import dataclasses
import shutil

import numpy as np

from repro.configs.registry import get_smoke_config
from repro.data.pipeline import SyntheticLMData
from repro.models import schema
from repro.optim import AdamWConfig
from repro.train import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M params: granite-family, 8 layers, d=512
    cfg = dataclasses.replace(
        get_smoke_config("granite-3-2b"), n_layers=8, d_model=512,
        n_heads=8, n_kv_heads=4, head_dim=64, d_ff=1536, vocab=8192,
        tie_embeddings=False)
    n = schema.param_count(cfg)
    print(f"model: {n/1e6:.1f}M params ({cfg.n_layers}L d={cfg.d_model})")

    shutil.rmtree(args.ckpt, ignore_errors=True)
    tcfg = TrainConfig(steps=args.steps, ckpt_dir=args.ckpt, ckpt_every=50,
                       log_every=10, warmup=30,
                       opt=AdamWConfig(lr=6e-4, weight_decay=0.01))
    data = SyntheticLMData(vocab=cfg.vocab, batch=8, seq=128)

    # run two thirds, "crash", resume — the curve must continue seamlessly
    crash_at = args.steps * 2 // 3
    print(f"\n-- run until simulated crash at step {crash_at} --")
    out1 = train(cfg, tcfg, data, stop_after=crash_at)
    print("\n-- CRASH — restarting from latest checkpoint --")
    out2 = train(cfg, tcfg, data)
    losses = out1["losses"] + out2["losses"]
    print(f"\nfirst-20 mean loss {np.mean(losses[:20]):.3f} → "
          f"last-20 mean {np.mean(losses[-20:]):.3f} "
          f"(down {np.mean(losses[:20]) - np.mean(losses[-20:]):.3f})")


if __name__ == "__main__":
    main()
