"""The §6.2 Amazon-trace experiment on the synthetic stand-in: LOCALSWAP
in a tandem of embedding caches, unconstrained vs the barycenter-distance
constrained variant (the paper found the constraint costs only ~1%).

  PYTHONPATH=src python examples/amazon_trace.py
"""
from benchmarks.fig78_trace import run


def main():
    out = run(n_items=3000, k=80, ls_iters=10000)
    u = out["fig7_unconstrained"]
    c = out["fig7_constrained"]
    print(f"\nunconstrained LOCALSWAP cost: {u['cost']:.2f}")
    print(f"constrained (best d* = {c['best_dstar']:.0f}) cost: "
          f"{c['best_cost']:.2f}  (+{out['constrained_overhead_pct']:.1f}%)")
    print(f"leaf stores popular-or-central items: "
          f"{u['frac_leaf_popular_or_central']:.1%}")
    print("checks:", out["checks"])


if __name__ == "__main__":
    main()
