"""Streaming serving end to end: N concurrent request streams, bucketed
batches, and a placement that follows the demand without ever blocking
the request path.

    PYTHONPATH=src python examples/streaming_serve.py

The run has two demand phases. Phase 1 multiplexes four Poisson streams
(distinct Zipf permutations, distinct rates) through the StreamDriver:
arrivals coalesce into variable-size batches, every batch runs at its
power-of-two bucket shape (one XLA compile per bucket, however many
distinct sizes the arrival process produces), and the §5 NETDUEL plane
duels candidate placements on device inside the serving loop. A settled
promotion rebuilds the runtime cache *and* triggers a background
offline re-solve (EngineConfig.refresh_on_promotion): the solve runs on
the placement control plane while the old placement keeps serving, and
the finished allocation is swapped in atomically between batches — the
only serving-thread cost is the swap itself (milliseconds, bounded by
one batch).

Phase 2 replaces every stream's demand with a fresh permutation (the
population's interests drift all at once). Hit rate collapses, the duel
plane detects the drift through promotion churn, and the
refresh-on-promotion loop re-solves against the *new* observed window —
the engine recovers without a single synchronous refresh call.
"""
import dataclasses

from repro.configs.registry import get_smoke_config
from repro.core import catalog as catalog_api
from repro.core import demand as demand_api
from repro.models import model as model_api
from repro.serve import (EngineConfig, SimCacheEngine, StreamDriver,
                         StreamSpec)


def report(tag, eng, st):
    print(f"[{tag}] {st.n_requests} requests / {st.n_batches} batches "
          f"({st.distinct_batch_sizes} distinct sizes) "
          f"{st.requests_per_s:.0f} req/s")
    print(f"[{tag}]   latency p50/p95/p99 = "
          f"{st.p50_ms:.0f}/{st.p95_ms:.0f}/{st.p99_ms:.0f} ms; "
          f"hit rate so far {eng.stats.hit_rate:.1%}")
    print(f"[{tag}]   duel churn {st.placement_events}, background "
          f"swaps {st.swaps} (max stall {st.max_swap_stall_s*1e3:.1f} ms)"
          f", placement v{eng.placement.version}")


def main():
    cfg = dataclasses.replace(get_smoke_config("granite-3-2b"),
                              n_layers=2, d_model=64, n_heads=4,
                              n_kv_heads=2, head_dim=16, d_ff=128,
                              vocab=256)
    params = model_api.init_params(cfg, 0)
    cat = catalog_api.embedding_catalog(n=400, dim=16, seed=1)
    ecfg = EngineConfig(k_device=16, k_pod=24, k_global=32,
                        h_ici=1.0, h_dcn=10.0, h_model=100.0,
                        metric="l2", algo="greedy",
                        netduel=True, duel_window=128, duel_arm_prob=0.5,
                        refresh_on_promotion=True)
    eng = SimCacheEngine(cfg, params, ecfg, cat.coords)

    def make_streams(phase_seed):
        rates = [5.0, 9.0, 2.0, 4.0]
        return [StreamSpec(
            demand=demand_api.zipf(cat, alpha=1.1,
                                   seed=phase_seed * 100 + s),
            rate=rates[s], seed=s + 1, name=f"user{s}")
            for s in range(4)]

    drv = StreamDriver(eng, make_streams(1), max_batch=64,
                       batch_window=2.0)
    print("== cold start: observing demand, no placement yet ==")
    drv.run(128)
    pred = eng.refresh_placement()
    print(f"initial placement solved; predicted C(A) = {pred:.2f}\n")

    print("== phase 1: four streams, NETDUEL online, background "
          "refresh on promotion churn ==")
    st1 = drv.run(600)
    drv.drain_refresh()
    report("phase1", eng, st1)

    print("\n== phase 2: demand drifts (every stream re-permuted) ==")
    eng.stats = type(eng.stats)()             # fresh hit-rate window
    drv.set_streams(make_streams(2))
    st2 = drv.run(600)
    drv.drain_refresh()
    report("phase2", eng, st2)
    print(f"\nfinal: hit rate after drift {eng.stats.hit_rate:.1%}, "
          f"placement refreshed {eng.refresh_count}x "
          f"({eng.swap_count} async swaps, total stall "
          f"{eng.swap_stall_s*1e3:.1f} ms)")


if __name__ == "__main__":
    main()
