"""NETDUEL (§5) adapting online to a demand shift — the λ-unaware policy
tracks a moving Gaussian without ever being told the rates — now running
on the *device-resident online control plane*: each phase is one jitted
``lax.scan`` over the whole request window (``device_netduel``), priced
by the same gain machinery the offline solvers use, and benchmarked
against the device-GREEDY offline reference (the batched gain oracle of
kernels/knn/gains.py) — the same two paths ``serve.engine`` wires
together with ``EngineConfig.netduel`` / ``refresh_placement``.

Phase 1 also replays the window through the host NumPy policy to show
the device scan reproduces it bit-for-bit (the contract of
tests/test_netduel_device.py).

  PYTHONPATH=src python examples/netduel_online.py
"""
import numpy as np

from repro.core import catalog, demand, topology
from repro.core.objective import DeviceInstance, Instance
from repro.core.placement import device_greedy, device_netduel, netduel


def offline_reference(inst: Instance) -> float:
    """λ-aware device-GREEDY cost — the offline yardstick (§3.2)."""
    slots = device_greedy(DeviceInstance.from_instance(inst))
    return inst.total_cost(np.where(slots < 0, 0, slots))


def main():
    L, k = 30, 40
    cat = catalog.grid(L=L)
    net = topology.tandem(k_leaf=k, k_parent=k, h=2.0, h_repo=50.0)

    # phase 1: demand centered bottom-left; phase 2: top-right
    base = cat.coords - cat.coords.min(0)
    d1 = np.exp(-np.abs(base - L * 0.25).sum(1) ** 2 / (2 * (L / 8) ** 2))
    d2 = np.exp(-np.abs(base - L * 0.75).sum(1) ** 2 / (2 * (L / 8) ** 2))
    dem1 = demand.Demand(lam=(d1 / d1.sum())[None, :])
    dem2 = demand.Demand(lam=(d2 / d2.sum())[None, :])
    inst1 = Instance(net=net, cat=cat, dem=dem1)
    inst2 = Instance(net=net, cat=cat, dem=dem2)
    dinst1 = DeviceInstance.from_instance(inst1)
    dinst2 = DeviceInstance.from_instance(inst2)

    rng = np.random.default_rng(0)
    objs1, ing1 = dem1.sample(40000, rng)
    objs2, ing2 = dem2.sample(40000, rng)

    st = device_netduel(dinst1, requests=(objs1, ing1), window=1200,
                        arm_prob=0.3, record_events=True)
    c1 = inst1.total_cost(st.slots)
    ref1 = offline_reference(inst1)
    print(f"after phase 1: C(A | λ1) = {c1:.4f} "
          f"({st.n_promotions} promotions in one scan launch; "
          f"offline device-GREEDY ref {ref1:.4f})")

    # the host policy replays the same window to the same state, bit
    # for bit — the scan is a port of the decisions, not of the spirit
    st_host = netduel(inst1, requests=(objs1, ing1), window=1200,
                      arm_prob=0.3)
    assert np.array_equal(st_host.sw.slots, st.slots)
    assert st_host.promotions == st.promotions
    print("host NumPy NETDUEL replay: identical promotion sequence "
          f"({len(st.promotions)} events) and final slots")

    st2 = device_netduel(dinst2, requests=(objs2, ing2), window=1200,
                        arm_prob=0.3, slots0=st.slots)
    ref2 = offline_reference(inst2)
    print(f"right after shift: C(A_old | λ2) = "
          f"{inst2.total_cost(st.slots):.4f}")
    c2 = inst2.total_cost(st2.slots)
    print(f"after adaptation:  C(A_new | λ2) = {c2:.4f} "
          f"({st2.n_promotions} promotions; "
          f"offline device-GREEDY ref {ref2:.4f})")
    assert c2 < inst2.total_cost(st.slots)
    gap = c2 / ref2 - 1.0
    print(f"NetDuel recovered from the demand shift without knowing λ; "
          f"the device control plane prices its remaining gap to the "
          f"offline GREEDY reference at {100 * gap:.1f}%.")


if __name__ == "__main__":
    main()
