"""NETDUEL (§5) adapting online to a demand shift — the λ-unaware policy
tracks a moving Gaussian without ever being told the rates — benchmarked
against the *device-resident* offline control plane: after each phase,
one ``device_greedy`` solve (the batched gain oracle of
kernels/knn/gains.py) gives the λ-aware offline reference cost NETDUEL
is chasing, the same path ``serve.engine.refresh_placement`` takes on a
rolling window.

  PYTHONPATH=src python examples/netduel_online.py
"""
import numpy as np

from repro.core import catalog, demand, topology
from repro.core.objective import DeviceInstance, Instance
from repro.core.placement import device_greedy, netduel


def offline_reference(inst: Instance) -> float:
    """λ-aware device-GREEDY cost — the offline yardstick (§3.2)."""
    slots = device_greedy(DeviceInstance.from_instance(inst))
    return inst.total_cost(np.where(slots < 0, 0, slots))


def main():
    L, k = 30, 40
    cat = catalog.grid(L=L)
    net = topology.tandem(k_leaf=k, k_parent=k, h=2.0, h_repo=50.0)

    # phase 1: demand centered bottom-left; phase 2: top-right
    base = cat.coords - cat.coords.min(0)
    d1 = np.exp(-np.abs(base - L * 0.25).sum(1) ** 2 / (2 * (L / 8) ** 2))
    d2 = np.exp(-np.abs(base - L * 0.75).sum(1) ** 2 / (2 * (L / 8) ** 2))
    dem1 = demand.Demand(lam=(d1 / d1.sum())[None, :])
    dem2 = demand.Demand(lam=(d2 / d2.sum())[None, :])
    inst1 = Instance(net=net, cat=cat, dem=dem1)
    inst2 = Instance(net=net, cat=cat, dem=dem2)

    rng = np.random.default_rng(0)
    objs1, ing1 = dem1.sample(40000, rng)
    objs2, ing2 = dem2.sample(40000, rng)

    st = netduel(inst1, requests=(objs1, ing1), window=1200, arm_prob=0.3)
    c1 = st.sw.cost(inst1)
    ref1 = offline_reference(inst1)
    print(f"after phase 1: C(A | λ1) = {c1:.4f} "
          f"({st.n_promotions} promotions; "
          f"offline device-GREEDY ref {ref1:.4f})")

    st2 = netduel(inst2, requests=(objs2, ing2), window=1200, arm_prob=0.3,
                  slots0=st.sw.slots)
    ref2 = offline_reference(inst2)
    print(f"right after shift: C(A_old | λ2) = "
          f"{inst2.total_cost(st.sw.slots):.4f}")
    print(f"after adaptation:  C(A_new | λ2) = {st2.sw.cost(inst2):.4f} "
          f"({st2.n_promotions} promotions; "
          f"offline device-GREEDY ref {ref2:.4f})")
    assert st2.sw.cost(inst2) < inst2.total_cost(st.sw.slots)
    gap = st2.sw.cost(inst2) / ref2 - 1.0
    print(f"NetDuel recovered from the demand shift without knowing λ; "
          f"the device control plane prices its remaining gap to the "
          f"offline GREEDY reference at {100 * gap:.1f}%.")


if __name__ == "__main__":
    main()
