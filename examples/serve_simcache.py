"""End-to-end driver: serve a small LM with batched requests through the
similarity-cache network (the paper's system deployed in front of a real
model — DESIGN.md §2).

Flow: cold phase (every request runs the model) → the engine's control
plane solves the paper's placement problem on the observed demand →
warm phase (most requests served by approximizers). Reports hit rate,
mean serving cost (in calibrated ms units), and model-call savings.

  PYTHONPATH=src python examples/serve_simcache.py
"""
import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.core import catalog as catalog_api
from repro.core import demand as demand_api
from repro.models import model as model_api
from repro.serve import EngineConfig, SimCacheEngine


def main():
    # a ~5M-param decoder LM as the "repository"
    cfg = dataclasses.replace(
        get_smoke_config("granite-3-2b"), n_layers=4, d_model=128,
        n_heads=4, n_kv_heads=2, head_dim=32, d_ff=256, vocab=512)
    params = model_api.init_params(cfg, 0)

    # request universe: 2000 embedded queries, Zipf popularity
    cat = catalog_api.embedding_catalog(n=2000, dim=32, seed=0)
    dem = demand_api.zipf(cat, alpha=1.1, seed=1)
    ecfg = EngineConfig(k_device=32, k_pod=64, k_global=96, metric="l2",
                        algo="cascade")
    eng = SimCacheEngine(cfg, params, ecfg, cat.coords)

    ms = eng.calibrate(jnp.zeros((16, 16), jnp.int32))
    print(f"calibrated: model forward = {ms:.1f} ms  "
          f"(h_ici {eng.ecfg.h_ici:.2f}, h_dcn {eng.ecfg.h_dcn:.2f})\n")

    rng = np.random.default_rng(0)

    def run_phase(name, n_batches, seed):
        eng.stats = type(eng.stats)()
        r = np.random.default_rng(seed)
        for _ in range(n_batches):
            ids, _ = dem.sample(16, r)
            prompts = jnp.asarray(
                r.integers(0, cfg.vocab, (16, 16)).astype(np.int32))
            eng.serve(ids, prompts)
        s = eng.stats
        print(f"{name:18s} hit-rate {s.hit_rate:5.1%}  "
              f"mean cost {s.mean_cost:8.2f}  model calls {s.model_calls}")

    run_phase("cold (no cache)", 8, seed=1)
    pred = eng.refresh_placement()
    print(f"\nplacement solved (cascade): predicted C(A) = {pred:.2f}\n")
    run_phase("warm (cached)", 8, seed=2)
    _ = rng


if __name__ == "__main__":
    main()
