"""Quickstart: the paper's content-placement problem in 30 lines.

Builds the §6.1 setup (grid catalog, Gaussian demand, tandem cache
network), solves placement with all four algorithms, and prints the
expected serving cost of each — reproducing the Fig. 3 ordering
(LocalSwap ≤ Greedy ≤ NetDuel, with the continuous approximation close).

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import catalog, demand, topology
from repro.core.objective import Instance
from repro.core.placement import (continuous, greedy, localswap, netduel,
                                  greedy_then_localswap)


def main():
    L, k, h, h_repo = 30, 30, 2.0, 50.0
    cat = catalog.grid(L=L)                      # 900 objects, norm-1
    net = topology.tandem(k_leaf=k, k_parent=k, h=h, h_repo=h_repo)
    dem = demand.gaussian_grid(cat, sigma=L / 8)
    inst = Instance(net=net, cat=cat, dem=dem)
    print(f"catalog {cat.n} objects; caches {k}+{k}; "
          f"no-cache cost C(∅) = {inst.empty_cost():.3f}\n")

    slots = greedy(inst)
    print(f"GREEDY              C(A) = {inst.total_cost(slots):.4f}")
    st = localswap(inst, n_iters=8000)
    print(f"LOCALSWAP           C(A) = {st.cost(inst):.4f} "
          f"({st.n_swaps} swaps)")
    casc = greedy_then_localswap(inst)
    print(f"GREEDY→LOCALSWAP    C(A) = {casc.cost(inst):.4f}  (Remark 1)")
    nd = netduel(inst, n_iters=40000, window=1500, arm_prob=0.3)
    print(f"NETDUEL (online)    C(A) = {nd.sw.cost(inst):.4f} "
          f"({nd.n_promotions} promotions)")
    spec = continuous.ChainSpec(ks=(float(k), float(k)), hs=(0.0, h),
                                h_repo=h_repo, gamma=1.0)
    _, c_cont, _ = continuous.solve_chain_thresholds(inst.lam[0], spec)
    print(f"continuous (11)     C    = {c_cont:.4f}  (Prop 4.2 thresholds)")


if __name__ == "__main__":
    main()
