"""Differential suite for the int8 quantized first-pass lookup
(kernels/quant.py + kernels/knn/ops.quantized_fused_lookup) in front of
the fused segmented-1-NN scan.

Three requirements, mirroring test_lsh_pruning.py's structure:

  * **exactness** — ``lookup(quantize=True, verify=True)`` re-scans
    every query whose winning cost reaches the per-query vT certificate
    and must be **bit-identical** to the exact fused path on every
    covered configuration: all metrics, γ ≠ 1, B = 1 and multi-tile
    batches, tiny and full-width top_t, single-device and sharded, and
    composed with LSH pruning;
  * **admissibility** — the unverified quantized lookup scans exact
    costs only over its top-T candidate union, so its winning cost can
    never be *below* the exact cost, and a top_t covering every key
    makes the first pass a pure re-indexing (bit-exact, bound +INF);
  * **oracle** — the jitted entry and the pure-jnp reference
    (quantized_fused_lookup_ref) agree on winners/costs/bound, one-way
    and shard-chunked.

The 10⁶-key quantized+pruned+sharded differential is CI_FULL-gated
(scripts/ci.sh full pass); the 8-way mesh tests run in ci.sh pass 2
under XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import assert_results_equal, make_net

from repro.kernels import quant
from repro.kernels.knn import (SimHashPolicy, quantized_fused_lookup,
                               quantized_fused_lookup_ref,
                               sharded_quantized_fused_lookup_ref)

EIGHT = jax.device_count() >= 8

CONFIGS = [
    (0, [5, 9, 3], [0.0, 0.5, 1.0], 2.0, 23),
    (1, [17, 2, 31, 8], [0.0, 0.2, 0.7, 1.3], 3.0, 1),       # B=1
    (5, [200, 150, 250], [0.0, 0.4, 0.8], 2.5, 700),   # 3 query tiles
]


# ------------------------------------------------------------- exactness
@pytest.mark.parametrize("metric,gamma", [("l2", 1.0), ("l1", 1.0),
                                          ("l2sq", 1.0), ("l2", 2.0)])
@pytest.mark.parametrize("top_t", [2, 16])
def test_quantized_verify_bit_identical(metric, gamma, top_t):
    """verify=True must reproduce the exact fused path bit-for-bit,
    whatever the int8 ranks missed at this rescore width — covering B=1
    and a 700-query multi-tile batch."""
    for seed, sizes, hs, h_repo, nq in CONFIGS:
        net, rng = make_net(seed, sizes, hs, h_repo, metric, gamma)
        q = jnp.asarray((rng.standard_normal((nq, 6)) * 2)
                        .astype(np.float32))
        res = net.lookup(q, quantize=True, verify=True, top_t=top_t)
        assert_results_equal(res, net._lookup_fused(q),
                             exact_cost=gamma == 1.0)


def test_quantized_verify_bit_identical_sharded():
    """Same contract through the mesh-sharded data plane (per-shard
    QuantizedRows + fold_repo=False launches + per-query min of the
    per-shard vT bounds)."""
    mesh = jax.make_mesh((1,), ("data",))
    net, rng = make_net(1, [17, 2, 31, 8], [0.0, 0.2, 0.7, 1.3], 3.0)
    snet, _ = make_net(1, [17, 2, 31, 8], [0.0, 0.2, 0.7, 1.3], 3.0,
                       sharded=True, mesh=mesh)
    q = jnp.asarray((rng.standard_normal((23, 6)) * 2).astype(np.float32))
    res = snet.lookup(q, quantize=True, verify=True, top_t=4)
    assert_results_equal(res, net._lookup_fused(q))
    assert_results_equal(res, snet.lookup(q))


def test_quantized_composes_with_lsh_pruning():
    """quantize=True under prune="lsh" sub-cuts the LSH candidate union
    with the int8 ranks; verify=True still closes both gaps to 0."""
    pol = SimHashPolicy(n_tables=2, n_bits=4, n_probes=2)
    net, rng = make_net(9, [100, 300], [0.2, 0.8], 3.0,
                        candidate_policy=pol)
    q = jnp.asarray(rng.standard_normal((32, 6)).astype(np.float32))
    exact = net._lookup_fused(q)
    res = net.lookup(q, prune="lsh", verify=True, quantize=True, top_t=8)
    assert_results_equal(res, exact)
    # unverified composition stays admissible
    got = net.lookup(q, prune="lsh", quantize=True, top_t=8)
    assert np.all(np.asarray(got.cost) >= np.asarray(exact.cost))


def test_quantized_full_width_equals_exact_without_verify():
    """top_t ≥ n_keys keeps every key in the rescore union: the first
    pass is a pure re-indexing of the exact scan — bit-identical even
    with verify=False, and the certificate is +INF (nothing cut)."""
    net, rng = make_net(2, [64, 64], [0.0, 1.0], 5.0)
    q = jnp.asarray((rng.standard_normal((23, 6)) * 2).astype(np.float32))
    assert_results_equal(net.lookup(q, quantize=True, top_t=4096),
                         net._lookup_fused(q))
    keys, h_key, meta = net.fused_layout()
    *_, bound = quantized_fused_lookup_ref(q, keys, h_key, meta,
                                           top_t=int(keys.shape[0]),
                                           h_repo=5.0)
    assert np.all(np.asarray(bound) >= 1e38)


# ---------------------------------------------------------- admissibility
@pytest.mark.parametrize("metric,gamma", [("l2", 1.0), ("l1", 0.7),
                                          ("l2sq", 1.0), ("l2", 2.0)])
def test_quantized_unverified_admissible(metric, gamma):
    """Without verification the quantized winner can only be *worse*
    (cost ≥ exact): the exact rescore runs over a subset of the keys,
    and the lower-bound cut is certified for every pair."""
    net, rng = make_net(3, [80, 120, 60], [0.0, 0.4, 0.9], 2.5, metric,
                        gamma)
    q = jnp.asarray((rng.standard_normal((64, 6)) * 2).astype(np.float32))
    exact = net._lookup_fused(q)
    for tt in (1, 4, 32):
        got = net.lookup(q, quantize=True, top_t=tt)
        assert np.all(np.asarray(got.cost) >= np.asarray(exact.cost)), tt
        assert np.all(np.asarray(got.cost) <= net.h_repo + 1e-6)


def test_quantized_certificate_is_honest():
    """Queries whose unverified cost already beats the vT certificate
    provably hold the exact winner — those rows must be bitwise the
    exact result even with verify=False."""
    net, rng = make_net(4, [150, 90], [0.0, 0.6], 3.0)
    q = jnp.asarray((rng.standard_normal((64, 6)) * 2).astype(np.float32))
    exact = net._lookup_fused(q)
    keys, h_key, meta = net.fused_layout()
    out = quantized_fused_lookup(q, keys, h_key, meta,
                                 net._quant_rows(0), top_t=4,
                                 metric=net.metric, gamma=net.gamma,
                                 h_repo=net.h_repo,
                                 use_pallas=net.use_pallas)
    cost, ac, level, slot, payload, bound = out
    safe = np.asarray(cost) < np.asarray(bound)
    assert safe.any()                 # the cut certifies some rows
    for got, want in [(cost, exact.cost), (ac, exact.approx_cost),
                      (level, exact.level), (slot, exact.slot),
                      (payload, exact.payload)]:
        np.testing.assert_array_equal(np.asarray(got)[safe],
                                      np.asarray(want)[safe])


# ------------------------------------------------------ ops — ref oracle
def test_quantized_ops_matches_ref_oracle():
    """The jitted entry and the pure-jnp oracle run the same first-pass
    selection and the same exact rescore: same winners, costs to 1e-6,
    bounds to 1-ulp (jit CSE can re-associate the lb scores)."""
    net, rng = make_net(7, [40, 25], [0.0, 0.4], 2.0, "l2", gamma=2.0)
    q = jnp.asarray(rng.standard_normal((19, 6)).astype(np.float32))
    keys, h_key, meta = net.fused_layout()
    kq = quant.quantize_rows(keys, "l2")
    out_k = quantized_fused_lookup(q, keys, h_key, meta, kq, top_t=8,
                                   metric="l2", gamma=2.0, h_repo=2.0)
    out_r = quantized_fused_lookup_ref(q, keys, h_key, meta, kq=kq,
                                       top_t=8, metric="l2", gamma=2.0,
                                       h_repo=2.0)
    for a, b in zip(out_k, out_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)


def test_quantized_sharded_ref_matches_one_way():
    """Chunked-oracle consistency: per-row quantization makes an S-chunk
    scan of the int8 image equivalent to the one-way scan + per-query
    min of the chunk certificates."""
    net, rng = make_net(8, [60, 45, 30], [0.0, 0.3, 0.9], 2.5)
    q = jnp.asarray(rng.standard_normal((17, 6)).astype(np.float32))
    keys, h_key, meta = net.fused_layout()
    one = quantized_fused_lookup_ref(q, keys, h_key, meta, top_t=6,
                                     h_repo=2.5)
    for s in (2, 4):
        chk = sharded_quantized_fused_lookup_ref(q, keys, h_key, meta, s,
                                                 top_t=6, h_repo=2.5)
        # winners/costs must be admissible vs the one-way oracle: each
        # chunk rescoring its own top-6 can only widen the union
        assert np.all(np.asarray(chk[0]) <= np.asarray(one[0]) + 1e-6)
        assert np.all(np.asarray(chk[0])
                      >= np.asarray(net._lookup_fused(q).cost) - 1e-6)


# --------------------------------------------------------------- plumbing
def test_quant_rows_memo_and_invalidation():
    """The plain quantized path memoizes QuantizedRows per layout;
    invalidate_layout() drops them with the other tables."""
    net, rng = make_net(11, [50, 80], [0.2, 0.8], 3.0)
    q = jnp.asarray(rng.standard_normal((8, 6)).astype(np.float32))
    net.lookup(q, quantize=True)
    assert any(k[0] == "quant_rows" for k in net._tables)
    net.lookup(q, quantize=True)
    assert sum(k[0] == "quant_rows" for k in net._tables) == 1   # a hit
    net.invalidate_layout()
    assert not net._tables


# ------------------------------------------------------------------- mesh
@pytest.mark.skipif(not EIGHT, reason="needs 8 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
def test_quantized_eight_way_differential():
    mesh = jax.make_mesh((8,), ("data",))
    for seed, sizes, hs, h_repo, nq in CONFIGS:
        net, rng = make_net(seed, sizes, hs, h_repo)
        snet, _ = make_net(seed, sizes, hs, h_repo, sharded=True,
                           mesh=mesh)
        q = jnp.asarray((rng.standard_normal((nq, 6)) * 2)
                        .astype(np.float32))
        res = snet.lookup(q, quantize=True, verify=True, top_t=4)
        assert_results_equal(res, net._lookup_fused(q))


@pytest.mark.skipif(not EIGHT, reason="needs 8 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
def test_quantized_plus_lsh_eight_way():
    mesh = jax.make_mesh((8,), ("data",))
    net, rng = make_net(5, [200, 150, 250], [0.0, 0.4, 0.8], 2.5)
    snet, _ = make_net(5, [200, 150, 250], [0.0, 0.4, 0.8], 2.5,
                       sharded=True, mesh=mesh)
    q = jnp.asarray((rng.standard_normal((300, 6)) * 2).astype(np.float32))
    res = snet.lookup(q, prune="lsh", quantize=True, verify=True, top_t=8)
    assert_results_equal(res, net._lookup_fused(q))
