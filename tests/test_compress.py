"""Unit tests for ft/compress.py and the shared int8 quantizer it now
re-exports from kernels/quant.py — round-trip error bounds, the
explicit all-zero-row guard, metric-space radius bounds, and the
axis_size compatibility helper (regression for the removed
``jax.lax.axis_size``; the cross-pod mean itself is exercised on an
8-device mesh in test_distributed.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ft.compress import axis_size, dequantize_int8, quantize_int8
from repro.kernels import quant


def test_quantize_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((32, 128)).astype(np.float32) * 5)
    q, s = quantize_int8(x)
    assert q.dtype == jnp.int8
    deq = dequantize_int8(q, s)
    # per-row max-abs scaling → absolute error ≤ scale/2 per element
    err = np.max(np.abs(np.asarray(deq - x)), axis=-1)
    bound = np.asarray(s)[:, 0]
    assert np.all(err <= bound), (err, bound)


def test_compress_quantizer_is_the_shared_one():
    """ft/compress and the kernels must quantize through one function:
    the re-export is identity, not a copy that could drift."""
    assert quantize_int8 is quant.quantize_int8
    assert dequantize_int8 is quant.dequantize_int8


def test_quantize_all_zero_row_guard():
    """All-zero rows get scale exactly 0.0 (not the historic 1e-20
    denormal floor): q == 0, dequant == exact zeros, radius == 0."""
    x = jnp.zeros((3, 16), jnp.float32)
    q, s = quantize_int8(x)
    np.testing.assert_array_equal(np.asarray(q), 0)
    assert np.all(np.asarray(s) == 0.0)          # exactly 0.0, not tiny
    np.testing.assert_array_equal(np.asarray(dequantize_int8(q, s)), 0.0)
    for metric in ("l2", "l2sq", "l1"):
        r = quant.quant_row_radius(s[:, 0], 16, metric)
        np.testing.assert_array_equal(np.asarray(r), 0.0)
    # mixed batch: zero rows keep the exact-zero guarantee alongside
    # normal rows, and sub-denormal rows never produce inf/NaN (XLA may
    # flush them to zero — then scale is exactly 0.0, same as zero rows,
    # consistent with what the FTZ exact kernel sees)
    x2 = jnp.asarray(np.array([[0.0] * 8,
                               [1e-42] * 8,
                               [3.0] + [0.0] * 7], np.float32))
    q2, s2 = quantize_int8(x2)
    deq2 = np.asarray(dequantize_int8(q2, s2))
    assert np.all(np.isfinite(deq2))
    np.testing.assert_array_equal(deq2[0], 0.0)
    err = np.abs(deq2 - np.asarray(x2))
    live = np.asarray(s2)[:, 0] > 0.0
    assert np.all(err[live] <= np.asarray(s2)[live] * quant.ELEM_ERR)


@pytest.mark.parametrize("metric", ["l2", "l2sq", "l1"])
def test_quant_row_radius_bounds_roundtrip_distance(metric):
    """The per-row radius must dominate the metric distance between a
    row and its dequantized image — the triangle-inequality ingredient
    of every certified lower bound downstream."""
    rng = np.random.default_rng(7)
    scales = np.array([1e-3, 1.0, 50.0], np.float32)
    x = rng.standard_normal((len(scales), 24, 48)).astype(np.float32)
    x = (x * scales[:, None, None]).reshape(-1, 48)
    rows = quant.quantize_rows(jnp.asarray(x), metric)
    deq = np.asarray(dequantize_int8(rows.q, rows.scale))
    diff = deq - x
    if metric == "l1":
        d = np.abs(diff).sum(-1)
    else:
        d = np.sqrt((diff * diff).sum(-1))   # radius is in distance units
    assert np.all(d <= np.asarray(rows.radius) + 1e-30), metric


def test_axis_size_compat_under_named_axis():
    """axis_size must work inside any named-axis context on current JAX
    (jax.lax.axis_size was removed; psum(1, axis) is the fallback)."""
    out = jax.vmap(lambda x: x * axis_size("i"), axis_name="i")(
        jnp.ones((4,)))
    np.testing.assert_array_equal(np.asarray(out), 4.0)


def test_crosspod_leaf_has_no_removed_api_calls():
    """Regression: _crosspod_leaf called jax.lax.axis_size, removed from
    the installed JAX — it must go through the compat helper (or not
    need the size at all, as the gathered leading dim carries it)."""
    import inspect

    from repro.ft import compress
    assert "jax.lax.axis_size" not in inspect.getsource(
        compress._crosspod_leaf)
