"""Unit tests for ft/compress.py — int8 quantization bounds and the
axis_size compatibility helper (regression for the removed
``jax.lax.axis_size``; the cross-pod mean itself is exercised on an
8-device mesh in test_distributed.py)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.ft.compress import axis_size, dequantize_int8, quantize_int8


def test_quantize_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((32, 128)).astype(np.float32) * 5)
    q, s = quantize_int8(x)
    assert q.dtype == jnp.int8
    deq = dequantize_int8(q, s)
    # per-row max-abs scaling → absolute error ≤ scale/2 per element
    err = np.max(np.abs(np.asarray(deq - x)), axis=-1)
    bound = np.asarray(s)[:, 0]
    assert np.all(err <= bound), (err, bound)


def test_axis_size_compat_under_named_axis():
    """axis_size must work inside any named-axis context on current JAX
    (jax.lax.axis_size was removed; psum(1, axis) is the fallback)."""
    out = jax.vmap(lambda x: x * axis_size("i"), axis_name="i")(
        jnp.ones((4,)))
    np.testing.assert_array_equal(np.asarray(out), 4.0)


def test_crosspod_leaf_has_no_removed_api_calls():
    """Regression: _crosspod_leaf called jax.lax.axis_size, removed from
    the installed JAX — it must go through the compat helper (or not
    need the size at all, as the gathered leading dim carries it)."""
    import inspect

    from repro.ft import compress
    assert "jax.lax.axis_size" not in inspect.getsource(
        compress._crosspod_leaf)
