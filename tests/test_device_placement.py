"""Differential tests of the device-resident placement control plane.

Device GREEDY / LOCALSWAP (core/placement/device.py, driven by the
batched gain oracle of kernels/knn/gains.py) must return allocations
**bit-identical** to the host NumPy oracles (greedy.py / localswap.py)
— same lowest-(o', j) and lowest-slot tie-breaks — on Gaussian-grid and
Zipf-embedding instances, in both C_a modes (materialized matrix /
streamed distance tiles), through both oracle backends (blocked jnp /
Pallas-interpret), and at any shard count (the in-process mesh tests
run 1-way in the default tier-1 pass and 8-way in scripts/ci.sh's
second pass).

The Gaussian grid demand is jittered deterministically: the exact grid
symmetry otherwise produces *exactly tied* gains whose f32-vs-f64
summation noise would make "bit-identical" depend on accumulation
order rather than on the tie-break contract. Genuine tie handling is
covered separately by the duplicate-object and gain_tol tests.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import catalog, demand, topology
from repro.core.objective import DeviceInstance, Instance, random_slots
from repro.core.placement import (device_greedy,
                                  device_greedy_then_localswap,
                                  device_localswap,
                                  device_localswap_polish, greedy,
                                  greedy_then_localswap, localswap,
                                  localswap_polish)
from repro.kernels.knn import (placement_gains, placement_gains_ref,
                               sharded_placement_gains)
from repro.launch.mesh import make_lookup_mesh

TOL = 1e-5          # one decision margin for host and device swap paths


def gauss_instance(L=8, k=(3, 4), sigma=2.0, seed=0):
    """§6.1 grid/Gaussian instance, demand jittered to break the grid's
    exact gain ties (see module docstring)."""
    cat = catalog.grid(L=L)
    net = topology.tandem(k_leaf=k[0], k_parent=k[1], h=2.0, h_repo=10.0)
    dem0 = demand.gaussian_grid(cat, sigma=sigma)
    rng = np.random.default_rng(seed)
    lam = dem0.lam * (1.0 + 1e-3 * rng.random(dem0.lam.shape))
    return Instance(net=net, cat=cat,
                    dem=demand.Demand(lam=lam / lam.sum()))


def zipf_instance(n=180, dim=6, k=(8, 12), seed=1):
    """§6.2 embedding/Zipf instance (tandem)."""
    cat = catalog.embedding_catalog(n=n, dim=dim, seed=seed)
    net = topology.tandem(k_leaf=k[0], k_parent=k[1], h=50.0, h_repo=400.0)
    return Instance(net=net, cat=cat,
                    dem=demand.zipf(cat, alpha=0.8, seed=seed + 1))


def tree_instance(seed=3):
    """Multi-ingress instance: 2-leaf equi-depth tree (§4.3) — exercises
    the gain oracle's ingress-segment axis."""
    cat = catalog.embedding_catalog(n=150, dim=4, seed=seed)
    net = topology.equi_depth_tree(2, 1, [4, 6], [0.0, 30.0], 300.0)
    dem = demand.zipf(cat, alpha=0.7, n_ingress=net.n_ingress, seed=seed)
    return Instance(net=net, cat=cat, dem=dem)


ALL_INSTANCES = [("gauss", gauss_instance), ("zipf", zipf_instance),
                 ("tree", tree_instance)]


# ------------------------------------------------------------- gain oracle
@pytest.mark.parametrize("metric", ["l1", "l2"])
def test_gain_kernel_matches_ref_and_host(metric):
    """Pallas kernel == jnp oracle == blocked jnp path == host
    add_gain_all, on a multi-ingress request matrix (the segment axis
    the kernels/gain kernel lacks)."""
    rng = np.random.default_rng(5)
    R, O, D, I, J = 117, 83, 5, 2, 3
    x = jnp.asarray(rng.standard_normal((R, D)).astype(np.float32))
    y = jnp.asarray(rng.standard_normal((O, D)).astype(np.float32))
    lam = jnp.asarray(rng.random((I, R)).astype(np.float32))
    cur = jnp.asarray((rng.random((I, R)) * 4).astype(np.float32))
    h = rng.random((I, J)).astype(np.float32)
    h[1, 0] = np.inf                                   # off-path entry
    hj = jnp.asarray(h)
    ref = placement_gains_ref(x, y, lam, cur,
                              jnp.where(jnp.isfinite(hj), hj, 1e30), metric)
    g_pl = placement_gains(x, y, lam, cur, hj, metric=metric,
                           use_pallas=True, interpret=True, br=32, bo=32)
    g_jnp = placement_gains(x, y, lam, cur, hj, metric=metric,
                            use_pallas=False, bo=32)
    np.testing.assert_allclose(g_pl, ref, rtol=5e-5, atol=5e-5)
    np.testing.assert_allclose(g_jnp, ref, rtol=5e-5, atol=5e-5)
    assert np.all(np.asarray(g_pl) >= 0.0)


def test_gain_oracle_matches_host_on_instance():
    inst = tree_instance()
    cur = np.repeat(inst.net.h_repo[:, None].astype(np.float64),
                    inst.cat.n, axis=1)
    ref = inst.add_gain_all(cur)                       # (O, J) host f64
    dinst = DeviceInstance.from_instance(inst, materialize_ca=False)
    g = dinst.gains(jnp.asarray(cur, jnp.float32))
    np.testing.assert_allclose(np.asarray(g), ref, rtol=1e-4, atol=1e-4)
    dmat = DeviceInstance.from_instance(inst, materialize_ca=True)
    gm = dmat.gains(jnp.asarray(cur, jnp.float32))
    np.testing.assert_allclose(np.asarray(gm), ref, rtol=1e-4, atol=1e-4)


def test_sharded_gain_oracle_bitwise_equal():
    """Candidate-axis sharding never changes a gain value: every
    candidate's sum is computed with identical request tiling in its
    one owning shard (1-way mesh in the default pass, 8-way in
    scripts/ci.sh pass 2)."""
    inst = zipf_instance(n=133)
    dinst = DeviceInstance.from_instance(inst, materialize_ca=False)
    cur = dinst.initial_costs()
    mesh = make_lookup_mesh(jax.device_count())
    gs = sharded_placement_gains(
        dinst.coords, dinst.coords, dinst.lam, cur, dinst.H, mesh,
        ("data",), metric=dinst.metric, gamma=dinst.gamma,
        use_pallas=False)
    gu = dinst.gains(cur)
    np.testing.assert_array_equal(np.asarray(gs), np.asarray(gu))


# ------------------------------------------------------------------ GREEDY
@pytest.mark.parametrize("name,make", ALL_INSTANCES)
@pytest.mark.parametrize("materialize", [True, False])
def test_device_greedy_bit_identical(name, make, materialize):
    inst = make()
    host_lazy = greedy(inst, lazy=True)
    host_eager = greedy(inst, lazy=False)
    np.testing.assert_array_equal(host_lazy, host_eager)
    dinst = DeviceInstance.from_instance(inst, materialize_ca=materialize)
    dev = device_greedy(dinst)
    np.testing.assert_array_equal(dev, host_lazy)


def test_device_greedy_through_pallas_oracle():
    """Same allocation when the full-gain launch goes through the
    Pallas kernel (interpret mode) instead of the blocked jnp path."""
    inst = zipf_instance(n=140, k=(5, 7))
    dinst = DeviceInstance.from_instance(inst, materialize_ca=False,
                                         use_pallas=True, interpret=True)
    np.testing.assert_array_equal(device_greedy(dinst), greedy(inst))


def test_device_greedy_sharded_bit_identical():
    """Mesh-sharded gain oracle → same allocation (8-way in CI pass 2)."""
    inst = zipf_instance(n=170, k=(6, 9), seed=4)
    mesh = make_lookup_mesh(jax.device_count())
    dinst = DeviceInstance.from_instance(inst, mesh=mesh, axes=("data",),
                                         materialize_ca=False)
    assert dinst.n_shards == jax.device_count()
    np.testing.assert_array_equal(device_greedy(dinst), greedy(inst))


def test_device_greedy_small_topk_still_exact():
    """The stale-refresh batch size is a perf knob, not a semantics
    knob: topk=1 degenerates to classic lazy greedy, same allocation."""
    inst = zipf_instance(n=90, k=(4, 5), seed=9)
    dinst = DeviceInstance.from_instance(inst)
    np.testing.assert_array_equal(device_greedy(dinst, topk=1),
                                  greedy(inst))


def test_device_gains_monotone_along_greedy_trajectory():
    """Submodularity (Prop 3.2) observed by the device oracle: marginal
    gains are monotone non-increasing along the greedy trajectory."""
    inst = gauss_instance(L=6, k=(3, 3))
    dinst = DeviceInstance.from_instance(inst, materialize_ca=False)
    cur = dinst.initial_costs()
    slots = device_greedy(dinst)
    prev = np.asarray(dinst.gains(cur))
    order = [int(s) for s in np.argsort(inst.slot_cache, kind="stable")]
    # replay the allocation pick by pick (per-cache slot order = pick
    # order within a cache; across caches the gain argmax decides, but
    # monotonicity must hold along *any* insertion order)
    for s in order:
        if slots[s] < 0:
            continue
        cur = dinst.apply_pick(cur, int(slots[s]),
                               int(inst.slot_cache[s]))
        g = np.asarray(dinst.gains(cur))
        assert np.all(g <= prev + 1e-4), np.max(g - prev)
        prev = g


# --------------------------------------------------------------- LOCALSWAP
@pytest.mark.parametrize("name,make", [ALL_INSTANCES[0], ALL_INSTANCES[1]])
def test_device_localswap_bit_identical(name, make):
    inst = make()
    dinst = DeviceInstance.from_instance(inst)
    hs = localswap(inst, n_iters=500, seed=7, tol=TOL)
    ds = device_localswap(dinst, n_iters=500, seed=7, tol=TOL)
    np.testing.assert_array_equal(hs.slots, ds.slots_np)
    assert hs.n_swaps == ds.n_swaps


@pytest.mark.parametrize("materialize", [True, False])
def test_device_polish_and_cascade_bit_identical(materialize):
    inst = zipf_instance(n=120, k=(5, 6), seed=2)
    dinst = DeviceInstance.from_instance(inst, materialize_ca=materialize)
    rng = np.random.default_rng(11)
    s0 = random_slots(inst, rng)
    hp = localswap_polish(inst, s0, max_passes=6, tol=TOL)
    dp = device_localswap_polish(dinst, s0, max_passes=6, tol=TOL)
    np.testing.assert_array_equal(hp.slots, dp.slots_np)
    assert hp.n_swaps == dp.n_swaps
    hc = greedy_then_localswap(inst, max_passes=6, tol=TOL)
    dc = device_greedy_then_localswap(dinst, max_passes=6, tol=TOL)
    np.testing.assert_array_equal(hc.slots, dc.slots_np)


def test_device_total_cost_matches_host():
    inst = zipf_instance(n=100, k=(4, 4))
    dinst = DeviceInstance.from_instance(inst, materialize_ca=False)
    slots = greedy(inst)
    slots = np.where(slots < 0, 0, slots)
    assert dinst.total_cost(slots) == pytest.approx(
        inst.total_cost(slots), rel=1e-5)


# ------------------------------------------------------- ties and gain_tol
def test_gain_tol_near_ties_resolve_by_index():
    """gain_tol regression (host oracle honesty): duplicated catalog
    points produce *exactly* tied candidate gains; every path — host
    lazy, host eager, device — must resolve them to the lowest (o', j)
    flat index, and a gain_tol above the best gain must leave all slots
    empty everywhere."""
    rng = np.random.default_rng(0)
    base = rng.uniform(0, 4, size=(12, 3)).astype(np.float32)
    coords = np.concatenate([base, base[:4]])          # exact duplicates
    cat = catalog.Catalog(coords=coords, metric="l2")
    net = topology.tandem(k_leaf=3, k_parent=3, h=0.5, h_repo=5.0)
    lam = np.concatenate([rng.random(12) + 0.05,
                          (rng.random(4) + 0.05)])[None, :]
    inst = Instance(net=net, cat=cat,
                    dem=demand.Demand(lam=lam / lam.sum()))
    lazy = greedy(inst, lazy=True)
    eager = greedy(inst, lazy=False)
    dev = device_greedy(DeviceInstance.from_instance(inst))
    np.testing.assert_array_equal(lazy, eager)
    np.testing.assert_array_equal(lazy, dev)
    placed = lazy[lazy >= 0]
    # a duplicate pair's gains tie exactly → the lower id must win
    assert not np.any(placed >= 12), placed
    # gain_tol above every gain: nothing is ever placed, on any path
    cur = np.repeat(inst.net.h_repo[:, None].astype(np.float64),
                    inst.cat.n, axis=1)
    big = float(inst.add_gain_all(cur).max()) + 1.0
    for slots in (greedy(inst, lazy=True, gain_tol=big),
                  greedy(inst, lazy=False, gain_tol=big),
                  device_greedy(DeviceInstance.from_instance(inst),
                                gain_tol=big)):
        assert np.all(slots == -1)
