"""End-to-end serving-engine tests: the paper's cache network in front of
a real (tiny) model on CPU."""
import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.core import catalog as catalog_api
from repro.core import demand as demand_api
from repro.models import model as model_api
from repro.serve import EngineConfig, SimCacheEngine


def make_engine(k=(16, 24, 32), algo="cascade", sharded=False, mesh=None):
    cfg = dataclasses.replace(get_smoke_config("granite-3-2b"),
                              n_layers=2, d_model=64, n_heads=4,
                              n_kv_heads=2, head_dim=16, d_ff=128, vocab=256)
    params = model_api.init_params(cfg, 0)
    cat = catalog_api.embedding_catalog(n=400, dim=16, seed=1)
    ecfg = EngineConfig(k_device=k[0], k_pod=k[1], k_global=k[2],
                        h_ici=1.0, h_dcn=10.0, h_model=100.0,
                        metric="l2", algo=algo, sharded=sharded)
    eng = SimCacheEngine(cfg, params, ecfg, cat.coords, mesh=mesh)
    return eng, cfg, cat


def serve_trace(eng, cfg, cat, n_batches=12, batch=16, seed=0):
    rng = np.random.default_rng(seed)
    dem = demand_api.zipf(cat, alpha=1.1, seed=3)
    for _ in range(n_batches):
        ids, _ = dem.sample(batch, rng)
        prompts = jnp.asarray(
            rng.integers(0, cfg.vocab, (batch, 8)).astype(np.int32))
        eng.serve(ids, prompts)
    return eng.stats


def test_engine_cold_then_cached():
    eng, cfg, cat = make_engine()
    stats = serve_trace(eng, cfg, cat, n_batches=4)
    assert stats.hit_rate == 0.0                 # no placement yet
    pred = eng.refresh_placement()
    assert pred > 0
    eng.stats = type(eng.stats)()                # count only warm phase
    stats = serve_trace(eng, cfg, cat, n_batches=8, seed=1)
    assert stats.hit_rate > 0.5, stats.hit_rate  # cache absorbs the head
    assert stats.model_calls < 10


def test_engine_cost_drops_with_placement():
    """Mean serving cost after placement must beat the all-repository
    baseline (= caching gain > 0, eq. (4) realized end-to-end)."""
    eng, cfg, cat = make_engine(algo="greedy")
    serve_trace(eng, cfg, cat, n_batches=4)
    eng.refresh_placement()
    eng.stats = type(eng.stats)()                # reset counters
    stats = serve_trace(eng, cfg, cat, n_batches=10, seed=2)
    assert stats.mean_cost < eng.ecfg.h_model * 0.7


def test_engine_calibration_sets_cost_units():
    eng, cfg, cat = make_engine()
    ms = eng.calibrate(jnp.zeros((4, 8), jnp.int32))
    assert ms > 0
    assert eng.ecfg.h_model == ms
    assert eng.ecfg.h_ici < eng.ecfg.h_dcn < eng.ecfg.h_model


def test_engine_sharded_data_plane_matches_fused():
    """EngineConfig.sharded + a mesh routes lookups through the
    mesh-sharded fused path; served stats must match the single-device
    fused engine bit-for-bit on the same trace (here a trivial 1-device
    mesh — the 8-way equivalence is covered by test_sharded_lookup)."""
    import jax
    mesh = jax.make_mesh((1,), ("data",))
    eng_f, cfg, cat = make_engine(algo="greedy")
    eng_s, _, _ = make_engine(algo="greedy", sharded=True, mesh=mesh)
    assert eng_s.lookup_shards is not None
    for eng in (eng_f, eng_s):
        serve_trace(eng, cfg, cat, n_batches=4)
        eng.refresh_placement()
        eng.stats = type(eng.stats)()
    assert eng_s.simcache.sharded and eng_s.simcache.mesh is mesh
    sf = serve_trace(eng_f, cfg, cat, n_batches=6, seed=5)
    ss = serve_trace(eng_s, cfg, cat, n_batches=6, seed=5)
    assert sf.n_hits == ss.n_hits
    assert sf.model_calls == ss.model_calls
    assert sf.total_cost == ss.total_cost
    assert sf.total_approx_cost == ss.total_approx_cost


def test_engine_sharded_requires_mesh():
    with np.testing.assert_raises(ValueError):
        make_engine(sharded=True, mesh=None)


def test_placement_algorithms_rank_sanely():
    """cascade ≤ greedy in predicted cost (Remark 1)."""
    preds = {}
    for algo in ("greedy", "cascade"):
        eng, cfg, cat = make_engine(algo=algo)
        serve_trace(eng, cfg, cat, n_batches=6)
        preds[algo] = eng.refresh_placement(algo)
    assert preds["cascade"] <= preds["greedy"] + 1e-9
