"""End-to-end serving-engine tests: the paper's cache network in front of
a real (tiny) model on CPU."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.core import catalog as catalog_api
from repro.core import demand as demand_api
from repro.models import model as model_api
from repro.serve import EngineConfig, SimCacheEngine


def make_engine(k=(16, 24, 32), algo="cascade", sharded=False, mesh=None):
    cfg = dataclasses.replace(get_smoke_config("granite-3-2b"),
                              n_layers=2, d_model=64, n_heads=4,
                              n_kv_heads=2, head_dim=16, d_ff=128, vocab=256)
    params = model_api.init_params(cfg, 0)
    cat = catalog_api.embedding_catalog(n=400, dim=16, seed=1)
    ecfg = EngineConfig(k_device=k[0], k_pod=k[1], k_global=k[2],
                        h_ici=1.0, h_dcn=10.0, h_model=100.0,
                        metric="l2", algo=algo, sharded=sharded)
    eng = SimCacheEngine(cfg, params, ecfg, cat.coords, mesh=mesh)
    return eng, cfg, cat


def serve_trace(eng, cfg, cat, n_batches=12, batch=16, seed=0):
    rng = np.random.default_rng(seed)
    dem = demand_api.zipf(cat, alpha=1.1, seed=3)
    for _ in range(n_batches):
        ids, _ = dem.sample(batch, rng)
        prompts = jnp.asarray(
            rng.integers(0, cfg.vocab, (batch, 8)).astype(np.int32))
        eng.serve(ids, prompts)
    return eng.stats


def test_engine_cold_then_cached():
    eng, cfg, cat = make_engine()
    stats = serve_trace(eng, cfg, cat, n_batches=4)
    assert stats.hit_rate == 0.0                 # no placement yet
    pred = eng.refresh_placement()
    assert pred > 0
    eng.stats = type(eng.stats)()                # count only warm phase
    stats = serve_trace(eng, cfg, cat, n_batches=8, seed=1)
    assert stats.hit_rate > 0.5, stats.hit_rate  # cache absorbs the head
    assert stats.model_calls < 10


def test_engine_cost_drops_with_placement():
    """Mean serving cost after placement must beat the all-repository
    baseline (= caching gain > 0, eq. (4) realized end-to-end)."""
    eng, cfg, cat = make_engine(algo="greedy")
    serve_trace(eng, cfg, cat, n_batches=4)
    eng.refresh_placement()
    eng.stats = type(eng.stats)()                # reset counters
    stats = serve_trace(eng, cfg, cat, n_batches=10, seed=2)
    assert stats.mean_cost < eng.ecfg.h_model * 0.7


def test_engine_calibration_sets_cost_units():
    eng, cfg, cat = make_engine()
    ms = eng.calibrate(jnp.zeros((4, 8), jnp.int32))
    assert ms > 0
    assert eng.ecfg.h_model == ms
    assert eng.ecfg.h_ici < eng.ecfg.h_dcn < eng.ecfg.h_model


def test_calibrate_rebuilds_simcache():
    """Staleness regression: calibrate() used to rebuild the topology but
    leave the already-built simcache (and an armed duel plane) serving
    the old h costs. It must re-install the held allocation against the
    measured costs and re-arm the duel in the new cost units."""
    eng, cfg, cat = make_engine(algo="greedy")
    eng.ecfg.netduel = True
    eng.ecfg.duel_window = 64
    serve_trace(eng, cfg, cat, n_batches=4)
    eng.refresh_placement()
    assert eng.duel is not None
    keys_before = [np.asarray(lv.keys).copy() for lv in eng.simcache.levels]
    v0 = eng.placement.version
    duel_before = eng.duel
    ms = eng.calibrate(jnp.zeros((4, 8), jnp.int32))
    # runtime network now prices the calibrated costs, not the stale ones
    assert [lv.h for lv in eng.simcache.levels] == \
        [0.0, eng.ecfg.h_ici, eng.ecfg.h_dcn]
    assert eng.simcache.h_repo == eng.ecfg.h_model == ms
    assert eng.placement.version > v0
    # same allocation, new prices: the stored keys are unchanged
    for a, lv in zip(keys_before, eng.simcache.levels):
        np.testing.assert_array_equal(a, np.asarray(lv.keys))
    # the duel plane was re-armed (old one was priced in stale units)
    assert eng.duel is not duel_before and eng.duel.t == 0
    # and serving still works end to end in the new units
    stats = serve_trace(eng, cfg, cat, n_batches=4, seed=7)
    assert stats.n_requests == 8 * 16


def test_engine_sharded_data_plane_matches_fused():
    """EngineConfig.sharded + a mesh routes lookups through the
    mesh-sharded fused path; served stats must match the single-device
    fused engine bit-for-bit on the same trace (here a trivial 1-device
    mesh — the 8-way equivalence is covered by test_sharded_lookup)."""
    import jax
    mesh = jax.make_mesh((1,), ("data",))
    eng_f, cfg, cat = make_engine(algo="greedy")
    eng_s, _, _ = make_engine(algo="greedy", sharded=True, mesh=mesh)
    assert eng_s.lookup_shards is not None
    for eng in (eng_f, eng_s):
        serve_trace(eng, cfg, cat, n_batches=4)
        eng.refresh_placement()
        eng.stats = type(eng.stats)()
    assert eng_s.simcache.sharded and eng_s.simcache.mesh is mesh
    sf = serve_trace(eng_f, cfg, cat, n_batches=6, seed=5)
    ss = serve_trace(eng_s, cfg, cat, n_batches=6, seed=5)
    assert sf.n_hits == ss.n_hits
    assert sf.model_calls == ss.model_calls
    assert sf.total_cost == ss.total_cost
    assert sf.total_approx_cost == ss.total_approx_cost


def test_engine_sharded_requires_mesh():
    with np.testing.assert_raises(ValueError):
        make_engine(sharded=True, mesh=None)


def test_placement_algorithms_rank_sanely():
    """cascade ≤ greedy in predicted cost (Remark 1)."""
    preds = {}
    for algo in ("greedy", "cascade"):
        eng, cfg, cat = make_engine(algo=algo)
        serve_trace(eng, cfg, cat, n_batches=6)
        preds[algo] = eng.refresh_placement(algo)
    assert preds["cascade"] <= preds["greedy"] + 1e-9


def test_observed_placement_tail_matches():
    """Demand-floor regression: the observed window keeps never-requested
    objects at an *exact-zero* rate (no ``+ 1e-9`` floor), so once the
    real gains are exhausted both the f64 host solver and the f32 device
    solver stop at the same pick and leave the same slots empty — the
    tail-fill ambiguity of the floored demand is gone."""
    from repro.core.objective import DeviceInstance
    from repro.core.placement import device_greedy, greedy

    eng, cfg, cat = make_engine(algo="greedy")
    # a head-only window: 12 requested objects with well-separated
    # counts against 72 slots forces the zero-gain tail regime
    eng.counts[0, :12] = 2.0 ** np.arange(12)
    inst = eng.observed_instance()
    assert np.all(inst.lam[0, 12:] == 0.0)
    host = greedy(inst)
    dinst = DeviceInstance.from_instance(inst, materialize_ca=False)
    for scan in (True, False):
        np.testing.assert_array_equal(
            host, device_greedy(dinst, scan=scan))
    assert (host < 0).sum() > 0          # the tail regime was entered
    # end-to-end: both engine paths produce the same predicted cost and
    # the same runtime placement
    pred_dev = eng.refresh_placement(device=True)
    keys_dev = [np.asarray(lv.keys).copy() for lv in eng.simcache.levels]
    pred_host = eng.refresh_placement(device=False)
    keys_host = [np.asarray(lv.keys) for lv in eng.simcache.levels]
    for a, b in zip(keys_dev, keys_host):
        np.testing.assert_array_equal(a, b)
    # predicted C(A) agrees to cost-scale noise (the host MXU-form C_a
    # carries ~sqrt(eps)·|x| self-distance noise on its diagonal that the
    # device's shape-stable form does not)
    assert abs(pred_dev - pred_host) < 1e-3 * eng.ecfg.h_model


def test_engine_counts_duplicates_in_batch():
    """Demand-undercount regression: a batch containing the same object
    k times must add k to its count. The old fancy-indexed
    ``counts[ids] += 1`` collapsed duplicates to a single increment —
    undercounting exactly the hot objects of a skewed trace — so the
    batched counts must match a sequential one-request-at-a-time replay."""
    eng, cfg, cat = make_engine()
    rng = np.random.default_rng(0)
    # duplicate-heavy batches: ids drawn from a tiny head so most
    # batches repeat objects many times
    batches = [rng.integers(0, 5, size=32) for _ in range(6)]
    for ids in batches:
        prompts = jnp.asarray(
            rng.integers(0, cfg.vocab, (len(ids), 8)).astype(np.int32))
        eng.serve(ids, prompts)
    expected = np.zeros(cat.n, dtype=np.float64)
    for ids in batches:                  # sequential replay ground truth
        for o in ids:
            expected[int(o)] += 1.0
    np.testing.assert_array_equal(eng.counts[0], expected)
    assert eng.counts[0, :5].sum() == 6 * 32


def test_engine_counts_thread_ingress_ids():
    """Multi-ingress accounting: serve() with ``ingress_ids`` lands each
    request in its own (ingress, object) cell, and observed_instance
    exposes the full per-ingress matrix instead of a collapsed
    ``lam[None, :]`` copy of row 0."""
    from repro.core.scenarios import scenario

    sc = scenario("isp", cache_budget=24, placement="degree", n_ingress=4,
                  seed=0)
    cfg = dataclasses.replace(get_smoke_config("granite-3-2b"),
                              n_layers=2, d_model=64, n_heads=4,
                              n_kv_heads=2, head_dim=16, d_ff=128, vocab=256)
    params = model_api.init_params(cfg, 0)
    cat = catalog_api.embedding_catalog(n=100, dim=8, seed=1)
    ecfg = EngineConfig(metric="l2", strategy="lce")
    eng = SimCacheEngine(cfg, params, ecfg, cat.coords, net=sc.net)
    assert eng.counts.shape == (4, 100)
    rng = np.random.default_rng(1)
    ids = rng.integers(0, 100, size=40)
    ings = rng.integers(0, 4, size=40)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (40, 8)).astype(np.int32))
    eng.serve(ids, prompts, ingress_ids=ings)
    expected = np.zeros((4, 100))
    np.add.at(expected, (ings, ids), 1.0)
    np.testing.assert_array_equal(eng.counts, expected)
    inst = eng.observed_instance()
    assert inst.lam.shape == (4, 100)
    np.testing.assert_allclose(inst.lam, expected / expected.sum())


def test_engine_strategy_plane_serves_end_to_end():
    """EngineConfig.strategy on a general-graph net: every request is
    answered, hits never exceed h_repo, occupancy respects capacities,
    and repeated traffic on a small head warms the path caches."""
    from repro.core.scenarios import scenario

    sc = scenario("scale_free", cache_budget=32, placement="betweenness",
                  n_ingress=4, seed=1)
    cfg = dataclasses.replace(get_smoke_config("granite-3-2b"),
                              n_layers=2, d_model=64, n_heads=4,
                              n_kv_heads=2, head_dim=16, d_ff=128, vocab=256)
    params = model_api.init_params(cfg, 0)
    cat = catalog_api.embedding_catalog(n=100, dim=8, seed=1)
    ecfg = EngineConfig(metric="l2", strategy="lce")
    eng = SimCacheEngine(cfg, params, ecfg, cat.coords, net=sc.net)
    assert eng.routing is not None and eng.simcache is None
    rng = np.random.default_rng(2)
    for _ in range(8):
        ids = rng.integers(0, 10, size=16)       # tiny head: re-requests
        ings = rng.integers(0, 4, size=16)
        prompts = jnp.asarray(
            rng.integers(0, cfg.vocab, (16, 8)).astype(np.int32))
        out, stats = eng.serve(ids, prompts, ingress_ids=ings)
        assert all(r is not None for r in out)   # every request answered
    assert (eng.routing.occupancy() <= sc.net.capacities).all()
    assert stats.n_hits > 0                      # warm head produced hits
    assert stats.mean_cost <= float(sc.net.h_repo.max()) + 1e-9


def test_engine_cold_observed_instance_is_uniform():
    eng, cfg, cat = make_engine()
    inst = eng.observed_instance()
    assert inst.lam.sum() == pytest.approx(1.0)
    assert np.all(inst.lam == inst.lam[0, 0])


def test_engine_netduel_online_plane():
    """EngineConfig.netduel: the duel plane observes every served batch
    (priced by the data-plane lookup costs), promotions rebuild the
    runtime cache, and the engine keeps serving correctly throughout."""
    eng, cfg, cat = make_engine(algo="greedy")
    eng.ecfg.netduel = True
    eng.ecfg.duel_window = 64
    eng.ecfg.duel_arm_prob = 0.5
    serve_trace(eng, cfg, cat, n_batches=4)
    eng.refresh_placement()
    assert eng.duel is not None
    assert eng.duel.t == 0
    stats = serve_trace(eng, cfg, cat, n_batches=16, seed=2)
    assert eng.duel.t == 16 * 16                 # every batch observed
    assert eng.duel.n_promotions > 0
    assert eng.placement_events > 0              # churn rebuilt the cache
    assert stats.hit_rate > 0.3                  # still serving sanely
    # the runtime cache serves exactly the duel's current placement
    stored = np.sort(np.concatenate(
        [np.asarray(lv.values)[np.asarray(lv.values) >= 0]
         for lv in eng.simcache.levels]))
    assert stored.size == eng.duel.slots_np.size
