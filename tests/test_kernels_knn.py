"""Per-kernel validation: KNN Pallas kernel vs pure-jnp oracle.

Sweeps shapes/dtypes/metrics (interpret=True executes the kernel body on
CPU) and asserts allclose + exact argmin agreement, plus hypothesis
property sweeps for the padding contracts.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.knn import knn_ref, nearest_approximizer

SHAPES = [
    (1, 1, 2), (7, 3, 2), (100, 37, 5), (256, 256, 128), (300, 257, 100),
    (64, 512, 2), (17, 9, 130), (512, 1000, 16),
]


@pytest.mark.parametrize("metric", ["l1", "l2", "l2sq"])
@pytest.mark.parametrize("shape", SHAPES)
def test_knn_matches_ref(metric, shape):
    Q, K, D = shape
    rng = np.random.default_rng(Q * 1000 + K)
    q = jnp.asarray(rng.standard_normal((Q, D)).astype(np.float32) * 3)
    k = jnp.asarray(rng.standard_normal((K, D)).astype(np.float32) * 3)
    md, am = nearest_approximizer(q, k, metric=metric)
    mr, ar = knn_ref(q, k, metric)
    np.testing.assert_allclose(md, mr, rtol=3e-5, atol=3e-5)
    np.testing.assert_array_equal(np.asarray(am), np.asarray(ar))


@pytest.mark.parametrize("gamma", [0.5, 1.0, 2.0])
def test_knn_gamma(gamma):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((33, 7)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((21, 7)).astype(np.float32))
    md, am = nearest_approximizer(q, k, metric="l2", gamma=gamma)
    mr, ar = knn_ref(q, k, "l2", gamma)
    np.testing.assert_allclose(md, mr, rtol=3e-5, atol=3e-5)
    np.testing.assert_array_equal(np.asarray(am), np.asarray(ar))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_knn_dtypes(dtype):
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((64, 32))).astype(dtype)
    k = jnp.asarray(rng.standard_normal((48, 32))).astype(dtype)
    md, am = nearest_approximizer(q, k, metric="l2sq")
    mr, ar = knn_ref(q, k, "l2sq")
    np.testing.assert_allclose(md, mr, rtol=3e-5, atol=3e-5)
    np.testing.assert_array_equal(np.asarray(am), np.asarray(ar))


def test_tie_breaks_to_lowest_index():
    """Duplicate keys (incl. the repeat-first padding) must resolve to the
    first occurrence, matching jnp.argmin semantics."""
    q = jnp.zeros((4, 8), jnp.float32)
    k = jnp.zeros((5, 8), jnp.float32)        # all keys identical
    _, am = nearest_approximizer(q, k, metric="l2")
    np.testing.assert_array_equal(np.asarray(am), np.zeros(4, np.int32))


@settings(max_examples=20, deadline=None)
@given(q=st.integers(1, 70), k=st.integers(1, 70), d=st.integers(1, 40),
       metric=st.sampled_from(["l1", "l2"]))
def test_knn_property_sweep(q, k, d, metric):
    rng = np.random.default_rng(q * 10007 + k * 101 + d)
    qs = jnp.asarray(rng.uniform(-5, 5, (q, d)).astype(np.float32))
    ks = jnp.asarray(rng.uniform(-5, 5, (k, d)).astype(np.float32))
    md, am = nearest_approximizer(qs, ks, metric=metric, bq=32, bk=32)
    mr, ar = knn_ref(qs, ks, metric)
    np.testing.assert_allclose(md, mr, rtol=3e-5, atol=3e-5)
    np.testing.assert_array_equal(np.asarray(am), np.asarray(ar))
