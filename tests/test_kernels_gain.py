"""Per-kernel validation: GREEDY gain Pallas kernel vs oracle + vs the
host-side objective.Instance.add_gain_all reference."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import catalog, demand, topology
from repro.core.objective import Instance
from repro.kernels.gain import gain_ref, greedy_gain


@pytest.mark.parametrize("shape", [
    (1, 1, 2, 1), (100, 50, 4, 2), (300, 300, 64, 3), (33, 17, 2, 5),
    (256, 512, 128, 2),
])
@pytest.mark.parametrize("metric", ["l1", "l2"])
def test_gain_matches_ref(shape, metric):
    R, O, D, J = shape
    rng = np.random.default_rng(R + O)
    x = jnp.asarray(rng.standard_normal((R, D)).astype(np.float32))
    y = jnp.asarray(rng.standard_normal((O, D)).astype(np.float32))
    lam = jnp.asarray(rng.random(R).astype(np.float32))
    cur = jnp.asarray((rng.random(R) * 4).astype(np.float32))
    h = rng.random((R, J)).astype(np.float32)
    h[0, 0] = np.inf                      # off-path entry
    hj = jnp.asarray(h)
    g = greedy_gain(x, y, lam, cur, hj, metric=metric)
    gr = gain_ref(x, y, lam, cur,
                  jnp.where(jnp.isfinite(hj), hj, 1e30), metric)
    np.testing.assert_allclose(g, gr, rtol=5e-5, atol=5e-5)


def test_gain_kernel_agrees_with_objective_reference():
    """Kernel gain == Instance.add_gain_all on a real grid instance."""
    cat = catalog.grid(L=8)
    net = topology.tandem(k_leaf=3, k_parent=3, h=2.0, h_repo=10.0)
    dem = demand.gaussian_grid(cat, sigma=2.0)
    inst = Instance(net=net, cat=cat, dem=dem)
    cur = np.repeat(inst.net.h_repo[:, None], cat.n, axis=1)
    ref = inst.add_gain_all(cur)                        # (O, J) host path
    # kernel path: flatten (ingress, object) requests
    x = jnp.asarray(cat.coords)
    lam = jnp.asarray(inst.lam[0].astype(np.float32))
    curj = jnp.asarray(cur[0].astype(np.float32))
    hreq = jnp.asarray(np.broadcast_to(inst.net.H[0], (cat.n, 2)).copy())
    g = greedy_gain(x, x, lam, curj, hreq, metric="l1", gamma=1.0)
    np.testing.assert_allclose(np.asarray(g), ref, rtol=1e-4, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(r=st.integers(1, 60), o=st.integers(1, 60), d=st.integers(1, 20),
       j=st.integers(1, 4))
def test_gain_property_sweep(r, o, d, j):
    rng = np.random.default_rng(r * 7919 + o * 31 + d)
    x = jnp.asarray(rng.uniform(-3, 3, (r, d)).astype(np.float32))
    y = jnp.asarray(rng.uniform(-3, 3, (o, d)).astype(np.float32))
    lam = jnp.asarray(rng.random(r).astype(np.float32))
    cur = jnp.asarray((rng.random(r) * 3).astype(np.float32))
    h = jnp.asarray(rng.random((r, j)).astype(np.float32))
    g = greedy_gain(x, y, lam, cur, h, metric="l1", br=32, bo=32)
    gr = gain_ref(x, y, lam, cur, h, "l1")
    np.testing.assert_allclose(g, gr, rtol=5e-5, atol=5e-5)
    assert np.all(np.asarray(g) >= 0.0)   # gains are relu-clamped
