"""Unit tests for NETDUEL (§5): duel mechanics and λ-unawareness."""
import numpy as np

from repro.core import catalog, demand, topology
from repro.core.objective import Instance, random_slots
from repro.core.placement import netduel


def small_instance(L=12, k=6, h=1.5, h_repo=15.0, sigma=None):
    cat = catalog.grid(L=L)
    net = topology.tandem(k_leaf=k, k_parent=k, h=h, h_repo=h_repo)
    dem = demand.gaussian_grid(cat, sigma=sigma or L / 6)
    return Instance(net=net, cat=cat, dem=dem)


def test_netduel_improves_over_random_init():
    inst = small_instance()
    rng = np.random.default_rng(0)
    slots0 = random_slots(inst, rng)
    c0 = inst.total_cost(slots0)
    st = netduel(inst, n_iters=30000, seed=0, slots0=slots0,
                 window=1000, arm_prob=0.3)
    assert st.n_promotions > 0
    assert st.sw.cost(inst) < c0 * 0.7, (c0, st.sw.cost(inst))


def test_netduel_is_lambda_unaware():
    """The policy must behave identically given the same request STREAM,
    regardless of which demand object generated it (it never reads λ)."""
    inst_a = small_instance(sigma=2.0)
    inst_b = small_instance(sigma=6.0)     # different λ, same topology
    rng = np.random.default_rng(1)
    objs, ings = inst_a.dem.sample(8000, rng)
    st_a = netduel(inst_a, requests=(objs, ings), seed=3, window=800)
    st_b = netduel(inst_b, requests=(objs, ings), seed=3, window=800)
    np.testing.assert_array_equal(st_a.sw.slots, st_b.sw.slots)


def test_netduel_virtual_never_stored_before_promotion():
    """Virtual objects are metadata only: the cache contents may only
    change at a promotion event (duel settle), never at arming."""
    inst = small_instance()
    rng = np.random.default_rng(2)
    slots0 = random_slots(inst, rng)
    st = netduel(inst, n_iters=500, seed=0, slots0=slots0,
                 window=10_000, arm_prob=1.0)   # duels never expire
    np.testing.assert_array_equal(st.sw.slots, slots0)
    assert st.n_promotions == 0


def test_netduel_tracks_serving_cost():
    inst = small_instance()
    st = netduel(inst, n_iters=5000, seed=4, window=500)
    assert st.n_served == 5000
    assert st.served_cost / st.n_served <= inst.empty_cost() + 1e-9
