"""Differential suite for the general-graph scenario plane.

Covers the ISSUE-8 invariants: the generated metric closure and H
matrix (symmetry, triangle inequality, off-path +inf, on-path costs
bounded by h_repo), ``classify_topology`` cleanly returning None on
irreducible graphs (while the chain generator still classifies as a
chain), host-vs-device GREEDY bit-identity on a random scale-free
instance (1-way here, 8-way under scripts/ci.sh pass 2), and the
on-path strategy layer's conservation contract (every request served
exactly once, occupancy never above capacity).
"""
import jax
import numpy as np
import pytest

from repro.core import catalog as catalog_api
from repro.core import demand as demand_api
from repro.core import scenarios, topology
from repro.core.objective import DeviceInstance, Instance
from repro.core.placement import device_greedy, greedy, warmstart
from repro.core.routing import (STRATEGIES, RouteDecision, StrategyPlane,
                                rnd_lru_serve_prob)
from repro.launch.mesh import make_lookup_mesh

FAMILIES = sorted(scenarios.GENERATORS)


# ===================================================================
# graphs + shortest paths
# ===================================================================
@pytest.mark.parametrize("family", FAMILIES)
def test_graph_generators_connected_symmetric(family):
    for seed in (0, 1):
        g = scenarios.GENERATORS[family](seed=seed)
        adj = g.adj
        np.testing.assert_array_equal(adj, adj.T)
        assert np.all(np.diag(adj) == 0.0)
        fin = np.isfinite(adj) & (adj > 0)
        assert np.all(adj[fin] > 0.0)
        # single connected component: the metric closure is all-finite
        assert np.isfinite(scenarios.floyd_warshall(adj)).all()


@pytest.mark.parametrize("family", FAMILIES)
def test_metric_closure_invariants(family):
    """dist is a metric: zero diagonal, symmetric, triangle inequality —
    and Floyd–Warshall == batched Dijkstra on every row."""
    g = scenarios.GENERATORS[family](seed=2)
    dist = scenarios.floyd_warshall(g.adj)
    V = dist.shape[0]
    assert np.all(np.diag(dist) == 0.0)
    np.testing.assert_allclose(dist, dist.T, rtol=0, atol=1e-12)
    # triangle: dist[u, w] <= dist[u, v] + dist[v, w] for all v
    via = dist[:, :, None] + dist[None, :, :]      # (u, v, w)
    assert np.all(dist[:, None, :].repeat(V, 1) <= via + 1e-9)
    dij = scenarios.batched_dijkstra(g.adj, np.arange(V))
    np.testing.assert_allclose(dij, dist, rtol=0, atol=1e-9)
    # dispatcher picks both methods consistently
    rows = np.array([0, 3, 5])
    np.testing.assert_allclose(
        scenarios.shortest_paths(g.adj, rows, method="dijkstra"),
        scenarios.shortest_paths(g.adj, rows, method="fw"),
        rtol=0, atol=1e-9)


@pytest.mark.parametrize("family", FAMILIES)
@pytest.mark.parametrize("placement", sorted(scenarios.CENTRALITIES))
def test_generated_H_invariants(family, placement):
    """The emitted CacheNetwork obeys the paper's routing constraint:
    off-path caches are +inf, on-path reach costs are the true shortest
    distances (so never above h_repo), every path ends at the
    repository, and the slot budget is met exactly."""
    sc = scenarios.scenario(family, cache_budget=40, placement=placement,
                            n_ingress=5, seed=4)
    net, dist = sc.net, sc.dist
    assert net.total_slots == 40
    assert net.n_ingress == 5
    node_of = {j: int(v) for j, v in enumerate(sc.cache_nodes)}
    for i, p in enumerate(sc.paths):
        assert p[0] == sc.ingress_nodes[i] and p[-1] == sc.repo_node
        # path distances are consistent with the closure
        assert dist[p[0], p[-1]] == pytest.approx(float(net.h_repo[i]),
                                                  rel=1e-6)
        on_path = {int(v) for v in p}
        for j in range(net.n_caches):
            if node_of[j] in on_path:
                assert np.isfinite(net.H[i, j])
                assert net.H[i, j] == pytest.approx(
                    dist[sc.ingress_nodes[i], node_of[j]], rel=1e-6)
                assert net.H[i, j] <= net.h_repo[i] + 1e-6
            else:
                assert np.isinf(net.H[i, j])     # off-path: +inf
    # coverage repair: any ingress whose path has intermediates sees
    # at least one cache
    for i, p in enumerate(sc.paths):
        if len(p) > 2:
            assert np.isfinite(net.H[i]).any()


def test_assign_budget_exact_and_proportional():
    caps = scenarios.assign_budget(np.array([4.0, 2.0, 1.0, 1.0]), 16)
    assert caps.sum() == 16
    assert caps[0] == 8 and caps[1] == 4
    caps = scenarios.assign_budget(np.zeros(3), 7)   # uniform fallback
    assert caps.sum() == 7 and caps.max() - caps.min() <= 1
    assert scenarios.assign_budget(np.ones(5), 0).sum() == 0


# ===================================================================
# warm-start classification falls through on irreducible graphs
# ===================================================================
@pytest.mark.parametrize("family", FAMILIES)
def test_classify_topology_none_on_general_graphs(family):
    """Multi-ingress general graphs are not §4-reducible: classify must
    return None (the solver then falls back to discrete GREEDY), never
    misclassify them as a chain/tree/tandem."""
    sc = scenarios.scenario(family, cache_budget=40, placement="degree",
                            n_ingress=5, seed=0)
    assert warmstart.classify_topology(sc.net) is None


def test_classify_topology_chain_still_reduces():
    """The chain generator's output keeps its §4.2 reduction — the
    general-graph plane must not break the reducible topologies."""
    net = topology.chain(4, 3, 2.0, 20.0)
    red = warmstart.classify_topology(net)
    assert isinstance(red, warmstart.ChainReduction)
    assert red.path == (0, 1, 2, 3)


def test_classify_topology_single_ingress_scenario_is_chain():
    """A single-ingress scenario IS a chain program (the finite-H caches
    ordered by reach cost): classification must succeed, with the path
    sorted by H."""
    sc = scenarios.scenario("isp", cache_budget=24,
                            placement="degree", n_ingress=1, seed=0)
    assert np.isfinite(sc.net.H[0]).any()
    red = warmstart.classify_topology(sc.net)
    assert isinstance(red, warmstart.ChainReduction)
    hs = np.asarray(red.spec.hs)
    assert np.all(np.diff(hs) >= 0)


# ===================================================================
# solvers consume generated instances unchanged
# ===================================================================
def scale_free_instance(seed=7, n=160, dim=5):
    sc = scenarios.scenario("scale_free", cache_budget=30,
                            placement="betweenness", n_ingress=4,
                            seed=seed)
    cat = catalog_api.embedding_catalog(n=n, dim=dim, seed=seed)
    dem = demand_api.zipf(cat, alpha=0.9, n_ingress=sc.net.n_ingress,
                          seed=seed + 1)
    return Instance(net=sc.net, cat=cat, dem=dem)


def test_host_vs_device_greedy_bit_identical_on_scale_free():
    """The ISSUE-8 differential: GREEDY on a random scale-free instance
    is bit-identical between the host NumPy oracle and the device gain
    oracle — at the current device count (1-way in tier-1, 8-way in
    ci.sh pass 2)."""
    inst = scale_free_instance()
    host = greedy(inst)
    mesh = make_lookup_mesh(jax.device_count())
    for dinst in (DeviceInstance.from_instance(inst,
                                               materialize_ca=False),
                  DeviceInstance.from_instance(inst, mesh=mesh,
                                               axes=("data",),
                                               materialize_ca=False)):
        np.testing.assert_array_equal(host, device_greedy(dinst))


def test_generated_instance_objective_sane():
    """Placement strictly beats the empty allocation on a generated
    instance (caching gain > 0 end to end through eq. (4))."""
    inst = scale_free_instance(seed=9)
    slots = greedy(inst)
    empty = np.full_like(slots, -1)
    assert inst.total_cost(np.where(slots < 0, 0, slots)) \
        < inst.total_cost(np.where(empty < 0, 0, empty))


# ===================================================================
# on-path strategy layer: conservation
# ===================================================================
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_strategy_conservation(strategy):
    """Every request is served exactly once (by one cache or the
    repository), occupancy never exceeds capacity, and serving cost
    never exceeds the ingress's repository cost."""
    sc = scenarios.scenario("watts_strogatz", cache_budget=36,
                            placement="degree", n_ingress=5, seed=3)
    rng = np.random.default_rng(11)
    coords = rng.normal(size=(250, 6)).astype(np.float32)
    pl = StrategyPlane(sc.net, coords, strategy=strategy, seed=5)
    n_total = 0
    for _ in range(6):
        objs = rng.integers(0, 250, size=64)
        ings = rng.integers(0, sc.net.n_ingress, size=64)
        dec = pl.serve(objs, ings)
        assert isinstance(dec, RouteDecision)
        # exactly one server per request: hit ⇔ a cache id, miss ⇔ −1
        assert np.all((dec.cache >= 0) == dec.hit)
        assert np.all(dec.payload[~dec.hit] == -1)
        assert np.all(dec.payload[dec.hit] >= 0)
        # cost is the chosen server's, never above the repo fallback
        assert np.all(dec.cost <= sc.net.h_repo[ings] + 1e-9)
        assert np.all(dec.cost[~dec.hit]
                      == sc.net.h_repo[ings[~dec.hit]])
        # occupancy within capacity after every batch
        assert np.all(pl.occupancy() <= sc.net.capacities)
        n_total += len(objs)
    assert pl.n_served == n_total
    # stored keys are unique per cache (LRU set semantics)
    for keys in pl.contents():
        assert len(keys) == len(set(keys.tolist()))


def test_strategy_exact_hit_zero_approx_cost():
    """Re-requesting the same object through the same ingress must hit
    with zero approximation cost once inserted (lce, exact repeat)."""
    sc = scenarios.scenario("isp", cache_budget=30, placement="degree",
                            n_ingress=3, seed=0)
    rng = np.random.default_rng(0)
    coords = rng.normal(size=(50, 4)).astype(np.float32)
    pl = StrategyPlane(sc.net, coords, strategy="lce", seed=0)
    first = pl.serve(np.array([7]), np.array([0]))
    assert not first.hit[0]                      # cold: repository
    again = pl.serve(np.array([7]), np.array([0]))
    assert again.hit[0]
    assert again.approx_cost[0] == 0.0
    assert again.payload[0] == 7
    assert again.cost[0] < first.cost[0]


def test_strategy_threshold_restricts_hits():
    """With an admission threshold θ every hit's C_a is ≤ θ."""
    sc = scenarios.scenario("isp", cache_budget=30, placement="degree",
                            n_ingress=3, seed=0)
    rng = np.random.default_rng(1)
    coords = rng.normal(size=(120, 4)).astype(np.float32)
    pl = StrategyPlane(sc.net, coords, strategy="sim-lru",
                       threshold=0.5, seed=0)
    for _ in range(5):
        objs = rng.integers(0, 120, size=48)
        ings = rng.integers(0, 3, size=48)
        dec = pl.serve(objs, ings)
        assert np.all(dec.approx_cost[dec.hit] <= 0.5 + 1e-9)


def test_strategy_unknown_name_raises():
    sc = scenarios.scenario("isp", cache_budget=10, n_ingress=2, seed=0)
    with pytest.raises(ValueError, match="unknown strategy"):
        StrategyPlane(sc.net, np.zeros((10, 2)), strategy="mru")


# ===================================================================
# RND-LRU serving probability: clamped, explicit boundary semantics
# ===================================================================
def test_rnd_serve_prob_clamps_unclamped_negative_q():
    """The pinned bugfix instance: C_a = 2 beyond θ_eff = 1 gives the
    raw formula q = 1 − 2/1 = −1 — the clamped helper must return an
    actual probability (0: never serves), and a *negative* slack, where
    the old ``max(theta, 1e-300)`` division guard produced q ≈ −2e300,
    must mean "never serves" too, not an astronomically negative number
    compared against a uniform draw."""
    assert rnd_lru_serve_prob(2.0, 1.0) == 0.0
    assert rnd_lru_serve_prob(0.5, 0.0) == 0.0
    assert rnd_lru_serve_prob(0.5, -3.0) == 0.0
    old_formula = 1.0 - 0.5 / max(-3.0, 1e-300)
    assert old_formula < -1e290              # what the clamp replaces


def test_rnd_serve_prob_is_a_probability_everywhere():
    for ca in np.linspace(0.0, 8.0, 33):
        for th in np.linspace(-2.0, 8.0, 41):
            q = rnd_lru_serve_prob(float(ca), float(th))
            assert 0.0 <= q <= 1.0
    # exact match always serves, even under an exact-hit-only threshold
    assert rnd_lru_serve_prob(0.0, 0.0) == 1.0
    assert rnd_lru_serve_prob(0.0, 5.0) == 1.0
    # interior value unchanged by the clamp
    assert rnd_lru_serve_prob(1.0, 4.0) == pytest.approx(0.75)


def test_rnd_lru_exact_hit_threshold_zero_still_serves():
    """θ = 0 RND-LRU is exact-hit caching: after a miss inserts the
    object, re-requesting it must hit with probability 1 (the q → 1
    limit at C_a = 0), not be dropped by the never-serves branch."""
    net = topology.single_cache(4, 10.0)
    coords = np.random.default_rng(0).normal(size=(20, 3))
    pl = StrategyPlane(net, coords, strategy="rnd-lru", threshold=0.0,
                       seed=0)
    assert not pl.serve(np.array([3]), np.array([0])).hit[0]
    for _ in range(5):                       # always, not a coin flip
        dec = pl.serve(np.array([3]), np.array([0]))
        assert dec.hit[0] and dec.approx_cost[0] == 0.0


def test_rnd_lru_boundary_q_zero_falls_through_to_repo():
    """A stored key at exactly C_a = θ is eligible but serves with
    q = 0: the request must fall through to the repository every time
    (never a negative-probability artifact), while a key strictly
    inside θ serves with positive frequency."""
    coords = np.zeros((3, 1))
    coords[1, 0] = 1.0                       # C_a(1, 0) = 1.0 exactly
    coords[2, 0] = 0.25                      # C_a(2, 0) = 0.25 < θ
    net = topology.single_cache(4, 100.0)

    def first_serve_hits(obj, n_trials):
        """Fraction of fresh planes (key 0 pre-inserted) whose FIRST
        request of ``obj`` hits — one trial per plane, because a miss
        inserts the exact object and would hit its own copy after."""
        hits = 0
        for t in range(n_trials):
            pl = StrategyPlane(net, coords, strategy="rnd-lru",
                               threshold=1.0, seed=t)
            pl.serve(np.array([0]), np.array([0]))   # miss-insert key 0
            hits += int(pl.serve(np.array([obj]),
                                 np.array([0])).hit[0])
        return hits / n_trials

    assert first_serve_hits(1, 60) == 0.0    # q = 1 − 1/1 = 0: never
    frac = first_serve_hits(2, 400)          # q = 1 − 0.25/1 = 0.75
    assert 0.65 < frac < 0.85


# ===================================================================
# strategy-plane edge cases: empty paths, zero capacity, duplicates
# ===================================================================
def _custom_net(H, h_repo, capacities):
    H = np.asarray(H, np.float64)
    return topology.CacheNetwork(
        n_caches=H.shape[1], capacities=np.asarray(capacities, np.int64),
        ingress=np.arange(H.shape[0]), H=H,
        h_repo=np.asarray(h_repo, np.float64), name="edge")


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_strategy_empty_forwarding_path(strategy):
    """An ingress whose H row is all +inf has an empty forwarding path:
    every request must be served by the repository at h_repo, with no
    insertions anywhere and no crash in the miss walk."""
    net = _custom_net(H=[[np.inf, np.inf], [0.5, 1.5]],
                      h_repo=[4.0, 6.0], capacities=[2, 2])
    coords = np.random.default_rng(1).normal(size=(30, 3))
    pl = StrategyPlane(net, coords, strategy=strategy, seed=2)
    assert len(pl.paths[0]) == 0
    rng = np.random.default_rng(5)
    dec = pl.serve(rng.integers(0, 30, 40), np.zeros(40, np.int64))
    assert not dec.hit.any()
    assert np.all(dec.cost == 4.0)
    assert np.all(pl.occupancy() == 0)       # nothing was inserted
    # the second ingress still works normally on the same plane
    dec2 = pl.serve(rng.integers(0, 30, 40), np.ones(40, np.int64))
    assert np.all(dec2.cost <= 6.0 + 1e-9)
    assert np.all(pl.occupancy() <= net.capacities)


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_strategy_zero_capacity_caches(strategy):
    """Zero-capacity caches on the path hold nothing — occupancy stays
    0 forever and every request pays ≥ its best nonzero-cache cost."""
    net = _custom_net(H=[[0.5, 1.0, 2.0]], h_repo=[8.0],
                      capacities=[0, 3, 0])
    coords = np.random.default_rng(2).normal(size=(40, 3))
    pl = StrategyPlane(net, coords, strategy=strategy, seed=1)
    rng = np.random.default_rng(9)
    for _ in range(4):
        dec = pl.serve(rng.integers(0, 40, 50), np.zeros(50, np.int64))
        occ = pl.occupancy()
        assert occ[0] == 0 and occ[2] == 0
        assert occ[1] <= 3
        assert np.all((dec.cache == -1) | (dec.cache == 1))
    # an all-zero-capacity network degenerates to pure repo serving
    net0 = _custom_net(H=[[0.5]], h_repo=[8.0], capacities=[0])
    pl0 = StrategyPlane(net0, coords, strategy=strategy, seed=1)
    dec = pl0.serve(rng.integers(0, 40, 30), np.zeros(30, np.int64))
    assert not dec.hit.any() and np.all(dec.cost == 8.0)


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_strategy_duplicate_objects_in_batch(strategy):
    """The same object several times in one batch is served in arrival
    order: conservation holds per request (not per distinct id), the
    second occurrence may hit the copy the first just inserted, and
    stored keys stay unique (LRU set semantics)."""
    sc = scenarios.scenario("isp", cache_budget=24, placement="degree",
                            n_ingress=2, seed=1)
    coords = np.random.default_rng(3).normal(size=(60, 4))
    pl = StrategyPlane(sc.net, coords, strategy=strategy, seed=4)
    objs = np.array([7, 7, 7, 12, 12, 7, 3, 3, 3, 3])
    ings = np.zeros(len(objs), np.int64)
    dec = pl.serve(objs, ings)
    assert pl.n_served == len(objs)
    assert np.all((dec.cache >= 0) == dec.hit)
    assert np.all(dec.cost <= sc.net.h_repo[0] + 1e-9)
    if strategy in ("lce", "sim-lru", "rnd-lru"):
        # first occurrence missed and inserted on-path → the repeat of
        # an exact-duplicate request hits (rnd-lru: C_a = 0 ⇒ q = 1)
        assert dec.hit[1] and dec.approx_cost[1] == 0.0
    assert np.all(pl.occupancy() <= sc.net.capacities)
    for keys in pl.contents():
        assert len(keys) == len(set(keys.tolist()))
