"""Differential suite for the general-graph scenario plane.

Covers the ISSUE-8 invariants: the generated metric closure and H
matrix (symmetry, triangle inequality, off-path +inf, on-path costs
bounded by h_repo), ``classify_topology`` cleanly returning None on
irreducible graphs (while the chain generator still classifies as a
chain), host-vs-device GREEDY bit-identity on a random scale-free
instance (1-way here, 8-way under scripts/ci.sh pass 2), and the
on-path strategy layer's conservation contract (every request served
exactly once, occupancy never above capacity).
"""
import jax
import numpy as np
import pytest

from repro.core import catalog as catalog_api
from repro.core import demand as demand_api
from repro.core import scenarios, topology
from repro.core.objective import DeviceInstance, Instance
from repro.core.placement import device_greedy, greedy, warmstart
from repro.core.routing import STRATEGIES, RouteDecision, StrategyPlane
from repro.launch.mesh import make_lookup_mesh

FAMILIES = sorted(scenarios.GENERATORS)


# ===================================================================
# graphs + shortest paths
# ===================================================================
@pytest.mark.parametrize("family", FAMILIES)
def test_graph_generators_connected_symmetric(family):
    for seed in (0, 1):
        g = scenarios.GENERATORS[family](seed=seed)
        adj = g.adj
        np.testing.assert_array_equal(adj, adj.T)
        assert np.all(np.diag(adj) == 0.0)
        fin = np.isfinite(adj) & (adj > 0)
        assert np.all(adj[fin] > 0.0)
        # single connected component: the metric closure is all-finite
        assert np.isfinite(scenarios.floyd_warshall(adj)).all()


@pytest.mark.parametrize("family", FAMILIES)
def test_metric_closure_invariants(family):
    """dist is a metric: zero diagonal, symmetric, triangle inequality —
    and Floyd–Warshall == batched Dijkstra on every row."""
    g = scenarios.GENERATORS[family](seed=2)
    dist = scenarios.floyd_warshall(g.adj)
    V = dist.shape[0]
    assert np.all(np.diag(dist) == 0.0)
    np.testing.assert_allclose(dist, dist.T, rtol=0, atol=1e-12)
    # triangle: dist[u, w] <= dist[u, v] + dist[v, w] for all v
    via = dist[:, :, None] + dist[None, :, :]      # (u, v, w)
    assert np.all(dist[:, None, :].repeat(V, 1) <= via + 1e-9)
    dij = scenarios.batched_dijkstra(g.adj, np.arange(V))
    np.testing.assert_allclose(dij, dist, rtol=0, atol=1e-9)
    # dispatcher picks both methods consistently
    rows = np.array([0, 3, 5])
    np.testing.assert_allclose(
        scenarios.shortest_paths(g.adj, rows, method="dijkstra"),
        scenarios.shortest_paths(g.adj, rows, method="fw"),
        rtol=0, atol=1e-9)


@pytest.mark.parametrize("family", FAMILIES)
@pytest.mark.parametrize("placement", sorted(scenarios.CENTRALITIES))
def test_generated_H_invariants(family, placement):
    """The emitted CacheNetwork obeys the paper's routing constraint:
    off-path caches are +inf, on-path reach costs are the true shortest
    distances (so never above h_repo), every path ends at the
    repository, and the slot budget is met exactly."""
    sc = scenarios.scenario(family, cache_budget=40, placement=placement,
                            n_ingress=5, seed=4)
    net, dist = sc.net, sc.dist
    assert net.total_slots == 40
    assert net.n_ingress == 5
    node_of = {j: int(v) for j, v in enumerate(sc.cache_nodes)}
    for i, p in enumerate(sc.paths):
        assert p[0] == sc.ingress_nodes[i] and p[-1] == sc.repo_node
        # path distances are consistent with the closure
        assert dist[p[0], p[-1]] == pytest.approx(float(net.h_repo[i]),
                                                  rel=1e-6)
        on_path = {int(v) for v in p}
        for j in range(net.n_caches):
            if node_of[j] in on_path:
                assert np.isfinite(net.H[i, j])
                assert net.H[i, j] == pytest.approx(
                    dist[sc.ingress_nodes[i], node_of[j]], rel=1e-6)
                assert net.H[i, j] <= net.h_repo[i] + 1e-6
            else:
                assert np.isinf(net.H[i, j])     # off-path: +inf
    # coverage repair: any ingress whose path has intermediates sees
    # at least one cache
    for i, p in enumerate(sc.paths):
        if len(p) > 2:
            assert np.isfinite(net.H[i]).any()


def test_assign_budget_exact_and_proportional():
    caps = scenarios.assign_budget(np.array([4.0, 2.0, 1.0, 1.0]), 16)
    assert caps.sum() == 16
    assert caps[0] == 8 and caps[1] == 4
    caps = scenarios.assign_budget(np.zeros(3), 7)   # uniform fallback
    assert caps.sum() == 7 and caps.max() - caps.min() <= 1
    assert scenarios.assign_budget(np.ones(5), 0).sum() == 0


# ===================================================================
# warm-start classification falls through on irreducible graphs
# ===================================================================
@pytest.mark.parametrize("family", FAMILIES)
def test_classify_topology_none_on_general_graphs(family):
    """Multi-ingress general graphs are not §4-reducible: classify must
    return None (the solver then falls back to discrete GREEDY), never
    misclassify them as a chain/tree/tandem."""
    sc = scenarios.scenario(family, cache_budget=40, placement="degree",
                            n_ingress=5, seed=0)
    assert warmstart.classify_topology(sc.net) is None


def test_classify_topology_chain_still_reduces():
    """The chain generator's output keeps its §4.2 reduction — the
    general-graph plane must not break the reducible topologies."""
    net = topology.chain(4, 3, 2.0, 20.0)
    red = warmstart.classify_topology(net)
    assert isinstance(red, warmstart.ChainReduction)
    assert red.path == (0, 1, 2, 3)


def test_classify_topology_single_ingress_scenario_is_chain():
    """A single-ingress scenario IS a chain program (the finite-H caches
    ordered by reach cost): classification must succeed, with the path
    sorted by H."""
    sc = scenarios.scenario("isp", cache_budget=24,
                            placement="degree", n_ingress=1, seed=0)
    assert np.isfinite(sc.net.H[0]).any()
    red = warmstart.classify_topology(sc.net)
    assert isinstance(red, warmstart.ChainReduction)
    hs = np.asarray(red.spec.hs)
    assert np.all(np.diff(hs) >= 0)


# ===================================================================
# solvers consume generated instances unchanged
# ===================================================================
def scale_free_instance(seed=7, n=160, dim=5):
    sc = scenarios.scenario("scale_free", cache_budget=30,
                            placement="betweenness", n_ingress=4,
                            seed=seed)
    cat = catalog_api.embedding_catalog(n=n, dim=dim, seed=seed)
    dem = demand_api.zipf(cat, alpha=0.9, n_ingress=sc.net.n_ingress,
                          seed=seed + 1)
    return Instance(net=sc.net, cat=cat, dem=dem)


def test_host_vs_device_greedy_bit_identical_on_scale_free():
    """The ISSUE-8 differential: GREEDY on a random scale-free instance
    is bit-identical between the host NumPy oracle and the device gain
    oracle — at the current device count (1-way in tier-1, 8-way in
    ci.sh pass 2)."""
    inst = scale_free_instance()
    host = greedy(inst)
    mesh = make_lookup_mesh(jax.device_count())
    for dinst in (DeviceInstance.from_instance(inst,
                                               materialize_ca=False),
                  DeviceInstance.from_instance(inst, mesh=mesh,
                                               axes=("data",),
                                               materialize_ca=False)):
        np.testing.assert_array_equal(host, device_greedy(dinst))


def test_generated_instance_objective_sane():
    """Placement strictly beats the empty allocation on a generated
    instance (caching gain > 0 end to end through eq. (4))."""
    inst = scale_free_instance(seed=9)
    slots = greedy(inst)
    empty = np.full_like(slots, -1)
    assert inst.total_cost(np.where(slots < 0, 0, slots)) \
        < inst.total_cost(np.where(empty < 0, 0, empty))


# ===================================================================
# on-path strategy layer: conservation
# ===================================================================
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_strategy_conservation(strategy):
    """Every request is served exactly once (by one cache or the
    repository), occupancy never exceeds capacity, and serving cost
    never exceeds the ingress's repository cost."""
    sc = scenarios.scenario("watts_strogatz", cache_budget=36,
                            placement="degree", n_ingress=5, seed=3)
    rng = np.random.default_rng(11)
    coords = rng.normal(size=(250, 6)).astype(np.float32)
    pl = StrategyPlane(sc.net, coords, strategy=strategy, seed=5)
    n_total = 0
    for _ in range(6):
        objs = rng.integers(0, 250, size=64)
        ings = rng.integers(0, sc.net.n_ingress, size=64)
        dec = pl.serve(objs, ings)
        assert isinstance(dec, RouteDecision)
        # exactly one server per request: hit ⇔ a cache id, miss ⇔ −1
        assert np.all((dec.cache >= 0) == dec.hit)
        assert np.all(dec.payload[~dec.hit] == -1)
        assert np.all(dec.payload[dec.hit] >= 0)
        # cost is the chosen server's, never above the repo fallback
        assert np.all(dec.cost <= sc.net.h_repo[ings] + 1e-9)
        assert np.all(dec.cost[~dec.hit]
                      == sc.net.h_repo[ings[~dec.hit]])
        # occupancy within capacity after every batch
        assert np.all(pl.occupancy() <= sc.net.capacities)
        n_total += len(objs)
    assert pl.n_served == n_total
    # stored keys are unique per cache (LRU set semantics)
    for keys in pl.contents():
        assert len(keys) == len(set(keys.tolist()))


def test_strategy_exact_hit_zero_approx_cost():
    """Re-requesting the same object through the same ingress must hit
    with zero approximation cost once inserted (lce, exact repeat)."""
    sc = scenarios.scenario("isp", cache_budget=30, placement="degree",
                            n_ingress=3, seed=0)
    rng = np.random.default_rng(0)
    coords = rng.normal(size=(50, 4)).astype(np.float32)
    pl = StrategyPlane(sc.net, coords, strategy="lce", seed=0)
    first = pl.serve(np.array([7]), np.array([0]))
    assert not first.hit[0]                      # cold: repository
    again = pl.serve(np.array([7]), np.array([0]))
    assert again.hit[0]
    assert again.approx_cost[0] == 0.0
    assert again.payload[0] == 7
    assert again.cost[0] < first.cost[0]


def test_strategy_threshold_restricts_hits():
    """With an admission threshold θ every hit's C_a is ≤ θ."""
    sc = scenarios.scenario("isp", cache_budget=30, placement="degree",
                            n_ingress=3, seed=0)
    rng = np.random.default_rng(1)
    coords = rng.normal(size=(120, 4)).astype(np.float32)
    pl = StrategyPlane(sc.net, coords, strategy="sim-lru",
                       threshold=0.5, seed=0)
    for _ in range(5):
        objs = rng.integers(0, 120, size=48)
        ings = rng.integers(0, 3, size=48)
        dec = pl.serve(objs, ings)
        assert np.all(dec.approx_cost[dec.hit] <= 0.5 + 1e-9)


def test_strategy_unknown_name_raises():
    sc = scenarios.scenario("isp", cache_budget=10, n_ingress=2, seed=0)
    with pytest.raises(ValueError, match="unknown strategy"):
        StrategyPlane(sc.net, np.zeros((10, 2)), strategy="mru")
