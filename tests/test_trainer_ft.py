"""Fault-tolerance integration tests: checkpoint/restart, determinism,
elastic re-shard, quantized moments, compression codecs, hedging."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore, save
from repro.configs.registry import get_smoke_config
from repro.data.pipeline import SyntheticLMData
from repro.ft.compress import dequantize_int8, quantize_int8
from repro.ft.straggler import HedgedDispatcher, simulated_replica
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.train.trainer import TrainConfig, train


def small_cfg():
    cfg = get_smoke_config("granite-3-2b")
    return dataclasses.replace(cfg, n_layers=2, d_model=64, n_heads=4,
                               n_kv_heads=2, head_dim=16, d_ff=128,
                               vocab=128)


def test_data_pipeline_deterministic():
    d = SyntheticLMData(vocab=128, batch=4, seq=16, seed=7)
    b1, b2 = d.batch_at(42), d.batch_at(42)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = d.batch_at(43)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_loss_decreases_on_synthetic_data(tmp_path):
    cfg = small_cfg()
    tcfg = TrainConfig(steps=60, ckpt_dir=str(tmp_path / "ck"),
                       ckpt_every=1000, log_every=1000,
                       opt=AdamWConfig(lr=2e-3, weight_decay=0.0))
    data = SyntheticLMData(vocab=cfg.vocab, batch=8, seq=32)
    out = train(cfg, tcfg, data, log=lambda *a: None)
    first, last = np.mean(out["losses"][:10]), np.mean(out["losses"][-10:])
    assert last < first - 0.2, (first, last)


def test_kill_and_resume_matches_uninterrupted_run(tmp_path):
    """Crash mid-run → resume: the loss trajectory must be identical to a
    never-crashed run (checkpoint + deterministic pipeline)."""
    cfg = small_cfg()
    data = SyntheticLMData(vocab=cfg.vocab, batch=8, seq=32)
    t_a = TrainConfig(steps=30, ckpt_dir=str(tmp_path / "a"), ckpt_every=10,
                      log_every=1000)
    full = train(cfg, t_a, data, log=lambda *a: None)

    t_b = TrainConfig(steps=30, ckpt_dir=str(tmp_path / "b"), ckpt_every=10,
                      log_every=1000)
    train(cfg, t_b, data, stop_after=20, log=lambda *a: None)   # "crash"
    assert latest_step(str(tmp_path / "b")) == 20
    resumed = train(cfg, t_b, data, log=lambda *a: None)        # restart
    np.testing.assert_allclose(resumed["losses"], full["losses"][20:],
                               rtol=2e-4, atol=2e-4)


def test_checkpoint_atomic_and_pruned(tmp_path):
    tree = {"a": np.arange(10.0), "b": {"c": np.ones((3, 3))}}
    for s in (1, 2, 3, 4):
        save(str(tmp_path), s, tree, keep=2)
    assert latest_step(str(tmp_path)) == 4
    step, back = restore(str(tmp_path))
    assert step == 4
    np.testing.assert_array_equal(back["a"], tree["a"])
    np.testing.assert_array_equal(back["b"]["c"], tree["b"]["c"])
    import os
    kept = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
    assert len(kept) == 2                       # pruning


def test_int8_moment_adamw_tracks_f32():
    """Quantized-moment AdamW stays close to f32 AdamW over 50 steps on a
    quadratic problem (the 8-bit-Adam sanity check)."""
    rng = np.random.default_rng(0)
    target = jnp.asarray(rng.standard_normal(64).astype(np.float32))

    def loss(p):
        return jnp.sum((p - target) ** 2)

    results = {}
    for md in ("float32", "int8"):
        cfg = AdamWConfig(lr=0.05, weight_decay=0.0, moment_dtype=md)
        p = jnp.zeros(64)
        st = adamw_init(p, cfg)
        for _ in range(50):
            g = jax.grad(loss)(p)
            p, st = adamw_update(g, st, p, cfg)
        results[md] = p
    err = float(jnp.max(jnp.abs(results["int8"] - results["float32"])))
    assert err < 0.5, err                        # tracks f32 coordinates
    # converges to (near) the same optimum: ≥99.7% of the loss reduction
    base = float(loss(jnp.zeros(64)))
    assert float(loss(results["int8"])) < 0.003 * base
    assert float(loss(results["float32"])) < 0.003 * base


def test_int8_codec_roundtrip(rng):
    x = jnp.asarray(rng.standard_normal((16, 256)).astype(np.float32) * 5)
    q, s = quantize_int8(x)
    back = dequantize_int8(q, s)
    rel = np.max(np.abs(np.asarray(back - x))) / np.max(np.abs(np.asarray(x)))
    assert rel < 1.0 / 100                      # per-row 1/127 bound + eps


def test_hedged_dispatch_cuts_tail_latency():
    primary = simulated_replica(0.010, slow_every=5, slow_factor=100.0)
    backup = simulated_replica(0.012)
    hd = HedgedDispatcher([primary, backup], hedge_after_s=0.02)
    lats = [hd(i)[1] for i in range(100)]
    assert max(lats) < 0.05                     # 1s stragglers cut to hedge
    assert hd.stats.n_hedged == 20


def test_hedged_approx_fallback():
    primary = simulated_replica(1.0)            # always slow
    backup = simulated_replica(1.0)             # backup also slow
    hd = HedgedDispatcher([primary, backup], hedge_after_s=0.01,
                          deadline_s=0.1,
                          approx_fallback=lambda r: (("approx", r), 0.0))
    out, lat = hd(7)
    assert out[0] == "approx" and lat == 0.1
    assert hd.stats.n_fallback == 1
