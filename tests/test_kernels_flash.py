"""Per-kernel validation: flash-attention forward vs the unfused oracle
(shape/GQA-group/causality sweeps, interpret mode on CPU), plus a
model-level parity check with the flag flipped."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention import flash_attention, flash_ref

CASES = [
    # (B, Sq, Skv, H, KH, Dh, causal)
    (2, 64, 64, 4, 2, 32, True),
    (1, 100, 100, 8, 8, 64, True),
    (2, 37, 37, 4, 1, 16, True),
    (1, 64, 128, 4, 2, 32, False),     # cross-attention shape
    (2, 256, 256, 8, 2, 128, True),
    (1, 1, 64, 4, 4, 32, False),       # single query row
]


@pytest.mark.parametrize("case", CASES)
def test_flash_matches_ref(case):
    B, Sq, Skv, H, KH, Dh, causal = case
    rng = np.random.default_rng(Sq * 7 + Skv)
    q = jnp.asarray(rng.standard_normal((B, Sq, H, Dh)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, Skv, KH, Dh)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, Skv, KH, Dh)).astype(np.float32))
    o = flash_attention(q, k, v, causal=causal, bq=32, bk=32)
    r = flash_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_dtypes(dtype):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, 48, 4, 32))).astype(dtype)
    k = jnp.asarray(rng.standard_normal((1, 48, 2, 32))).astype(dtype)
    v = jnp.asarray(rng.standard_normal((1, 48, 2, 32))).astype(dtype)
    o = flash_attention(q, k, v, bq=16, bk=16)
    r = flash_ref(q, k, v)
    assert o.dtype == dtype
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32),
                               rtol=2e-2, atol=2e-2)


@settings(max_examples=12, deadline=None)
@given(sq=st.integers(1, 70), h=st.sampled_from([2, 4, 8]),
       kh_div=st.sampled_from([1, 2]), dh=st.sampled_from([8, 16, 32]),
       causal=st.booleans())
def test_flash_property_sweep(sq, h, kh_div, dh, causal):
    kh = max(h // kh_div, 1)
    rng = np.random.default_rng(sq * 31 + h * 7 + dh)
    q = jnp.asarray(rng.uniform(-2, 2, (1, sq, h, dh)).astype(np.float32))
    k = jnp.asarray(rng.uniform(-2, 2, (1, sq, kh, dh)).astype(np.float32))
    v = jnp.asarray(rng.uniform(-2, 2, (1, sq, kh, dh)).astype(np.float32))
    o = flash_attention(q, k, v, causal=causal, bq=16, bk=16)
    r = flash_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                               rtol=5e-5, atol=5e-5)


def test_model_level_flash_parity():
    """cfg.use_flash_attention swaps the kernel into the full model; the
    train loss must match the einsum path at f32 tolerance."""
    from repro.configs.registry import get_smoke_config
    from repro.models import model
    cfg = get_smoke_config("granite-3-2b")
    cfg_f = dataclasses.replace(cfg, use_flash_attention=True)
    params = model.init_params(cfg, 0)
    rng = np.random.default_rng(5)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 32))),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)))}
    l0, _ = jax.jit(model.make_train_forward(cfg))(params, batch)
    l1, _ = jax.jit(model.make_train_forward(cfg_f))(params, batch)
    assert abs(float(l0) - float(l1)) < 5e-4, (float(l0), float(l1))
