"""Property-based tests (hypothesis) of the system's invariants.

The paper's structural claims, checked on randomized instances:
  * Prop 3.2 — G(A) is non-negative, monotone and submodular over the
    slot matroid;
  * GREEDY's 1/2 bound vs brute-force optimum (tiny instances);
  * Prop 3.3 — localswap_polish fixed points are locally optimal;
  * Remark 1 — cascade cost ≤ greedy cost, and still ≥ ½·OPT gain;
  * eq. (1) — serving cost never exceeds the repository cost, and adding
    any approximizer never increases any request's cost;
  * LSH/k-means candidate pruning (kernels/knn/lsh.py) — admissibility
    (scanning fewer keys can only raise the winning cost) and the
    verifier contract (``verify=True`` closes the pruning gap to 0);
  * int8 quantized first pass (kernels/quant.py) — the certified lower
    bound never exceeds the exact cost on any random catalog/metric/γ,
    the quantized lookup is admissible the same way pruning is, and
    ``quantize=True, verify=True`` restores the exact lexicographic
    winner even when quantized ranks reorder near ties;
  * incremental best-two delta (core/objective.best_two_delta) — the
    scanned LOCALSWAP trajectory with delta re-arms is bit-identical to
    the full-rebuild trajectory on every random instance;
  * §5 NETDUEL — a promotion never increases the cost measured on the
    duel's own window requests (the settle rule's defining guarantee);
  * scanned device control plane — the single-launch while_loop/scan
    paths are bit-identical to the per-step jitted paths at every
    ``topk``/window split (pure batching, never a semantics change).
"""
import itertools

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import catalog, demand, topology
from repro.core.objective import DeviceInstance, Instance, random_slots
from repro.core.placement import (device_greedy, device_localswap,
                                  device_netduel, greedy,
                                  greedy_then_localswap, localswap_polish)
from repro.core.placement.localswap import is_locally_optimal
from repro.core.simcache import SimCacheNetwork


def make_random_instance(seed, n_obj=6, dim=2, k=(1, 1), h=0.5, h_repo=3.0,
                         metric="l1", gamma=1.0):
    rng = np.random.default_rng(seed)
    coords = rng.uniform(0, 4, size=(n_obj, dim)).astype(np.float32)
    cat = catalog.Catalog(coords=coords, metric=metric, gamma=gamma)
    net = topology.tandem(k_leaf=k[0], k_parent=k[1], h=h, h_repo=h_repo)
    lam = rng.random((1, n_obj)) + 0.05
    dem = demand.Demand(lam=lam / lam.sum())
    return Instance(net=net, cat=cat, dem=dem)


def gain_of(inst, pairs):
    """Caching gain of an approximizer set given as (obj, cache) pairs,
    ignoring the fixed slot layout (for submodularity checks we allow any
    feasible multiset respecting capacities)."""
    slots = np.full(inst.net.total_slots, -1, dtype=np.int64)
    offsets = {j: list(np.where(inst.slot_cache == j)[0]) for j in
               range(inst.net.n_caches)}
    for (o, j) in pairs:
        slots[offsets[j].pop(0)] = o
    return inst.caching_gain(slots)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_gain_nonneg_monotone_submodular(seed):
    inst = make_random_instance(seed, n_obj=5, k=(2, 2))
    rng = np.random.default_rng(seed + 1)
    universe = [(o, j) for o in range(5) for j in range(2)]
    rng.shuffle(universe)
    # A ⊂ B with room for one more element per cache
    A = universe[:1]
    B = universe[:2] if universe[1][1] != universe[1 - 1][1] or True else universe[:2]
    # keep per-cache counts ≤ capacity−1 so A∪{α}, B∪{α} stay feasible
    def count(S, j):
        return sum(1 for (_, jj) in S if jj == j)
    B = [p for p in B if count(B[:B.index(p)], p[1]) < 1]
    alpha = next(p for p in universe if p not in B and count(B, p[1]) < 2)
    gA, gB = gain_of(inst, A), gain_of(inst, B)
    assert gA >= -1e-9 and gB >= -1e-9
    assert gB >= gA - 1e-9                      # monotone (A ⊆ B)
    mgA = gain_of(inst, A + [alpha]) - gA
    mgB = gain_of(inst, B + [alpha]) - gB
    assert mgA >= mgB - 1e-7                    # submodular


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_greedy_half_approximation(seed):
    inst = make_random_instance(seed, n_obj=5, k=(1, 1))
    gslots = greedy(inst)
    g_gain = inst.caching_gain(gslots)
    best = -np.inf
    for combo in itertools.product(range(5), repeat=2):
        best = max(best, inst.caching_gain(np.array(combo, np.int64)))
    assert g_gain >= 0.5 * best - 1e-9


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), n_obj=st.integers(4, 8),
       k_leaf=st.integers(1, 3), k_parent=st.integers(1, 3))
def test_greedy_lazy_matches_eager(seed, n_obj, k_leaf, k_parent):
    """The accelerated/lazy greedy (stale max-heap, §3.2's "smart
    implementation") must return the *exact* textbook-greedy solution:
    submodularity guarantees stale heap gains only overestimate, so
    re-evaluating the popped candidate preserves the selection order.
    Random continuous coords make exact gain ties measure-zero, so the
    allocations — not just their costs — must coincide."""
    inst = make_random_instance(seed, n_obj=n_obj, k=(k_leaf, k_parent),
                                metric="l2")
    lazy_slots = greedy(inst, lazy=True)
    eager_slots = greedy(inst, lazy=False)
    np.testing.assert_array_equal(lazy_slots, eager_slots)
    assert inst.total_cost(lazy_slots) == \
        pytest.approx(inst.total_cost(eager_slots), rel=1e-12)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_polish_fixed_point_is_locally_optimal(seed):
    inst = make_random_instance(seed, n_obj=6, k=(1, 2))
    rng = np.random.default_rng(seed)
    st_ = localswap_polish(inst, random_slots(inst, rng))
    assert is_locally_optimal(inst, st_.slots)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_cascade_dominates_greedy_and_half_opt(seed):
    inst = make_random_instance(seed, n_obj=5, k=(1, 1))
    g = greedy(inst)
    casc = greedy_then_localswap(inst)
    assert casc.cost(inst) <= inst.total_cost(g) + 1e-9
    best_gain = max(inst.caching_gain(np.array(c, np.int64))
                    for c in itertools.product(range(5), repeat=2))
    assert inst.caching_gain(casc.slots) >= 0.5 * best_gain - 1e-9


def _sampled_placement_net(seed):
    """A random placement turned into a runtime network plus a query
    batch sampled from a random demand — the pruning properties must
    hold for *every* such draw."""
    rng = np.random.default_rng(seed)
    n_obj = int(rng.integers(40, 120))
    cat = catalog.embedding_catalog(n=n_obj, dim=int(rng.integers(2, 8)),
                                    seed=seed)
    lam = rng.random((1, n_obj)) + 0.01
    dem = demand.Demand(lam=lam / lam.sum())
    k0, k1 = int(rng.integers(1, 20)), int(rng.integers(1, 20))
    stored = rng.choice(n_obj, k0 + k1, replace=False)
    slots = np.concatenate([stored, np.full(2, -1)]).astype(np.int64)
    slot_cache = np.array([0] * k0 + [1] * (k1 + 2))
    net = SimCacheNetwork.from_placement(
        cat.coords, slots, slot_cache, hs=[0.0, 0.5],
        h_repo=float(rng.uniform(0.5, 5.0)), metric="l2")
    obj, _ = dem.sample(int(rng.integers(1, 64)), rng)
    q = jnp.asarray(cat.coords[obj]
                    + rng.normal(0, 0.1, (obj.size, cat.dim))
                    .astype(np.float32))
    return net, q


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000),
       prune=st.sampled_from(["lsh", "kmeans"]))
def test_pruned_lookup_cost_admissible(seed, prune):
    """Admissibility: the pruned lookup scans a subset of the keys, so
    its winning cost is ≥ the exact fused cost for every query of every
    sampled placement/batch — pruning can hide the winner, never invent
    a cheaper one."""
    net, q = _sampled_placement_net(seed)
    pruned = net.lookup(q, prune=prune)
    exact = net._lookup_fused(q)
    assert np.all(np.asarray(pruned.cost) >= np.asarray(exact.cost))
    assert np.all(np.asarray(pruned.cost) <= net.h_repo + 1e-6)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000),
       prune=st.sampled_from(["lsh", "kmeans"]))
def test_pruned_verify_closes_gap(seed, prune):
    """verify=True closes the pruning gap to 0 — bit-identical winners
    *and* costs vs the exact fused path, for every sampled
    placement/query batch."""
    net, q = _sampled_placement_net(seed)
    res = net.lookup(q, prune=prune, verify=True)
    exact = net._lookup_fused(q)
    for name in ("level", "slot", "payload", "cost", "approx_cost"):
        np.testing.assert_array_equal(
            np.asarray(getattr(res, name)),
            np.asarray(getattr(exact, name)), err_msg=name)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000),
       metric=st.sampled_from(["l2", "l2sq", "l1"]),
       gamma=st.sampled_from([0.7, 1.0, 2.0]))
def test_quantized_bound_never_exceeds_exact_cost(seed, metric, gamma):
    """Admissibility of the raw lb machinery: for every random catalog,
    metric and γ the certified int8 lower bound on C_a is ≤ the exact
    f32 cost for *every* pair — the property that makes the quantized
    first pass safe to prune with."""
    from repro.core import costs
    from repro.kernels import quant
    rng = np.random.default_rng(seed)
    scale = float(10.0 ** rng.uniform(-2, 2))
    keys = jnp.asarray(rng.standard_normal((70, 5)).astype(np.float32)
                       * scale)
    q = jnp.asarray(rng.standard_normal((24, 5)).astype(np.float32)
                    * scale)
    kq = quant.quantize_rows(keys, metric)
    lb = np.asarray(quant.lb_approx_cost_tiles(q, kq, metric, gamma))
    exact = np.asarray(costs.approx_cost(q, keys, metric, gamma))
    assert np.all(lb <= exact), (lb - exact).max()
    assert np.all(lb >= 0.0)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), top_t=st.sampled_from([1, 4, 16]))
def test_quantized_lookup_cost_admissible(seed, top_t):
    """The quantized first pass scans int8 lower bounds and re-scores
    only its top-T candidates exactly, so — like LSH pruning — its
    winning cost is ≥ the exact fused cost and ≤ h_repo for every query
    of every sampled placement/batch."""
    net, q = _sampled_placement_net(seed)
    got = net.lookup(q, quantize=True, top_t=top_t)
    exact = net._lookup_fused(q)
    assert np.all(np.asarray(got.cost) >= np.asarray(exact.cost))
    assert np.all(np.asarray(got.cost) <= net.h_repo + 1e-6)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), top_t=st.sampled_from([1, 4, 16]))
def test_quantized_verify_closes_gap(seed, top_t):
    """``quantize=True, verify=True`` is exact by construction: queries
    whose winning cost ≥ the per-query certificate are re-scanned
    through the exact kernel, so every field is bit-identical to the
    exact fused path even at top_t=1."""
    net, q = _sampled_placement_net(seed)
    res = net.lookup(q, quantize=True, verify=True, top_t=top_t)
    exact = net._lookup_fused(q)
    for name in ("level", "slot", "payload", "cost", "approx_cost"):
        np.testing.assert_array_equal(
            np.asarray(getattr(res, name)),
            np.asarray(getattr(exact, name)), err_msg=name)


def test_quantized_near_tie_rescoring_restores_winner():
    """Near-tie regression: a photo-finish cluster whose true cost gaps
    (~1e-4) sit far below int8 resolution at the working scale, so the
    quantized lower-bound ranks *actually reorder* the finish (asserted
    — the unverified top_t=1 winner is the wrong key). verify=True must
    restore the exact lexicographic winner bitwise."""
    rng = np.random.default_rng(0)
    dim = 6
    base = rng.standard_normal(dim).astype(np.float32) * 3
    # 12 keys at distance ≈5 from the probe with tiny gaps — 5 ≫ the
    # quantization radii, so the lb's don't clamp to 0 and the int8
    # rank order is decided by rounding noise, not by the true gaps
    dirs = rng.standard_normal((12, dim)).astype(np.float32)
    dirs /= np.linalg.norm(dirs, axis=1, keepdims=True)
    gaps = (rng.random(12) * 3e-4).astype(np.float32)
    keys = base + dirs * (5.0 + gaps)[:, None]
    far = rng.standard_normal((30, dim)).astype(np.float32) * 8 + 30
    coords = np.concatenate([keys, far]).astype(np.float32)
    slots = np.arange(coords.shape[0]).astype(np.int64)
    slot_cache = np.zeros(coords.shape[0], np.int64)
    net = SimCacheNetwork.from_placement(coords, slots, slot_cache,
                                         hs=[0.0], h_repo=100.0,
                                         metric="l2")
    q = jnp.asarray(base[None])
    exact = net._lookup_fused(q)
    unverified = net.lookup(q, quantize=True, top_t=1)
    assert int(np.asarray(unverified.slot)[0]) != \
        int(np.asarray(exact.slot)[0])            # ranks really reorder
    res = net.lookup(q, quantize=True, verify=True, top_t=1)
    for name in ("level", "slot", "payload", "cost", "approx_cost"):
        np.testing.assert_array_equal(
            np.asarray(getattr(res, name)),
            np.asarray(getattr(exact, name)), err_msg=name)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_localswap_incremental_bit_identical(seed):
    """Delta best-two re-arm == full rebuild along the whole scanned
    LOCALSWAP trajectory, on every random instance (cap overflow inside
    the scan falls back to the rebuild branch, so this also covers the
    lax.cond seam)."""
    inst = make_random_instance(seed, n_obj=8, k=(2, 2), metric="l2")
    dinst = DeviceInstance.from_instance(inst)
    a = device_localswap(dinst, n_iters=250, seed=seed, incremental=True)
    b = device_localswap(dinst, n_iters=250, seed=seed, incremental=False)
    np.testing.assert_array_equal(a.slots_np, b.slots_np)
    assert a.n_swaps == b.n_swaps
    for name in ("best1", "arg1", "best2"):
        np.testing.assert_array_equal(np.asarray(getattr(a, name)),
                                      np.asarray(getattr(b, name)))


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_quantized_gains_upper_bound_and_greedy_identical(seed):
    """The quantized gain oracle returns *upper* bounds (lower-bound
    C_a ⇒ upper-bound gain), so lazy GREEDY's exact re-scoring before
    acceptance keeps the allocation bit-identical to the exact oracle."""
    inst = make_random_instance(seed, n_obj=7, k=(2, 3), metric="l2")
    dinst = DeviceInstance.from_instance(inst)
    cur = dinst.initial_costs()
    g_exact = np.asarray(dinst.gains(cur))
    g_q = np.asarray(dinst.gains(cur, quantize=True))
    assert np.all(g_q >= g_exact - 0.0)          # admissible upper bound
    np.testing.assert_array_equal(device_greedy(dinst, quantize=True),
                                  device_greedy(dinst))


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), delta=st.sampled_from([0.0, 0.05, 0.3]))
def test_netduel_promotions_never_hurt_window_cost(seed, delta):
    """§5 settle rule: a virtual wins only with vs > (1+δ)·rs and
    vs > 0, i.e. on the duel's *own* window requests the promoted
    object's measured saving strictly exceeds the incumbent's — the
    window-measured cost change rs − vs is < −δ·rs ≤ 0 for every
    promotion, on every random instance and margin."""
    inst = make_random_instance(seed, n_obj=8, k=(2, 2), h_repo=5.0)
    st_ = device_netduel(DeviceInstance.from_instance(inst),
                         n_iters=2500, seed=seed + 1, window=120,
                         delta=delta, arm_prob=0.6, record_events=True)
    for (t, y, obj, rs, vs) in st_.promotions:
        assert vs > 0.0
        assert vs > (1.0 + np.float32(delta)) * np.float32(rs)
        assert rs - vs < -delta * rs + 1e-9      # window cost never rises


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000), topk=st.sampled_from([1, 2, 7, 64]))
def test_scanned_greedy_bit_identical_at_every_topk(seed, topk):
    """The single-launch while_loop GREEDY is pure batching: at every
    stale-refresh width ``topk`` it returns exactly the per-step path's
    allocation (which is itself the host oracle's)."""
    inst = make_random_instance(seed, n_obj=7, k=(2, 3), metric="l2")
    dinst = DeviceInstance.from_instance(inst)
    stepped = device_greedy(dinst, topk=topk, scan=False)
    scanned = device_greedy(dinst, topk=topk, scan=True)
    np.testing.assert_array_equal(stepped, scanned)
    np.testing.assert_array_equal(scanned, greedy(inst))


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_scanned_localswap_bit_identical(seed):
    """One scan launch per window == one jitted step per request."""
    inst = make_random_instance(seed, n_obj=8, k=(2, 2), metric="l2")
    dinst = DeviceInstance.from_instance(inst)
    a = device_localswap(dinst, n_iters=250, seed=seed, scan=False)
    b = device_localswap(dinst, n_iters=250, seed=seed, scan=True)
    np.testing.assert_array_equal(a.slots_np, b.slots_np)
    assert a.n_swaps == b.n_swaps


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_request_costs_bounded_and_monotone(seed):
    inst = make_random_instance(seed, n_obj=6, k=(2, 2))
    rng = np.random.default_rng(seed)
    slots = random_slots(inst, rng)
    costs = inst.request_costs(slots)
    repo = inst.net.h_repo[:, None]
    assert np.all(costs <= repo + 1e-6)          # eq. (1): repo caps cost
    # adding an approximizer (filling an empty slot) never hurts anyone
    slots2 = slots.copy()
    empty = np.where(slots2 < 0)[0]
    probe = empty[0] if empty.size else 0
    slots2[probe] = int(rng.integers(0, 6))
    if (slots2 >= 0).sum() >= (slots >= 0).sum():
        pass  # replacement case can hurt; only check pure additions
    if empty.size:
        costs2 = inst.request_costs(slots2)
        assert np.all(costs2 <= costs + 1e-6)
