"""Differential multi-device suite for the mesh-sharded fused lookup.

Three implementations of eq. (1) must agree everywhere:
  * looped  — one KNN kernel per level, minima compared centrally;
  * fused   — one segmented-1-NN pallas_call over the concatenation;
  * sharded — the fused kernel once *per key shard* under shard_map,
    per-shard minima all-gathered and reduced lexicographically (min
    cost, ties to the lowest shard = lowest concatenated index), with
    the repository folded once after the reduction.

The sharded path is required to be **bit-identical** to the fused path
for γ = 1 (identical f32 arithmetic per (query, key) pair; the reduction
is an argmin over exactly the kernel's own running-min values); for
γ ≠ 1 XLA may contract pow/sqrt/add chains differently across kernels,
so costs compare to 1e-6 like the existing fused-vs-looped suite.

Coverage: uneven shard sizes (ΣK_j not divisible by the shard count →
invalid padding keys), empty levels whose sentinel keys straddle shard
boundaries, exact cost ties across shards (tie-break determinism), B=1
and multi-query-tile batches, and the memoized-layout staleness
contract.

Device counts: the pure-jnp chunked oracle (sharded_fused_lookup_ref)
runs in-process at any shard count; real-mesh tests run either on a
1-device mesh in-process, on an 8-way mesh in a subprocess (always), or
in-process when the suite itself runs under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the second CI
pass — see scripts/ci.sh).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import assert_results_equal, make_net

from repro.core.simcache import REPO_LEVEL, CacheLevel, SimCacheNetwork
from repro.kernels.knn import sharded_fused_lookup_ref

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
EIGHT = jax.device_count() >= 8


# --------------------------------------------------------------- oracle
@pytest.mark.parametrize("metric", ["l1", "l2", "l2sq"])
@pytest.mark.parametrize("n_shards", [1, 2, 3, 5, 8, 17])
def test_sharded_oracle_matches_fused(metric, n_shards):
    """The mesh-free chunked oracle reproduces the fused path bit-for-bit
    at every shard count — including counts that don't divide ΣK_j
    (padding) and counts exceeding ΣK_j (some shards entirely padding)."""
    net, rng = make_net(0, [5, 9, 3], [0.0, 0.5, 1.0], 2.0, metric)
    q = jnp.asarray((rng.standard_normal((23, 6)) * 2).astype(np.float32))
    ref = net._lookup_fused(q)
    keys, h_key, meta = net.fused_layout()
    cost, ca, lvl, slot, pay = sharded_fused_lookup_ref(
        q, keys, h_key, meta, n_shards, metric=metric, h_repo=2.0)
    np.testing.assert_array_equal(np.asarray(cost), np.asarray(ref.cost))
    np.testing.assert_array_equal(np.asarray(ca),
                                  np.asarray(ref.approx_cost))
    np.testing.assert_array_equal(np.asarray(lvl), np.asarray(ref.level))
    np.testing.assert_array_equal(np.asarray(slot), np.asarray(ref.slot))
    np.testing.assert_array_equal(np.asarray(pay), np.asarray(ref.payload))


@pytest.mark.parametrize("n_shards", [2, 4, 7])
def test_sharded_oracle_empty_levels_and_repo(n_shards):
    """Sentinel keys of empty levels land in arbitrary shards and must
    stay masked; an all-empty network serves everything from the repo."""
    net, rng = make_net(3, [4, 1, 4], [0.0, 0.1, 0.4], 2.5, "l2sq",
                        empty=(1,))
    q = jnp.asarray(rng.standard_normal((11, 6)).astype(np.float32))
    keys, h_key, meta = net.fused_layout()
    out = sharded_fused_lookup_ref(q, keys, h_key, meta, n_shards,
                                   metric="l2sq", h_repo=2.5)
    assert not np.any(np.asarray(out[2]) == 1)
    assert np.all(np.isfinite(np.asarray(out[0])))
    ref = net._lookup_fused(q)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(ref.cost))

    net_all, rng = make_net(4, [1, 1], [0.0, 0.3], 7.5, "l2",
                            empty=(0, 1))
    q = jnp.asarray(rng.standard_normal((5, 6)).astype(np.float32))
    keys, h_key, meta = net_all.fused_layout()
    cost, ca, lvl, slot, pay = sharded_fused_lookup_ref(
        q, keys, h_key, meta, n_shards, metric="l2", h_repo=7.5)
    np.testing.assert_allclose(np.asarray(cost), 7.5)
    np.testing.assert_array_equal(np.asarray(lvl), REPO_LEVEL)
    np.testing.assert_array_equal(np.asarray(pay), -1)
    np.testing.assert_array_equal(np.asarray(ca), 0.0)


# ------------------------------------------------------- 1-device mesh
def test_sharded_one_device_mesh_bit_identical():
    """The real shard_map path on a trivial 1-device mesh: sharded ==
    fused == looped, bitwise (γ = 1)."""
    mesh = jax.make_mesh((1,), ("data",))
    net, rng = make_net(1, [17, 2, 31, 8], [0.0, 0.2, 0.7, 1.3], 3.0)
    snet, _ = make_net(1, [17, 2, 31, 8], [0.0, 0.2, 0.7, 1.3], 3.0,
                       sharded=True, mesh=mesh)
    q = jnp.asarray((rng.standard_normal((23, 6)) * 2).astype(np.float32))
    assert_results_equal(snet.lookup(q), net._lookup_fused(q))
    assert_results_equal(snet.lookup(q), net._lookup_looped(q))


def test_sharded_no_levels_serves_repo():
    mesh = jax.make_mesh((1,), ("data",))
    net = SimCacheNetwork(levels=[], h_repo=4.5, metric="l2",
                          sharded=True, mesh=mesh)
    q = jnp.asarray(np.random.default_rng(0)
                    .standard_normal((6, 5)).astype(np.float32))
    res = net.lookup(q)
    np.testing.assert_array_equal(np.asarray(res.level), REPO_LEVEL)
    np.testing.assert_allclose(np.asarray(res.cost), 4.5)
    assert not np.any(np.asarray(res.hit))


# -------------------------------------------------- staleness contract
@pytest.mark.parametrize("sharded", [False, True])
def test_stale_layout_then_invalidate(sharded):
    """Documented memoization contract: mutating ``levels`` without
    invalidate_layout() keeps serving the *stale* concatenation (old
    results, verbatim); invalidate_layout() restores agreement with the
    looped path — for both the fused and the sharded data plane."""
    kw = dict(sharded=True, mesh=jax.make_mesh((1,), ("data",))) \
        if sharded else {}
    net, rng = make_net(10, [4, 4], [0.0, 0.5], 3.0, "l2", **kw)
    q = jnp.asarray(rng.standard_normal((8, 6)).astype(np.float32))
    before = net.lookup(q)                       # memoizes the layout
    new_keys = jnp.asarray(rng.standard_normal((5, 6)).astype(np.float32))
    net.levels[0] = CacheLevel(
        keys=new_keys,
        values=jnp.asarray(np.arange(100, 105, dtype=np.int32)), h=0.0)
    stale = net.lookup(q)                        # no invalidate yet
    assert_results_equal(stale, before)          # serves the old layout
    # the looped path reads `levels` directly, so it already disagrees
    # (the mutation moved level 0's keys under the queries)
    assert not np.array_equal(np.asarray(stale.payload),
                              np.asarray(net._lookup_looped(q).payload))
    net.invalidate_layout()
    assert_results_equal(net.lookup(q), net._lookup_looped(q))


def test_invalidate_layout_clears_sharded_memo():
    mesh = jax.make_mesh((1,), ("data",))
    net, rng = make_net(11, [6, 3], [0.0, 0.4], 2.0, "l2",
                        sharded=True, mesh=mesh)
    q = jnp.asarray(rng.standard_normal((4, 6)).astype(np.float32))
    net.lookup(q)
    assert net._sharded_layout          # memoized per shard count
    net.invalidate_layout()
    assert not net._sharded_layout and net._layout is None


# ------------------------------------------------------- shard policy
def test_lookup_shard_policy_contract():
    """LookupShardPolicy resolves shard axes from the mesh (preference:
    model → data → pod, falling back to all axes for unrecognised
    meshes); n_shards is the product of the chosen axis sizes."""
    from repro.launch.sharding import LookupShardPolicy

    pol = LookupShardPolicy.create(jax.make_mesh((1,), ("data",)))
    assert pol.axes == ("data",) and pol.n_shards == 1

    pol2 = LookupShardPolicy.create(jax.make_mesh((1, 1),
                                                  ("data", "model")))
    assert pol2.axes == ("model", "data")        # model preferred first
    # unrecognised axis names: shard over whatever the mesh has
    pol3 = LookupShardPolicy.create(jax.make_mesh((1,), ("lookup",)))
    assert pol3.axes == ("lookup",)

    # shard-count arithmetic at a multi-device count (mesh shape is the
    # only thing n_shards consults, so a stub suffices on 1 device)
    class _Mesh:
        shape = {"model": 4, "data": 2}
    pol4 = LookupShardPolicy(mesh=_Mesh(), axes=("model", "data"))
    assert pol4.n_shards == 8


# ------------------------------------------------------- dtype contract
def test_from_placement_sentinel_values_dtype():
    """Empty levels must build their sentinel ``values`` as int32
    directly (the old path built int64 then downcast), and occupied
    levels likewise store int32 payloads end to end."""
    rng = np.random.default_rng(9)
    coords = rng.standard_normal((40, 5)).astype(np.float32)
    slot_cache = np.array([0] * 4 + [1] * 4)
    slots = np.concatenate([rng.choice(40, 4, replace=False),
                            np.full(4, -1)]).astype(np.int64)
    net = SimCacheNetwork.from_placement(coords, slots, slot_cache,
                                         hs=[0.0, 0.5], h_repo=2.0)
    for lv in net.levels:
        assert lv.values.dtype == jnp.int32, lv.values.dtype
        assert lv.keys.dtype == jnp.float32
    assert int(net.levels[1].values[0]) == -1       # sentinel payload


# ------------------------------------------- in-process 8-way (CI pass 2)
@pytest.mark.skipif(not EIGHT, reason="needs 8 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
@pytest.mark.parametrize("metric,gamma", [("l2", 1.0), ("l1", 1.0),
                                          ("l2sq", 2.0)])
def test_sharded_eight_way_differential(metric, gamma):
    mesh = jax.make_mesh((8,), ("data",))
    for seed, sizes, hs, h_repo, nq in [
        (0, [5, 9, 3], [0.0, 0.5, 1.0], 2.0, 23),      # K=17: pad to 24
        (1, [17, 2, 31, 8], [0.0, 0.2, 0.7, 1.3], 3.0, 1),   # B=1
        (3, [200, 150, 250], [0.0, 0.4, 0.8], 2.5, 300),     # multi-tile
    ]:
        net, rng = make_net(seed, sizes, hs, h_repo, metric, gamma)
        snet, _ = make_net(seed, sizes, hs, h_repo, metric, gamma,
                           sharded=True, mesh=mesh)
        q = jnp.asarray((rng.standard_normal((nq, 6)) * 2)
                        .astype(np.float32))
        assert_results_equal(snet.lookup(q), net._lookup_fused(q),
                             exact_cost=gamma == 1.0)
        assert_results_equal(snet.lookup(q), net._lookup_looped(q),
                             exact_cost=gamma == 1.0)


@pytest.mark.skipif(not EIGHT, reason="needs 8 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
def test_sharded_eight_way_tie_break():
    mesh = jax.make_mesh((8,), ("data",))
    net, snet, q = _tie_instance(mesh)
    rf, rs = net._lookup_fused(q), snet.lookup(q)
    assert_results_equal(rs, rf)
    # the duplicate key tying across levels resolves to the lower level
    np.testing.assert_array_equal(np.asarray(rs.level), 0)
    np.testing.assert_array_equal(np.asarray(rs.slot), 5)


def _tie_instance(mesh):
    """Two 8-key levels with equal h and an identical key planted at
    slot 5 of both — concatenated indices 5 and 13 land in *different*
    shards of an 8-way mesh (2 keys per shard), so the cross-shard
    reduction must break the exact cost tie toward the lower shard."""
    rng = np.random.default_rng(42)
    dup = np.ones((1, 6), np.float32)
    mk = lambda: np.concatenate(                      # noqa: E731
        [(rng.standard_normal((5, 6)) * 9 + 20).astype(np.float32), dup,
         (rng.standard_normal((2, 6)) * 9 + 20).astype(np.float32)])
    levels = [CacheLevel(keys=jnp.asarray(mk()),
                         values=jnp.asarray(
                             np.arange(8 * j, 8 * j + 8, dtype=np.int32)),
                         h=0.5) for j in range(2)]
    net = SimCacheNetwork(levels=list(levels), h_repo=9.0)
    snet = SimCacheNetwork(levels=list(levels), h_repo=9.0, sharded=True,
                           mesh=mesh)
    return net, snet, jnp.asarray(np.broadcast_to(dup, (3, 6)).copy())


def test_sharded_tie_break_oracle_any_devices():
    """Same tie instance, via the chunked oracle (no mesh needed)."""
    net, _, q = _tie_instance(jax.make_mesh((1,), ("data",)))
    keys, h_key, meta = net.fused_layout()
    out = sharded_fused_lookup_ref(q, keys, h_key, meta, 8, h_repo=9.0)
    np.testing.assert_array_equal(np.asarray(out[2]), 0)    # level
    np.testing.assert_array_equal(np.asarray(out[3]), 5)    # slot
    ref = net._lookup_fused(q)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(ref.cost))


# ---------------------------------------------------- 8-way subprocess
def run_in_subprocess(body: str):
    """8 forced host devices in a fresh interpreter, independent of the
    parent's device count (XLA_FLAGS is popped from the env and re-set
    in-script), so these tests give real 8-way mesh coverage even in the
    default single-device tier-1 pass. ci.sh's 8-device pass 2 deselects
    them (-k "not _subprocess") — rerunning them there adds nothing."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        import jax.numpy as jnp
        import numpy as np
        assert jax.device_count() == 8
        from repro.core.simcache import (REPO_LEVEL, SENTINEL_COORD,
                                         CacheLevel, SimCacheNetwork)

        def make_net(seed, sizes, hs, h_repo, metric="l2", gamma=1.0,
                     d=6, empty=(), **kw):
            rng = np.random.default_rng(seed)
            levels = []
            for j, (k, h) in enumerate(zip(sizes, hs)):
                if j in empty:
                    keys = np.full((1, d), SENTINEL_COORD, np.float32)
                    vals = np.full((1,), -1, np.int32)
                else:
                    keys = (rng.standard_normal((k, d)) * 2).astype(
                        np.float32)
                    vals = rng.integers(0, 10_000, k).astype(np.int32)
                levels.append(CacheLevel(keys=jnp.asarray(keys),
                                         values=jnp.asarray(vals),
                                         h=float(h)))
            return SimCacheNetwork(levels=levels, h_repo=float(h_repo),
                                   metric=metric, gamma=gamma, **kw), rng

        def check(a, b, exact=True):
            for n in ("level", "slot", "payload", "hit"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(a, n)), np.asarray(getattr(b, n)),
                    err_msg=n)
            for n in ("cost", "approx_cost"):
                x = np.asarray(getattr(a, n))
                y = np.asarray(getattr(b, n))
                if exact:
                    np.testing.assert_array_equal(x, y, err_msg=n)
                else:
                    np.testing.assert_allclose(x, y, rtol=1e-6,
                                               atol=1e-6, err_msg=n)
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, \
        f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_eight_way_mesh_differential_subprocess():
    """The acceptance-criterion run: sharded == fused == looped on a real
    8-way host-device mesh, covering uneven shard sizes (padding), empty
    levels with sentinels split across shards, B=1, and a multi-tile
    batch."""
    run_in_subprocess("""
        mesh = jax.make_mesh((8,), ("data",))
        cases = [
            # uneven: K=17 pads to 24, shards hold 3 keys, 7 of them pad
            (0, [5, 9, 3], [0.0, 0.5, 1.0], 2.0, "l2", 1.0, (), 23),
            # B=1 and a 4-level chain
            (1, [17, 2, 31, 8], [0.0, 0.2, 0.7, 1.3], 3.0, "l2", 1.0,
             (), 1),
            # empty middle level: its sentinel is one of K=9 keys spread
            # over 8 shards — masking must survive the shard split
            (3, [4, 1, 4], [0.0, 0.1, 0.4], 2.5, "l2sq", 1.0, (1,), 11),
            # all levels empty: everything from the repository
            (4, [1, 1], [0.0, 0.3], 7.5, "l1", 1.0, (0, 1), 5),
            # large batch: 700 queries = 3 query tiles at BQ=256
            (5, [200, 150, 250], [0.0, 0.4, 0.8], 2.5, "l2", 1.0,
             (), 700),
            # gamma != 1 compares costs to 1e-6 (FMA contraction)
            (6, [64, 64], [0.0, 1.0], 5.0, "l2", 2.0, (), 23),
        ]
        for (seed, sizes, hs, h_repo, metric, gamma, empty, nq) in cases:
            net, rng = make_net(seed, sizes, hs, h_repo, metric, gamma,
                                empty=empty)
            snet, _ = make_net(seed, sizes, hs, h_repo, metric, gamma,
                               empty=empty, sharded=True, mesh=mesh)
            q = jnp.asarray((rng.standard_normal((nq, 6)) * 2)
                            .astype(np.float32))
            rs = snet.lookup(q)
            check(rs, net._lookup_fused(q), exact=gamma == 1.0)
            check(rs, net._lookup_looped(q), exact=gamma == 1.0)
            if empty:
                for e in empty:
                    assert not np.any(np.asarray(rs.level) == e)
        print("8-way differential ok:", len(cases), "cases")
    """)


def test_eight_way_ties_and_staleness_subprocess():
    run_in_subprocess("""
        mesh = jax.make_mesh((8,), ("data",))
        # exact tie across shards: identical key at slot 5 of two levels
        # with equal h (concatenated indices 5 and 13 → shards 2 and 6);
        # deterministic winner = lower shard = lower level
        rng = np.random.default_rng(42)
        dup = np.ones((1, 6), np.float32)
        mk = lambda: np.concatenate(
            [(rng.standard_normal((5, 6)) * 9 + 20).astype(np.float32),
             dup,
             (rng.standard_normal((2, 6)) * 9 + 20).astype(np.float32)])
        levels = [CacheLevel(keys=jnp.asarray(mk()),
                             values=jnp.asarray(np.arange(
                                 8 * j, 8 * j + 8, dtype=np.int32)),
                             h=0.5) for j in range(2)]
        net = SimCacheNetwork(levels=list(levels), h_repo=9.0)
        snet = SimCacheNetwork(levels=list(levels), h_repo=9.0,
                               sharded=True, mesh=mesh)
        q = jnp.asarray(np.broadcast_to(dup, (3, 6)).copy())
        rs = snet.lookup(q)
        check(rs, net._lookup_fused(q))
        assert np.all(np.asarray(rs.level) == 0), np.asarray(rs.level)
        assert np.all(np.asarray(rs.slot) == 5), np.asarray(rs.slot)
        # repo tie on the sharded path: h level == h_repo → cache serves
        key = np.ones((1, 6), np.float32)
        tie = SimCacheNetwork(
            levels=[CacheLevel(keys=jnp.asarray(key),
                               values=jnp.asarray(
                                   np.array([7], np.int32)), h=2.0)],
            h_repo=2.0, sharded=True, mesh=mesh)
        r = tie.lookup(jnp.asarray(key))
        assert int(r.level[0]) == 0 and int(r.payload[0]) == 7
        # staleness on a real mesh: stale sharded layout serves the old
        # keys until invalidate_layout()
        snet2 = SimCacheNetwork(levels=list(levels), h_repo=9.0,
                                sharded=True, mesh=mesh)
        before = snet2.lookup(q)
        snet2.levels[0] = CacheLevel(
            keys=jnp.asarray(np.full((4, 6), 50.0, np.float32)),
            values=jnp.asarray(np.arange(4, dtype=np.int32)), h=0.5)
        stale = snet2.lookup(q)
        np.testing.assert_array_equal(np.asarray(stale.payload),
                                      np.asarray(before.payload))
        snet2.invalidate_layout()
        ref = SimCacheNetwork(levels=list(snet2.levels), h_repo=9.0)
        check(snet2.lookup(q), ref._lookup_fused(q))
        print("8-way ties + staleness ok")
    """)
