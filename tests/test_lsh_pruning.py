"""Differential suite for LSH / k-means candidate pruning in front of
the fused segmented-1-NN lookup (kernels/knn/lsh.py).

Three requirements, mirroring test_sharded_lookup.py's structure:

  * **recall** — at default table parameters the pruned lookup (no
    verification) finds the exact winner for ≥ 99% of queries drawn
    from the paper's Gaussian-grid and Zipf demands;
  * **exactness** — with ``verify=True`` the pruned path re-scans every
    query whose pruned cost reaches the un-scanned-h bound and must be
    **bit-identical** to the exact fused path (and to the looped
    per-level reference) on every covered configuration: both policies,
    all metrics, γ ≠ 1, empty levels, B = 1 and multi-tile batches,
    single-device and sharded;
  * **composition** — pruning only ever shrinks a shard's scan: the
    per-shard candidate mask must not disturb ``reduce_shard_minima``
    or the cross-shard tie-break order, and empty-level sentinels /
    shard padding must never be selected as candidates.

Staleness is *stricter* than the fused layout's documented
serve-stale-verbatim contract: a pruned lookup against mutated but not
invalidated ``levels`` must raise, not return stale candidates.

The 10⁶-key recall test is marked ``slow`` and gated on CI_FULL=1 — it
runs only in the nightly/full pass (scripts/ci.sh).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import assert_results_equal, make_net

from benchmarks.common import lookup_recall
from repro.core import catalog as catalog_api
from repro.core import demand as demand_api
from repro.core.simcache import REPO_LEVEL, CacheLevel, SimCacheNetwork
from repro.kernels.knn import (KMeansPolicy, SimHashPolicy, pad_to_shards,
                               pruned_fused_lookup, pruned_fused_lookup_ref,
                               sharded_pruned_fused_lookup_ref)

EIGHT = jax.device_count() >= 8
FULL = bool(os.environ.get("CI_FULL"))

# probes both buckets of every 1-bit table → all valid keys are
# candidates; pruning becomes a pure re-indexing of the exact scan, the
# right instrument for deterministic tie-break tests
COVER_ALL = SimHashPolicy(n_tables=2, n_bits=1, n_probes=2)


# ------------------------------------------------------------- exactness
@pytest.mark.parametrize("prune", ["lsh", "kmeans"])
@pytest.mark.parametrize("metric,gamma", [("l2", 1.0), ("l1", 1.0),
                                          ("l2sq", 1.0), ("l2", 2.0)])
def test_pruned_verify_bit_identical(prune, metric, gamma):
    """verify=True must reproduce the exact fused path bit-for-bit (and
    the looped reference), whatever the candidate tables missed —
    covering B=1 and a 700-query multi-tile batch."""
    for seed, sizes, hs, h_repo, nq in [
        (0, [5, 9, 3], [0.0, 0.5, 1.0], 2.0, 23),
        (1, [17, 2, 31, 8], [0.0, 0.2, 0.7, 1.3], 3.0, 1),      # B=1
        (5, [200, 150, 250], [0.0, 0.4, 0.8], 2.5, 700),  # 3 query tiles
    ]:
        net, rng = make_net(seed, sizes, hs, h_repo, metric, gamma)
        q = jnp.asarray((rng.standard_normal((nq, 6)) * 2)
                        .astype(np.float32))
        res = net.lookup(q, prune=prune, verify=True)
        assert_results_equal(res, net._lookup_fused(q),
                             exact_cost=gamma == 1.0)
        assert_results_equal(res, net._lookup_looped(q),
                             exact_cost=gamma == 1.0)


@pytest.mark.parametrize("prune", ["lsh", "kmeans"])
def test_pruned_verify_bit_identical_sharded(prune):
    """Same contract through the mesh-sharded data plane (per-shard
    tables + fold_repo=False launches + untouched reduction)."""
    mesh = jax.make_mesh((1,), ("data",))
    net, rng = make_net(1, [17, 2, 31, 8], [0.0, 0.2, 0.7, 1.3], 3.0)
    snet, _ = make_net(1, [17, 2, 31, 8], [0.0, 0.2, 0.7, 1.3], 3.0,
                       sharded=True, mesh=mesh)
    q = jnp.asarray((rng.standard_normal((23, 6)) * 2).astype(np.float32))
    res = snet.lookup(q, prune=prune, verify=True)
    assert_results_equal(res, net._lookup_fused(q))
    assert_results_equal(res, snet.lookup(q))


def test_pruned_full_coverage_equals_exact_without_verify():
    """A policy whose probes cover every bucket makes pruning a pure
    ascending re-indexing of the full scan: bit-identical even with
    verify=False, and the bound is +INF (nothing un-scanned)."""
    net, rng = make_net(2, [64, 64], [0.0, 1.0], 5.0,
                        candidate_policy=COVER_ALL)
    q = jnp.asarray((rng.standard_normal((23, 6)) * 2).astype(np.float32))
    assert_results_equal(net.lookup(q, prune="lsh"), net._lookup_fused(q))
    keys, h_key, meta = net.fused_layout()
    t = COVER_ALL.build(np.asarray(keys), np.asarray(meta)[3] > 0)
    *_, bound = pruned_fused_lookup_ref(q, keys, h_key, meta, t,
                                        cap_union=keys.shape[0],
                                        h_repo=5.0)
    assert float(bound) >= 1e38


# --------------------------------------------------------------- recall
@pytest.mark.parametrize("prune", ["lsh", "kmeans"])
@pytest.mark.parametrize("workload", ["gauss", "zipf"])
def test_recall_on_paper_demands(prune, workload):
    """Default table parameters reach recall ≥ 0.99 on queries drawn
    from the paper's Gaussian-grid (§6.1) and Zipf-embedding (§6.2)
    demand models."""
    rng = np.random.default_rng(7)
    if workload == "gauss":
        cat = catalog_api.grid(L=40)                     # 1600 objects
        dem = demand_api.gaussian_grid(cat, sigma=8.0)
        metric = "l1"
    else:
        cat = catalog_api.embedding_catalog(n=2000, dim=16, seed=3)
        dem = demand_api.zipf(cat, alpha=0.8, seed=4)
        metric = "l2"
    stored = rng.choice(cat.n, 600, replace=False)
    levels = [CacheLevel(
        keys=jnp.asarray(cat.coords[idx]),
        values=jnp.asarray(idx.astype(np.int32)), h=float(h))
        for idx, h in ((stored[:400], 0.0), (stored[400:], 0.5))]
    net = SimCacheNetwork(levels=levels, h_repo=1e9, metric=metric)
    obj, _ = dem.sample(512, rng)
    q = jnp.asarray(cat.coords[obj])
    pruned = net.lookup(q, prune=prune)
    exact = net._lookup_fused(q)
    r = lookup_recall(pruned, exact)
    assert r >= 0.99, (prune, workload, r)
    # admissibility rides along: pruning can only raise the cost
    assert np.all(np.asarray(pruned.cost) >= np.asarray(exact.cost))


# ----------------------------------------------------- sentinel masking
@pytest.mark.parametrize("prune", ["lsh", "kmeans"])
def test_empty_level_sentinels_never_candidates(prune):
    """Sentinel keys of empty levels carry valid == 0 and must be
    excluded at table-build time (never in any bucket) and never be
    served; an all-empty network still answers from the repository."""
    net, rng = make_net(3, [4, 1, 4], [0.0, 0.1, 0.4], 2.5, "l2sq",
                        empty=(1,))
    keys, _, meta = net.fused_layout()
    sentinel_row = 4                      # level 1's single sentinel slot
    assert int(np.asarray(meta)[3, sentinel_row]) == 0
    for policy in (SimHashPolicy(), KMeansPolicy()):
        t = policy.build(np.asarray(keys), np.asarray(meta)[3] > 0)
        assert not np.any(t.buckets == sentinel_row)
    q = jnp.asarray(rng.standard_normal((11, 6)).astype(np.float32))
    for verify in (False, True):
        res = net.lookup(q, prune=prune, verify=verify)
        assert not np.any(np.asarray(res.level) == 1)
        assert np.all(np.isfinite(np.asarray(res.cost)))
    assert_results_equal(net.lookup(q, prune=prune, verify=True),
                         net._lookup_fused(q))

    net_all, rng = make_net(4, [1, 1], [0.0, 0.3], 7.5, "l2",
                            empty=(0, 1))
    q = jnp.asarray(rng.standard_normal((5, 6)).astype(np.float32))
    res = net_all.lookup(q, prune=prune, verify=True)
    np.testing.assert_array_equal(np.asarray(res.level), REPO_LEVEL)
    np.testing.assert_allclose(np.asarray(res.cost), 7.5)
    np.testing.assert_array_equal(np.asarray(res.payload), -1)


def test_no_levels_at_all_pruned():
    net = SimCacheNetwork(levels=[], h_repo=4.5, metric="l2")
    q = jnp.asarray(np.random.default_rng(0)
                    .standard_normal((6, 5)).astype(np.float32))
    res = net.lookup(q, prune="lsh", verify=True)
    np.testing.assert_array_equal(np.asarray(res.level), REPO_LEVEL)
    np.testing.assert_allclose(np.asarray(res.cost), 4.5)


# ------------------------------------------- cross-shard tie determinism
def _tie_instance(**kw):
    """Two 8-key levels with equal h and an identical key planted at
    slot 5 of both — concatenated indices 5 and 13 land in different
    shards of an 8-way split, so the winner must be the lower shard
    (= lower level) even when both duplicates survive pruning."""
    rng = np.random.default_rng(42)
    dup = np.ones((1, 6), np.float32)
    mk = lambda: np.concatenate(                      # noqa: E731
        [(rng.standard_normal((5, 6)) * 9 + 20).astype(np.float32), dup,
         (rng.standard_normal((2, 6)) * 9 + 20).astype(np.float32)])
    levels = [CacheLevel(keys=jnp.asarray(mk()),
                         values=jnp.asarray(
                             np.arange(8 * j, 8 * j + 8, dtype=np.int32)),
                         h=0.5) for j in range(2)]
    net = SimCacheNetwork(levels=list(levels), h_repo=9.0,
                          candidate_policy=COVER_ALL, **kw)
    return net, jnp.asarray(np.broadcast_to(dup, (3, 6)).copy())


def test_pruned_tie_break_oracle_eight_shards():
    """The chunked per-shard oracle with full-coverage tables: pruning
    must not perturb the cross-shard exact-cost tie (lower shard wins),
    at shard counts that do and don't divide the key count."""
    net, q = _tie_instance()
    keys, h_key, meta = net.fused_layout()
    ref = net._lookup_fused(q)
    for n_shards in (2, 3, 8):
        kp, hp, mp = pad_to_shards(keys, h_key, meta, n_shards)
        S = kp.shape[0] // n_shards
        ts = [COVER_ALL.for_shard(s).build(
            np.asarray(kp)[s * S:(s + 1) * S],
            np.asarray(mp)[3, s * S:(s + 1) * S] > 0)
            for s in range(n_shards)]
        out = sharded_pruned_fused_lookup_ref(q, kp, hp, mp, ts,
                                              cap_union=S, h_repo=9.0)
        np.testing.assert_array_equal(np.asarray(out[2]), 0)     # level
        np.testing.assert_array_equal(np.asarray(out[3]), 5)     # slot
        np.testing.assert_array_equal(np.asarray(out[0]),
                                      np.asarray(ref.cost))


def test_pruned_tie_break_one_device_mesh():
    net, q = _tie_instance()
    snet, _ = _tie_instance(sharded=True,
                            mesh=jax.make_mesh((1,), ("data",)))
    for verify in (False, True):
        res = snet.lookup(q, prune="lsh", verify=verify)
        assert_results_equal(res, net._lookup_fused(q))
        np.testing.assert_array_equal(np.asarray(res.level), 0)
        np.testing.assert_array_equal(np.asarray(res.slot), 5)


@pytest.mark.skipif(not EIGHT, reason="needs 8 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
def test_pruned_tie_break_eight_way_mesh():
    """The real 8-way shard_map path: the duplicate keys sit in shards 2
    and 6 (2 keys per shard); the candidate mask only shrinks each
    shard's scan, so reduce_shard_minima still breaks the tie to the
    lower shard."""
    snet, q = _tie_instance(sharded=True,
                            mesh=jax.make_mesh((8,), ("data",)))
    net, _ = _tie_instance()
    for prune in ("lsh", "kmeans"):
        for verify in (False, True):
            res = snet.lookup(q, prune=prune, verify=verify)
            if prune == "lsh":        # full-coverage tables: bit-exact
                assert_results_equal(res, net._lookup_fused(q))
            np.testing.assert_array_equal(np.asarray(res.level), 0)
            np.testing.assert_array_equal(np.asarray(res.slot), 5)


@pytest.mark.skipif(not EIGHT, reason="needs 8 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
@pytest.mark.parametrize("prune", ["lsh", "kmeans"])
def test_pruned_eight_way_differential(prune):
    mesh = jax.make_mesh((8,), ("data",))
    for seed, sizes, hs, h_repo, empty, nq in [
        (0, [5, 9, 3], [0.0, 0.5, 1.0], 2.0, (), 23),
        (3, [4, 1, 4], [0.0, 0.1, 0.4], 2.5, (1,), 11),
        (5, [200, 150, 250], [0.0, 0.4, 0.8], 2.5, (), 300),
    ]:
        net, rng = make_net(seed, sizes, hs, h_repo, empty=empty)
        snet, _ = make_net(seed, sizes, hs, h_repo, empty=empty,
                           sharded=True, mesh=mesh)
        q = jnp.asarray((rng.standard_normal((nq, 6)) * 2)
                        .astype(np.float32))
        res = snet.lookup(q, prune=prune, verify=True)
        assert_results_equal(res, net._lookup_fused(q))
        if empty:
            for e in empty:
                assert not np.any(np.asarray(res.level) == e)


# ------------------------------------------------------------ staleness
@pytest.mark.parametrize("sharded", [False, True])
def test_stale_tables_fail_loudly(sharded):
    """Stricter than the layout's serve-stale-verbatim contract: a
    pruned lookup after mutating ``levels`` without invalidate_layout()
    must raise, not return candidates from the dead layout. After
    invalidation the rebuilt tables agree with the looped path again."""
    kw = dict(sharded=True, mesh=jax.make_mesh((1,), ("data",))) \
        if sharded else {}
    net, rng = make_net(10, [4, 4], [0.0, 0.5], 3.0, "l2", **kw)
    q = jnp.asarray(rng.standard_normal((8, 6)).astype(np.float32))
    net.lookup(q, prune="lsh")                   # builds layout + tables
    net.levels[0] = CacheLevel(
        keys=jnp.asarray(rng.standard_normal((5, 6)).astype(np.float32)),
        values=jnp.asarray(np.arange(100, 105, dtype=np.int32)), h=0.0)
    with pytest.raises(RuntimeError, match="stale candidate tables"):
        net.lookup(q, prune="lsh")
    # the un-pruned path keeps its documented stale-serve behaviour
    net.lookup(q)
    net.invalidate_layout()
    assert not net._tables
    assert_results_equal(net.lookup(q, prune="lsh", verify=True),
                         net._lookup_looped(q))


def test_invalidate_layout_clears_tables_memo():
    net, rng = make_net(11, [6, 3], [0.0, 0.4], 2.0, "l2")
    q = jnp.asarray(rng.standard_normal((4, 6)).astype(np.float32))
    net.lookup(q, prune="lsh")
    net.lookup(q, prune="kmeans")
    assert len(net._tables) == 2           # memoized per (policy, shards)
    net.lookup(q, prune="lsh")
    assert len(net._tables) == 2           # hit, not a rebuild
    net.invalidate_layout()
    assert not net._tables and net._layout is None


# ------------------------------------------------------ ops — ref oracle
def test_pruned_ops_matches_ref_oracle():
    """Same tables through the jitted gather entry (Pallas kernel) and
    the pure-jnp oracle: same winners, costs to 1e-6, same bound."""
    net, rng = make_net(7, [40, 25], [0.0, 0.4], 2.0, "l2", gamma=2.0)
    q = jnp.asarray(rng.standard_normal((19, 6)).astype(np.float32))
    keys, h_key, meta = net.fused_layout()
    pol = SimHashPolicy(n_tables=2, n_bits=3, n_probes=2)
    t = pol.build(np.asarray(keys), np.asarray(meta)[3] > 0)
    cap = pol.resolve_cap(keys.shape[0])
    out_k = pruned_fused_lookup(q, keys, h_key, meta,
                                jnp.asarray(t.proj), jnp.asarray(t.buckets),
                                kind=t.kind, n_probes=t.n_probes,
                                cap_union=cap, metric="l2", gamma=2.0,
                                h_repo=2.0)
    out_r = pruned_fused_lookup_ref(q, keys, h_key, meta, t, cap,
                                    metric="l2", gamma=2.0, h_repo=2.0)
    for a, b in zip(out_k, out_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)
    # use_pallas=False routes the same pruned path through the oracle
    import dataclasses
    res = net.lookup(q, prune="lsh", verify=True)
    no_pallas = dataclasses.replace(net, use_pallas=False)
    assert_results_equal(res, no_pallas.lookup(q, prune="lsh",
                                               verify=True),
                         exact_cost=False)


def test_hot_bucket_capped_and_verify_still_exact():
    """One bucket of near-duplicate popular keys must not inflate the
    dense table: per-bucket capacity clamps at 8× the mean load, the
    overflow (highest rows) is dropped at build time, and — because
    dropped members are "un-scanned" to the verify bound — verify=True
    stays bit-identical to the exact path regardless of the skew."""
    rng = np.random.default_rng(0)
    hot = np.ones((1, 6), np.float32) + \
        0.001 * rng.standard_normal((500, 6)).astype(np.float32)
    cold = (rng.standard_normal((100, 6)) * 9 + 20).astype(np.float32)
    keys = np.concatenate([hot, cold])
    net = SimCacheNetwork(
        levels=[CacheLevel(keys=jnp.asarray(keys),
                           values=jnp.asarray(np.arange(600,
                                                        dtype=np.int32)),
                           h=0.5)], h_repo=9.0)
    _, _, meta = net.fused_layout()
    pol = SimHashPolicy(n_bits=4)              # 16 buckets, mean load 38
    t = pol.build(keys, np.asarray(meta)[3] > 0)
    assert t.buckets.shape[-1] <= 8 * -(-600 // 16)   # capped, not 500
    q = jnp.asarray(np.concatenate(
        [hot[:3], cold[:3],
         rng.standard_normal((4, 6)).astype(np.float32)]))
    assert_results_equal(net.lookup(q, prune="lsh", verify=True),
                         net._lookup_fused(q))


# --------------------------------------------------- Demand.sample fix
def test_demand_sample_float32_catalog_reproducible():
    """Regression: probabilities normalized at float32 precision (what a
    float32 catalog produces) deviate from 1 by more than rng.choice's
    float64 tolerance (√eps ≈ 1.5e-8) and used to abort with
    "probabilities do not sum to 1"; sample() now casts to float64 and
    renormalizes, returning platform-independent int64 draws,
    reproducible under a fixed seed."""
    # float32-rounded thirds: sum in float64 is 1 + 3e-8, past tolerance
    lam = np.asarray(np.full((1, 3), np.float32(1 / 3)), np.float64)
    assert abs(float(lam.sum()) - 1.0) > 1.5e-8       # the trigger
    with pytest.raises(ValueError):                   # the old code path
        np.random.default_rng(0).choice(3, size=4, p=lam.ravel())
    dem = demand_api.Demand(lam=lam)
    obj, ing = dem.sample(64, np.random.default_rng(123))
    obj2, ing2 = dem.sample(64, np.random.default_rng(123))
    np.testing.assert_array_equal(obj, obj2)
    np.testing.assert_array_equal(ing, ing2)
    assert obj.dtype == np.int64 and ing.dtype == np.int64
    assert obj.min() >= 0 and obj.max() < 3
    assert np.all(ing == 0)
    # a float32 lam matrix works too (the catalog-facing case)
    dem32 = demand_api.Demand(lam=np.full((1, 3), np.float32(1 / 3)))
    o3, _ = dem32.sample(16, np.random.default_rng(5))
    assert o3.dtype == np.int64


# -------------------------------------------------- nightly recall, 10⁶
@pytest.mark.slow
@pytest.mark.skipif(not FULL, reason="slow: nightly/full pass only "
                    "(CI_FULL=1)")
def test_recall_one_million_keys():
    """The catalogs-≫-10⁵ regime the tentpole targets: 10⁶ keys across
    two levels, Zipf-weighted queries, default tables — recall ≥ 0.99
    and the pruned scan covers < ½ of the keys (the bench measures the
    actual speedup; this guards the quality side)."""
    rng = np.random.default_rng(0)
    n, d = 1_000_000, 16
    coords = rng.standard_normal((n, d)).astype(np.float32)
    half = n // 2
    levels = [CacheLevel(keys=jnp.asarray(coords[:half]),
                         values=jnp.asarray(np.arange(half,
                                                      dtype=np.int32)),
                         h=0.0),
              CacheLevel(keys=jnp.asarray(coords[half:]),
                         values=jnp.asarray(np.arange(half, n,
                                                      dtype=np.int32)),
                         h=0.5)]
    net = SimCacheNetwork(levels=levels, h_repo=1e9, metric="l2")
    ranks = rng.permutation(n)[:4096]
    p = 1.0 / (np.arange(1, 4097) ** 0.9)
    ids = ranks[rng.choice(4096, 16, p=p / p.sum())]
    q = jnp.asarray(coords[ids]
                    + 0.05 * rng.standard_normal((16, d)).astype(
                        np.float32))
    pruned = net.lookup(q, prune="lsh")
    exact = net._lookup_fused(q)
    assert lookup_recall(pruned, exact) >= 0.99
    assert np.all(np.asarray(pruned.cost) >= np.asarray(exact.cost))
    pol = SimHashPolicy()
    assert pol.resolve_cap(n) < n // 2
