"""Analytic hit-rate plane (core/analysis/hitrate.py): the Che
characteristic-time solver, similarity-ball enumeration (exact + LSH),
and the network fixed point — validated against scalar references and
against ``StrategyPlane`` trace replays on the instances the model
claims (single caches and multi-ingress graph scenarios; the full
family × demand grid rides benchmarks/hitrate_bench.py)."""
import numpy as np
import pytest

from repro.core import catalog as catalog_api
from repro.core import demand as demand_api
from repro.core import scenarios, topology
from repro.core.analysis import (HitRatePrediction, exact_hit_balls,
                                 predict_hitrates, similarity_balls,
                                 solve_characteristic_time, surrogate_cost)
from repro.core.routing import StrategyPlane


def _zipf_rates(n, alpha=0.8, seed=0):
    rng = np.random.default_rng(seed)
    lam = 1.0 / (rng.permutation(n) + 1.0) ** alpha
    return lam / lam.sum()


def _replay_hit_rate(net, coords, dem, strategy, threshold, n_requests,
                     seed=7, warm_frac=0.5):
    """Measured hit rate of a StrategyPlane trace replay, counted over
    the post-warmup tail only (the analytic plane predicts steady
    state, not the cold fill)."""
    pl = StrategyPlane(net, coords, strategy=strategy,
                       threshold=threshold, seed=seed)
    rng = np.random.default_rng(seed)
    warm = int(n_requests * warm_frac)
    hits = total = 0
    for start in range(0, n_requests, 2048):
        k = min(2048, n_requests - start)
        objs, ings = dem.sample(k, rng)
        dec = pl.serve(objs, ings)
        lo = max(warm - start, 0)
        if lo < k:
            hits += int(dec.hit[lo:].sum())
            total += k - lo
    return hits / total


# ===================================================================
# characteristic-time solver
# ===================================================================
def test_solver_matches_scalar_bisection():
    """The jitted vectorized solve agrees with a plain f64 scalar
    bisection of Σ (1 − e^{−λT}) = C."""
    lam = _zipf_rates(300)
    cap = 25.0
    T = solve_characteristic_time(lam, cap)

    def occ_sum(t):
        return np.sum(-np.expm1(-lam * t))

    lo, hi = 0.0, 1.0
    while occ_sum(hi) < cap:
        hi *= 2.0
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        lo, hi = (mid, hi) if occ_sum(mid) < cap else (lo, mid)
    assert T == pytest.approx(0.5 * (lo + hi), rel=1e-3)
    # the constraint itself is met tightly
    assert occ_sum(T) == pytest.approx(cap, rel=1e-3)


def test_solver_edge_capacities():
    lam = _zipf_rates(50)
    # capacity ≥ #requested objects: the cache holds everything → T = ∞
    assert np.isinf(solve_characteristic_time(lam, 50))
    assert np.isinf(solve_characteristic_time(lam, 80))
    # zero capacity → T = 0
    assert solve_characteristic_time(lam, 0) == 0.0
    # batched (J, O) form with per-cache capacities
    T = solve_characteristic_time(np.stack([lam, lam, lam]),
                                  np.array([10.0, 0.0, 50.0]))
    assert T.shape == (3,)
    assert 0.0 < T[0] < np.inf and T[1] == 0.0 and np.isinf(T[2])


def test_two_rate_defaults_to_classic_che():
    """entry_rates=None must be exactly the classic solve (μ = ν = λ):
    the two-rate occupancy reduces to 1 − e^{−λT} there."""
    lam = _zipf_rates(200, seed=3)
    assert solve_characteristic_time(lam, 20) == \
        solve_characteristic_time(lam, 20, entry_rates=lam)


def test_solver_scale_invariance():
    """Demand is per-request: scaling λ by c scales T by 1/c and leaves
    every occupancy (hence every hit rate) unchanged."""
    lam = _zipf_rates(150, seed=5)
    T1 = solve_characteristic_time(lam, 12)
    T2 = solve_characteristic_time(100.0 * lam, 12)
    assert T2 == pytest.approx(T1 / 100.0, rel=1e-3)
    np.testing.assert_allclose(-np.expm1(-lam * T1),
                               -np.expm1(-100.0 * lam * T2), atol=1e-4)


# ===================================================================
# similarity balls
# ===================================================================
def test_exact_hit_balls_are_identity():
    b = exact_hit_balls(7)
    assert b.max_size == 1 and b.theta == 0.0
    np.testing.assert_array_equal(b.idx[:, 0], np.arange(7))
    assert np.all(b.q == 1.0) and np.all(b.dist == 0.0)
    # θ ≤ 0 in the enumerator degenerates to the same structure
    coords = np.random.default_rng(0).normal(size=(7, 3)).astype(np.float32)
    for theta in (0.0, -1.0, None):
        d = similarity_balls(coords, theta)
        np.testing.assert_array_equal(d.idx, b.idx)


def test_similarity_balls_exact_against_bruteforce():
    """Exact enumeration == the O(O²) f64 brute force: membership,
    ascending distance order, self first, q weights for both modes."""
    cat = catalog_api.embedding_catalog(n=250, dim=4, seed=2)
    coords = np.asarray(cat.coords, np.float64)
    d_full = np.sqrt(((coords[:, None, :] - coords[None, :, :]) ** 2)
                     .sum(-1))
    theta = float(np.quantile(d_full[d_full > 0], 0.02))
    balls = similarity_balls(cat.coords, theta, mode="exact")
    assert balls.mean_size > 1.0          # θ wide enough to be non-trivial
    for o in range(250):
        members = balls.idx[o][balls.idx[o] < 250]
        assert members[0] == o and balls.dist[o, 0] == 0.0
        np.testing.assert_array_equal(
            np.sort(members), np.nonzero(d_full[o] <= theta)[0])
        dd = balls.dist[o][:len(members)]
        assert np.all(np.diff(dd) >= 0)   # sorted ascending by C_a
        np.testing.assert_allclose(dd, np.sort(d_full[o][members]),
                                    rtol=1e-5, atol=1e-5)
    # hard weights: exactly the membership indicator
    assert np.all(balls.q[balls.idx < 250] == 1.0)
    assert np.all(balls.q[balls.idx >= 250] == 0.0)
    # rnd weights: clip(1 − d/θ, 0, 1) on the same members
    rnd = similarity_balls(cat.coords, theta, mode="exact", q_mode="rnd")
    mem = rnd.idx < 250
    np.testing.assert_allclose(
        rnd.q[mem], np.clip(1.0 - rnd.dist[mem] / theta, 0.0, 1.0),
        rtol=1e-6, atol=1e-6)


def test_similarity_balls_lsh_subset_of_exact():
    """The LSH path returns a subset of the exact balls (recall < 1 is
    allowed, false members are not), always keeps self, and every kept
    member passes the exact θ filter."""
    cat = catalog_api.embedding_catalog(n=600, dim=6, seed=4)
    exact = similarity_balls(cat.coords, theta=60.0, mode="exact")
    lsh = similarity_balls(cat.coords, theta=60.0, mode="lsh", seed=1)
    kept = dropped = 0
    for o in range(600):
        em = set(exact.idx[o][exact.idx[o] < 600].tolist())
        lm = set(lsh.idx[o][lsh.idx[o] < 600].tolist())
        assert o in lm
        assert lm <= em, f"LSH ball {o} contains non-members"
        kept += len(lm)
        dropped += len(em - lm)
    assert np.all(lsh.dist[lsh.idx < 600] <= 60.0 + 1e-4)
    # multi-probe SimHash keeps the bulk of the near neighbors
    assert kept / max(kept + dropped, 1) > 0.5


def test_similarity_balls_max_ball_truncates_farthest():
    cat = catalog_api.embedding_catalog(n=200, dim=4, seed=6)
    full = similarity_balls(cat.coords, theta=120.0, mode="exact")
    assert full.max_size > 3
    cut = similarity_balls(cat.coords, theta=120.0, mode="exact",
                           max_ball=3)
    assert cut.max_size == 3 and cut.truncated > 0
    # the kept members are each ball's nearest 3 (self included)
    np.testing.assert_array_equal(cut.idx, full.idx[:, :3])


# ===================================================================
# single cache: prediction vs trace replay
# ===================================================================
def test_classic_che_matches_lru_replay():
    """Exact-hit (θ=0) prediction vs a simulated classic LRU (sim-lru
    with threshold 0 inserts on miss + refreshes on exact hit): the
    textbook Che regime, within 3pp."""
    cat = catalog_api.embedding_catalog(n=300, dim=8, seed=1)
    net = topology.single_cache(30, 150.0)
    dem = demand_api.zipf(cat, alpha=0.9, seed=2)
    pred = predict_hitrates(net, dem.lam, exact_hit_balls(300))
    measured = _replay_hit_rate(net, cat.coords, dem, "sim-lru", 0.0,
                                n_requests=40_000)
    assert abs(pred.hit_rate - measured) < 0.03
    # occupancies respect the capacity constraint
    assert pred.occupancy.sum() == pytest.approx(30.0, rel=1e-2)


@pytest.mark.parametrize("strategy,q_mode", [("sim-lru", "hard"),
                                             ("rnd-lru", "rnd")])
def test_similarity_prediction_matches_replay(strategy, q_mode):
    """The similarity generalization on one cache: SIM-LRU (hard balls)
    and RND-LRU (clipped-linear q) within 5pp of a trace replay."""
    cat = catalog_api.embedding_catalog(n=400, dim=8, seed=0)
    coords = np.asarray(cat.coords, np.float64)
    d = np.sqrt(((coords[:1000, None, :] - coords[None, :, :]) ** 2)
                .sum(-1))
    theta = float(np.quantile(d[d > 0], 0.02))
    net = topology.single_cache(30, 1e9)   # slack never binds: θ does
    dem = demand_api.zipf(cat, alpha=0.9, seed=2)
    balls = similarity_balls(cat.coords, theta, q_mode=q_mode,
                             mode="exact")
    assert balls.mean_size > 2.0           # non-trivial similarity regime
    pred = predict_hitrates(net, dem.lam, balls)
    measured = _replay_hit_rate(net, cat.coords, dem, strategy, theta,
                                n_requests=40_000)
    assert abs(pred.hit_rate - measured) < 0.05, \
        f"{strategy}: predicted {pred.hit_rate:.3f} vs " \
        f"measured {measured:.3f} (ball {balls.mean_size:.1f})"


def test_multi_ingress_graph_prediction_matches_replay():
    """Network composition on a PR 8 general-graph scenario (the
    validity regime: multi-ingress decorrelates the shared caches):
    exact-hit prediction vs replay within 5pp."""
    sc = scenarios.scenario("scale_free", cache_budget=32,
                            placement="degree", n_ingress=4, seed=3)
    cat = catalog_api.embedding_catalog(n=400, dim=8, seed=1)
    dem = demand_api.zipf(cat, alpha=1.0, n_ingress=4, seed=5)
    pred = predict_hitrates(sc.net, dem.lam, exact_hit_balls(400))
    measured = _replay_hit_rate(sc.net, cat.coords, dem, "sim-lru", 0.0,
                                n_requests=40_000)
    assert abs(pred.hit_rate - measured) < 0.05, \
        f"predicted {pred.hit_rate:.3f} vs measured {measured:.3f}"


# ===================================================================
# prediction structure + monotonicity
# ===================================================================
def _single_cache_pred(cap=20, theta=None, q_mode="hard", n=300):
    cat = catalog_api.embedding_catalog(n=n, dim=6, seed=3)
    net = topology.single_cache(cap, 1e9)
    dem = demand_api.zipf(cat, alpha=0.9, seed=4)
    balls = exact_hit_balls(n) if theta is None else \
        similarity_balls(cat.coords, theta, q_mode=q_mode, mode="exact")
    return predict_hitrates(net, dem.lam, balls)


def test_prediction_conservation_and_shapes():
    sc = scenarios.scenario("isp", cache_budget=24, placement="degree",
                            n_ingress=3, seed=1)
    cat = catalog_api.embedding_catalog(n=200, dim=6, seed=2)
    dem = demand_api.zipf(cat, alpha=0.8, n_ingress=3, seed=1)
    pred = predict_hitrates(sc.net, dem.lam, exact_hit_balls(200))
    assert isinstance(pred, HitRatePrediction)
    J = sc.net.n_caches
    assert pred.T.shape == (J,) and pred.occupancy.shape == (J, 200)
    assert pred.hit_prob.shape == (3, 200)
    assert pred.serve_prob.shape == (3, J, 200)
    # probabilities and the λ-weighted aggregates are consistent
    assert np.all((pred.occupancy >= 0) & (pred.occupancy <= 1))
    assert np.all((pred.hit_prob >= -1e-12) & (pred.hit_prob <= 1 + 1e-12))
    assert pred.hit_rate == pytest.approx(
        float((dem.lam * pred.hit_prob).sum() / dem.lam.sum()), abs=1e-9)
    assert pred.cache_hit_rate.sum() == pytest.approx(pred.hit_rate,
                                                      abs=1e-9)
    assert pred.hit_rate + pred.miss_rate == pytest.approx(1.0)
    # per-cache expected occupancy never exceeds capacity
    assert np.all(pred.occupancy.sum(axis=1)
                  <= sc.net.capacities + 1e-2 * sc.net.capacities.max())
    assert 0.0 < pred.mean_cost <= float(sc.net.h_repo.max()) + 1e-9


def test_hit_rate_monotone_in_capacity_and_theta():
    by_cap = [_single_cache_pred(cap=c).hit_rate for c in (5, 20, 80)]
    assert by_cap[0] < by_cap[1] < by_cap[2]
    cat = catalog_api.embedding_catalog(n=300, dim=6, seed=3)
    coords = np.asarray(cat.coords, np.float64)
    d = np.sqrt(((coords[:, None, :] - coords[None, :, :]) ** 2).sum(-1))
    t1, t2 = (float(np.quantile(d[d > 0], q)) for q in (0.01, 0.05))
    by_theta = [_single_cache_pred(theta=t).hit_rate
                for t in (None, t1, t2)]
    assert by_theta[0] <= by_theta[1] + 1e-9
    assert by_theta[1] <= by_theta[2] + 1e-9
    assert by_theta[2] > by_theta[0]      # similarity strictly helps


def test_balls_object_count_mismatch_raises():
    net = topology.single_cache(5, 10.0)
    with pytest.raises(ValueError, match="enumerated over"):
        predict_hitrates(net, np.ones((1, 20)) / 20.0, exact_hit_balls(10))


# ===================================================================
# engine surrogate
# ===================================================================
def test_surrogate_cost_tracks_drift_and_capacity():
    """The refresh gate's contract: identical demand → identical cost,
    drifted demand → a different cost, more capacity → lower cost."""
    cat = catalog_api.embedding_catalog(n=250, dim=6, seed=0)
    net = topology.chain(3, [8, 8, 8], [1.0, 2.0, 4.0], 100.0)
    lam_a = demand_api.zipf(cat, alpha=1.0, seed=1).lam
    lam_b = demand_api.zipf(cat, alpha=1.0, seed=9).lam   # re-permuted
    c_a = surrogate_cost(net, lam_a)
    assert c_a == surrogate_cost(net, lam_a.copy())       # deterministic
    assert abs(c_a - surrogate_cost(net, lam_b)) > 0.0
    big = topology.chain(3, [32, 32, 32], [1.0, 2.0, 4.0], 100.0)
    assert surrogate_cost(big, lam_a) < c_a
    # cost is bounded by the repo cost (it is a per-request mean)
    assert 0.0 < c_a < 100.0
