"""Differential device-vs-host NETDUEL suite (§5 online control plane).

``device_netduel`` (one jitted lax.scan over the request window,
core/placement/netduel.py) must reproduce the host policy **bit for
bit** on materialized-C_a instances: identical promotion sequences
(time, slot, object, and the f32 savings that won the duel), identical
final slots/virtual/deadline state, and the identical served-cost sum
(sequential f64 accumulation of the same f32 per-request costs). The
host implementation does all duel arithmetic in f32 with the same
elementary ops in the same order as the scan, and draws all randomness
up front, which is what makes this an exact contract rather than a
statistical one.

Instances mirror tests/test_device_placement.py (jittered Gaussian
grid, Zipf embedding, multi-ingress tree). The mesh test builds over
every visible device: 1-way in the default tier-1 pass, 8-way in
scripts/ci.sh's second pass (the duel's table refresh then runs through
``objective.sharded_best_two``). The 10⁵-object window is CI_FULL-gated
(slow marker) — at that size no host C_a matrix can exist, so it is a
device-only scale proof.
"""
import os

import jax
import numpy as np
import pytest

from repro.core import catalog, demand, topology
from repro.core.objective import DeviceInstance, Instance, random_slots
from repro.core.placement import DuelPlane, device_netduel, netduel
from repro.launch.mesh import make_lookup_mesh


def gauss_instance(L=8, k=(3, 4), sigma=2.0, seed=0):
    cat = catalog.grid(L=L)
    net = topology.tandem(k_leaf=k[0], k_parent=k[1], h=2.0, h_repo=10.0)
    dem0 = demand.gaussian_grid(cat, sigma=sigma)
    rng = np.random.default_rng(seed)
    lam = dem0.lam * (1.0 + 1e-3 * rng.random(dem0.lam.shape))
    return Instance(net=net, cat=cat,
                    dem=demand.Demand(lam=lam / lam.sum()))


def zipf_instance(n=150, dim=6, k=(6, 9), seed=1):
    cat = catalog.embedding_catalog(n=n, dim=dim, seed=seed)
    net = topology.tandem(k_leaf=k[0], k_parent=k[1], h=50.0, h_repo=400.0)
    return Instance(net=net, cat=cat,
                    dem=demand.zipf(cat, alpha=0.8, seed=seed + 1))


def tree_instance(seed=3):
    cat = catalog.embedding_catalog(n=150, dim=4, seed=seed)
    net = topology.equi_depth_tree(2, 1, [4, 6], [0.0, 30.0], 300.0)
    dem = demand.zipf(cat, alpha=0.7, n_ingress=net.n_ingress, seed=seed)
    return Instance(net=net, cat=cat, dem=dem)


ALL_INSTANCES = [("gauss", gauss_instance), ("zipf", zipf_instance),
                 ("tree", tree_instance)]


def assert_duel_equal(st_h, st_d, served=True):
    """Host DuelState == device DeviceDuelState: tolerance-free ints and
    bitwise f32 duel state; served cost to f64-roundoff (both sides sum
    the same f32 sequence in f64)."""
    np.testing.assert_array_equal(st_h.sw.slots, st_d.slots)
    assert st_h.n_promotions == st_d.n_promotions
    assert st_h.promotions == st_d.promotions
    np.testing.assert_array_equal(st_h.virt, st_d.virt)
    np.testing.assert_array_equal(st_h.deadline, st_d.deadline)
    np.testing.assert_array_equal(st_h.real_sav, st_d.real_sav)
    np.testing.assert_array_equal(st_h.virt_sav, st_d.virt_sav)
    if served:
        assert st_h.n_served == st_d.n_served
        np.testing.assert_allclose(st_d.served_cost, st_h.served_cost,
                                   rtol=1e-12)


# ------------------------------------------------------------ differential
@pytest.mark.parametrize("name,make", ALL_INSTANCES)
def test_device_netduel_bit_identical(name, make):
    inst = make()
    dinst = DeviceInstance.from_instance(inst)        # materialized C_a
    kw = dict(n_iters=6000, seed=3, window=400, arm_prob=0.35)
    st_h = netduel(inst, **kw)
    st_d = device_netduel(dinst, record_events=True, **kw)
    assert st_h.n_promotions > 0                      # a non-trivial run
    assert_duel_equal(st_h, st_d)


def test_device_netduel_fixed_stream_and_lambda_unawareness():
    """With an explicit (fixed virtual-arrival) request stream the device
    scan replays the host trajectory exactly, and — like the host — it
    never reads λ: a different demand over the same catalog/topology
    yields the same promotions given the same stream and draws."""
    inst_a = zipf_instance(seed=5)
    inst_b = Instance(net=inst_a.net, cat=inst_a.cat,
                      dem=demand.uniform(inst_a.cat))
    rng = np.random.default_rng(9)
    requests = inst_a.dem.sample(5000, rng)
    slots0 = random_slots(inst_a, np.random.default_rng(1))
    kw = dict(seed=7, window=300, arm_prob=0.4, slots0=slots0,
              requests=requests)
    st_h = netduel(inst_a, **kw)
    st_d = device_netduel(DeviceInstance.from_instance(inst_a),
                          record_events=True, **kw)
    st_u = device_netduel(DeviceInstance.from_instance(inst_b),
                          record_events=True, **kw)
    assert_duel_equal(st_h, st_d)
    np.testing.assert_array_equal(st_d.slots, st_u.slots)
    assert st_d.promotions == st_u.promotions


def test_device_netduel_cost_trace_matches():
    inst = zipf_instance()
    kw = dict(n_iters=3000, seed=2, window=250, arm_prob=0.4,
              record_every=500)
    st_h = netduel(inst, **kw)
    st_d = device_netduel(DeviceInstance.from_instance(inst), **kw)
    assert len(st_h.sw.cost_trace) == len(st_d.cost_trace)
    np.testing.assert_allclose(st_d.cost_trace, st_h.sw.cost_trace,
                               rtol=1e-5)


# --------------------------------------------------------- duel mechanics
def _line_instance():
    """1-D l1 catalog [x0=0, x1=3, q=4] over a single 1-slot cache with
    h_repo=6: a stream [x1, q, q, ...] arms virtual x1 against real x0
    and accumulates *exactly* rs=2 and vs=3 per q-request (small-int
    f32 arithmetic — no rounding anywhere)."""
    coords = np.array([[0.0], [3.0], [4.0]], np.float32)
    cat = catalog.Catalog(coords=coords, metric="l1")
    net = topology.single_cache(k=1, h_repo=6.0)
    lam = np.full((1, 3), 1.0 / 3)
    return Instance(net=net, cat=cat, dem=demand.Demand(lam=lam))


@pytest.mark.parametrize("delta,promotes", [
    (0.5, False),        # vs == (1+δ)·rs exactly → strict > fails
    (0.4999, True),      # just under the boundary → promote
    (0.5001, False),     # just over → discard
])
def test_delta_margin_boundary_tie(delta, promotes):
    """δ-margin boundary: at settle the duel holds vs = 3w, rs = 2w
    exactly (integers in f32), so δ = 0.5 puts the comparison *exactly*
    on the boundary — the strict-> contract must discard on both paths,
    and both paths must flip together just off the boundary."""
    inst = _line_instance()
    w = 16
    # one full duel exactly: arm x1 at t=0, settle at t=w (the stream
    # ends there, before the slot's re-armed successor can win)
    objs = np.array([1] + [2] * w)
    ings = np.zeros_like(objs)
    kw = dict(seed=0, window=w, delta=delta, arm_prob=1.0,
              slots0=np.array([0]), requests=(objs, ings))
    st_h = netduel(inst, **kw)
    st_d = device_netduel(DeviceInstance.from_instance(inst),
                          record_events=True, **kw)
    assert_duel_equal(st_h, st_d)
    assert (st_h.n_promotions > 0) == promotes
    if promotes:
        t, y, obj, rs, vs = st_h.promotions[0]
        assert (t, y, obj) == (w, 0, 1)
        assert vs == 3.0 * w and rs == 2.0 * w


def test_deadline_rearm_cycles():
    """Settled slots re-arm (possibly in the same step) with fresh
    deadlines and zeroed savings; several duel generations per slot stay
    in lockstep between host and device."""
    inst = gauss_instance()
    kw = dict(n_iters=3000, seed=4, window=60, arm_prob=1.0)
    st_h = netduel(inst, **kw)
    st_d = device_netduel(DeviceInstance.from_instance(inst),
                          record_events=True, **kw)
    assert_duel_equal(st_h, st_d)
    # every slot must have been re-armed well past the first window
    assert np.all(st_h.deadline > 3000 - 2 * 60)
    assert st_h.n_promotions > 1


def test_never_promoted_window():
    """window > n_iters: no duel ever settles — the cache contents may
    only change at a promotion, so slots stay at slots0 on both paths
    (virtual objects are metadata only)."""
    inst = zipf_instance()
    slots0 = random_slots(inst, np.random.default_rng(8))
    kw = dict(n_iters=500, seed=1, window=10_000, arm_prob=1.0,
              slots0=slots0)
    st_h = netduel(inst, **kw)
    st_d = device_netduel(DeviceInstance.from_instance(inst),
                          record_events=True, **kw)
    assert_duel_equal(st_h, st_d)
    assert st_h.n_promotions == 0
    np.testing.assert_array_equal(st_d.slots, slots0)
    assert np.any(st_d.virt >= 0)                     # armed, just unsettled


# ----------------------------------------------------- incremental re-arm
@pytest.mark.parametrize("name,make", ALL_INSTANCES)
def test_device_netduel_incremental_bit_identical(name, make):
    """The delta best-two re-arm after a promotion (``incremental=True``,
    the default) must be bitwise the full-rebuild path: same slots,
    same promotion events (time/slot/object/savings), same per-step
    served costs. The dirty-row recompute and its PROMOTE_CAP overflow
    fallback are pure batching, never a semantics change."""
    inst = make()
    dinst = DeviceInstance.from_instance(inst)
    kw = dict(n_iters=6000, seed=3, window=400, arm_prob=0.35,
              record_events=True)
    st_i = device_netduel(dinst, incremental=True, **kw)
    st_f = device_netduel(dinst, incremental=False, **kw)
    assert st_i.n_promotions > 0
    np.testing.assert_array_equal(st_i.slots, st_f.slots)
    assert st_i.n_promotions == st_f.n_promotions
    assert st_i.promotions == st_f.promotions
    np.testing.assert_array_equal(st_i.virt, st_f.virt)
    np.testing.assert_array_equal(st_i.deadline, st_f.deadline)
    np.testing.assert_array_equal(st_i.real_sav, st_f.real_sav)
    np.testing.assert_array_equal(st_i.virt_sav, st_f.virt_sav)
    np.testing.assert_array_equal(st_i.b1_trace, st_f.b1_trace)
    assert st_i.served_cost == st_f.served_cost


def test_duelplane_incremental_bit_identical():
    """DuelPlane's promotion re-arm goes through the same delta path —
    observing identical batch streams (including a bucketed, padded
    batch) with incremental on/off must hold slots, promotion counts and
    served cost in lockstep across every observe()."""
    inst = zipf_instance(seed=11)
    dinst = DeviceInstance.from_instance(inst)
    slots0 = random_slots(inst, np.random.default_rng(2))
    planes = [DuelPlane(dinst, slots0, window=120, arm_prob=0.6, seed=5,
                        incremental=inc) for inc in (True, False)]
    rng = np.random.default_rng(7)
    for b in range(6):
        objs, ings = inst.dem.sample(96, rng)
        n_valid = 96 if b % 2 == 0 else 70        # alternate bucketed
        for p in planes:
            p.observe(objs, ings, n_valid=n_valid)
        pi, pf = planes
        np.testing.assert_array_equal(pi.slots_np, pf.slots_np)
        assert pi.n_promotions == pf.n_promotions
        assert pi.served_cost == pf.served_cost
    assert planes[0].n_promotions > 0             # a non-trivial stream


# ------------------------------------------------------------------- mesh
def test_device_netduel_sharded_mesh():
    """DeviceInstance carrying the data-plane mesh axes routes the duel's
    table refreshes through ``sharded_best_two`` — still bit-identical
    to the host (1-way in the default pass, a real 8-way request-axis
    sharding in scripts/ci.sh pass 2)."""
    inst = tree_instance()
    mesh = make_lookup_mesh(jax.device_count())
    d_mesh = DeviceInstance.from_instance(inst, mesh=mesh, axes=("data",))
    assert d_mesh.n_shards == jax.device_count()
    kw = dict(n_iters=4000, seed=2, window=300, arm_prob=0.35)
    st_h = netduel(inst, **kw)
    st_m = device_netduel(d_mesh, record_events=True, **kw)
    assert_duel_equal(st_h, st_m)


# ------------------------------------------------------------------ scale
@pytest.mark.slow
def test_netduel_large_window_smoke():
    """CI_FULL-gated 10⁵-object NETDUEL window: at this size the dense
    C_a (40 GB) cannot exist, so the duel runs with streamed shape-stable
    pricing — a device-only scale proof (one scan launch for the whole
    window) with sanity invariants instead of a host differential."""
    if not os.environ.get("CI_FULL"):
        pytest.skip("10⁵-object NETDUEL window runs in the CI_FULL pass")
    n = 100_000
    cat = catalog.embedding_catalog(n=n, dim=16, seed=0)
    net = topology.tandem(k_leaf=32, k_parent=32, h=50.0, h_repo=500.0)
    inst = Instance(net=net, cat=cat, dem=demand.zipf(cat, alpha=0.9,
                                                      seed=1))
    dinst = DeviceInstance.from_instance(inst, materialize_ca=False)
    st = device_netduel(dinst, n_iters=2000, seed=0, window=400,
                        arm_prob=0.3)
    assert st.n_served == 2000
    assert np.isfinite(st.served_cost)
    assert st.served_cost / st.n_served <= 500.0 + 1e-6
    assert np.all((st.slots >= 0) & (st.slots < n))
    # the duel must actually have turned over cache contents at 10⁵
    assert st.n_promotions > 0
