"""Tests of the continuous-case machinery (paper §4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.placement import continuous as C


def test_zeta_matches_definition():
    for g in (0.5, 1.0, 2.0):
        assert C.zeta(g) == pytest.approx(2 ** ((2 - g) / 2) / (g + 2))


def test_eq7_equals_direct_tessellation():
    """Uniform λ, k slots over M unit regions → eq (7) equals summing the
    per-cell cost c(r) of eq (5) over the regular square tessellation."""
    for g in (0.5, 1.0, 2.0):
        M, k = 25, 100.0
        lams = np.ones(M)
        per_region = k / M
        r = np.sqrt(1.0 / (2.0 * per_region))
        direct = M * per_region * C.cell_cost(r, 1.0, g)
        assert C.single_cache_cost(lams, k, g) == pytest.approx(direct)


def test_single_cache_allocation_proportionality():
    """k_i ∝ λ_i^{2/(γ+2)} (the Lagrange condition of §4.1)."""
    rng = np.random.default_rng(1)
    lams = rng.gamma(2.0, 1.0, 10)
    g = 1.3
    k = C.single_cache_allocation(lams, 50.0, g)
    ratio = k / lams ** (2.0 / (g + 2.0))
    assert np.allclose(ratio, ratio[0])
    assert k.sum() == pytest.approx(50.0)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000), gamma=st.sampled_from([0.5, 1.0, 2.0]))
def test_chain_md_matches_threshold_structure(seed, gamma):
    """Mirror descent on (11) and the Prop 4.2 threshold solver agree
    (both reach the global optimum of the convex program)."""
    rng = np.random.default_rng(seed)
    lams = rng.gamma(2.0, 1.0, 40)
    spec = C.ChainSpec(ks=(25.0, 25.0), hs=(0.0, 1.5), h_repo=6.0,
                       gamma=gamma)
    _, c_md = C.solve_chain(lams, spec, iters=5000)
    _, c_th, _ = C.solve_chain_thresholds(lams, spec)
    assert c_md == pytest.approx(c_th, rel=2e-2)
    # threshold solution can only be better or equal (it is the exact
    # structure of the optimum); MD evaluates in f32, hence the slack
    assert c_th <= c_md + 1e-5 * max(1.0, c_th)


def test_prop42_threshold_monotonicity():
    """The optimal w from mirror descent respects Prop 4.2/4.3: the
    minimum λ served (mostly) by cache j dominates the maximum λ served
    by cache j+1."""
    rng = np.random.default_rng(7)
    lams = np.sort(rng.gamma(2.0, 1.0, 60))[::-1].copy()
    spec = C.ChainSpec(ks=(30.0, 30.0), hs=(0.0, 2.0), h_repo=8.0, gamma=1.0)
    w, _ = C.solve_chain(lams, spec, iters=8000)
    owner = np.argmax(w, axis=1)          # dominant server per region
    # regions are sorted by decreasing λ → owner must be nondecreasing
    # (cache 1 first, then cache 2, then repo), barring boundary regions
    changes = np.diff(owner)
    assert np.all(changes >= -0) or np.sum(changes < 0) <= 2


def test_prop44_tree_equals_scaled_chain():
    rng = np.random.default_rng(3)
    lams = rng.gamma(2.0, 1.0, 30)
    spec = C.ChainSpec(ks=(20.0, 40.0), hs=(0.0, 1.0), h_repo=5.0, gamma=1.0)
    betas = np.array([0.5, 1.0, 2.0, 0.25])
    _, c_chain, _ = C.solve_chain_thresholds(lams, spec)
    assert C.tree_cost(lams, betas, spec) == pytest.approx(
        betas.sum() * c_chain)


def test_homogeneity_in_lambda():
    """The optimal chain cost is degree-1 homogeneous in λ (the property
    behind Prop 4.4's replication argument)."""
    rng = np.random.default_rng(11)
    lams = rng.gamma(2.0, 1.0, 25)
    spec = C.ChainSpec(ks=(15.0, 30.0), hs=(0.0, 1.0), h_repo=4.0, gamma=1.0)
    _, c1, _ = C.solve_chain_thresholds(lams, spec)
    _, c3, _ = C.solve_chain_thresholds(3.0 * lams, spec)
    assert c3 == pytest.approx(3.0 * c1, rel=1e-6)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000))
def test_eq15_gradient_matches_autodiff(seed):
    rng = np.random.default_rng(seed)
    M = 15
    lams = rng.gamma(2.0, 1.0, M)
    w1 = rng.uniform(0.05, 0.95, M)
    args = (10.0, 12.0, 0.4, 0.6, 1.0)
    g_auto = jax.grad(C.tandem_both_cost)(
        jnp.asarray(w1), jnp.asarray(lams), *args)
    g_hand = C.tandem_both_grad(w1, lams, *args)
    # f32 autodiff vs f64 hand formula: tolerance at f32 level
    np.testing.assert_allclose(np.asarray(g_auto), g_hand, rtol=3e-3,
                               atol=3e-4)


def test_tandem_both_beta0_recovers_leaf_only_regime():
    """β=0 (no parent arrivals) must reduce (14) to the leaf-only tandem
    of (11) — costs agree at the respective optima."""
    rng = np.random.default_rng(5)
    lams = rng.gamma(2.0, 1.0, 30)
    k1 = k2 = 20.0
    h = 0.8
    w1, c14 = C.solve_tandem_both(lams, k1, k2, h, beta=0.0, gamma=1.0,
                                  iters=8000, lr=0.1)
    # (11) with caches [k1,k2], hs [0,h], and an unreachable repository
    spec = C.ChainSpec(ks=(k1, k2), hs=(0.0, h), h_repo=1e9, gamma=1.0)
    _, c11, _ = C.solve_chain_thresholds(lams, spec)
    assert c14 == pytest.approx(c11, rel=2e-2)


def test_shifted_tessellation_closed_form_vs_numeric():
    for h in (0.0, 0.01, 0.03, 0.08):
        cf = C.shifted_tessellation_cost(k=100, h=h, area=1.0, lam=1.0)
        nm = C.shifted_tessellation_cost_numeric(k=100, h=h, area=1.0,
                                                 lam=1.0, gamma=1.0)
        assert cf == pytest.approx(nm, rel=2e-3)


def test_shifted_tessellation_no_forwarding_beyond_r():
    """h > r ⇒ z = 0 ⇒ the parent provides no help to leaf arrivals
    (the paper: 'if h > r requests are not forwarded')."""
    k, area = 64, 1.0
    r = np.sqrt(area / (2 * k))
    base = C.shifted_tessellation_cost(k, h=r * 1.01, area=area, lam=1.0)
    plain = 2.0 * k * C.cell_cost(r, 1.0, 1.0)
    assert base == pytest.approx(plain)


def test_shifted_beats_aligned_tessellation():
    """Fig 2's point: shifting the parent tessellation strictly reduces
    the cost whenever h < r (corner regions get cheaper service)."""
    k, area, h = 100, 1.0, 0.02
    shifted = C.shifted_tessellation_cost(k, h, area, 1.0)
    r = np.sqrt(area / (2 * k))
    aligned = 2.0 * k * C.cell_cost(r, 1.0, 1.0)   # parent mirrors leaf ⇒ no help
    assert shifted < aligned
