"""Tests of the continuous-case machinery (paper §4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.placement import continuous as C


def test_zeta_matches_definition():
    for g in (0.5, 1.0, 2.0):
        assert C.zeta(g) == pytest.approx(2 ** ((2 - g) / 2) / (g + 2))


def test_eq7_equals_direct_tessellation():
    """Uniform λ, k slots over M unit regions → eq (7) equals summing the
    per-cell cost c(r) of eq (5) over the regular square tessellation."""
    for g in (0.5, 1.0, 2.0):
        M, k = 25, 100.0
        lams = np.ones(M)
        per_region = k / M
        r = np.sqrt(1.0 / (2.0 * per_region))
        direct = M * per_region * C.cell_cost(r, 1.0, g)
        assert C.single_cache_cost(lams, k, g) == pytest.approx(direct)


def test_single_cache_allocation_proportionality():
    """k_i ∝ λ_i^{2/(γ+2)} (the Lagrange condition of §4.1)."""
    rng = np.random.default_rng(1)
    lams = rng.gamma(2.0, 1.0, 10)
    g = 1.3
    k = C.single_cache_allocation(lams, 50.0, g)
    ratio = k / lams ** (2.0 / (g + 2.0))
    assert np.allclose(ratio, ratio[0])
    assert k.sum() == pytest.approx(50.0)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000), gamma=st.sampled_from([0.5, 1.0, 2.0]))
def test_chain_md_matches_threshold_structure(seed, gamma):
    """Mirror descent on (11) and the Prop 4.2 threshold solver agree
    (both reach the global optimum of the convex program)."""
    rng = np.random.default_rng(seed)
    lams = rng.gamma(2.0, 1.0, 40)
    spec = C.ChainSpec(ks=(25.0, 25.0), hs=(0.0, 1.5), h_repo=6.0,
                       gamma=gamma)
    _, c_md = C.solve_chain(lams, spec, iters=5000)
    _, c_th, _ = C.solve_chain_thresholds(lams, spec)
    assert c_md == pytest.approx(c_th, rel=2e-2)
    # threshold solution can only be better or equal (it is the exact
    # structure of the optimum); MD evaluates in f32, hence the slack
    assert c_th <= c_md + 1e-5 * max(1.0, c_th)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), alpha=st.sampled_from([0.6, 1.0, 1.3]),
       gamma=st.sampled_from([0.5, 1.0, 2.0]))
def test_chain_md_matches_thresholds_on_zipf(seed, alpha, gamma):
    """Random Zipf instances (the warm-start pipeline's demand family):
    mirror descent on (11) and the Prop 4.2 threshold solver find the
    same optimum — the structural solver is not specialized to smooth
    λ."""
    rng = np.random.default_rng(seed)
    M = int(rng.integers(30, 120))
    lams = 1.0 / (np.arange(1, M + 1) ** alpha)
    rng.shuffle(lams)
    ks = tuple(float(k) for k in rng.integers(5, M, 2))
    spec = C.ChainSpec(ks=ks, hs=(0.0, float(rng.uniform(0.2, 3.0))),
                       h_repo=float(rng.uniform(4.0, 20.0)), gamma=gamma)
    _, c_md = C.solve_chain(lams, spec, iters=6000)
    _, c_th, _ = C.solve_chain_thresholds(lams, spec)
    assert c_md == pytest.approx(c_th, rel=3e-2)
    assert c_th <= c_md + 1e-5 * max(1.0, c_th)


def test_solve_chain_bit_deterministic():
    """Fixed iters/lr ⇒ bit-reproducible across calls AND across a jit
    cache flush (fresh compile) — the property that keeps warm-started
    background refreshes replayable by the trace-replay goldens."""
    import jax
    rng = np.random.default_rng(2)
    lams = rng.gamma(2.0, 1.0, 50)
    spec = C.ChainSpec(ks=(20.0, 35.0), hs=(0.0, 1.2), h_repo=7.0,
                       gamma=1.0)
    w1, c1 = C.solve_chain(lams, spec, iters=800)
    w2, c2 = C.solve_chain(lams, spec, iters=800)
    np.testing.assert_array_equal(w1, w2)
    assert c1 == c2
    jax.clear_caches()                    # force a recompile
    w3, c3 = C.solve_chain(lams, spec, iters=800)
    np.testing.assert_array_equal(w1, w3)
    assert c1 == c3
    # thresholds path is pure NumPy — same pin, trivially
    s1 = C.solve_chain_thresholds(lams, spec)
    s2 = C.solve_chain_thresholds(lams, spec)
    np.testing.assert_array_equal(s1[0], s2[0])
    assert s1[1] == s2[1]


def test_prop42_threshold_monotonicity():
    """The optimal w from mirror descent respects Prop 4.2/4.3: the
    minimum λ served (mostly) by cache j dominates the maximum λ served
    by cache j+1."""
    rng = np.random.default_rng(7)
    lams = np.sort(rng.gamma(2.0, 1.0, 60))[::-1].copy()
    spec = C.ChainSpec(ks=(30.0, 30.0), hs=(0.0, 2.0), h_repo=8.0, gamma=1.0)
    w, _ = C.solve_chain(lams, spec, iters=8000)
    owner = np.argmax(w, axis=1)          # dominant server per region
    # regions are sorted by decreasing λ → owner must be nondecreasing
    # (cache 1 first, then cache 2, then repo), barring boundary regions
    changes = np.diff(owner)
    assert np.all(changes >= -0) or np.sum(changes < 0) <= 2


def test_prop44_tree_equals_scaled_chain():
    rng = np.random.default_rng(3)
    lams = rng.gamma(2.0, 1.0, 30)
    spec = C.ChainSpec(ks=(20.0, 40.0), hs=(0.0, 1.0), h_repo=5.0, gamma=1.0)
    betas = np.array([0.5, 1.0, 2.0, 0.25])
    _, c_chain, _ = C.solve_chain_thresholds(lams, spec)
    assert C.tree_cost(lams, betas, spec) == pytest.approx(
        betas.sum() * c_chain)


def test_homogeneity_in_lambda():
    """The optimal chain cost is degree-1 homogeneous in λ (the property
    behind Prop 4.4's replication argument)."""
    rng = np.random.default_rng(11)
    lams = rng.gamma(2.0, 1.0, 25)
    spec = C.ChainSpec(ks=(15.0, 30.0), hs=(0.0, 1.0), h_repo=4.0, gamma=1.0)
    _, c1, _ = C.solve_chain_thresholds(lams, spec)
    _, c3, _ = C.solve_chain_thresholds(3.0 * lams, spec)
    assert c3 == pytest.approx(3.0 * c1, rel=1e-6)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000))
def test_eq15_gradient_matches_autodiff(seed):
    rng = np.random.default_rng(seed)
    M = 15
    lams = rng.gamma(2.0, 1.0, M)
    w1 = rng.uniform(0.05, 0.95, M)
    args = (10.0, 12.0, 0.4, 0.6, 1.0)
    g_auto = jax.grad(C.tandem_both_cost)(
        jnp.asarray(w1), jnp.asarray(lams), *args)
    g_hand = C.tandem_both_grad(w1, lams, *args)
    # f32 autodiff vs f64 hand formula: tolerance at f32 level
    np.testing.assert_allclose(np.asarray(g_auto), g_hand, rtol=3e-3,
                               atol=3e-4)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), gamma=st.sampled_from([0.5, 1.0, 2.0]),
       beta=st.sampled_from([0.0, 0.3, 2.0]))
def test_eq15_gradient_matches_autodiff_random_params(seed, gamma, beta):
    """The hand-coded (15) gradient tracks JAX autodiff of (14) across
    the full parameter family (γ, β, k₁, k₂, h drawn at random), not
    just the fixed point of the basic cross-check."""
    rng = np.random.default_rng(seed)
    M = int(rng.integers(8, 40))
    lams = rng.gamma(2.0, 1.0, M)
    w1 = rng.uniform(0.05, 0.95, M)
    k1, k2 = rng.uniform(5.0, 40.0, 2)
    h = float(rng.uniform(0.05, 2.0))
    g_auto = jax.grad(C.tandem_both_cost)(
        jnp.asarray(w1), jnp.asarray(lams), float(k1), float(k2), h,
        float(beta), float(gamma))
    g_hand = C.tandem_both_grad(w1, lams, float(k1), float(k2), h,
                                float(beta), float(gamma))
    scale = np.max(np.abs(g_hand)) + 1e-12
    np.testing.assert_allclose(np.asarray(g_auto) / scale, g_hand / scale,
                               rtol=3e-3, atol=3e-4)


# ------------------------------------------------------- thresholds_to_w
def _w_invariants(lams, splits, n_caches):
    order = np.argsort(-lams, kind="stable")
    w = C.thresholds_to_w(lams, splits, order, n_caches)
    M = len(lams)
    # rows: each region fully assigned (partition of unity)
    np.testing.assert_allclose(w.sum(axis=1), np.ones(M), atol=1e-12)
    assert np.all(w >= 0.0)
    # columns: each cache's mass equals its band width
    pos = np.concatenate([[0.0], np.asarray(splits, float), [float(M)]])
    pos = np.maximum.accumulate(np.clip(pos, 0.0, float(M)))
    np.testing.assert_allclose(w.sum(axis=0), np.diff(pos), atol=1e-12)
    return w


def test_thresholds_to_w_duplicate_lambda_ties():
    """All-equal λ: the stable sort fixes an arbitrary but deterministic
    order; the w matrix must still be an exact partition with per-band
    masses equal to the band widths."""
    lams = np.ones(10)
    w = _w_invariants(lams, np.array([2.5, 7.0]), 2)
    assert w.shape == (10, 3)


def test_thresholds_to_w_single_region():
    """M=1: one region split across caches by fractional shares."""
    w = _w_invariants(np.array([3.0]), np.array([0.25, 0.75]), 2)
    np.testing.assert_allclose(w[0], [0.25, 0.5, 0.25])


def test_thresholds_to_w_capacity_exceeds_catalog():
    """k beyond the catalog mass pushes the unconstrained split past M;
    the sanitized splits must clip, keep w a partition, and leave the
    repository band empty (everything cached)."""
    lams = np.array([5.0, 3.0, 2.0, 1.0])
    w = _w_invariants(lams, np.array([2.0, 9.0]), 2)
    assert w[:, 2].sum() == pytest.approx(0.0)    # nothing reaches repo
    # non-monotone splits are made nondecreasing, not an error
    _w_invariants(lams, np.array([3.0, 1.0]), 2)


def test_tandem_both_beta0_recovers_leaf_only_regime():
    """β=0 (no parent arrivals) must reduce (14) to the leaf-only tandem
    of (11) — costs agree at the respective optima."""
    rng = np.random.default_rng(5)
    lams = rng.gamma(2.0, 1.0, 30)
    k1 = k2 = 20.0
    h = 0.8
    w1, c14 = C.solve_tandem_both(lams, k1, k2, h, beta=0.0, gamma=1.0,
                                  iters=8000, lr=0.1)
    # (11) with caches [k1,k2], hs [0,h], and an unreachable repository
    spec = C.ChainSpec(ks=(k1, k2), hs=(0.0, h), h_repo=1e9, gamma=1.0)
    _, c11, _ = C.solve_chain_thresholds(lams, spec)
    assert c14 == pytest.approx(c11, rel=2e-2)


def test_shifted_tessellation_closed_form_vs_numeric():
    for h in (0.0, 0.01, 0.03, 0.08):
        cf = C.shifted_tessellation_cost(k=100, h=h, area=1.0, lam=1.0)
        nm = C.shifted_tessellation_cost_numeric(k=100, h=h, area=1.0,
                                                 lam=1.0, gamma=1.0)
        assert cf == pytest.approx(nm, rel=2e-3)


def test_shifted_tessellation_no_forwarding_beyond_r():
    """h > r ⇒ z = 0 ⇒ the parent provides no help to leaf arrivals
    (the paper: 'if h > r requests are not forwarded')."""
    k, area = 64, 1.0
    r = np.sqrt(area / (2 * k))
    base = C.shifted_tessellation_cost(k, h=r * 1.01, area=area, lam=1.0)
    plain = 2.0 * k * C.cell_cost(r, 1.0, 1.0)
    assert base == pytest.approx(plain)


def test_shifted_beats_aligned_tessellation():
    """Fig 2's point: shifting the parent tessellation strictly reduces
    the cost whenever h < r (corner regions get cheaper service)."""
    k, area, h = 100, 1.0, 0.02
    shifted = C.shifted_tessellation_cost(k, h, area, 1.0)
    r = np.sqrt(area / (2 * k))
    aligned = 2.0 * k * C.cell_cost(r, 1.0, 1.0)   # parent mirrors leaf ⇒ no help
    assert shifted < aligned
