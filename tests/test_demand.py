"""Demand-model validation: degenerate traces must fail loudly at
construction (the old code divided by zero in ``_normalize`` and handed
the solvers NaN rates that only exploded much later)."""
import numpy as np
import pytest

from repro.core import catalog as catalog_api
from repro.core import demand as demand_api


@pytest.fixture(scope="module")
def cat():
    return catalog_api.embedding_catalog(n=50, dim=4, seed=0)


def test_from_trace_counts_requests(cat):
    dem = demand_api.from_trace(10, np.array([1, 1, 3, 7]),
                                np.array([0, 1, 0, 1]), n_ingress=2)
    assert dem.lam.shape == (2, 10)
    assert dem.lam.sum() == pytest.approx(1.0)
    assert dem.lam[0, 1] == pytest.approx(0.25)
    assert dem.lam[1, 1] == pytest.approx(0.25)
    assert dem.lam[0, 3] == pytest.approx(0.25)
    assert dem.lam[1, 7] == pytest.approx(0.25)


def test_from_trace_empty_raises(cat):
    with pytest.raises(ValueError, match="empty trace"):
        demand_api.from_trace(10, np.array([], np.int64),
                              np.array([], np.int64))


def test_from_trace_length_mismatch_raises(cat):
    with pytest.raises(ValueError, match="length mismatch"):
        demand_api.from_trace(10, np.array([1, 2, 3]),
                              np.array([0, 0]), n_ingress=1)


def test_from_trace_object_id_out_of_range_raises(cat):
    with pytest.raises(ValueError, match="object ids"):
        demand_api.from_trace(10, np.array([3, 10]), np.array([0, 0]))
    with pytest.raises(ValueError, match="object ids"):
        demand_api.from_trace(10, np.array([-1, 3]), np.array([0, 0]))


def test_from_trace_ingress_id_out_of_range_raises(cat):
    """The n_ingress/ids mismatch: a trace recorded on a 4-ingress
    network loaded with the default n_ingress=1 must be rejected, not
    silently mis-binned (or IndexError'd) by np.add.at."""
    with pytest.raises(ValueError, match="ingress ids"):
        demand_api.from_trace(10, np.array([1, 2]), np.array([0, 3]),
                              n_ingress=1)
    with pytest.raises(ValueError, match="ingress ids"):
        demand_api.from_trace(10, np.array([1, 2]), np.array([0, -2]),
                              n_ingress=2)


def test_normalize_zero_rates_raises():
    with pytest.raises(ValueError, match="positive finite sum"):
        demand_api._normalize(np.zeros((1, 8)))


def test_normalize_nonfinite_raises():
    lam = np.ones((1, 8))
    lam[0, 3] = np.inf
    with pytest.raises(ValueError, match="positive finite sum"):
        demand_api._normalize(lam)
    lam[0, 3] = np.nan
    with pytest.raises(ValueError, match="positive finite sum"):
        demand_api._normalize(lam)


def test_generators_still_normalize(cat):
    """The validation must not reject any legitimate generator output."""
    for dem in (demand_api.uniform(cat),
                demand_api.zipf(cat, alpha=0.8, n_ingress=3),
                demand_api.gaussian_grid(cat, sigma=2.0)):
        assert dem.lam.sum() == pytest.approx(1.0)
        assert np.isfinite(dem.lam).all()


# ===================================================================
# sample(): cached-CDF fast path
# ===================================================================
def test_sample_bit_compatible_with_generator_choice(cat):
    """The cached-CDF inverse sampling is bit-compatible with the old
    ``rng.choice(size, p=flat_lam)`` implementation: same rng state →
    same requests (golden traces depend on this)."""
    dem = demand_api.zipf(cat, alpha=1.1, n_ingress=3, seed=2)
    obj, ing = dem.sample(500, np.random.default_rng(42))
    rng_ref = np.random.default_rng(42)
    p = np.asarray(dem.lam, np.float64).ravel()
    flat_ref = rng_ref.choice(p.size, size=500, p=p / p.sum())
    ing_ref, obj_ref = np.divmod(flat_ref, dem.lam.shape[1])
    np.testing.assert_array_equal(obj, obj_ref)
    np.testing.assert_array_equal(ing, ing_ref)


def test_sample_single_draws_equal_batched(cat):
    """n calls of sample(1) consume the rng exactly like one sample(n)
    — the streaming driver draws one request at a time, the benches
    draw batches; both must walk the same trace."""
    dem = demand_api.zipf(cat, alpha=0.9, n_ingress=2, seed=1)
    obj_b, ing_b = dem.sample(200, np.random.default_rng(7))
    rng = np.random.default_rng(7)
    singles = [dem.sample(1, rng) for _ in range(200)]
    np.testing.assert_array_equal(obj_b,
                                  np.concatenate([o for o, _ in singles]))
    np.testing.assert_array_equal(ing_b,
                                  np.concatenate([i for _, i in singles]))


def test_sample_statistics_match_lam(cat):
    dem = demand_api.zipf(cat, alpha=1.0, n_ingress=2, seed=3)
    obj, ing = dem.sample(200_000, np.random.default_rng(0))
    emp = np.zeros_like(dem.lam)
    np.add.at(emp, (ing, obj), 1.0)
    emp /= emp.sum()
    assert np.abs(emp - dem.lam).max() < 5e-3


def test_sample_per_call_cost_does_not_scale_with_catalog():
    """Perf guard for the O(n_ingress·O)-per-call regression: after the
    first call builds the CDF, a sample(1) on a 100× larger catalog
    must not cost ~100× more (the old code renormalized the full lam
    matrix inside every call)."""
    import time

    def per_call_s(n_objects, calls=300):
        lam = np.random.default_rng(0).random((4, n_objects))
        dem = demand_api.Demand(lam=lam / lam.sum())
        rng = np.random.default_rng(1)
        dem.sample(1, rng)                      # build the cached CDF
        t0 = time.perf_counter()
        for _ in range(calls):
            dem.sample(1, rng)
        return (time.perf_counter() - t0) / calls

    small, big = per_call_s(2_000), per_call_s(200_000)
    # searchsorted is O(log O): allow generous jitter, reject O(O)
    assert big < small * 20 + 1e-4, \
        f"sample(1) scaled with catalog size: {small:.2e}s → {big:.2e}s"
