"""Demand-model validation: degenerate traces must fail loudly at
construction (the old code divided by zero in ``_normalize`` and handed
the solvers NaN rates that only exploded much later)."""
import numpy as np
import pytest

from repro.core import catalog as catalog_api
from repro.core import demand as demand_api


@pytest.fixture(scope="module")
def cat():
    return catalog_api.embedding_catalog(n=50, dim=4, seed=0)


def test_from_trace_counts_requests(cat):
    dem = demand_api.from_trace(10, np.array([1, 1, 3, 7]),
                                np.array([0, 1, 0, 1]), n_ingress=2)
    assert dem.lam.shape == (2, 10)
    assert dem.lam.sum() == pytest.approx(1.0)
    assert dem.lam[0, 1] == pytest.approx(0.25)
    assert dem.lam[1, 1] == pytest.approx(0.25)
    assert dem.lam[0, 3] == pytest.approx(0.25)
    assert dem.lam[1, 7] == pytest.approx(0.25)


def test_from_trace_empty_raises(cat):
    with pytest.raises(ValueError, match="empty trace"):
        demand_api.from_trace(10, np.array([], np.int64),
                              np.array([], np.int64))


def test_from_trace_length_mismatch_raises(cat):
    with pytest.raises(ValueError, match="length mismatch"):
        demand_api.from_trace(10, np.array([1, 2, 3]),
                              np.array([0, 0]), n_ingress=1)


def test_from_trace_object_id_out_of_range_raises(cat):
    with pytest.raises(ValueError, match="object ids"):
        demand_api.from_trace(10, np.array([3, 10]), np.array([0, 0]))
    with pytest.raises(ValueError, match="object ids"):
        demand_api.from_trace(10, np.array([-1, 3]), np.array([0, 0]))


def test_from_trace_ingress_id_out_of_range_raises(cat):
    """The n_ingress/ids mismatch: a trace recorded on a 4-ingress
    network loaded with the default n_ingress=1 must be rejected, not
    silently mis-binned (or IndexError'd) by np.add.at."""
    with pytest.raises(ValueError, match="ingress ids"):
        demand_api.from_trace(10, np.array([1, 2]), np.array([0, 3]),
                              n_ingress=1)
    with pytest.raises(ValueError, match="ingress ids"):
        demand_api.from_trace(10, np.array([1, 2]), np.array([0, -2]),
                              n_ingress=2)


def test_normalize_zero_rates_raises():
    with pytest.raises(ValueError, match="positive finite sum"):
        demand_api._normalize(np.zeros((1, 8)))


def test_normalize_nonfinite_raises():
    lam = np.ones((1, 8))
    lam[0, 3] = np.inf
    with pytest.raises(ValueError, match="positive finite sum"):
        demand_api._normalize(lam)
    lam[0, 3] = np.nan
    with pytest.raises(ValueError, match="positive finite sum"):
        demand_api._normalize(lam)


def test_generators_still_normalize(cat):
    """The validation must not reject any legitimate generator output."""
    for dem in (demand_api.uniform(cat),
                demand_api.zipf(cat, alpha=0.8, n_ingress=3),
                demand_api.gaussian_grid(cat, sigma=2.0)):
        assert dem.lam.sum() == pytest.approx(1.0)
        assert np.isfinite(dem.lam).all()
