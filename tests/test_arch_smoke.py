"""Per-architecture smoke tests (deliverable f): every assigned arch is
instantiated at a reduced config of the same family and runs one forward
AND one backward (train) step plus a prefill→decode parity check on CPU,
asserting output shapes and no NaNs. Full configs are checked for
parameter-count fidelity against the published sizes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config, get_smoke_config, list_archs
from repro.models import model, schema, transformer

ARCHS = list_archs()

PUBLISHED_SIZES = {           # ±5% unless noted
    "jamba-1.5-large-398b": 398e9,
    "deepseek-67b": 67e9,
    "granite-3-2b": 2.5e9,
    "deepseek-coder-33b": 33e9,
    "phi3-medium-14b": 14e9,
    "granite-moe-3b-a800m": 3.3e9,
    "dbrx-132b": 132e9,
    "xlstm-350m": 0.35e9,     # ±40%: block internals are ours (DESIGN.md)
    "whisper-small": 0.244e9,  # ±20%: conv frontend stubbed
    "qwen2-vl-7b": 7.6e9,     # backbone only (vision tower stubbed)
}

ACTIVE_SIZES = {
    "jamba-1.5-large-398b": 94e9,
    "granite-moe-3b-a800m": 0.8e9,
    "dbrx-132b": 36e9,
}


def make_batch(cfg, rng, B=2, S=24):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S))),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))}
    if cfg.is_encdec:
        batch["audio_embeds"] = jnp.asarray(
            rng.standard_normal((B, 64, 128)), jnp.float32)
    if cfg.mrope:
        S_img = 8
        batch["image_embeds"] = jnp.asarray(
            rng.standard_normal((B, S_img, 1280)), jnp.float32)
        batch["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(S + S_img)[None, None, :], (3, B, S + S_img)
        ).astype(jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finiteness(arch, rng):
    cfg = get_smoke_config(arch)
    params = model.init_params(cfg, 0)
    batch = make_batch(cfg, rng)
    loss, metrics = jax.jit(model.make_train_forward(cfg))(params, batch)
    assert np.isfinite(float(loss)), arch
    assert float(metrics["ce"]) == pytest.approx(np.log(cfg.padded_vocab),
                                                 rel=0.15)


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_gradient_step(arch, rng):
    """Backward pass produces finite grads for every leaf; loss drops
    after one SGD step on the same batch."""
    cfg = get_smoke_config(arch)
    params = model.init_params(cfg, 0)
    batch = make_batch(cfg, rng)
    fwd = model.make_train_forward(cfg)
    (loss0, _), grads = jax.jit(
        jax.value_and_grad(fwd, has_aux=True))(params, batch)
    flat = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g))) for g in flat), arch
    params2 = jax.tree.map(lambda p, g: p - 0.3 * g, params, grads)
    loss1, _ = jax.jit(fwd)(params2, batch)
    assert float(loss1) < float(loss0), (arch, float(loss0), float(loss1))


@pytest.mark.parametrize("arch", [a for a in ARCHS if a != "whisper-small"])
def test_prefill_decode_parity(arch, rng):
    cfg = get_smoke_config(arch)
    params = model.init_params(cfg, 0)
    B, S = 2, 24
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))
    full, _, _ = transformer.forward(cfg, params, {"tokens": toks},
                                     mode="train")
    Sp = S - 4
    logits, caches = jax.jit(model.make_prefill(cfg))(
        params, {"tokens": toks[:, :Sp]})
    caches = model._pad_caches(cfg, caches, S)
    step = jax.jit(model.make_serve_step(cfg))
    errs = [float(jnp.max(jnp.abs(logits - full[:, :Sp])))]
    for t in range(4):
        lg, caches = step(params, toks[:, Sp + t:Sp + t + 1], caches, Sp + t)
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, Sp + t]))))
    assert max(errs) < 2e-3, (arch, errs)


def test_whisper_parity(rng):
    cfg = get_smoke_config("whisper-small")
    params = model.init_params(cfg, 0)
    from repro.models import encdec
    B, S = 2, 20
    audio = jnp.asarray(rng.standard_normal((B, 64, 128)), jnp.float32)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))
    full, _, _ = encdec.encdec_forward(
        cfg, params, {"tokens": toks, "audio_embeds": audio}, mode="train")
    Sp = S - 4
    lp, caches = jax.jit(model.make_prefill(cfg))(
        params, {"tokens": toks[:, :Sp], "audio_embeds": audio})
    caches = model._pad_caches(cfg, caches, S)
    step = jax.jit(model.make_serve_step(cfg))
    errs = [float(jnp.max(jnp.abs(lp - full[:, :Sp])))]
    for t in range(4):
        lg, caches = step(params, toks[:, Sp + t:Sp + t + 1], caches, Sp + t)
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, Sp + t]))))
    assert max(errs) < 2e-3, errs


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_matches_published(arch):
    cfg = get_config(arch)
    n = schema.param_count(cfg)
    target = PUBLISHED_SIZES[arch]
    tol = {"xlstm-350m": 0.4, "whisper-small": 0.2}.get(arch, 0.05)
    assert abs(n - target) / target < tol, (arch, n, target)


@pytest.mark.parametrize("arch", sorted(ACTIVE_SIZES))
def test_active_param_count(arch):
    cfg = get_config(arch)
    n = schema.active_param_count(cfg)
    assert abs(n - ACTIVE_SIZES[arch]) / ACTIVE_SIZES[arch] < 0.15, n


@pytest.mark.parametrize("arch", ["xlstm-350m", "jamba-1.5-large-398b"])
def test_subquadratic_flag(arch):
    assert get_config(arch).subquadratic     # long_500k eligibility


def test_full_attention_archs_marked():
    for a in ARCHS:
        cfg = get_config(a)
        if a not in ("xlstm-350m", "jamba-1.5-large-398b"):
            assert not cfg.subquadratic
