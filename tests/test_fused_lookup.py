"""Differential suite: fused segmented-1-NN lookup vs the per-level
looped reference path.

The fused path (one pallas_call over the concatenation of all levels
plus the repository-as-virtual-key) must reproduce the looped path
(one KNN kernel per level, minima compared centrally) exactly: same
winning (level, slot, payload) everywhere, and bitwise-equal costs for
γ = 1 (both paths evaluate identical f32 arithmetic per (query, key)
pair and min is associative). For γ ≠ 1 XLA may contract the
pow/sqrt/add chain into FMAs differently across the two kernels, so
costs there are compared to 1e-6 (observed deltas are 1 ulp). Covers
random multi-level networks, all metrics, γ ≠ 1, empty levels
(sentinel masking), single-level networks, repo-wins and repo-ties
cases, plus the pure-jnp fused oracle and jit-ability.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import assert_results_equal as assert_lookups_equal
from conftest import make_net

from repro.core.simcache import REPO_LEVEL, CacheLevel, SimCacheNetwork
from repro.kernels.knn import fused_lookup, fused_lookup_ref


@pytest.mark.parametrize("metric", ["l1", "l2", "l2sq"])
@pytest.mark.parametrize("gamma", [1.0, 0.5, 2.0])
def test_fused_matches_looped_random_levels(metric, gamma):
    for seed, sizes, hs, h_repo, nq in [
        (0, [5, 9, 3], [0.0, 0.5, 1.0], 2.0, 23),
        (1, [17, 2, 31, 8], [0.0, 0.2, 0.7, 1.3], 3.0, 23),
        (2, [64, 64], [0.0, 1.0], 5.0, 23),
        # ΣK = 600 → 3 key tiles and 300 queries → 2 query tiles at the
        # default 256 block: exercises the cross-tile running-min
        # accumulation, metadata carry, and last-tile repo fold
        (3, [200, 150, 250], [0.0, 0.4, 0.8], 2.5, 300),
    ]:
        net, rng = make_net(seed, sizes, hs, h_repo, metric, gamma)
        q = jnp.asarray((rng.standard_normal((nq, 6)) * 2)
                        .astype(np.float32))
        assert_lookups_equal(net._lookup_fused(q), net._lookup_looped(q),
                             exact_cost=gamma == 1.0)


@pytest.mark.parametrize("metric", ["l1", "l2", "l2sq"])
def test_fused_empty_levels_masked(metric):
    """Sentinel keys of empty levels must never win even under l2sq,
    where an unmasked 1e30-style sentinel used to overflow to inf."""
    net, rng = make_net(3, [4, 1, 4], [0.0, 0.1, 0.4], 2.5, metric,
                        empty=(1,))
    q = jnp.asarray(rng.standard_normal((11, 6)).astype(np.float32))
    res = net._lookup_fused(q)
    assert not np.any(np.asarray(res.level) == 1)
    assert np.all(np.isfinite(np.asarray(res.cost)))
    assert_lookups_equal(res, net._lookup_looped(q))

    # all levels empty → everything served by the repository
    net_all, rng = make_net(4, [1, 1], [0.0, 0.3], 7.5, metric,
                            empty=(0, 1))
    res = net_all._lookup_fused(jnp.asarray(
        rng.standard_normal((5, 6)).astype(np.float32)))
    np.testing.assert_array_equal(np.asarray(res.level), REPO_LEVEL)
    np.testing.assert_array_equal(np.asarray(res.payload), -1)
    np.testing.assert_allclose(np.asarray(res.cost), 7.5)
    np.testing.assert_array_equal(np.asarray(res.approx_cost), 0.0)
    assert not np.any(np.asarray(res.hit))


def test_fused_single_level():
    net, rng = make_net(5, [13], [0.25], 4.0, "l2", 1.0)
    q = jnp.asarray(rng.standard_normal((9, 6)).astype(np.float32))
    assert_lookups_equal(net._lookup_fused(q), net._lookup_looped(q))


def test_fused_repo_wins_and_ties():
    """With a tiny h_repo the repository undercuts every cache; a cache
    exactly tying h_repo must win (strict-improvement repo rule, same as
    argmin over [levels…, repo])."""
    net, rng = make_net(6, [6, 6], [0.0, 0.1], 1e-4, "l2")
    q = jnp.asarray((rng.standard_normal((17, 6)) * 3).astype(np.float32))
    res = net._lookup_fused(q)
    np.testing.assert_array_equal(np.asarray(res.level), REPO_LEVEL)
    assert_lookups_equal(res, net._lookup_looped(q))

    # exact tie: query == stored key, h level == h_repo → cache serves
    key = np.ones((1, 6), np.float32)
    lv = CacheLevel(keys=jnp.asarray(key),
                    values=jnp.asarray(np.array([7], np.int32)), h=2.0)
    net_tie = SimCacheNetwork(levels=[lv], h_repo=2.0, metric="l2")
    res = net_tie.lookup(jnp.asarray(key))
    assert int(res.level[0]) == 0 and int(res.payload[0]) == 7
    assert bool(res.hit[0])


def test_fused_matches_ref_oracle():
    """use_pallas=False routes the fused layout through the pure-jnp
    oracle — identical results."""
    net, rng = make_net(7, [5, 8, 2], [0.0, 0.4, 0.9], 2.0, "l2", 2.0)
    q = jnp.asarray(rng.standard_normal((19, 6)).astype(np.float32))
    keys, h_key, meta = net.fused_layout()
    out_k = fused_lookup(q, keys, h_key, meta, metric="l2", gamma=2.0,
                         h_repo=2.0, use_pallas=True)
    out_r = fused_lookup_ref(q, keys, h_key, meta, metric="l2", gamma=2.0,
                             h_repo=2.0)
    for a, b in zip(out_k, out_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)
    no_pallas = dataclasses.replace(net, use_pallas=False)
    assert_lookups_equal(net.lookup(q), no_pallas.lookup(q),
                         exact_cost=False)


def test_fused_lookup_is_jittable_end_to_end():
    """The whole fused lookup jits as one function of the query batch —
    no retraces across calls with the same shapes."""
    net, rng = make_net(8, [12, 7], [0.0, 0.6], 3.0, "l2")
    keys, h_key, meta = net.fused_layout()

    @jax.jit
    def serve(q):
        return fused_lookup(q, keys, h_key, meta, metric="l2",
                            h_repo=3.0)

    for seed in range(3):
        r = np.random.default_rng(seed)
        q = jnp.asarray(r.standard_normal((16, 6)).astype(np.float32))
        cost, ca, lvl, slot, pay = serve(q)
        ref = net._lookup_looped(q)
        np.testing.assert_array_equal(np.asarray(lvl),
                                      np.asarray(ref.level))
        # re-jitting in a new surrounding program can re-fuse the cost
        # arithmetic (FMA contraction) → compare to 1e-6, not bitwise
        np.testing.assert_allclose(np.asarray(cost),
                                   np.asarray(ref.cost),
                                   rtol=1e-6, atol=1e-6)


def test_fused_no_levels_at_all():
    """A network with zero cache levels serves everything from the
    repository, fused and looped alike (and with the jnp oracle)."""
    q = jnp.asarray(np.random.default_rng(0)
                    .standard_normal((6, 5)).astype(np.float32))
    for use_pallas in (True, False):
        net = SimCacheNetwork(levels=[], h_repo=4.5, metric="l2",
                              use_pallas=use_pallas)
        res = net.lookup(q)
        np.testing.assert_array_equal(np.asarray(res.level), REPO_LEVEL)
        np.testing.assert_allclose(np.asarray(res.cost), 4.5)
        np.testing.assert_array_equal(np.asarray(res.payload), -1)
        assert_lookups_equal(res, net._lookup_looped(q))


def test_invalidate_layout_after_mutation():
    """The fused layout is memoized; mutating levels + invalidate_layout
    must be reflected, matching the looped path again."""
    net, rng = make_net(10, [4, 4], [0.0, 0.5], 3.0, "l2")
    q = jnp.asarray(rng.standard_normal((8, 6)).astype(np.float32))
    net.lookup(q)                                  # memoize old layout
    new_keys = jnp.asarray(rng.standard_normal((5, 6)).astype(np.float32))
    net.levels[0] = CacheLevel(keys=new_keys, values=jnp.asarray(
        np.arange(100, 105, dtype=np.int32)), h=0.0)
    net.invalidate_layout()
    assert_lookups_equal(net._lookup_fused(q), net._lookup_looped(q))


def test_from_placement_fused_roundtrip():
    """from_placement → fused lookup == looped lookup on a placement-
    shaped input, including an empty level (all slots unassigned)."""
    rng = np.random.default_rng(9)
    coords = rng.standard_normal((40, 5)).astype(np.float32)
    slot_cache = np.array([0] * 4 + [1] * 4 + [2] * 4)
    slots = np.concatenate([rng.choice(40, 8, replace=False),
                            np.full(4, -1)]).astype(np.int64)
    f = SimCacheNetwork.from_placement(coords, slots, slot_cache,
                                       hs=[0.0, 0.5, 1.0], h_repo=2.0,
                                       metric="l1", fused=True)
    l = SimCacheNetwork.from_placement(coords, slots, slot_cache,
                                       hs=[0.0, 0.5, 1.0], h_repo=2.0,
                                       metric="l1", fused=False)
    q = jnp.asarray(coords[:25])
    assert_lookups_equal(f.lookup(q), l.lookup(q))
    # level 2 is empty → never serves, and its sentinel stays masked
    assert not np.any(np.asarray(f.lookup(q).level) == 2)
