"""End-to-end trace-replay regression: the §5 online control plane
running *inside* the serving engine, locked by a checked-in golden file.

A fixed recorded request trace is replayed through ``serve.Engine`` with
``EngineConfig.netduel=True``; the golden file pins the served-cost
trajectory (f32-tol floats) and the placement churn (tolerance-free
ints: per-batch hit counts, promotion counts, churn-event batches, and
the final duel slots). Any silent drift in the data-plane/control-plane
fusion — lookup costs feeding the duel, promotions rebuilding the
runtime cache, the arming rng, the observed-demand normalization —
shows up as a golden mismatch.

Regenerate after an *intentional* behavior change with:

    PYTHONPATH=src python tests/test_trace_replay.py --write
"""
import dataclasses
import json
import os
import sys

import jax.numpy as jnp
import numpy as np

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "netduel_trace.json")
GOLDEN_STREAM = os.path.join(os.path.dirname(__file__), "golden",
                             "streaming_trace.json")


def _build_engine():
    from repro.configs.registry import get_smoke_config
    from repro.core import catalog as catalog_api
    from repro.models import model as model_api
    from repro.serve import EngineConfig, SimCacheEngine

    cfg = dataclasses.replace(get_smoke_config("granite-3-2b"),
                              n_layers=2, d_model=64, n_heads=4,
                              n_kv_heads=2, head_dim=16, d_ff=128,
                              vocab=256)
    params = model_api.init_params(cfg, 0)
    cat = catalog_api.embedding_catalog(n=300, dim=16, seed=1)
    ecfg = EngineConfig(k_device=8, k_pod=12, k_global=16,
                        h_ici=1.0, h_dcn=10.0, h_model=100.0,
                        metric="l2", algo="greedy", netduel=True,
                        duel_window=64, duel_arm_prob=0.5, duel_seed=0)
    return SimCacheEngine(cfg, params, ecfg, cat.coords), cfg, cat


def _replay():
    """The recorded trace: 4 cold batches, one offline refresh (arming
    the duel plane), 24 warm batches observed by the duel."""
    from repro.core import demand as demand_api

    eng, cfg, cat = _build_engine()
    rng = np.random.default_rng(0)
    dem = demand_api.zipf(cat, alpha=1.1, seed=3)

    def batch():
        ids, _ = dem.sample(16, rng)
        prompts = jnp.asarray(
            rng.integers(0, cfg.vocab, (16, 8)).astype(np.int32))
        return ids, prompts

    for _ in range(4):
        eng.serve(*batch())
    eng.refresh_placement()
    assert eng.duel is not None
    cost_traj, hits_traj, churn_batches, promo_traj = [], [], [], []
    for b in range(24):
        before = eng.placement_events
        _, stats = eng.serve(*batch())
        cost_traj.append(stats.total_cost)
        hits_traj.append(stats.n_hits)
        promo_traj.append(eng.duel.n_promotions)
        if eng.placement_events > before:
            churn_batches.append(b)
    return {
        "cost_trajectory": cost_traj,
        "hits_trajectory": hits_traj,
        "promotions_trajectory": promo_traj,
        "churn_batches": churn_batches,
        "placement_events": eng.placement_events,
        "final_duel_slots": [int(s) for s in eng.duel.slots_np],
        "duel_served_cost": eng.duel.served_cost,
    }


def _replay_streaming():
    """The streaming trace: three Poisson streams multiplexed through
    the bucketed StreamDriver path, a mid-stream background refresh
    swapped in atomically at a fixed batch boundary, then a second
    serving phase on the new placement. Batch sizes are set by the
    virtual clock and the swap point is pinned (request → wait → poll),
    so the whole trajectory — including which batches the duel promotes
    on — is deterministic and golden-able."""
    from repro.core import demand as demand_api
    from repro.serve import StreamDriver, StreamSpec

    eng, cfg, cat = _build_engine()
    streams = [
        StreamSpec(demand=demand_api.zipf(cat, alpha=1.1, seed=s + 1),
                   rate=[5.0, 9.0, 2.0][s], seed=s + 1, name=f"user{s}")
        for s in range(3)]
    drv = StreamDriver(eng, streams, max_batch=48, batch_window=2.0)
    st_cold = drv.run(64)
    eng.refresh_placement()                    # arms the duel plane
    st1 = drv.run(160)
    # the mid-stream swap, at a deterministic batch boundary
    assert eng.request_refresh()
    assert eng.wait_refresh(timeout=300)
    assert eng.poll_refresh()
    st2 = drv.run(160)
    return {
        "batch_sizes": st_cold.batch_sizes + st1.batch_sizes
        + st2.batch_sizes,
        "n_hits": eng.stats.n_hits,
        "model_calls": eng.stats.model_calls,
        "total_cost": eng.stats.total_cost,
        "placement_events": eng.placement_events,
        "placement_version": eng.placement.version,
        "n_promotions": eng.duel.n_promotions,
        "final_duel_slots": [int(s) for s in eng.duel.slots_np],
        "duel_served_cost": eng.duel.served_cost,
    }


def test_netduel_trace_replay_matches_golden():
    with open(GOLDEN) as f:
        golden = json.load(f)
    got = _replay()
    # tolerance-free ints: churn, hits, promotions, final placement
    assert got["hits_trajectory"] == golden["hits_trajectory"]
    assert got["promotions_trajectory"] == golden["promotions_trajectory"]
    assert got["churn_batches"] == golden["churn_batches"]
    assert got["placement_events"] == golden["placement_events"]
    assert got["final_duel_slots"] == golden["final_duel_slots"]
    # f32-tol costs (accumulated lookup costs / duel pricing)
    np.testing.assert_allclose(got["cost_trajectory"],
                               golden["cost_trajectory"], rtol=1e-5)
    np.testing.assert_allclose(got["duel_served_cost"],
                               golden["duel_served_cost"], rtol=1e-5)


def test_streaming_trace_replay_matches_golden():
    """The streaming engine (bucketed batches + double-buffered swap)
    replays its golden bit-for-bit: batch forming, serving accounting,
    duel churn, and the post-swap placement are all pinned."""
    with open(GOLDEN_STREAM) as f:
        golden = json.load(f)
    got = _replay_streaming()
    assert got["batch_sizes"] == golden["batch_sizes"]
    assert got["n_hits"] == golden["n_hits"]
    assert got["model_calls"] == golden["model_calls"]
    assert got["placement_events"] == golden["placement_events"]
    assert got["placement_version"] == golden["placement_version"]
    assert got["n_promotions"] == golden["n_promotions"]
    assert got["final_duel_slots"] == golden["final_duel_slots"]
    np.testing.assert_allclose(got["total_cost"], golden["total_cost"],
                               rtol=1e-5)
    np.testing.assert_allclose(got["duel_served_cost"],
                               golden["duel_served_cost"], rtol=1e-5)


if __name__ == "__main__":
    if "--write" in sys.argv:
        os.makedirs(os.path.dirname(GOLDEN), exist_ok=True)
        for path, fn in ((GOLDEN, _replay),
                         (GOLDEN_STREAM, _replay_streaming)):
            with open(path, "w") as f:
                json.dump(fn(), f, indent=1)
            print(f"wrote {path}")
    else:
        print(__doc__)
