"""Streaming serving-engine tests: batch bucketing (retrace regression +
masked-padding equivalence), the double-buffered placement swap (async
background solve differential vs the synchronous path, and an explicit
pre/post-swap replay), and the multi-stream driver.

The 8-way variants ride scripts/ci.sh pass 2
(--xla_force_host_platform_device_count=8), like the other mesh suites.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import tracecount
from repro.configs.registry import get_smoke_config
from repro.core import catalog as catalog_api
from repro.core import demand as demand_api
from repro.models import model as model_api
from repro.serve import (EngineConfig, SimCacheEngine, StreamDriver,
                         StreamSpec, bucket_size)


def make_engine(n_objects=300, netduel=True, bucket=True, sharded=False,
                mesh=None, **ecfg_kw):
    cfg = dataclasses.replace(get_smoke_config("granite-3-2b"),
                              n_layers=2, d_model=64, n_heads=4,
                              n_kv_heads=2, head_dim=16, d_ff=128,
                              vocab=256)
    params = model_api.init_params(cfg, 0)
    cat = catalog_api.embedding_catalog(n=n_objects, dim=16, seed=1)
    ecfg = EngineConfig(k_device=8, k_pod=12, k_global=16,
                        h_ici=1.0, h_dcn=10.0, h_model=100.0,
                        metric="l2", algo="greedy", netduel=netduel,
                        duel_window=64, duel_arm_prob=0.5, duel_seed=0,
                        bucket=bucket, sharded=sharded, **ecfg_kw)
    eng = SimCacheEngine(cfg, params, ecfg, cat.coords, mesh=mesh)
    return eng, cfg, cat


def mixed_batches(cat, cfg, sizes, seed=0):
    """One fixed request trace with the given per-batch sizes."""
    rng = np.random.default_rng(seed)
    dem = demand_api.zipf(cat, alpha=1.1, seed=3)
    batches = []
    for k in sizes:
        ids, _ = dem.sample(k, rng)
        prompts = jnp.asarray(
            rng.integers(0, cfg.vocab, (k, 8)).astype(np.int32))
        batches.append((ids, prompts))
    return batches


def accounting(eng):
    """The full serving/duel accounting a differential run must pin."""
    acct = {"n_hits": eng.stats.n_hits,
            "n_requests": eng.stats.n_requests,
            "model_calls": eng.stats.model_calls,
            "total_cost": eng.stats.total_cost,
            "total_approx_cost": eng.stats.total_approx_cost,
            "placement_events": eng.placement_events}
    if eng.duel is not None:
        acct["n_promotions"] = eng.duel.n_promotions
        acct["duel_served_cost"] = eng.duel.served_cost
        acct["duel_t"] = eng.duel.t
        acct["duel_slots"] = tuple(int(s) for s in eng.duel.slots_np)
    return acct


# ===================================================================
# bucketing
# ===================================================================
def test_bucket_size():
    assert bucket_size(1) == 8
    assert bucket_size(8) == 8
    assert bucket_size(9) == 16
    assert bucket_size(64) == 64
    assert bucket_size(700) == 1024
    assert bucket_size(3, lo=1) == 4


def test_bucketed_matches_unbucketed_exactly():
    """The masked-padding contract end to end: a mixed-batch-size trace
    served through the bucketed path produces bit-identical accounting —
    hits, costs, duel trajectory, promotion churn — to the unbucketed
    engine. Padding rows never leak into stats, counts, or the duel."""
    sizes = [1, 7, 16, 9, 33, 5, 16, 2, 31]
    accts = {}
    for bucket in (True, False):
        eng, cfg, cat = make_engine(bucket=bucket)
        batches = mixed_batches(cat, cfg, [16] * 4 + sizes)
        for ids, prompts in batches[:4]:          # cold
            eng.serve(ids, prompts)
        eng.refresh_placement()
        for ids, prompts in batches[4:]:
            eng.serve(ids, prompts)
        accts[bucket] = accounting(eng)
        accts[bucket]["counts"] = eng.counts.copy().tobytes()
    assert accts[True] == accts[False]


def test_retrace_regression_one_compile_per_bucket():
    """Serving batch sizes {1, 7, 64, 700} buckets to {8, 64, 1024}: the
    fused lookup and the duel scan must each compile at most once per
    bucket (3), not once per batch size (4) — and a second pass over the
    same sizes must add no traces at all."""
    eng, cfg, cat = make_engine()
    sizes = [1, 7, 64, 700]
    assert {bucket_size(s) for s in sizes} == {8, 64, 1024}
    warm = mixed_batches(cat, cfg, [16] * 4, seed=9)
    for ids, prompts in warm:
        eng.serve(ids, prompts)
    eng.refresh_placement()
    batches = mixed_batches(cat, cfg, sizes + sizes, seed=1)
    with tracecount.snapshot() as s:
        for ids, prompts in batches[:4]:
            eng.serve(ids, prompts)
        assert s.delta("fused_lookup") <= 3, \
            "fused lookup retraced beyond one compile per bucket"
        assert s.delta("duel_scan") <= 3, \
            "duel scan retraced beyond one compile per bucket"
        # steady state: the same sizes again compile nothing new
        lookups0, duels0 = s.delta("fused_lookup"), s.delta("duel_scan")
        for ids, prompts in batches[4:]:
            eng.serve(ids, prompts)
        assert s.delta("fused_lookup") == lookups0
        assert s.delta("duel_scan") == duels0


def test_unbucketed_retraces_per_batch_size():
    """The inverse pin: without bucketing, every distinct batch size is
    its own compile of the fused lookup — the pathology the bucketed
    path removes (and serving_bench.py quantifies)."""
    eng, cfg, cat = make_engine(netduel=False, bucket=False)
    for ids, prompts in mixed_batches(cat, cfg, [16] * 2, seed=9):
        eng.serve(ids, prompts)
    eng.refresh_placement()
    # the jit cache is process-global (keyed on shape), so these sizes
    # must not appear in any other test in this module
    sizes = [10, 11, 13, 14]
    with tracecount.snapshot() as s:
        for ids, prompts in mixed_batches(cat, cfg, sizes, seed=1):
            eng.serve(ids, prompts)
        assert s.delta("fused_lookup") == len(sizes)


# ===================================================================
# double-buffered placement: the atomic swap
# ===================================================================
def _swap_differential(sharded=False, mesh=None, **ecfg_kw):
    """Serve a stream across a mid-stream background refresh + atomic
    swap (run A); then replay the same requests against the pre- and
    post-swap placements explicitly (run B: synchronous solve installed
    at the same batch boundary; the solve itself must match A's
    background solve bit-for-bit). Accounting must agree exactly.
    ``ecfg_kw`` forwards EngineConfig overrides to all three runs (the
    warm-start variants below swap the solver for the §4 continuous
    pipeline — the differential contract is solver-independent)."""
    sizes = [16, 9, 16, 23, 16, 11, 16, 16, 7, 16]
    swap_after = 5                       # solve after batch 4, swap at 5

    # ---- run A: streamed, background solve, atomic swap
    eng_a, cfg, cat = make_engine(sharded=sharded, mesh=mesh, **ecfg_kw)
    batches = mixed_batches(cat, cfg, [16] * 4 + sizes)
    for ids, prompts in batches[:4]:
        eng_a.serve(ids, prompts)
    eng_a.refresh_placement()
    v0 = eng_a.placement.version
    traj_a = []
    for b, (ids, prompts) in enumerate(batches[4:]):
        if b == swap_after - 1:
            assert eng_a.request_refresh()
            assert eng_a.refresh_in_flight
            assert not eng_a.request_refresh()   # one in flight at a time
        eng_a.serve(ids, prompts)                # old placement serves
        if b == swap_after - 1:
            assert eng_a.wait_refresh(timeout=120)
            assert eng_a.poll_refresh()          # the atomic swap
            assert not eng_a.refresh_in_flight
        else:
            assert not eng_a.poll_refresh()
        traj_a.append(accounting(eng_a))
    assert eng_a.placement.version > v0
    slots_post = np.asarray(eng_a.placement.slots).copy()

    # ---- run B: same trace, *synchronous* solve at the same boundary
    eng_b, _, _ = make_engine(sharded=sharded, mesh=mesh, **ecfg_kw)
    for ids, prompts in batches[:4]:
        eng_b.serve(ids, prompts)
    eng_b.refresh_placement()
    traj_b = []
    pending = None
    for b, (ids, prompts) in enumerate(batches[4:]):
        if b == swap_after - 1:
            # snapshot + solve at A's request point (before this batch)
            inst = eng_b.observed_instance()
            pending = eng_b._solve(inst, eng_b.ecfg.algo,
                                   eng_b.ecfg.device_placement)[0], inst
        eng_b.serve(ids, prompts)
        if b == swap_after - 1:
            slots_b, inst = pending
            # background solve == synchronous solve on the same snapshot
            np.testing.assert_array_equal(slots_b, slots_post)
            eng_b._install(slots_b, inst)
        traj_b.append(accounting(eng_b))
    assert traj_a == traj_b

    # ---- run C: explicit replay against the captured post-swap
    # placement (no solver at all — the placement is installed verbatim)
    eng_c, _, _ = make_engine(sharded=sharded, mesh=mesh, **ecfg_kw)
    for ids, prompts in batches[:4]:
        eng_c.serve(ids, prompts)
    eng_c.refresh_placement()
    for b, (ids, prompts) in enumerate(batches[4:]):
        eng_c.serve(ids, prompts)
        if b == swap_after - 1:
            eng_c._install(slots_post, eng_c.observed_instance())
    assert accounting(eng_c) == traj_a[-1]


def test_atomic_swap_differential():
    _swap_differential()


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 devices (ci.sh pass 2)")
def test_atomic_swap_differential_8way():
    mesh = jax.make_mesh((8,), ("data",))
    _swap_differential(sharded=True, mesh=mesh)


def test_atomic_swap_differential_warmstart():
    """Warm-started background refresh (EngineConfig.warm_start: the §4
    analytic solve + Prop 4.2 band map + bounded polish) swapped in by
    poll_refresh is serving-equivalent to the synchronous warm-start
    solve at the same batch boundary — the warm path is deterministic,
    so the whole mid-swap differential holds bit-for-bit."""
    _swap_differential(warm_start=True, warm_polish_iters=128)


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 devices (ci.sh pass 2)")
def test_atomic_swap_differential_warmstart_8way():
    mesh = jax.make_mesh((8,), ("data",))
    _swap_differential(sharded=True, mesh=mesh, warm_start=True,
                       warm_polish_iters=128)


def test_refresh_in_flight_flag_and_versioning():
    eng, cfg, cat = make_engine(netduel=False)
    for ids, prompts in mixed_batches(cat, cfg, [16] * 4):
        eng.serve(ids, prompts)
    assert eng.placement.version == 0 and eng.simcache is None
    eng.refresh_placement()
    assert eng.placement.version == 1
    assert not eng.refresh_in_flight
    assert not eng.poll_refresh()            # nothing pending
    assert eng.request_refresh()
    assert eng.wait_refresh(timeout=120)
    assert eng.refresh_in_flight             # solved but not yet swapped
    assert eng.poll_refresh()
    assert eng.placement.version == 2
    assert eng.refresh_count == 2 and eng.swap_count == 1
    assert eng.max_swap_stall_s > 0.0


# ===================================================================
# multi-stream driver
# ===================================================================
def _streams(cat, n=3):
    rates = [5.0, 9.0, 2.0]
    return [StreamSpec(demand=demand_api.zipf(cat, alpha=1.1, seed=s + 1),
                       rate=rates[s % len(rates)], seed=s + 1,
                       name=f"user{s}") for s in range(n)]


def test_stream_driver_conserves_requests_and_versions():
    eng, cfg, cat = make_engine(refresh_on_promotion=True)
    drv = StreamDriver(eng, _streams(cat), max_batch=64, batch_window=3.0)
    st_cold = drv.run(100)
    assert st_cold.n_requests == 100
    eng.refresh_placement()
    st = drv.run(400)
    drv.drain_refresh()
    assert st.n_requests == 400
    assert sum(st.batch_sizes) == 400
    assert len(st.batch_latencies_ms) == st.n_batches
    assert st.distinct_batch_sizes > 1       # arrival-driven mixed sizes
    # versions observed by the serving loop never go backwards
    assert all(b >= a for a, b in zip(st.versions, st.versions[1:]))
    assert eng.stats.n_requests == 500


def test_stream_driver_is_deterministic_in_accounting():
    """Two identically seeded driver runs produce identical request
    traces and identical serving accounting (wall-clock latencies may
    differ; the accounting may not)."""
    accts = []
    for _ in range(2):
        eng, cfg, cat = make_engine()
        drv = StreamDriver(eng, _streams(cat), max_batch=32,
                           batch_window=2.0)
        drv.run(80)
        eng.refresh_placement()
        st = drv.run(200)
        accts.append((accounting(eng), tuple(st.batch_sizes)))
    assert accts[0] == accts[1]


def test_stream_driver_refresh_cadence():
    """refresh_every triggers background solves on a fixed cadence; all
    of them eventually swap in and serving never observes a stall longer
    than the per-batch budget by construction of the poll point."""
    eng, cfg, cat = make_engine(netduel=False)
    drv = StreamDriver(eng, _streams(cat), max_batch=32,
                       batch_window=2.0, refresh_every=4)
    drv.run(64)
    eng.refresh_placement()
    st = drv.run(256)
    drv.drain_refresh()
    assert st.refreshes_started > 0
    assert eng.swap_count > 0
    assert eng.refresh_count >= eng.swap_count
    assert not eng.refresh_in_flight
    assert st.requests_per_s > 0 and st.p99_ms >= st.p50_ms >= 0


def test_stream_driver_stall_window_is_per_run():
    """Stall-window regression: DriverStats.max_swap_stall_s must be the
    max over the swaps of *that* run. The old code copied the engine's
    all-time max, so a second run with no swaps at all still reported
    the first run's stall as its own."""
    eng, cfg, cat = make_engine(netduel=False)
    drv = StreamDriver(eng, _streams(cat), max_batch=32,
                       batch_window=2.0, refresh_every=4)
    drv.run(64)
    eng.refresh_placement()
    st1 = drv.run(256)
    drv.drain_refresh()
    assert eng.swap_count > 0
    assert st1.max_swap_stall_s > 0.0        # this run did swap
    assert st1.max_swap_stall_s <= eng.max_swap_stall_s
    # second run: no refresh cadence → no swaps → no stall to report
    drv.refresh_every = 0
    st2 = drv.run(64)
    assert st2.swaps == 0
    assert st2.max_swap_stall_s == 0.0, \
        "a swap-free run must not report the engine's all-time stall"
    assert eng.max_swap_stall_s > 0.0        # the all-time max survives


def test_stream_driver_threads_ingress_ids():
    """Ingress-threading regression: a multi-ingress stream population
    must land each request in its own (ingress, object) demand cell.
    The old driver popped ``(t, obj, _ing)`` and dropped the ingress, so
    every request was accounted to ingress 0."""
    from repro.core.scenarios import scenario

    sc = scenario("isp", cache_budget=24, placement="degree",
                  n_ingress=3, seed=0)
    cfg = dataclasses.replace(get_smoke_config("granite-3-2b"),
                              n_layers=2, d_model=64, n_heads=4,
                              n_kv_heads=2, head_dim=16, d_ff=128,
                              vocab=256)
    params = model_api.init_params(cfg, 0)
    cat = catalog_api.embedding_catalog(n=200, dim=16, seed=1)
    ecfg = EngineConfig(metric="l2", strategy="sim-lru", netduel=False)
    eng = SimCacheEngine(cfg, params, ecfg, cat.coords, net=sc.net)
    specs = [StreamSpec(demand=demand_api.zipf(cat, alpha=1.0,
                                               n_ingress=3, seed=s + 1),
                        rate=4.0, seed=s + 1) for s in range(2)]
    drv = StreamDriver(eng, specs, max_batch=32, batch_window=2.0)
    st = drv.run(300)
    assert st.n_requests == 300
    assert eng.counts.shape == (3, 200)
    per_ingress = eng.counts.sum(axis=1)
    assert per_ingress.sum() == 300
    assert np.count_nonzero(per_ingress) == 3, \
        "multi-ingress demand collapsed into a single ingress row"


def test_stream_rate_validation():
    eng, cfg, cat = make_engine(netduel=False)
    with pytest.raises(ValueError):
        StreamDriver(eng, [StreamSpec(demand=demand_api.zipf(cat),
                                      rate=0.0)])
    with pytest.raises(ValueError):
        StreamDriver(eng, [])


# ===================================================================
# analytic refresh gate (EngineConfig.refresh_min_gain)
# ===================================================================
def test_refresh_gate_skips_stationary_triggers_on_drift():
    """The surrogate-gated control plane: under stationary demand the
    analytic cost barely moves between snapshots, so cadence-triggered
    refresh requests are skipped (no device solve); switching the
    stream population to a flatter demand moves the predicted cost past
    the gate and the background solve fires again."""
    eng, cfg, cat = make_engine(netduel=False, refresh_min_gain=10.0)
    drv = StreamDriver(eng, _streams(cat), max_batch=32,
                       batch_window=2.0)
    drv.run(300)                             # warm the observed window
    eng.refresh_placement()                  # install + gate baseline
    drv.refresh_every = 4                    # cadence on from here
    st1 = drv.run(300)                       # stationary phase
    drv.drain_refresh()
    assert st1.refresh_skipped > 0
    assert st1.refresh_triggered == 0
    assert st1.refreshes_started == 0        # skipped ⇒ never started
    assert eng.swap_count == 0               # and nothing ever swapped
    # drift: replace the zipf population with uniform demand — the
    # observed window flattens, the predicted cost climbs past the gate
    drv.set_streams([StreamSpec(demand=demand_api.uniform(cat),
                                rate=5.0, seed=99)])
    st2 = drv.run(600)
    drv.drain_refresh()
    assert st2.refresh_triggered > 0
    assert st2.refreshes_started == st2.refresh_triggered
    assert eng.swap_count > 0                # the drift solve swapped in
    # engine-level counters aggregate both phases
    assert eng.stats.refresh_skipped >= st1.refresh_skipped
    assert eng.stats.refresh_triggered == st2.refresh_triggered


def test_refresh_gate_off_by_default():
    """refresh_min_gain = 0 keeps the old behavior bit-for-bit: every
    cadence request starts a solve, nothing is skipped, and no
    surrogate is ever evaluated on the request path."""
    eng, cfg, cat = make_engine(netduel=False)
    assert eng.ecfg.refresh_min_gain == 0.0
    drv = StreamDriver(eng, _streams(cat), max_batch=32,
                       batch_window=2.0, refresh_every=4)
    drv.run(64)
    eng.refresh_placement()
    st = drv.run(256)
    drv.drain_refresh()
    assert st.refreshes_started > 0
    assert st.refresh_skipped == 0 and st.refresh_triggered == 0
    assert eng._surrogate_baseline is None


def test_refresh_gate_no_serving_cost_regression():
    """Skipping solves must not cost serving quality: on the same
    stationary trace, the gated engine's mean per-request cost stays
    within 5% of the always-refresh engine's (their placements solve
    the same converging demand window, so skipped solves were
    redundant)."""
    costs = {}
    for gain in (0.0, 10.0):
        eng, cfg, cat = make_engine(netduel=False, refresh_min_gain=gain)
        drv = StreamDriver(eng, _streams(cat), max_batch=32,
                           batch_window=2.0, refresh_every=4)
        drv.run(300)
        eng.refresh_placement()
        drv.run(500)
        drv.drain_refresh()
        costs[gain] = eng.stats.mean_cost
    assert costs[10.0] <= costs[0.0] * 1.05, \
        f"gated serving cost {costs[10.0]:.3f} regressed vs " \
        f"always-refresh {costs[0.0]:.3f}"


# ===================================================================
# bounded latency window
# ===================================================================
def test_latency_ring_is_bounded_with_correct_percentiles():
    """The unbounded-list leak fix: ServeStats / DriverStats keep the
    newest LATENCY_WINDOW batch latencies only, and the percentiles are
    computed over exactly that window (a long run's early samples age
    out instead of accumulating forever)."""
    from repro.serve.engine import LATENCY_WINDOW, ServeStats
    from repro.serve.stream import DriverStats

    for stats in (ServeStats(), DriverStats()):
        ring = stats.batch_latencies_ms
        assert ring.maxlen == LATENCY_WINDOW
        n_extra = 5000
        for v in range(LATENCY_WINDOW + n_extra):   # a very long run
            ring.append(float(v))
        assert len(ring) == LATENCY_WINDOW          # memory stays O(1)
        # the window holds [n_extra, LATENCY_WINDOW + n_extra): the
        # percentiles must reflect the survivors, not the aged-out head
        assert stats.latency_percentile(0) == float(n_extra)
        assert stats.p50_ms == pytest.approx(
            n_extra + (LATENCY_WINDOW - 1) / 2.0)
        assert stats.latency_percentile(100) \
            == float(LATENCY_WINDOW + n_extra - 1)
        assert stats.p99_ms <= stats.latency_percentile(100)


def test_latency_window_served_engine_appends_bounded():
    """End to end: every served batch appends one latency sample into
    the bounded ring (same count as before the fix on short runs)."""
    eng, cfg, cat = make_engine(netduel=False)
    batches = mixed_batches(cat, cfg, [16] * 6)
    for ids, prompts in batches:
        eng.serve(ids, prompts)
    assert len(eng.stats.batch_latencies_ms) == 6
    assert eng.stats.p99_ms >= eng.stats.p50_ms > 0.0
