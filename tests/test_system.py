"""End-to-end behaviour tests for the paper's system: placement control
plane → runtime cache network → model serving, plus the training loop.

(The per-subsystem suites live in the sibling test modules; this file
exercises the composed system the way examples/ do, with assertions.)
"""
import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import catalog, demand, topology
from repro.core.objective import Instance
from repro.core.placement import greedy, greedy_then_localswap, localswap
from repro.core.simcache import SimCacheNetwork
from repro.configs.registry import get_smoke_config
from repro.data.pipeline import SyntheticLMData
from repro.models import model as model_api
from repro.optim import AdamWConfig
from repro.train import TrainConfig, train


def test_placement_to_dataplane_roundtrip():
    """Offline C(A) == empirical cost of the runtime cache serving the
    full demand-weighted request set (eq. (2) both ways)."""
    cat = catalog.grid(L=20)
    net = topology.tandem(k_leaf=12, k_parent=12, h=3.0, h_repo=25.0)
    dem = demand.gaussian_grid(cat, sigma=4.0)
    inst = Instance(net=net, cat=cat, dem=dem)
    st = greedy_then_localswap(inst, max_passes=6)
    offline = st.cost(inst)
    sc = SimCacheNetwork.from_placement(
        cat.coords, st.slots, inst.slot_cache, hs=[0.0, 3.0], h_repo=25.0,
        metric="l1", gamma=1.0)
    res = sc.lookup(jnp.asarray(cat.coords))
    empirical = float(np.sum(dem.lam[0] * np.asarray(res.cost)))
    assert abs(empirical - offline) < 1e-3 * max(offline, 1.0)
    # and the allocation actually beats no cache
    assert offline < inst.empty_cost() * 0.25


def test_full_pipeline_cost_ordering():
    """Across algorithms, the end-to-end ordering of Fig 3 holds on a
    fresh instance (cascade ≤ greedy; localswap close)."""
    cat = catalog.grid(L=16)
    net = topology.tandem(k_leaf=8, k_parent=8, h=2.0, h_repo=20.0)
    dem = demand.gaussian_grid(cat, sigma=3.0)
    inst = Instance(net=net, cat=cat, dem=dem)
    c_greedy = inst.total_cost(greedy(inst))
    c_ls = localswap(inst, n_iters=6000, seed=0).cost(inst)
    c_casc = greedy_then_localswap(inst, max_passes=6).cost(inst)
    assert c_casc <= c_greedy + 1e-9
    assert c_ls <= c_greedy * 1.05


def test_train_then_serve_smoke(tmp_path):
    """Train a tiny LM a few steps, then serve it behind the cache
    network — the full framework path in one test."""
    cfg = dataclasses.replace(
        get_smoke_config("granite-3-2b"), n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=256)
    tcfg = TrainConfig(steps=20, ckpt_dir=str(tmp_path), ckpt_every=10,
                       log_every=100, opt=AdamWConfig(lr=1e-3))
    data = SyntheticLMData(vocab=cfg.vocab, batch=4, seq=32)
    out = train(cfg, tcfg, data, log=lambda *a: None)
    assert np.isfinite(out["losses"][-1])

    from repro.core import catalog as catalog_api
    from repro.serve import EngineConfig, SimCacheEngine
    cat = catalog_api.embedding_catalog(n=200, dim=8, seed=0)
    eng = SimCacheEngine(cfg, out["params"],
                         EngineConfig(k_device=8, k_pod=16, k_global=16,
                                      h_ici=1.0, h_dcn=5.0, h_model=50.0),
                         cat.coords)
    rng = np.random.default_rng(0)
    dem = demand.zipf(cat, alpha=1.2, seed=1)
    for _ in range(4):
        ids, _ = dem.sample(8, rng)
        prompts = jnp.asarray(rng.integers(0, cfg.vocab, (8, 8)),
                              dtype=jnp.int32)
        eng.serve(ids, prompts)
    eng.refresh_placement()
    eng.stats = type(eng.stats)()
    for _ in range(6):
        ids, _ = dem.sample(8, rng)
        prompts = jnp.asarray(rng.integers(0, cfg.vocab, (8, 8)),
                              dtype=jnp.int32)
        eng.serve(ids, prompts)
    assert eng.stats.hit_rate > 0.3
    assert eng.stats.mean_cost < 50.0           # beats all-repository


def test_int8_kv_decode_close_to_bf16():
    """The int8 KV cache (serving memory optimization, §Perf cell C)
    decodes within quantization tolerance of the bf16 cache."""
    cfg = get_smoke_config("granite-3-2b")
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    params = model_api.init_params(cfg, 0)
    rng = np.random.default_rng(1)
    B, S = 2, 24
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))
    import jax
    from repro.models import transformer
    full, _, _ = transformer.forward(cfg, params, {"tokens": toks},
                                     mode="train")
    Sp = S - 4
    _, caches = jax.jit(model_api.make_prefill(cfg8))(
        params, {"tokens": toks[:, :Sp]})
    caches = model_api._pad_caches(cfg8, caches, S)
    step = jax.jit(model_api.make_serve_step(cfg8))
    errs = []
    for t in range(4):
        lg, caches = step(params, toks[:, Sp + t:Sp + t + 1], caches, Sp + t)
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, Sp + t]))))
    assert max(errs) < 0.05, errs
