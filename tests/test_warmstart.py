"""Gap-measurement + structural suite for the §4 warm-start pipeline
(core/placement/warmstart.py) — the lock on the near-O(O) placement
path.

Three layers:

1. **Measured optimality gaps** vs ``device_greedy`` at O ∈ {10³, 10⁴}
   on the three reducible topology classes (3-cache chain, leaf-fed
   tandem, equi-depth tree — grid catalogs with Gaussian demand, the
   §6.1 regime the continuous limit models). The asserted bounds are
   *recorded measurements* (benchmarks/warmstart_bench.py is where they
   came from), not theory: the pipeline typically lands within ±2% of
   GREEDY and often beats it.
2. **Prop 4.2 structure**: after band-mapping, every object a chain
   cache stores has popularity rank inside that cache's (extended) band
   window — contiguity survives the discretization; and the analytic
   warm start + polish is never worse than a cold LOCALSWAP given the
   same swap window from random slots.
3. **Hypothesis-style invariants** over random chains / tandems / trees
   (classification, slot validity, determinism), plus a CI_FULL-gated
   10⁶-object run — the regime where no discrete solver can exist (the
   gain table alone would need O(O²) streamed distance work per pass).
"""
from __future__ import annotations

import functools
import math
import os

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import catalog as catalog_api
from repro.core import demand as demand_api
from repro.core import topology as topology_api
from repro.core.objective import DeviceInstance, Instance, random_slots
from repro.core.placement import warmstart as ws
from repro.core.placement.device import device_greedy, device_localswap
from repro.core.placement.localswap import localswap

FULL = bool(os.environ.get("CI_FULL"))

# Recorded measured-gap ceilings of warm-start+polish vs device_greedy
# (see benchmarks/warmstart_bench.py, results/bench/warmstart.json —
# observed gaps are ≤ ~4.5%, frequently negative).
GAP_BOUND = {"chain": 0.06, "tandem": 0.06, "tree": 0.06}
POLISH = {1024: 128, 10_000: 512}


def make_instance(topo: str, O: int, k: int = 64) -> Instance:
    """Same instances the bench measures: grid catalog (side √O),
    Gaussian demand, one of the three reducible topology classes."""
    L = math.isqrt(O)
    assert L * L == O
    cat = catalog_api.grid(L=L)
    if topo == "tandem":
        net = topology_api.tandem(k_leaf=k, k_parent=k, h=2.0,
                                  h_repo=100.0)
        dem = demand_api.gaussian_grid(cat, sigma=L / 4)
    elif topo == "chain":
        net = topology_api.chain(3, [k, k, k], [0.0, 2.0, 6.0], 100.0)
        dem = demand_api.gaussian_grid(cat, sigma=L / 4)
    else:
        net = topology_api.equi_depth_tree(branching=2, depth=1,
                                           k_per_level=[k, k],
                                           h_per_level=[0.0, 3.0],
                                           h_repo=100.0)
        dem = demand_api.gaussian_grid(cat, sigma=L / 4, n_ingress=2)
    return Instance(net=net, cat=cat, dem=dem)


@functools.lru_cache(maxsize=None)
def gap_point(topo: str, O: int):
    """(gap, report, inst) for one (topology, O) — cached so the gap,
    contiguity and cold-start tests share one solve + one greedy run."""
    inst = make_instance(topo, O)
    dinst = DeviceInstance.from_instance(inst)
    rep = ws.warm_start(inst, dinst=dinst, polish_iters=POLISH[O])
    g = device_greedy(dinst)
    cg = inst.total_cost(np.where(g < 0, 0, g))
    gap = (inst.total_cost(rep.slots) - cg) / cg
    return gap, rep, inst


# ===================================================================
# 1 · measured optimality gaps vs device_greedy
# ===================================================================
@pytest.mark.parametrize("topo", ["chain", "tandem", "tree"])
def test_gap_1e3(topo):
    gap, _, _ = gap_point(topo, 1024)
    assert gap <= GAP_BOUND[topo], \
        f"{topo}@1024: gap {gap:.3%} above recorded bound"


@pytest.mark.parametrize("topo", ["chain", "tandem", "tree"])
def test_gap_1e4(topo):
    gap, _, _ = gap_point(topo, 10_000)
    assert gap <= GAP_BOUND[topo], \
        f"{topo}@10⁴: gap {gap:.3%} above recorded bound"


def test_gap_shrinks_with_polish():
    """The analytic map alone overpays at small O (band-edge
    discretization); the bounded polish closes most of it."""
    gap, rep, inst = gap_point("tandem", 1024)
    pre = inst.total_cost(rep.slots_warm)
    post = inst.total_cost(rep.slots)
    assert post <= pre + 1e-9
    assert rep.n_swaps > 0


# ===================================================================
# 2 · Prop 4.2 structure after mapping
# ===================================================================
@pytest.mark.parametrize("topo", ["chain", "tandem", "tree"])
def test_bands_contiguous_after_mapping(topo):
    """Discrete Prop 4.2: each chain-position cache stores only objects
    whose popularity rank lies in its band's rank_window — the
    contiguous-band structure survives the discretization (checked on
    the pre-polish allocation; the polish is free to deviate where the
    discrete objective disagrees with the continuum)."""
    _, rep, inst = gap_point(topo, 1024)
    rank_of = np.empty(inst.cat.n, np.int64)
    rank_of[rep.order] = np.arange(inst.cat.n)
    slot_cache = inst.slot_cache
    for p, caches in enumerate(rep.groups):
        for j in caches:
            k = int(inst.net.capacities[j])
            lo, hi = ws.rank_window(inst.cat.n, int(rep.bounds[p]),
                                    int(rep.bounds[p + 1]), k)
            stored = rep.slots_warm[slot_cache == j]
            r = rank_of[stored]
            assert r.min() >= lo and r.max() < hi, \
                f"{topo} cache {j}: ranks [{r.min()},{r.max()}] escape " \
                f"band window [{lo},{hi})"
            assert len(np.unique(stored)) == k, "band fill not distinct"


@pytest.mark.parametrize("topo", ["chain", "tandem", "tree"])
def test_warm_polish_never_worse_than_cold_localswap(topo):
    """Same swap window, warm vs cold start: polishing the analytic
    placement must not lose to LOCALSWAP from random slots — the warm
    start is worth keeping, per-seed, not just on average."""
    _, rep, inst = gap_point(topo, 1024)
    dinst = DeviceInstance.from_instance(inst)
    cw = inst.total_cost(rep.slots)
    for seed in (0, 1):
        cold0 = random_slots(inst, np.random.default_rng(seed))
        st_ = device_localswap(dinst, n_iters=POLISH[1024], seed=0,
                               slots0=cold0)
        cc = inst.total_cost(np.where(st_.slots_np < 0, 0, st_.slots_np))
        assert cw <= cc + 1e-9 * max(1.0, abs(cc)), \
            f"{topo}: warm {cw:.4f} lost to cold seed {seed} {cc:.4f}"


# ===================================================================
# 3 · classification + random-instance invariants
# ===================================================================
def test_classify_chain_topologies():
    for net, n_path in (
            (topology_api.single_cache(32, 50.0), 1),
            (topology_api.tandem(8, 16, 2.0, 50.0), 2),
            (topology_api.chain(4, 8, 1.0, 50.0), 4),
            (topology_api.tpu_hierarchy(8, 12, 16, 0.5, 2.0, 30.0), 3)):
        red = ws.classify_topology(net)
        assert red is not None and red.kind == "chain"
        assert len(red.path) == n_path
        assert red.spec.hs == tuple(sorted(red.spec.hs))


def test_classify_tandem_both():
    net = topology_api.tandem_both(8, 16, 2.0, 50.0)
    red = ws.classify_topology(net, gamma=1.0)
    assert red.kind == "tandem_both"
    assert (red.leaf, red.parent) == (0, 1)
    assert (red.leaf_ingress, red.parent_ingress) == (0, 1)
    assert red.h == pytest.approx(2.0)


def test_classify_tree():
    net = topology_api.equi_depth_tree(branching=3, depth=2,
                                       k_per_level=[4, 8, 16],
                                       h_per_level=[0.0, 1.0, 3.0],
                                       h_repo=50.0)
    red = ws.classify_topology(net)
    assert red.kind == "tree"
    assert [len(lv) for lv in red.levels] == [9, 3, 1]
    assert red.spec.ks == (4.0, 8.0, 16.0)
    assert red.spec.hs == (0.0, 1.0, 3.0)


def test_classify_rejects_irregular_topologies():
    # unequal path costs across ingresses: not an equi-depth tree
    H = np.array([[0.0, 1.0, np.inf],
                  [0.0, np.inf, 5.0]], np.float32)
    net = topology_api.CacheNetwork(
        n_caches=3, capacities=np.array([8, 8, 8]),
        ingress=np.array([0, 1]), H=H,
        h_repo=np.array([50.0, 50.0], np.float32))
    assert ws.classify_topology(net) is None
    # non-uniform level capacity breaks Prop 4.4 replication
    H2 = np.array([[0.0, np.inf, 2.0],
                   [np.inf, 0.0, 2.0]], np.float32)
    net2 = topology_api.CacheNetwork(
        n_caches=3, capacities=np.array([8, 16, 8]),
        ingress=np.array([0, 1]), H=H2,
        h_repo=np.array([50.0, 50.0], np.float32))
    assert ws.classify_topology(net2) is None
    # warm_start surfaces the fallback contract as a ValueError
    cat = catalog_api.embedding_catalog(n=64, dim=4, seed=0)
    dem = demand_api.zipf(cat, alpha=1.0, n_ingress=2, seed=1)
    with pytest.raises(ValueError, match="discrete solvers"):
        ws.warm_start(Instance(net=net, cat=cat, dem=dem))


def _check_valid(inst, rep):
    K = inst.net.total_slots
    for slots in (rep.slots_warm, rep.slots):
        assert slots.shape == (K,)
        assert slots.min() >= 0 and slots.max() < inst.cat.n
    for j in range(inst.net.n_caches):
        stored = rep.slots_warm[inst.slot_cache == j]
        k = int(inst.net.capacities[j])
        assert len(stored) == k
        if k <= inst.cat.n:
            assert len(np.unique(stored)) == k


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), n_caches=st.integers(1, 4),
       alpha=st.sampled_from([0.5, 0.9, 1.2]))
def test_random_chain_invariants(seed, n_caches, alpha):
    """Random chains over random Zipf embedding catalogs: classification
    succeeds, every slot filled with a distinct in-range object, the
    pipeline is deterministic, and the result never loses to the empty
    allocation."""
    rng = np.random.default_rng(seed)
    O = int(rng.integers(50, 400))
    cat = catalog_api.embedding_catalog(n=O, dim=6, seed=seed)
    ks = rng.integers(4, max(6, O // 4), n_caches)
    hs = np.concatenate([[0.0], np.sort(rng.uniform(0.5, 20.0,
                                                    n_caches - 1))])
    net = topology_api.chain(n_caches, ks.tolist(), hs.tolist(), 100.0)
    dem = demand_api.zipf(cat, alpha=alpha, seed=seed + 1)
    inst = Instance(net=net, cat=cat, dem=dem)
    red = ws.classify_topology(inst.net, gamma=inst.cat.gamma)
    assert red.kind == "chain" and len(red.path) == n_caches
    rep = ws.warm_start(inst, polish_iters=64, device=False)
    _check_valid(inst, rep)
    assert inst.total_cost(rep.slots) <= inst.empty_cost() + 1e-9
    rep2 = ws.warm_start(inst, polish_iters=64, device=False)
    np.testing.assert_array_equal(rep.slots, rep2.slots)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000), branching=st.integers(2, 3),
       depth=st.integers(1, 2))
def test_random_tree_invariants(seed, branching, depth):
    rng = np.random.default_rng(seed)
    O = int(rng.integers(60, 300))
    cat = catalog_api.embedding_catalog(n=O, dim=5, seed=seed)
    ks = rng.integers(3, 12, depth + 1).tolist()
    hs = np.concatenate([[0.0], np.sort(rng.uniform(0.5, 8.0, depth))])
    net = topology_api.equi_depth_tree(branching, depth, ks, hs.tolist(),
                                       50.0)
    dem = demand_api.zipf(cat, alpha=0.8, n_ingress=net.n_ingress,
                          seed=seed + 1)
    inst = Instance(net=net, cat=cat, dem=dem)
    red = ws.classify_topology(inst.net)
    assert red.kind == "tree"
    assert [len(lv) for lv in red.levels] == \
        [branching ** (depth - d) for d in range(depth + 1)]
    rep = ws.warm_start(inst, polish_iters=48, device=False)
    _check_valid(inst, rep)
    assert inst.total_cost(rep.slots) <= inst.empty_cost() + 1e-9


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000),
       beta=st.sampled_from([0.25, 1.0, 4.0]))
def test_random_tandem_both_invariants(seed, beta):
    rng = np.random.default_rng(seed)
    O = int(rng.integers(64, 400))
    cat = catalog_api.embedding_catalog(n=O, dim=6, seed=seed)
    net = topology_api.tandem_both(int(rng.integers(4, 32)),
                                   int(rng.integers(4, 32)), 2.0, 60.0)
    dem = demand_api.zipf(cat, alpha=0.9, n_ingress=2, seed=seed + 1,
                          betas=np.array([1.0, beta]))
    inst = Instance(net=net, cat=cat, dem=dem)
    red = ws.classify_topology(inst.net, gamma=inst.cat.gamma)
    assert red.kind == "tandem_both"
    rep = ws.warm_start(inst, polish_iters=48, device=False)
    _check_valid(inst, rep)
    assert inst.total_cost(rep.slots) <= inst.empty_cost() + 1e-9


def test_small_catalog_wraps():
    """k > O: every object stored, duplicates legal, no −1 slots."""
    cat = catalog_api.grid(L=3)                   # 9 objects
    net = topology_api.tandem(k_leaf=16, k_parent=4, h=1.0, h_repo=20.0)
    dem = demand_api.uniform(cat)
    inst = Instance(net=net, cat=cat, dem=dem)
    rep = ws.warm_start(inst, polish_iters=0)
    assert rep.slots.shape == (20,)
    assert rep.slots.min() >= 0 and rep.slots.max() < 9
    leaf = rep.slots[inst.slot_cache == 0]
    assert set(leaf.tolist()) == set(range(9))    # wraps the catalog


# ===================================================================
# 4 · the 10⁶-object regime (CI_FULL nightly)
# ===================================================================
@pytest.mark.slow
@pytest.mark.skipif(not FULL, reason="10⁶-object run: CI_FULL=1 only")
def test_warmstart_1e6_objects():
    """The regime the pipeline exists for: 10⁶ objects, where the
    discrete solvers cannot run (no gain table can exist). Asserts the
    analytic pipeline completes, yields a valid Prop 4.2-banded
    allocation, and beats the empty allocation by the device (streamed)
    cost evaluator."""
    inst = make_instance("tandem", 1_000_000)
    rep = ws.warm_start(inst, polish_iters=0)
    _check_valid(inst, rep)
    rank_of = np.empty(inst.cat.n, np.int64)
    rank_of[rep.order] = np.arange(inst.cat.n)
    for p, caches in enumerate(rep.groups):
        for j in caches:
            k = int(inst.net.capacities[j])
            lo, hi = ws.rank_window(inst.cat.n, int(rep.bounds[p]),
                                    int(rep.bounds[p + 1]), k)
            r = rank_of[rep.slots_warm[inst.slot_cache == j]]
            assert r.min() >= lo and r.max() < hi
    dinst = DeviceInstance.from_instance(inst, materialize_ca=False)
    cost = dinst.total_cost(rep.slots)
    assert cost < inst.empty_cost()
    # near-O(O): the full solve+map runs in seconds, not GREEDY-hours
    assert rep.total_s < 60.0
