"""Distribution tests that need >1 device: run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the main pytest
process must keep seeing 1 device for the smoke tests)."""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_in_subprocess(body: str):
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        import jax.numpy as jnp
        import numpy as np
        assert jax.device_count() == 8
    """) + textwrap.dedent(body)
    env = dict(os.environ,
               PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_sharded_train_step_matches_single_device():
    """pjit train step on a 4×2 mesh must produce the same loss as the
    unsharded step (SPMD is semantics-preserving)."""
    run_in_subprocess("""
        import dataclasses
        from repro.configs.registry import get_smoke_config
        from repro.launch.mesh import make_debug_mesh
        from repro.launch.sharding import MeshShardPolicy
        from repro.models import model, schema
        from repro.models.sharding_api import NO_SHARD

        cfg = get_smoke_config("granite-3-2b")
        mesh = make_debug_mesh(4, 2)
        policy = MeshShardPolicy.create(cfg, mesh, "train")
        params = model.init_params(cfg, 0)
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32))),
                 "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)))}
        l_ref, _ = jax.jit(model.make_train_forward(cfg, NO_SHARD))(params, batch)
        with mesh:
            shard_tree = policy.param_sharding_tree(schema.param_schema(cfg))
            p_sh = jax.device_put(params, shard_tree)
            b_sh = jax.device_put(batch, policy.batch_sharding_tree(
                {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                 for k, v in batch.items()}))
            l_sh, _ = jax.jit(model.make_train_forward(cfg, policy))(p_sh, b_sh)
        err = abs(float(l_ref) - float(l_sh))
        assert err < 2e-3, (float(l_ref), float(l_sh))
        print("sharded == unsharded:", float(l_ref), float(l_sh))
    """)


def test_moe_expert_parallel_matches():
    run_in_subprocess("""
        from repro.configs.registry import get_smoke_config
        from repro.launch.mesh import make_debug_mesh
        from repro.launch.sharding import MeshShardPolicy
        from repro.models import model, schema
        from repro.models.sharding_api import NO_SHARD

        cfg = get_smoke_config("dbrx-132b")   # 4 experts, EP over model=2
        mesh = make_debug_mesh(4, 2)
        policy = MeshShardPolicy.create(cfg, mesh, "train")
        params = model.init_params(cfg, 0)
        rng = np.random.default_rng(1)
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32))),
                 "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)))}
        l_ref, _ = jax.jit(model.make_train_forward(cfg, NO_SHARD))(params, batch)
        with mesh:
            p_sh = jax.device_put(
                params, policy.param_sharding_tree(schema.param_schema(cfg)))
            l_sh, _ = jax.jit(model.make_train_forward(cfg, policy))(p_sh, batch)
        assert abs(float(l_ref) - float(l_sh)) < 2e-3
        print("EP ok", float(l_ref), float(l_sh))
    """)


def test_compressed_crosspod_mean():
    run_in_subprocess("""
        from repro.ft.compress import compressed_crosspod_mean
        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.standard_normal((16, 64)).astype(np.float32))
        with mesh:
            out = compressed_crosspod_mean({"g": g}, mesh)["g"]
        # replicated input → mean is the identity, up to int8 error
        rel = np.max(np.abs(np.asarray(out) - np.asarray(g))) / \
            np.max(np.abs(np.asarray(g)))
        assert rel < 0.02, rel
        print("compressed mean rel err", rel)
    """)


def test_elastic_remesh_restore():
    """Checkpoint on a 4×2 mesh, restore onto 2×4 and 8×1 — losses agree."""
    run_in_subprocess("""
        import tempfile
        from repro.checkpoint import save, restore_for_mesh
        from repro.configs.registry import get_smoke_config
        from repro.ft.elastic import plan_mesh, reshard_plan
        from repro.launch.sharding import MeshShardPolicy
        from repro.models import model, schema

        cfg = get_smoke_config("granite-3-2b")
        params = model.init_params(cfg, 0)
        d = tempfile.mkdtemp()
        save(d, 5, {"params": params})
        rng = np.random.default_rng(2)
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16))),
                 "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)))}
        losses = []
        for (nd, nm) in ((4, 2), (2, 4), (8, 1)):
            mesh = jax.make_mesh((nd, nm), ("data", "model"))
            policy = MeshShardPolicy.create(cfg, mesh, "train")
            tree = {"params": policy.param_sharding_tree(
                schema.param_schema(cfg))}
            step, state = restore_for_mesh(d, tree)
            assert step == 5
            with mesh:
                loss, _ = jax.jit(model.make_train_forward(cfg, policy))(
                    state["params"], batch)
            losses.append(float(loss))
        assert max(losses) - min(losses) < 2e-3, losses
        print("elastic restore ok", losses)
    """)


def test_decode_kv_seq_sharding():
    """Decode with the KV-cache sequence axis sharded over model must
    match unsharded decode (distributed flash-decode semantics)."""
    run_in_subprocess("""
        from repro.configs.registry import get_smoke_config
        from repro.launch.mesh import make_debug_mesh
        from repro.launch.sharding import MeshShardPolicy
        from repro.models import model, schema, transformer
        from repro.models.sharding_api import NO_SHARD

        cfg = get_smoke_config("granite-3-2b")
        params = model.init_params(cfg, 0)
        rng = np.random.default_rng(3)
        B, S = 4, 16
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))
        logits, caches = jax.jit(model.make_prefill(cfg))(
            params, {"tokens": toks[:, :S-1]})
        caches = model._pad_caches(cfg, caches, S)
        l_ref, _ = jax.jit(model.make_serve_step(cfg))(
            params, toks[:, S-1:], caches, S-1)

        mesh = make_debug_mesh(4, 2)
        policy = MeshShardPolicy.create(cfg, mesh, "decode")
        with mesh:
            p_sh = jax.device_put(
                params, policy.param_sharding_tree(schema.param_schema(cfg)))
            c_sh = jax.device_put(caches, policy.cache_sharding_tree(
                jax.eval_shape(lambda: caches)))
            l_sh, _ = jax.jit(model.make_serve_step(cfg, policy))(
                p_sh, toks[:, S-1:], c_sh, S-1)
        err = float(jnp.max(jnp.abs(l_ref - l_sh)))
        assert err < 2e-3, err
        print("kv_seq decode ok", err)
    """)
