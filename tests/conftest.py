"""Shared fixtures. NOTE: no XLA_FLAGS here — the default tier-1 pass
runs against the single real CPU device; only launch/dryrun.py forces
512 host devices (and does so before any jax import). scripts/ci.sh
adds a *second* pass that opts the whole suite into 8 forced host
devices (the in-process mesh tests in test_sharded_lookup.py are
skipif-gated on device_count ≥ 8 and only execute there); the suite is
green under both device counts.

Offline environments lack ``hypothesis``; rather than skipping the five
property-based modules wholesale, we install a minimal seeded-random
stand-in into sys.modules *before collection* (conftest imports first).
It covers exactly the API surface the suite uses — ``given`` with
keyword strategies, ``settings(max_examples=…, deadline=…)``,
``strategies.integers/sampled_from/booleans`` — drawing deterministic
examples from a per-test seeded RNG. Real hypothesis, when installed,
always wins.
"""
import functools
import inspect
import random
import sys
import types
import zlib

import numpy as np
import pytest


def _install_hypothesis_stub() -> None:
    try:
        import hypothesis  # noqa: F401  (real one available — use it)
        return
    except ImportError:
        pass

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def integers(min_value, max_value):
        return _Strategy(lambda r: r.randint(min_value, max_value))

    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda r: r.choice(elements))

    def booleans():
        return _Strategy(lambda r: bool(r.getrandbits(1)))

    def floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda r: r.uniform(min_value, max_value))

    class settings:
        """Decorator recording max_examples on the wrapped test."""
        def __init__(self, max_examples=20, **_kw):
            self.max_examples = max_examples

        def __call__(self, fn):
            fn._stub_max_examples = self.max_examples
            return fn

    class _UnsatisfiedAssumption(Exception):
        pass

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_stub_max_examples", 20)
                rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
                ran = 0
                for _ in range(n * 20):          # rejection budget
                    if ran == n:
                        break
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    try:
                        fn(*args, **kwargs, **drawn)
                    except _UnsatisfiedAssumption:
                        continue                 # reject example, redraw
                    except Exception as e:
                        # real hypothesis prints the falsifying example;
                        # surface the drawn kwargs the same way
                        e.args = (f"{e.args[0] if e.args else e!r}"
                                  f"\n[hypothesis-stub falsifying "
                                  f"example: {drawn}]",) + e.args[1:]
                        raise
                    ran += 1
                if ran == 0:
                    pytest.skip("stub: no example satisfied assume()")
            wrapper.hypothesis_stub = True
            # hide the drawn params from pytest's fixture resolution
            # (wraps copies __wrapped__, whose signature pytest follows)
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper
        return deco

    def assume(condition):
        """Reject the current drawn example (redrawn by given's loop),
        mirroring real hypothesis rather than skipping the whole test."""
        if not condition:
            raise _UnsatisfiedAssumption()

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.assume = assume
    mod.HealthCheck = types.SimpleNamespace(too_slow=None,
                                            filter_too_much=None,
                                            data_too_large=None)
    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.sampled_from = sampled_from
    st.booleans = booleans
    st.floats = floats
    mod.strategies = st
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st


_install_hypothesis_stub()


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: nightly/full-pass only (scripts/ci.sh deselects with "
        '-m "not slow"; CI_FULL=1 runs them)')


@pytest.fixture
def rng():
    return np.random.default_rng(0)


# --------------------------------------------------------------------------
# Shared helpers of the lookup differential suites (test_fused_lookup,
# test_sharded_lookup, test_lsh_pruning): one definition of "a random
# multi-level network" and of "two LookupResults agree".
def make_net(seed, sizes, hs, h_repo, metric="l2", gamma=1.0, d=6,
             empty=(), **kw):
    """Random multi-level SimCacheNetwork (levels in ``empty`` get the
    sentinel key of an empty level) plus the rng for query draws."""
    import jax.numpy as jnp

    from repro.core.simcache import (SENTINEL_COORD, CacheLevel,
                                     SimCacheNetwork)
    rng_ = np.random.default_rng(seed)
    levels = []
    for j, (k, h) in enumerate(zip(sizes, hs)):
        if j in empty:
            keys = np.full((1, d), SENTINEL_COORD, np.float32)
            vals = np.full((1,), -1, np.int32)
        else:
            keys = (rng_.standard_normal((k, d)) * 2).astype(np.float32)
            vals = rng_.integers(0, 10_000, k).astype(np.int32)
        levels.append(CacheLevel(keys=jnp.asarray(keys),
                                 values=jnp.asarray(vals), h=float(h)))
    return SimCacheNetwork(levels=levels, h_repo=float(h_repo),
                           metric=metric, gamma=gamma, **kw), rng_


def assert_results_equal(a, b, exact_cost=True):
    """Two LookupResults serve identical traffic: equal winners always,
    costs bitwise for γ = 1 (``exact_cost``) else to 1e-6 (FMA
    contraction may differ across kernels)."""
    for name in ("level", "slot", "payload", "hit"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a, name)), np.asarray(getattr(b, name)),
            err_msg=name)
    for name in ("cost", "approx_cost"):
        x, y = np.asarray(getattr(a, name)), np.asarray(getattr(b, name))
        if exact_cost:
            np.testing.assert_array_equal(x, y, err_msg=name)
        else:
            np.testing.assert_allclose(x, y, rtol=1e-6, atol=1e-6,
                                       err_msg=name)
