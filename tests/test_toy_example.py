"""Paper §3.4 toy example, reproduced exactly.

Five contents x1..x5 with C_a(x2,x3)=C_a(x3,x4)=0,
C_a(x1,x2)=C_a(x4,x5)=ε, all other pairs ∞ (costs symmetric).
λ3 > λ2 = λ4 > λ1 = λ5, repository cost h_s > 2ε.

Claims verified:
  1. single cache k=2: optimum {x2,x4}; GREEDY reaches {x3, x} with
     x ∈ {x1,x5} and is NOT locally optimal; LOCALSWAP reaches {x2,x4}.
  2. tandem k=1+1, h(1,2) small: optimal {(x4,1),(x2,2)} / {(x2,1),(x4,2)};
     GREEDY still picks x3 at the leaf; LocalSwap reaches an optimum.
  3. the paper's numeric regime h_s=1, h(1,2)=ε=4/9, λ=(1,4/3,·,4/3,1):
     {(x3,1),(x1/5,2)} are global minima, {(x4,1),(x2,2)}-type states are
     local minima; GREEDY finds a global optimum.
"""
import itertools

import numpy as np
import pytest

from repro.core import catalog, demand, topology
from repro.core.objective import Instance
from repro.core.placement import greedy, localswap, localswap_polish
from repro.core.placement.localswap import is_locally_optimal

BIG = np.float32(1e9)   # stand-in for the paper's infinite cost


def toy_ca(eps: float) -> np.ndarray:
    ca = np.full((5, 5), BIG, dtype=np.float32)
    np.fill_diagonal(ca, 0.0)
    for (i, j, v) in [(1, 2, 0.0), (2, 3, 0.0), (0, 1, eps), (3, 4, eps)]:
        ca[i, j] = ca[j, i] = v
    return ca


def make_instance(net, lam_rows, eps):
    cat = catalog.Catalog(coords=np.zeros((5, 1), np.float32))
    lam = np.asarray(lam_rows, dtype=np.float64)
    dem = demand.Demand(lam=lam / lam.sum())
    return Instance(net=net, cat=cat, dem=dem, ca_matrix=toy_ca(eps))


def brute_force_best(inst):
    best, arg = np.inf, None
    K = inst.net.total_slots
    for combo in itertools.product(range(5), repeat=K):
        c = inst.total_cost(np.array(combo, dtype=np.int64))
        if c < best - 1e-12:
            best, arg = c, combo
    return best, arg


class TestSingleCache:
    eps = 0.25
    lam = [[1.0, 4 / 3, 2.0, 4 / 3, 1.0]]

    def _inst(self):
        net = topology.single_cache(k=2, h_repo=1.0)  # h_s = 1 > 2ε
        return make_instance(net, self.lam, self.eps)

    def test_optimum_is_x2_x4(self):
        inst = self._inst()
        best, arg = brute_force_best(inst)
        assert sorted(arg) == [1, 3]

    def test_greedy_reaches_x3_plus_edge(self):
        inst = self._inst()
        slots = sorted(greedy(inst).tolist())
        assert slots in ([0, 2], [2, 4])

    def test_greedy_not_locally_optimal(self):
        inst = self._inst()
        assert not is_locally_optimal(inst, greedy(inst))

    def test_localswap_reaches_unique_local_optimum(self):
        inst = self._inst()
        st = localswap(inst, n_iters=4000, seed=3)
        assert sorted(st.slots.tolist()) == [1, 3]
        assert is_locally_optimal(inst, st.slots)

    def test_cost_ordering(self):
        inst = self._inst()
        g = inst.total_cost(greedy(inst))
        ls = localswap(inst, n_iters=4000, seed=0).cost(inst)
        assert ls < g


class TestTandemSmallH:
    """Tandem, h(1,2) small: optimal keeps the {x2,x4} structure split
    across the two caches; GREEDY still anchors on x3."""
    eps = 0.25
    h12 = 0.05
    lam = [[1.0, 4 / 3, 2.0, 4 / 3, 1.0]]

    def _inst(self):
        net = topology.tandem(k_leaf=1, k_parent=1, h=self.h12,
                              h_repo=1.0 + self.h12)
        return make_instance(net, self.lam, self.eps)

    def test_optimal_structure(self):
        inst = self._inst()
        _, arg = brute_force_best(inst)
        assert sorted(arg) == [1, 3]

    def test_greedy_keeps_x3_at_leaf(self):
        inst = self._inst()
        slots = greedy(inst)
        assert slots[0] == 2              # x3 at the leaf cache
        assert slots[1] in (0, 4)

    def test_localswap_reaches_optimum(self):
        inst = self._inst()
        st = localswap(inst, n_iters=6000, seed=1)
        best, _ = brute_force_best(inst)
        assert st.cost(inst) == pytest.approx(best, abs=1e-9)


class TestPaperNumericRegime:
    """h_s=1, h(1,2)=ε=4/9, λ1=λ5=1, λ2=λ4=4/3, λ3=2 (> λ2): the paper
    states {(x3,1),(x1,2)}/{(x3,1),(x5,2)} are global minima while the
    {(x2/x4)} configurations are only local minima; GREEDY succeeds."""
    eps = 4.0 / 9.0
    lam = [[1.0, 4 / 3, 2.0, 4 / 3, 1.0]]

    def _inst(self):
        net = topology.tandem(k_leaf=1, k_parent=1, h=self.eps,
                              h_repo=1.0 + self.eps)
        return make_instance(net, self.lam, self.eps)

    def test_global_minimum_is_x3_based(self):
        inst = self._inst()
        _, arg = brute_force_best(inst)
        assert arg[0] == 2 and arg[1] in (0, 4)

    def test_x2_x4_state_is_local_minimum(self):
        inst = self._inst()
        slots = np.array([3, 1], dtype=np.int64)      # (x4 leaf, x2 parent)
        assert is_locally_optimal(inst, slots)
        best, _ = brute_force_best(inst)
        assert inst.total_cost(slots) > best + 1e-6   # ...but not global

    def test_greedy_finds_global(self):
        inst = self._inst()
        best, _ = brute_force_best(inst)
        assert inst.total_cost(greedy(inst)) == pytest.approx(best, abs=1e-9)

    def test_localswap_can_stick_at_local_minimum(self):
        inst = self._inst()
        st = localswap_polish(inst, np.array([3, 1], dtype=np.int64))
        # started at the local min, polish must not escape (it's a fixed point)
        assert sorted(st.slots.tolist()) == [1, 3]
        assert st.n_swaps == 0
