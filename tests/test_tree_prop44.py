"""Beyond-paper experiment: Prop 4.4 checked in the DISCRETE system.

The paper proves (continuous limit) that in an equi-depth tree with
leaf-only arrivals and per-leaf rates β_ℓ·λ(x), the optimum replicates
one chain solution at every level. Here we verify the discrete analogue
empirically: solving the full tree with LOCALSWAP does not beat
replicating the chain solution by more than a small margin, and the
replicated solution is feasible/near-locally-optimal — evidence the
structure survives discretization (the paper only conjectures this via
the continuous argument).
"""
import numpy as np

from repro.core import catalog, demand, topology
from repro.core.objective import Instance
from repro.core.placement import greedy_then_localswap, localswap


def build_tree_and_chain(L=16, k=8, h=2.0, h_repo=30.0, betas=(1.0, 2.0)):
    cat = catalog.grid(L=L)
    base = demand.gaussian_grid(cat, sigma=L / 6).lam[0]

    tree = topology.equi_depth_tree(
        branching=2, depth=1, k_per_level=[k, k], h_per_level=[0.0, h],
        h_repo=h_repo)
    lam_tree = np.stack([b * base for b in betas])
    dem_tree = demand.Demand(lam=lam_tree / lam_tree.sum())
    inst_tree = Instance(net=tree, cat=cat, dem=dem_tree)

    chain = topology.tandem(k_leaf=k, k_parent=k, h=h, h_repo=h_repo)
    dem_chain = demand.Demand(lam=(base / base.sum())[None, :])
    inst_chain = Instance(net=chain, cat=cat, dem=dem_chain)
    return inst_tree, inst_chain, betas


def replicate_chain_solution(inst_tree, chain_slots, k):
    """chain slots [leaf | parent] → tree slots [leaf0 | leaf1 | root]."""
    leaf, parent = chain_slots[:k], chain_slots[k:]
    return np.concatenate([leaf, leaf, parent])


def test_replicated_chain_is_near_optimal_on_tree():
    inst_tree, inst_chain, betas = build_tree_and_chain()
    k = 8
    chain_sol = greedy_then_localswap(inst_chain, max_passes=8)
    rep_slots = replicate_chain_solution(inst_tree, chain_sol.slots, k)
    c_rep = inst_tree.total_cost(rep_slots)

    st = localswap(inst_tree, n_iters=12000, seed=0)
    c_free = st.cost(inst_tree)
    # free optimization may exploit discreteness a little, but Prop 4.4
    # says the replicated structure is the continuum optimum: ≤ ~10% gap
    assert c_rep <= c_free * 1.10, (c_rep, c_free)


def test_beta_scaling_preserves_allocation():
    """The optimal tree allocation must be invariant to the per-leaf β
    (the linearity argument in the Prop 4.4 proof): scaling one leaf's
    rate leaves the replicated solution's *relative* cost unchanged."""
    costs = {}
    for betas in ((1.0, 1.0), (1.0, 4.0)):
        inst_tree, inst_chain, _ = build_tree_and_chain(betas=betas)
        chain_sol = greedy_then_localswap(inst_chain, max_passes=8)
        rep = replicate_chain_solution(inst_tree, chain_sol.slots, 8)
        costs[betas] = inst_tree.total_cost(rep) / inst_tree.empty_cost()
    # normalized cost identical: degree-1 homogeneity in λ
    assert abs(costs[(1.0, 1.0)] - costs[(1.0, 4.0)]) < 1e-6


def test_tree_cost_homogeneous_in_lambda():
    """tree_cost (continuous Prop 4.4) is degree-1 homogeneous in λ —
    for both the threshold solver (exact, ~1e-6) and mirror descent
    (f32 fixed-iteration, ~2% slack). This is the property that lets
    the warm-start pipeline solve one aggregate-rate chain and
    replicate it across every cache of each tree level."""
    from repro.core.placement import continuous as cont
    rng = np.random.default_rng(4)
    lams = rng.gamma(2.0, 1.0, 30)
    betas = np.array([1.0, 0.5, 2.0])
    spec = cont.ChainSpec(ks=(12.0, 24.0), hs=(0.0, 1.5), h_repo=6.0,
                          gamma=1.0)
    for c_scale in (3.0, 0.25):
        c1 = cont.tree_cost(lams, betas, spec, use_thresholds=True)
        cs = cont.tree_cost(c_scale * lams, betas, spec,
                            use_thresholds=True)
        assert abs(cs - c_scale * c1) <= 1e-6 * c_scale * c1
    c1_md = cont.tree_cost(lams, betas, spec, use_thresholds=False)
    c3_md = cont.tree_cost(3.0 * lams, betas, spec, use_thresholds=False)
    assert abs(c3_md - 3.0 * c1_md) <= 2e-2 * 3.0 * c1_md
