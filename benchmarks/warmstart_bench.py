"""Warm-start pipeline benchmark: §4 continuous-limit placement at scale.

Measures, per topology class (3-cache chain, leaf-fed tandem, equi-depth
tree — all grid catalogs with Gaussian demand, the paper's §6.1 regime)
and catalog size O:

* the warm-start pipeline stages (classify+solve, band map, LOCALSWAP
  polish) — cold wall clock (compiles included) and steady-state;
* device-GREEDY steady-state at every O where it still runs
  (``GREEDY_MAX``), plus the measured optimality gap
  (C_warm − C_greedy)/C_greedy of warm-start+polish against it;
* at the FULL scale (``WARMSTART_BENCH_FULL=1`` / ``CI_FULL=1`` via
  scripts/ci.sh): the 10⁶-object run, where no discrete solver can run
  — the gain table alone would be O(O·J) per pass over streamed O(O²)
  distance tiles. The committed headline compares the full pipeline at
  10⁶ against device-GREEDY at its feasibility frontier (the largest
  benched O where it completes): the warm start must be ≥ 10× faster
  *while solving a 100× larger instance* (asserted in-bench, recorded
  in results/bench/warmstart.json).

Gap bounds asserted here mirror tests/test_warmstart.py's recorded
bounds — the bench is where they were measured.

  PYTHONPATH=src:. python benchmarks/warmstart_bench.py [--smoke]
  WARMSTART_BENCH_FULL=1 PYTHONPATH=src:. python benchmarks/warmstart_bench.py
"""
from __future__ import annotations

import argparse
import math
import os

import numpy as np

from benchmarks.common import csv_line, save_json, timed
from repro.core import catalog, demand, topology
from repro.core.objective import DeviceInstance, Instance
from repro.core.placement import warmstart as ws
from repro.core.placement.device import device_greedy, device_localswap

FULL = bool(os.environ.get("WARMSTART_BENCH_FULL"))

GREEDY_MAX = 10_000      # feasibility frontier: largest benched O where
#                          device-GREEDY completes in-budget (past it,
#                          each of its O(K) picks pays a full streamed
#                          gain pass — hours at 10⁶)
GAP_BOUND = 0.06         # measured-gap ceiling vs device-GREEDY, all
#                          topology classes, O ∈ {10³, 10⁴} (observed:
#                          warm+polish is typically *better* on grids)
MIN_FRONTIER_SPEEDUP = 10.0


def make_instance(topo: str, O: int, k: int = 64) -> Instance:
    """Grid catalog + Gaussian demand on one of the three §4 topology
    classes; O must be a perfect square (grid side L = √O)."""
    L = math.isqrt(O)
    assert L * L == O, f"O={O} not a perfect square"
    cat = catalog.grid(L=L)
    if topo == "tandem":
        net = topology.tandem(k_leaf=k, k_parent=k, h=2.0, h_repo=100.0)
        dem = demand.gaussian_grid(cat, sigma=L / 4)
    elif topo == "chain":
        net = topology.chain(3, [k, k, k], [0.0, 2.0, 6.0], 100.0)
        dem = demand.gaussian_grid(cat, sigma=L / 4)
    elif topo == "tree":
        net = topology.equi_depth_tree(branching=2, depth=1,
                                       k_per_level=[k, k],
                                       h_per_level=[0.0, 3.0],
                                       h_repo=100.0)
        dem = demand.gaussian_grid(cat, sigma=L / 4, n_ingress=2)
    else:
        raise ValueError(topo)
    return Instance(net=net, cat=cat, dem=dem)


def bench_point(topo: str, O: int, polish: int, k: int = 64) -> dict:
    """One (topology, O) measurement row."""
    inst = make_instance(topo, O, k=k)
    dinst = DeviceInstance.from_instance(inst)
    row = {"name": f"{topo}/O{O}", "topo": topo, "O": O, "k": k,
           "total_slots": int(inst.net.total_slots),
           "polish_iters": polish,
           "streamed_ca": bool(dinst.ca is None)}

    rep, cold = timed(ws.warm_start, inst, dinst=dinst,
                      polish_iters=polish)
    rep2, steady = timed(ws.warm_start, inst, dinst=dinst,
                         polish_iters=polish)
    assert np.array_equal(rep.slots, rep2.slots), "warm start nondeterministic"
    row.update(warm_cold_s=cold, warm_s=steady,
               solve_s=rep2.solve_s, map_s=rep2.map_s,
               polish_s=rep2.polish_s, n_swaps=rep2.n_swaps,
               cont_cost=rep2.cont_cost, kind=rep2.kind)

    # cost accounting: exact host f64 where C_a fits, streamed device
    # evaluator otherwise (the only path that exists at 10⁶)
    cost_of = inst.total_cost if dinst.ca is not None else dinst.total_cost
    row["warm_cost"] = float(cost_of(rep2.slots))
    row["warm_cost_premap"] = float(cost_of(rep2.slots_warm))

    if O <= GREEDY_MAX:
        device_greedy(dinst)                      # compile
        g, tg = timed(device_greedy, dinst)
        g = np.where(g < 0, 0, g)
        row["greedy_s"] = tg
        row["greedy_cost"] = float(cost_of(g))
        row["gap"] = (row["warm_cost"] - row["greedy_cost"]) \
            / row["greedy_cost"]
        row["speedup_matched"] = tg / steady
        assert row["gap"] <= GAP_BOUND, \
            f"{row['name']}: warm-start gap {row['gap']:.3%} exceeds " \
            f"{GAP_BOUND:.0%}"
    csv_line(f"warmstart/{row['name']}", steady * 1e6,
             f"gap={row.get('gap', float('nan')):.4f};"
             f"solve={rep2.solve_s:.3f}s;polish={rep2.polish_s:.3f}s")
    return row


def polish_sweep(O: int = 10_000, topo: str = "tandem") -> list[dict]:
    """Gap vs polish-window size at the frontier O — how much discrete
    cleanup the analytic map still needs (shrinks as O grows: the band
    map converges to the continuum optimum)."""
    inst = make_instance(topo, O)
    dinst = DeviceInstance.from_instance(inst)
    g = device_greedy(dinst)
    cg = inst.total_cost(np.where(g < 0, 0, g))
    rows = []
    for w in (0, 128, 512):
        rep, _ = timed(ws.warm_start, inst, dinst=dinst, polish_iters=w)
        rows.append({"name": f"polish_sweep/{topo}/O{O}/W{w}",
                     "W": w, "warm_s": rep.total_s,
                     "gap": (inst.total_cost(rep.slots) - cg) / cg})
        csv_line(rows[-1]["name"], rep.total_s * 1e6,
                 f"gap={rows[-1]['gap']:.4f}")
    return rows


def run(smoke: bool = False, full: bool = FULL) -> dict:
    out: dict = {"rows": [], "polish_sweep": [],
                 "greedy_max_O": GREEDY_MAX, "gap_bound": GAP_BOUND}
    sizes = [1024] if smoke else [1024, 10_000]
    polish = {1024: 128, 10_000: 512}
    for topo in ("tandem", "chain", "tree"):
        for O in sizes:
            out["rows"].append(bench_point(topo, O, polish[O]))
    if not smoke:
        out["polish_sweep"] = polish_sweep()

    if full:
        # 10⁶ objects: device-GREEDY cannot run (frontier is GREEDY_MAX);
        # the headline pipeline is the pure analytic placement (polish
        # W=0 — at 10⁶ the bands are ~10⁵ objects wide and the
        # discretization error the polish removes has vanished; the
        # polish-sweep rows above quantify that trend), plus an
        # informational small-window polish run recording what an O(K)
        # discrete cleanup costs at this scale.
        O_full = 1_000_000
        head = bench_point("tandem", O_full, polish=0)
        out["rows"].append(head)
        out["rows"].append(bench_point("tandem", O_full, polish=16))
        frontier = next(r for r in out["rows"]
                        if r["O"] == GREEDY_MAX and r["topo"] == "tandem")
        speedup = frontier["greedy_s"] / head["warm_s"]
        out["headline"] = {
            "what": "warm-start pipeline (solve+map+polish) at O=10⁶ vs "
                    "device-GREEDY at its feasibility frontier "
                    f"O={GREEDY_MAX} — the largest benched size where "
                    "GREEDY completes; the warm start solves a "
                    f"{O_full // GREEDY_MAX}× larger instance",
            "warm_1e6_s": head["warm_s"],
            "greedy_frontier_s": frontier["greedy_s"],
            "greedy_frontier_O": GREEDY_MAX,
            "speedup_vs_frontier": speedup,
            "greedy_1e6_projection_s":
                frontier["greedy_s"] * (O_full / GREEDY_MAX),
            "projection_note": "linear per-object extrapolation — a "
                               "lower bound; streamed C_a makes GREEDY "
                               "superlinear past CA_MATERIALIZE_MAX",
        }
        csv_line("warmstart/headline", head["warm_s"] * 1e6,
                 f"speedup_vs_frontier={speedup:.1f}x")
        assert speedup >= MIN_FRONTIER_SPEEDUP, \
            f"warm@1e6 only {speedup:.1f}x faster than greedy@frontier"

    save_json("warmstart.json", out)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="O=1024 rows only (the scripts/ci.sh gate)")
    args = ap.parse_args()
    r = run(smoke=args.smoke)
    print(f"{len(r['rows'])} rows -> results/bench/warmstart.json")
