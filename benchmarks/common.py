"""Shared benchmark scaffolding: instances, timing, CSV output."""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import catalog, demand, topology
from repro.core.objective import Instance

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "bench")


def out_path(name: str) -> str:
    os.makedirs(RESULTS, exist_ok=True)
    return os.path.join(RESULTS, name)


def save_json(name: str, obj) -> None:
    with open(out_path(name), "w") as f:
        json.dump(obj, f, indent=1)


def csv_line(name: str, us_per_call: float, derived: str = "") -> str:
    line = f"{name},{us_per_call:.1f},{derived}"
    print(line)
    return line


def timed(fn, *args, repeat: int = 1, **kw):
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt


def bench_jax(fn, *args, repeat: int = 3, **kw) -> float:
    """Steady-state seconds/call for a jax computation: one warmup call
    (trace + compile), then block_until_ready-timed repeats."""
    import jax
    jax.block_until_ready(fn(*args, **kw))
    t0 = time.perf_counter()
    for _ in range(repeat):
        jax.block_until_ready(fn(*args, **kw))
    return (time.perf_counter() - t0) / repeat


def lookup_recall(pruned, exact) -> float:
    """Fraction of queries whose pruned LookupResult found the exact
    winner (same payload at the same level) — the single definition of
    the benchmark recall column (tests assert the same criterion)."""
    same = (np.asarray(pruned.payload) == np.asarray(exact.payload)) \
        & (np.asarray(pruned.level) == np.asarray(exact.level))
    return float(np.mean(same))


def tandem_instance(L: int, sigma: float, h: float, k: int,
                    h_repo: float, gamma: float = 1.0) -> Instance:
    """The paper's §6.1 setup: L×L grid, Gaussian demand, tandem network."""
    cat = catalog.grid(L=L, gamma=gamma)
    net = topology.tandem(k_leaf=k, k_parent=k, h=h, h_repo=h_repo)
    dem = demand.gaussian_grid(cat, sigma=sigma)
    return Instance(net=net, cat=cat, dem=dem)


def tandem_both_instance(L: int, h: float, k: int, h_repo: float,
                         gamma: float = 1.0, sigma: float | None = None,
                         beta: float = 1.0) -> Instance:
    """§4.4/Fig 5-6: tandem with arrivals at both leaf and parent."""
    cat = catalog.grid(L=L, gamma=gamma)
    net = topology.tandem_both(k_leaf=k, k_parent=k, h=h, h_repo=h_repo)
    if sigma is None:
        dem = demand.uniform(cat, n_ingress=2, betas=np.array([1.0, beta]))
    else:
        dem = demand.gaussian_grid(cat, sigma=sigma, n_ingress=2,
                                   betas=np.array([1.0, beta]))
    return Instance(net=net, cat=cat, dem=dem)
