"""Render the §Dry-run / §Roofline tables from results/dryrun/*.json."""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(tag: str = "") -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        base = os.path.basename(path)[:-5]
        parts = base.split("__")
        if len(parts) < 3:
            continue
        mesh_part = parts[2]                   # "single" | "multi" [+ _tag]
        file_tag = mesh_part.split("_", 1)[1] if "_" in mesh_part else ""
        if file_tag != tag:
            continue
        with open(path) as f:
            rows.append(json.load(f))
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])
                             if r["shape"] in SHAPE_ORDER else 9,
                             r["mesh"]))
    return rows


def fmt_s(x: float) -> str:
    return f"{x:.3e}"


def bottleneck_note(r: dict) -> str:
    """One sentence per cell: what moves the dominant term down
    (validated levers from §Perf where available)."""
    arch, shape = r["arch"], r["shape"]
    dom = r["roofline"]["dominant"]
    moe = arch in ("jamba-1.5-large-398b", "dbrx-132b",
                   "granite-moe-3b-a800m")
    heads_bad = arch in ("deepseek-coder-33b", "phi3-medium-14b",
                         "qwen2-vl-7b", "whisper-small")
    small = arch in ("granite-3-2b", "xlstm-350m", "whisper-small",
                     "granite-moe-3b-a800m")
    if shape in ("decode_32k", "long_500k"):
        if dom == "collective_s":
            return ("replicate/TP-shard serving weights instead of FSDP "
                    "(+int8 KV to fit) — validated: →HBM floor")
        return "already at the weights+KV bandwidth floor"
    if dom == "compute_s" and moe:
        return ("gather MoE dispatch removes the one-hot einsum tax "
                "(validated: jamba 98→58 s compute)")
    if dom == "collective_s" and heads_bad:
        return ("seq-attention + Megatron SP replaces the batch "
                "round-trip (validated: ~15-25x fewer coll bytes)")
    if dom == "collective_s" and small:
        if arch == "xlstm-350m":
            return ("ZeRO-DP with batch spreading — plain SP refuted "
                    "(recurrent chunk scan crosses seq shards)")
        return ("16-way TP is over-wide for this d_model: ZeRO-DP+SP "
                "(validated: granite-moe 357→1.4 s)")
    if dom == "collective_s":
        return ("bf16 reduction flows + EP all-to-all dispatch "
                "(projected ~8x on the EP combine)")
    if dom == "memory_s":
        return ("flash/chunked attention removes unfused score traffic "
                "(next lever)")
    return "increase per-device arithmetic intensity (larger microbatch)"


def markdown_table(rows: list[dict], mesh: str = "single") -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | "
           "dominant | MODEL_FLOPS | useful ratio | roofline % | "
           "args GiB/dev | note |")
    sep = "|" + "---|" * 11
    lines = [hdr, sep]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                         f"— | — | — | — | SKIP: {r['reason'][:60]} |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                         f"— | — | — | — | FAILED |")
            continue
        ro = r["roofline"]
        mem = r.get("memory_analysis", {})
        args_gib = mem.get("argument_size_in_bytes", 0) / 2 ** 30
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(ro['compute_s'])} | "
            f"{fmt_s(ro['memory_s'])} | {fmt_s(ro['collective_s'])} | "
            f"{ro['dominant'].replace('_s', '')} | "
            f"{ro['model_flops']:.2e} | {ro['useful_flops_ratio']:.2f} | "
            f"{ro['roofline_fraction'] * 100:.1f} | {args_gib:.2f} | "
            f"{bottleneck_note(r)} |")
    return "\n".join(lines)


def optimized_rows() -> list[dict]:
    """All tagged (hillclimbed) cells, any tag."""
    import glob as g
    rows = []
    for path in sorted(g.glob(os.path.join(RESULTS, "*.json"))):
        base = os.path.basename(path)[:-5]
        parts = base.split("__")
        if len(parts) < 3 or "_" not in parts[2]:
            continue
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def run() -> dict:
    rows = load()
    ok = [r for r in rows if r["status"] == "ok"]
    failed = [r for r in rows if r["status"] == "failed"]
    skipped = [r for r in rows if r["status"] == "skipped"]
    print(f"roofline_table,0.0,ok={len(ok)};skip={len(skipped)};"
          f"failed={len(failed)}")
    for mesh in ("single", "multi"):
        path = os.path.join(RESULTS, f"table_{mesh}.md")
        with open(path, "w") as f:
            f.write(markdown_table(rows, mesh))
    opt = optimized_rows()
    hdr = ("| arch | shape | mesh | tag/policy | compute s | memory s | "
           "collective s | dominant | roofline % |\n|" + "---|" * 9)
    lines = [hdr]
    for r in sorted(opt, key=lambda x: (x["arch"], x["shape"])):
        if r.get("status") != "ok":
            continue
        ro = r["roofline"]
        pol = r.get("policy", {})
        tag = ",".join(f"{k}={v}" for k, v in pol.items()
                       if v not in (None, "tp", True, "einsum", "compute",
                                    False))
        args = r.get("memory_analysis", {}).get(
            "argument_size_in_bytes", 0) / 2 ** 30
        frac = f"{ro['roofline_fraction']*100:.1f}"
        if args > 16.0:
            frac += f" (INVALID: {args:.1f} GiB > HBM)"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {tag} | "
            f"{fmt_s(ro['compute_s'])} | {fmt_s(ro['memory_s'])} | "
            f"{fmt_s(ro['collective_s'])} | "
            f"{ro['dominant'].replace('_s','')} | {frac} |")
    with open(os.path.join(RESULTS, "table_optimized.md"), "w") as f:
        f.write("\n".join(lines))
    if failed:
        for r in failed:
            print(f"FAILED CELL: {r['arch']} × {r['shape']} × {r['mesh']}")
    return {"ok": len(ok), "skipped": len(skipped), "failed": len(failed)}


if __name__ == "__main__":
    run()
