"""Figs. 5–6: the tandem network with arrivals at BOTH nodes (§4.4).

Fig 5: LOCALSWAP parent allocation under Gaussian and Uniform traffic —
the parent now covers the center of the domain too (the Prop 4.2
threshold structure is lost); we record the parent's coverage of the
central region as the quantitative check. The warm-start pipeline's
eq (14)–(15) tandem-both reduction (core.placement.warmstart) is run
alongside it: its density map must reproduce the same center coverage.

Fig 6: uniform λ, total cost vs h for γ ∈ {0.5, 1, 2}: LOCALSWAP
(points) vs the shifted-tessellation continuous approximation (curves;
closed form for γ=1, numerical quadrature otherwise).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (csv_line, save_json, tandem_both_instance,
                               timed)
from repro.core.placement import continuous as cont
from repro.core.placement import warmstart as ws
from repro.core.placement import localswap


def _parent_center_coverage(inst, slots) -> float:
    """Fraction of central-quarter demand (leaf ingress) served by the
    parent cache — ≈0 in the leaf-fed tandem (Fig 4), >0 here (Fig 5)."""
    best1, arg1, _ = inst.best_two(slots)
    owner = np.where(arg1[0] >= 0, inst.slot_cache[arg1[0]], -1)
    c = inst.cat.coords
    center = c.mean(0)
    L = c.max(0) - c.min(0) + 1
    central = (np.abs(c - center) <= L / 8).all(axis=1)
    lam = inst.lam[0]
    mass = lam[central]
    return float(np.sum(mass * (owner[central] == 1)) / mass.sum())


def run(L: int = 40, k: int = 40, h_repo: float = 200.0,
        hs=(0.5, 1.0, 2.0, 3.0), gammas=(0.5, 1.0, 2.0),
        ls_iters: int = 12000) -> dict:
    out: dict = {"L": L, "k": k, "fig5": {}, "fig6": {}}

    # ---- Fig 5: allocations (gaussian + uniform) ----
    # the paper's Fig 5 sits in the h < r regime (z > 0: parent slots help
    # leaf arrivals); with our quick-mode k/L the cell radius is
    # r = sqrt(L²/2k) ≈ 4.5, so h = 1 keeps the regime (h = 3 would give
    # z ≈ 0.7 and a near-invisible shifted-tessellation effect)
    h_fig5 = 1.0
    for name, sigma in (("gaussian", L / 8), ("uniform", None)):
        inst = tandem_both_instance(L, h_fig5, k, h_repo, sigma=sigma)
        ls, tl = timed(lambda: localswap(inst, n_iters=ls_iters, seed=0))
        cov = _parent_center_coverage(inst, ls.slots)
        parent_pts = inst.cat.coords[ls.slots[inst.slot_cache == 1]]
        red = ws.classify_topology(inst.net, gamma=inst.cat.gamma)
        rep, tw = timed(lambda: ws.warm_start(inst, reduction=red,
                                              polish_iters=256,
                                              device=False))
        cov_ws = _parent_center_coverage(inst, rep.slots)
        out["fig5"][name] = {
            "cost": ls.cost(inst),
            "parent_center_coverage": cov,
            "parent_points": parent_pts.tolist(),
            "warmstart_cost": inst.total_cost(rep.slots),
            "warmstart_parent_center_coverage": cov_ws,
        }
        csv_line(f"fig5/{name}/localswap", tl * 1e6,
                 f"cost={ls.cost(inst):.4f};center_cov={cov:.3f}")
        csv_line(f"fig5/{name}/warmstart", tw * 1e6,
                 f"cost={out['fig5'][name]['warmstart_cost']:.4f};"
                 f"center_cov={cov_ws:.3f}")

    # ---- Fig 6: cost vs h per gamma, uniform traffic ----
    area = float(L * L)
    for gamma in gammas:
        rows = []
        for h in hs:
            inst = tandem_both_instance(L, h, k, h_repo, gamma=gamma)
            ls, tl = timed(lambda: localswap(inst, n_iters=ls_iters, seed=1))
            # continuous: shifted tessellations, per-request normalization
            # (demand sums to 1 over both ingresses → λ = 1/(2·area))
            lam_density = 1.0 / (2.0 * area)
            c_cont = cont.shifted_tessellation_cost_numeric(
                k=k, h=h, area=area, lam=lam_density, beta=1.0, gamma=gamma)
            rows.append({"h": h, "localswap": ls.cost(inst),
                         "continuous": c_cont, "t_localswap_s": tl})
            csv_line(f"fig6/g={gamma:g}/h={h:g}", tl * 1e6,
                     f"ls={rows[-1]['localswap']:.4f};cont={c_cont:.4f}")
        out["fig6"][f"gamma={gamma:g}"] = rows

    # checks: parent covers the center here (unlike the leaf-fed tandem);
    # continuous tracks localswap within 25% for γ=1 uniform
    g1 = out["fig6"]["gamma=1"]
    rel = float(np.mean([abs(r["continuous"] - r["localswap"])
                         / max(r["localswap"], 1e-12) for r in g1]))
    out["checks"] = {
        "parent covers center (uniform)":
            out["fig5"]["uniform"]["parent_center_coverage"] > 0.10,
        "warmstart parent covers center (uniform)":
            out["fig5"]["uniform"]["warmstart_parent_center_coverage"]
            > 0.10,
        "continuous tracks localswap (gamma=1)": rel < 0.25,
    }
    out["fig6_relgap_gamma1"] = rel
    save_json("fig56.json", out)
    return out


if __name__ == "__main__":
    r = run()
    print(r["checks"])
