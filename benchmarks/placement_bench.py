"""Placement control-plane benchmark: host NumPy oracles vs the
device-resident control plane (kernels/knn/gains.py + DeviceInstance +
the scanned loops of core/placement/{device,netduel}.py).

Rows:

* ``gain_oracle/O…`` — one full (O, J) marginal-gain evaluation (the
  per-step cost GREEDY/LOCALSWAP pay at refresh time) on a Zipf
  embedding instance, host ``Instance.add_gain_all`` (cached C_a
  matrix while it fits, streamed row blocks past
  ``objective.CA_MATERIALIZE_MAX``) vs ``DeviceInstance.gains``
  (streamed distance tiles, one jitted launch). ``device_quant_s``
  times the int8 upper-bound oracle (``gains(cur, quantize=True)`` —
  the bound lazy GREEDY re-scores exactly before accepting, so the
  allocation stays bit-identical). O ∈ {10³, 10⁴} by default;
  ``PLACEMENT_BENCH_FULL=1`` (the KERNEL_BENCH_FULL-style nightly
  gate, see scripts/ci.sh) adds the 10⁵ row, where the dense host C_a
  can no longer exist at all.
* ``greedy/O…`` — end-to-end GREEDY solve: host lazy heap vs the
  per-step device loop (one jit dispatch per pick — the path that was
  dispatch-bound below ~10³ candidates) vs the scanned device loop
  (PR 5: the whole accept loop is one ``lax.while_loop`` launch).
  Scanned == per-step bit-identically (asserted); vs the host,
  *serving-equivalence* (identical per-cache object sets) is asserted
  and bit-identity recorded where f32/f64 near-ties didn't reorder
  adjacent picks. ``speedup`` is host/scanned — the old 10³ crossover
  is gone.
* ``localswap/O…`` — a 2000-request emulated window: host per-request
  NumPy vs the scanned device window (one ``lax.scan`` launch instead
  of one jitted step per request); serving-equivalence asserted,
  bit-identity recorded. ``device_s`` is the incremental best-two
  path (delta re-arm after each accepted swap — the default);
  ``device_full_s`` keeps the old full O(O·K) rebuild per accept, and
  the two trajectories are asserted bitwise-equal.
* ``netduel/O…`` — a 4000-request online NETDUEL window: host f32
  reference vs the device scan. Bit-identical promotions/slots at the
  materialized-C_a size (asserted); the 10⁴ row runs the streamed
  shape-stable pricing; PLACEMENT_BENCH_FULL adds a device-only 10⁵
  row (no host C_a can exist there). Same ``device_s`` (incremental
  promotion re-arm) vs ``device_full_s`` (full rebuild) split.

Timings are CPU/interpret-grade (same caveat as kernel_bench.py): the
point is the host-vs-device *ratio* of the control plane, recorded in
results/bench/placement.json. Device rows are steady-state (one warmup
call first) — the jitted scans amortize their compile across refreshes
exactly like the data-plane kernels.
"""
from __future__ import annotations

import os

import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_jax, csv_line, save_json, timed
from repro.core import catalog, demand, topology
from repro.core.objective import DeviceInstance, Instance
from repro.core.placement import (device_greedy, device_localswap,
                                  device_netduel, greedy, localswap,
                                  netduel)


def make_instance(n: int, dim: int = 16, seed: int = 0,
                  k: int = 64) -> Instance:
    cat = catalog.embedding_catalog(n=n, dim=dim, seed=seed)
    net = topology.tandem(k_leaf=k, k_parent=k, h=50.0, h_repo=500.0)
    dem = demand.zipf(cat, alpha=0.8, seed=seed + 1)
    return Instance(net=net, cat=cat, dem=dem)


def initial_cur(inst: Instance) -> np.ndarray:
    return np.repeat(inst.net.h_repo[:, None].astype(np.float64),
                     inst.cat.n, axis=1)


def timed_warm(fn, *args, **kw):
    """(result, steady-state seconds): one warmup call (compile), then
    one timed call — the regime a rolling control plane actually runs
    in."""
    fn(*args, **kw)
    return timed(fn, *args, **kw)


def same_placement(inst: Instance, a: np.ndarray, b: np.ndarray):
    """(serving_equivalent, bit_identical). Slots within one cache are
    interchangeable — a cache serves its *set* — so two allocations
    with identical per-cache multisets serve identical traffic even
    when f32-vs-f64 near-ties ordered two adjacent picks differently."""
    bit = bool(np.array_equal(a, b))
    if bit:
        return True, True
    for j in range(inst.net.n_caches):
        sel = inst.slot_cache == j
        if sorted(a[sel]) != sorted(b[sel]):
            return False, False
    return True, False


def run() -> dict:
    rows = []
    sizes = [1_000, 10_000]
    full = bool(os.environ.get("PLACEMENT_BENCH_FULL"))
    if full:
        sizes.append(100_000)
    for n in sizes:
        inst = make_instance(n)
        cur = initial_cur(inst)
        if n <= 10_000:
            inst.ca                       # warm the cached C_a (host path)
        _, t_host = timed(inst.add_gain_all, cur)
        dinst = DeviceInstance.from_instance(inst, materialize_ca=False)
        cur_dev = jnp.asarray(cur, jnp.float32)
        t_dev = bench_jax(dinst.gains, cur_dev,
                          repeat=3 if n <= 10_000 else 1)
        t_quant = bench_jax(lambda c: dinst.gains(c, quantize=True),
                            cur_dev, repeat=3 if n <= 10_000 else 1)
        name = f"gain_oracle/O{n}_J2_D16"
        rows.append({"name": name, "host_s": t_host, "device_s": t_dev,
                     "device_quant_s": t_quant,
                     "speedup": t_host / t_dev,
                     "quant_speedup": t_dev / t_quant})
        csv_line(name, t_dev * 1e6,
                 f"host_s={t_host:.3f},speedup={t_host/t_dev:.1f}x,"
                 f"quant_s={t_quant:.3f}"
                 f"({t_dev/t_quant:.2f}x vs exact device)")

    # end-to-end GREEDY, 128 picks. The per-step device loop is
    # dispatch-bound at 10³ candidates (one jit dispatch per pick); the
    # scanned while_loop launch removes that bound — no crossover left.
    for n in (1_000, 10_000):
        inst = make_instance(n)
        hs, t_hg = timed(greedy, inst)
        dinst = DeviceInstance.from_instance(inst, materialize_ca=False)
        ds_step, t_step = timed_warm(device_greedy, dinst, scan=False)
        ds_scan, t_scan = timed_warm(device_greedy, dinst, scan=True)
        assert np.array_equal(ds_step, ds_scan), \
            "scanned greedy diverged from the per-step device path"
        equiv, bit = same_placement(inst, hs, ds_scan)
        assert equiv, "device allocation diverged from host"
        name = f"greedy/O{n}_K128"
        rows.append({"name": name, "host_s": t_hg,
                     "device_stepped_s": t_step, "device_s": t_scan,
                     "speedup": t_hg / t_scan, "allocations_equal": bit,
                     "serving_equivalent": True})
        csv_line(name, t_scan * 1e6,
                 f"host_s={t_hg:.3f},stepped_s={t_step:.3f},"
                 f"speedup={t_hg/t_scan:.1f}x,"
                 + ("bit_identical" if bit else "serving_equivalent"))

    # LOCALSWAP: one 2000-request emulated window, host per-request vs
    # one scanned launch (identical stream, tol, trajectory).
    for n in (1_000, 10_000):
        inst = make_instance(n)
        inst.ca
        tol = 1e-5
        hsw, t_hl = timed(localswap, inst, n_iters=2000, seed=7, tol=tol)
        dinst = DeviceInstance.from_instance(inst, materialize_ca=False)
        dsw_step, t_step = timed_warm(device_localswap, dinst,
                                      n_iters=2000, seed=7, tol=tol,
                                      scan=False)
        dsw_full, t_full = timed_warm(device_localswap, dinst,
                                      n_iters=2000, seed=7, tol=tol,
                                      scan=True, incremental=False)
        dsw, t_dl = timed_warm(device_localswap, dinst, n_iters=2000,
                               seed=7, tol=tol, scan=True)
        assert np.array_equal(dsw_step.slots_np, dsw.slots_np), \
            "scanned LOCALSWAP diverged from the per-step device path"
        assert np.array_equal(dsw_full.slots_np, dsw.slots_np) \
            and dsw_full.n_swaps == dsw.n_swaps, \
            "incremental LOCALSWAP diverged from the full-rebuild path"
        equiv, bit = same_placement(inst, hsw.slots, dsw.slots_np)
        assert equiv, "device LOCALSWAP trajectory diverged from host"
        name = f"localswap/O{n}_T2000"
        rows.append({"name": name, "host_s": t_hl,
                     "device_stepped_s": t_step,
                     "device_full_s": t_full, "device_s": t_dl,
                     "speedup": t_hl / t_dl,
                     "stepped_speedup": t_step / t_dl,
                     "incremental_speedup": t_full / t_dl,
                     "n_swaps": int(dsw.n_swaps),
                     "allocations_equal": bit, "serving_equivalent": True})
        csv_line(name, t_dl * 1e6,
                 f"host_s={t_hl:.3f},stepped_s={t_step:.3f},"
                 f"full_s={t_full:.3f},speedup={t_hl/t_dl:.1f}x,"
                 f"incremental={t_full/t_dl:.2f}x,swaps={dsw.n_swaps},"
                 + ("bit_identical" if bit else "serving_equivalent"))

    # NETDUEL: a 4000-request online window in one scan launch. The 10³
    # row materializes C_a → bit-identical promotions asserted; the 10⁴
    # row uses streamed shape-stable pricing (host still indexes its
    # dense matrix), so the trajectories can drift at f32 near-ties —
    # there the *outcome* is asserted instead: both final placements
    # must land within 10% of each other's total cost.
    duel_sizes = [1_000, 10_000] + ([100_000] if full else [])
    for n in duel_sizes:
        inst = make_instance(n)
        kw = dict(n_iters=4000, seed=0, window=500, arm_prob=0.3)
        materialize = n <= 1_000
        dinst = DeviceInstance.from_instance(inst,
                                             materialize_ca=materialize)
        std_full, t_df = timed_warm(device_netduel, dinst,
                                    record_events=materialize,
                                    incremental=False, **kw)
        std, t_dd = timed_warm(device_netduel, dinst,
                               record_events=materialize, **kw)
        assert np.array_equal(std_full.slots, std.slots) \
            and std_full.n_promotions == std.n_promotions, \
            "incremental NETDUEL diverged from the full-rebuild path"
        row = {"name": f"netduel/O{n}_T4000", "device_s": t_dd,
               "device_full_s": t_df,
               "incremental_speedup": t_df / t_dd,
               "n_promotions": int(std.n_promotions)}
        if n <= 10_000:
            inst.ca
            sth, t_hd = timed(netduel, inst, **kw)
            c_h = inst.total_cost(sth.sw.slots)
            c_d = inst.total_cost(std.slots)
            assert c_d <= 1.1 * c_h and c_h <= 1.1 * c_d, \
                "device NETDUEL outcome diverged from host"
            row.update(host_s=t_hd, speedup=t_hd / t_dd,
                       host_cost=c_h, device_cost=c_d)
            if materialize:
                assert np.array_equal(sth.sw.slots, std.slots) \
                    and sth.promotions == std.promotions, \
                    "device NETDUEL trajectory diverged from host"
                row["bit_identical"] = True
            derived = f"host_s={t_hd:.3f},speedup={t_hd/t_dd:.1f}x," \
                      f"incremental={t_df/t_dd:.2f}x," \
                      f"promos={std.n_promotions}"
        else:
            derived = f"device_only,incremental={t_df/t_dd:.2f}x," \
                      f"promos={std.n_promotions}"
        rows.append(row)
        csv_line(row["name"], t_dd * 1e6, derived)

    save_json("placement.json", rows)
    return {"rows": rows}


if __name__ == "__main__":
    run()
