"""Placement control-plane benchmark: host NumPy oracles vs the
device-resident gain oracle (kernels/knn/gains.py + DeviceInstance).

Rows:

* ``gain_oracle/O…`` — one full (O, J) marginal-gain evaluation (the
  per-step cost GREEDY/LOCALSWAP pay at refresh time) on a Zipf
  embedding instance, host ``Instance.add_gain_all`` (cached C_a
  matrix while it fits, streamed row blocks past
  ``objective.CA_MATERIALIZE_MAX``) vs ``DeviceInstance.gains``
  (streamed distance tiles, one jitted launch). O ∈ {10³, 10⁴} by
  default; ``PLACEMENT_BENCH_FULL=1`` (the KERNEL_BENCH_FULL-style
  nightly gate, see scripts/ci.sh) adds the 10⁵ row, where the dense
  host C_a can no longer exist at all.
* ``greedy/O…`` — end-to-end GREEDY solve, host lazy heap vs device
  batched lazy (bit-identical allocations, asserted).

Timings are CPU/interpret-grade (same caveat as kernel_bench.py): the
point is the host-vs-device *ratio* of the control plane, recorded in
results/bench/placement.json.
"""
from __future__ import annotations

import os

import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_jax, csv_line, save_json, timed
from repro.core import catalog, demand, topology
from repro.core.objective import DeviceInstance, Instance
from repro.core.placement import device_greedy, greedy


def make_instance(n: int, dim: int = 16, seed: int = 0,
                  k: int = 64) -> Instance:
    cat = catalog.embedding_catalog(n=n, dim=dim, seed=seed)
    net = topology.tandem(k_leaf=k, k_parent=k, h=50.0, h_repo=500.0)
    dem = demand.zipf(cat, alpha=0.8, seed=seed + 1)
    return Instance(net=net, cat=cat, dem=dem)


def initial_cur(inst: Instance) -> np.ndarray:
    return np.repeat(inst.net.h_repo[:, None].astype(np.float64),
                     inst.cat.n, axis=1)


def run() -> dict:
    rows = []
    sizes = [1_000, 10_000]
    if os.environ.get("PLACEMENT_BENCH_FULL"):
        sizes.append(100_000)
    for n in sizes:
        inst = make_instance(n)
        cur = initial_cur(inst)
        if n <= 10_000:
            inst.ca                       # warm the cached C_a (host path)
        _, t_host = timed(inst.add_gain_all, cur)
        dinst = DeviceInstance.from_instance(inst, materialize_ca=False)
        cur_dev = jnp.asarray(cur, jnp.float32)
        t_dev = bench_jax(dinst.gains, cur_dev,
                          repeat=3 if n <= 10_000 else 1)
        name = f"gain_oracle/O{n}_J2_D16"
        rows.append({"name": name, "host_s": t_host, "device_s": t_dev,
                     "speedup": t_host / t_dev})
        csv_line(name, t_dev * 1e6,
                 f"host_s={t_host:.3f},speedup={t_host/t_dev:.1f}x")
    # end-to-end GREEDY, 128 picks: at 10³ candidates the host lazy heap
    # wins (the device loop is jit-dispatch-bound), at 10⁴ the oracle
    # cost dominates and the device path takes over — recorded at both
    # sizes so the crossover is visible.
    for n in (1_000, 10_000):
        inst = make_instance(n)
        hs, t_hg = timed(greedy, inst)
        dinst = DeviceInstance.from_instance(inst, materialize_ca=False)
        ds, t_dg = timed(device_greedy, dinst)
        assert np.array_equal(hs, ds), "device allocation diverged from host"
        name = f"greedy/O{n}_K128"
        rows.append({"name": name, "host_s": t_hg, "device_s": t_dg,
                     "speedup": t_hg / t_dg, "allocations_equal": True})
        csv_line(name, t_dg * 1e6,
                 f"host_s={t_hg:.3f},speedup={t_hg/t_dg:.1f}x,bit_identical")
    save_json("placement.json", rows)
    return {"rows": rows}


if __name__ == "__main__":
    run()
