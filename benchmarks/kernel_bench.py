"""Kernel micro-benchmarks (interpret mode on CPU: correctness-grade
timings; the derived column reports achieved GB/s and GFLOP/s as a
plausibility anchor, not TPU performance).

The sharded_lookup rows shard the fused segmented key tensor over every
available device (run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for an 8-way
mesh; on one device the row degenerates to a 1-shard mesh and measures
pure shard_map + reduction overhead).

The pruned_lookup rows measure the LSH / k-means candidate pre-filter
(kernels/knn/lsh.py) against the exact fused scan on Zipf-weighted
query batches (repeated popular items + small noise — the paper's
workload shape), recording achieved recall next to the speedup. The
10⁶-key rows multiply the exact-scan baseline cost by ~10×; opt in with
``KERNEL_BENCH_FULL=1`` (the nightly/full configuration).

The quantized_lookup rows measure the int8 first-pass path
(kernels/quant.py): a full-width XLA lower-bound scan cuts the key set
to top-T per query, and only the union is re-scored through the exact
fused kernel. ``verify`` rows re-scan certificate misses and are exact
bit-for-bit; ``recall`` reports how often the unverified winner already
is the exact one. The quant_prune row composes both cuts (LSH gather →
int8 sub-cut). Rows where the quantized path does *not* win (small key
counts, where the exact scan is already one cheap launch) are recorded
alongside the wins — the speedup column is honest, not curated.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_jax as _bench
from benchmarks.common import csv_line, lookup_recall, save_json
from repro.core.simcache import CacheLevel, SimCacheNetwork
from repro.kernels.gain import greedy_gain
from repro.kernels.knn import (KMeansPolicy, SimHashPolicy,
                               nearest_approximizer)
from repro.launch.mesh import make_lookup_mesh


def run() -> dict:
    rng = np.random.default_rng(0)
    rows = []
    for (Q, K, D, metric) in [(1024, 4096, 128, "l2"),
                              (1024, 4096, 2, "l1"),
                              (4096, 16384, 100, "l2sq")]:
        q = jnp.asarray(rng.standard_normal((Q, D)).astype(np.float32))
        k = jnp.asarray(rng.standard_normal((K, D)).astype(np.float32))
        dt = _bench(nearest_approximizer, q, k, metric=metric)
        flops = 2.0 * Q * K * D if metric != "l1" else 3.0 * Q * K * D
        name = f"knn/Q{Q}_K{K}_D{D}_{metric}"
        rows.append({"name": name, "us": dt * 1e6,
                     "gflops": flops / dt / 1e9})
        csv_line(name, dt * 1e6, f"gflops={flops/dt/1e9:.1f}")
    # fused network-wide lookup (one pallas_call) vs the per-level loop:
    # the O(L) kernel-launch + host stack/argmin overhead it removes
    # grows with depth, so the speedup is reported per level count.
    # K_j = 64 is the engine's device-level slot count — each looped
    # launch pads its level to the 256-key block alone, while the fused
    # scan pads the ΣK_j concatenation once.
    n_dev = jax.device_count()
    mesh = make_lookup_mesh(n_dev)
    for L in (2, 4, 8):
        Q, Kj, D = 512, 64, 64
        levels = [CacheLevel(
            keys=jnp.asarray(rng.standard_normal((Kj, D))
                             .astype(np.float32)),
            values=jnp.asarray(rng.integers(0, 10_000, Kj)
                               .astype(np.int32)),
            h=0.1 * j) for j in range(L)]
        q = jnp.asarray(rng.standard_normal((Q, D)).astype(np.float32))
        net = SimCacheNetwork(levels=levels, h_repo=5.0, metric="l2")
        snet = SimCacheNetwork(levels=levels, h_repo=5.0, metric="l2",
                               sharded=True, mesh=mesh)
        t_fused = _bench(lambda x: net._lookup_fused(x).cost, q)
        t_loop = _bench(lambda x: net._lookup_looped(x).cost, q)
        t_shard = _bench(lambda x: snet._lookup_sharded(x).cost, q)
        name = f"fused_lookup/L{L}_Q{Q}_K{Kj}_D{D}_l2"
        rows.append({"name": name, "us": t_fused * 1e6,
                     "looped_us": t_loop * 1e6,
                     "sharded_us": t_shard * 1e6,
                     "n_shards": n_dev,
                     "speedup": t_loop / t_fused})
        csv_line(name, t_fused * 1e6,
                 f"looped_us={t_loop*1e6:.1f},"
                 f"sharded_us={t_shard*1e6:.1f}({n_dev}shard),"
                 f"speedup={t_loop/t_fused:.2f}x")
    # LSH / k-means pruned lookup vs the exact fused scan. Table params
    # keep the per-query candidate count small enough that the *batch
    # union* stays well under max_candidates (overflow truncation is
    # what kills recall, not hashing quality).
    pruned_policies = {
        10_000: [SimHashPolicy(n_tables=4, n_bits=11, n_probes=2,
                               max_candidates=4096),
                 KMeansPolicy(n_clusters=512, n_probes=8, n_iters=5,
                              max_candidates=8192)],
        100_000: [SimHashPolicy(n_tables=4, n_bits=14, n_probes=2,
                                max_candidates=8192),
                  KMeansPolicy(n_clusters=2048, n_probes=8, n_iters=5,
                               max_candidates=32768)],
        1_000_000: [SimHashPolicy(n_tables=4, n_bits=16, n_probes=2,
                                  max_candidates=16384)],
    }
    sizes = [10_000, 100_000]
    if os.environ.get("KERNEL_BENCH_FULL"):
        sizes.append(1_000_000)
    for n in sizes:
        D, B = 64, 64
        coords = rng.standard_normal((n, D)).astype(np.float32)
        half = n // 2
        levels = [CacheLevel(keys=jnp.asarray(coords[:half]),
                             values=jnp.asarray(
                                 np.arange(half, dtype=np.int32)), h=0.0),
                  CacheLevel(keys=jnp.asarray(coords[half:]),
                             values=jnp.asarray(
                                 np.arange(half, n, dtype=np.int32)),
                             h=0.5)]
        net = SimCacheNetwork(levels=levels, h_repo=1e9, metric="l2")
        pz = 1.0 / (np.arange(1, 4097) ** 0.9)
        ids = rng.permutation(n)[:4096][rng.choice(4096, B,
                                                   p=pz / pz.sum())]
        q = jnp.asarray(coords[ids] + 0.05 * rng.standard_normal(
            (B, D)).astype(np.float32))
        exact = net._lookup_fused(q)
        t_exact = _bench(lambda x: net._lookup_fused(x).cost, q)
        for pol in pruned_policies[n]:
            pnet = SimCacheNetwork(levels=levels, h_repo=1e9, metric="l2",
                                   candidate_policy=pol)
            res = pnet.lookup(q, prune=pol.kind)
            recall = lookup_recall(res, exact)
            t_pruned = _bench(
                lambda x: pnet.lookup(x, prune=pol.kind).cost, q)
            name = f"pruned_lookup/{pol.kind}_n{n}_Q{B}_D{D}_l2"
            rows.append({"name": name, "us": t_pruned * 1e6,
                         "exact_us": t_exact * 1e6,
                         "speedup": t_exact / t_pruned,
                         "recall": recall})
            csv_line(name, t_pruned * 1e6,
                     f"exact_us={t_exact*1e6:.1f},"
                     f"speedup={t_exact/t_pruned:.2f}x,"
                     f"recall={recall:.4f}")
        # int8 first pass against the same exact-scan baseline: the
        # full-width lb scan is a cheap XLA matmul pass, the exact
        # fused kernel then rescoring only the ≤ B·T candidate union
        res_q = net.lookup(q, quantize=True)
        recall_q = lookup_recall(res_q, exact)
        t_quant = _bench(lambda x: net.lookup(x, quantize=True).cost, q)
        t_qver = _bench(
            lambda x: net.lookup(x, quantize=True, verify=True).cost, q)
        name = f"quantized_lookup/n{n}_Q{B}_D{D}_l2"
        rows.append({"name": name, "us": t_quant * 1e6,
                     "verify_us": t_qver * 1e6,
                     "exact_us": t_exact * 1e6,
                     "speedup": t_exact / t_quant,
                     "verify_speedup": t_exact / t_qver,
                     "recall": recall_q})
        csv_line(name, t_quant * 1e6,
                 f"exact_us={t_exact*1e6:.1f},"
                 f"speedup={t_exact/t_quant:.2f}x,"
                 f"verify_speedup={t_exact/t_qver:.2f}x,"
                 f"recall={recall_q:.4f}")
        # composed cut: LSH gather first, int8 sub-cut inside the union
        pol = pruned_policies[n][0]
        pnet = SimCacheNetwork(levels=levels, h_repo=1e9, metric="l2",
                               candidate_policy=pol)
        res_qp = pnet.lookup(q, prune=pol.kind, quantize=True)
        recall_qp = lookup_recall(res_qp, exact)
        t_qp = _bench(
            lambda x: pnet.lookup(x, prune=pol.kind, quantize=True).cost,
            q)
        name = f"quant_prune_lookup/{pol.kind}_n{n}_Q{B}_D{D}_l2"
        rows.append({"name": name, "us": t_qp * 1e6,
                     "exact_us": t_exact * 1e6,
                     "speedup": t_exact / t_qp, "recall": recall_qp})
        csv_line(name, t_qp * 1e6,
                 f"exact_us={t_exact*1e6:.1f},"
                 f"speedup={t_exact/t_qp:.2f}x,recall={recall_qp:.4f}")
    # honest small-key row: at a few thousand keys the exact fused scan
    # is already one cheap launch, so the two-pass quantized path buys
    # little or nothing — recorded so the speedup table stays honest
    n_small, D, B = 4_096, 64, 64
    coords = rng.standard_normal((n_small, D)).astype(np.float32)
    levels = [CacheLevel(keys=jnp.asarray(coords),
                         values=jnp.asarray(
                             np.arange(n_small, dtype=np.int32)), h=0.0)]
    net = SimCacheNetwork(levels=levels, h_repo=1e9, metric="l2")
    q = jnp.asarray(coords[rng.integers(0, n_small, B)]
                    + 0.05 * rng.standard_normal((B, D)).astype(np.float32))
    t_exact = _bench(lambda x: net._lookup_fused(x).cost, q)
    t_quant = _bench(lambda x: net.lookup(x, quantize=True).cost, q)
    name = f"quantized_lookup/n{n_small}_Q{B}_D{D}_l2"
    rows.append({"name": name, "us": t_quant * 1e6,
                 "exact_us": t_exact * 1e6,
                 "speedup": t_exact / t_quant})
    csv_line(name, t_quant * 1e6,
             f"exact_us={t_exact*1e6:.1f},"
             f"speedup={t_exact/t_quant:.2f}x")
    for (R, O, D, J) in [(2048, 2048, 128, 3)]:
        x = jnp.asarray(rng.standard_normal((R, D)).astype(np.float32))
        y = jnp.asarray(rng.standard_normal((O, D)).astype(np.float32))
        lam = jnp.asarray(rng.random(R).astype(np.float32))
        cur = jnp.asarray((rng.random(R) * 4).astype(np.float32))
        h = jnp.asarray(rng.random((R, J)).astype(np.float32))
        dt = _bench(greedy_gain, x, y, lam, cur, h, metric="l2")
        name = f"gain/R{R}_O{O}_D{D}_J{J}"
        rows.append({"name": name, "us": dt * 1e6})
        csv_line(name, dt * 1e6, "")
    save_json("kernels.json", rows)
    return {"rows": rows}


if __name__ == "__main__":
    run()
