"""Kernel micro-benchmarks (interpret mode on CPU: correctness-grade
timings; the derived column reports achieved GB/s and GFLOP/s as a
plausibility anchor, not TPU performance).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_line, save_json
from repro.kernels.gain import greedy_gain
from repro.kernels.knn import nearest_approximizer


def _bench(fn, *args, repeat=3, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeat


def run() -> dict:
    rng = np.random.default_rng(0)
    rows = []
    for (Q, K, D, metric) in [(1024, 4096, 128, "l2"),
                              (1024, 4096, 2, "l1"),
                              (4096, 16384, 100, "l2sq")]:
        q = jnp.asarray(rng.standard_normal((Q, D)).astype(np.float32))
        k = jnp.asarray(rng.standard_normal((K, D)).astype(np.float32))
        dt = _bench(nearest_approximizer, q, k, metric=metric)
        flops = 2.0 * Q * K * D if metric != "l1" else 3.0 * Q * K * D
        name = f"knn/Q{Q}_K{K}_D{D}_{metric}"
        rows.append({"name": name, "us": dt * 1e6,
                     "gflops": flops / dt / 1e9})
        csv_line(name, dt * 1e6, f"gflops={flops/dt/1e9:.1f}")
    for (R, O, D, J) in [(2048, 2048, 128, 3)]:
        x = jnp.asarray(rng.standard_normal((R, D)).astype(np.float32))
        y = jnp.asarray(rng.standard_normal((O, D)).astype(np.float32))
        lam = jnp.asarray(rng.random(R).astype(np.float32))
        cur = jnp.asarray((rng.random(R) * 4).astype(np.float32))
        h = jnp.asarray(rng.random((R, J)).astype(np.float32))
        dt = _bench(greedy_gain, x, y, lam, cur, h, metric="l2")
        name = f"gain/R{R}_O{O}_D{D}_J{J}"
        rows.append({"name": name, "us": dt * 1e6})
        csv_line(name, dt * 1e6, "")
    save_json("kernels.json", rows)
    return {"rows": rows}


if __name__ == "__main__":
    run()
