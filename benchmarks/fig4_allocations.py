"""Fig. 4: allocations produced by GREEDY, LOCALSWAP, the continuous
approximation, the warm-start band map and NETDUEL in the leaf-fed
tandem (σ = L/8, h = 3).

The continuous ownership and the warm-start allocation both come from
the serving engine's classify→solve→map pipeline
(core.placement.warmstart) — the figure doubles as a structural check
that the production code reproduces the paper's Fig 4 panels.

Emits, per algorithm: the stored grid positions per cache and the
leaf/parent ownership of each request region (who serves it), plus
structure metrics: the paper's qualitative observation that GREEDY and
NETDUEL produce more irregular allocations than LOCALSWAP is quantified
as the mean within-cache nearest-stored-neighbor distance variance.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_line, save_json, tandem_instance, timed
from repro.core.placement import continuous as cont
from repro.core.placement import warmstart as ws
from repro.core.placement import greedy, localswap, netduel


def _alloc_record(inst, slots):
    best1, arg1, _ = inst.best_two(slots)
    owner_cache = np.where(arg1[0] >= 0, inst.slot_cache[arg1[0]], -1)
    leaf = inst.cat.coords[slots[inst.slot_cache == 0]]
    parent = inst.cat.coords[slots[inst.slot_cache == 1]]

    def irregularity(pts):
        if len(pts) < 2:
            return 0.0
        d = np.abs(pts[:, None, :] - pts[None, :, :]).sum(-1)
        np.fill_diagonal(d, np.inf)
        nn = d.min(1)
        return float(nn.var() / max(nn.mean() ** 2, 1e-9))

    return {
        "leaf_points": leaf.tolist(), "parent_points": parent.tolist(),
        "owner_cache": owner_cache.tolist(),
        "cost": inst.total_cost(slots),
        "irregularity_leaf": irregularity(leaf),
        "irregularity_parent": irregularity(parent),
    }


def run(L: int = 50, k: int = 50, h: float = 3.0, h_repo: float = 100.0,
        ls_iters: int = 10000, nd_iters: int = 60000) -> dict:
    inst = tandem_instance(L, L / 8, h, k, h_repo)
    out = {"L": L, "k": k, "h": h, "allocs": {}}

    g, tg = timed(lambda: greedy(inst))
    out["allocs"]["greedy"] = _alloc_record(inst, g)
    ls, tl = timed(lambda: localswap(inst, n_iters=ls_iters, seed=0))
    out["allocs"]["localswap"] = _alloc_record(inst, ls.slots)
    nd, tn = timed(lambda: netduel(inst, n_iters=nd_iters, seed=0,
                                   window=1500, arm_prob=0.3))
    out["allocs"]["netduel"] = _alloc_record(inst, nd.sw.slots)

    # continuous approximation: w ownership per region (no stored points)
    # — solved through the warm-start classify→solve path
    red = ws.classify_topology(inst.net, gamma=inst.cat.gamma)
    sol = ws.solve_continuous(inst, red)
    w = cont.thresholds_to_w(inst.lam[0], sol.splits, sol.order, 2)
    out["allocs"]["continuous"] = {
        "owner_cache": np.argmax(w, axis=1).tolist(), "cost": sol.cost}
    # ... and the discrete allocation the band map + polish produce
    rep, tw = timed(lambda: ws.warm_start(inst, reduction=red,
                                          polish_iters=256, device=False))
    out["allocs"]["warmstart"] = _alloc_record(inst, rep.slots)
    csv_line("fig4/warmstart", tw * 1e6,
             f"cost={out['allocs']['warmstart']['cost']:.4f}")

    for name in ("greedy", "localswap", "netduel"):
        rec = out["allocs"][name]
        csv_line(f"fig4/{name}", 0.0,
                 f"cost={rec['cost']:.4f};irr_leaf={rec['irregularity_leaf']:.3f}")
    # paper: LocalSwap is the most regular of the discrete algorithms
    out["checks"] = {
        "localswap most regular": (
            out["allocs"]["localswap"]["irregularity_leaf"] <=
            min(out["allocs"]["greedy"]["irregularity_leaf"],
                out["allocs"]["netduel"]["irregularity_leaf"]) * 1.25),
        "warmstart competitive with greedy": (
            out["allocs"]["warmstart"]["cost"] <=
            out["allocs"]["greedy"]["cost"] * 1.10)}
    save_json("fig4.json", out)
    return out


if __name__ == "__main__":
    r = run()
    print(r["checks"])
