"""General-graph scenario bench: paper-GREEDY placement vs on-path
LRU-style routing strategies on the same traces (results/bench/graphs.json).

For each graph family (ISP-like / scale-free / Watts–Strogatz) and each
demand shape (Zipf / Gaussian-around-barycenter), one multi-ingress
trace is sampled and served two ways:

* **paper-GREEDY** — the offline plane: build the empirical instance
  from the trace (``demand.from_trace``), solve GREEDY, and evaluate
  the placement's mean per-request cost with ``Instance.total_cost``
  (with empirical frequencies this equals an exact replay of the trace
  against the static placement, since per-request cost is deterministic
  given the allocation).
* **routing strategies** — the online plane: replay the identical trace
  through ``core.routing.StrategyPlane`` (LCE / LCD / SIM-LRU by
  default), reporting full-trace and warm-half mean costs and hit rate.

Cache slots are budget-split over the graph by degree centrality
(``core.scenarios.assign_budget``) for both planes, so the comparison
isolates *content selection* (demand-aware offline vs λ-unaware LRU),
not cache sizing. The ``check`` field asserts only conservation-level
sanity (every mean cost ≤ the repository-only baseline); which plane
wins by how much is the measurement.

Schema documented in benchmarks/README.md.
"""
from __future__ import annotations

import os
import sys
import time

import numpy as np

from benchmarks.common import csv_line, save_json
from repro.core import catalog as catalog_api
from repro.core import demand as demand_api
from repro.core import scenarios
from repro.core.objective import Instance
from repro.core.placement import greedy
from repro.core.routing import StrategyPlane

FAMILIES = ("isp", "scale_free", "watts_strogatz")
STRATEGIES = ("lce", "lcd", "sim-lru")


def _demands(cat, n_ingress: int, seed: int):
    return (("zipf", demand_api.zipf(cat, alpha=0.9, n_ingress=n_ingress,
                                     seed=seed)),
            ("gauss", demand_api.gaussian_grid(cat, sigma=2.0,
                                               n_ingress=n_ingress)))


def bench_scenario(family: str, dem_name: str, dem, cat, sc,
                   n_requests: int, seed: int) -> dict:
    net = sc.net
    rng = np.random.default_rng(seed)
    objs, ings = dem.sample(n_requests, rng)

    # repository-only baseline: mean h_repo over the trace
    repo_cost = float(np.mean(net.h_repo[ings]))

    # ---- paper-GREEDY on the empirical (trace) demand
    emp = demand_api.from_trace(cat.n, objs, ings,
                                n_ingress=net.n_ingress)
    inst = Instance(net=net, cat=cat, dem=emp)
    t0 = time.perf_counter()
    slots = greedy(inst)
    solve_s = time.perf_counter() - t0
    greedy_cost = float(inst.total_cost(np.where(slots < 0, 0, slots)))

    # ---- LRU-style strategies replay the identical trace
    strat_rows = {}
    for strat in STRATEGIES:
        pl = StrategyPlane(net, cat.coords, metric=cat.metric,
                           gamma=cat.gamma, strategy=strat, seed=seed)
        t0 = time.perf_counter()
        dec = pl.serve(objs, ings)
        serve_s = time.perf_counter() - t0
        half = n_requests // 2
        strat_rows[strat] = {
            "mean_cost": float(dec.cost.mean()),
            "warm_mean_cost": float(dec.cost[half:].mean()),
            "hit_rate": float(dec.hit.mean()),
            "warm_hit_rate": float(dec.hit[half:].mean()),
            "evictions": int(pl.n_evicted),
            "serve_s": serve_s,
        }

    best = min(strat_rows, key=lambda s: strat_rows[s]["warm_mean_cost"])
    row = {
        "name": f"{family}_{dem_name}",
        "family": family,
        "graph_nodes": int(sc.graph.n_nodes),
        "graph_edges": int(np.isfinite(np.triu(sc.graph.adj, 1)).sum()),
        "placement": sc.placement,
        "cache_budget": int(net.total_slots),
        "n_caches": int(net.n_caches),
        "n_ingress": int(net.n_ingress),
        "n_objects": int(cat.n),
        "demand": dem_name,
        "n_requests": int(n_requests),
        "repo_only_cost": repo_cost,
        "greedy": {"mean_cost": greedy_cost, "solve_s": solve_s},
        "strategies": strat_rows,
        "best_strategy": best,
        "greedy_vs_best_lru":
            greedy_cost / strat_rows[best]["warm_mean_cost"],
        "check": bool(
            greedy_cost <= repo_cost + 1e-9
            and all(r["mean_cost"] <= repo_cost + 1e-9
                    for r in strat_rows.values())),
    }
    assert row["check"], f"{row['name']}: a plane exceeded the " \
        f"repository-only baseline"
    csv_line(row["name"], solve_s * 1e6,
             f"greedy={greedy_cost:.3f},"
             f"{best}={strat_rows[best]['warm_mean_cost']:.3f},"
             f"repo={repo_cost:.3f}")
    return row


def run(smoke: bool = False) -> dict:
    full = bool(os.environ.get("GRAPHS_BENCH_FULL"))
    if smoke:
        n_objects, n_requests, budget, n_ingress = 200, 800, 32, 4
    elif full:
        n_objects, n_requests, budget, n_ingress = 4000, 40000, 128, 8
    else:
        n_objects, n_requests, budget, n_ingress = 1200, 8000, 64, 6
    cat = catalog_api.embedding_catalog(n=n_objects, dim=8, seed=0)
    rows = []
    for fi, family in enumerate(FAMILIES):
        sc = scenarios.scenario(family, cache_budget=budget,
                                placement="degree",
                                n_ingress=n_ingress, seed=fi)
        for dem_name, dem in _demands(cat, sc.net.n_ingress, seed=7):
            rows.append(bench_scenario(family, dem_name, dem, cat, sc,
                                       n_requests, seed=fi + 13))
    save_json("graphs.json", rows)
    return {"rows": rows,
            "checks": {r["name"]: r["check"] for r in rows}}


if __name__ == "__main__":
    run(smoke="--smoke" in sys.argv)
