"""Benchmark harness: one module per paper table/figure + kernels +
roofline. Prints ``name,us_per_call,derived`` CSV lines; JSON artifacts
land in results/bench/.

  python -m benchmarks.run [--full] [--only fig3,fig4,...]
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (L=100, 10k items; slow)")
    ap.add_argument("--only", default="",
                    help="comma list: fig3,fig4,fig56,fig78,kernels,"
                         "roofline,serving,warmstart,graphs,hitrate")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (fig3_tandem, fig4_allocations,
                            fig56_both_arrivals, fig78_trace, graphs_bench,
                            hitrate_bench, kernel_bench, roofline_table,
                            serving_bench, warmstart_bench)

    t0 = time.time()
    checks: dict = {}

    def want(name):
        return only is None or name in only

    if want("fig3"):
        kw = dict(L=100, k=100, ls_iters=20000, nd_iters=120000) \
            if args.full else {}
        checks["fig3"] = fig3_tandem.run(**kw)["checks"]
    if want("fig4"):
        kw = dict(L=100, k=100, ls_iters=25000, nd_iters=120000) \
            if args.full else {}
        checks["fig4"] = fig4_allocations.run(**kw)["checks"]
    if want("fig56"):
        kw = dict(L=60, k=60, ls_iters=25000) if args.full else {}
        checks["fig56"] = fig56_both_arrivals.run(**kw)["checks"]
    if want("fig78"):
        kw = dict(n_items=10000, ls_iters=40000) if args.full else {}
        checks["fig78"] = fig78_trace.run(**kw)["checks"]
    if want("kernels"):
        kernel_bench.run()
    if want("roofline"):
        roofline_table.run()
    if want("serving"):
        serving_bench.run(smoke=not args.full)
    if want("warmstart"):
        # gap bounds + (under WARMSTART_BENCH_FULL=1) the 10⁶ headline
        # are asserted inside the bench itself
        warmstart_bench.run(smoke=not args.full)
    if want("graphs"):
        # general-graph scenarios: paper-GREEDY vs on-path LRU routing
        # strategies; the repo-baseline check is asserted in-bench
        checks["graphs"] = graphs_bench.run(smoke=not args.full)["checks"]
    if want("hitrate"):
        # analytic Che predictions vs measured SIM/RND-LRU replays; the
        # ≤5%-absolute Zipf bound (+ the HITRATE_BENCH_FULL=1 10⁶-object
        # LSH path) is asserted in-bench
        checks["hitrate"] = hitrate_bench.run(smoke=not args.full)["checks"]

    print(f"\n== paper-claim checks ({time.time()-t0:.0f}s) ==")
    n_fail = 0
    for fig, cs in checks.items():
        for name, ok in cs.items():
            print(f"  [{'PASS' if ok else 'FAIL'}] {fig}: {name}")
            n_fail += (not ok)
    if n_fail:
        print(f"{n_fail} claim checks FAILED")
        sys.exit(1)
    print("all claim checks passed")


if __name__ == "__main__":
    main()
