"""Streaming serving benchmark: batch bucketing vs per-size retracing,
and the multi-stream driver with the double-buffered placement refresh.

Rows (results/bench/serving.json):

* ``bucketing/mixed_sizes`` — the same mixed-batch-size request trace
  (many distinct sizes, the shape of an arrival-driven stream) served
  twice from a cold jit cache: once with ``EngineConfig.bucket=False``
  (one XLA compile per distinct size per entry point — fused lookup,
  duel scan, miss prefill) and once with the bucketed path (one compile
  per power-of-two bucket). Timing *includes* the compiles — sustained
  requests/s is exactly what a serving process sees on a fresh stream.
  ``speedup = bucketed_rps / unbucketed_rps``; the trace counters
  (repro.tracecount) record how many compiles each leg actually paid.
* ``driver/max_batch{B}`` — a StreamDriver run (3 Poisson streams
  multiplexed on a virtual clock) at ≥3 batch-size caps: sustained
  requests/s, p50/p95/p99 batch latency, background-refresh cadence
  (``refresh_every`` batches), atomic-swap counts and stall time.
  ``stall_bounded_by_batch`` asserts the double-buffer contract: the
  longest serving-thread stall a placement refresh ever caused
  (``max_swap_stall_ms``) stays below the longest single batch — the
  solve itself never blocks the request path.

``--smoke`` shrinks the trace for CI (scripts/ci.sh runs it on every
push); ``SERVING_BENCH_FULL=1`` widens the sweep (more distinct sizes,
longer driver runs) like the other *_BENCH_FULL nightly gates. The
committed serving.json comes from a default (non-smoke) run.
"""
from __future__ import annotations

import dataclasses
import os
import sys
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_line, save_json
from repro import tracecount
from repro.configs.registry import get_smoke_config
from repro.core import catalog as catalog_api
from repro.core import demand as demand_api
from repro.models import model as model_api
from repro.serve import (EngineConfig, SimCacheEngine, StreamDriver,
                         StreamSpec)


def build_engine(bucket: bool = True, netduel: bool = True,
                 refresh_on_promotion: bool = False,
                 n_objects: int = 400):
    cfg = dataclasses.replace(get_smoke_config("granite-3-2b"),
                              n_layers=2, d_model=64, n_heads=4,
                              n_kv_heads=2, head_dim=16, d_ff=128,
                              vocab=256)
    params = model_api.init_params(cfg, 0)
    cat = catalog_api.embedding_catalog(n=n_objects, dim=16, seed=1)
    ecfg = EngineConfig(k_device=16, k_pod=24, k_global=32,
                        h_ici=1.0, h_dcn=10.0, h_model=100.0,
                        metric="l2", algo="greedy", netduel=netduel,
                        duel_window=128, duel_arm_prob=0.5, duel_seed=0,
                        bucket=bucket,
                        refresh_on_promotion=refresh_on_promotion)
    return SimCacheEngine(cfg, params, ecfg, cat.coords), cfg, cat


def mixed_trace(cat, cfg, sizes, seed=0):
    rng = np.random.default_rng(seed)
    dem = demand_api.zipf(cat, alpha=1.1, seed=3)
    out = []
    for k in sizes:
        ids, _ = dem.sample(k, rng)
        out.append((ids, jnp.asarray(
            rng.integers(0, cfg.vocab, (k, 8)).astype(np.int32))))
    return out


def bench_bucketing(n_distinct: int) -> dict:
    """Serve one mixed-batch-size trace through both engine modes from a
    cold jit cache each; wall clock includes every compile."""
    rng = np.random.default_rng(7)
    # distinct sizes spread over [1, 96]: ~5 power-of-two buckets but
    # n_distinct separate XLA compiles for the unbucketed path
    sizes = list(rng.choice(np.arange(1, 97), size=n_distinct,
                            replace=False))
    sizes = [int(s) for s in sizes] * 2          # revisit each size once
    leg = {}
    for bucket in (False, True):
        eng, cfg, cat = build_engine(bucket=bucket)
        trace = mixed_trace(cat, cfg, [32] * 4, seed=9)
        for ids, prompts in trace:               # cold fill
            eng.serve(ids, prompts)
        eng.refresh_placement()
        work = mixed_trace(cat, cfg, sizes, seed=1)
        with tracecount.snapshot() as s:
            t0 = time.perf_counter()
            for ids, prompts in work:
                eng.serve(ids, prompts)
            dt = time.perf_counter() - t0
            traces = s.delta("fused_lookup") + s.delta("duel_scan")
        n_req = sum(len(ids) for ids, _ in work)
        leg[bucket] = {"rps": n_req / dt, "wall_s": dt, "traces": traces,
                       "n_requests": n_req}
    row = {"name": "bucketing/mixed_sizes",
           "n_batches": len(sizes),
           "distinct_sizes": len(set(sizes)),
           "n_requests": leg[True]["n_requests"],
           "unbucketed_rps": leg[False]["rps"],
           "unbucketed_wall_s": leg[False]["wall_s"],
           "unbucketed_traces": leg[False]["traces"],
           "bucketed_rps": leg[True]["rps"],
           "bucketed_wall_s": leg[True]["wall_s"],
           "bucketed_traces": leg[True]["traces"],
           "speedup": leg[True]["rps"] / leg[False]["rps"]}
    csv_line(row["name"], leg[True]["wall_s"] * 1e6,
             f"speedup={row['speedup']:.1f}x,"
             f"traces={row['bucketed_traces']}v{row['unbucketed_traces']}")
    return row


def bench_driver(max_batch: int, n_requests: int,
                 refresh_every: int = 8) -> dict:
    """One StreamDriver run: 3 Poisson streams, cadence-triggered
    background refreshes swapped in between batches."""
    eng, cfg, cat = build_engine(refresh_on_promotion=True)
    streams = [
        StreamSpec(demand=demand_api.zipf(cat, alpha=1.1, seed=s + 1),
                   rate=[5.0, 9.0, 2.0][s], seed=s + 1, name=f"user{s}")
        for s in range(3)]
    drv = StreamDriver(eng, streams, max_batch=max_batch,
                       batch_window=2.0, refresh_every=refresh_every)
    drv.run(max(n_requests // 8, max_batch))     # warm + observe demand
    eng.refresh_placement()
    st = drv.run(n_requests)
    drv.drain_refresh()
    max_batch_ms = max(st.batch_latencies_ms)
    row = {"name": f"driver/max_batch{max_batch}",
           "n_requests": st.n_requests, "n_batches": st.n_batches,
           "distinct_batch_sizes": st.distinct_batch_sizes,
           "requests_per_s": st.requests_per_s,
           "p50_ms": st.p50_ms, "p95_ms": st.p95_ms, "p99_ms": st.p99_ms,
           "refresh_every": refresh_every,
           "refreshes_started": st.refreshes_started,
           "swaps": st.swaps,
           "placement_events": st.placement_events,
           "swap_stall_s": st.swap_stall_s,
           "max_swap_stall_ms": st.max_swap_stall_s * 1e3,
           "max_batch_latency_ms": max_batch_ms,
           "stall_bounded_by_batch":
               bool(st.max_swap_stall_s * 1e3 <= max_batch_ms),
           "hit_rate": eng.stats.hit_rate,
           "final_version": eng.placement.version}
    assert row["stall_bounded_by_batch"], \
        "placement swap stalled serving longer than one batch"
    csv_line(row["name"], st.p50_ms * 1e3,
             f"rps={st.requests_per_s:.0f},p99_ms={st.p99_ms:.0f},"
             f"swaps={st.swaps},max_stall_ms="
             f"{row['max_swap_stall_ms']:.1f}")
    return row


def run(smoke: bool = False) -> dict:
    full = bool(os.environ.get("SERVING_BENCH_FULL"))
    if smoke:
        n_distinct, driver_caps, n_req = 6, (32, 64), 300
    elif full:
        n_distinct, driver_caps, n_req = 32, (32, 64, 128, 256), 4000
    else:
        n_distinct, driver_caps, n_req = 16, (64, 128, 256), 1500
    rows = [bench_bucketing(n_distinct)]
    for cap in driver_caps:
        rows.append(bench_driver(cap, n_req))
    save_json("serving.json", rows)
    return {"rows": rows}


if __name__ == "__main__":
    run(smoke="--smoke" in sys.argv)
