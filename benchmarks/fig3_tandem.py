"""Fig. 3: total cost in the leaf-fed tandem vs parent cost h, for
GREEDY, LOCALSWAP, the continuous approximation (11), the warm-start
pipeline (continuous solve + Prop 4.2 band map + bounded polish) and
NETDUEL, with a wide (σ = L/2) and a narrow (σ = L/8) Gaussian.

The continuous curve is produced by the same classify→solve path the
serving engine's warm start uses (core.placement.warmstart), not a
hand-built ChainSpec — so this figure exercises the production code.

Paper claims verified quantitatively (results/bench/fig3.json):
  * LocalSwap ≤ Greedy ≤ NetDuel (cost ordering);
  * the continuous approximation tracks LocalSwap more closely for
    σ = L/2 (λ varies smoothly over cells) than for σ = L/8;
  * warm-start+polish tracks LocalSwap across the h sweep.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_line, save_json, tandem_instance, timed
from repro.core.placement import warmstart as ws
from repro.core.placement import greedy, localswap, netduel


def run(L: int = 50, k: int = 50, h_repo: float = 100.0,
        hs=(0.0, 1.0, 2.0, 4.0, 8.0), ls_iters: int = 8000,
        nd_iters: int = 60000) -> dict:
    out: dict = {"L": L, "k": k, "h_repo": h_repo, "curves": {}}
    for sigma_name, sigma in (("L/2", L / 2), ("L/8", L / 8)):
        rows = []
        for h in hs:
            inst = tandem_instance(L, sigma, h, k, h_repo)
            g, tg = timed(lambda: greedy(inst))
            ls, tl = timed(lambda: localswap(inst, n_iters=ls_iters, seed=0))
            nd, tn = timed(lambda: netduel(inst, n_iters=nd_iters, seed=0,
                                           window=1500, arm_prob=0.3))
            red = ws.classify_topology(inst.net, gamma=inst.cat.gamma)
            sol, tc = timed(lambda: ws.solve_continuous(inst, red))
            rep, tw = timed(lambda: ws.warm_start(inst, reduction=red,
                                                  polish_iters=256,
                                                  device=False))
            rows.append({
                "h": h,
                "greedy": inst.total_cost(g),
                "localswap": ls.cost(inst),
                "netduel": nd.sw.cost(inst),
                "continuous": sol.cost,
                "warmstart": inst.total_cost(rep.slots),
                "t_greedy_s": tg, "t_localswap_s": tl, "t_netduel_s": tn,
                "t_continuous_s": tc, "t_warmstart_s": tw,
            })
            csv_line(f"fig3/{sigma_name}/h={h:g}/greedy", tg * 1e6,
                     f"cost={rows[-1]['greedy']:.4f}")
            csv_line(f"fig3/{sigma_name}/h={h:g}/localswap", tl * 1e6,
                     f"cost={rows[-1]['localswap']:.4f}")
            csv_line(f"fig3/{sigma_name}/h={h:g}/netduel", tn * 1e6,
                     f"cost={rows[-1]['netduel']:.4f}")
            csv_line(f"fig3/{sigma_name}/h={h:g}/continuous", tc * 1e6,
                     f"cost={rows[-1]['continuous']:.4f}")
            csv_line(f"fig3/{sigma_name}/h={h:g}/warmstart", tw * 1e6,
                     f"cost={rows[-1]['warmstart']:.4f}")
        out["curves"][sigma_name] = rows
    # paper-claim checks
    checks = {}
    for sname, rows in out["curves"].items():
        checks[f"localswap<=greedy ({sname})"] = all(
            r["localswap"] <= r["greedy"] * 1.02 for r in rows)
        checks[f"greedy<=netduel ({sname})"] = all(
            r["greedy"] <= r["netduel"] * 1.10 for r in rows)
    gap = {s: float(np.mean([abs(r["continuous"] - r["localswap"])
                             / max(r["localswap"], 1e-9)
                             for r in out["curves"][s]]))
           for s in out["curves"]}
    checks["continuous closer for smooth lambda"] = gap["L/2"] <= gap["L/8"]
    ws_gap = {s: float(np.mean([abs(r["warmstart"] - r["localswap"])
                                / max(r["localswap"], 1e-9)
                                for r in out["curves"][s]]))
              for s in out["curves"]}
    checks["warmstart tracks localswap"] = all(g <= 0.10
                                               for g in ws_gap.values())
    out["checks"] = checks
    out["continuous_vs_localswap_relgap"] = gap
    out["warmstart_vs_localswap_relgap"] = ws_gap
    save_json("fig3.json", out)
    return out


if __name__ == "__main__":
    print(run())
