"""Analytic hit-rate validation bench: Che-approximation predictions
(core/analysis/hitrate.py) pinned against measured SIM-LRU / RND-LRU
trace replays (results/bench/hitrate.json).

For each PR 8 graph family (ISP-like / scale-free / Watts–Strogatz),
each demand shape (Zipf / Gaussian-around-barycenter) and each
similarity strategy, one multi-ingress trace is sampled and served two
ways:

* **measured** — replay through ``core.routing.StrategyPlane`` at
  serving threshold θ; the warm half of the trace is the steady-state
  hit rate the analytic plane claims to predict.
* **predicted** — enumerate the similarity balls at the same θ
  (``similarity_balls``: hard q for SIM-LRU, clipped-linear for
  RND-LRU) and solve the network fixed point
  (``predict_hitrates``) on the *true* demand matrix.

Coordinate rescaling: scenario graphs carry hop-scale costs (repo-cost
slacks O(1)) while ``embedding_catalog`` distances are O(100), so raw
coordinates make every similarity ball collapse to {self}. The bench
rescales coords so that θ — set to a fixed fraction of the median
on-path slack — captures a small distance quantile of the catalog:
similarity serving is non-trivial (mean ball of a few members) and the
slack eligibility of ``routing.serve_one`` still binds per cache.

The ``check`` field asserts the ISSUE-10 acceptance bound: on Zipf
demand the predicted hit rate is within ≤ 5% *absolute* of the
measured warm-half hit rate (Gaussian rows are recorded for the
drift/regime picture but not gated — concentrated demand pushes the
Che ansatz's IRM/many-objects assumptions harder).

``HITRATE_BENCH_FULL=1`` (nightly) additionally runs the 10⁶-object
path: LSH ball enumeration (``mode='lsh'`` — the SimHash candidate
machinery of kernels/knn/lsh.py) plus the analytic solve, with wall
times recorded; no replay at that scale (StrategyPlane is a host
per-request loop — the analytic plane existing is the point).

Schema documented in benchmarks/README.md.
"""
from __future__ import annotations

import os
import sys
import time

import numpy as np

from benchmarks.common import csv_line, save_json
from repro.core import catalog as catalog_api
from repro.core import demand as demand_api
from repro.core import scenarios
from repro.core.analysis import predict_hitrates, similarity_balls
from repro.core.catalog import Catalog
from repro.core.routing import StrategyPlane

FAMILIES = ("isp", "scale_free", "watts_strogatz")
MODES = (("sim-lru", "hard"), ("rnd-lru", "rnd"))
SLACK_FRAC = 0.4          # θ = SLACK_FRAC × median on-path slack
BALL_QUANTILE = 0.01      # rescale so θ captures this distance quantile
TOL_ZIPF = 0.05           # ≤ 5% absolute on Zipf rows (ISSUE-10)


def _median_slack(net) -> float:
    H = np.asarray(net.H, np.float64)
    h_repo = np.asarray(net.h_repo, np.float64)
    slacks = (h_repo[:, None] - H)[np.isfinite(H)]
    return float(np.median(slacks[slacks > 0]))


def _rescaled_catalog(n_objects: int, net, seed: int) -> tuple[Catalog,
                                                               float]:
    """Embedding catalog rescaled so θ (a fixed fraction of the median
    repo-cost slack) equals the BALL_QUANTILE of pairwise distances —
    C_a becomes commensurate with the graph's cost scale."""
    cat = catalog_api.embedding_catalog(n=n_objects, dim=8, seed=seed)
    coords = np.asarray(cat.coords, np.float64)
    rng = np.random.default_rng(seed)
    a = rng.integers(0, n_objects, 4096)
    b = rng.integers(0, n_objects, 4096)
    keep = a != b
    d = np.sqrt(((coords[a[keep]] - coords[b[keep]]) ** 2).sum(axis=1))
    theta = SLACK_FRAC * _median_slack(net)
    scale = theta / float(np.quantile(d, BALL_QUANTILE))
    return Catalog(coords=(coords * scale).astype(np.float32),
                   metric="l2", gamma=1.0,
                   name=f"{cat.name}_x{scale:.2g}"), theta


def _demands(cat, n_ingress: int, seed: int):
    return (("zipf", demand_api.zipf(cat, alpha=0.9, n_ingress=n_ingress,
                                     seed=seed)),
            ("gauss", demand_api.gaussian_grid(cat, sigma=2.0,
                                               n_ingress=n_ingress)))


def bench_scenario(family: str, dem_name: str, dem, cat, sc, theta: float,
                   n_requests: int, seed: int) -> dict:
    net = sc.net
    rng = np.random.default_rng(seed)
    objs, ings = dem.sample(n_requests, rng)
    half = n_requests // 2

    strat_rows = {}
    for strat, q_mode in MODES:
        # ---- measured: replay the trace at threshold θ
        pl = StrategyPlane(net, cat.coords, metric=cat.metric,
                           gamma=cat.gamma, strategy=strat,
                           threshold=theta, seed=seed)
        t0 = time.perf_counter()
        dec = pl.serve(objs, ings)
        replay_s = time.perf_counter() - t0
        measured = float(dec.hit[half:].mean())

        # ---- predicted: balls at the same θ + the network fixed point
        t0 = time.perf_counter()
        balls = similarity_balls(cat.coords, theta, metric=cat.metric,
                                 gamma=cat.gamma, q_mode=q_mode)
        balls_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        pred = predict_hitrates(net, dem.lam, balls)
        solve_s = time.perf_counter() - t0

        abs_err = abs(pred.hit_rate - measured)
        strat_rows[strat] = {
            "measured_warm_hit_rate": measured,
            "measured_full_hit_rate": float(dec.hit.mean()),
            "measured_warm_mean_cost": float(dec.cost[half:].mean()),
            "predicted_hit_rate": pred.hit_rate,
            "predicted_mean_cost": pred.mean_cost,
            "abs_err": abs_err,
            "mean_ball": balls.mean_size,
            "residual": pred.residual,
            "replay_s": replay_s,
            "balls_s": balls_s,
            "solve_s": solve_s,
        }
        csv_line(f"hitrate_{family}_{dem_name}_{strat}", solve_s * 1e6,
                 f"pred={pred.hit_rate:.3f},meas={measured:.3f},"
                 f"err={abs_err:.3f},ball={balls.mean_size:.1f}")

    check = dem_name != "zipf" or all(
        r["abs_err"] <= TOL_ZIPF for r in strat_rows.values())
    row = {
        "name": f"{family}_{dem_name}",
        "family": family,
        "demand": dem_name,
        "placement": sc.placement,
        "cache_budget": int(net.total_slots),
        "n_caches": int(net.n_caches),
        "n_ingress": int(net.n_ingress),
        "n_objects": int(cat.n),
        "n_requests": int(n_requests),
        "theta": theta,
        "median_slack": _median_slack(net),
        "tol_zipf": TOL_ZIPF,
        "strategies": strat_rows,
        "check": bool(check),
    }
    assert row["check"], \
        f"{row['name']}: Che prediction off by more than {TOL_ZIPF:.0%} " \
        f"absolute on Zipf: " + ", ".join(
            f"{s}={r['abs_err']:.3f}" for s, r in strat_rows.items())
    return row


def bench_full_scale(n_objects: int = 1_000_000) -> dict:
    """The 10⁶-object nightly path: LSH ball enumeration + the analytic
    solve (milliseconds-per-sweep is the module's scaling claim). No
    replay — the host per-request simulator is exactly what the
    analytic plane replaces at this scale."""
    sc = scenarios.scenario("scale_free", cache_budget=4096,
                            placement="degree", n_ingress=6, seed=0)
    cat, theta = _rescaled_catalog(n_objects, sc.net, seed=0)
    dem = demand_api.zipf(cat, alpha=0.9, n_ingress=sc.net.n_ingress,
                          seed=7)
    t0 = time.perf_counter()
    balls = similarity_balls(cat.coords, theta, mode="lsh", seed=0,
                             max_ball=64)
    balls_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    pred = predict_hitrates(sc.net, dem.lam, balls, n_sweeps=8)
    solve_s = time.perf_counter() - t0
    row = {
        "name": "full_1e6_lsh",
        "n_objects": int(n_objects),
        "theta": theta,
        "mean_ball": balls.mean_size,
        "truncated": int(balls.truncated),
        "balls_s": balls_s,
        "solve_s": solve_s,
        "predicted_hit_rate": pred.hit_rate,
        "predicted_mean_cost": pred.mean_cost,
        "check": bool(np.isfinite(pred.hit_rate)
                      and 0.0 <= pred.hit_rate <= 1.0
                      and balls.mean_size >= 1.0),
    }
    assert row["check"], "full-scale analytic solve produced garbage"
    csv_line("hitrate_full_1e6", solve_s * 1e6,
             f"balls={balls_s:.1f}s,ball={balls.mean_size:.1f},"
             f"pred={pred.hit_rate:.3f}")
    return row


def run(smoke: bool = False) -> dict:
    full = bool(os.environ.get("HITRATE_BENCH_FULL"))
    if smoke:
        n_objects, n_requests, budget, n_ingress = 200, 4000, 32, 4
    else:
        n_objects, n_requests, budget, n_ingress = 600, 20000, 48, 5
    rows = []
    for fi, family in enumerate(FAMILIES):
        sc = scenarios.scenario(family, cache_budget=budget,
                                placement="degree",
                                n_ingress=n_ingress, seed=fi)
        cat, theta = _rescaled_catalog(n_objects, sc.net, seed=fi)
        for dem_name, dem in _demands(cat, sc.net.n_ingress, seed=7):
            rows.append(bench_scenario(family, dem_name, dem, cat, sc,
                                       theta, n_requests, seed=fi + 13))
    if full:
        rows.append(bench_full_scale())
    save_json("hitrate.json", rows)
    return {"rows": rows,
            "checks": {r["name"]: r["check"] for r in rows}}


if __name__ == "__main__":
    run(smoke="--smoke" in sys.argv)
