"""Figs. 7–8: the Amazon-trace experiment (§6.2), on a synthetic stand-in.

The McAuley image-embedding trace is not available offline; we synthesize
a statistically matched substitute (flagged clearly in EXPERIMENTS.md):
10k items in R^100, radially-DECREASING request density (Fig 8's
empirical finding), Zipf popularity assigned independently of geometry
(the paper found rank ⟂ barycenter-distance), Euclidean C_a, tandem
cache 100+100, h = 150.

Reproduced claims:
  * LOCALSWAP's leaf cache prefers items that are popular OR central
    (Fig 7 left);
  * the barycenter-distance-constrained variant (leaf keeps d < d*,
    parent d ≥ d*) is within ~1% of unconstrained cost at the best d*
    (paper: 269 vs 266) — the simple structure survives in realistic
    data;
  * request density per spherical shell decreases with radius (Fig 8).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (bench_jax, csv_line, lookup_recall,
                               save_json, timed)
from repro.core import catalog as catalog_api
from repro.core import demand as demand_api
from repro.core import topology
from repro.core.objective import DeviceInstance, Instance
from repro.core.placement import device_greedy, greedy, localswap
from repro.core.placement.localswap import constrained_localswap
from repro.core.simcache import SimCacheNetwork
from repro.launch.mesh import make_lookup_mesh


def build_instance(n_items: int = 4000, dim: int = 100, h: float = 150.0,
                   k: int = 100, seed: int = 0):
    cat = catalog_api.embedding_catalog(n=n_items, dim=dim, seed=seed,
                                        radial="decreasing")
    dem = demand_api.zipf(cat, alpha=0.8, seed=seed + 1)
    net = topology.tandem(k_leaf=k, k_parent=k, h=h, h_repo=1000.0)
    return Instance(net=net, cat=cat, dem=dem)


def shell_density(cat, dem, n_shells: int = 20):
    r = np.linalg.norm(cat.coords, axis=1)
    edges = np.linspace(0, np.quantile(r, 0.99), n_shells + 1)
    dens = []
    for i in range(n_shells):
        m = (r >= edges[i]) & (r < edges[i + 1])
        vol = edges[i + 1] - edges[i]
        dens.append(float(dem.lam[0][m].sum() / max(vol, 1e-9)))
    return edges.tolist(), dens


def run(n_items: int = 4000, k: int = 100, h: float = 150.0,
        ls_iters: int = 15000,
        dstars=(250.0, 350.0, 450.0, 600.0, 800.0)) -> dict:
    inst = build_instance(n_items=n_items, k=k, h=h)
    out: dict = {"n_items": n_items, "k": k, "h": h}

    # Fig 8: shell density decreasing
    edges, dens = shell_density(inst.cat, inst.dem)
    out["fig8"] = {"edges": edges, "density": dens}
    half = len(dens) // 2
    out.setdefault("checks", {})["density decreasing"] = \
        float(np.mean(dens[:half])) > float(np.mean(dens[half:]))

    # Fig 7 left: unconstrained LocalSwap
    ls, tl = timed(lambda: localswap(inst, n_iters=ls_iters, seed=0))
    cost_u = ls.cost(inst)
    radii = np.linalg.norm(inst.cat.coords, axis=1)
    pop_rank = np.argsort(np.argsort(-inst.lam[0]))
    leaf_items = ls.slots[inst.slot_cache == 0]
    leaf_popular = pop_rank[leaf_items] < n_items * 0.1
    leaf_central = radii[leaf_items] < np.quantile(radii, 0.25)
    out["fig7_unconstrained"] = {
        "cost": cost_u, "t_s": tl,
        "leaf_rank": pop_rank[leaf_items].tolist(),
        "leaf_radius": radii[leaf_items].tolist(),
        "frac_leaf_popular_or_central":
            float(np.mean(leaf_popular | leaf_central)),
    }
    csv_line("fig78/unconstrained", tl * 1e6, f"cost={cost_u:.2f}")
    out["checks"]["leaf stores popular-or-central"] = \
        out["fig7_unconstrained"]["frac_leaf_popular_or_central"] > 0.5

    # data-plane timing on this trace: serve the full catalog as a query
    # batch through the runtime cache network — per-level looped
    # reference vs fused single-kernel vs mesh-sharded fused (one kernel
    # per shard over all available devices; run under
    # XLA_FLAGS=--xla_force_host_platform_device_count=8 for 8 shards)
    mk = lambda **kw: SimCacheNetwork.from_placement(        # noqa: E731
        inst.cat.coords, ls.slots, inst.slot_cache,
        hs=[0.0, h], h_repo=1000.0, metric=inst.cat.metric,
        gamma=inst.cat.gamma, **kw)
    q = jnp.asarray(inst.cat.coords)
    n_dev = jax.device_count()
    mesh = make_lookup_mesh(n_dev)
    nf, nl = mk(fused=True), mk(fused=False)
    ns = mk(fused=True, sharded=True, mesh=mesh)
    t_fused = bench_jax(lambda: nf.lookup(q).cost)
    t_loop = bench_jax(lambda: nl.lookup(q).cost)
    t_shard = bench_jax(lambda: ns.lookup(q).cost)
    # LSH-pruned row on the same trace: K = 200 stored keys is far below
    # the catalogs-≫-10⁵ regime pruning targets (kernel_bench.py has
    # those), so this row mostly prices the hashing overhead — the
    # recall column is the point here.
    exact = nf.lookup(q)
    pruned = nf.lookup(q, prune="lsh")
    t_pruned = bench_jax(lambda: nf.lookup(q, prune="lsh").cost)
    recall = lookup_recall(pruned, exact)
    out["fused_lookup"] = {"fused_us": t_fused * 1e6,
                           "looped_us": t_loop * 1e6,
                           "sharded_us": t_shard * 1e6,
                           "pruned_us": t_pruned * 1e6,
                           "pruned_recall": recall,
                           "n_shards": n_dev,
                           "speedup": t_loop / t_fused}
    csv_line(f"fig78/fused_lookup/Q{n_items}", t_fused * 1e6,
             f"looped_us={t_loop*1e6:.1f},"
             f"sharded_us={t_shard*1e6:.1f}({n_dev}shard),"
             f"pruned_us={t_pruned*1e6:.1f}(recall={recall:.4f}),"
             f"speedup={t_loop/t_fused:.2f}x")

    # placement-refresh row: the control-plane path serve/engine takes
    # on a rolling window — host lazy GREEDY vs the device-resident
    # batched lazy GREEDY (streamed-C_a mode; since PR 5 the accept
    # loop is one lax.while_loop launch, so no per-pick jit dispatch).
    # placement_bench.py records the scanned/stepped/host columns and
    # the ~30× oracle-level gap at 10⁴.
    hg, t_hg = timed(lambda: greedy(inst))
    dinst = DeviceInstance.from_instance(inst, materialize_ca=False)
    dg, t_dg = timed(lambda: device_greedy(dinst))
    out["placement_refresh"] = {
        "host_greedy_s": t_hg, "device_greedy_s": t_dg,
        "speedup": t_hg / t_dg,
        "allocations_equal": bool(np.array_equal(hg, dg))}
    csv_line(f"fig78/placement_refresh/O{n_items}", t_dg * 1e6,
             f"host_s={t_hg:.3f},speedup={t_hg/t_dg:.2f}x,"
             f"equal={out['placement_refresh']['allocations_equal']}")

    # Fig 7 right: constrained variant, sweep d*
    slot_cache = inst.slot_cache
    best = None
    rows = []
    for dstar in dstars:
        allowed = np.zeros((inst.net.total_slots, inst.cat.n), dtype=bool)
        allowed[slot_cache == 0] = radii[None, :] < dstar
        allowed[slot_cache == 1] = radii[None, :] >= dstar
        st, tc = timed(lambda: constrained_localswap(
            inst, allowed, n_iters=ls_iters, seed=0))
        c = st.cost(inst)
        rows.append({"dstar": dstar, "cost": c, "t_s": tc})
        csv_line(f"fig78/constrained/dstar={dstar:g}", tc * 1e6,
                 f"cost={c:.2f}")
        if best is None or c < best[1]:
            best = (dstar, c)
    out["fig7_constrained"] = {"sweep": rows, "best_dstar": best[0],
                               "best_cost": best[1]}
    # paper: +1% on the real trace; the synthetic stand-in's geometry is
    # harsher (popularity fully ⟂ radius), so the check allows 15% — the
    # qualitative claim is that the simple d* rule stays close to optimal
    out["checks"]["constrained close to unconstrained (<15%)"] = \
        best[1] <= cost_u * 1.15
    out["constrained_overhead_pct"] = 100.0 * (best[1] / cost_u - 1.0)
    save_json("fig78.json", out)
    return out


if __name__ == "__main__":
    r = run()
    print(r["checks"], "overhead", r["constrained_overhead_pct"])
