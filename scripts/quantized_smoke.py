"""Quantized-path CI smoke: the int8 first-pass lookup must be
bit-identical to the exact fused scan through whatever mesh is visible.

Run from scripts/ci.sh in both passes — 1-way in the default pass, a
real 8-way request-axis sharding under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — asserting

  * ``lookup(quantize=True, verify=True)`` == exact fused, bitwise;
  * composed with LSH pruning (gather → int8 sub-cut), still bitwise;
  * the unverified path stays admissible (cost ≥ exact, ≤ h_repo).

``--full`` (the CI_FULL nightly gate) scales the differential to 10⁶
keys, quantized + pruned + sharded at once — the headline configuration
of results/bench/kernels.json, checked for exactness rather than speed.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.simcache import CacheLevel, SimCacheNetwork
from repro.kernels.knn import SimHashPolicy
from repro.launch.mesh import make_lookup_mesh


def build(levels, sharded: bool, policy=None):
    kw = dict(sharded=True, mesh=make_lookup_mesh(jax.device_count())) \
        if sharded else {}
    return SimCacheNetwork(levels=levels, h_repo=1e9, metric="l2",
                           candidate_policy=policy, **kw)


def assert_bitwise(got, want, label: str):
    for f in ("level", "slot", "payload", "cost", "approx_cost"):
        a, b = np.asarray(getattr(got, f)), np.asarray(getattr(want, f))
        assert np.array_equal(a, b), f"{label}: field {f} diverged"


def main(full: bool) -> None:
    n = 1_000_000 if full else 20_000
    d, b = 64, 64
    rng = np.random.default_rng(0)
    pol = SimHashPolicy(n_tables=4, n_bits=16 if full else 11,
                        n_probes=2, max_candidates=16384 if full else 4096)
    coords = rng.standard_normal((n, d)).astype(np.float32)
    half = n // 2
    levels = [CacheLevel(keys=jnp.asarray(coords[:half]),
                         values=jnp.asarray(
                             np.arange(half, dtype=np.int32)), h=0.0),
              CacheLevel(keys=jnp.asarray(coords[half:]),
                         values=jnp.asarray(
                             np.arange(half, n, dtype=np.int32)), h=0.5)]
    net = build(levels, sharded=False)
    snet = build(levels, sharded=True, policy=pol)
    q = jnp.asarray(coords[rng.integers(0, n, b)]
                    + 0.05 * rng.standard_normal((b, d)).astype(np.float32))
    exact = net._lookup_fused(q)
    shards = jax.device_count()

    got = snet.lookup(q, quantize=True, verify=True)
    assert_bitwise(got, exact, f"quantize+verify ({shards}-way)")
    got = snet.lookup(q, prune="lsh", quantize=True, verify=True)
    assert_bitwise(got, exact, f"quantize+lsh+verify ({shards}-way)")
    raw = snet.lookup(q, quantize=True)
    assert np.all(np.asarray(raw.cost) >= np.asarray(exact.cost))
    assert np.all(np.asarray(raw.cost) <= 1e9 + 1e-6)
    print(f"quantized smoke OK: n={n}, {shards}-way mesh, "
          "verify bitwise + lsh composition + admissibility")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="10⁶-key quantized+pruned+sharded differential")
    main(ap.parse_args().full)
