#!/usr/bin/env bash
# Tier-1 verification, exactly as ROADMAP.md specifies. pytest exits
# non-zero on collection errors (e.g. a missing optional dependency
# breaking an import at collection time), so this script fails fast on
# the class of regression that once left five modules uncollectable.
#
# Pass 2 is a second full tier-1 run under 8 forced host devices so the
# in-process mesh tests (skipif device_count < 8) actually execute in
# CI: the sharded-vs-fused-vs-looped differential suite runs on a real
# 8-way mesh, not only through its subprocess harness — and the whole
# suite is exercised multi-device. The *_subprocess tests spawn a fresh
# interpreter that forces its own 8 devices whatever the parent sees,
# so rerunning them here adds nothing; deselect them to save their
# interpreter + jax startup cost. Same -x -q flags, so collection
# errors still fail the build.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -m pytest -x -q "$@"
XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    python -m pytest -x -q -k "not _subprocess" "$@"
