#!/usr/bin/env bash
# Tier-1 verification, exactly as ROADMAP.md specifies. pytest exits
# non-zero on collection errors (e.g. a missing optional dependency
# breaking an import at collection time), so this script fails fast on
# the class of regression that once left five modules uncollectable.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -m pytest -x -q "$@"
