#!/usr/bin/env bash
# Tier-1 verification, exactly as ROADMAP.md specifies. pytest exits
# non-zero on collection errors (e.g. a missing optional dependency
# breaking an import at collection time), so this script fails fast on
# the class of regression that once left five modules uncollectable.
#
# Pass 2 is a second full tier-1 run under 8 forced host devices so the
# in-process mesh tests (skipif device_count < 8) actually execute in
# CI: both the sharded-vs-fused-vs-looped differential suite *and* the
# LSH/k-means pruning suite (tests/test_lsh_pruning.py) run on a real
# 8-way mesh, not only through the subprocess harness / 1-device mesh —
# and the whole suite is exercised multi-device. The *_subprocess tests
# spawn a fresh interpreter that forces its own 8 devices whatever the
# parent sees, so rerunning them here adds nothing; deselect them to
# save their interpreter + jax startup cost. Same -x -q flags, so
# collection errors still fail the build.
#
# Marker split: both default passes deselect `-m "not slow"` — the
# slow-marked tests (e.g. the 10⁶-key LSH recall test) are additionally
# env-gated and run only in the nightly/full pass, opted in with
# CI_FULL=1 (which drops the marker filter from pass 1 and opens the
# env gate). Pass 2 keeps the deselect even then: the slow tests are
# device-count independent, so rerunning them 8-way adds nothing —
# the same rationale as the *_subprocess deselect.
#
# The differential placement suites (tests/test_device_placement.py —
# device GREEDY/LOCALSWAP bit-identical to the NumPy oracles — and
# tests/test_netduel_device.py — the scanned device NETDUEL
# bit-identical to the host §5 policy) run in BOTH passes under
# -m "not slow": their mesh tests build over every visible device, so
# pass 1 exercises the 1-shard oracles and pass 2 the real 8-way
# candidate/request-axis sharding (sharded_placement_gains +
# sharded_best_two). The trace-replay golden test
# (tests/test_trace_replay.py, EngineConfig.netduel end-to-end) and the
# control-plane property tests ride the same passes. The warm-start
# gap suite (tests/test_warmstart.py — measured optimality gaps of the
# §4 continuous-limit pipeline vs device-GREEDY) rides them too, plus a
# smoke row of its bench below. The nightly CI_FULL pass additionally
# (i) opens the env gate of the 10⁵-object NETDUEL window
# (tests/test_netduel_device.py::test_netduel_large_window_smoke —
# slow-marked, device-only: no host C_a can exist at that size) and the
# 10⁶-object warm-start run (tests/test_warmstart.py::
# test_warmstart_1e6_objects), (ii) runs the placement and
# warm-start benchmarks with their FULL gates open: the 10⁵-candidate
# gain-oracle row, the 10⁵ device-only NETDUEL window row, and the
# 10⁶-object warm-start headline (≥10× faster than device-GREEDY at
# its feasibility frontier, asserted in-bench), and (iii) scales the
# quantized smoke to the 10⁶-key quantized+pruned+sharded differential.
#
# The quantized-path smoke (scripts/quantized_smoke.py) runs after each
# pytest pass: ``lookup(quantize=True, verify=True)`` and its LSH
# composition must be bit-identical to the exact fused scan — 1-way in
# pass 1, through a real 8-way mesh after pass 2.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
MARKER=(-m "not slow")
if [[ "${CI_FULL:-0}" == "1" ]]; then
    MARKER=()
fi
python -m pytest -x -q ${MARKER[@]+"${MARKER[@]}"} "$@"
# quantized-path smoke, 1-way: int8 first-pass lookup bit-identical to
# the exact fused scan (verify + LSH composition, asserted in-script)
python scripts/quantized_smoke.py
XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    python -m pytest -x -q -m "not slow" -k "not _subprocess" "$@"
# same quantized smoke through a real 8-way request-axis sharding
XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    python scripts/quantized_smoke.py
# streaming serving smoke: bucketed-vs-unbucketed speedup, driver rows,
# and the swap-stall bound are asserted inside the bench itself
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    python benchmarks/serving_bench.py --smoke
# warm-start smoke: O=1024 gap rows vs device-GREEDY, all three
# topology classes — the gap bound is asserted inside the bench
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    python benchmarks/warmstart_bench.py --smoke
# general-graph smoke: paper-GREEDY vs on-path LRU strategies over the
# three graph families — the repo-baseline check is asserted in-bench
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    python benchmarks/graphs_bench.py --smoke
# analytic hit-rate smoke: Che predictions vs measured SIM/RND-LRU
# replays — the ≤5%-absolute Zipf bound is asserted in-bench
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    python benchmarks/hitrate_bench.py --smoke
if [[ "${CI_FULL:-0}" == "1" ]]; then
    # 10⁶-key quantized+pruned+sharded differential (bitwise, in-script)
    XLA_FLAGS="--xla_force_host_platform_device_count=8" \
        python scripts/quantized_smoke.py --full
    PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" PLACEMENT_BENCH_FULL=1 \
        python benchmarks/placement_bench.py
    # nightly serving sweep: more distinct sizes, longer driver runs
    PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" SERVING_BENCH_FULL=1 \
        python benchmarks/serving_bench.py
    # 10⁶-object warm-start headline (speedup-vs-frontier asserted)
    PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" WARMSTART_BENCH_FULL=1 \
        python benchmarks/warmstart_bench.py
    # full general-graph sweep: 4k objects, 40k-request traces
    PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" GRAPHS_BENCH_FULL=1 \
        python benchmarks/graphs_bench.py
    # 10⁶-object analytic path: LSH ball enumeration + the Che solve
    PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" HITRATE_BENCH_FULL=1 \
        python benchmarks/hitrate_bench.py
fi
