"""AdamW with optional quantized moments (distributed-optimization trick).

At 398B parameters, f32 Adam moments alone are 3.2 TB; per-chip state is
the binding constraint for the train_4k cells (EXPERIMENTS.md §Dry-run).
``moment_dtype="int8"`` stores m and v as int8 with per-row f32 scales
(blockwise over the trailing dim — the 8-bit-Adam recipe), cutting
optimizer state from 8 to ~2.06 bytes/param with negligible quality loss
at these batch sizes. Moments inherit the parameter PartitionSpecs, so
the state is fully sharded (ZeRO-style) over data×model.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"     # float32 | bfloat16 | int8


# ----------------------------------------------------- int8 moment codec
def _q8_encode(x: jax.Array) -> dict:
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-20)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "s": scale.astype(jnp.float32)}


def _q8_decode(e: dict) -> jax.Array:
    return e["q"].astype(jnp.float32) * e["s"]


def _encode(x: jax.Array, dtype: str):
    if dtype == "int8":
        return _q8_encode(x)
    return x.astype(jnp.dtype(dtype))


def _decode(e: Any, dtype: str) -> jax.Array:
    if dtype == "int8":
        return _q8_decode(e)
    return e.astype(jnp.float32)


# ------------------------------------------------------------- optimizer
def adamw_init(params: Any, cfg: AdamWConfig) -> dict:
    def zero_like(p):
        z = jnp.zeros(p.shape, jnp.float32)
        return _encode(z, cfg.moment_dtype)
    return {
        "m": jax.tree.map(zero_like, params),
        "v": jax.tree.map(zero_like, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(grads: Any, state: dict, params: Any, cfg: AdamWConfig,
                 lr_scale: jax.Array | float = 1.0) -> tuple[Any, dict]:
    """One AdamW step. Returns (new_params, new_state)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-12))

    is_moment = lambda t: isinstance(t, dict) and "q" in t   # noqa: E731

    def upd(p, g, m_e, v_e):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * _decode(m_e, cfg.moment_dtype) + (1 - cfg.b1) * g
        v = cfg.b2 * _decode(v_e, cfg.moment_dtype) + (1 - cfg.b2) * g * g
        mh = m / (1 - cfg.b1 ** step.astype(jnp.float32))
        vh = v / (1 - cfg.b2 ** step.astype(jnp.float32))
        upd = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - cfg.lr * lr_scale * upd
                 ).astype(p.dtype)
        return new_p, _encode(m, cfg.moment_dtype), _encode(v, cfg.moment_dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    _ = is_moment
    return new_params, {"m": new_m, "v": new_v, "step": step}
