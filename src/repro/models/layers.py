"""Transformer building blocks: norms, rotary embeddings (RoPE + M-RoPE),
grouped-query attention (train/prefill/decode paths), SwiGLU MLP.

All functions are pure and shape-polymorphic; sharding is applied by the
caller via logical-axis constraints (launch/sharding.py). Softmax and
normalization statistics are computed in f32 regardless of the compute
dtype (bf16 on TPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# ------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """(head_dim/2,) inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 1e4) -> jax.Array:
    """Standard RoPE. x: (B, S, H, Dh); positions: (B, S) int32."""
    dh = x.shape[-1]
    inv = rope_freqs(dh, theta)                          # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (B, S, Dh/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, sections: tuple,
                theta: float = 1e4) -> jax.Array:
    """Multimodal RoPE (Qwen2-VL): ``positions`` is (3, B, S) —
    temporal / height / width ids; the Dh/2 frequency pairs are split
    into ``sections`` (e.g. (16, 24, 24) for Dh=128), each rotated by its
    own position stream."""
    dh = x.shape[-1]
    assert sum(sections) == dh // 2, (sections, dh)
    inv = rope_freqs(dh, theta)                          # (Dh/2,)
    # per-frequency position stream: section s uses positions[s]
    sec_ids = jnp.repeat(jnp.arange(3), jnp.array(sections),
                         total_repeat_length=dh // 2)    # (Dh/2,)
    pos = positions.astype(jnp.float32)                  # (3, B, S)
    pos_per_freq = pos[sec_ids]                          # (Dh/2, B, S)
    ang = jnp.moveaxis(pos_per_freq, 0, -1) * inv        # (B, S, Dh/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# -------------------------------------------------------------- attention
def gqa_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True,
                  q_offset: jax.Array | int = 0,
                  kv_len: jax.Array | None = None) -> jax.Array:
    """Grouped-query attention.

    q: (B, Sq, H, Dh); k, v: (B, Skv, KH, Dh) with H % KH == 0.
    ``q_offset`` positions the query block inside the kv timeline (decode:
    q_offset = current length − Sq). ``kv_len`` masks out cache slots
    beyond the valid length (decode with a statically-shaped cache).
    Returns (B, Sq, H, Dh). Softmax in f32.
    """
    B, Sq, H, Dh = q.shape
    Skv, KH = k.shape[1], k.shape[2]
    G = H // KH
    qg = q.reshape(B, Sq, KH, G, Dh)
    scale = 1.0 / jnp.sqrt(jnp.float32(Dh))
    scores = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale   # (B,KH,G,Sq,Skv)
    tpos = jnp.arange(Skv)[None, :]
    neg = jnp.float32(-1e30)
    if causal:
        qpos = q_offset + jnp.arange(Sq)[:, None]
        scores = jnp.where(tpos <= qpos, scores, neg)
    if kv_len is not None:
        scores = jnp.where(tpos < kv_len, scores, neg)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, Dh).astype(q.dtype)


# ------------------------------------------------------------------- MLP
def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    """SwiGLU: (silu(x·Wg) ⊙ (x·Wu))·Wd. Weights: (D, F), (D, F), (F, D)."""
    g = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, w_gate.astype(x.dtype)))
    u = jnp.einsum("bsd,df->bsf", x, w_up.astype(x.dtype))
    return jnp.einsum("bsf,fd->bsd", g * u, w_down.astype(x.dtype))


def gelu_mlp(x: jax.Array, w_up: jax.Array, b_up: jax.Array,
             w_down: jax.Array, b_down: jax.Array) -> jax.Array:
    """Whisper-style GELU MLP with biases."""
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, w_up.astype(x.dtype))
                    + b_up.astype(x.dtype))
    return jnp.einsum("bsf,fd->bsd", h, w_down.astype(x.dtype)) \
        + b_down.astype(x.dtype)
