"""Mixture-of-Experts layer: top-k routing with grouped einsum dispatch.

GShard-style static-capacity dispatch, adapted for TPU SPMD:

  * tokens are split into groups of ``group_size`` so the one-hot
    dispatch/combine tensors stay (G, Tg, E, Cg) with Tg small — memory
    O(T·E·Cg/G) instead of O(T·E·C) (the classic GShard memory cliff);
  * experts run as one stacked einsum over the expert axis, which shards
    cleanly over the mesh "model"/"experts" axis (expert parallelism);
    the combine einsum contracts the expert axis → one all-reduce, the
    canonical EP collective;
  * capacity C_g = ceil(Tg · k · capacity_factor / E); overflow tokens
    are dropped (their residual passes through — standard behaviour);
  * the router computes in f32 and returns the Switch-style load-balance
    auxiliary loss.

Expert-axis sharding requires E % mesh_model == 0; the resolver
(launch/sharding.py) otherwise falls back to within-expert d_ff sharding
(granite-moe: 40 experts, d_ff=512).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _capacity(tg: int, k: int, e: int, cf: float) -> int:
    if cf <= 0:                        # no-drop mode (decode): worst case
        return tg * k
    c = int(tg * k * cf / e) + 1
    return max(c, 1)


def _route(xt_2d: jax.Array, router: jax.Array, topk: int):
    """Shared routing: top-k gates + Switch aux loss. xt_2d: (T, D)."""
    E = router.shape[1]
    logits = jnp.einsum("td,de->te", xt_2d.astype(jnp.float32),
                        router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                 # (T, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, topk)        # (T, K)
    gate_vals = gate_vals / (jnp.sum(gate_vals, -1, keepdims=True) + 1e-9)
    me = jnp.mean(probs, axis=0)
    fe = jnp.mean(jax.nn.one_hot(gate_idx[..., 0], E), axis=0)
    aux = E * jnp.sum(me * fe)
    return gate_vals, gate_idx, aux


def _positions_in_expert(gate_idx: jax.Array, E: int, cap: int):
    """Capacity assignment, sequential over the K choices.
    gate_idx: (..., T, K) → (pos_in_expert (..., T, K), keep mask)."""
    T_axis = -2
    counts = None
    poss, keeps = [], []
    K = gate_idx.shape[-1]
    for k in range(K):
        idx_k = gate_idx[..., k]
        mask_k = jax.nn.one_hot(idx_k, E, dtype=jnp.int32)   # (..., T, E)
        base = jnp.cumsum(mask_k, axis=T_axis) - mask_k
        if counts is not None:
            base = base + counts[..., None, :]
        pos_k = jnp.sum(base * mask_k, axis=-1)              # (..., T)
        poss.append(pos_k)
        keeps.append(pos_k < cap)
        counts = (0 if counts is None else counts) + \
            jnp.sum(mask_k, axis=T_axis)
    return jnp.stack(poss, -1), jnp.stack(keeps, -1)


def _moe_einsum(x, router, we_gate, we_up, we_down, topk, capacity_factor,
                group_size, shard):
    """GShard-style grouped one-hot dispatch (the TPU-classic baseline).

    The dispatch/combine einsums cost T·E_loc·Cg·D each — under expert
    sharding this does NOT shrink with E, so it can exceed the expert
    matmuls themselves (the known GShard dispatch tax; quantified in
    EXPERIMENTS.md §Perf, where the gather path removes it)."""
    B, S, D = x.shape
    E = router.shape[1]
    T = B * S
    g = min(group_size, T)
    while T % g:                       # group size must divide tokens
        g -= 1
    G, Tg = T // g, g
    Cg = _capacity(Tg, topk, E, capacity_factor)
    xt = x.reshape(G, Tg, D)

    gate_vals, gate_idx, aux = _route(x.reshape(T, D), router, topk)
    gate_vals = gate_vals.reshape(G, Tg, -1)
    gate_idx = gate_idx.reshape(G, Tg, -1)
    pos, keep = _positions_in_expert(gate_idx, E, Cg)        # (G, Tg, K)

    dispatch = jnp.zeros((G, Tg, E, Cg), x.dtype)
    combine = jnp.zeros((G, Tg, E, Cg), jnp.float32)
    for k in range(gate_idx.shape[-1]):
        mask_k = jax.nn.one_hot(gate_idx[..., k], E, dtype=x.dtype)
        oh_pos = jax.nn.one_hot(jnp.where(keep[..., k], pos[..., k], Cg),
                                Cg, dtype=x.dtype)
        sel = mask_k[..., None] * oh_pos[..., None, :]
        dispatch = dispatch + sel
        combine = combine + sel.astype(jnp.float32) * \
            (gate_vals[..., k] * keep[..., k])[..., None, None]

    xe = jnp.einsum("gtec,gtd->egcd", dispatch, xt)          # (E, G, Cg, D)
    if shard is not None:
        xe = shard(xe, ("experts", "moe_group", None, "embed"))
    h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", xe,
                               we_gate.astype(x.dtype)))
    h = h * jnp.einsum("egcd,edf->egcf", xe, we_up.astype(x.dtype))
    ye = jnp.einsum("egcf,efd->egcd", h, we_down.astype(x.dtype))
    if shard is not None:
        ye = shard(ye, ("experts", "moe_group", None, "embed"))
    y = jnp.einsum("gtec,egcd->gtd", combine.astype(x.dtype), ye)
    return y.reshape(B, S, D), aux


def _moe_gather(x, router, we_gate, we_up, we_down, topk, capacity_factor,
                group_size, shard):
    """Grouped gather/scatter dispatch (beyond-paper optimization, §Perf).

    Replaces the O(T·E·Cg·D) one-hot dispatch/combine einsums with
    O(slots·D) batched gathers. Groups follow the token (batch) sharding,
    so every scatter/gather stays shard-local under SPMD — the only MoE
    collective left is the expert-contraction all-reduce. Dispatch FLOPs
    ≈ 0 (pure data movement); capacity/drop semantics identical to the
    einsum path (same _positions_in_expert)."""
    B, S, D = x.shape
    E = router.shape[1]
    T = B * S
    g = min(group_size, T)
    while T % g:
        g -= 1
    G, Tg = T // g, g
    Cg = _capacity(Tg, topk, E, capacity_factor)
    xt = x.reshape(G, Tg, D)

    gate_vals, gate_idx, aux = _route(x.reshape(T, D), router, topk)
    gate_vals = gate_vals.reshape(G, Tg, -1)                 # (G, Tg, K)
    gate_idx = gate_idx.reshape(G, Tg, -1)
    pos, keep = _positions_in_expert(gate_idx, E, Cg)        # (G, Tg, K)

    slot = gate_idx * Cg + pos                               # (G, Tg, K)
    slot = jnp.where(keep, slot, E * Cg)                     # overflow slot
    tok_ids = jnp.broadcast_to(
        jnp.arange(Tg, dtype=jnp.int32)[None, :, None], slot.shape)
    token_of_slot = jnp.zeros((G, E * Cg), jnp.int32)
    token_of_slot = token_of_slot.at[
        jnp.arange(G, dtype=jnp.int32)[:, None],
        slot.reshape(G, -1)].set(tok_ids.reshape(G, -1), mode="drop")

    xe = jnp.take_along_axis(xt, token_of_slot[..., None], axis=1)
    xe = xe.reshape(G, E, Cg, D)
    if shard is not None:
        xe = shard(xe, ("moe_group", "experts", None, "embed"))
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe,
                               we_gate.astype(x.dtype)))
    h = h * jnp.einsum("gecd,edf->gecf", xe, we_up.astype(x.dtype))
    ye = jnp.einsum("gecf,efd->gecd", h, we_down.astype(x.dtype))
    if shard is not None:
        ye = shard(ye, ("moe_group", "experts", None, "embed"))
    ye_flat = ye.reshape(G, E * Cg, D)
    picked = jnp.take_along_axis(
        ye_flat, jnp.minimum(slot.reshape(G, -1), E * Cg - 1)[..., None],
        axis=1).reshape(G, Tg, -1, D)
    picked = jnp.where(keep[..., None], picked, 0.0)
    y = jnp.sum(picked * gate_vals[..., None].astype(x.dtype), axis=2)
    return y.reshape(B, S, D), aux


def moe_mlp(x: jax.Array, router: jax.Array, we_gate: jax.Array,
            we_up: jax.Array, we_down: jax.Array, topk: int,
            capacity_factor: float = 1.25, group_size: int = 512,
            dispatch: str = "einsum", shard=None
            ) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) → (y, aux_loss). Expert weights: (E, D, F)/(E, F, D)."""
    if dispatch == "gather":
        return _moe_gather(x, router, we_gate, we_up, we_down, topk,
                           capacity_factor, group_size, shard)
    return _moe_einsum(x, router, we_gate, we_up, we_down, topk,
                       capacity_factor, group_size, shard)
