"""Public model API: build (init, loss, train-forward, serve-step) from an
ArchConfig. This is the single entry point used by the trainer, the
serving engine, the dry-run and the smoke tests.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import encdec, schema, transformer
from repro.models.sharding_api import NO_SHARD, ShardPolicy

AUX_LOSS_WEIGHT = 0.01
Z_LOSS_WEIGHT = 1e-4


def init_params(cfg: ArchConfig, seed: int = 0) -> dict:
    return schema.init_params(cfg, jax.random.PRNGKey(seed))


def _forward(cfg, params, batch, *, mode, caches, pos, shard):
    if cfg.is_encdec:
        return encdec.encdec_forward(cfg, params, batch, mode=mode,
                                     caches=caches, pos=pos, shard=shard)
    return transformer.forward(cfg, params, batch, mode=mode, caches=caches,
                               pos=pos, shard=shard)


def loss_fn(cfg: ArchConfig, params: dict, batch: dict,
            shard: ShardPolicy = NO_SHARD) -> tuple[jax.Array, dict]:
    """Token cross-entropy (+ MoE aux loss + z-loss). ``batch`` needs
    ``tokens`` (B, S) and ``labels`` (B, S_lab); an optional ``loss_mask``
    zeroes out positions (padding / image prefix / prompt)."""
    logits, _, aux = _forward(cfg, params, batch, mode="train", caches=None,
                              pos=0, shard=shard)
    labels = batch["labels"]
    # logits cover the full input sequence; score the last S_lab positions
    # (vlm: image prefix is unscored by construction)
    S_lab = labels.shape[1]
    logits = logits[:, -S_lab:, :]
    logits_f = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits_f, axis=-1)
    ll = jnp.take_along_axis(logits_f, labels[..., None], axis=-1)[..., 0]
    nll = logz - ll
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones_like(nll)
    else:
        mask = mask.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    ce = jnp.sum(nll * mask) / denom
    zloss = jnp.sum((logz ** 2) * mask) / denom
    total = ce + AUX_LOSS_WEIGHT * aux + Z_LOSS_WEIGHT * zloss
    return total, {"ce": ce, "aux": aux, "zloss": zloss}


def make_train_forward(cfg: ArchConfig, shard: ShardPolicy = NO_SHARD
                       ) -> Callable:
    """(params, batch) → (loss, metrics); jit/pjit-able."""
    return functools.partial(loss_fn, cfg, shard=shard)


def make_prefill(cfg: ArchConfig, shard: ShardPolicy = NO_SHARD) -> Callable:
    def prefill(params, batch):
        logits, caches, _ = _forward(cfg, params, batch, mode="prefill",
                                     caches=None, pos=0, shard=shard)
        return logits, caches
    return prefill


def make_serve_step(cfg: ArchConfig, shard: ShardPolicy = NO_SHARD
                    ) -> Callable:
    """One decode step: (params, tokens (B,1), caches, pos) →
    (logits (B, 1, V), new caches). ``pos`` is the current sequence
    length (the new token's position)."""
    def serve_step(params, tokens, caches, pos):
        B = tokens.shape[0]
        batch = {"tokens": tokens,
                 "positions": jnp.full((B, 1), pos, jnp.int32)}
        if cfg.mrope:
            batch["mrope_positions"] = jnp.full((3, B, 1), pos, jnp.int32)
        logits, new_caches, _ = _forward(cfg, params, batch, mode="decode",
                                         caches=caches, pos=pos, shard=shard)
        return logits, new_caches
    return serve_step


def init_cache(cfg: ArchConfig, batch_size: int, max_len: int) -> Any:
    return transformer.init_cache(cfg, batch_size, max_len)


def greedy_generate(cfg: ArchConfig, params: dict, prompt: jax.Array,
                    n_steps: int, max_len: int | None = None,
                    shard: ShardPolicy = NO_SHARD) -> jax.Array:
    """Tiny reference sampler (greedy argmax) used by examples/tests."""
    B, S = prompt.shape
    max_len = max_len or (S + n_steps)
    prefill = jax.jit(make_prefill(cfg, shard))
    step = jax.jit(make_serve_step(cfg, shard))
    batch = {"tokens": prompt}
    if cfg.mrope:
        pos = jnp.broadcast_to(jnp.arange(S)[None, None, :], (3, B, S))
        batch["mrope_positions"] = pos
    if cfg.is_encdec:
        raise NotImplementedError("use the serving engine for enc-dec")
    logits, caches = prefill(params, batch)
    # pad the prefill cache out to max_len so decode can extend it
    caches = _pad_caches(cfg, caches, max_len)
    tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
    out = [tok]
    for t in range(n_steps - 1):
        logits, caches = step(params, tok, caches, S + t)
        tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def _pad_caches(cfg: ArchConfig, caches: Any, max_len: int) -> Any:
    """Pad prefill KV caches along the sequence axis to ``max_len``.
    Only the self-attention "k"/"v" leaves grow; SSM/xLSTM states and
    cross-attention caches are fixed-size."""
    from repro.models.transformer import _kv_quant

    def pad_entry(block_cache: dict) -> dict:
        out = dict(block_cache)
        for key in ("k", "v"):
            if key not in out:
                continue
            x = out[key]
            if cfg.kv_cache_dtype == "int8" and x.dtype != jnp.int8:
                q, sc = _kv_quant(x)
                out[key], out[key + "_s"] = q, sc
                x = q
            if x.shape[2] < max_len:
                pad = ((0, 0), (0, 0), (0, max_len - x.shape[2]),
                       (0, 0), (0, 0))
                out[key] = jnp.pad(out[key], pad)
                if key + "_s" in out:
                    out[key + "_s"] = jnp.pad(out[key + "_s"], pad)
        return out
    return {bk: pad_entry(bc) for bk, bc in caches.items()}
