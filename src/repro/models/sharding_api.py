"""Sharding policy interface the models are written against.

Models never import mesh machinery; they call ``shard(x, logical_axes)``
and consult the few strategy knobs below. launch/sharding.py provides a
mesh-aware implementation; the default is a no-op (single device smoke
tests, examples).

Attention strategies (resolved per arch × mode by launch/sharding.py):
  * "heads"  — classic TP: q heads over the model axis; KV heads are
    repeated to the TP degree when kv < tp (GQA), so both operands of the
    attention einsums carry the model axis (no redundant compute);
  * "batch"  — DP attention: batch over (data×model) inside the attention
    sublayer only (archs whose head count doesn't divide the model axis:
    deepseek-coder 56H, phi3 40H, qwen2-vl 28H, whisper 12H);
  * "kv_seq" — decode: the KV cache (and score) sequence axis over the
    model axis — distributed flash-decode; partial softmax combines via
    the all-reduce XLA inserts;
  * "none"   — no attention-specific sharding (smoke/CPU).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShardPolicy:
    attn_strategy: str = "none"      # heads | batch | kv_seq | none
    kv_repeat: int = 1               # KV head repetition under heads-TP

    def __call__(self, x, axes):
        """Apply a sharding constraint for logical ``axes``; no-op here."""
        return x


NO_SHARD = ShardPolicy()
