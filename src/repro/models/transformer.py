"""Decoder-only LM stack covering the dense / moe / hybrid / ssm / vlm
families: scan-over-super-blocks, GQA attention with pluggable sharding
strategies, MoE, Mamba, xLSTM mixers, RoPE / M-RoPE.

Modes:
  * "train"   — full-sequence teacher forcing, optional remat per block;
  * "prefill" — like train but returns the serving cache (KV / SSM state);
  * "decode"  — one token per call against a statically-shaped cache.

The cache is a dict keyed like params["blocks"] with per-kind leaves
stacked on the scanned super-block axis (see init_cache).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers
from repro.models.moe import moe_mlp
from repro.models.schema import block_pattern
from repro.models.sharding_api import NO_SHARD, ShardPolicy
from repro.models.ssm import mamba_mixer, mlstm_mixer, slstm_mixer

Cache = Any


# ----------------------------------------------------------- sub-layers --
def _kv_quant(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric int8 per-(token, head) quantization for KV caches."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-10)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _attention(cfg: ArchConfig, p: dict, x: jax.Array, positions, mode: str,
               cache: dict | None, pos, shard: ShardPolicy,
               mrope_pos=None, pfx: str = "", cross_src=None,
               causal: bool = True):
    """Attention sublayer (self or cross). Returns (out, new_cache)."""
    dt = x.dtype
    h = layers.rms_norm(x, p[f"{pfx}attn_norm"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhe->bshe", h, p[f"{pfx}wq"].astype(dt))
    if cfg.qkv_bias and f"{pfx}bq" in p:
        q = q + p[f"{pfx}bq"].astype(dt)

    if pfx == "x":
        # cross attention: K/V from the encoder output (cached at prefill,
        # read back from the cache during decode)
        if cross_src is None:
            assert cache is not None and "xk" in cache
            k, v = cache["xk"].astype(dt), cache["xv"].astype(dt)
            new_cache = {"xk": cache["xk"], "xv": cache["xv"]}
        else:
            k = jnp.einsum("bsd,dhe->bshe", cross_src, p[f"{pfx}wk"].astype(dt))
            v = jnp.einsum("bsd,dhe->bshe", cross_src, p[f"{pfx}wv"].astype(dt))
            new_cache = {"xk": k, "xv": v} if mode == "prefill" else {}
        q = shard(q, ("attn_batch", "attn_seq", "heads", "head_dim"))
        out = layers.gqa_attention(q, k, v, causal=False)
    else:
        k = jnp.einsum("bsd,dhe->bshe", h, p[f"{pfx}wk"].astype(dt))
        v = jnp.einsum("bsd,dhe->bshe", h, p[f"{pfx}wv"].astype(dt))
        if cfg.qkv_bias and f"{pfx}bk" in p:
            k = k + p[f"{pfx}bk"].astype(dt)
            v = v + p[f"{pfx}bv"].astype(dt)
        if cfg.mrope and mrope_pos is not None:
            q = layers.apply_mrope(q, mrope_pos, cfg.mrope_sections,
                                   cfg.rope_theta)
            k = layers.apply_mrope(k, mrope_pos, cfg.mrope_sections,
                                   cfg.rope_theta)
        else:
            q = layers.apply_rope(q, positions, cfg.rope_theta)
            k = layers.apply_rope(k, positions, cfg.rope_theta)

        new_cache = {}
        if mode == "decode":
            assert cache is not None
            q8 = cfg.kv_cache_dtype == "int8"
            if q8:
                # quantized KV cache: int8 payload + per-(token, head)
                # f32 scales — halves the decode HBM floor (§Perf)
                kq, ks = _kv_quant(k)
                vq, vs = _kv_quant(v)
                upd = jax.lax.dynamic_update_slice
                kc = upd(cache["k"], kq, (0, pos, 0, 0))
                vc = upd(cache["v"], vq, (0, pos, 0, 0))
                ksc = upd(cache["k_s"], ks, (0, pos, 0, 0))
                vsc = upd(cache["v_s"], vs, (0, pos, 0, 0))
                new_cache = {"k": kc, "v": vc, "k_s": ksc, "v_s": vsc}
                kf = kc.astype(dt) * ksc.astype(dt)
                vf = vc.astype(dt) * vsc.astype(dt)
            else:
                kc = jax.lax.dynamic_update_slice(
                    cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
                vc = jax.lax.dynamic_update_slice(
                    cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
                new_cache = {"k": kc, "v": vc}
                kf, vf = kc.astype(dt), vc.astype(dt)
            kf = shard(kf, ("batch", "kv_seq", "kv_heads", "head_dim"))
            vf = shard(vf, ("batch", "kv_seq", "kv_heads", "head_dim"))
            q = shard(q, ("attn_batch", "attn_seq", "heads", "head_dim"))
            out = layers.gqa_attention(q, kf, vf,
                                       causal=False, kv_len=pos + 1)
        else:
            if mode == "prefill":
                new_cache = {"k": k, "v": v}
            if shard.kv_repeat > 1:
                k = jnp.repeat(k, shard.kv_repeat, axis=2)
                v = jnp.repeat(v, shard.kv_repeat, axis=2)
            q = shard(q, ("attn_batch", "attn_seq", "heads", "head_dim"))
            k = shard(k, ("attn_batch", "attn_seq", "rep_kv_heads", "head_dim"))
            v = shard(v, ("attn_batch", "attn_seq", "rep_kv_heads", "head_dim"))
            if cfg.use_flash_attention:
                from repro.kernels.flash_attention import flash_attention
                out = flash_attention(q, k, v, causal=causal)
            else:
                out = layers.gqa_attention(q, k, v, causal=causal)
    out = shard(out, ("attn_batch", "attn_seq", "heads", "head_dim"))
    y = jnp.einsum("bshe,hed->bsd", out, p[f"{pfx}wo"].astype(dt))
    return shard(y, ("batch", "seq", "embed")), new_cache


def _mlp(cfg: ArchConfig, p: dict, x: jax.Array, shard: ShardPolicy):
    h = layers.rms_norm(x, p["mlp_norm"], cfg.norm_eps)
    y = layers.swiglu(h, p["w_gate"], p["w_up"], p["w_down"])
    return shard(y, ("batch", "seq", "embed"))


def _moe(cfg: ArchConfig, p: dict, x: jax.Array, shard: ShardPolicy,
         mode: str):
    h = layers.rms_norm(x, p["moe_norm"], cfg.norm_eps)
    # decode: no-drop capacity (a dropped decode token would corrupt the
    # stream); train/prefill: the configured capacity factor
    cf = -1.0 if mode == "decode" else cfg.capacity_factor
    y, aux = moe_mlp(h, p["router"], p["we_gate"], p["we_up"], p["we_down"],
                     topk=cfg.moe_topk, capacity_factor=cf,
                     group_size=cfg.moe_group_size,
                     dispatch=cfg.moe_dispatch, shard=shard)
    return shard(y, ("batch", "seq", "embed")), aux


def apply_block(cfg: ArchConfig, kind: str, p: dict, x: jax.Array, *,
                positions, mode: str, cache, pos, shard: ShardPolicy,
                mrope_pos=None, cross_src=None):
    """One decoder block (mixer + FFN [+ cross-attn]). Returns
    (x, new_cache, aux_loss)."""
    aux = jnp.float32(0.0)
    new_cache: dict = {}
    if kind in ("mlstm", "slstm"):
        mixer = mlstm_mixer if kind == "mlstm" else slstm_mixer
        norm_key = "m_norm" if kind == "mlstm" else "s_norm"
        h = layers.rms_norm(x, p[norm_key], cfg.norm_eps)
        y, st = mixer(h, p, cfg, state=cache if cache else None, mode=mode)
        x = x + shard(y, ("batch", "seq", "embed"))
        if st is not None:
            new_cache.update(st)
        return x, new_cache, aux

    mixer_kind, ffn_kind = kind.split("+")
    if mixer_kind == "attn":
        y, kvc = _attention(cfg, p, x, positions, mode, cache, pos, shard,
                            mrope_pos=mrope_pos)
        x = x + y
        new_cache.update(kvc)
    else:  # mamba
        h = layers.rms_norm(x, p["m_norm"], cfg.norm_eps)
        st_in = {k: cache[k] for k in ("h", "conv")} \
            if (cache and "h" in cache) else None
        y, st = mamba_mixer(h, p, cfg, state=st_in, mode=mode)
        x = x + shard(y, ("batch", "seq", "embed"))
        if st is not None:
            new_cache.update(st)

    if cfg.is_encdec and (cross_src is not None
                          or (cache and "xk" in cache)):
        y, xc = _attention(cfg, p, x, positions, mode, cache, pos, shard,
                           pfx="x", cross_src=cross_src)
        x = x + y
        new_cache.update(xc)

    if ffn_kind == "moe":
        y, aux = _moe(cfg, p, x, shard, mode)
        x = x + y
    elif cfg.d_ff or cfg.dense_ff:
        x = x + _mlp(cfg, p, x, shard)
    return x, new_cache, aux


# ------------------------------------------------------------- the stack -
def _block_key(bi: int, kind: str) -> str:
    return f"b{bi}_{kind.replace('+', '_')}"


def decoder_stack(cfg: ArchConfig, params: dict, x: jax.Array, *,
                  positions, mode: str, caches, pos, shard: ShardPolicy,
                  mrope_pos=None, cross_src=None):
    """Scan the super-block pattern over x. caches: dict block_key →
    pytree stacked on the super-block axis (or None)."""
    pattern = block_pattern(cfg)
    n_super = cfg.n_layers // len(pattern)
    want_cache = mode in ("prefill", "decode")

    def super_block(x, block_params, block_caches):
        new_caches = {}
        aux_sum = jnp.float32(0.0)
        for bi, kind in enumerate(pattern):
            key = _block_key(bi, kind)
            x, nc, aux = apply_block(
                cfg, kind, block_params[key], x,
                positions=positions, mode=mode,
                cache=block_caches.get(key) if block_caches else None,
                pos=pos, shard=shard, mrope_pos=mrope_pos,
                cross_src=cross_src)
            new_caches[key] = nc
            aux_sum = aux_sum + aux
        return x, new_caches, aux_sum

    if cfg.remat and mode == "train":
        super_block = jax.checkpoint(super_block)

    def scan_body(carry, xs):
        x, aux_acc = carry
        block_params, block_caches = xs
        x, new_caches, aux = super_block(x, block_params, block_caches)
        return (x, aux_acc + aux), new_caches

    stacked = params["blocks"]
    caches_xs = caches if caches is not None else {k: {} for k in stacked}
    if cfg.scan_layers and n_super > 1:
        (x, aux), new_caches = jax.lax.scan(
            scan_body, (x, jnp.float32(0.0)), (stacked, caches_xs))
    else:
        # unrolled (n_super == 1 or scan disabled)
        aux = jnp.float32(0.0)
        new_list = []
        for i in range(n_super):
            sl = jax.tree.map(lambda a: a[i], stacked)
            cl = jax.tree.map(lambda a: a[i], caches_xs) if caches else None
            (x, aux), nc = scan_body((x, aux), (sl, cl))
            new_list.append(nc)
        new_caches = jax.tree.map(lambda *zs: jnp.stack(zs), *new_list) \
            if want_cache else None
    return x, (new_caches if want_cache else None), aux


# ------------------------------------------------------------ full model -
def embed_inputs(cfg: ArchConfig, params: dict, batch: dict,
                 shard: ShardPolicy) -> tuple[jax.Array, jax.Array]:
    """Token (+ stub-frontend) embedding. Returns (x, positions)."""
    dt = jnp.dtype(cfg.compute_dtype)
    tokens = batch["tokens"]
    x = params["embed"][tokens].astype(dt)
    if cfg.frontend == "vision_stub" and "image_embeds" in batch:
        img = jnp.einsum("bse,ed->bsd", batch["image_embeds"].astype(dt),
                         params["vision_proj"].astype(dt))
        x = jnp.concatenate([img, x], axis=1)
    B, S = x.shape[:2]
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x = shard(x, ("batch", "seq", "embed"))
    return x, positions


def lm_head(cfg: ArchConfig, params: dict, x: jax.Array,
            shard: ShardPolicy) -> jax.Array:
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype))
    return shard(logits, ("batch", "seq", "vocab"))


def forward(cfg: ArchConfig, params: dict, batch: dict, *,
            mode: str = "train", caches=None, pos=0,
            shard: ShardPolicy = NO_SHARD, cross_src=None):
    """Full forward. Returns (logits, new_caches, aux)."""
    x, positions = embed_inputs(cfg, params, batch, shard)
    mrope_pos = batch.get("mrope_positions")
    x, new_caches, aux = decoder_stack(
        cfg, params, x, positions=positions, mode=mode, caches=caches,
        pos=pos, shard=shard, mrope_pos=mrope_pos, cross_src=cross_src)
    logits = lm_head(cfg, params, x, shard)
    return logits, new_caches, aux


# ---------------------------------------------------------------- caches -
def init_cache(cfg: ArchConfig, batch_size: int, max_len: int,
               dtype=None) -> dict:
    """Statically-shaped serving cache for decode."""
    dt = jnp.dtype(dtype or cfg.compute_dtype)
    pattern = block_pattern(cfg)
    n_super = cfg.n_layers // len(pattern)
    kh, dh = cfg.n_kv_heads, cfg.head_dim
    di, n, cw = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    nh = cfg.n_heads
    caches = {}
    for bi, kind in enumerate(pattern):
        key = _block_key(bi, kind)
        if kind == "mlstm":
            dhe = cfg.ssm_expand * cfg.d_model // nh
            caches[key] = {
                "C": jnp.zeros((n_super, batch_size, nh, dhe, dhe),
                               jnp.float32),
                "n": jnp.zeros((n_super, batch_size, nh, dhe), jnp.float32)}
            continue
        if kind == "slstm":
            dhe = cfg.d_model // nh
            z = jnp.zeros((n_super, batch_size, nh, dhe), jnp.float32)
            caches[key] = {"c": z, "n": z, "h": z}
            continue
        mixer, _ = kind.split("+")
        c = {}
        if mixer == "attn":
            if cfg.kv_cache_dtype == "int8":
                c["k"] = jnp.zeros((n_super, batch_size, max_len, kh, dh),
                                   jnp.int8)
                c["v"] = jnp.zeros((n_super, batch_size, max_len, kh, dh),
                                   jnp.int8)
                c["k_s"] = jnp.zeros((n_super, batch_size, max_len, kh, 1),
                                     jnp.float32)
                c["v_s"] = jnp.zeros((n_super, batch_size, max_len, kh, 1),
                                     jnp.float32)
            else:
                c["k"] = jnp.zeros((n_super, batch_size, max_len, kh, dh), dt)
                c["v"] = jnp.zeros((n_super, batch_size, max_len, kh, dh), dt)
        else:
            c["h"] = jnp.zeros((n_super, batch_size, di, n), jnp.float32)
            c["conv"] = jnp.zeros((n_super, batch_size, cw - 1, di), dt)
        if cfg.is_encdec:
            c["xk"] = jnp.zeros((n_super, batch_size, cfg.cross_len, kh, dh), dt)
            c["xv"] = jnp.zeros((n_super, batch_size, cfg.cross_len, kh, dh), dt)
        caches[key] = c
    return caches
