"""Declarative parameter schema.

Every model is described as a nested dict of :class:`ParamSpec`
(shape, logical sharding axes, initializer). The same schema drives
(1) parameter initialization, (2) pjit PartitionSpecs via
launch/sharding.py, (3) parameter counting, and (4) checkpoint layout —
one source of truth, consistent by construction.

Layer stacking: the decoder is a sequence of *super-blocks* scanned with
``lax.scan``; each super-block is an (unrolled) pattern of heterogeneous
blocks (paper-faithful jamba: [attn, mamba×7] with MoE on every other
layer). Per-block params carry a leading ``n_super`` axis (logical axis
"layers").
"""
from __future__ import annotations

import dataclasses
import math
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    axes: tuple                      # logical axis names, len == len(shape)
    init: str = "normal"             # normal | zeros | ones | mamba_a | mamba_dt
    scale: float = 0.02

    def make(self, key: jax.Array, dtype) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, dtype)
        if self.init == "mamba_a":        # A_log = log(1..N) per channel
            n = self.shape[-1]
            a = jnp.tile(jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32)),
                         self.shape[:-1] + (1,))
            return a.astype(dtype)
        if self.init == "mamba_dt":       # dt bias ~ softplus^-1(0.001..0.1)
            lo, hi = 1e-3, 1e-1
            u = jax.random.uniform(key, self.shape, jnp.float32)
            dt = jnp.exp(u * (math.log(hi) - math.log(lo)) + math.log(lo))
            return jnp.log(jnp.expm1(dt)).astype(dtype)
        return (jax.random.normal(key, self.shape, jnp.float32)
                * self.scale).astype(dtype)


# ------------------------------------------------------------ block kinds
def attn_specs(cfg: ArchConfig, cross: bool = False) -> dict:
    d, h, kh, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    pfx = "x" if cross else ""
    out = {
        f"{pfx}attn_norm": ParamSpec((d,), ("embed",), "ones"),
        f"{pfx}wq": ParamSpec((d, h, dh), ("embed", "heads", "head_dim")),
        f"{pfx}wk": ParamSpec((d, kh, dh), ("embed", "kv_heads", "head_dim")),
        f"{pfx}wv": ParamSpec((d, kh, dh), ("embed", "kv_heads", "head_dim")),
        f"{pfx}wo": ParamSpec((h, dh, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias and not cross:
        out[f"{pfx}bq"] = ParamSpec((h, dh), ("heads", "head_dim"), "zeros")
        out[f"{pfx}bk"] = ParamSpec((kh, dh), ("kv_heads", "head_dim"), "zeros")
        out[f"{pfx}bv"] = ParamSpec((kh, dh), ("kv_heads", "head_dim"), "zeros")
    return out


def mlp_specs(cfg: ArchConfig, ff: int) -> dict:
    d = cfg.d_model
    return {
        "mlp_norm": ParamSpec((d,), ("embed",), "ones"),
        "w_gate": ParamSpec((d, ff), ("embed", "ff")),
        "w_up": ParamSpec((d, ff), ("embed", "ff")),
        "w_down": ParamSpec((ff, d), ("ff", "embed")),
    }


def gelu_mlp_specs(cfg: ArchConfig, ff: int) -> dict:
    d = cfg.d_model
    return {
        "mlp_norm": ParamSpec((d,), ("embed",), "ones"),
        "mlp_norm_b": ParamSpec((d,), ("embed",), "zeros"),
        "w_up": ParamSpec((d, ff), ("embed", "ff")),
        "b_up": ParamSpec((ff,), ("ff",), "zeros"),
        "w_down": ParamSpec((ff, d), ("ff", "embed")),
        "b_down": ParamSpec((d,), ("embed",), "zeros"),
    }


def moe_specs(cfg: ArchConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe_experts
    return {
        "moe_norm": ParamSpec((d,), ("embed",), "ones"),
        "router": ParamSpec((d, e), ("embed", None)),
        "we_gate": ParamSpec((e, d, f), ("experts", "embed", "ff")),
        "we_up": ParamSpec((e, d, f), ("experts", "embed", "ff")),
        "we_down": ParamSpec((e, f, d), ("experts", "ff", "embed")),
    }


def mamba_specs(cfg: ArchConfig) -> dict:
    d, di = cfg.d_model, cfg.d_inner
    n, dtr, cw = cfg.ssm_state, cfg.ssm_dt_rank, cfg.ssm_conv
    return {
        "m_norm": ParamSpec((d,), ("embed",), "ones"),
        "in_proj": ParamSpec((d, 2 * di), ("embed", "ff")),
        "conv_w": ParamSpec((cw, di), (None, "ff")),
        "conv_b": ParamSpec((di,), ("ff",), "zeros"),
        "x_proj": ParamSpec((di, dtr + 2 * n), ("ff", None)),
        "dt_w": ParamSpec((dtr, di), (None, "ff")),
        "dt_b": ParamSpec((di,), ("ff",), "mamba_dt"),
        "A_log": ParamSpec((di, n), ("ff", None), "mamba_a"),
        "Dskip": ParamSpec((di,), ("ff",), "ones"),
        "out_proj": ParamSpec((di, d), ("ff", "embed")),
    }


def mlstm_specs(cfg: ArchConfig) -> dict:
    """mLSTM block operating in a ``ssm_expand``×-projected space
    (the xLSTM paper's projection factor; di = expand·d)."""
    d, nh = cfg.d_model, cfg.n_heads
    di = cfg.ssm_expand * d
    dh = di // nh
    return {
        "m_norm": ParamSpec((d,), ("embed",), "ones"),
        "wq": ParamSpec((d, nh, dh), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, nh, dh), ("embed", "heads", "head_dim")),
        "wv": ParamSpec((d, nh, dh), ("embed", "heads", "head_dim")),
        "w_if": ParamSpec((d, 2, nh), ("embed", None, "heads")),
        "w_og": ParamSpec((d, di), ("embed", "ff")),
        "w_out": ParamSpec((di, d), ("ff", "embed")),
    }


def slstm_specs(cfg: ArchConfig) -> dict:
    d, nh = cfg.d_model, cfg.n_heads
    dh = d // nh
    return {
        "s_norm": ParamSpec((d,), ("embed",), "ones"),
        "w_izfo": ParamSpec((d, 4, nh, dh), ("embed", None, "heads", "head_dim")),
        "r_izfo": ParamSpec((4, nh, dh, dh), (None, "heads", "head_dim", None),
                            scale=0.01),
        "b_izfo": ParamSpec((4, nh, dh), (None, "heads", "head_dim"), "zeros"),
        "w_sout": ParamSpec((d, d), ("ff", "embed")),
    }


# ----------------------------------------------------------- block layout
def block_pattern(cfg: ArchConfig) -> list[str]:
    """The per-super-block sequence of block kinds; homogeneous across
    super-blocks so lax.scan applies. Kinds:
      attn+mlp | attn+moe | mamba+mlp | mamba+moe | mlstm | slstm
    """
    if cfg.xlstm:
        pat = []
        for i in range(cfg.slstm_every):
            pat.append("slstm" if (i + 1) % cfg.slstm_every == 0 else "mlstm")
        assert cfg.n_layers % len(pat) == 0
        return pat
    period = max(cfg.attn_every, 1) if cfg.attn_every else 1
    period = np.lcm(period, cfg.moe_every if cfg.moe_experts else 1)
    pat = []
    for i in range(period):
        mixer = "attn" if cfg.is_attn_layer(i) else "mamba"
        ffn = "moe" if cfg.is_moe_layer(i) else "mlp"
        pat.append(f"{mixer}+{ffn}")
    assert cfg.n_layers % period == 0, (cfg.name, cfg.n_layers, period)
    return pat


def block_specs(cfg: ArchConfig, kind: str) -> dict:
    if kind == "mlstm":
        return mlstm_specs(cfg)
    if kind == "slstm":
        return slstm_specs(cfg)
    mixer, ffn = kind.split("+")
    out = {}
    out.update(attn_specs(cfg) if mixer == "attn" else mamba_specs(cfg))
    if ffn == "moe":
        out.update(moe_specs(cfg))
    else:
        ff = cfg.dense_ff if cfg.dense_ff else cfg.d_ff
        out.update(mlp_specs(cfg, ff))
    return out


def _stack(specs: dict, n: int) -> dict:
    """Add the scanned leading 'layers' axis to every spec in the block."""
    return {k: ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.init, s.scale)
            for k, s in specs.items()}


def param_schema(cfg: ArchConfig) -> dict:
    """Full model schema: nested dict name → ParamSpec."""
    d, vp = cfg.d_model, cfg.padded_vocab
    schema: dict = {
        "embed": ParamSpec((vp, d), ("vocab", "embed"), scale=0.02),
        "final_norm": ParamSpec((d,), ("embed",), "ones"),
    }
    if not cfg.tie_embeddings:
        schema["lm_head"] = ParamSpec((d, vp), ("embed", "vocab"))

    pattern = block_pattern(cfg)
    n_super = cfg.n_layers // len(pattern)
    blocks = {}
    for bi, kind in enumerate(pattern):
        blocks[f"b{bi}_{kind.replace('+', '_')}"] = \
            _stack(block_specs(cfg, kind), n_super)
    schema["blocks"] = blocks

    if cfg.is_encdec:
        enc_blocks = {}
        enc_specs = {}
        enc_specs.update(attn_specs(cfg))
        enc_specs.update(gelu_mlp_specs(cfg, cfg.d_ff))
        enc_blocks["enc"] = _stack(enc_specs, cfg.n_enc_layers)
        # decoder cross-attention, one per decoder layer
        cross = _stack(attn_specs(cfg, cross=True), n_super)
        for bi, kind in enumerate(pattern):
            blocks[f"b{bi}_{kind.replace('+', '_')}"].update(cross)
        schema["enc_blocks"] = enc_blocks
        schema["enc_final_norm"] = ParamSpec((d,), ("embed",), "ones")
    if cfg.frontend == "vision_stub":
        schema["vision_proj"] = ParamSpec((1280, d), (None, "embed"))
    if cfg.frontend == "audio_stub":
        schema["audio_proj"] = ParamSpec((128, d), (None, "embed"))
    return schema


# -------------------------------------------------------------- utilities
def init_params(cfg: ArchConfig, key: jax.Array) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    flat = {}

    def walk(tree, prefix):
        for k, v in tree.items():
            if isinstance(v, dict):
                walk(v, prefix + (k,))
            else:
                flat[prefix + (k,)] = v

    schema = param_schema(cfg)
    walk(schema, ())
    keys = jax.random.split(key, len(flat))
    out: dict = {}
    for (path, spec), sk in zip(sorted(flat.items()), keys):
        node = out
        for p in path[:-1]:
            node = node.setdefault(p, {})
        node[path[-1]] = spec.make(sk, dtype)
    return out


def param_count(cfg: ArchConfig, padded: bool = False) -> int:
    """Total parameter count from the schema (vocab padding excluded by
    default so the number matches the published size)."""
    total = 0
    vp, v = cfg.padded_vocab, cfg.vocab

    def walk(tree):
        nonlocal total
        for key, s in tree.items():
            if isinstance(s, dict):
                walk(s)
                continue
            n = int(np.prod(s.shape))
            if not padded and key in ("embed", "lm_head"):
                n = n // vp * v
            total += n

    walk(param_schema(cfg))
    return total


def active_param_count(cfg: ArchConfig) -> int:
    """Parameters touched per token (MoE: only top-k experts active)."""
    total = param_count(cfg)
    if cfg.moe_experts:
        per_expert = 3 * cfg.d_model * cfg.d_ff
        n_moe = sum(1 for i in range(cfg.n_layers) if cfg.is_moe_layer(i))
        total -= n_moe * (cfg.moe_experts - cfg.moe_topk) * per_expert
    return total
