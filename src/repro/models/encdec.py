"""Whisper-style encoder-decoder backbone.

The audio conv frontend is a STUB (DESIGN.md §5): ``batch["audio_embeds"]``
carries precomputed frame features (B, S_frames, 128), projected into
d_model by ``audio_proj``. The encoder is a bidirectional transformer
with sinusoidal positions and GELU MLPs; the decoder is the shared
decoder stack with cross-attention (RoPE self-attention — a documented
deviation from learned positions so 32k decode caches are well-defined).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers
from repro.models.sharding_api import NO_SHARD, ShardPolicy
from repro.models import transformer


def sinusoidal_positions(S: int, d: int, dtype) -> jax.Array:
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d)
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    return pe[:, :d].astype(dtype)


def encode(cfg: ArchConfig, params: dict, audio_embeds: jax.Array,
           shard: ShardPolicy = NO_SHARD) -> jax.Array:
    """audio_embeds: (B, S_enc, 128) stub frame features → (B, S_enc, D)."""
    dt = jnp.dtype(cfg.compute_dtype)
    x = jnp.einsum("bse,ed->bsd", audio_embeds.astype(dt),
                   params["audio_proj"].astype(dt))
    x = x + sinusoidal_positions(x.shape[1], cfg.d_model, dt)[None]
    x = shard(x, ("batch", "seq", "embed"))
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

    def enc_block(x, p):
        y, _ = transformer._attention(cfg, p, x, positions, "train", None,
                                      0, shard, causal=False)
        x = x + y
        h = layers.layer_norm(x, p["mlp_norm"], p["mlp_norm_b"],
                              cfg.norm_eps)
        x = x + shard(layers.gelu_mlp(h, p["w_up"], p["b_up"], p["w_down"],
                                      p["b_down"]), ("batch", "seq", "embed"))
        return x, None

    x, _ = jax.lax.scan(enc_block, x, params["enc_blocks"]["enc"])
    return layers.rms_norm(x, params["enc_final_norm"], cfg.norm_eps)


def encdec_forward(cfg: ArchConfig, params: dict, batch: dict, *,
                   mode: str = "train", caches=None, pos=0,
                   shard: ShardPolicy = NO_SHARD):
    """Full enc-dec forward. For decode, the encoder output is already
    folded into the cross-attention cache, so the encoder is skipped."""
    if mode == "decode":
        return transformer.forward(cfg, params, batch, mode=mode,
                                   caches=caches, pos=pos, shard=shard)
    enc_out = encode(cfg, params, batch["audio_embeds"], shard)
    return transformer.forward(cfg, params, batch, mode=mode, caches=caches,
                               pos=pos, shard=shard, cross_src=enc_out)
