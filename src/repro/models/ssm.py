"""Recurrent sequence mixers: Mamba (selective SSM) and xLSTM (mLSTM +
sLSTM), in TPU-friendly chunkwise-parallel forms.

Hardware adaptation (DESIGN.md §6): the CUDA Mamba kernel's
shared-memory selective scan becomes a *chunked associative scan* — the
sequence is processed in VMEM-sized chunks via ``lax.scan`` (inter-chunk
recurrence) with ``lax.associative_scan`` inside each chunk (intra-chunk
parallelism on the VPU). The (B, chunk, D_inner, N) discretized-state
tensor is the VMEM working set; D_inner shards over the mesh "model"
axis. The mLSTM uses the chunkwise gated-linear-attention form with
sigmoid gating (stable without the max-stabilizer; log-decay ratios are
exponentiated only for s ≤ t so every factor is ≤ 1).

All mixers expose both a parallel form (train/prefill) and an O(1)
single-step form (decode), sharing parameters.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# --------------------------------------------------------------- mamba ---


def _causal_depthwise_conv(x: jax.Array, w: jax.Array, b: jax.Array
                           ) -> jax.Array:
    """x: (B, S, Di); w: (CW, Di) depthwise causal conv via shifted adds
    (no conv op → trivially shardable on Di)."""
    cw = w.shape[0]
    out = jnp.zeros_like(x)
    for i in range(cw):
        shift = cw - 1 - i
        xs = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, :x.shape[1], :] \
            if shift else x
        out = out + xs * w[i]
    return out + b


def _ssm_scan_chunk(h0: jax.Array, dA: jax.Array, dBx: jax.Array
                    ) -> tuple[jax.Array, jax.Array]:
    """One chunk of the selective scan. h0: (B, Di, N);
    dA, dBx: (B, C, Di, N). Returns (h_end, h_all (B, C, Di, N))."""
    def combine(a, b):
        a1, b1 = a
        a2, b2 = b
        return a2 * a1, a2 * b1 + b2
    cumA, inner = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
    h_all = cumA * h0[:, None] + inner
    return h_all[:, -1], h_all


def mamba_mixer(x: jax.Array, p: dict, cfg, state: dict | None = None,
                mode: str = "train", chunk: int = 128
                ) -> tuple[jax.Array, dict | None]:
    """x: (B, S, D). state (decode): {"h": (B, Di, N), "conv": (B, CW−1, Di)}."""
    B, S, D = x.shape
    di, n = cfg.d_inner, cfg.ssm_state
    cw = cfg.ssm_conv
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    x1, z = jnp.split(xz, 2, axis=-1)                       # (B, S, Di)

    if mode == "decode":
        assert state is not None and S == 1
        buf = jnp.concatenate([state["conv"], x1], axis=1)  # (B, CW, Di)
        conv = jnp.einsum("bwd,wd->bd", buf,
                          p["conv_w"].astype(x.dtype))[:, None, :] \
            + p["conv_b"].astype(x.dtype)
        new_conv = buf[:, 1:, :]
    else:
        conv = _causal_depthwise_conv(x1, p["conv_w"].astype(x.dtype),
                                      p["conv_b"].astype(x.dtype))
        new_conv = None
    xc = jax.nn.silu(conv)

    xdb = jnp.einsum("bsd,de->bse", xc, p["x_proj"].astype(x.dtype))
    dt_low = xdb[..., :cfg.ssm_dt_rank]
    Bc = xdb[..., cfg.ssm_dt_rank:cfg.ssm_dt_rank + n].astype(jnp.float32)
    Cc = xdb[..., cfg.ssm_dt_rank + n:].astype(jnp.float32)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rd->bsd", dt_low, p["dt_w"].astype(x.dtype))
        .astype(jnp.float32) + p["dt_b"].astype(jnp.float32))  # (B,S,Di)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                # (Di, N)
    xcf = xc.astype(jnp.float32)

    if mode == "decode":
        dA = jnp.exp(dt[:, 0, :, None] * A)                     # (B, Di, N)
        dBx = (dt[:, 0, :, None] * Bc[:, 0, None, :]
               * xcf[:, 0, :, None])
        h = dA * state["h"] + dBx
        y = jnp.einsum("bdn,bn->bd", h, Cc[:, 0])[:, None, :]
        new_state = {"h": h, "conv": new_conv}
    else:
        nchunks = max(S // chunk, 1)
        csize = S // nchunks
        assert S % nchunks == 0, (S, chunk)

        def step(h0, xs):
            dt_c, Bc_c, x_c = xs                                # (B,C,·)
            dA = jnp.exp(dt_c[..., None] * A)                   # (B,C,Di,N)
            dBx = dt_c[..., None] * Bc_c[:, :, None, :] * x_c[..., None]
            h_end, h_all = _ssm_scan_chunk(h0, dA, dBx)
            return h_end, h_all

        resh = lambda a: a.reshape(B, nchunks, csize, *a.shape[2:]) \
            .swapaxes(0, 1)                                     # noqa: E731
        h0 = jnp.zeros((B, di, n), jnp.float32) if state is None \
            else state["h"]
        h_end, h_chunks = jax.lax.scan(
            step, h0, (resh(dt), resh(Bc), resh(xcf)))
        h_all = h_chunks.swapaxes(0, 1).reshape(B, S, di, n)
        y = jnp.einsum("bsdn,bsn->bsd", h_all, Cc)
        new_state = None
        if mode == "prefill":
            new_state = {"h": h_end,
                         "conv": x1[:, S - (cw - 1):, :] if S >= cw - 1
                         else jnp.pad(x1, ((0, 0), (cw - 1 - S, 0), (0, 0)))}
    y = (y + p["Dskip"].astype(jnp.float32) * xcf).astype(x.dtype)
    y = y * jax.nn.silu(z)
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype)), \
        new_state


# --------------------------------------------------------------- mLSTM ---
def _mlstm_chunk(q, k, v, li, lf, C0, n0, eps=1.0):
    """Chunkwise gated linear attention (sigmoid-gated mLSTM).

    q,k,v: (B, H, C, dh); li/lf: (B, H, C) log input/forget gates (≤ 0).
    C0: (B, H, dh, dh); n0: (B, H, dh). Returns (y, C1, n1)."""
    csz = q.shape[2]
    lF = jnp.cumsum(lf, axis=-1)                    # log Π f up to t
    # inter-chunk: y_state_t = F_t · q_t C0
    decay_t = jnp.exp(lF)[..., None]                # (B,H,C,1)
    y_state = decay_t * jnp.einsum("bhtd,bhde->bhte", q, C0)
    n_state = decay_t * jnp.einsum("bhtd,bhd->bht", q, n0)[..., None]
    # intra-chunk: w[t,s] = exp(lF_t − lF_s) · i_s for s ≤ t  (≤ 1·i_s)
    logw = lF[:, :, :, None] - lF[:, :, None, :] + li[:, :, None, :]
    tri = jnp.tril(jnp.ones((csz, csz), bool))
    w = jnp.where(tri, jnp.exp(logw), 0.0)          # (B,H,C,C)
    qk = jnp.einsum("bhtd,bhsd->bhts", q, k)
    scores = qk * w
    y_intra = jnp.einsum("bhts,bhsd->bhtd", scores, v)
    # n_intra_t = Σ_{s≤t} w[t,s] · (q_t · k_s)
    n_intra = jnp.sum(scores, axis=-1, keepdims=True)   # (B,H,C,1)
    den = jnp.maximum(jnp.abs(n_state + n_intra), eps)
    y = (y_state + y_intra) / den
    # chunk-end state
    decay_end = jnp.exp(lF[:, :, -1])[..., None, None]
    rel = jnp.exp(lF[:, :, -1:] - lF) * jnp.exp(li)  # (B,H,C)
    C1 = decay_end * C0 + jnp.einsum("bhs,bhsd,bhse->bhde", rel, k, v)
    n1 = decay_end[..., 0] * n0 + jnp.einsum("bhs,bhsd->bhd", rel, k)
    return y, C1, n1


def mlstm_mixer(x: jax.Array, p: dict, cfg, state: dict | None = None,
                mode: str = "train") -> tuple[jax.Array, dict | None]:
    """x: (B, S, D). state: {"C": (B,H,dh,dh), "n": (B,H,dh)} f32."""
    B, S, D = x.shape
    nh = cfg.n_heads
    di = cfg.ssm_expand * D
    dh = di // nh
    to_f32 = lambda a: a.astype(jnp.float32)            # noqa: E731
    q = jnp.einsum("bsd,dhe->bhse", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhe->bhse", x, p["wk"].astype(x.dtype)) / \
        jnp.sqrt(jnp.float32(dh)).astype(x.dtype)
    v = jnp.einsum("bsd,dhe->bhse", x, p["wv"].astype(x.dtype))
    gates = jnp.einsum("bsd,dgh->bgsh", x.astype(jnp.float32),
                       p["w_if"].astype(jnp.float32))   # (B,2,S,H)
    li = jax.nn.log_sigmoid(gates[:, 0].swapaxes(1, 2))  # (B,H,S)
    lf = jax.nn.log_sigmoid(gates[:, 1].swapaxes(1, 2))
    q, k, v = to_f32(q), to_f32(k), to_f32(v)

    if mode == "decode":
        assert S == 1 and state is not None
        f = jnp.exp(lf[:, :, 0])[..., None, None]
        i = jnp.exp(li[:, :, 0])[..., None, None]
        C = f * state["C"] + i * jnp.einsum("bhd,bhe->bhde",
                                            k[:, :, 0], v[:, :, 0])
        n = f[..., 0] * state["n"] + i[..., 0] * k[:, :, 0]
        # xLSTM normalizer: lower-bound 1 (not eps) — keeps the
        # output bounded when q ⟂ n and the recurrence numerically stable
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh",
                                             q[:, :, 0], n)), 1.0)
        y = jnp.einsum("bhd,bhde->bhe", q[:, :, 0], C) / den[..., None]
        y = y[:, :, None, :]
        new_state = {"C": C, "n": n}
    else:
        csz = min(cfg.xlstm_chunk, S)
        while S % csz:
            csz -= 1
        nchunks = S // csz
        # (B, nh, S, ·) → (nchunks, B, nh, csz, ·) for scan xs
        r4 = lambda a: a.reshape(B, nh, nchunks, csz, a.shape[-1]) \
            .transpose(2, 0, 1, 3, 4)                   # noqa: E731
        r3 = lambda a: a.reshape(B, nh, nchunks, csz) \
            .transpose(2, 0, 1, 3)                      # noqa: E731

        def step(carry, xs):
            C0, n0 = carry
            qc, kc, vc, lic, lfc = xs
            y, C1, n1 = _mlstm_chunk(qc, kc, vc, lic, lfc, C0, n0)
            return (C1, n1), y

        C0 = jnp.zeros((B, nh, dh, dh), jnp.float32) if state is None \
            else state["C"]
        n0 = jnp.zeros((B, nh, dh), jnp.float32) if state is None \
            else state["n"]
        (C1, n1), ys = jax.lax.scan(
            step, (C0, n0), (r4(q), r4(k), r4(v), r3(li), r3(lf)))
        y = ys.transpose(1, 2, 0, 3, 4).reshape(B, nh, S, dh)
        new_state = {"C": C1, "n": n1} if mode == "prefill" else None

    y = y.swapaxes(1, 2).reshape(B, S, di).astype(x.dtype)
    og = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", x,
                                   p["w_og"].astype(x.dtype)))
    return jnp.einsum("bse,ed->bsd", y * og,
                      p["w_out"].astype(x.dtype)), new_state


# --------------------------------------------------------------- sLSTM ---
def slstm_mixer(x: jax.Array, p: dict, cfg, state: dict | None = None,
                mode: str = "train") -> tuple[jax.Array, dict | None]:
    """Scalar-memory LSTM with per-head block-diagonal recurrence.
    state: {"c": (B,H,dh), "n": (B,H,dh), "h": (B,H,dh)} f32."""
    B, S, D = x.shape
    nh = cfg.n_heads
    dh = D // nh
    wx = jnp.einsum("bsd,dghe->bsghe", x.astype(jnp.float32),
                    p["w_izfo"].astype(jnp.float32))    # (B,S,4,H,dh)
    r = p["r_izfo"].astype(jnp.float32)                 # (4,H,dh,dh)
    b = p["b_izfo"].astype(jnp.float32)                 # (4,H,dh)

    def cell(carry, wxt):
        c, n, h = carry                                  # (B,H,dh) each
        rec = jnp.einsum("bhe,ghef->bghf", h, r)
        z = wxt + rec + b                                # (B,4,H,dh)
        i = jax.nn.sigmoid(z[:, 0])
        zin = jnp.tanh(z[:, 1])
        f = jax.nn.sigmoid(z[:, 2])
        o = jax.nn.sigmoid(z[:, 3])
        c1 = f * c + i * zin
        n1 = f * n + i
        h1 = o * c1 / jnp.maximum(n1, 1e-6)
        return (c1, n1, h1), h1

    if state is None:
        z0 = jnp.zeros((B, nh, dh), jnp.float32)
        carry = (z0, z0, z0)
    else:
        carry = (state["c"], state["n"], state["h"])
    carry, hs = jax.lax.scan(cell, carry, wx.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).reshape(B, S, D).astype(x.dtype)
    new_state = None
    if mode in ("prefill", "decode"):
        new_state = {"c": carry[0], "n": carry[1], "h": carry[2]}
    return jnp.einsum("bsd,de->bse", y, p["w_sout"].astype(x.dtype)), \
        new_state
