"""Step-atomic checkpointing with elastic re-mesh on restore.

Layout: <dir>/step_<N>/ holding one .npz per top-level key plus a JSON
manifest. Writes go to a tmp dir renamed into place (atomic on POSIX), so
a crash mid-save can never corrupt the latest checkpoint — restart keeps
the previous step (fault-tolerance deliverable).

``restore_for_mesh`` re-shards on load: the on-disk format is
mesh-agnostic (full arrays), so a checkpoint written on one mesh restores
onto any other (elastic scale up/down), with jax.device_put placing each
leaf according to the new sharding tree.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _flatten(tree, prefix=()):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, prefix + (str(k),)))
    else:
        out["/".join(prefix)] = tree
    return out


def _unflatten(flat: dict):
    root: dict = {}
    for path, v in flat.items():
        node = root
        parts = path.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return root


def save(ckpt_dir: str, step: int, tree: dict, keep: int = 3) -> str:
    """Atomically write ``tree`` as step_<step>; prune to ``keep`` newest."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{k: np.asarray(v) for k, v in flat.items()})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, "keys": sorted(flat)}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    # prune old steps
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_")
                   and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d))
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int | None = None) -> tuple[int, dict]:
    step = latest_step(ckpt_dir) if step is None else step
    assert step is not None, f"no checkpoint in {ckpt_dir}"
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    return step, _unflatten(flat)


def restore_for_mesh(ckpt_dir: str, sharding_tree, step: int | None = None
                     ) -> tuple[int, dict]:
    """Restore and re-shard for a (possibly different) mesh — elastic
    scaling: each leaf is device_put with its new NamedSharding."""
    step, tree = restore(ckpt_dir, step)

    def place(leaf, sh):
        return jax.device_put(leaf, sh) if sh is not None else leaf

    flat_t = _flatten(tree)
    flat_s = _flatten(sharding_tree) if sharding_tree is not None else {}
    placed = {k: place(v, flat_s.get(k)) for k, v in flat_t.items()}
    return step, _unflatten(placed)
