from repro.checkpoint.ckpt import (latest_step, restore, restore_for_mesh,
                                   save)

__all__ = ["save", "restore", "restore_for_mesh", "latest_step"]
