"""qwen2-vl-7b [vlm] — M-RoPE, dynamic resolution (arXiv:2409.12191; hf).
28L, d_model 3584, 28H (GQA kv=4), d_ff 18944, vocab 152064, QKV biases.

The vision tower is a STUB: input_specs() provides precomputed patch
embeddings (B, S_img, 1280) projected by ``vision_proj``; M-RoPE
position ids (3, B, S) come with the batch. 28 heads are not divisible
by model=16 → batch/kv-seq attention sharding fallback."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    d_ff=18944, vocab=152064,
    mrope=True, mrope_sections=(16, 24, 24), qkv_bias=True,
    frontend="vision_stub", rope_theta=1e6,
)
