"""dbrx-132b [moe] — 16 experts top-4, fine-grained
(hf:databricks/dbrx-base; unverified tier).
40L, d_model 6144, 48H (GQA kv=8), d_ff 10752, vocab 100352."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=10752, vocab=100352,
    moe_experts=16, moe_topk=4,
)
