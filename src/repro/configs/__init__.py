"""Per-architecture configs (exact published numbers) + registry."""
