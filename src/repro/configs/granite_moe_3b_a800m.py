"""granite-moe-3b-a800m [moe] — 40 experts top-8, fine-grained d_ff=512
(hf:ibm-granite/granite-3.0-*-base family). 32L, d_model 1536, 24H
(GQA kv=8), vocab 49155, tied embeddings. 40 experts don't divide the
16-way model axis → the resolver shards within-expert d_ff instead
(DESIGN.md §4)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
    d_ff=512, vocab=49155,
    moe_experts=40, moe_topk=8, tie_embeddings=True,
)
