"""deepseek-coder-33b [dense] — llama-arch (arXiv:2401.14196; hf).
62L, d_model 7168, 56H (GQA kv=8), d_ff 19200, vocab 32256.
56 heads do not divide the 16-way model axis: the sharding resolver
switches attention to batch-sharding (train/prefill) and kv-sequence
sharding (decode) — see DESIGN.md §4 and launch/sharding.py."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-coder-33b", family="dense",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=19200, vocab=32256,
)
