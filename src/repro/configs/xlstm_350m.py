"""xlstm-350m [ssm] — sLSTM + mLSTM blocks (arXiv:2405.04517; unverified
tier). 24L, d_model 1024, 4 heads, no FFN (blocks carry their own
projections), vocab 50304. Pattern: one sLSTM every 8 blocks, rest
mLSTM with projection factor 2 (chunkwise-parallel training form).
Recurrent (constant-size state) ⇒ sub-quadratic ⇒ runs long_500k."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304,
    xlstm=True, slstm_every=8, ssm_expand=2, xlstm_chunk=128,
    subquadratic=True,
)
