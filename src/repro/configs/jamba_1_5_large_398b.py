"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e
top-2 on every other layer (matches the 398B total / ~98B active of
arXiv:2403.19887 / 2408.12570; hf-verified).

72L, d_model 8192, 64H (GQA kv=8), d_ff 24576, vocab 65536.
Layer layout: layer i is attention iff i % 8 == 0 (9 attn / 63 mamba);
MoE iff i % 2 == 1 (36 MoE layers, 16 experts each, top-2), dense MLP
otherwise. Sub-quadratic (mamba-dominated) ⇒ runs long_500k.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=24576, dense_ff=24576, vocab=65536,
    moe_experts=16, moe_topk=2, moe_every=2, moe_offset=1,
    attn_every=8, ssm_state=16, ssm_expand=2,
    subquadratic=True,
)
