"""Architecture registry: public arch ids → full + smoke configs.

Every assigned architecture is selectable via ``--arch <id>``. Smoke
configs are family-preserving reductions (same block pattern, tiny dims)
used by per-arch CPU smoke tests; the full configs are exercised only
through the dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig
from repro.configs import (jamba_1_5_large_398b, deepseek_67b, granite_3_2b,
                           deepseek_coder_33b, phi3_medium_14b,
                           granite_moe_3b_a800m, dbrx_132b, xlstm_350m,
                           whisper_small, qwen2_vl_7b)

_MODULES = {
    "jamba-1.5-large-398b": jamba_1_5_large_398b,
    "deepseek-67b": deepseek_67b,
    "granite-3-2b": granite_3_2b,
    "deepseek-coder-33b": deepseek_coder_33b,
    "phi3-medium-14b": phi3_medium_14b,
    "granite-moe-3b-a800m": granite_moe_3b_a800m,
    "dbrx-132b": dbrx_132b,
    "xlstm-350m": xlstm_350m,
    "whisper-small": whisper_small,
    "qwen2-vl-7b": qwen2_vl_7b,
}


def list_archs() -> list[str]:
    return sorted(_MODULES)


def get_config(arch: str) -> ArchConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list_archs()}")
    return _MODULES[arch].CONFIG


def get_smoke_config(arch: str) -> ArchConfig:
    mod = _MODULES[arch]
    if hasattr(mod, "SMOKE"):
        return mod.SMOKE
    return reduce_config(mod.CONFIG)


def reduce_config(cfg: ArchConfig) -> ArchConfig:
    """Family-preserving tiny version of a config (same block pattern)."""
    from repro.models.schema import block_pattern
    period = len(block_pattern(cfg))
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=period * min(2, max(1, cfg.n_layers // period)),
        d_model=128,
        n_heads=4,
        n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=32,
        d_ff=256 if cfg.d_ff else 0,
        dense_ff=256 if cfg.dense_ff else 0,
        vocab=512,
        moe_experts=min(cfg.moe_experts, 4),
        moe_topk=min(cfg.moe_topk, 2),
        capacity_factor=-1.0 if cfg.moe_experts else cfg.capacity_factor,
        n_enc_layers=2 if cfg.is_encdec else 0,
        cross_len=64 if cfg.is_encdec else cfg.cross_len,
        ssm_dt_rank=8,
        xlstm_chunk=16,
        mrope_sections=(8, 4, 4) if cfg.mrope else cfg.mrope_sections,
        param_dtype="float32",
        compute_dtype="float32",
    )
