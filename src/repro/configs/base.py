"""Architecture configuration schema.

One :class:`ArchConfig` fully determines a model: the registry
(configs/registry.py) maps public arch ids (``--arch jamba-1.5-large-398b``)
to a full config and a reduced smoke config of the same family.
"""
from __future__ import annotations

import dataclasses


def ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # default d_model // n_heads

    # --- MoE ---
    moe_experts: int = 0
    moe_topk: int = 0
    moe_every: int = 1           # MoE on layers with index % moe_every == moe_offset
    moe_offset: int = 0
    capacity_factor: float = 1.25
    dense_ff: int = 0            # d_ff of the dense MLP on non-MoE layers (hybrid MoE)
    moe_group_size: int = 512    # tokens per dispatch group (einsum mode)
    moe_dispatch: str = "einsum"  # einsum (GShard baseline) | gather (opt)

    # --- hybrid (jamba): attention on every `attn_every`-th layer, rest Mamba
    attn_every: int = 0          # 0 ⇒ all layers are attention
    ssm_state: int = 16          # Mamba N
    ssm_conv: int = 4            # Mamba depthwise conv width
    ssm_expand: int = 2          # d_inner = expand × d_model
    ssm_dt_rank: int = 0         # default ceil(d_model/16)

    # --- xLSTM ---
    xlstm: bool = False
    slstm_every: int = 8         # one sLSTM block every k layers (rest mLSTM)
    xlstm_chunk: int = 128       # chunkwise-parallel mLSTM chunk length

    # --- encoder-decoder (whisper) ---
    is_encdec: bool = False
    n_enc_layers: int = 0
    cross_len: int = 1500        # encoder frames attended to while decoding

    # --- VLM (qwen2-vl) ---
    mrope: bool = False
    mrope_sections: tuple = (16, 24, 24)

    # --- common ---
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    qkv_bias: bool = False       # qwen2 uses QKV biases
    subquadratic: bool = False   # eligible for long_500k
    frontend: str = "none"       # none | audio_stub | vision_stub

    # --- runtime policy ---
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True
    scan_layers: bool = True
    kv_cache_dtype: str = "compute"   # compute (bf16) | int8 (quantized)
    use_flash_attention: bool = False  # fused Pallas attention (TPU)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.ssm_dt_rank == 0:
            object.__setattr__(self, "ssm_dt_rank",
                               ceil_to(self.d_model, 16) // 16)
        assert self.n_heads % self.n_kv_heads == 0

    # ------------------------------------------------------------- derived
    @property
    def padded_vocab(self) -> int:
        """Vocab padded to 256 so TP-16 embedding sharding always divides."""
        return ceil_to(self.vocab, 256)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def is_attn_layer(self, i: int) -> bool:
        if self.attn_every == 0:
            return True
        return i % self.attn_every == 0

    def is_moe_layer(self, i: int) -> bool:
        if self.moe_experts == 0:
            return False
        return i % self.moe_every == self.moe_offset

    # Parameter counts are computed from the actual parameter schema
    # (models/schema.py: param_count / active_param_count) so the numbers
    # can never drift from the implementation.
