"""whisper-small [audio] — encoder-decoder backbone (arXiv:2212.04356;
unverified tier). 12L enc + 12L dec, d_model 768, 12H, d_ff 3072 (GELU
MLP with biases), vocab 51865, tied embeddings.

The conv frontend is a STUB: input_specs() provides precomputed frame
embeddings (B, S_frames, 128) projected by ``audio_proj``. Deviation
noted in DESIGN.md: decoder uses RoPE instead of learned positions so
the decode_32k cell (KV cache of 32768) is well-defined."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab=51865,
    is_encdec=True, n_enc_layers=12, cross_len=1500,
    tie_embeddings=True, frontend="audio_stub",
)
