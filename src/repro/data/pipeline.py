"""Deterministic synthetic LM data pipeline.

Every batch is a pure function of (seed, step, shard) — the property the
fault-tolerance story rests on: a restarted or straggling host recomputes
*exactly* the batch it owes, so checkpoint/restart never skews the data
order and stragglers can be re-executed anywhere (DESIGN.md §4).

The token stream is a noisy affine recurrence over the vocab with
slowly-varying per-sequence coefficients: enough learnable structure for
a ~100M model to visibly drop loss within a few hundred steps (the
examples/train_lm.py driver), while needing no external corpus.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticLMData:
    vocab: int
    batch: int            # per-host batch
    seq: int
    seed: int = 0
    n_shards: int = 1
    shard: int = 0

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.shard]))
        B, S, V = self.batch, self.seq + 1, self.vocab
        a = rng.integers(1, 8, size=(B, 1))
        b = rng.integers(0, V, size=(B, 1))
        noise = rng.integers(0, 4, size=(B, S))
        t0 = rng.integers(0, V, size=(B, 1))
        idx = np.arange(S)[None, :]
        toks = (t0 + a * idx + b * (idx // 16) + noise) % V
        toks = toks.astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
