"""Serving engine with a similarity-cache front tier (the paper's system,
deployed): batched requests are embedded, looked up in the cache network,
and only misses run the model (the "repository"); responses are inserted
back according to the configured placement policy.

Hierarchy (DESIGN.md §2): level 0 = device-local shard (h=0), level 1 =
pod (ICI), level 2 = cross-pod (DCN); repository = the model itself. On
this container the levels are simulated with calibrated h costs. With
``EngineConfig.fused`` (default) a batch lookup is one fused
segmented-KNN pallas_call over all levels at once — jitted once per
placement, no per-level kernel launches or retraces. With
``EngineConfig.sharded`` and an engine ``mesh``, the segmented key
tensor is partitioned across the mesh axes picked by
``LookupShardPolicy`` and each device scans only its resident shard
(one fused kernel per shard + a tiny cross-shard reduction,
bit-identical results) — the catalog then scales with the mesh instead
of a single device's memory. ``EngineConfig.prune`` ("lsh" | "kmeans")
puts the candidate pre-filter of kernels/knn/lsh.py in front of the
scan (per shard when sharded) for catalogs ≫ 10⁵ keys;
``EngineConfig.verify`` keeps the exact scan as the verifier of last
resort, re-scanning any query past the pruning bound.

Cost-unit calibration: ``h`` values and C_a live in the same unit —
milliseconds of serving latency — via :meth:`calibrate`, which times one
model decode batch (the repository cost h_s) and scales the
dissimilarity metric so the paper's efficiency/accuracy trade-off is a
latency trade-off (γ keeps its role).

Placement control plane: the engine records empirical demand; calling
``refresh_placement(algo)`` re-solves the offline problem (GREEDY /
LOCALSWAP / cascade) on the observed measure — the paper's offline
algorithms applied on a rolling window. With
``EngineConfig.device_placement`` (default) the solve runs on the
*device-resident* control plane (core/placement/device.py): the
observed instance becomes a ``DeviceInstance``, marginal gains come
from the batched gain oracle of kernels/knn/gains.py (sharded over the
same mesh axes as the data-plane keys when ``sharded``), and
GREEDY/LOCALSWAP loop over jitted incremental updates — so a rolling
re-placement no longer stalls the host exactly when the catalog grows.
``device_placement=False`` keeps the NumPy oracles (the control-plane
twin of ``fused=False``). The two paths are bit-identical on
well-separated instances (tests/test_device_placement.py), and on an
*observed* window the tail is no longer ambiguous: never-requested
objects keep an exact-zero rate (``observed_instance`` normalizes the
raw counts in f64 with no floor), so a candidate whose only value was
tail demand has a gain of exactly 0.0 on both the f32 device path and
the f64 host path, and once the real gains are exhausted both paths
stop at the same point and leave the same slots unfilled — the old
``counts + 1e-9`` floor put sub-f32-resolution gains everywhere and
let the two paths fill the statistically-irrelevant tail in different
orders (regression pinned by tests/test_serve_engine.py::
test_observed_placement_tail_matches). Near-ties between *requested*
objects remain subject to the usual f32/f64 caveat of
core/placement/device.py.

``netduel=True`` additionally runs the §5 online policy *on device,
inside the serving loop*: a persistent ``DuelPlane``
(core/placement/netduel.py) keeps the duel state — real/virtual
savings, deadlines, serving tables — as device arrays sharded
alongside the data-plane keys (same ``LookupShardPolicy`` axes), and
each served batch is observed in one ``lax.scan`` launch priced by the
*same fused-lookup costs the data plane just computed* (a request is
priced once for serving and dueling). A settled promotion rebuilds the
runtime cache from the duel's slots (``placement_events`` counts these
churn events) — the λ-unaware complement of the offline
``refresh_placement`` solves.

Control-plane/data-plane split: the data plane (lookups) and control
plane (placement solves) share the mesh and the shard axes picked by
``LookupShardPolicy``, but run disjoint kernels — a placement refresh
is a burst of gain-oracle launches between serving batches, never on
the serving path itself.

Straggler mitigation: ``HedgedLookup`` (ft/straggler.py) wraps the
per-level lookups; a slow level is cut off and served by the next level
up — the cache hierarchy degrades gracefully by paying approximation
cost instead of waiting (a property unique to similarity caching; cost
quantified with the paper's own objective).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import demand as demand_api
from repro.core.catalog import Catalog
from repro.core.objective import DeviceInstance, Instance
from repro.core.placement import (DuelPlane, device_greedy,
                                  device_greedy_then_localswap,
                                  device_localswap, greedy,
                                  greedy_then_localswap, localswap)
from repro.core.simcache import SimCacheNetwork
from repro.core.topology import tpu_hierarchy
from repro.launch.sharding import LookupShardPolicy
from repro.models import model as model_api


@dataclasses.dataclass
class EngineConfig:
    k_device: int = 64            # level-0 slots
    k_pod: int = 128
    k_global: int = 256
    h_ici: float = 0.1            # placeholder until calibrate()
    h_dcn: float = 1.0
    h_model: float = 10.0         # repository = run the model
    gamma: float = 1.0
    metric: str = "l2"
    algo: str = "cascade"         # greedy | localswap | cascade
    fused: bool = True            # single fused lookup kernel per batch
    sharded: bool = False         # mesh-sharded keys (needs engine mesh)
    prune: str | None = None      # "lsh" | "kmeans" candidate pre-filter
    verify: bool = False          # exact re-scan past the pruning bound
    device_placement: bool = True  # device-resident placement control plane
    swap_tol: float = 1e-3        # device LOCALSWAP accept margin (f32-safe
    #                               at calibrated-ms cost scales)
    netduel: bool = False         # §5 online duels on device, per batch
    duel_window: int = 512        # duel length in requests
    duel_delta: float = 0.05      # relative promotion margin δ
    duel_arm_prob: float = 0.25   # per-request arming probability
    duel_seed: int = 0            # arming-randomness seed


@dataclasses.dataclass
class ServeStats:
    n_requests: int = 0
    n_hits: int = 0
    total_cost: float = 0.0
    total_approx_cost: float = 0.0
    model_calls: int = 0

    @property
    def hit_rate(self) -> float:
        return self.n_hits / max(self.n_requests, 1)

    @property
    def mean_cost(self) -> float:
        return self.total_cost / max(self.n_requests, 1)


class SimCacheEngine:
    """Batched serving for a decoder LM behind a similarity-cache network."""

    def __init__(self, cfg: ArchConfig, params, ecfg: EngineConfig,
                 catalog_coords: np.ndarray,
                 mesh: jax.sharding.Mesh | None = None):
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        self.coords = catalog_coords.astype(np.float32)   # request space
        self.net = tpu_hierarchy(ecfg.k_device, ecfg.k_pod, ecfg.k_global,
                                 ecfg.h_ici, ecfg.h_dcn, ecfg.h_model)
        self.counts = np.zeros(self.coords.shape[0], dtype=np.float64)
        self.responses: dict[int, np.ndarray] = {}        # payload store
        self.stats = ServeStats()
        self.duel: DuelPlane | None = None                # online §5 plane
        self.placement_events = 0                         # duel churn count
        self._prefill = jax.jit(model_api.make_prefill(cfg))
        self.simcache: SimCacheNetwork | None = None
        # key-axis shard policy for the sharded data plane: resolved once
        # from the mesh, reused on every placement refresh
        self.mesh = mesh
        self.lookup_shards = (LookupShardPolicy.create(mesh,
                                                       prune=ecfg.prune)
                              if mesh is not None else None)
        if ecfg.sharded and mesh is None:
            raise ValueError("EngineConfig.sharded requires a mesh")

    # ------------------------------------------------------- calibration
    def calibrate(self, sample_prompt: jnp.ndarray, n: int = 3) -> float:
        """Measure the repository cost (one prefill batch) in ms and set
        h_model; ICI/DCN levels get fixed fractions (real deployments
        measure them the same way)."""
        self._prefill(self.params, {"tokens": sample_prompt})
        t0 = time.perf_counter()
        for _ in range(n):
            jax.block_until_ready(
                self._prefill(self.params, {"tokens": sample_prompt}))
        ms = (time.perf_counter() - t0) / n * 1e3
        self.ecfg.h_model = ms
        self.ecfg.h_ici = ms * 0.01
        self.ecfg.h_dcn = ms * 0.1
        self.net = tpu_hierarchy(self.ecfg.k_device, self.ecfg.k_pod,
                                 self.ecfg.k_global, self.ecfg.h_ici,
                                 self.ecfg.h_dcn, self.ecfg.h_model)
        return ms

    # ----------------------------------------------------- control plane
    def observed_instance(self) -> Instance:
        """Empirical demand window as a placement instance.

        Counts are normalized in f64 with *no* floor: never-requested
        objects keep an exact-zero rate, so every candidate gain they
        would contribute is exactly 0.0 in f32 and f64 alike and the
        host/device solvers agree bit-for-bit on the (unplaced) tail —
        the old ``counts + 1e-9`` floor drowned the tail below f32
        resolution instead. A cold engine (no requests yet) falls back
        to uniform demand.
        """
        total = self.counts.sum()
        if total <= 0.0:
            lam = np.full_like(self.counts, 1.0 / self.counts.size)
        else:
            lam = self.counts / total
        dem = demand_api.Demand(lam=lam[None, :])
        cat = Catalog(coords=self.coords, metric=self.ecfg.metric,
                      gamma=self.ecfg.gamma)
        return Instance(net=self.net, cat=cat, dem=dem)

    def refresh_placement(self, algo: str | None = None,
                          device: bool | None = None) -> float:
        """Re-solve offline placement on the observed demand window;
        rebuild the runtime cache. Returns the predicted C(A).

        ``device=None`` follows ``EngineConfig.device_placement``: the
        default device path solves on a DeviceInstance via the batched
        gain oracle (mesh-sharded alongside the data-plane keys when
        ``sharded``); ``device=False`` forces the NumPy oracles.
        """
        algo = algo or self.ecfg.algo
        if device is None:
            device = self.ecfg.device_placement
        inst = self.observed_instance()
        if device:
            sh = (self.lookup_shards.gain_shard_args()
                  if (self.ecfg.sharded and self.lookup_shards) else None)
            dinst = DeviceInstance.from_instance(
                inst, mesh=sh[0] if sh else None,
                axes=sh[1] if sh else (), materialize_ca=False)
            if algo == "greedy":
                slots = device_greedy(dinst)
            elif algo == "localswap":
                slots = device_localswap(dinst, n_iters=4000,
                                         tol=self.ecfg.swap_tol).slots_np
            else:
                slots = device_greedy_then_localswap(
                    dinst, max_passes=8, tol=self.ecfg.swap_tol).slots_np
        elif algo == "greedy":
            slots = greedy(inst)
        elif algo == "localswap":
            slots = localswap(inst, n_iters=4000).slots
        else:
            slots = greedy_then_localswap(inst, max_passes=8).slots
        slots = np.where(slots < 0, 0, slots)
        self._rebuild_simcache(slots, inst.slot_cache)
        if self.ecfg.netduel:
            # online §5 plane: duel state lives on device, sharded along
            # the same axes as the data-plane keys, and persists across
            # serve() batches (reset on every offline re-solve)
            sh = (self.lookup_shards.gain_shard_args()
                  if (self.ecfg.sharded and self.lookup_shards) else None)
            duel_dinst = DeviceInstance.from_instance(
                inst, mesh=sh[0] if sh else None,
                axes=sh[1] if sh else (), materialize_ca=False)
            self.duel = DuelPlane(
                duel_dinst, slots, window=self.ecfg.duel_window,
                delta=self.ecfg.duel_delta,
                arm_prob=self.ecfg.duel_arm_prob, seed=self.ecfg.duel_seed)
        if device:
            # device evaluator — the only C(A) path that exists past
            # objective.CA_MATERIALIZE_MAX catalogs
            return dinst.total_cost(slots)
        return inst.total_cost(slots)

    def _rebuild_simcache(self, slots: np.ndarray,
                          slot_cache: np.ndarray | None = None) -> None:
        """(Re)build the runtime lookup network from an allocation —
        shared by the offline refresh and the online duel's promotion
        churn."""
        if slot_cache is None:
            slot_cache = self.net.slot_layout()
        hs = [0.0, self.ecfg.h_ici, self.ecfg.h_dcn]
        self.simcache = SimCacheNetwork.from_placement(
            self.coords, slots, slot_cache, hs, self.ecfg.h_model,
            metric=self.ecfg.metric, gamma=self.ecfg.gamma,
            fused=self.ecfg.fused, sharded=self.ecfg.sharded,
            mesh=self.mesh,
            shard_axes=(self.lookup_shards.axes
                        if self.lookup_shards else None),
            candidate_policy=(self.lookup_shards.candidate_policy()
                              if self.lookup_shards else None))

    # --------------------------------------------------------- data plane
    def serve(self, request_ids: np.ndarray, prompts: jnp.ndarray
              ) -> tuple[list, ServeStats]:
        """Serve a batch. request_ids index the catalog (their embeddings
        are the lookup keys); prompts are the token batch for misses."""
        self.counts[request_ids] += 1.0
        self.stats.n_requests += len(request_ids)
        out: list = [None] * len(request_ids)

        if self.simcache is None:
            miss_idx = np.arange(len(request_ids))
        else:
            q = jnp.asarray(self.coords[request_ids])
            res = self.simcache.lookup(q, prune=self.ecfg.prune,
                                       verify=self.ecfg.verify)
            hits = np.asarray(res.hit)
            payloads = np.asarray(res.payload)
            self.stats.total_cost += float(np.sum(np.asarray(res.cost)))
            self.stats.total_approx_cost += float(
                np.sum(np.asarray(res.approx_cost)))
            for i in np.nonzero(hits)[0]:
                out[i] = self.responses.get(int(payloads[i]))
            self.stats.n_hits += int(hits.sum())
            miss_idx = np.nonzero(~hits)[0]
            if self.duel is not None:
                # online control plane: observe the batch in one scan
                # launch, priced by the costs the lookup just computed
                if self.duel.observe(np.asarray(request_ids),
                                     b1_ext=np.asarray(res.cost)):
                    self._rebuild_simcache(self.duel.slots_np)
                    self.placement_events += 1

        if len(miss_idx):
            # repository: run the model on the miss sub-batch
            logits, _ = self._prefill(self.params,
                                      {"tokens": prompts[miss_idx]})
            resp = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1))
            self.stats.model_calls += 1
            if self.simcache is None:
                self.stats.total_cost += self.ecfg.h_model * len(miss_idx)
            for j, i in enumerate(miss_idx):
                rid = int(request_ids[i])
                self.responses[rid] = resp[j:j + 1]
                out[i] = resp[j:j + 1]
        return out, self.stats
