"""Serving engine with a similarity-cache front tier (the paper's system,
deployed): batched requests are embedded, looked up in the cache network,
and only misses run the model (the "repository"); responses are inserted
back according to the configured placement policy.

Hierarchy (DESIGN.md §2): level 0 = device-local shard (h=0), level 1 =
pod (ICI), level 2 = cross-pod (DCN); repository = the model itself. On
this container the levels are simulated with calibrated h costs. With
``EngineConfig.fused`` (default) a batch lookup is one fused
segmented-KNN pallas_call over all levels at once — jitted once per
placement, no per-level kernel launches or retraces. With
``EngineConfig.sharded`` and an engine ``mesh``, the segmented key
tensor is partitioned across the mesh axes picked by
``LookupShardPolicy`` and each device scans only its resident shard
(one fused kernel per shard + a tiny cross-shard reduction,
bit-identical results) — the catalog then scales with the mesh instead
of a single device's memory. ``EngineConfig.prune`` ("lsh" | "kmeans")
puts the candidate pre-filter of kernels/knn/lsh.py in front of the
scan (per shard when sharded) for catalogs ≫ 10⁵ keys;
``EngineConfig.verify`` keeps the exact scan as the verifier of last
resort, re-scanning any query past the pruning bound.

Batch bucketing (``EngineConfig.bucket``, default on): every served
batch is padded up to a power-of-two bucket (≥ ``min_bucket``) before
touching a jitted entry point — the fused lookup, the duel scan
(``DuelPlane.observe(n_valid=…)``), and the miss-prefill each compile
once per *bucket*, not once per distinct batch size. Padding rows are
masked everywhere: they never enter ``counts``, ``ServeStats``, the
duel trajectory (bit-identical to the unpadded one — the masked-scan
contract of core/placement/netduel.py), or the responses returned.
Without bucketing a mixed-batch-size request stream pays one XLA
compile per new size per entry point — the retrace pathology the
streaming driver (serve/stream.py) and benchmarks/serving_bench.py
quantify.

Double-buffered placement: the active data plane lives in a versioned
:class:`PlacementBuffer` (simcache + the allocation it serves).
``refresh_placement`` stays the synchronous path (solve, install, swap
— one call); the streaming path splits it: ``request_refresh`` snapshots
the observed demand and solves GREEDY/LOCALSWAP on the device control
plane *in a background thread while the old placement keeps serving*,
and ``poll_refresh`` installs a finished solve with one atomic swap
(rebuild the runtime network host-side, re-arm the duel plane, bump
``PlacementBuffer.version``). The swap — never the solve — is the only
serving-thread stall, timed into ``swap_stall_s``/``max_swap_stall_s``;
``refresh_in_flight`` and the version counter make the whole cycle
observable and race-free (the worker only writes the pending result
under a lock; the serving thread swaps it in between batches).

Cost-unit calibration: ``h`` values and C_a live in the same unit —
milliseconds of serving latency — via :meth:`calibrate`, which times one
model decode batch (the repository cost h_s) and scales the
dissimilarity metric so the paper's efficiency/accuracy trade-off is a
latency trade-off (γ keeps its role). Calibration *invalidates the
active placement buffer*: an already-built simcache indexes the old h
costs (and its memoized LSH tables / shard layouts index that stale
layout), so the runtime network is rebuilt from the held allocation
with the measured costs — the staleness this used to leave behind is
pinned by tests/test_serve_engine.py::test_calibrate_rebuilds_simcache.

Placement control plane: the engine records empirical demand; calling
``refresh_placement(algo)`` re-solves the offline problem (GREEDY /
LOCALSWAP / cascade) on the observed measure — the paper's offline
algorithms applied on a rolling window. With
``EngineConfig.device_placement`` (default) the solve runs on the
*device-resident* control plane (core/placement/device.py): the
observed instance becomes a ``DeviceInstance``, marginal gains come
from the batched gain oracle of kernels/knn/gains.py (sharded over the
same mesh axes as the data-plane keys when ``sharded``), and
GREEDY/LOCALSWAP loop over jitted incremental updates — so a rolling
re-placement no longer stalls the host exactly when the catalog grows.
``device_placement=False`` keeps the NumPy oracles (the control-plane
twin of ``fused=False``). The two paths are bit-identical on
well-separated instances (tests/test_device_placement.py), and on an
*observed* window the tail is no longer ambiguous: never-requested
objects keep an exact-zero rate (``observed_instance`` normalizes the
raw counts in f64 with no floor), so a candidate whose only value was
tail demand has a gain of exactly 0.0 on both the f32 device path and
the f64 host path, and once the real gains are exhausted both paths
stop at the same pick and leave the same slots unfilled — the old
``counts + 1e-9`` floor put sub-f32-resolution gains everywhere and
let the two paths fill the statistically-irrelevant tail in different
orders (regression pinned by tests/test_serve_engine.py::
test_observed_placement_tail_matches). Near-ties between *requested*
objects remain subject to the usual f32/f64 caveat of
core/placement/device.py.

``netduel=True`` additionally runs the §5 online policy *on device,
inside the serving loop*: a persistent ``DuelPlane``
(core/placement/netduel.py) keeps the duel state — real/virtual
savings, deadlines, serving tables — as device arrays sharded
alongside the data-plane keys (same ``LookupShardPolicy`` axes), and
each served batch is observed in one ``lax.scan`` launch priced by the
*same fused-lookup costs the data plane just computed* (a request is
priced once for serving and dueling). A settled promotion rebuilds the
runtime cache from the duel's slots (``placement_events`` counts these
churn events) — the λ-unaware complement of the offline
``refresh_placement`` solves. With ``refresh_on_promotion=True`` a
settled promotion additionally *triggers* a background offline rebuild
(``request_refresh``): the duel's churn is the signal that observed
demand drifted enough to justify re-solving — the rebuild trigger of
the streaming loop.

Control-plane/data-plane split: the data plane (lookups) and control
plane (placement solves) share the mesh and the shard axes picked by
``LookupShardPolicy``, but run disjoint kernels — a placement refresh
is a burst of gain-oracle launches between serving batches (or on the
background thread), never on the serving path itself.

Straggler mitigation: ``HedgedLookup`` (ft/straggler.py) wraps the
per-level lookups; a slow level is cut off and served by the next level
up — the cache hierarchy degrades gracefully by paying approximation
cost instead of waiting (a property unique to similarity caching; cost
quantified with the paper's own objective).
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import demand as demand_api
from repro.core.analysis import surrogate_cost
from repro.core.catalog import Catalog
from repro.core.objective import DeviceInstance, Instance
from repro.core.placement import (DuelPlane, device_greedy,
                                  device_greedy_then_localswap,
                                  device_localswap, greedy,
                                  greedy_then_localswap, localswap,
                                  warmstart)
from repro.core.routing import StrategyPlane
from repro.core.simcache import SimCacheNetwork
from repro.core.topology import CacheNetwork, tpu_hierarchy
from repro.launch.sharding import LookupShardPolicy
from repro.models import model as model_api


def bucket_size(n: int, lo: int = 8) -> int:
    """Smallest power-of-two bucket ≥ max(n, lo) — the shape every jitted
    serving entry point actually sees under ``EngineConfig.bucket``."""
    m = max(int(lo), 1)
    while m < n:
        m <<= 1
    return m


def _pad_rows(x, m: int):
    """Pad axis 0 up to m rows by repeating row 0 (a always-valid filler:
    real coordinates / real tokens, so padded rows can never produce
    NaN/inf that a zero-filler might under exotic metrics). Results for
    padding rows are discarded by the caller — per-row kernel outputs
    are independent, so the first n rows are bitwise the unpadded run's."""
    n = x.shape[0]
    if m <= n:
        return x
    reps = jnp.repeat(x[:1], m - n, axis=0) if isinstance(x, jax.Array) \
        else np.repeat(x[:1], m - n, axis=0)
    cat = jnp.concatenate if isinstance(x, jax.Array) else np.concatenate
    return cat([x, reps], axis=0)


@dataclasses.dataclass
class EngineConfig:
    k_device: int = 64            # level-0 slots
    k_pod: int = 128
    k_global: int = 256
    h_ici: float = 0.1            # placeholder until calibrate()
    h_dcn: float = 1.0
    h_model: float = 10.0         # repository = run the model
    gamma: float = 1.0
    metric: str = "l2"
    algo: str = "cascade"         # greedy | localswap | cascade
    fused: bool = True            # single fused lookup kernel per batch
    sharded: bool = False         # mesh-sharded keys (needs engine mesh)
    prune: str | None = None      # "lsh" | "kmeans" candidate pre-filter
    verify: bool = False          # exact re-scan past the pruning bound
    quantize: bool = False        # int8 lower-bound first pass + exact
    #                               rescoring of the top-T candidates
    #                               (composes with prune/sharded; with
    #                               verify=True bit-identical to exact)
    device_placement: bool = True  # device-resident placement control plane
    swap_tol: float = 1e-3        # device LOCALSWAP accept margin (f32-safe
    #                               at calibrated-ms cost scales)
    netduel: bool = False         # §5 online duels on device, per batch
    duel_window: int = 512        # duel length in requests
    duel_delta: float = 0.05      # relative promotion margin δ
    duel_arm_prob: float = 0.25   # per-request arming probability
    duel_seed: int = 0            # arming-randomness seed
    bucket: bool = True           # power-of-two batch bucketing
    min_bucket: int = 8           # smallest bucket (tiny batches coalesce)
    refresh_on_promotion: bool = False  # duel churn → background re-solve
    refresh_min_gain: float = 0.0 # analytic refresh gate: request_refresh
    #                               prices the snapshotted demand with the
    #                               Che surrogate (core/analysis/hitrate)
    #                               and skips the device solve when the
    #                               predicted cost moved less than this
    #                               since the last installed solve (cost
    #                               units, i.e. calibrated ms; 0 = gate
    #                               off, every request solves)
    warm_start: bool = False      # §4 continuous-limit warm start: solve
    #                               the topology's continuous program,
    #                               band-map (Prop 4.2), polish — replaces
    #                               the O(O·J) discrete solve on every
    #                               refresh when the topology reduces
    warm_polish_iters: int = 512  # LOCALSWAP polish window after the
    #                               analytic warm start (O(1) in catalog
    #                               size; 0 = pure analytic placement)
    strategy: str | None = None   # on-path routing strategy (core/routing.py:
    #                               lce | lcd | probcache | sim-lru | rnd-lru)
    #                               instead of the offline-placement plane —
    #                               the λ-unaware baseline on any graph,
    #                               including multi-ingress nets the fused
    #                               simcache can't serve
    strategy_threshold: float | None = None  # C_a admission threshold θ
    strategy_seed: int = 0        # probcache / rnd-lru coin seed


# retained batch-latency window: percentiles are computed over the most
# recent LATENCY_WINDOW batches. An unbounded list was a slow leak on
# long driver runs (every batch appended forever); a deque(maxlen=…)
# ring keeps memory O(1) and the percentiles exact on the window.
LATENCY_WINDOW = 65536


@dataclasses.dataclass
class ServeStats:
    n_requests: int = 0
    n_hits: int = 0
    total_cost: float = 0.0
    total_approx_cost: float = 0.0
    model_calls: int = 0
    # refresh-gate outcomes (EngineConfig.refresh_min_gain): requests
    # skipped because the analytic surrogate saw too small a predicted
    # cost delta vs started because it saw enough (or the gate is off)
    refresh_skipped: int = 0
    refresh_triggered: int = 0
    # wall-clock per served batch (appended by SimCacheEngine.serve);
    # the latency percentiles the streaming driver/bench report —
    # bounded ring, newest LATENCY_WINDOW batches
    batch_latencies_ms: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=LATENCY_WINDOW))

    @property
    def hit_rate(self) -> float:
        return self.n_hits / max(self.n_requests, 1)

    @property
    def mean_cost(self) -> float:
        return self.total_cost / max(self.n_requests, 1)

    def latency_percentile(self, q: float) -> float:
        if not self.batch_latencies_ms:
            return 0.0
        return float(np.percentile(self.batch_latencies_ms, q))

    @property
    def p50_ms(self) -> float:
        return self.latency_percentile(50)

    @property
    def p95_ms(self) -> float:
        return self.latency_percentile(95)

    @property
    def p99_ms(self) -> float:
        return self.latency_percentile(99)


class PlacementBuffer:
    """The active data plane, versioned: the runtime cache network plus
    the allocation it was built from. The control plane never mutates a
    live buffer's network — it builds the next state and the engine
    swaps it in atomically (one pointer assignment + version bump on the
    serving thread), so a lookup always runs against a complete,
    internally consistent placement and ``version`` tells every observer
    exactly which one."""

    def __init__(self):
        self.simcache: SimCacheNetwork | None = None
        self.slots: np.ndarray | None = None
        self.slot_cache: np.ndarray | None = None
        self.version: int = 0

    def install(self, simcache: SimCacheNetwork, slots: np.ndarray,
                slot_cache: np.ndarray) -> None:
        self.simcache = simcache
        self.slots = slots
        self.slot_cache = slot_cache
        self.version += 1


class SimCacheEngine:
    """Batched serving for a decoder LM behind a similarity-cache network."""

    def __init__(self, cfg: ArchConfig, params, ecfg: EngineConfig,
                 catalog_coords: np.ndarray,
                 mesh: jax.sharding.Mesh | None = None,
                 net: CacheNetwork | None = None):
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        self.coords = catalog_coords.astype(np.float32)   # request space
        # ``net`` overrides the built-in 3-level hierarchy with any
        # CacheNetwork (e.g. a core.scenarios general-graph scenario);
        # calibrate() only knows how to rescale the built-in one.
        self.custom_net = net is not None
        self.net = net if net is not None else tpu_hierarchy(
            ecfg.k_device, ecfg.k_pod, ecfg.k_global,
            ecfg.h_ici, ecfg.h_dcn, ecfg.h_model)
        # per-(ingress, object) empirical demand — multi-ingress nets see
        # the demand each ingress actually received (single-ingress
        # callers land everything in row 0)
        self.counts = np.zeros((self.net.n_ingress, self.coords.shape[0]),
                               dtype=np.float64)
        # on-path strategy plane: when configured it IS the serving
        # decision maker (per-request LRU walk over the ingress's path)
        # and the offline simcache is never built
        self.routing: StrategyPlane | None = None
        if ecfg.strategy is not None:
            self.routing = StrategyPlane(
                self.net, self.coords, metric=ecfg.metric,
                gamma=ecfg.gamma, strategy=ecfg.strategy,
                threshold=ecfg.strategy_threshold, seed=ecfg.strategy_seed)
        self.responses: dict[int, np.ndarray] = {}        # payload store
        self.stats = ServeStats()
        self.duel: DuelPlane | None = None                # online §5 plane
        self.placement_events = 0                         # duel churn count
        self._prefill = jax.jit(model_api.make_prefill(cfg))
        self.placement = PlacementBuffer()                # active data plane
        # background-refresh control: the worker thread solves, the
        # serving thread swaps; _pending crosses under _refresh_lock
        self._refresh_lock = threading.Lock()
        self._refresh_thread: threading.Thread | None = None
        self._pending: tuple | None = None
        self._in_flight = False
        self.refresh_count = 0            # completed installs (sync+async)
        self.swap_count = 0               # async atomic swaps
        self.swap_stall_s = 0.0           # total serving-thread swap time
        self.max_swap_stall_s = 0.0       # all-time max across swaps
        self.last_swap_stall_s = 0.0      # most recent swap only — what
        #                                   per-run windows (stream.py) max
        #                                   over, instead of the all-time
        #                                   value above
        self.last_predicted_cost: float | None = None
        # analytic-surrogate cost at the demand snapshot of the last
        # installed solve — the refresh gate's comparison point (None
        # until a gated solve has run, so the first request always goes
        # through)
        self._surrogate_baseline: float | None = None
        # key-axis shard policy for the sharded data plane: resolved once
        # from the mesh, reused on every placement refresh
        self.mesh = mesh
        self.lookup_shards = (LookupShardPolicy.create(mesh,
                                                       prune=ecfg.prune)
                              if mesh is not None else None)
        if ecfg.sharded and mesh is None:
            raise ValueError("EngineConfig.sharded requires a mesh")

    # -------------------------------------------------- data-plane state
    @property
    def simcache(self) -> SimCacheNetwork | None:
        """The active runtime network (the double buffer's live half)."""
        return self.placement.simcache

    @property
    def placement_version(self) -> int:
        return self.placement.version

    @property
    def refresh_in_flight(self) -> bool:
        """True from ``request_refresh`` until the swap lands (the
        observable refresh-in-flight flag of the streaming loop)."""
        return self._in_flight

    # ------------------------------------------------------- calibration
    def calibrate(self, sample_prompt: jnp.ndarray, n: int = 3) -> float:
        """Measure the repository cost (one prefill batch) in ms and set
        h_model; ICI/DCN levels get fixed fractions (real deployments
        measure them the same way).

        Rebuilds the topology *and* the active placement buffer: a
        simcache built before calibration serves the old h costs (its
        per-key cost offsets, memoized LSH tables and shard layouts all
        bake the stale values in), so the held allocation is re-installed
        against the measured costs, and an armed duel plane — priced in
        the old cost units — is re-armed from the observed window.
        """
        if self.custom_net:
            raise ValueError(
                "calibrate() rescales the built-in tpu_hierarchy levels; "
                "a custom CacheNetwork carries its own cost unit — build "
                "it with calibrated delays instead")
        self._prefill(self.params, {"tokens": sample_prompt})
        t0 = time.perf_counter()
        for _ in range(n):
            jax.block_until_ready(
                self._prefill(self.params, {"tokens": sample_prompt}))
        ms = (time.perf_counter() - t0) / n * 1e3
        self.ecfg.h_model = ms
        self.ecfg.h_ici = ms * 0.01
        self.ecfg.h_dcn = ms * 0.1
        self.net = tpu_hierarchy(self.ecfg.k_device, self.ecfg.k_pod,
                                 self.ecfg.k_global, self.ecfg.h_ici,
                                 self.ecfg.h_dcn, self.ecfg.h_model)
        if self.placement.slots is not None:
            # re-install the held allocation with the measured costs —
            # the stale-simcache regression fix (same slots, new h's)
            self._rebuild_simcache(self.placement.slots,
                                   self.placement.slot_cache)
            if self.duel is not None:
                self._arm_duel(self.observed_instance(),
                               self.placement.slots)
        return ms

    # ----------------------------------------------------- control plane
    def observed_instance(self) -> Instance:
        """Empirical demand window as a placement instance.

        Counts are normalized in f64 with *no* floor: never-requested
        objects keep an exact-zero rate, so every candidate gain they
        would contribute is exactly 0.0 in f32 and f64 alike and the
        host/device solvers agree bit-for-bit on the (unplaced) tail —
        the old ``counts + 1e-9`` floor drowned the tail below f32
        resolution instead. A cold engine (no requests yet) falls back
        to uniform demand.

        Counts are per-(ingress, object): a multi-ingress net's solve
        sees the demand each ingress actually received, not a collapsed
        single-row copy (the old ``lam[None, :]`` hardcoding).
        """
        total = self.counts.sum()
        if total <= 0.0:
            lam = np.full_like(self.counts, 1.0 / self.counts.size)
        else:
            lam = self.counts / total
        dem = demand_api.Demand(lam=lam)
        cat = Catalog(coords=self.coords, metric=self.ecfg.metric,
                      gamma=self.ecfg.gamma)
        return Instance(net=self.net, cat=cat, dem=dem)

    def _control_shard_args(self):
        """(mesh, axes) for the control plane, or None — the single
        resolution point shared by the solver, the duel plane, and the
        background refresh (LookupShardPolicy.control_plane_args)."""
        if self.lookup_shards is None:
            return None
        return self.lookup_shards.control_plane_args(self.ecfg.sharded)

    def _solve(self, inst: Instance, algo: str, device: bool,
               shard: bool = True) -> tuple[np.ndarray, float]:
        """Run the offline solver on one observed instance; returns the
        (clamped) allocation and the predicted C(A). Pure function of
        its inputs — safe to run on the background refresh thread.

        ``shard=False`` solves on a single device even when the engine
        is mesh-sharded. The background refresh thread must use it: two
        threads enqueueing *collective* programs concurrently (the
        sharded control-plane solve racing the serving thread's sharded
        lookups) have no cross-program per-device launch-order
        guarantee, so their device executions can interleave and
        deadlock the client's collective rendezvous. The control-plane
        oracles are bit-identical at any shard count (locked by
        tests/test_device_placement.py), so the unsharded background
        solve returns the same allocation the sharded one would — the
        atomic-swap differentials in tests/test_streaming.py assert
        exactly that against a sharded synchronous solve.

        With ``EngineConfig.warm_start`` on and a topology that reduces
        to a §4 continuous program (the engine's tpu_hierarchy chain
        always does), the discrete solver is replaced by the
        continuous-limit pipeline of placement/warmstart.py: solve the
        program analytically, band-map per Prop 4.2, polish with a
        bounded LOCALSWAP window — deterministic, so background
        refreshes stay replayable. Irreducible topologies fall back to
        ``algo`` untouched."""
        warm_red = warmstart.classify_topology(inst.net,
                                               gamma=inst.cat.gamma) \
            if self.ecfg.warm_start else None
        if device:
            sh = self._control_shard_args() if shard else None
            dinst = DeviceInstance.from_instance(
                inst, mesh=sh[0] if sh else None,
                axes=sh[1] if sh else (), materialize_ca=False)
        if warm_red is not None:
            slots = warmstart.warm_start(
                inst, reduction=warm_red, device=device,
                dinst=dinst if device else None,
                polish_iters=self.ecfg.warm_polish_iters,
                tol=self.ecfg.swap_tol).slots
        elif device:
            if algo == "greedy":
                slots = device_greedy(dinst)
            elif algo == "localswap":
                slots = device_localswap(dinst, n_iters=4000,
                                         tol=self.ecfg.swap_tol).slots_np
            else:
                slots = device_greedy_then_localswap(
                    dinst, max_passes=8, tol=self.ecfg.swap_tol).slots_np
        elif algo == "greedy":
            slots = greedy(inst)
        elif algo == "localswap":
            slots = localswap(inst, n_iters=4000).slots
        else:
            slots = greedy_then_localswap(inst, max_passes=8).slots
        slots = np.where(slots < 0, 0, slots)
        if device:
            # device evaluator — the only C(A) path that exists past
            # objective.CA_MATERIALIZE_MAX catalogs
            pred = dinst.total_cost(slots)
        else:
            pred = inst.total_cost(slots)
        return slots, pred

    def _arm_duel(self, inst: Instance, slots: np.ndarray) -> None:
        """(Re-)arm the online §5 plane: duel state lives on device,
        sharded along the same axes as the data-plane keys, and persists
        across serve() batches (reset on every offline install)."""
        sh = self._control_shard_args()
        duel_dinst = DeviceInstance.from_instance(
            inst, mesh=sh[0] if sh else None,
            axes=sh[1] if sh else (), materialize_ca=False)
        self.duel = DuelPlane(
            duel_dinst, slots, window=self.ecfg.duel_window,
            delta=self.ecfg.duel_delta,
            arm_prob=self.ecfg.duel_arm_prob, seed=self.ecfg.duel_seed)

    def _install(self, slots: np.ndarray, inst: Instance) -> None:
        """Install a solved allocation into the active buffer: rebuild
        the runtime network, re-arm the duel plane, bump the version.
        Runs on the serving thread — this *is* the atomic swap."""
        self._rebuild_simcache(slots, inst.slot_cache)
        if self.ecfg.netduel:
            self._arm_duel(inst, slots)
        self.refresh_count += 1

    def refresh_placement(self, algo: str | None = None,
                          device: bool | None = None) -> float:
        """Re-solve offline placement on the observed demand window;
        rebuild the runtime cache. Returns the predicted C(A).

        ``device=None`` follows ``EngineConfig.device_placement``: the
        default device path solves on a DeviceInstance via the batched
        gain oracle (mesh-sharded alongside the data-plane keys when
        ``sharded``); ``device=False`` forces the NumPy oracles.

        This is the *synchronous* path (solve + install in one call,
        serving blocked throughout) — the streaming loop uses
        :meth:`request_refresh` / :meth:`poll_refresh` instead.
        """
        algo = algo or self.ecfg.algo
        if device is None:
            device = self.ecfg.device_placement
        inst = self.observed_instance()
        slots, pred = self._solve(inst, algo, device)
        self._install(slots, inst)
        self.last_predicted_cost = pred
        if self.ecfg.refresh_min_gain > 0.0:
            self._surrogate_baseline = surrogate_cost(
                inst.net, np.asarray(inst.dem.lam, np.float64))
        return pred

    # ------------------------------------------- double-buffered refresh
    def request_refresh(self, algo: str | None = None,
                        device: bool | None = None) -> bool:
        """Start a background placement re-solve against a snapshot of
        the observed demand; the active buffer keeps serving throughout.
        Returns False (and does nothing) if a refresh is already in
        flight. The finished solve is *not* installed here — call
        :meth:`poll_refresh` from the serving loop to swap it in.

        With ``EngineConfig.refresh_min_gain > 0`` the snapshot is first
        priced by the analytic Che surrogate
        (``core.analysis.surrogate_cost``, milliseconds even at 10⁶
        objects): if the predicted per-request cost moved less than the
        gate since the demand snapshot of the last installed solve, the
        device solve is skipped (returns False,
        ``ServeStats.refresh_skipped`` += 1) — stationary demand stops
        paying for rebuilds it doesn't need, while drift still triggers
        (``refresh_triggered``)."""
        if self._in_flight:
            return False
        algo = algo or self.ecfg.algo
        if device is None:
            device = self.ecfg.device_placement
        inst = self.observed_instance()       # snapshot: lam is a copy
        surrogate_now: float | None = None
        if self.ecfg.refresh_min_gain > 0.0:
            surrogate_now = surrogate_cost(
                inst.net, np.asarray(inst.dem.lam, np.float64))
            base = self._surrogate_baseline
            if base is not None and \
                    abs(surrogate_now - base) < self.ecfg.refresh_min_gain:
                self.stats.refresh_skipped += 1
                return False
            self.stats.refresh_triggered += 1
        self._in_flight = True

        def work():
            try:
                # unsharded: a collective solve here would race the
                # serving thread's collectives (see _solve's docstring)
                slots, pred = self._solve(inst, algo, device, shard=False)
                with self._refresh_lock:
                    self._pending = (slots, inst, pred, surrogate_now)
            except BaseException:
                self._in_flight = False       # never wedge the flag
                raise

        self._refresh_thread = threading.Thread(
            target=work, name="placement-refresh", daemon=True)
        self._refresh_thread.start()
        return True

    def wait_refresh(self, timeout: float | None = None) -> bool:
        """Block until the in-flight solve finishes (the *solve*, not the
        swap — call :meth:`poll_refresh` after). True if nothing is
        running or the thread completed within ``timeout``."""
        t = self._refresh_thread
        if t is None or not t.is_alive():
            return True
        t.join(timeout)
        return not t.is_alive()

    def poll_refresh(self) -> bool:
        """Install a finished background solve, if any: the atomic swap.
        The serving thread stalls only for the host-side rebuild + duel
        re-arm (timed into ``swap_stall_s``/``max_swap_stall_s``), never
        for the solve. Returns True iff a swap happened."""
        with self._refresh_lock:
            pend, self._pending = self._pending, None
        if pend is None:
            return False
        slots, inst, pred, surrogate_now = pend
        t0 = time.perf_counter()
        self._install(slots, inst)
        stall = time.perf_counter() - t0
        self.swap_stall_s += stall
        self.max_swap_stall_s = max(self.max_swap_stall_s, stall)
        self.last_swap_stall_s = stall
        self.swap_count += 1
        self.last_predicted_cost = pred
        if surrogate_now is not None:
            # the installed solve's snapshot becomes the gate baseline
            self._surrogate_baseline = surrogate_now
        self._in_flight = False
        return True

    def _rebuild_simcache(self, slots: np.ndarray,
                          slot_cache: np.ndarray | None = None) -> None:
        """(Re)build the runtime lookup network from an allocation and
        install it into the placement buffer (version += 1) — shared by
        the offline install, the online duel's promotion churn, and the
        calibration rebuild."""
        if self.net.n_ingress > 1:
            raise ValueError(
                "the fused simcache serves one ingress row of H; a "
                "multi-ingress CacheNetwork needs the on-path strategy "
                "plane (EngineConfig.strategy) instead")
        if slot_cache is None:
            slot_cache = self.net.slot_layout()
        if self.custom_net:
            # a custom single-ingress net (core/scenarios.py) carries its
            # own per-cache reach costs in its H row
            hs = [float(h) for h in np.asarray(self.net.H[0], np.float64)]
            h_repo = float(self.net.h_repo[0])
        else:
            # built-in hierarchy: use the exact f64 config values (the
            # net stores H in f32 — going through it would round them)
            hs = [0.0, self.ecfg.h_ici, self.ecfg.h_dcn]
            h_repo = self.ecfg.h_model
        simcache = SimCacheNetwork.from_placement(
            self.coords, slots, slot_cache, hs, h_repo,
            metric=self.ecfg.metric, gamma=self.ecfg.gamma,
            fused=self.ecfg.fused, sharded=self.ecfg.sharded,
            mesh=self.mesh,
            shard_axes=(self.lookup_shards.axes
                        if self.lookup_shards else None),
            candidate_policy=(self.lookup_shards.candidate_policy()
                              if self.lookup_shards else None))
        self.placement.install(simcache, np.asarray(slots), slot_cache)

    # --------------------------------------------------------- data plane
    def serve(self, request_ids: np.ndarray, prompts: jnp.ndarray,
              ingress_ids: np.ndarray | None = None
              ) -> tuple[list, ServeStats]:
        """Serve a batch. request_ids index the catalog (their embeddings
        are the lookup keys); prompts are the token batch for misses.
        ``ingress_ids`` says where each request entered the network
        (None → ingress 0, the single-ingress hierarchy's only row).

        With ``EngineConfig.bucket`` the lookup, the duel observation and
        the miss-prefill all run at the batch's power-of-two bucket shape
        (padding masked out of every stat and the duel trajectory), so a
        stream of mixed batch sizes compiles each entry point once per
        bucket instead of once per size.
        """
        t_batch0 = time.perf_counter()
        request_ids = np.asarray(request_ids)
        n = len(request_ids)
        if ingress_ids is None:
            ingress_ids = np.zeros(n, dtype=np.int64)
        else:
            ingress_ids = np.asarray(ingress_ids, dtype=np.int64)
        # np.add.at, not fancy-indexed +=: a batch with the same object
        # twice must count twice (the += form collapses duplicates and
        # undercounts exactly the hot objects of a skewed trace)
        np.add.at(self.counts, (ingress_ids, request_ids), 1.0)
        self.stats.n_requests += n
        out: list = [None] * n
        bucket = self.ecfg.bucket

        route_dec = None
        if self.routing is not None:
            # on-path strategy plane: per-request LRU walk over the
            # ingress's forwarding path decides server and insertions —
            # no offline simcache, no duel (λ-unaware by design)
            route_dec = self.routing.serve(request_ids, ingress_ids)
            self.stats.total_cost += float(route_dec.cost.sum())
            self.stats.total_approx_cost += float(
                route_dec.approx_cost.sum())
            self.stats.n_hits += int(route_dec.hit.sum())
            miss_idx = np.nonzero(~route_dec.hit)[0]
        elif self.simcache is None:
            miss_idx = np.arange(n)
        else:
            q = jnp.asarray(self.coords[request_ids])
            if bucket:
                q = _pad_rows(q, bucket_size(n, self.ecfg.min_bucket))
            res = self.simcache.lookup(q, prune=self.ecfg.prune,
                                       verify=self.ecfg.verify,
                                       quantize=self.ecfg.quantize)
            # slice the valid prefix before any accounting: padded rows
            # never touch stats, responses, or the demand window
            hits = np.asarray(res.hit)[:n]
            payloads = np.asarray(res.payload)[:n]
            full_cost = np.asarray(res.cost)          # bucket shape
            self.stats.total_cost += float(np.sum(full_cost[:n]))
            self.stats.total_approx_cost += float(
                np.sum(np.asarray(res.approx_cost)[:n]))
            for i in np.nonzero(hits)[0]:
                out[i] = self.responses.get(int(payloads[i]))
            self.stats.n_hits += int(hits.sum())
            miss_idx = np.nonzero(~hits)[0]
            if self.duel is not None:
                # online control plane: observe the batch in one scan
                # launch, priced by the costs the lookup just computed —
                # at the bucket shape, padded steps masked to no-ops
                ids_b = _pad_rows(request_ids, full_cost.shape[0])
                if self.duel.observe(ids_b, b1_ext=full_cost,
                                     n_valid=n if bucket else None):
                    self._rebuild_simcache(self.duel.slots_np)
                    self.placement_events += 1
                    if self.ecfg.refresh_on_promotion:
                        # duel churn = demand drifted: trigger the
                        # background offline re-solve (no-op if one is
                        # already in flight)
                        self.request_refresh()

        if len(miss_idx):
            # repository: run the model on the miss sub-batch (padded to
            # its own bucket so the prefill compiles per bucket too)
            sel = prompts[jnp.asarray(miss_idx)]
            if bucket:
                sel = _pad_rows(sel, bucket_size(len(miss_idx),
                                                 self.ecfg.min_bucket))
            logits, _ = self._prefill(self.params, {"tokens": sel})
            resp = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1))
            self.stats.model_calls += 1
            if self.routing is None and self.simcache is None:
                # cold engine without a strategy plane: repository cost
                # per miss (the routing plane already counted dec.cost)
                self.stats.total_cost += self.ecfg.h_model * len(miss_idx)
            for j, i in enumerate(miss_idx):
                rid = int(request_ids[i])
                self.responses[rid] = resp[j:j + 1]
                out[i] = resp[j:j + 1]
        if route_dec is not None:
            # fill hits AFTER the miss prefill: a request can hit a key
            # an earlier miss of this very batch just inserted, whose
            # response only exists once the model ran
            for i in np.nonzero(route_dec.hit)[0]:
                out[i] = self.responses.get(int(route_dec.payload[i]))
        self.stats.batch_latencies_ms.append(
            (time.perf_counter() - t_batch0) * 1e3)
        return out, self.stats
