"""Async multi-stream serving driver: many concurrent request streams,
bucketed batches, and a double-buffered placement refresh that never
blocks the request path for more than one atomic swap.

This is the event-driven session layer in front of ``SimCacheEngine``
(modeled on the Icarus ``execution/network.py`` session model: requests
are events on a virtual clock, the network processes them in arrival
order). Each :class:`StreamSpec` is one logical user population — its
own demand distribution (e.g. a Zipf permutation per tenant), its own
Poisson arrival rate, its own rng — and the :class:`StreamDriver`
multiplexes all of them into a single serving loop:

* a heap of per-stream next-arrival events yields requests in global
  virtual-time order (streams with higher rates contribute
  proportionally more arrivals — no round-robin artifacts);
* consecutive arrivals coalesce into a batch until either ``max_batch``
  requests are pending or the batch has been open for ``batch_window``
  virtual time units — so batch sizes *vary with arrival statistics*,
  which is exactly the mixed-batch-size workload that batch bucketing
  (``EngineConfig.bucket``) exists for;
* every dispatched batch is served through the engine's bucketed path,
  then the driver polls the double-buffered control plane
  (``engine.poll_refresh()``): a background solve that finished since
  the last batch is swapped in atomically *between* batches, and the
  swap stall is the only serving-thread cost of a placement refresh;
* refreshes are triggered either on a fixed cadence
  (``refresh_every`` batches) or by the engine itself on NETDUEL
  promotion churn (``EngineConfig.refresh_on_promotion``).

:class:`DriverStats` aggregates the numbers the serving bench records:
sustained requests/s, p50/p95/p99 batch latency, refresh/swap counts,
swap stall totals, and the placement-version trajectory.
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
import time

import jax.numpy as jnp
import numpy as np

from repro.core.demand import Demand
from repro.serve.engine import LATENCY_WINDOW, SimCacheEngine


@dataclasses.dataclass
class StreamSpec:
    """One logical request stream: a demand distribution plus a Poisson
    arrival rate (requests per unit of virtual time)."""
    demand: Demand
    rate: float = 1.0
    seed: int = 0
    name: str = ""


class RequestStream:
    """Poisson arrival process over one stream's demand. Draws are taken
    lazily but from a dedicated generator per stream, so a multi-stream
    trace is reproducible regardless of interleaving."""

    def __init__(self, spec: StreamSpec, index: int):
        if spec.rate <= 0.0:
            raise ValueError(f"stream {index}: rate must be > 0")
        self.spec = spec
        self.index = index
        self.rng = np.random.default_rng(spec.seed)
        self.t = float(self.rng.exponential(1.0 / spec.rate))
        self.n_emitted = 0

    def pop(self) -> tuple[float, int, int]:
        """(arrival_time, object_id, ingress_id) of the current arrival;
        advances the stream to its next one."""
        obj, ing = self.spec.demand.sample(1, self.rng)
        t = self.t
        self.t += float(self.rng.exponential(1.0 / self.spec.rate))
        self.n_emitted += 1
        return t, int(obj[0]), int(ing[0])


@dataclasses.dataclass
class DriverStats:
    """What one driver run measured (the serving-bench row schema)."""
    n_requests: int = 0
    n_batches: int = 0
    wall_s: float = 0.0
    batch_sizes: list = dataclasses.field(default_factory=list)
    # bounded ring (same window as ServeStats): percentiles over the
    # newest LATENCY_WINDOW batches, O(1) memory on long runs
    batch_latencies_ms: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=LATENCY_WINDOW))
    versions: list = dataclasses.field(default_factory=list)
    refreshes_started: int = 0
    refresh_skipped: int = 0        # surrogate gate said "not worth it"
    refresh_triggered: int = 0      # gate evaluated and let it through
    swaps: int = 0
    swap_stall_s: float = 0.0
    max_swap_stall_s: float = 0.0   # max over THIS run's swaps only
    placement_events: int = 0

    @property
    def requests_per_s(self) -> float:
        return self.n_requests / self.wall_s if self.wall_s > 0 else 0.0

    def latency_percentile(self, q: float) -> float:
        if not self.batch_latencies_ms:
            return 0.0
        return float(np.percentile(self.batch_latencies_ms, q))

    @property
    def p50_ms(self) -> float:
        return self.latency_percentile(50)

    @property
    def p95_ms(self) -> float:
        return self.latency_percentile(95)

    @property
    def p99_ms(self) -> float:
        return self.latency_percentile(99)

    @property
    def distinct_batch_sizes(self) -> int:
        return len(set(self.batch_sizes))


class StreamDriver:
    """Multiplex N request streams into the engine's bucketed batch path,
    refreshing placement through the double buffer between batches."""

    def __init__(self, engine: SimCacheEngine,
                 streams: list[StreamSpec],
                 max_batch: int = 256,
                 batch_window: float = 1.0,
                 prompt_len: int = 8,
                 refresh_every: int = 0,
                 prompt_seed: int = 0):
        if not streams:
            raise ValueError("need at least one stream")
        self.engine = engine
        self.streams = [RequestStream(s, i) for i, s in enumerate(streams)]
        self.max_batch = int(max_batch)
        self.batch_window = float(batch_window)
        self.prompt_len = int(prompt_len)
        self.refresh_every = int(refresh_every)
        self._prompt_rng = np.random.default_rng(prompt_seed)
        # event heap: (next_arrival_time, stream_index) — the virtual
        # clock that serializes all streams into one arrival order
        self._heap = [(s.t, s.index) for s in self.streams]
        heapq.heapify(self._heap)
        self._batches_run = 0

    def set_streams(self, streams: list[StreamSpec]) -> None:
        """Replace the stream population mid-run (demand drift at the
        session level): new demands/rates/rngs, fresh arrival heap; the
        engine and its observed-demand window carry over untouched."""
        if not streams:
            raise ValueError("need at least one stream")
        self.streams = [RequestStream(s, i) for i, s in enumerate(streams)]
        self._heap = [(s.t, s.index) for s in self.streams]
        heapq.heapify(self._heap)

    # ------------------------------------------------------ batch forming
    def _next_batch(self, n_left: int) -> tuple[np.ndarray, np.ndarray]:
        """Pop arrivals in virtual-time order until the batch closes:
        ``max_batch`` pending, the batch open longer than
        ``batch_window`` virtual time, or the run budget exhausted.
        Returns (object_ids, ingress_ids) — the ingress each request
        entered at rides along to the engine's demand accounting."""
        ids: list[int] = []
        ings: list[int] = []
        t_open: float | None = None
        cap = min(self.max_batch, n_left)
        while len(ids) < cap:
            t_next = self._heap[0][0]
            if t_open is not None and t_next - t_open > self.batch_window:
                break
            _, si = heapq.heappop(self._heap)
            stream = self.streams[si]
            t_arr, obj, ing = stream.pop()
            if t_open is None:
                t_open = t_arr
            ids.append(obj)
            ings.append(ing)
            heapq.heappush(self._heap, (stream.t, si))
        return (np.asarray(ids, dtype=np.int64),
                np.asarray(ings, dtype=np.int64))

    def _prompts(self, n: int) -> jnp.ndarray:
        vocab = self.engine.cfg.vocab
        return jnp.asarray(self._prompt_rng.integers(
            0, vocab, (n, self.prompt_len)).astype(np.int32))

    # -------------------------------------------------------------- run
    def run(self, n_requests: int) -> DriverStats:
        """Serve ~``n_requests`` requests (to batch granularity); returns
        the aggregated driver stats. Callable repeatedly — streams, the
        virtual clock, and the engine all continue where they left off
        (so a caller can swap demand phases between calls)."""
        eng = self.engine
        st = DriverStats()
        swaps0 = eng.swap_count
        stall0 = eng.swap_stall_s
        events0 = eng.placement_events
        skipped0 = eng.stats.refresh_skipped
        triggered0 = eng.stats.refresh_triggered
        t_run0 = time.perf_counter()
        while st.n_requests < n_requests:
            ids, ings = self._next_batch(n_requests - st.n_requests)
            eng.serve(ids, self._prompts(len(ids)), ingress_ids=ings)
            self._batches_run += 1
            st.n_batches += 1
            st.n_requests += len(ids)
            st.batch_sizes.append(len(ids))
            st.batch_latencies_ms.append(
                eng.stats.batch_latencies_ms[-1])
            # cadence trigger: start a background re-solve every k
            # batches (promotion-triggered refreshes come from the
            # engine itself via EngineConfig.refresh_on_promotion)
            if self.refresh_every and \
                    self._batches_run % self.refresh_every == 0:
                if eng.request_refresh():
                    st.refreshes_started += 1
            # the atomic swap point: a finished background solve is
            # installed between batches, never mid-lookup
            if eng.poll_refresh():
                # per-run stall window: max over the swaps *this* run
                # performed, not the engine's all-time high-water mark
                # (which a later run would report as its own stall)
                st.max_swap_stall_s = max(st.max_swap_stall_s,
                                          eng.last_swap_stall_s)
            st.versions.append(eng.placement.version)
        st.wall_s = time.perf_counter() - t_run0
        st.swaps = eng.swap_count - swaps0
        st.swap_stall_s = eng.swap_stall_s - stall0
        st.placement_events = eng.placement_events - events0
        st.refresh_skipped = eng.stats.refresh_skipped - skipped0
        st.refresh_triggered = eng.stats.refresh_triggered - triggered0
        return st

    def drain_refresh(self) -> bool:
        """Finish any in-flight background solve and swap it in (used at
        phase boundaries / end of run so no solve is left dangling)."""
        self.engine.wait_refresh()
        return self.engine.poll_refresh()
