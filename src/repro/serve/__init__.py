from repro.serve.engine import (EngineConfig, PlacementBuffer, ServeStats,
                                SimCacheEngine, bucket_size)
from repro.serve.stream import (DriverStats, RequestStream, StreamDriver,
                                StreamSpec)

__all__ = ["SimCacheEngine", "EngineConfig", "ServeStats",
           "PlacementBuffer", "bucket_size", "StreamDriver", "StreamSpec",
           "RequestStream", "DriverStats"]
