from repro.serve.engine import EngineConfig, ServeStats, SimCacheEngine

__all__ = ["SimCacheEngine", "EngineConfig", "ServeStats"]
