"""Trace/compile counters for jitted entry points.

A ``bump(name)`` call placed inside a jitted function body is a Python
side effect: it executes once per *trace* (i.e. once per new cache entry
— a new static-argument combination or a new input shape/dtype), never
per call. The counters therefore measure exactly what batch bucketing is
supposed to bound: how many distinct compiled specializations a serving
workload forces out of the fused lookup, the duel scan, and the prefill.

Used by the retrace-regression tests (tests/test_streaming.py) and
benchmarks/serving_bench.py; zero overhead on the executed path.
"""
from __future__ import annotations

import collections

COUNTS: collections.Counter = collections.Counter()


def bump(name: str) -> None:
    """Record one trace of ``name`` (call from inside the jitted body)."""
    COUNTS[name] += 1


def get(name: str) -> int:
    return COUNTS[name]


def reset() -> None:
    COUNTS.clear()


class snapshot:
    """Context manager: ``with snapshot() as s: ...; s.delta("name")``
    gives traces since entry without resetting the global counters."""

    def __enter__(self) -> "snapshot":
        self._at_entry = dict(COUNTS)
        return self

    def __exit__(self, *exc) -> None:
        pass

    def delta(self, name: str) -> int:
        return COUNTS[name] - self._at_entry.get(name, 0)
