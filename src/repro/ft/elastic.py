"""Elastic scaling: checkpoint → different mesh.

Checkpoints are mesh-agnostic (full arrays per leaf; checkpoint/ckpt.py),
so scaling a job up or down is: stop, restore_for_mesh with the new
sharding tree, continue. The deterministic data pipeline (data/pipeline.py)
is keyed by (step, shard), so the new world size re-partitions batches
without skipping or repeating data.

This module adds the policy pieces: choosing a new mesh for a changed
device count and validating that every parameter still shards.
"""
from __future__ import annotations

import jax

from repro.configs.base import ArchConfig
from repro.launch.sharding import MeshShardPolicy
from repro.models import schema as schema_api


def plan_mesh(n_devices: int, model_parallelism: int = 16):
    """Pick a (data, model) mesh for the available devices; shrink TP if
    the device count doesn't support it."""
    while n_devices % model_parallelism and model_parallelism > 1:
        model_parallelism //= 2
    return jax.make_mesh((n_devices // model_parallelism,
                          model_parallelism), ("data", "model"))


def reshard_plan(cfg: ArchConfig, mesh, mode: str = "train"):
    """Sharding tree for restore_for_mesh on the new mesh."""
    policy = MeshShardPolicy.create(cfg, mesh, mode)
    return policy.param_sharding_tree(schema_api.param_schema(cfg))
