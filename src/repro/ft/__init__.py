from repro.ft.compress import (compressed_crosspod_mean, dequantize_int8,
                               quantize_int8)
from repro.ft.straggler import HedgedDispatcher

__all__ = ["quantize_int8", "dequantize_int8", "compressed_crosspod_mean",
           "HedgedDispatcher"]
