"""Straggler mitigation: hedged dispatch.

A request sent to a slow replica is re-issued ("hedged") to a backup
after a deadline; the first completion wins. In this container replicas
are simulated callables with injectable latency (tests); on a real
cluster the callables are RPCs to model replicas.

The similarity-cache tier adds a second, cheaper mitigation unique to
this paper's setting: when even the hedge would miss the deadline, the
engine can serve the best cached approximizer instead — trading
approximation cost C_a for tail latency. ``approx_fallback`` quantifies
that trade with the paper's own cost model.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable


@dataclasses.dataclass
class HedgeStats:
    n_primary: int = 0
    n_hedged: int = 0
    n_fallback: int = 0
    total_latency: float = 0.0


class HedgedDispatcher:
    """Sequential simulation of hedged dispatch (deterministic, testable).

    ``replicas`` are callables returning (result, sim_latency_s); the
    dispatcher "waits" on the primary until ``hedge_after_s`` of
    simulated time, then consults the backup, taking whichever finishes
    first in simulated time.
    """

    def __init__(self, replicas: list[Callable], hedge_after_s: float,
                 deadline_s: float | None = None,
                 approx_fallback: Callable | None = None):
        assert len(replicas) >= 2
        self.replicas = replicas
        self.hedge_after = hedge_after_s
        self.deadline = deadline_s
        self.fallback = approx_fallback
        self.stats = HedgeStats()

    def __call__(self, request):
        r0, lat0 = self.replicas[0](request)
        if lat0 <= self.hedge_after:
            self.stats.n_primary += 1
            self.stats.total_latency += lat0
            return r0, lat0
        r1, lat1 = self.replicas[1](request)
        hedged_lat = self.hedge_after + lat1
        best, lat = (r0, lat0) if lat0 <= hedged_lat else (r1, hedged_lat)
        if self.deadline is not None and lat > self.deadline \
                and self.fallback is not None:
            fb, fb_cost = self.fallback(request)
            self.stats.n_fallback += 1
            self.stats.total_latency += self.deadline
            return fb, self.deadline
        self.stats.n_hedged += 1
        self.stats.total_latency += lat
        return best, lat


def simulated_replica(base_latency: float, slow_every: int = 0,
                      slow_factor: float = 10.0):
    """Deterministic replica: every ``slow_every``-th call straggles."""
    state = {"n": 0}

    def call(request):
        state["n"] += 1
        lat = base_latency
        if slow_every and state["n"] % slow_every == 0:
            lat *= slow_factor
        return ("ok", request), lat
    return call
