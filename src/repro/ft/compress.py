"""Cross-pod gradient compression (distributed-optimization trick).

The 'pod' mesh axis crosses DCN (~25× less bandwidth than ICI). Gradients
are reduced hierarchically: full-precision psum *within* each pod over
ICI, then an int8-quantized exchange *across* pods — 4× fewer DCN bytes
than an f32 psum leg at a quantization error that vanishes into the Adam
noise floor (per-row scales keep relative error < 1/127 per block).

Implemented with shard_map so the two legs are explicit (a plain pjit
all-reduce would fuse them into one f32 ring over both axes).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

# The int8 quantizer lives in kernels/quant.py now — one implementation
# shared with the compressed first-pass distance path of the lookup and
# gain kernels (and with an explicit all-zero-row guard: scale 0.0, not
# the historic denormal 1e-20 floor). Re-exported here so existing
# gradient-exchange callers and tests keep their import site.
from repro.kernels.quant import dequantize_int8, quantize_int8

__all__ = ["axis_size", "quantize_int8", "dequantize_int8",
           "compressed_crosspod_mean"]


def axis_size(axis_name: str) -> jax.Array | int:
    """Size of a named mesh axis, from inside shard_map/vmap/pmap.

    ``jax.lax.axis_size`` was removed from the installed JAX; a psum of
    ones over the axis is the portable spelling (constant-folded at
    trace time).
    """
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(axis_name)
    return jax.lax.psum(1, axis_name)


def _crosspod_leaf(g: jax.Array, pod_axis: str) -> jax.Array:
    """Mean over the pod axis with int8 exchange (inside shard_map)."""
    q, s = quantize_int8(g)
    # all_gather the quantized payload + scales (int8 over DCN), then
    # dequantize-and-mean locally
    qs = jax.lax.all_gather(q, pod_axis)            # (n_pods, ...) int8
    ss = jax.lax.all_gather(s, pod_axis)
    deq = dequantize_int8(qs, ss)
    out = jnp.mean(deq, axis=0).reshape(g.shape if g.ndim else (1,))
    return out.reshape(g.shape) if g.ndim else out[0]


def compressed_crosspod_mean(grads: Any, mesh, pod_axis: str = "pod",
                             data_axis: str = "data") -> Any:
    """Hierarchical gradient mean: f32 psum over data (ICI), int8
    exchange over pods (DCN). Leaves must be replicated over the model
    axis or sharded consistently; the shard_map below runs per (pod,
    data) shard and leaves other dims alone."""
    def per_shard(g):
        g = jax.lax.pmean(g, data_axis)             # ICI leg, f32
        return _crosspod_leaf(g, pod_axis)          # DCN leg, int8

    spec = P()        # gradients replicated within the mapped axes

    def apply(leaf):
        fn = shard_map(per_shard, mesh=mesh,
                       in_specs=spec, out_specs=spec,
                       check_rep=False)
        return fn(leaf)
    return jax.tree.map(apply, grads)
