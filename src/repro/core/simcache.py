"""Runtime similarity-cache network: lookup → forward → serve.

This is the *online data plane* for an allocation produced by the
placement algorithms (the paper's offline control plane). A
:class:`SimCacheNetwork` holds, per cache level, the stored object
embeddings ("keys") and opaque payload ids ("values" — e.g. a response
blob or a KV-prefix handle in the serving engine).

``lookup`` realizes eq. (1): every request is served by the approximizer
minimizing C_a(o, o') + h(i, j) over the caches on its path plus the
repository — the paper's optimal-forwarding assumption. The default
(``fused=True``) path concatenates every level's keys into one segmented
tensor with per-key additive cost offsets and answers the network-wide
query with a *single* Pallas kernel launch (the repository rides along
as a virtual key), so a batch lookup is one jitted pallas_call with no
per-level Python loop, host-side stack, or argmin. ``fused=False`` keeps
the original per-level probe (one KNN kernel per level, minima compared
centrally) as the differential-testing reference.

``sharded=True`` (with a ``mesh``) is the SPMD variant of the fused
path for catalogs too large for one device: :meth:`sharded_layout` pads
the segmented tensor so the key axis divides the shard count and
shard_map partitions it into contiguous balanced chunks, one per device
along ``shard_axes``. Each shard runs the *same* fused kernel over only
its resident keys (``fold_repo=False``), and the per-shard minima — five
scalars per query per shard — are gathered and reduced lexicographically
(min cost, ties to the lowest shard, i.e. the lowest concatenated index)
with the repository folded once after the reduction, so the result is
bit-identical to the single-device fused lookup. Queries are replicated;
only the O(B·n_shards) minima cross devices, never the key tensor. The
same memoization contract applies: mutating ``levels`` requires
:meth:`invalidate_layout`, which drops both the fused and the sharded
layouts.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.knn import (fused_lookup, mesh_axes_size,
                               nearest_approximizer, pad_to_shards,
                               sharded_fused_lookup)

REPO_LEVEL = -1

# Empty-level sentinel coordinate: far enough that a sentinel can never
# undercut the repository, small enough that its *squared* l2 distance
# (~1e30) stays finite in f32 — the old 1e30 sentinel overflowed l2sq to
# inf (and could reach NaN via inf−inf in the dot-product expansion).
# The fused kernel additionally masks sentinel keys explicitly via the
# valid flag (payload == −1 semantics), so it never relies on magnitude.
SENTINEL_COORD = 1e15


@dataclasses.dataclass
class CacheLevel:
    keys: jax.Array           # (k_j, d) stored object embeddings
    values: jax.Array         # (k_j,) payload ids (int32)
    h: float                  # retrieval cost from the ingress


@dataclasses.dataclass
class LookupResult:
    level: jax.Array          # (B,) serving level per request (−1 = repo)
    slot: jax.Array           # (B,) slot within level (undefined for repo)
    payload: jax.Array        # (B,) payload id (−1 for repo)
    cost: jax.Array           # (B,) total C(r, A) incurred
    approx_cost: jax.Array    # (B,) C_a component only
    hit: jax.Array            # (B,) bool, served by some cache


@dataclasses.dataclass
class SimCacheNetwork:
    """A chain of similarity caches in front of a repository (model).

    ``sharded=True`` serves lookups with the mesh-sharded fused path:
    ``mesh`` must be set and the key axis is partitioned over
    ``shard_axes`` (default: every mesh axis, in order).
    """
    levels: list[CacheLevel]
    h_repo: float
    metric: str = "l2"
    gamma: float = 1.0
    use_pallas: bool = True
    fused: bool = True
    sharded: bool = False
    mesh: jax.sharding.Mesh | None = None
    shard_axes: tuple[str, ...] | None = None
    _layout: tuple | None = dataclasses.field(
        default=None, init=False, repr=False, compare=False)
    _sharded_layout: dict = dataclasses.field(
        default_factory=dict, init=False, repr=False, compare=False)

    def __post_init__(self):
        if self.sharded and self.mesh is None:
            raise ValueError("sharded=True requires a mesh")

    @classmethod
    def from_placement(cls, coords: np.ndarray, slots: np.ndarray,
                       slot_cache: np.ndarray, hs: Sequence[float],
                       h_repo: float, metric: str = "l2",
                       gamma: float = 1.0, use_pallas: bool = True,
                       fused: bool = True, sharded: bool = False,
                       mesh: jax.sharding.Mesh | None = None,
                       shard_axes: tuple[str, ...] | None = None
                       ) -> "SimCacheNetwork":
        """Build the runtime network from a placement-algorithm output.

        ``slots``/``slot_cache`` are the flat allocation of
        objective.Instance; ``coords`` the catalog embeddings. Payload id
        = object id (the serving engine maps ids to artifacts).
        """
        levels = []
        for j, h in enumerate(hs):
            idx = slots[slot_cache == j]
            idx = idx[idx >= 0]
            if idx.size == 0:           # empty cache level still valid
                keys = np.full((1, coords.shape[1]), SENTINEL_COORD,
                               np.float32)     # unreachable sentinel key
                vals = np.full((1,), -1, np.int32)
            else:
                keys = coords[idx].astype(np.float32)
                vals = idx.astype(np.int32)
            levels.append(CacheLevel(keys=jnp.asarray(keys),
                                     values=jnp.asarray(vals),
                                     h=float(h)))
        return cls(levels=levels, h_repo=float(h_repo), metric=metric,
                   gamma=gamma, use_pallas=use_pallas, fused=fused,
                   sharded=sharded, mesh=mesh, shard_axes=shard_axes)

    # ------------------------------------------------------- fused layout
    def fused_layout(self) -> tuple[jax.Array, jax.Array, jax.Array]:
        """Concatenated (keys, h_key, meta) over all levels, memoized.

        ``meta`` is (4, ΣK_j) i32 with rows (level, slot, payload,
        valid); sentinel entries of empty levels keep payload == −1 and
        valid == 0 so the kernel masks them explicitly.

        Memoized: mutating ``levels`` after the first lookup requires
        :meth:`invalidate_layout`, or the fused path keeps serving the
        stale concatenation.
        """
        if self._layout is None:
            keys, h_key, metas = [], [], []
            for j, lv in enumerate(self.levels):
                kj = lv.keys.shape[0]
                vals = np.asarray(lv.values, np.int32)
                keys.append(np.asarray(lv.keys, np.float32))
                h_key.append(np.full((kj,), lv.h, np.float32))
                metas.append(np.stack([
                    np.full((kj,), j, np.int32),
                    np.arange(kj, dtype=np.int32),
                    vals,
                    (vals >= 0).astype(np.int32),
                ]))
            d = self.levels[0].keys.shape[1] if self.levels else 1
            cat = (np.concatenate(keys, 0) if keys
                   else np.zeros((0, d), np.float32))
            hk = (np.concatenate(h_key) if h_key
                  else np.zeros((0,), np.float32))
            mt = (np.concatenate(metas, 1) if metas
                  else np.zeros((4, 0), np.int32))
            self._layout = (jnp.asarray(cat), jnp.asarray(hk),
                            jnp.asarray(mt))
        return self._layout

    # ----------------------------------------------------- sharded layout
    def resolved_shard_axes(self) -> tuple[str, ...]:
        """Mesh axes the key axis shards over (default: all, in order)."""
        if self.shard_axes is not None:
            return tuple(self.shard_axes)
        return tuple(self.mesh.axis_names)

    def n_shards(self) -> int:
        return mesh_axes_size(self.mesh, self.resolved_shard_axes())

    def sharded_layout(self, n_shards: int
                       ) -> tuple[jax.Array, jax.Array, jax.Array]:
        """Fused layout padded so the key axis divides ``n_shards``.

        Padding keys (kernels.knn.pad_to_shards) are all-zero with
        valid == 0 / payload == −1 — masked explicitly by the kernel, so
        shards stay *balanced* (equal contiguous chunks of the
        level-ordered concatenation) without perturbing any distance.
        Memoized per shard count; the same :meth:`invalidate_layout`
        contract applies.
        """
        if n_shards not in self._sharded_layout:
            self._sharded_layout[n_shards] = pad_to_shards(
                *self.fused_layout(), n_shards)
        return self._sharded_layout[n_shards]

    def invalidate_layout(self) -> None:
        """Drop the memoized fused + sharded layouts after mutating
        ``levels``."""
        self._layout = None
        self._sharded_layout = {}

    def lookup(self, queries: jax.Array) -> LookupResult:
        """Serve a batch of query embeddings (B, d) per eq. (1).

        Sharded (``sharded=True`` + mesh): one fused kernel per key
        shard + cross-shard lexicographic reduction — bit-identical to
        the fused path.
        Fused (default): one pallas_call over the segmented key tensor.
        Looped (``fused=False``): one KNN kernel per level + central
        argmin — kept as the reference for differential tests.
        """
        if self.sharded:
            return self._lookup_sharded(queries)
        if self.fused:
            return self._lookup_fused(queries)
        return self._lookup_looped(queries)

    def _lookup_fused(self, queries: jax.Array) -> LookupResult:
        keys, h_key, meta = self.fused_layout()
        cost, ca, lvl, slot, pay = fused_lookup(
            queries, keys, h_key, meta, metric=self.metric,
            gamma=self.gamma, h_repo=self.h_repo, repo_level=REPO_LEVEL,
            use_pallas=self.use_pallas)
        return LookupResult(level=lvl, slot=slot, payload=pay, cost=cost,
                            approx_cost=ca, hit=lvl != REPO_LEVEL)

    def _lookup_sharded(self, queries: jax.Array) -> LookupResult:
        if self.fused_layout()[0].shape[0] == 0:   # no keys → repository
            return self._lookup_fused(queries)
        n = self.n_shards()
        keys, h_key, meta = self.sharded_layout(n)
        cost, ca, lvl, slot, pay = sharded_fused_lookup(
            queries, keys, h_key, meta, self.mesh,
            self.resolved_shard_axes(), metric=self.metric,
            gamma=self.gamma, h_repo=self.h_repo, repo_level=REPO_LEVEL,
            use_pallas=self.use_pallas)
        return LookupResult(level=lvl, slot=slot, payload=pay, cost=cost,
                            approx_cost=ca, hit=lvl != REPO_LEVEL)

    def _lookup_looped(self, queries: jax.Array) -> LookupResult:
        B = queries.shape[0]
        costs, slots_, pays, appr = [], [], [], []
        for lv in self.levels:
            ca, idx = nearest_approximizer(
                queries, lv.keys, metric=self.metric, gamma=self.gamma,
                use_pallas=self.use_pallas)
            costs.append(ca + lv.h)
            appr.append(ca)
            slots_.append(idx)
            pays.append(lv.values[idx])
        # repository: zero approximation cost, fixed h_repo
        costs.append(jnp.full((B,), self.h_repo, jnp.float32))
        appr.append(jnp.zeros((B,), jnp.float32))
        slots_.append(jnp.zeros((B,), jnp.int32))
        pays.append(jnp.full((B,), -1, jnp.int32))

        call = jnp.stack(costs)                       # (L+1, B)
        best = jnp.argmin(call, axis=0)               # metadata probe
        n_lv = len(self.levels)
        level = jnp.where(best == n_lv, REPO_LEVEL, best).astype(jnp.int32)
        take = lambda xs: jnp.take_along_axis(          # noqa: E731
            jnp.stack(xs), best[None, :], axis=0)[0]
        return LookupResult(
            level=level, slot=take(slots_), payload=take(pays),
            cost=take(costs), approx_cost=take(appr),
            hit=level != REPO_LEVEL)

    def expected_cost(self, queries: jax.Array,
                      weights: jax.Array | None = None) -> float:
        """Empirical C(A) over a query sample (eq. (2) estimator)."""
        res = self.lookup(queries)
        if weights is None:
            return float(jnp.mean(res.cost))
        return float(jnp.sum(weights * res.cost) / jnp.sum(weights))
