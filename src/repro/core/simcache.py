"""Runtime similarity-cache network: lookup → forward → serve.

This is the *online data plane* for an allocation produced by the
placement algorithms (the paper's offline control plane). A
:class:`SimCacheNetwork` holds, per cache level, the stored object
embeddings ("keys") and opaque payload ids ("values" — e.g. a response
blob or a KV-prefix handle in the serving engine).

``lookup`` realizes eq. (1): every request is served by the approximizer
minimizing C_a(o, o') + h(i, j) over the caches on its path plus the
repository — the paper's optimal-forwarding assumption, implemented as
the metadata probe of DESIGN.md §2 (per-level KNN minima compared
centrally; on a real mesh the per-level minima are tiny all-gathers).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.knn import nearest_approximizer

REPO_LEVEL = -1


@dataclasses.dataclass
class CacheLevel:
    keys: jax.Array           # (k_j, d) stored object embeddings
    values: jax.Array         # (k_j,) payload ids (int32)
    h: float                  # retrieval cost from the ingress


@dataclasses.dataclass
class LookupResult:
    level: jax.Array          # (B,) serving level per request (−1 = repo)
    slot: jax.Array           # (B,) slot within level (undefined for repo)
    payload: jax.Array        # (B,) payload id (−1 for repo)
    cost: jax.Array           # (B,) total C(r, A) incurred
    approx_cost: jax.Array    # (B,) C_a component only
    hit: jax.Array            # (B,) bool, served by some cache


@dataclasses.dataclass
class SimCacheNetwork:
    """A chain of similarity caches in front of a repository (model)."""
    levels: list[CacheLevel]
    h_repo: float
    metric: str = "l2"
    gamma: float = 1.0
    use_pallas: bool = True

    @classmethod
    def from_placement(cls, coords: np.ndarray, slots: np.ndarray,
                       slot_cache: np.ndarray, hs: Sequence[float],
                       h_repo: float, metric: str = "l2",
                       gamma: float = 1.0, use_pallas: bool = True
                       ) -> "SimCacheNetwork":
        """Build the runtime network from a placement-algorithm output.

        ``slots``/``slot_cache`` are the flat allocation of
        objective.Instance; ``coords`` the catalog embeddings. Payload id
        = object id (the serving engine maps ids to artifacts).
        """
        levels = []
        for j, h in enumerate(hs):
            idx = slots[slot_cache == j]
            idx = idx[idx >= 0]
            if idx.size == 0:           # empty cache level still valid
                keys = np.zeros((1, coords.shape[1]), np.float32)
                vals = np.full((1,), -1, np.int64)
                keys[:] = np.float32(1e30)   # unreachable sentinel key
            else:
                keys = coords[idx].astype(np.float32)
                vals = idx
            levels.append(CacheLevel(keys=jnp.asarray(keys),
                                     values=jnp.asarray(vals, jnp.int32),
                                     h=float(h)))
        return cls(levels=levels, h_repo=float(h_repo), metric=metric,
                   gamma=gamma, use_pallas=use_pallas)

    def lookup(self, queries: jax.Array) -> LookupResult:
        """Serve a batch of query embeddings (B, d) per eq. (1)."""
        B = queries.shape[0]
        costs, slots_, pays, appr = [], [], [], []
        for lv in self.levels:
            ca, idx = nearest_approximizer(
                queries, lv.keys, metric=self.metric, gamma=self.gamma,
                use_pallas=self.use_pallas)
            costs.append(ca + lv.h)
            appr.append(ca)
            slots_.append(idx)
            pays.append(lv.values[idx])
        # repository: zero approximation cost, fixed h_repo
        costs.append(jnp.full((B,), self.h_repo, jnp.float32))
        appr.append(jnp.zeros((B,), jnp.float32))
        slots_.append(jnp.zeros((B,), jnp.int32))
        pays.append(jnp.full((B,), -1, jnp.int32))

        call = jnp.stack(costs)                       # (L+1, B)
        best = jnp.argmin(call, axis=0)               # metadata probe
        n_lv = len(self.levels)
        level = jnp.where(best == n_lv, REPO_LEVEL, best).astype(jnp.int32)
        take = lambda xs: jnp.take_along_axis(          # noqa: E731
            jnp.stack(xs), best[None, :], axis=0)[0]
        return LookupResult(
            level=level, slot=take(slots_), payload=take(pays),
            cost=take(costs), approx_cost=take(appr),
            hit=level != REPO_LEVEL)

    def expected_cost(self, queries: jax.Array,
                      weights: jax.Array | None = None) -> float:
        """Empirical C(A) over a query sample (eq. (2) estimator)."""
        res = self.lookup(queries)
        if weights is None:
            return float(jnp.mean(res.cost))
        return float(jnp.sum(weights * res.cost) / jnp.sum(weights))
