"""Runtime similarity-cache network: lookup → forward → serve.

This is the *online data plane* for an allocation produced by the
placement algorithms (the paper's offline control plane). A
:class:`SimCacheNetwork` holds, per cache level, the stored object
embeddings ("keys") and opaque payload ids ("values" — e.g. a response
blob or a KV-prefix handle in the serving engine).

``lookup`` realizes eq. (1): every request is served by the approximizer
minimizing C_a(o, o') + h(i, j) over the caches on its path plus the
repository — the paper's optimal-forwarding assumption. The default
(``fused=True``) path concatenates every level's keys into one segmented
tensor with per-key additive cost offsets and answers the network-wide
query with a *single* Pallas kernel launch (the repository rides along
as a virtual key), so a batch lookup is one jitted pallas_call with no
per-level Python loop, host-side stack, or argmin. ``fused=False`` keeps
the original per-level probe (one KNN kernel per level, minima compared
centrally) as the differential-testing reference.

``sharded=True`` (with a ``mesh``) is the SPMD variant of the fused
path for catalogs too large for one device: :meth:`sharded_layout` pads
the segmented tensor so the key axis divides the shard count and
shard_map partitions it into contiguous balanced chunks, one per device
along ``shard_axes``. Each shard runs the *same* fused kernel over only
its resident keys (``fold_repo=False``), and the per-shard minima — five
scalars per query per shard — are gathered and reduced lexicographically
(min cost, ties to the lowest shard, i.e. the lowest concatenated index)
with the repository folded once after the reduction, so the result is
bit-identical to the single-device fused lookup. Queries are replicated;
only the O(B·n_shards) minima cross devices, never the key tensor. The
same memoization contract applies: mutating ``levels`` requires
:meth:`invalidate_layout`, which drops both the fused and the sharded
layouts.

``lookup(prune="lsh"|"kmeans")`` puts a candidate pre-filter
(kernels.knn.lsh) in front of the fused scan: the query batch is hashed
against memoized SimHash / k-means-routing tables, the batch union of
candidate rows is gathered into one compact padded index tensor, and
the *same* fused kernel runs over only those rows — per shard of the
balanced contiguous ``sharded_layout`` when ``sharded=True``, with
``reduce_shard_minima`` and the tie-break order untouched.
``verify=True`` re-scans every query whose pruned cost reaches the
returned un-scanned-h bound through the exact path, making the result
bit-identical to the exact fused lookup by construction (the verifier
contract in kernels/knn/lsh.py). Tables are memoized next to the
layouts; unlike the plain fused path, a pruned lookup against mutated
but not invalidated ``levels`` raises instead of serving stale
candidates.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.knn import (default_policy, fused_lookup,
                               mesh_axes_size, nearest_approximizer,
                               pad_to_shards, pruned_fused_lookup,
                               quantized_fused_lookup,
                               sharded_fused_lookup,
                               sharded_pruned_fused_lookup,
                               sharded_quantized_fused_lookup,
                               stack_shard_tables)
from repro.kernels.knn.ops import DEFAULT_TOP_T

REPO_LEVEL = -1

# Empty-level sentinel coordinate: far enough that a sentinel can never
# undercut the repository, small enough that its *squared* l2 distance
# (~1e30) stays finite in f32 — the old 1e30 sentinel overflowed l2sq to
# inf (and could reach NaN via inf−inf in the dot-product expansion).
# The fused kernel additionally masks sentinel keys explicitly via the
# valid flag (payload == −1 semantics), so it never relies on magnitude.
SENTINEL_COORD = 1e15


@dataclasses.dataclass
class CacheLevel:
    keys: jax.Array           # (k_j, d) stored object embeddings
    values: jax.Array         # (k_j,) payload ids (int32)
    h: float                  # retrieval cost from the ingress


@dataclasses.dataclass
class LookupResult:
    level: jax.Array          # (B,) serving level per request (−1 = repo)
    slot: jax.Array           # (B,) slot within level (undefined for repo)
    payload: jax.Array        # (B,) payload id (−1 for repo)
    cost: jax.Array           # (B,) total C(r, A) incurred
    approx_cost: jax.Array    # (B,) C_a component only
    hit: jax.Array            # (B,) bool, served by some cache


@dataclasses.dataclass
class SimCacheNetwork:
    """A chain of similarity caches in front of a repository (model).

    ``sharded=True`` serves lookups with the mesh-sharded fused path:
    ``mesh`` must be set and the key axis is partitioned over
    ``shard_axes`` (default: every mesh axis, in order).
    """
    levels: list[CacheLevel]
    h_repo: float
    metric: str = "l2"
    gamma: float = 1.0
    use_pallas: bool = True
    fused: bool = True
    sharded: bool = False
    mesh: jax.sharding.Mesh | None = None
    shard_axes: tuple[str, ...] | None = None
    # CandidatePolicy override, used only when its ``kind`` matches the
    # ``prune=`` argument of lookup(); other kinds fall back to
    # kernels.knn.lsh.default_policy so one network can still serve both
    # pruning families side by side.
    candidate_policy: object | None = None
    _layout: tuple | None = dataclasses.field(
        default=None, init=False, repr=False, compare=False)
    _layout_fp: tuple | None = dataclasses.field(
        default=None, init=False, repr=False, compare=False)
    _sharded_layout: dict = dataclasses.field(
        default_factory=dict, init=False, repr=False, compare=False)
    _tables: dict = dataclasses.field(
        default_factory=dict, init=False, repr=False, compare=False)

    def __post_init__(self):
        if self.sharded and self.mesh is None:
            raise ValueError("sharded=True requires a mesh")

    @classmethod
    def from_placement(cls, coords: np.ndarray, slots: np.ndarray,
                       slot_cache: np.ndarray, hs: Sequence[float],
                       h_repo: float, metric: str = "l2",
                       gamma: float = 1.0, use_pallas: bool = True,
                       fused: bool = True, sharded: bool = False,
                       mesh: jax.sharding.Mesh | None = None,
                       shard_axes: tuple[str, ...] | None = None,
                       candidate_policy: object | None = None
                       ) -> "SimCacheNetwork":
        """Build the runtime network from a placement-algorithm output.

        ``slots``/``slot_cache`` are the flat allocation of
        objective.Instance; ``coords`` the catalog embeddings. Payload id
        = object id (the serving engine maps ids to artifacts).
        """
        levels = []
        for j, h in enumerate(hs):
            idx = slots[slot_cache == j]
            idx = idx[idx >= 0]
            if idx.size == 0:           # empty cache level still valid
                keys = np.full((1, coords.shape[1]), SENTINEL_COORD,
                               np.float32)     # unreachable sentinel key
                vals = np.full((1,), -1, np.int32)
            else:
                keys = coords[idx].astype(np.float32)
                vals = idx.astype(np.int32)
            levels.append(CacheLevel(keys=jnp.asarray(keys),
                                     values=jnp.asarray(vals),
                                     h=float(h)))
        return cls(levels=levels, h_repo=float(h_repo), metric=metric,
                   gamma=gamma, use_pallas=use_pallas, fused=fused,
                   sharded=sharded, mesh=mesh, shard_axes=shard_axes,
                   candidate_policy=candidate_policy)

    # ------------------------------------------------------- fused layout
    def fused_layout(self) -> tuple[jax.Array, jax.Array, jax.Array]:
        """Concatenated (keys, h_key, meta) over all levels, memoized.

        ``meta`` is (4, ΣK_j) i32 with rows (level, slot, payload,
        valid); sentinel entries of empty levels keep payload == −1 and
        valid == 0 so the kernel masks them explicitly.

        Memoized: mutating ``levels`` after the first lookup requires
        :meth:`invalidate_layout`, or the fused path keeps serving the
        stale concatenation.
        """
        if self._layout is None:
            keys, h_key, metas = [], [], []
            for j, lv in enumerate(self.levels):
                kj = lv.keys.shape[0]
                vals = np.asarray(lv.values, np.int32)
                keys.append(np.asarray(lv.keys, np.float32))
                h_key.append(np.full((kj,), lv.h, np.float32))
                metas.append(np.stack([
                    np.full((kj,), j, np.int32),
                    np.arange(kj, dtype=np.int32),
                    vals,
                    (vals >= 0).astype(np.int32),
                ]))
            d = self.levels[0].keys.shape[1] if self.levels else 1
            cat = (np.concatenate(keys, 0) if keys
                   else np.zeros((0, d), np.float32))
            hk = (np.concatenate(h_key) if h_key
                  else np.zeros((0,), np.float32))
            mt = (np.concatenate(metas, 1) if metas
                  else np.zeros((4, 0), np.int32))
            self._layout = (jnp.asarray(cat), jnp.asarray(hk),
                            jnp.asarray(mt))
            self._layout_fp = self._levels_fingerprint()
        return self._layout

    # ----------------------------------------------------- sharded layout
    def resolved_shard_axes(self) -> tuple[str, ...]:
        """Mesh axes the key axis shards over (default: all, in order)."""
        if self.shard_axes is not None:
            return tuple(self.shard_axes)
        return tuple(self.mesh.axis_names)

    def n_shards(self) -> int:
        return mesh_axes_size(self.mesh, self.resolved_shard_axes())

    def sharded_layout(self, n_shards: int
                       ) -> tuple[jax.Array, jax.Array, jax.Array]:
        """Fused layout padded so the key axis divides ``n_shards``.

        Padding keys (kernels.knn.pad_to_shards) are all-zero with
        valid == 0 / payload == −1 — masked explicitly by the kernel, so
        shards stay *balanced* (equal contiguous chunks of the
        level-ordered concatenation) without perturbing any distance.
        Memoized per shard count; the same :meth:`invalidate_layout`
        contract applies.
        """
        if n_shards not in self._sharded_layout:
            self._sharded_layout[n_shards] = pad_to_shards(
                *self.fused_layout(), n_shards)
        return self._sharded_layout[n_shards]

    def invalidate_layout(self) -> None:
        """Drop the memoized fused + sharded layouts (and the candidate
        pruning tables built from them) after mutating ``levels``."""
        self._layout = None
        self._layout_fp = None
        self._sharded_layout = {}
        self._tables = {}

    # -------------------------------------------------- candidate tables
    def _levels_fingerprint(self) -> tuple:
        """Identity of the current ``levels`` content: the array objects
        themselves (strong references — compared with ``is``, and their
        liveness makes id/slot reuse impossible) plus the h costs, so
        pruned lookups can detect a mutation that was not followed by
        :meth:`invalidate_layout`."""
        return tuple((lv.keys, lv.values, float(lv.h))
                     for lv in self.levels)

    @staticmethod
    def _fingerprints_match(a: tuple | None, b: tuple) -> bool:
        return a is not None and len(a) == len(b) and all(
            ak is bk and av is bv and ah == bh
            for (ak, av, ah), (bk, bv, bh) in zip(a, b))

    def _check_layout_fresh(self) -> None:
        if self._layout is not None and not self._fingerprints_match(
                self._layout_fp, self._levels_fingerprint()):
            raise RuntimeError(
                "stale candidate tables: `levels` were mutated after the "
                "fused layout (and the LSH/k-means tables indexing it) "
                "were built — call invalidate_layout() before a pruned "
                "lookup. The un-pruned paths serve the stale layout "
                "verbatim (documented memoization contract); pruning "
                "refuses, rather than returning candidates into a layout "
                "that no longer exists.")

    def _resolve_policy(self, prune: str):
        pol = self.candidate_policy
        if pol is not None and getattr(pol, "kind", None) == prune:
            return pol
        return default_policy(prune)

    def _tables_for(self, policy, n_shards: int
                    ) -> tuple[jax.Array, jax.Array, int]:
        """Memoized (proj, buckets, n_probes) for one policy: built over
        the fused layout (``n_shards == 0``) or per contiguous balanced
        shard chunk, stacked on a leading shard axis (``n_shards ≥ 1``).
        Dropped by :meth:`invalidate_layout` alongside the layouts."""
        memo_key = (policy, n_shards)
        if memo_key not in self._tables:
            if n_shards == 0:
                keys, _, meta = self.fused_layout()
                t = policy.build(np.asarray(keys),
                                 np.asarray(meta)[3] > 0)
                self._tables[memo_key] = (jnp.asarray(t.proj),
                                          jnp.asarray(t.buckets),
                                          t.n_probes)
            else:
                keys, _, meta = self.sharded_layout(n_shards)
                keys_np, meta_np = np.asarray(keys), np.asarray(meta)
                S = keys_np.shape[0] // n_shards
                ts = [policy.for_shard(s).build(
                    keys_np[s * S:(s + 1) * S],
                    meta_np[3, s * S:(s + 1) * S] > 0)
                    for s in range(n_shards)]
                proj_s, buckets_s, n_probes = stack_shard_tables(ts)
                self._tables[memo_key] = (jnp.asarray(proj_s),
                                          jnp.asarray(buckets_s),
                                          n_probes)
        return self._tables[memo_key]

    def lookup(self, queries: jax.Array, prune: str | None = None,
               verify: bool = False, quantize: bool = False,
               top_t: int | None = None) -> LookupResult:
        """Serve a batch of query embeddings (B, d) per eq. (1).

        Sharded (``sharded=True`` + mesh): one fused kernel per key
        shard + cross-shard lexicographic reduction — bit-identical to
        the fused path.
        Fused (default): one pallas_call over the segmented key tensor.
        Looped (``fused=False``): one KNN kernel per level + central
        argmin — kept as the reference for differential tests.
        Pruned (``prune="lsh"|"kmeans"``): candidate pre-filter in front
        of the fused/sharded scan; ``verify=True`` re-scans any query
        whose pruned cost reaches the un-scanned-h bound — bit-identical
        to the exact path by construction (kernels/knn/lsh.py).
        Quantized (``quantize=True``): int8 lower-bound first pass over
        the (possibly pruned) key rows selects the ``top_t`` candidates
        per query; only their batch union reaches the exact fused scan.
        The returned cost is exact for every query whose cost beats the
        per-query certificate bound; ``verify=True`` re-scans the rest,
        making the result bit-identical to the exact path by
        construction (kernels/quant.py admissibility). Composes with
        ``prune=`` (LSH gather first, quantized cut second) and with
        sharding.
        """
        if prune is not None:
            return self._lookup_pruned(queries, prune, verify,
                                       quantize=quantize, top_t=top_t)
        if quantize:
            return self._lookup_quantized(queries, verify, top_t)
        if self.sharded:
            return self._lookup_sharded(queries)
        if self.fused:
            return self._lookup_fused(queries)
        return self._lookup_looped(queries)

    def _lookup_fused(self, queries: jax.Array) -> LookupResult:
        keys, h_key, meta = self.fused_layout()
        cost, ca, lvl, slot, pay = fused_lookup(
            queries, keys, h_key, meta, metric=self.metric,
            gamma=self.gamma, h_repo=self.h_repo, repo_level=REPO_LEVEL,
            use_pallas=self.use_pallas)
        return LookupResult(level=lvl, slot=slot, payload=pay, cost=cost,
                            approx_cost=ca, hit=lvl != REPO_LEVEL)

    def _lookup_sharded(self, queries: jax.Array) -> LookupResult:
        if self.fused_layout()[0].shape[0] == 0:   # no keys → repository
            return self._lookup_fused(queries)
        n = self.n_shards()
        keys, h_key, meta = self.sharded_layout(n)
        cost, ca, lvl, slot, pay = sharded_fused_lookup(
            queries, keys, h_key, meta, self.mesh,
            self.resolved_shard_axes(), metric=self.metric,
            gamma=self.gamma, h_repo=self.h_repo, repo_level=REPO_LEVEL,
            use_pallas=self.use_pallas)
        return LookupResult(level=lvl, slot=slot, payload=pay, cost=cost,
                            approx_cost=ca, hit=lvl != REPO_LEVEL)

    def _quant_rows(self, n_shards: int):
        """Memoized int8 image (quant.QuantizedRows) of the fused
        (``n_shards == 0``) or sharded key rows — dropped alongside the
        layouts by :meth:`invalidate_layout`. All-zero padding rows
        quantize to scale 0.0 (the explicit guard in kernels/quant.py)
        and stay masked by their valid == 0 flag."""
        memo_key = ("quant_rows", n_shards)
        if memo_key not in self._tables:
            from repro.kernels import quant
            keys = (self.fused_layout() if n_shards == 0
                    else self.sharded_layout(n_shards))[0]
            self._tables[memo_key] = quant.quantize_rows(keys, self.metric)
        return self._tables[memo_key]

    def _lookup_quantized(self, queries: jax.Array, verify: bool,
                          top_t: int | None) -> LookupResult:
        self._check_layout_fresh()
        if self.fused_layout()[0].shape[0] == 0:   # no keys → repository
            return self._lookup_fused(queries)
        tt = DEFAULT_TOP_T if top_t is None else int(top_t)
        if self.sharded:
            n = self.n_shards()
            keys, h_key, meta = self.sharded_layout(n)
            out = sharded_quantized_fused_lookup(
                queries, keys, h_key, meta, self._quant_rows(n), self.mesh,
                self.resolved_shard_axes(), top_t=tt, metric=self.metric,
                gamma=self.gamma, h_repo=self.h_repo,
                repo_level=REPO_LEVEL, use_pallas=self.use_pallas)
        else:
            keys, h_key, meta = self.fused_layout()
            out = quantized_fused_lookup(
                queries, keys, h_key, meta, self._quant_rows(0), top_t=tt,
                metric=self.metric, gamma=self.gamma, h_repo=self.h_repo,
                repo_level=REPO_LEVEL, use_pallas=self.use_pallas)
        cost, ca, lvl, slot, pay, bound = out
        res = LookupResult(level=lvl, slot=slot, payload=pay, cost=cost,
                           approx_cost=ca, hit=lvl != REPO_LEVEL)
        if not verify:
            return res
        return self._verify_rescan(queries, res, bound)

    def _lookup_pruned(self, queries: jax.Array, prune: str,
                       verify: bool, quantize: bool = False,
                       top_t: int | None = None) -> LookupResult:
        policy = self._resolve_policy(prune)
        self._check_layout_fresh()
        if self.fused_layout()[0].shape[0] == 0:   # no keys → repository
            return self._lookup_fused(queries)
        tt = DEFAULT_TOP_T if top_t is None else int(top_t)
        if self.sharded:
            n = self.n_shards()
            keys, h_key, meta = self.sharded_layout(n)
            proj, buckets, n_probes = self._tables_for(policy, n)
            cost, ca, lvl, slot, pay, bound = sharded_pruned_fused_lookup(
                queries, keys, h_key, meta, proj, buckets, self.mesh,
                self.resolved_shard_axes(), kind=policy.kind,
                n_probes=n_probes,
                cap_union=policy.resolve_cap(keys.shape[0] // n),
                metric=self.metric, gamma=self.gamma, h_repo=self.h_repo,
                repo_level=REPO_LEVEL, use_pallas=self.use_pallas,
                quantize=quantize, top_t=tt)
        else:
            keys, h_key, meta = self.fused_layout()
            proj, buckets, n_probes = self._tables_for(policy, 0)
            cost, ca, lvl, slot, pay, bound = pruned_fused_lookup(
                queries, keys, h_key, meta, proj, buckets,
                kind=policy.kind, n_probes=n_probes,
                cap_union=policy.resolve_cap(keys.shape[0]),
                metric=self.metric, gamma=self.gamma, h_repo=self.h_repo,
                repo_level=REPO_LEVEL, use_pallas=self.use_pallas,
                quantize=quantize, top_t=tt)
        res = LookupResult(level=lvl, slot=slot, payload=pay, cost=cost,
                           approx_cost=ca, hit=lvl != REPO_LEVEL)
        if not verify:
            return res
        return self._verify_rescan(queries, res, bound)

    def _verify_rescan(self, queries: jax.Array, res: LookupResult,
                       bound: jax.Array) -> LookupResult:
        # verifier: cost < bound proves the pruned/quantized winner exact
        # (every un-scanned valid key costs ≥ bound); anything else —
        # including exact ties, whose break could prefer an un-scanned
        # lower index — re-scans through the exact fused/sharded path.
        # Only the flagged queries re-scan (per-query kernel rows are
        # independent, so a sub-batch is bitwise the full batch's rows),
        # padded to a power of two so repeated verify calls reuse a
        # handful of compiled exact-scan shapes instead of one per
        # flagged count. ``bound`` is a scalar for the LSH path (the
        # un-scanned-h floor) and per-query (B,) for the quantized cut
        # (each query's top-T certificate) — the broadcast compare covers
        # both.
        lvl, slot = res.level, res.slot
        pay, cost, ca = res.payload, res.cost, res.approx_cost
        idx = np.nonzero(np.asarray(cost >= bound))[0]
        if idx.size == 0:
            return res
        m = 1
        while m < idx.size:
            m <<= 1
        m = min(m, queries.shape[0])
        pad_idx = np.concatenate(
            [idx, np.zeros(m - idx.size, idx.dtype)]).astype(np.int32)
        exact = (self._lookup_sharded(queries[jnp.asarray(pad_idx)])
                 if self.sharded
                 else self._lookup_fused(queries[jnp.asarray(pad_idx)]))
        jidx = jnp.asarray(idx.astype(np.int32))
        put = lambda dst, src: dst.at[jidx].set(    # noqa: E731
            src[:idx.size])
        lvl2 = put(lvl, exact.level)
        return LookupResult(
            level=lvl2, slot=put(slot, exact.slot),
            payload=put(pay, exact.payload),
            cost=put(cost, exact.cost),
            approx_cost=put(ca, exact.approx_cost),
            hit=lvl2 != REPO_LEVEL)

    def _lookup_looped(self, queries: jax.Array) -> LookupResult:
        B = queries.shape[0]
        costs, slots_, pays, appr = [], [], [], []
        for lv in self.levels:
            ca, idx = nearest_approximizer(
                queries, lv.keys, metric=self.metric, gamma=self.gamma,
                use_pallas=self.use_pallas)
            costs.append(ca + lv.h)
            appr.append(ca)
            slots_.append(idx)
            pays.append(lv.values[idx])
        # repository: zero approximation cost, fixed h_repo
        costs.append(jnp.full((B,), self.h_repo, jnp.float32))
        appr.append(jnp.zeros((B,), jnp.float32))
        slots_.append(jnp.zeros((B,), jnp.int32))
        pays.append(jnp.full((B,), -1, jnp.int32))

        call = jnp.stack(costs)                       # (L+1, B)
        best = jnp.argmin(call, axis=0)               # metadata probe
        n_lv = len(self.levels)
        level = jnp.where(best == n_lv, REPO_LEVEL, best).astype(jnp.int32)
        take = lambda xs: jnp.take_along_axis(          # noqa: E731
            jnp.stack(xs), best[None, :], axis=0)[0]
        return LookupResult(
            level=level, slot=take(slots_), payload=take(pays),
            cost=take(costs), approx_cost=take(appr),
            hit=level != REPO_LEVEL)

    def expected_cost(self, queries: jax.Array,
                      weights: jax.Array | None = None) -> float:
        """Empirical C(A) over a query sample (eq. (2) estimator)."""
        res = self.lookup(queries)
        if weights is None:
            return float(jnp.mean(res.cost))
        return float(jnp.sum(weights * res.cost) / jnp.sum(weights))
