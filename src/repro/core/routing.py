"""On-path routing strategies: *where a response gets cached* along the
return path — the online, λ-unaware alternative to the offline
placement plane.

The offline plane (GREEDY/LOCALSWAP over ``objective.Instance``)
decides the allocation once from measured demand; this module instead
runs the classic ICN on-path strategies over the same
:class:`~repro.core.topology.CacheNetwork` contract — each cache is an
LRU list, a request walks the caches on its ingress's forwarding path
(finite ``H[i, ·]`` entries in ascending reach-cost order), and the
strategy decides which caches take a copy of the response on the way
back (Icarus `models/strategy/onpath.py`), generalized to *similarity*
serving: a cache serves a request from its nearest stored key at cost
C_a(o, key) + h(i, j), exactly eq. (1) restricted to current contents.

Serving rule (all strategies): the request is served by the
cost-minimizing server among the on-path caches' nearest keys and the
repository (ties → the cache nearest the ingress), so per-request cost
is never above h_repo. An optional ``threshold`` restricts cache hits
to C_a ≤ threshold (the literal SIM-LRU admission of "Similarity
Caching: Theory and Algorithms", 1912.03888).

Strategies (insertion/refresh behavior):

* ``lce``      — leave copy everywhere: a miss inserts the object at
  every on-path cache; a hit at path position p additionally copies the
  *served key* into every cache below p (the return path).
* ``lcd``      — leave copy down: a miss inserts only at the cache
  adjacent to the repository; a hit at position p copies the served key
  one hop down (position p−1). Content migrates toward the ingress one
  level per hit.
* ``probcache``— ProbCache-style probabilistic insert: a miss inserts
  at position p with probability (remaining cache capacity from p to
  the repository / 10·mean capacity) · (p+1)/path-length — deeper
  caches insert rarely, edge caches aggressively, capacity-weighted as
  in Psaras et al.; a hit applies the same rule below the serving
  position.
* ``sim-lru``  — similarity LRU (SIM-LRU of 1912.03888, applied
  per cache along the path): a hit only refreshes the served key's LRU
  position; a miss inserts the exact object at every traversed cache.
* ``rnd-lru``  — RND-LRU: like ``sim-lru``, but an eligible cache
  serves only with probability q = 1 − C_a/θ_eff (nearer keys are
  likelier to answer; θ_eff is ``threshold`` or the cache's repo-cost
  slack) — a refusal falls through to the next cache on the path.

Every cache is bounded LRU: inserting into a full cache evicts the
least-recently-used key; re-inserting an existing key refreshes it.
The conservation contract — each request served exactly once, cache
occupancy ≤ capacity — is locked by tests/test_scenarios.py.

``serve.engine.SimCacheEngine`` plugs this in via
``EngineConfig.strategy`` (the strategy plane replaces the
offline-placement simcache as the serving decision maker; model calls
for misses are unchanged) and ``serve/stream.py`` threads per-request
ingress ids through to it.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict

import numpy as np

from repro.core.topology import CacheNetwork

STRATEGIES = ("lce", "lcd", "probcache", "sim-lru", "rnd-lru")


def rnd_lru_serve_prob(ca: float, theta_eff: float) -> float:
    """RND-LRU serving probability, clamped to a probability:

        q = clamp(1 − C_a/θ_eff, 0, 1)

    with the two boundary semantics made explicit instead of left to
    the raw formula:

    * ``ca <= 0`` (an exact-match key) always serves — q → 1 as
      C_a → 0 for any positive θ_eff, and an exact hit under θ_eff = 0
      (an exact-hit-only threshold) is still a hit;
    * ``theta_eff <= 0`` (non-positive slack) never serves — the raw
      1 − C_a/θ_eff is negative for every C_a > 0 there (the old
      ``max(theta, 1e-300)`` guard only kept the *division* finite, so
      q could still come out hugely negative and only accidentally
      behaved like "never" when compared against a uniform draw).

    ``serve_one``'s own eligibility arithmetic (C_a + H < h_repo in
    f64) cannot currently produce an eligible cache whose unclamped q
    is negative, so this is defensive hardening pinned at the unit
    level (tests/test_scenarios.py) rather than a behavior change on
    reachable traces.
    """
    if ca <= 0.0:
        return 1.0
    if theta_eff <= 0.0:
        return 0.0
    return float(min(max(1.0 - ca / theta_eff, 0.0), 1.0))


@dataclasses.dataclass
class RouteDecision:
    """Per-request serving decisions of one batch (host f64 arrays)."""
    cost: np.ndarray          # (B,) C_a + h of the chosen server
    approx_cost: np.ndarray   # (B,) C_a component only (0 for repo)
    hit: np.ndarray           # (B,) bool — served by some cache
    cache: np.ndarray         # (B,) serving cache id, −1 = repository
    payload: np.ndarray       # (B,) served object id (−1 = fresh fetch)


class StrategyPlane:
    """LRU cache states + one on-path strategy over a ``CacheNetwork``.

    ``coords`` is the catalog embedding matrix; approximation costs are
    computed on the fly as metric(o, key)^γ in f64 (host plane — this
    is the baseline the device-resident offline plane is benchmarked
    against, not a hot path)."""

    def __init__(self, net: CacheNetwork, coords: np.ndarray,
                 metric: str = "l2", gamma: float = 1.0,
                 strategy: str = "lce", threshold: float | None = None,
                 seed: int = 0):
        if strategy not in STRATEGIES:
            raise ValueError(f"unknown strategy {strategy!r}; "
                             f"expected one of {STRATEGIES}")
        self.net = net
        self.coords = np.asarray(coords, np.float64)
        self.metric = metric
        self.gamma = float(gamma)
        self.strategy = strategy
        self.threshold = threshold
        self.rng = np.random.default_rng(seed)
        H = np.asarray(net.H, np.float64)
        # per-ingress forwarding path: finite-H caches in ascending
        # reach-cost order (stable ties → lowest cache id)
        self.paths = []
        for i in range(net.n_ingress):
            fin = np.nonzero(np.isfinite(H[i]))[0]
            self.paths.append(fin[np.argsort(H[i, fin], kind="stable")])
        self.H = H
        self.h_repo = np.asarray(net.h_repo, np.float64)
        self.caps = np.asarray(net.capacities, np.int64)
        # LRU state: OrderedDict per cache, most-recently-used last
        self.caches = [OrderedDict() for _ in range(net.n_caches)]
        self.n_served = 0
        self.n_inserted = 0
        self.n_evicted = 0

    # ------------------------------------------------------------ helpers
    def _ca(self, obj: int, keys: np.ndarray) -> np.ndarray:
        """(K,) approximation costs C_a(obj, keys) in f64 numpy (no jit:
        cache sizes change every step, a jitted path would retrace)."""
        q = self.coords[obj]
        x = self.coords[keys]
        if self.metric == "l1":
            d = np.abs(x - q).sum(axis=1)
        elif self.metric in ("l2", "l2sq"):
            d2 = ((x - q) ** 2).sum(axis=1)
            d = d2 if self.metric == "l2sq" else np.sqrt(d2)
        else:
            raise ValueError(f"unknown metric {self.metric!r}")
        return d if self.gamma == 1.0 else d ** self.gamma

    def _nearest(self, j: int, obj: int) -> tuple[float, int]:
        """(C_a, key) of cache j's nearest stored key (inf, −1 if empty;
        ties → the lowest key id, matching the solvers' argmin order)."""
        if not self.caches[j]:
            return np.inf, -1
        keys = np.fromiter(self.caches[j].keys(), np.int64,
                           len(self.caches[j]))
        keys.sort()
        ca = self._ca(obj, keys)
        a = int(np.argmin(ca))
        return float(ca[a]), int(keys[a])

    def _insert(self, j: int, obj: int) -> None:
        c = self.caches[j]
        if self.caps[j] <= 0:
            return
        if obj in c:
            c.move_to_end(obj)
            return
        c[obj] = None
        self.n_inserted += 1
        if len(c) > self.caps[j]:
            c.popitem(last=False)              # evict LRU
            self.n_evicted += 1

    def _refresh(self, j: int, key: int) -> None:
        if key in self.caches[j]:
            self.caches[j].move_to_end(key)

    def _prob_insert(self, path: np.ndarray, upto: int) -> None:
        """ProbCache-style inserts at path positions [0, upto)."""
        L = len(path)
        if L == 0:
            return
        caps = self.caps[path].astype(np.float64)
        mean_cap = max(float(caps.mean()), 1.0)
        for p in range(upto):
            weight = float(caps[p:].sum()) / (10.0 * mean_cap)
            prob = min(1.0, weight * (p + 1) / L)
            if self.rng.random() < prob:
                self._insert(int(path[p]), self._pending_obj)

    # ------------------------------------------------------------- serving
    def serve_one(self, obj: int, ing: int) -> tuple[float, float, int, int]:
        """Serve one request; returns (cost, approx_cost, cache, payload)
        with cache = −1 / payload = −1 for a repository fetch."""
        path = self.paths[ing]
        repo = float(self.h_repo[ing])
        # nearest key + total cost per on-path cache
        cas = np.empty(len(path), np.float64)
        keys = np.empty(len(path), np.int64)
        for p, j in enumerate(path):
            cas[p], keys[p] = self._nearest(int(j), obj)
        costs = cas + self.H[ing, path]
        eligible = costs < repo
        if self.threshold is not None:
            eligible &= cas <= self.threshold
        serve_p = -1
        if self.strategy == "rnd-lru":
            # walk up the path; each eligible cache answers with prob
            # q = clamp(1 − C_a/θ_eff, 0, 1), a refusal falls through
            for p in np.nonzero(eligible)[0]:
                theta = (self.threshold if self.threshold is not None
                         else repo - self.H[ing, path[p]])
                q = rnd_lru_serve_prob(float(cas[p]), float(theta))
                if q <= 0.0 and theta <= 0.0:
                    # non-positive slack: can never serve — skip
                    # without spending a coin (q = 0 at ca == θ still
                    # draws, matching the pre-clamp rng stream)
                    continue
                if self.rng.random() < q:
                    serve_p = int(p)
                    break
        elif np.any(eligible):
            masked = np.where(eligible, costs, np.inf)
            serve_p = int(np.argmin(masked))    # ties → nearest cache

        self._pending_obj = obj
        if serve_p < 0:                          # repository fetch
            for p in self._miss_insert_positions(path):
                self._insert(int(path[p]), obj)
            if self.strategy == "probcache":
                self._prob_insert(path, len(path))
            return repo, 0.0, -1, -1
        j = int(path[serve_p])
        key = int(keys[serve_p])
        self._refresh(j, key)
        self._hit_insert(path, serve_p, key)
        return float(costs[serve_p]), float(cas[serve_p]), j, key

    def _miss_insert_positions(self, path: np.ndarray) -> range:
        if len(path) == 0 or self.strategy == "probcache":
            return range(0)
        if self.strategy == "lcd":
            return range(len(path) - 1, len(path))   # top cache only
        return range(len(path))                      # lce / sim-lru / rnd-lru

    def _hit_insert(self, path: np.ndarray, p: int, key: int) -> None:
        """Copies left on the return path below the serving position."""
        if self.strategy == "lce":
            for q in range(p):
                self._insert(int(path[q]), key)
        elif self.strategy == "lcd" and p > 0:
            self._insert(int(path[p - 1]), key)
        elif self.strategy == "probcache":
            self._pending_obj = key
            self._prob_insert(path, p)
        # sim-lru / rnd-lru: refresh only, no new copies

    def serve(self, objs: np.ndarray, ings: np.ndarray) -> RouteDecision:
        """Serve a batch in arrival order; every request is served by
        exactly one server (a cache or the repository)."""
        objs = np.asarray(objs, np.int64)
        ings = np.asarray(ings, np.int64)
        B = objs.shape[0]
        dec = RouteDecision(
            cost=np.empty(B), approx_cost=np.empty(B),
            hit=np.zeros(B, bool), cache=np.full(B, -1, np.int64),
            payload=np.full(B, -1, np.int64))
        for b in range(B):
            c, ca, j, key = self.serve_one(int(objs[b]), int(ings[b]))
            dec.cost[b] = c
            dec.approx_cost[b] = ca
            dec.cache[b] = j
            dec.payload[b] = key
            dec.hit[b] = j >= 0
        self.n_served += B
        return dec

    # ---------------------------------------------------------- inspection
    def occupancy(self) -> np.ndarray:
        """(n_caches,) stored-key counts (≤ capacities, always)."""
        return np.array([len(c) for c in self.caches], np.int64)

    def contents(self) -> list[np.ndarray]:
        """Stored keys per cache, LRU → MRU order."""
        return [np.fromiter(c.keys(), np.int64, len(c))
                for c in self.caches]


def build_strategy(strategy: str, net: CacheNetwork, coords: np.ndarray,
                   metric: str = "l2", gamma: float = 1.0,
                   threshold: float | None = None,
                   seed: int = 0) -> StrategyPlane:
    """Factory used by ``serve.engine`` (EngineConfig.strategy)."""
    return StrategyPlane(net, coords, metric=metric, gamma=gamma,
                         strategy=strategy, threshold=threshold, seed=seed)
