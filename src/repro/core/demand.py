"""Request processes (demand models) over a catalog × ingress nodes.

The paper's request model: request r = (o, i) arrives as a Poisson process
of rate λ_r. We represent demand as a matrix ``lam`` of shape
(n_ingress, n_objects), normalized so the aggregate rate is 1 (the paper
normalizes costs per request).

Demand generators cover the paper's experiments:
* Gaussian-on-grid (§6.1): λ_o ∝ exp(−d_o² / 2σ²), d_o = hop distance to
  the grid center.
* Uniform (§6.1 / Fig 5 right, Fig 6).
* Zipf popularity over an embedding catalog (the Amazon trace stand-in,
  §6.2 — popularity rank uncorrelated with distance from barycenter).
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.core.catalog import Catalog


@dataclasses.dataclass(frozen=True)
class Demand:
    lam: np.ndarray            # (n_ingress, n_objects), sums to 1
    name: str = "demand"

    @property
    def n_ingress(self) -> int:
        return self.lam.shape[0]

    @property
    def n_objects(self) -> int:
        return self.lam.shape[1]

    @functools.cached_property
    def _cdf(self) -> np.ndarray:
        """Normalized cumulative weights over the flattened (ingress,
        object) grid, computed once per Demand (``lam`` is frozen).

        Cast to float64 and renormalized: a float32 catalog's
        probabilities can sum to 1 ± few·1e-7, and the renormalization
        keeps draws reproducible under a fixed ``rng`` regardless of
        the platform's float/int widths. (``cached_property`` writes
        straight into the instance ``__dict__``, which is fine on a
        frozen dataclass — only ``__setattr__`` is blocked.)
        """
        p = np.asarray(self.lam, np.float64).ravel()
        cdf = np.cumsum(p)
        cdf /= cdf[-1]
        return cdf

    def sample(self, n: int,
               rng: np.random.Generator) -> tuple[np.ndarray, np.ndarray]:
        """Sample n requests → (object_idx, ingress_idx), iid ∝ λ.

        Draws are inverse-CDF over the cached cumulative weights —
        O(n·log(O)) per call instead of the O(n_ingress·O) per call of
        rebuilding the probability vector for ``rng.choice`` (which
        ``serve/stream.py`` was paying once per streamed request).
        This is bit-compatible with the previous implementation:
        ``Generator.choice(size, p)`` itself draws
        ``cdf.searchsorted(random(n), side='right')``, so the same
        ``rng`` state yields the same requests, and n calls of
        ``sample(1)`` equal one ``sample(n)``.
        """
        flat = self._cdf.searchsorted(rng.random(n), side="right")
        ing, obj = np.divmod(flat, self.lam.shape[1])
        return obj.astype(np.int64), ing.astype(np.int64)


def _normalize(lam: np.ndarray) -> np.ndarray:
    """Normalize rates to sum 1, rejecting degenerate inputs up front:
    a zero/NaN total would silently produce NaN lam here and only blow
    up later deep inside a solver."""
    total = float(np.sum(lam))
    if not np.isfinite(total) or total <= 0.0:
        raise ValueError(
            f"demand rates must have a positive finite sum, got {total}")
    return (lam / total).astype(np.float64)


def gaussian_grid(cat: Catalog, sigma: float, n_ingress: int = 1,
                  betas: np.ndarray | None = None) -> Demand:
    """Gaussian demand centered on the grid (paper §6.1).

    λ_o ∝ exp(−d_o²/(2σ²)) with d_o the norm-1 hop distance from the grid
    center. With multiple ingress nodes the spatial shape is identical up
    to per-ingress scale factors β_ℓ (the paper's equi-depth-tree
    assumption, §4.3).
    """
    center = cat.coords.mean(axis=0)
    d = np.abs(cat.coords - center).sum(axis=1)
    base = np.exp(-d.astype(np.float64) ** 2 / (2.0 * sigma ** 2))
    betas = np.ones(n_ingress) if betas is None else np.asarray(betas, np.float64)
    lam = betas[:, None] * base[None, :]
    return Demand(lam=_normalize(lam), name=f"gauss_s{sigma:g}")


def uniform(cat: Catalog, n_ingress: int = 1,
            betas: np.ndarray | None = None) -> Demand:
    betas = np.ones(n_ingress) if betas is None else np.asarray(betas, np.float64)
    lam = np.repeat(betas[:, None], cat.n, axis=1)
    return Demand(lam=_normalize(lam), name="uniform")


def zipf(cat: Catalog, alpha: float = 0.8, n_ingress: int = 1, seed: int = 0,
         betas: np.ndarray | None = None) -> Demand:
    """Zipf popularity assigned in a random order (rank ⟂ geometry, §6.2)."""
    rng = np.random.default_rng(seed)
    ranks = rng.permutation(cat.n) + 1
    base = 1.0 / ranks.astype(np.float64) ** alpha
    betas = np.ones(n_ingress) if betas is None else np.asarray(betas, np.float64)
    lam = betas[:, None] * base[None, :]
    return Demand(lam=_normalize(lam), name=f"zipf{alpha:g}")


def from_trace(n_objects: int, obj_ids: np.ndarray, ingress_ids: np.ndarray,
               n_ingress: int = 1) -> Demand:
    """Empirical demand from a request trace (object id, ingress id).

    Raises ``ValueError`` on an empty trace or on ids outside the
    catalog/ingress ranges — both used to flow through as NaN lam or an
    IndexError from ``np.add.at``, failing far from the broken input."""
    obj_ids = np.asarray(obj_ids, dtype=np.int64)
    ingress_ids = np.asarray(ingress_ids, dtype=np.int64)
    if obj_ids.size == 0:
        raise ValueError("empty trace: no requests to build demand from")
    if obj_ids.shape != ingress_ids.shape:
        raise ValueError(
            f"trace length mismatch: {obj_ids.size} object ids vs "
            f"{ingress_ids.size} ingress ids")
    if obj_ids.min() < 0 or obj_ids.max() >= n_objects:
        raise ValueError(
            f"object ids must be in [0, {n_objects}), got range "
            f"[{obj_ids.min()}, {obj_ids.max()}]")
    if ingress_ids.min() < 0 or ingress_ids.max() >= n_ingress:
        raise ValueError(
            f"ingress ids must be in [0, {n_ingress}), got range "
            f"[{ingress_ids.min()}, {ingress_ids.max()}]")
    lam = np.zeros((n_ingress, n_objects), dtype=np.float64)
    np.add.at(lam, (ingress_ids, obj_ids), 1.0)
    return Demand(lam=_normalize(lam), name="trace")
