"""Problem instance + vectorized evaluation of the paper's objective.

Implements eqs. (1)–(4):

    C(r, A) = min_{α ∈ A ∪ S} C(r, α)          (1)
    C(A)    = Σ_r λ_r C(r, A)                   (2) discrete case
    G(A)    = C(∅) − C(A)                       caching gain (§3.1)

An *allocation* is a flat int64 vector ``slots`` of length
``net.total_slots`` holding object ids (−1 = empty slot); slot ``s``
belongs to cache ``net.slot_layout()[s]``. This fixed layout makes the
matroid constraint (Prop 3.2 / Appendix A) trivially satisfied by
construction and maps 1:1 onto device-resident cache shards.

Requests are the pairs (ingress i, object o) with rate ``dem.lam[i, o]``;
the request space equals the catalog (O_R = O), as in the paper's
experiments.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.core.catalog import Catalog
from repro.core.demand import Demand
from repro.core.topology import CacheNetwork

INF = np.float32(np.inf)


@dataclasses.dataclass(frozen=True)
class Instance:
    """A similarity-caching placement problem instance (discrete case).

    ``ca_matrix`` optionally supplies an explicit approximation-cost
    matrix (the paper's first instance, §2); otherwise C_a is derived
    from catalog coordinates (metric^γ).
    """
    net: CacheNetwork
    cat: Catalog
    dem: Demand
    ca_matrix: np.ndarray | None = None

    def __post_init__(self):
        assert self.dem.n_ingress == self.net.n_ingress
        assert self.dem.n_objects == self.cat.n
        if self.ca_matrix is not None:
            assert self.ca_matrix.shape == (self.cat.n, self.cat.n)

    @functools.cached_property
    def ca(self) -> np.ndarray:
        """Full (O, O) approximation-cost matrix (float32, cached)."""
        return self.cat.ca() if self.ca_matrix is None else self.ca_matrix

    @functools.cached_property
    def slot_cache(self) -> np.ndarray:
        return self.net.slot_layout()

    @functools.cached_property
    def lam(self) -> np.ndarray:
        return self.dem.lam

    # ---------------------------------------------------------------- eval
    def slot_costs(self, slots: np.ndarray) -> np.ndarray:
        """(I, O, K) cost of serving request (i, o) with slot s.

        cost[i, o, s] = C_a[o, slots[s]] + H[i, cache(s)]; +inf for empty
        slots and off-path caches.
        """
        K = slots.shape[0]
        ca_cols = np.where(slots[None, :] >= 0,
                           self.ca[:, np.maximum(slots, 0)], INF)   # (O, K)
        h = self.net.H[:, self.slot_cache]                           # (I, K)
        return ca_cols[None, :, :] + h[:, None, :]

    def best_two(self, slots: np.ndarray):
        """Per-request best/second-best over slots ∪ {repository}.

        Returns (best1, arg1, best2): arg1 is the slot index, or −1 when
        the repository is the best server. best2 likewise includes the
        repository as a candidate.
        """
        c = self.slot_costs(slots)                                   # (I,O,K)
        if c.shape[2] > 1:
            part = np.argpartition(c, 1, axis=2)[:, :, :2]           # O(K)
            vals = np.take_along_axis(c, part, axis=2)
            first = np.argmin(vals, axis=2, keepdims=True)
            b1 = np.take_along_axis(vals, first, axis=2)[:, :, 0]
            b2 = np.take_along_axis(vals, 1 - first, axis=2)[:, :, 0]
            a1 = np.take_along_axis(part, first, axis=2)[:, :, 0]
        else:
            b1, a1 = c[:, :, 0], np.zeros(c.shape[:2], dtype=np.int64)
            b2 = np.full_like(b1, INF)
        repo = self.net.h_repo[:, None].astype(np.float32)
        # fold the repository in as the always-available approximizer S
        best1 = np.minimum(b1, repo)
        arg1 = np.where(repo < b1, -1, a1)
        best2 = np.minimum(np.where(repo < b1, b1, b2), repo)
        return best1, arg1, best2

    def request_costs(self, slots: np.ndarray) -> np.ndarray:
        """C(r, A) for every request (I, O) — eq. (1)."""
        best1, _, _ = self.best_two(slots)
        return best1

    def total_cost(self, slots: np.ndarray) -> float:
        """Expected cost C(A) per unit rate — eq. (2)."""
        return float(np.sum(self.lam * self.request_costs(slots)))

    def empty_cost(self) -> float:
        """C(∅): every request served by its repository."""
        return float(np.sum(self.lam * self.net.h_repo[:, None]))

    def caching_gain(self, slots: np.ndarray) -> float:
        """G(A) = C(∅) − C(A) (§3.1); non-negative, monotone, submodular."""
        return self.empty_cost() - self.total_cost(slots)

    # ------------------------------------------------------------- greedy
    def add_gain_single(self, cur: np.ndarray, obj: int, cache: int) -> float:
        """Marginal gain of adding approximizer (obj, cache) given current
        per-request costs ``cur`` (I, O):  Σ_r λ_r·relu(cur_r − C(r, α))."""
        newc = self.ca[:, obj][None, :] + self.net.H[:, cache][:, None]
        return float(np.sum(self.lam * np.maximum(cur - newc, 0.0)))

    def add_gain_all(self, cur: np.ndarray, block: int = 2048) -> np.ndarray:
        """(O, J) marginal gain for every candidate approximizer.

        gain[o', j] = Σ_{i,o} λ[i,o]·relu(cur[i,o] − H[i,j] − C_a[o, o']),
        computed in O-row blocks to bound the (O×O) temporary. This is the
        reference implementation of the fused Pallas ``gain`` kernel
        (kernels/gain/ref.py re-exports it in pure jnp).
        """
        O, J = self.cat.n, self.net.n_caches
        gain = np.zeros((O, J), dtype=np.float64)
        for i in range(self.net.n_ingress):
            lam_i = self.lam[i]
            for j in range(J):
                h = self.net.H[i, j]
                if not np.isfinite(h):
                    continue
                a = cur[i] - h                                    # (O,)
                for s in range(0, O, block):
                    blk = slice(s, s + block)
                    m = np.maximum(a[blk, None] - self.ca[blk, :], 0.0)
                    gain[:, j] += lam_i[blk] @ m
        return gain

    def updated_costs(self, cur: np.ndarray, obj: int, cache: int) -> np.ndarray:
        """cur after adding (obj, cache): min(cur, C_a[:,obj] + H[:,cache])."""
        newc = self.ca[:, obj][None, :] + self.net.H[:, cache][:, None]
        return np.minimum(cur, newc)


def random_slots(inst: Instance, rng: np.random.Generator) -> np.ndarray:
    """Random initial allocation (LocalSwap/NetDuel start state, §3.3)."""
    return rng.integers(0, inst.cat.n, size=inst.net.total_slots, dtype=np.int64)


def empty_slots(inst: Instance) -> np.ndarray:
    return np.full(inst.net.total_slots, -1, dtype=np.int64)
