"""Problem instance + vectorized evaluation of the paper's objective.

Implements eqs. (1)–(4):

    C(r, A) = min_{α ∈ A ∪ S} C(r, α)          (1)
    C(A)    = Σ_r λ_r C(r, A)                   (2) discrete case
    G(A)    = C(∅) − C(A)                       caching gain (§3.1)

An *allocation* is a flat int64 vector ``slots`` of length
``net.total_slots`` holding object ids (−1 = empty slot); slot ``s``
belongs to cache ``net.slot_layout()[s]``. This fixed layout makes the
matroid constraint (Prop 3.2 / Appendix A) trivially satisfied by
construction and maps 1:1 onto device-resident cache shards.

Requests are the pairs (ingress i, object o) with rate ``dem.lam[i, o]``;
the request space equals the catalog (O_R = O), as in the paper's
experiments.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.core.catalog import Catalog
from repro.core.demand import Demand
from repro.core.topology import CacheNetwork

INF = np.float32(np.inf)

# past this catalog size the dense (O, O) C_a matrix is never built:
# the host oracle streams row/column blocks and the device twin streams
# distance tiles (kernels/knn/gains.py)
CA_MATERIALIZE_MAX = 16384


@dataclasses.dataclass(frozen=True)
class Instance:
    """A similarity-caching placement problem instance (discrete case).

    ``ca_matrix`` optionally supplies an explicit approximation-cost
    matrix (the paper's first instance, §2); otherwise C_a is derived
    from catalog coordinates (metric^γ).
    """
    net: CacheNetwork
    cat: Catalog
    dem: Demand
    ca_matrix: np.ndarray | None = None

    def __post_init__(self):
        assert self.dem.n_ingress == self.net.n_ingress
        assert self.dem.n_objects == self.cat.n
        if self.ca_matrix is not None:
            assert self.ca_matrix.shape == (self.cat.n, self.cat.n)

    @functools.cached_property
    def ca(self) -> np.ndarray:
        """Full (O, O) approximation-cost matrix (float32, cached)."""
        return self.cat.ca() if self.ca_matrix is None else self.ca_matrix

    @functools.cached_property
    def slot_cache(self) -> np.ndarray:
        return self.net.slot_layout()

    @functools.cached_property
    def lam(self) -> np.ndarray:
        return self.dem.lam

    # ---------------------------------------------------------------- eval
    def slot_costs(self, slots: np.ndarray) -> np.ndarray:
        """(I, O, K) cost of serving request (i, o) with slot s.

        cost[i, o, s] = C_a[o, slots[s]] + H[i, cache(s)]; +inf for empty
        slots and off-path caches.
        """
        K = slots.shape[0]
        ca_cols = np.where(slots[None, :] >= 0,
                           self.ca[:, np.maximum(slots, 0)], INF)   # (O, K)
        h = self.net.H[:, self.slot_cache]                           # (I, K)
        return ca_cols[None, :, :] + h[:, None, :]

    def best_two(self, slots: np.ndarray):
        """Per-request best/second-best over slots ∪ {repository}.

        Returns (best1, arg1, best2): arg1 is the slot index, or −1 when
        the repository is the best server. best2 likewise includes the
        repository as a candidate. Ties break to the *lowest slot index*
        (argmin semantics) — the contract shared bit-for-bit with the
        device twin (``DeviceInstance.best_two``), so host and device
        LOCALSWAP attribute corrections to the same slot.
        """
        c = self.slot_costs(slots)                                   # (I,O,K)
        a1 = np.argmin(c, axis=2)                                    # lowest s
        b1 = np.take_along_axis(c, a1[:, :, None], axis=2)[:, :, 0]
        masked = c.copy()
        np.put_along_axis(masked, a1[:, :, None], INF, axis=2)
        b2 = masked.min(axis=2)
        repo = self.net.h_repo[:, None].astype(np.float32)
        # fold the repository in as the always-available approximizer S
        best1 = np.minimum(b1, repo)
        arg1 = np.where(repo < b1, -1, a1)
        best2 = np.minimum(np.where(repo < b1, b1, b2), repo)
        return best1, arg1, best2

    def request_costs(self, slots: np.ndarray) -> np.ndarray:
        """C(r, A) for every request (I, O) — eq. (1)."""
        best1, _, _ = self.best_two(slots)
        return best1

    def total_cost(self, slots: np.ndarray) -> float:
        """Expected cost C(A) per unit rate — eq. (2)."""
        return float(np.sum(self.lam * self.request_costs(slots)))

    def empty_cost(self) -> float:
        """C(∅): every request served by its repository."""
        return float(np.sum(self.lam * self.net.h_repo[:, None]))

    def caching_gain(self, slots: np.ndarray) -> float:
        """G(A) = C(∅) − C(A) (§3.1); non-negative, monotone, submodular."""
        return self.empty_cost() - self.total_cost(slots)

    # ------------------------------------------------------------- greedy
    def _ca_col(self, obj: int) -> np.ndarray:
        """(O,) column C_a[:, obj] — cached-matrix view or on-the-fly."""
        if self.ca_matrix is not None or "ca" in self.__dict__ \
                or self.cat.n <= CA_MATERIALIZE_MAX:
            return self.ca[:, obj]
        return self.cat.ca(cols=np.array([obj]))[:, 0]

    def add_gain_single(self, cur: np.ndarray, obj: int, cache: int) -> float:
        """Marginal gain of adding approximizer (obj, cache) given current
        per-request costs ``cur`` (I, O):  Σ_r λ_r·relu(cur_r − C(r, α))."""
        newc = self._ca_col(obj)[None, :] + self.net.H[:, cache][:, None]
        return float(np.sum(self.lam * np.maximum(cur - newc, 0.0)))

    def _ca_rows(self, rows: np.ndarray | slice) -> np.ndarray:
        """(len(rows), O) block of C_a — a view of the cached matrix when
        it exists (or is small enough to build), computed on the fly
        otherwise. ``CA_MATERIALIZE_MAX`` keeps the honest-oracle path
        usable at catalog sizes where a dense (O, O) C_a cannot exist."""
        if self.ca_matrix is not None or "ca" in self.__dict__ \
                or self.cat.n <= CA_MATERIALIZE_MAX:
            return self.ca[rows]
        idx = np.arange(self.cat.n)[rows] if isinstance(rows, slice) else rows
        return self.cat.ca(rows=idx)

    def add_gain_all(self, cur: np.ndarray, block: int = 2048) -> np.ndarray:
        """(O, J) marginal gain for every candidate approximizer.

        gain[o', j] = Σ_{i,o} λ[i,o]·relu(cur[i,o] − H[i,j] − C_a[o, o']),
        computed in O-row blocks to bound the (O×O) temporary; each C_a
        row block is fetched once and reused across every (ingress,
        cache) pair (on-the-fly for catalogs past ``CA_MATERIALIZE_MAX``,
        where the dense matrix cannot be cached). This is the host
        differential oracle of the device gain kernel
        (kernels/knn/gains.py; kernels/gain/ref.py is the single-ingress
        jnp flavor).
        """
        O, J = self.cat.n, self.net.n_caches
        gain = np.zeros((O, J), dtype=np.float64)
        for s in range(0, O, block):
            blk = slice(s, s + block)
            ca_blk = self._ca_rows(blk)
            for i in range(self.net.n_ingress):
                for j in range(J):
                    h = self.net.H[i, j]
                    if not np.isfinite(h):
                        continue
                    a = cur[i, blk] - h                           # (b,)
                    m = np.maximum(a[:, None] - ca_blk, 0.0)
                    gain[:, j] += self.lam[i, blk] @ m
        return gain

    def add_gain_delta(self, cur_old: np.ndarray, cur_new: np.ndarray,
                       block: int = 2048) -> np.ndarray:
        """(O, J) change in :meth:`add_gain_all` when per-request costs
        drop from ``cur_old`` to ``cur_new`` (elementwise ≤).

        Only requests whose cost actually changed contribute, so one
        GREEDY pick (which improves the few requests near the new
        approximizer) updates the whole gain table in O(changed·O·J)
        instead of the eager path's full O(O²·J) recompute — the
        vectorized row-update reuse of ``updated_costs`` applied to the
        gain table itself.
        """
        O, J = self.cat.n, self.net.n_caches
        delta = np.zeros((O, J), dtype=np.float64)
        changed = cur_new < cur_old                               # (I, O)
        for i in range(self.net.n_ingress):
            idx = np.nonzero(changed[i])[0]
            if idx.size == 0:
                continue
            for s in range(0, idx.size, block):
                sel = idx[s:s + block]
                ca_blk = self._ca_rows(sel)
                a_new = cur_new[i, sel][:, None]
                a_old = cur_old[i, sel][:, None]
                lam_i = self.lam[i, sel]
                for j in range(J):
                    h = self.net.H[i, j]
                    if not np.isfinite(h):
                        continue
                    m = (np.maximum(a_new - h - ca_blk, 0.0)
                         - np.maximum(a_old - h - ca_blk, 0.0))
                    delta[:, j] += lam_i @ m
        return delta

    def updated_costs(self, cur: np.ndarray, obj: int, cache: int) -> np.ndarray:
        """cur after adding (obj, cache): min(cur, C_a[:,obj] + H[:,cache])."""
        newc = self._ca_col(obj)[None, :] + self.net.H[:, cache][:, None]
        return np.minimum(cur, newc)


# ===================================================================== device
# Device-resident twin of Instance: the placement control plane's state
# (per-request serving costs, slot layout, C_a access) lives on the
# accelerator and every oracle/update below is a jitted op, so
# GREEDY/LOCALSWAP (core/placement/device.py) never round-trips the
# O(O·J) gain grid through host NumPy. Two C_a modes:
#
#   * materialized — the host (O, O) matrix uploaded once (bit-identical
#     C_a entries to the host oracle; the small-instance fidelity mode);
#   * streaming    — distance tiles computed on the fly by the
#     kernels/knn/gains.py oracle (the only mode possible past
#     CA_MATERIALIZE_MAX, and the one that shards over a mesh).

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("metric", "gamma"))
def _ca_cols_device(coords, objs, metric: str, gamma: float):
    from repro.core import costs
    return costs.approx_cost_stable(coords, coords[objs], metric, gamma)


@functools.partial(jax.jit, static_argnames=("metric", "gamma", "has_ca"))
def _gain_at_device(coords, ca, lam, cur, H, objs, caches,
                    metric: str, gamma: float, has_ca: bool):
    """(k,) exact marginal gains of candidate pairs (objs[c], caches[c])
    given current costs ``cur`` (I, O) — the batched lazy-greedy refresh."""
    if has_ca:
        cac = ca[:, objs]                                      # (O, k)
    else:
        from repro.core import costs
        # shape-stable form: bitwise-consistent with _apply_pick_device,
        # so a candidate already folded into ``cur`` refreshes to an
        # exact-zero gain (no phantom f32 tail gains — see costs.py)
        cac = costs.approx_cost_stable(coords, coords[objs], metric, gamma)
    hsel = H[:, caches]                                        # (I, k)
    slack = cur[:, :, None] - cac[None, :, :] - hsel[:, None, :]
    return jnp.sum(lam[:, :, None] * jnp.maximum(slack, 0.0), axis=(0, 1))


@functools.partial(jax.jit, static_argnames=("metric", "gamma", "has_ca"))
def _apply_pick_device(coords, ca, H, cur, obj, cache,
                       metric: str, gamma: float, has_ca: bool):
    """cur ← min(cur, C_a[:, obj] + H[:, cache]) — incremental update."""
    if has_ca:
        col = ca[:, obj]
    else:
        from repro.core import costs
        col = costs.approx_cost_stable(coords, coords[obj][None, :],
                                       metric, gamma)[:, 0]
    newc = col[None, :] + H[:, cache][:, None]
    return jnp.minimum(cur, newc)


def _stable_ca_cols(x, keys, metric: str, gamma: float,
                    block: int = 16) -> jax.Array:
    """(R, K) shape-stable C_a against the slot keys, lax.map-blocked
    over slot chunks so the (R, block, D) broadcast temporary stays
    bounded at 10⁵-object catalogs. Per-pair values equal
    ``costs.approx_cost_stable`` at any batch shape by construction."""
    from repro.core import costs
    K, D = keys.shape
    pad = (-K) % block
    tiles = jnp.pad(keys, ((0, pad), (0, 0))).reshape(-1, block, D)
    out = jax.lax.map(
        lambda kt: costs.approx_cost_stable(x, kt, metric, gamma), tiles)
    return jnp.moveaxis(out, 0, 1).reshape(x.shape[0], -1)[:, :K]


def _best_two_rows_pre(rows, keys, slots, slot_cache, H,
                       metric: str, gamma: float, has_ca: bool):
    """Pre-repo-fold best-two for a block of request rows: (b1, a1, b2,
    a2), all over *slots only* (the repo escape is folded separately by
    :func:`_fold_repo_rows`). The slot-index witnesses a1/a2 are what
    the incremental path (:func:`best_two_delta`) keys its dirty-row
    detection on — the fold erases a1 when the repo wins, so deltas must
    carry the pre-fold tables.

    ``rows`` is either a (R, O) block of C_a rows (``has_ca``) or the
    (R, D) request coordinates, with ``keys`` the (K, D) slot-key
    coordinates. Rows are independent, which is exactly what lets
    :func:`sharded_best_two_tables` shard_map this over the request axis
    with bit-identical per-row results. The coords mode uses the
    shape-stable distance form (costs.pairwise_distance_stable), so a
    table entry for pair (r, y) is bitwise the value every other
    incremental op (swap deltas, duel pricing, apply_pick) computes for
    that pair — the streamed control plane has one canonical C_a.
    """
    safe = jnp.maximum(slots, 0)
    if has_ca:
        d = rows[:, safe]                                      # (R, K)
    else:
        d = _stable_ca_cols(rows, keys, metric, gamma)
    ca_cols = jnp.where(slots[None, :] >= 0, d, jnp.inf)
    c = ca_cols[None, :, :] + H[:, slot_cache][:, None, :]     # (I, R, K)
    a1 = jnp.argmin(c, axis=2).astype(jnp.int32)
    b1 = jnp.take_along_axis(c, a1[:, :, None], axis=2)[:, :, 0]
    k_iota = jax.lax.broadcasted_iota(jnp.int32, c.shape, 2)
    masked = jnp.where(k_iota == a1[:, :, None], jnp.inf, c)
    b2 = jnp.min(masked, axis=2)
    a2 = jnp.argmin(masked, axis=2).astype(jnp.int32)
    return b1, a1, b2, a2


def _fold_repo_rows(b1, a1, b2, h_repo):
    """Fold the repo escape (cost h_repo, index -1) into pre-fold slot
    tables — exactly the historical tail of ``_best_two_rows``, so
    fold(pre) is bitwise the old fused computation."""
    repo = h_repo[:, None]
    best1 = jnp.minimum(b1, repo)
    arg1 = jnp.where(repo < b1, -1, a1).astype(jnp.int32)
    best2 = jnp.minimum(jnp.where(repo < b1, b1, b2), repo)
    return best1, arg1, best2


def _best_two_rows(rows, keys, slots, slot_cache, H, h_repo,
                   metric: str, gamma: float, has_ca: bool):
    """best1/arg1/best2 for a block of request rows — pre-fold tables
    (:func:`_best_two_rows_pre`) with the repo escape folded in."""
    b1, a1, b2, _ = _best_two_rows_pre(rows, keys, slots, slot_cache, H,
                                       metric, gamma, has_ca)
    return _fold_repo_rows(b1, a1, b2, h_repo)


@functools.partial(jax.jit, static_argnames=("metric", "gamma", "has_ca"))
def _best_two_device(coords, ca, slots, slot_cache, H, h_repo,
                     metric: str, gamma: float, has_ca: bool):
    """Device mirror of Instance.best_two — identical lowest-slot-index
    tie-break (jnp.argmin keeps the first minimum, like np.argmin)."""
    rows = ca if has_ca else coords
    keys = jnp.zeros((0, 0), jnp.float32) if has_ca \
        else coords[jnp.maximum(slots, 0)]
    return _best_two_rows(rows, keys, slots, slot_cache, H, h_repo,
                          metric, gamma, has_ca)


@functools.partial(jax.jit, static_argnames=("metric", "gamma", "has_ca",
                                             "mesh", "axes"))
def sharded_best_two(coords, ca, slots, slot_cache, H, h_repo, mesh,
                     axes: tuple, metric: str, gamma: float, has_ca: bool):
    """Mesh-sharded best1/arg1/best2: the request axis (the (I, O) cost
    tables' object dimension) is shard_mapped over ``axes`` — the same
    axes the data-plane keys shard over — with slot keys and topology
    replicated. Every request row is computed with the exact ops of
    :func:`_best_two_device`, so results are bit-identical at any shard
    count; this is the refresh kernel the online control plane
    (NETDUEL's promotion re-arm, the scanned LOCALSWAP) runs when a
    ``DeviceInstance`` carries mesh axes.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.kernels.knn.ops import _pad_axis, mesh_axes_size
    n_shards = mesh_axes_size(mesh, axes)
    n_obj = coords.shape[0] if not has_ca else ca.shape[0]
    safe = jnp.maximum(slots, 0)
    if has_ca:
        rows = _pad_axis(ca, n_shards, 0, "zero")
        keys = jnp.zeros((0, 0), jnp.float32)
    else:
        rows = _pad_axis(coords, n_shards, 0, "zero")
        keys = coords[safe]

    def shard_fn(rows_s, keys_s, slots_s, slot_cache_s, H_s, h_repo_s):
        return _best_two_rows(rows_s, keys_s, slots_s, slot_cache_s, H_s,
                              h_repo_s, metric, gamma, has_ca)

    best1, arg1, best2 = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(tuple(axes), None), P(), P(), P(), P(), P()),
        out_specs=(P(None, tuple(axes)),) * 3,
        check_rep=False)(rows, keys, slots, slot_cache, H, h_repo)
    return best1[:, :n_obj], arg1[:, :n_obj], best2[:, :n_obj]


def best_two_refresh(coords, ca, slots, slot_cache, H, h_repo,
                     metric: str, gamma: float, has_ca: bool,
                     mesh=None, axes: tuple = ()):
    """The single serving-table refresh every control-plane consumer
    shares (``DeviceInstance.best_two``, the NETDUEL scan's promotion
    re-arm, the scanned LOCALSWAP's post-swap re-arm): static dispatch
    to :func:`sharded_best_two` when mesh axes are configured, else the
    single-device kernel — bit-identical either way. Callers pass
    ``mesh=None`` when the policy resolves to one shard."""
    if mesh is not None:
        return sharded_best_two(coords, ca, slots, slot_cache, H, h_repo,
                                mesh, axes, metric, gamma, has_ca)
    return _best_two_device(coords, ca, slots, slot_cache, H, h_repo,
                            metric, gamma, has_ca)


@functools.partial(jax.jit, static_argnames=("metric", "gamma", "has_ca"))
def _best_two_tables_device(coords, ca, slots, slot_cache, H,
                            metric: str, gamma: float, has_ca: bool):
    rows = ca if has_ca else coords
    keys = jnp.zeros((0, 0), jnp.float32) if has_ca \
        else coords[jnp.maximum(slots, 0)]
    return _best_two_rows_pre(rows, keys, slots, slot_cache, H,
                              metric, gamma, has_ca)


@functools.partial(jax.jit, static_argnames=("metric", "gamma", "has_ca",
                                             "mesh", "axes"))
def sharded_best_two_tables(coords, ca, slots, slot_cache, H, mesh,
                            axes: tuple, metric: str, gamma: float,
                            has_ca: bool):
    """Mesh-sharded pre-fold tables (b1, a1, b2, a2): the request axis is
    shard_mapped over ``axes`` exactly like :func:`sharded_best_two`, so
    per-row results are bit-identical at any shard count."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.kernels.knn.ops import _pad_axis, mesh_axes_size
    n_shards = mesh_axes_size(mesh, axes)
    n_obj = coords.shape[0] if not has_ca else ca.shape[0]
    safe = jnp.maximum(slots, 0)
    if has_ca:
        rows = _pad_axis(ca, n_shards, 0, "zero")
        keys = jnp.zeros((0, 0), jnp.float32)
    else:
        rows = _pad_axis(coords, n_shards, 0, "zero")
        keys = coords[safe]

    def shard_fn(rows_s, keys_s, slots_s, slot_cache_s, H_s):
        return _best_two_rows_pre(rows_s, keys_s, slots_s, slot_cache_s,
                                  H_s, metric, gamma, has_ca)

    b1, a1, b2, a2 = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(tuple(axes), None), P(), P(), P(), P()),
        out_specs=(P(None, tuple(axes)),) * 4,
        check_rep=False)(rows, keys, slots, slot_cache, H)
    return b1[:, :n_obj], a1[:, :n_obj], b2[:, :n_obj], a2[:, :n_obj]


def best_two_tables(coords, ca, slots, slot_cache, H,
                    metric: str, gamma: float, has_ca: bool,
                    mesh=None, axes: tuple = ()):
    """Pre-fold best-two tables (b1, a1, b2, a2) over the slot axis only
    — the carried state of the incremental refresh path. Post-fold
    serving tables are ``_fold_repo_rows(b1, a1, b2, h_repo)``, bitwise
    what :func:`best_two_refresh` returns."""
    if mesh is not None:
        return sharded_best_two_tables(coords, ca, slots, slot_cache, H,
                                       mesh, axes, metric, gamma, has_ca)
    return _best_two_tables_device(coords, ca, slots, slot_cache, H,
                                   metric, gamma, has_ca)


# Public name for folding pre-fold tables into serving tables.
fold_best_two = _fold_repo_rows


def default_delta_cap(n_obj: int) -> int:
    """Static dirty-row budget for :func:`best_two_delta`: generous
    enough that overflow (full rebuild) stays rare along scanned
    LOCALSWAP/NETDUEL trajectories, small enough that the gathered
    recompute is a fraction of a rebuild."""
    return max(64, n_obj // 16)


def best_two_delta(coords, ca, b1, a1, b2, a2, slots_new, ys, slot_cache,
                   H, metric: str, gamma: float, has_ca: bool,
                   cap: int, mesh=None, axes: tuple = ()):
    """Incremental pre-fold best-two refresh after slot writes.

    ``ys`` is a (P,) ascending i32 vector of the slot indices whose
    occupant changed (padded with K = total slots for unused lanes);
    ``slots_new`` is the post-write layout. Only rows whose current
    witness (a1 or a2) references a changed slot can need more than a
    two-candidate insertion: for every other row the changed slots' old
    costs sat strictly above best2 (or tied with a higher index than the
    stored witness — argmin keeps the first minimum), so removing them
    cannot move the tables, and inserting the new costs is an exact
    two-way merge with the same lowest-slot-index tie-break the full
    rebuild's argmin applies. Dirty rows are gathered (up to the static
    ``cap``) and recomputed by the full per-row kernel on the canonical
    shape-stable C_a, so the result is bitwise the full rebuild's; if
    more than ``cap`` rows are dirty the whole table is rebuilt
    (lax.cond — same jitted program either way).
    """
    K = int(slot_cache.shape[0])
    n_obj = ca.shape[0] if has_ca else coords.shape[0]
    return _best_two_delta_jit(coords, ca, b1, a1, b2, a2, slots_new, ys,
                               slot_cache, H, metric=metric, gamma=gamma,
                               has_ca=has_ca, cap=min(cap, n_obj),
                               n_slots=K, mesh=mesh, axes=tuple(axes))


@functools.partial(jax.jit, static_argnames=(
    "metric", "gamma", "has_ca", "cap", "n_slots", "mesh", "axes"))
def _best_two_delta_jit(coords, ca, b1, a1, b2, a2, slots_new, ys,
                        slot_cache, H, metric: str, gamma: float,
                        has_ca: bool, cap: int, n_slots: int,
                        mesh=None, axes: tuple = ()):
    from repro.core import costs
    K = n_slots
    R = b1.shape[1]
    n_pend = ys.shape[0]
    safe_y = jnp.minimum(ys, K - 1)
    valid_y = ys < K                                          # (P,)

    # Canonical C_a columns for the rewritten slots: same per-pair bits
    # as the full rebuild's _stable_ca_cols (shape-stable distance form).
    obj = jnp.maximum(slots_new[safe_y], 0)                   # (P,)
    if has_ca:
        cols = ca[:, obj]                                     # (R, P)
    else:
        cols = costs.approx_cost_stable(coords, coords[obj], metric, gamma)
    cols = jnp.where(slots_new[safe_y][None, :] >= 0, cols, jnp.inf)
    cn_all = cols[None, :, :] + H[:, slot_cache[safe_y]][:, None, :]
    cn_all = jnp.where(valid_y[None, None, :], cn_all, jnp.inf)  # (I,R,P)

    # Dirty rows: any ORIGINAL witness lands on a changed slot.
    hit1 = jnp.any((a1[:, :, None] == ys[None, None, :]) & valid_y, -1)
    hit2 = jnp.any((a2[:, :, None] == ys[None, None, :]) & valid_y, -1)
    dirty_r = jnp.any(hit1 | hit2, axis=0)                    # (R,)
    n_dirty = jnp.sum(dirty_r)

    # Two-candidate insertion of each new column, ascending slot order so
    # ties among the new columns themselves break to the lowest index —
    # exactly argmin's first-minimum rule. Clean rows end exact; dirty
    # rows are overwritten below.
    nb1, na1, nb2, na2 = b1, a1, b2, a2
    for j in range(n_pend):
        cn, yj, vj = cn_all[:, :, j], ys[j], valid_y[j]
        take1 = vj & ((cn < nb1) | ((cn == nb1) & (yj < na1)))
        take2 = (~take1) & vj & ((cn < nb2) | ((cn == nb2) & (yj < na2)))
        nb2 = jnp.where(take1, nb1, jnp.where(take2, cn, nb2))
        na2 = jnp.where(take1, na1,
                        jnp.where(take2, yj, na2)).astype(jnp.int32)
        nb1 = jnp.where(take1, cn, nb1)
        na1 = jnp.where(take1, yj, na1).astype(jnp.int32)

    # Recompute the dirty rows with the full per-row kernel (row
    # independence + canonical C_a make the subset bitwise the rebuild).
    ridx = jnp.nonzero(dirty_r, size=cap, fill_value=R)[0].astype(jnp.int32)
    safe_r = jnp.minimum(ridx, R - 1)
    keys_new = jnp.zeros((0, 0), jnp.float32) if has_ca \
        else coords[jnp.maximum(slots_new, 0)]
    rows_sub = ca[safe_r] if has_ca else coords[safe_r]
    sb1, sa1, sb2, sa2 = _best_two_rows_pre(
        rows_sub, keys_new, slots_new, slot_cache, H, metric, gamma, has_ca)
    nb1 = nb1.at[:, ridx].set(sb1, mode="drop")
    na1 = na1.at[:, ridx].set(sa1, mode="drop")
    nb2 = nb2.at[:, ridx].set(sb2, mode="drop")
    na2 = na2.at[:, ridx].set(sa2, mode="drop")

    def _rebuild(_):
        if mesh is not None:
            return sharded_best_two_tables(coords, ca, slots_new,
                                           slot_cache, H, mesh, axes,
                                           metric, gamma, has_ca)
        rows = ca if has_ca else coords
        return _best_two_rows_pre(rows, keys_new, slots_new, slot_cache,
                                  H, metric, gamma, has_ca)

    return jax.lax.cond(n_dirty > cap, _rebuild,
                        lambda _: (nb1, na1, nb2, na2), operand=None)


@dataclasses.dataclass(frozen=True, eq=False)
class DeviceInstance:
    """Device-resident twin of :class:`Instance`.

    Holds the arrays every control-plane op needs (f32 coords, rates,
    retrieval costs, slot layout) plus an optional materialized C_a, and
    exposes the jitted primitives GREEDY/LOCALSWAP are built from:
    :meth:`gains` (full batched oracle, mesh-sharded when configured),
    :meth:`gain_at` (exact refresh of a candidate batch),
    :meth:`apply_pick` (incremental cost update) and :meth:`best_two`.
    ``host`` keeps the originating NumPy instance for demand sampling
    and differential testing — it is never touched by the jitted ops.
    """
    host: Instance
    coords: jax.Array                  # (O, D) f32
    lam: jax.Array                     # (I, O) f32
    H: jax.Array                       # (I, J) f32, +inf off-path
    h_repo: jax.Array                  # (I,) f32
    slot_cache: jax.Array              # (K,) i32
    ca: jax.Array | None               # (O, O) materialized C_a, or None
    metric: str
    gamma: float
    mesh: object = None
    axes: tuple = ()
    use_pallas: bool | None = None
    interpret: bool | None = None

    @classmethod
    def from_instance(cls, inst: Instance, mesh=None, axes: tuple = (),
                      materialize_ca: bool | None = None,
                      use_pallas: bool | None = None,
                      interpret: bool | None = None) -> "DeviceInstance":
        if materialize_ca is None:
            materialize_ca = (inst.ca_matrix is not None
                              or inst.cat.n <= 4096)
        if inst.ca_matrix is not None and not materialize_ca:
            raise ValueError("explicit ca_matrix instances must materialize")
        return cls(
            host=inst,
            coords=jnp.asarray(inst.cat.coords, jnp.float32),
            lam=jnp.asarray(inst.lam, jnp.float32),
            H=jnp.asarray(inst.net.H, jnp.float32),
            h_repo=jnp.asarray(inst.net.h_repo, jnp.float32),
            slot_cache=jnp.asarray(inst.slot_cache, jnp.int32),
            ca=jnp.asarray(inst.ca, jnp.float32) if materialize_ca else None,
            metric=inst.cat.metric, gamma=inst.cat.gamma,
            mesh=mesh, axes=tuple(axes),
            use_pallas=use_pallas, interpret=interpret)

    # ----------------------------------------------------------- shapes
    @property
    def n_objects(self) -> int:
        return self.coords.shape[0]

    @property
    def n_caches(self) -> int:
        return self.H.shape[1]

    @property
    def n_shards(self) -> int:
        if self.mesh is None or not self.axes:
            return 1
        from repro.kernels.knn import mesh_axes_size
        return mesh_axes_size(self.mesh, self.axes)

    # ------------------------------------------------------------- ops
    def initial_costs(self) -> jax.Array:
        """C(r, ∅) = h_repo, per (ingress, object) — f32 (I, O)."""
        return jnp.broadcast_to(
            self.h_repo[:, None], (self.lam.shape[0], self.n_objects)
        ).astype(jnp.float32)

    def gains(self, cur: jax.Array, quantize: bool = False) -> jax.Array:
        """(O, J) marginal gains of every candidate — one oracle launch
        (one per candidate shard when a mesh is configured). With
        ``quantize`` the oracle runs the int8 lower-bound distance pass,
        returning admissible *upper* bounds on every gain — valid lazy
        priorities, not exact values; callers must re-score before
        acceptance (``device_greedy`` does, through its stale-entry
        refresh)."""
        from repro.kernels.knn import (placement_gains,
                                       placement_gains_matrix,
                                       sharded_placement_gains)
        if self.ca is not None:
            return placement_gains_matrix(self.ca, self.lam, cur, self.H,
                                          quantize=quantize)
        if self.mesh is not None and self.n_shards > 1:
            return sharded_placement_gains(
                self.coords, self.coords, self.lam, cur, self.H,
                self.mesh, self.axes, metric=self.metric, gamma=self.gamma,
                use_pallas=self.use_pallas, interpret=self.interpret,
                quantize=quantize)
        return placement_gains(self.coords, self.coords, self.lam, cur,
                               self.H, metric=self.metric, gamma=self.gamma,
                               use_pallas=self.use_pallas,
                               interpret=self.interpret, quantize=quantize)

    def gain_at(self, cur: jax.Array, objs: jax.Array, caches: jax.Array
                ) -> jax.Array:
        ca = self.ca if self.ca is not None else jnp.zeros((0, 0), jnp.float32)
        return _gain_at_device(self.coords, ca, self.lam, cur, self.H,
                               objs, caches, self.metric, self.gamma,
                               self.ca is not None)

    def apply_pick(self, cur: jax.Array, obj, cache) -> jax.Array:
        ca = self.ca if self.ca is not None else jnp.zeros((0, 0), jnp.float32)
        return _apply_pick_device(self.coords, ca, self.H, cur,
                                  jnp.asarray(obj), jnp.asarray(cache),
                                  self.metric, self.gamma,
                                  self.ca is not None)

    def best_two(self, slots: jax.Array):
        """best1/arg1/best2 serving tables — request-axis mesh-sharded
        (``sharded_best_two``) when the instance carries the data-plane
        shard axes; bit-identical either way."""
        ca = self.ca if self.ca is not None else jnp.zeros((0, 0), jnp.float32)
        sharded = self.mesh is not None and self.n_shards > 1
        return best_two_refresh(self.coords, ca, jnp.asarray(slots),
                                self.slot_cache, self.H, self.h_repo,
                                self.metric, self.gamma, self.ca is not None,
                                mesh=self.mesh if sharded else None,
                                axes=self.axes if sharded else ())

    def best_two_tables(self, slots: jax.Array):
        """Pre-fold (b1, a1, b2, a2) tables over the slot axis — the
        carried state of the incremental refresh; fold with
        ``fold_best_two(b1, a1, b2, h_repo)`` for serving tables."""
        ca = self.ca if self.ca is not None else jnp.zeros((0, 0), jnp.float32)
        sharded = self.mesh is not None and self.n_shards > 1
        return best_two_tables(self.coords, ca, jnp.asarray(slots),
                               self.slot_cache, self.H,
                               self.metric, self.gamma, self.ca is not None,
                               mesh=self.mesh if sharded else None,
                               axes=self.axes if sharded else ())

    def best_two_delta(self, b1, a1, b2, a2, slots_new, ys,
                       cap: int | None = None):
        """Incremental pre-fold refresh after writing slots ``ys`` (see
        :func:`best_two_delta`); bitwise :meth:`best_two_tables` on the
        new layout."""
        ca = self.ca if self.ca is not None else jnp.zeros((0, 0), jnp.float32)
        sharded = self.mesh is not None and self.n_shards > 1
        if cap is None:
            cap = default_delta_cap(self.n_objects)
        return best_two_delta(self.coords, ca, b1, a1, b2, a2,
                              jnp.asarray(slots_new), jnp.asarray(ys),
                              self.slot_cache, self.H,
                              self.metric, self.gamma, self.ca is not None,
                              cap=cap,
                              mesh=self.mesh if sharded else None,
                              axes=self.axes if sharded else ())

    def ca_col(self, obj) -> jax.Array:
        """(O,) column C_a[:, obj] as a device array."""
        if self.ca is not None:
            return self.ca[:, obj]
        return _ca_cols_device(self.coords, jnp.asarray(obj)[None],
                               self.metric, self.gamma)[:, 0]

    def total_cost(self, slots) -> float:
        """C(A) evaluated on device (f32) — the only total-cost path that
        exists for catalogs past CA_MATERIALIZE_MAX."""
        best1, _, _ = self.best_two(jnp.asarray(slots))
        return float(jnp.sum(self.lam * best1))


def random_slots(inst: Instance, rng: np.random.Generator) -> np.ndarray:
    """Random initial allocation (LocalSwap/NetDuel start state, §3.3)."""
    return rng.integers(0, inst.cat.n, size=inst.net.total_slots, dtype=np.int64)


def empty_slots(inst: Instance) -> np.ndarray:
    return np.full(inst.net.total_slots, -1, dtype=np.int64)
