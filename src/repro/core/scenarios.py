"""Icarus-style scenario plane: arbitrary graphs → :class:`CacheNetwork`.

The paper's placement machinery (eqs. (1)–(4), GREEDY/LOCALSWAP, the
device control plane) is defined for *any* network in which each
request (ingress i, object o) has a forwarding path with reach costs
h(i, j) — the solvers only ever see the (n_ingress, n_caches) H matrix
with +inf off-path entries. ``core/topology.py`` can construct just the
paper's chains/tandems/trees; this module generates the H matrix for
general graphs, the way Icarus generates experiment scenarios
(`icarus/scenarios/cacheplacement.py`):

1. **graph generators** — :func:`isp_like` (two-tier core/edge/leaf
   POP structure), :func:`scale_free` (Barabási–Albert preferential
   attachment), :func:`watts_strogatz` (rewired ring lattice). All
   return a :class:`Graph`: a symmetric (V, V) link-delay matrix with
   +inf for absent links, repaired to a single connected component.
2. **batched shortest paths** — :func:`floyd_warshall` (one vectorized
   numpy relaxation per pivot, good for dense/small V) and
   :func:`batched_dijkstra` (all sources advanced in lockstep, one
   vectorized frontier relaxation per settled node — the right shape
   when only the ingress rows are needed). Both return the same metric
   closure; :func:`shortest_paths` dispatches.
3. **cache-budget placement** — :func:`assign_budget` splits a total
   slot budget over candidate nodes proportionally to
   degree/betweenness centrality (or uniformly), largest-remainder so
   the budget is met exactly (Icarus's ``iround`` discipline).
4. **network emission** — :func:`build_scenario` picks ingress (lowest
   degree — the receivers sit at the network edge) and repository
   (highest degree) nodes, routes every ingress to the repository along
   its shortest path, and emits the existing ``CacheNetwork`` contract:
   ``H[i, j] = dist(i, cache_j)`` when cache_j lies on ingress i's
   forwarding path, +inf otherwise (the paper's routing constraint),
   ``h_repo[i] = dist(i, repository)``. Everything downstream —
   ``objective.Instance``, ``DeviceInstance``, GREEDY/LOCALSWAP, the
   NETDUEL plane, ``warmstart.classify_topology`` (which returns None
   on irreducible graphs and falls through to the discrete solvers) —
   consumes the result unchanged.

The on-path *strategy* layer that serves requests over these networks
online (LCE/LCD/ProbCache/SIM-LRU/RND-LRU) lives in
``core/routing.py``; benchmarks/graphs_bench.py compares it against
paper-GREEDY placement on the same traces.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.topology import CacheNetwork

INF = np.inf


# ------------------------------------------------------------------ graphs
@dataclasses.dataclass(frozen=True)
class Graph:
    """An undirected weighted graph: ``adj[u, v]`` is the link delay
    (symmetric, +inf = no link, 0 on the diagonal)."""
    adj: np.ndarray
    name: str = "graph"

    @property
    def n_nodes(self) -> int:
        return self.adj.shape[0]

    def degrees(self) -> np.ndarray:
        """(V,) link count per node (unweighted degree)."""
        return np.sum(np.isfinite(self.adj) & (self.adj > 0), axis=1)


def _empty_adj(n: int) -> np.ndarray:
    adj = np.full((n, n), INF, dtype=np.float64)
    np.fill_diagonal(adj, 0.0)
    return adj


def _add_edge(adj: np.ndarray, u: int, v: int, w: float) -> None:
    if u == v:
        return
    adj[u, v] = adj[v, u] = min(adj[u, v], float(w))


def _delay(rng: np.random.Generator, delay: tuple[float, float]) -> float:
    lo, hi = delay
    return float(rng.uniform(lo, hi))


def _connect_components(adj: np.ndarray, rng: np.random.Generator,
                        delay: tuple[float, float]) -> None:
    """Repair connectivity in place: link each extra component's
    lowest-id node to the main component (deterministic given rng)."""
    n = adj.shape[0]
    comp = np.full(n, -1, np.int64)
    c = 0
    for s in range(n):
        if comp[s] >= 0:
            continue
        stack = [s]
        comp[s] = c
        while stack:
            u = stack.pop()
            for v in np.nonzero(np.isfinite(adj[u]))[0]:
                if comp[v] < 0:
                    comp[v] = c
                    stack.append(int(v))
        c += 1
    for cc in range(1, c):
        u = int(np.nonzero(comp == cc)[0][0])
        v = int(rng.integers(0, np.sum(comp == 0)))
        v = int(np.nonzero(comp == 0)[0][v])
        _add_edge(adj, u, v, _delay(rng, delay))


def scale_free(n: int = 48, m: int = 2, seed: int = 0,
               delay: tuple[float, float] = (1.0, 2.0)) -> Graph:
    """Barabási–Albert preferential attachment: each new node links to
    ``m`` distinct existing nodes chosen ∝ degree."""
    assert n > m >= 1
    rng = np.random.default_rng(seed)
    adj = _empty_adj(n)
    # seed clique over the first m+1 nodes, then preferential attachment
    targets = []                    # degree-weighted repeat list
    for u in range(m + 1):
        for v in range(u + 1, m + 1):
            _add_edge(adj, u, v, _delay(rng, delay))
            targets += [u, v]
    for u in range(m + 1, n):
        chosen: set[int] = set()
        while len(chosen) < m:
            chosen.add(int(targets[rng.integers(0, len(targets))]))
        for v in chosen:
            _add_edge(adj, u, v, _delay(rng, delay))
            targets += [u, v]
    return Graph(adj=adj, name=f"ba_n{n}_m{m}")


def watts_strogatz(n: int = 40, k: int = 4, beta: float = 0.3,
                   seed: int = 0,
                   delay: tuple[float, float] = (1.0, 2.0)) -> Graph:
    """Watts–Strogatz small world: ring lattice (each node linked to its
    k/2 nearest neighbours per side), each edge rewired with prob β;
    connectivity repaired afterwards."""
    assert k % 2 == 0 and 0 < k < n
    rng = np.random.default_rng(seed)
    adj = _empty_adj(n)
    for u in range(n):
        for off in range(1, k // 2 + 1):
            v = (u + off) % n
            if rng.random() < beta:
                w = int(rng.integers(0, n))
                tries = 0
                while (w == u or np.isfinite(adj[u, w])) and tries < 8:
                    w = int(rng.integers(0, n))
                    tries += 1
                v = v if (w == u or np.isfinite(adj[u, w])) else w
            _add_edge(adj, u, v, _delay(rng, delay))
    _connect_components(adj, rng, delay)
    return Graph(adj=adj, name=f"ws_n{n}_k{k}")


def isp_like(n_core: int = 6, n_edge: int = 12, n_leaf: int = 24,
             seed: int = 0,
             core_delay: tuple[float, float] = (0.5, 1.0),
             edge_delay: tuple[float, float] = (1.0, 2.0),
             leaf_delay: tuple[float, float] = (2.0, 4.0)) -> Graph:
    """Two-tier ISP-like POP structure: a core ring with chord links
    (fast), edge routers dual-homed onto random cores, access leaves
    single-homed onto edge routers (slow last mile). Node order:
    cores [0, n_core), edges [n_core, n_core+n_edge), leaves after."""
    rng = np.random.default_rng(seed)
    n = n_core + n_edge + n_leaf
    adj = _empty_adj(n)
    for u in range(n_core):                        # core ring + chords
        _add_edge(adj, u, (u + 1) % n_core, _delay(rng, core_delay))
    for u in range(n_core):
        for v in range(u + 2, n_core):
            if rng.random() < 0.3:
                _add_edge(adj, u, v, _delay(rng, core_delay))
    for e in range(n_edge):                        # dual-homed edges
        u = n_core + e
        homes = rng.choice(n_core, size=min(2, n_core), replace=False)
        for v in homes:
            _add_edge(adj, u, int(v), _delay(rng, edge_delay))
    for l in range(n_leaf):                        # single-homed leaves
        u = n_core + n_edge + l
        v = n_core + int(rng.integers(0, n_edge))
        _add_edge(adj, u, v, _delay(rng, leaf_delay))
    return Graph(adj=adj, name=f"isp_c{n_core}_e{n_edge}_l{n_leaf}")


GENERATORS = {"isp": isp_like, "scale_free": scale_free,
              "watts_strogatz": watts_strogatz}


# ----------------------------------------------------------- shortest paths
def floyd_warshall(adj: np.ndarray) -> np.ndarray:
    """All-pairs shortest path distances, one vectorized (V, V)
    relaxation per pivot node."""
    d = np.array(adj, dtype=np.float64)
    for k in range(d.shape[0]):
        np.minimum(d, d[:, k, None] + d[None, k, :], out=d)
    return d


def batched_dijkstra(adj: np.ndarray,
                     sources: np.ndarray | Sequence[int]) -> np.ndarray:
    """(S, V) shortest-path distances from ``sources``: all sources
    advance in lockstep — each of the V settle rounds picks every
    source's nearest unvisited node at once and relaxes all S frontiers
    with one broadcast minimum (no per-edge Python loop)."""
    src = np.asarray(sources, np.int64)
    V = adj.shape[0]
    S = src.shape[0]
    dist = np.full((S, V), INF, dtype=np.float64)
    dist[np.arange(S), src] = 0.0
    done = np.zeros((S, V), dtype=bool)
    for _ in range(V):
        cand = np.where(done, INF, dist)                  # (S, V)
        u = np.argmin(cand, axis=1)                       # (S,)
        still = np.isfinite(cand[np.arange(S), u])
        done[np.arange(S), u] |= still
        # relax every source's frontier row in one broadcast
        du = dist[np.arange(S), u][:, None]               # (S, 1)
        relax = np.where(still[:, None], du + adj[u, :], INF)
        np.minimum(dist, relax, out=dist)
    return dist


def shortest_paths(adj: np.ndarray,
                   sources: np.ndarray | Sequence[int] | None = None,
                   method: str = "auto") -> np.ndarray:
    """Distance rows for ``sources`` (all nodes when None). ``method``:
    "fw" | "dijkstra" | "auto" (Dijkstra when only a few source rows
    are needed, Floyd–Warshall for the full closure)."""
    V = adj.shape[0]
    if sources is None:
        sources = np.arange(V)
    src = np.asarray(sources, np.int64)
    if method == "auto":
        method = "dijkstra" if src.shape[0] * 4 < V else "fw"
    if method == "fw":
        return floyd_warshall(adj)[src]
    if method == "dijkstra":
        return batched_dijkstra(adj, src)
    raise ValueError(f"unknown method {method!r}")


def route(adj: np.ndarray, dist_to_dst: np.ndarray, src: int,
          dst: int) -> list[int]:
    """Shortest path src → dst as a node list, reconstructed by greedy
    descent on ``dist_to_dst`` (= dist[:, dst]): from u, step to the
    neighbour minimizing link + remaining distance (ties → lowest node
    id, so routes are deterministic)."""
    path = [int(src)]
    u = int(src)
    while u != dst:
        nxt = adj[u] + dist_to_dst
        nxt[u] = INF         # zero diagonal: staying put ties the
        #                      optimal hop and argmin would pick it
        v = int(np.argmin(nxt))
        if not np.isfinite(nxt[v]):
            raise ValueError(f"no route from {src} to {dst}")
        path.append(v)
        u = v
    return path


# --------------------------------------------------------------- centrality
def degree_centrality(g: Graph) -> np.ndarray:
    return g.degrees().astype(np.float64)


def betweenness_centrality(g: Graph) -> np.ndarray:
    """Weighted betweenness (Brandes): per-source Dijkstra with
    predecessor lists + the standard dependency back-accumulation."""
    adj = g.adj
    V = adj.shape[0]
    bc = np.zeros(V, dtype=np.float64)
    nbrs = [np.nonzero(np.isfinite(adj[u]) & (np.arange(V) != u))[0]
            for u in range(V)]
    for s in range(V):
        dist = np.full(V, INF)
        sigma = np.zeros(V)
        preds: list[list[int]] = [[] for _ in range(V)]
        dist[s] = 0.0
        sigma[s] = 1.0
        done = np.zeros(V, dtype=bool)
        order = []
        for _ in range(V):
            cand = np.where(done, INF, dist)
            u = int(np.argmin(cand))
            if not np.isfinite(cand[u]):
                break
            done[u] = True
            order.append(u)
            for v in nbrs[u]:
                alt = dist[u] + adj[u, v]
                if alt < dist[v] - 1e-12:
                    dist[v] = alt
                    sigma[v] = sigma[u]
                    preds[v] = [u]
                elif abs(alt - dist[v]) <= 1e-12 and not done[v]:
                    sigma[v] += sigma[u]
                    preds[v].append(u)
        delta = np.zeros(V)
        for w in reversed(order):
            for u in preds[w]:
                delta[u] += sigma[u] / sigma[w] * (1.0 + delta[w])
            if w != s:
                bc[w] += delta[w]
    return bc / 2.0                      # undirected: each pair counted twice


CENTRALITIES = {"uniform": None, "degree": degree_centrality,
                "betweenness": betweenness_centrality}


def assign_budget(scores: np.ndarray, budget: int) -> np.ndarray:
    """Split ``budget`` slots over candidates ∝ ``scores`` (uniform when
    all-zero), largest remainder so the total is met exactly."""
    scores = np.asarray(scores, np.float64)
    n = scores.shape[0]
    assert budget >= 0 and n > 0
    if scores.sum() <= 0.0:
        scores = np.ones(n)
    frac = scores / scores.sum() * budget
    caps = np.floor(frac).astype(np.int64)
    short = budget - int(caps.sum())
    if short > 0:
        order = np.argsort(-(frac - caps), kind="stable")
        caps[order[:short]] += 1
    return caps


# ----------------------------------------------------------------- scenario
@dataclasses.dataclass(frozen=True)
class Scenario:
    """A generated experiment scenario: the graph, its metric closure,
    and the emitted :class:`CacheNetwork` the solvers consume.

    ``cache_nodes[j]`` is the graph node hosting cache j;
    ``paths[i]`` the full node sequence of ingress i's forwarding path
    (ingress → … → repository)."""
    graph: Graph
    net: CacheNetwork
    dist: np.ndarray                   # (V, V) metric closure
    cache_nodes: np.ndarray            # (n_caches,)
    ingress_nodes: np.ndarray          # (n_ingress,)
    repo_node: int
    paths: tuple                       # tuple[tuple[int, ...], ...]
    placement: str = "degree"

    @property
    def name(self) -> str:
        return self.net.name


def build_scenario(g: Graph, cache_budget: int, placement: str = "degree",
                   n_ingress: int = 8, repo_node: int | None = None,
                   ingress_nodes: np.ndarray | None = None) -> Scenario:
    """Emit the :class:`CacheNetwork` for ``g``.

    Ingress nodes default to the ``n_ingress`` lowest-degree nodes
    (receivers live at the network edge, as in Icarus topologies), the
    repository to the highest-degree non-ingress node (the best-connected
    POP hosts the origin). Candidate cache nodes are every other node;
    ``cache_budget`` total slots are split over them by ``placement``
    centrality and nodes awarded zero slots are dropped from the cache
    list. H follows the paper's on-path routing constraint.
    """
    if placement not in CENTRALITIES:
        raise ValueError(f"unknown placement {placement!r}; "
                         f"expected one of {sorted(CENTRALITIES)}")
    V = g.n_nodes
    deg = g.degrees()
    if ingress_nodes is None:
        # lowest degree first, ties to the lowest node id
        order = np.lexsort((np.arange(V), deg))
        ingress_nodes = np.sort(order[:n_ingress])
    ingress_nodes = np.asarray(ingress_nodes, np.int64)
    if repo_node is None:
        mask = np.ones(V, dtype=bool)
        mask[ingress_nodes] = False
        cand = np.nonzero(mask)[0]
        repo_node = int(cand[np.argmax(deg[cand])])
    if repo_node in set(ingress_nodes.tolist()):
        raise ValueError("repository node cannot also be an ingress")

    candidates = np.array([v for v in range(V)
                           if v != repo_node
                           and v not in set(ingress_nodes.tolist())],
                          np.int64)
    cent_fn = CENTRALITIES[placement]
    scores = (np.ones(candidates.shape[0]) if cent_fn is None
              else cent_fn(g)[candidates])
    caps = assign_budget(scores, cache_budget)

    dist = floyd_warshall(g.adj)
    paths = tuple(tuple(route(g.adj, dist[:, repo_node], int(i), repo_node))
                  for i in ingress_nodes)

    # coverage repair: centrality splits can leave an ingress whose whole
    # forwarding path got zero slots (an all-inf H row — the solvers then
    # can't serve that ingress from any cache). Move one slot from the
    # largest cache to the best-scoring intermediate node of each
    # uncovered path; a direct ingress→repo edge has no intermediates
    # and legitimately stays repo-only.
    cand_idx = {int(v): c for c, v in enumerate(candidates)}
    for p in paths:
        mid = [cand_idx[v] for v in p[1:-1] if v in cand_idx]
        if not mid or any(caps[c] > 0 for c in mid):
            continue
        donor = int(np.argmax(caps))
        if caps[donor] <= 1:
            continue                    # nothing to spare
        take = mid[int(np.argmax(scores[mid]))]
        caps[donor] -= 1
        caps[take] += 1

    keep = caps > 0
    cache_nodes = candidates[keep]
    caps = caps[keep]
    node_to_cache = {int(v): j for j, v in enumerate(cache_nodes)}
    H = np.full((ingress_nodes.shape[0], cache_nodes.shape[0]), np.inf,
                dtype=np.float32)
    for i, p in enumerate(paths):
        for v in p:
            j = node_to_cache.get(int(v))
            if j is not None:
                H[i, j] = dist[ingress_nodes[i], v]
    h_repo = dist[ingress_nodes, repo_node].astype(np.float32)
    net = CacheNetwork(
        n_caches=cache_nodes.shape[0], capacities=caps.astype(np.int64),
        ingress=ingress_nodes, H=H, h_repo=h_repo,
        name=f"{g.name}_{placement}")
    return Scenario(graph=g, net=net, dist=dist, cache_nodes=cache_nodes,
                    ingress_nodes=ingress_nodes, repo_node=int(repo_node),
                    paths=paths, placement=placement)


def scenario(family: str, cache_budget: int = 64,
             placement: str = "degree", n_ingress: int = 8, seed: int = 0,
             **graph_kw) -> Scenario:
    """One-call helper: generate the ``family`` graph and emit its
    network. ``family`` ∈ {"isp", "scale_free", "watts_strogatz"}."""
    if family not in GENERATORS:
        raise ValueError(f"unknown family {family!r}; "
                         f"expected one of {sorted(GENERATORS)}")
    g = GENERATORS[family](seed=seed, **graph_kw)
    return build_scenario(g, cache_budget=cache_budget,
                          placement=placement, n_ingress=n_ingress)
