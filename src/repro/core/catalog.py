"""Object catalogs: discrete grids and continuous R^p embedding spaces.

The paper's two instances (§2):

* **grid** — §6.1: objects on the points of an L×L grid with the norm-1
  (hop) metric and C_a(x,y) = d(x,y)^γ.
* **embeddings** — §6.2: objects embedded in R^d (d=100 for the Amazon
  trace), Euclidean distance as dissimilarity.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import costs


@dataclasses.dataclass(frozen=True)
class Catalog:
    """A finite catalog of objects with coordinates in R^p.

    ``coords`` are float32 (n_objects, p). The request space is the
    catalog itself in the discrete setting (O_R == O), which is how the
    paper's experiments are set up.
    """
    coords: np.ndarray
    metric: str = "l1"
    gamma: float = 1.0
    name: str = "catalog"

    @property
    def n(self) -> int:
        return self.coords.shape[0]

    @property
    def dim(self) -> int:
        return self.coords.shape[1]

    def ca(self, rows: np.ndarray | None = None,
           cols: np.ndarray | None = None) -> np.ndarray:
        """C_a block between object subsets (default: full matrix)."""
        x = self.coords if rows is None else self.coords[rows]
        y = self.coords if cols is None else self.coords[cols]
        return costs.approx_cost_np(x, y, self.metric, self.gamma)


def grid(L: int = 100, gamma: float = 1.0) -> Catalog:
    """L×L grid catalog with norm-1 metric (paper §6.1; 10000 objects at L=100)."""
    xs, ys = np.meshgrid(np.arange(L), np.arange(L), indexing="ij")
    coords = np.stack([xs.ravel(), ys.ravel()], axis=-1).astype(np.float32)
    return Catalog(coords=coords, metric="l1", gamma=gamma, name=f"grid{L}")


def embedding_catalog(n: int, dim: int, seed: int = 0, radial: str = "decreasing",
                      gamma: float = 1.0) -> Catalog:
    """Synthetic R^dim catalog emulating the Amazon/McAuley embeddings (§6.2).

    Directions are uniform on the sphere; radii are drawn so that the
    request density within spherical shells *decreases* with distance from
    the barycenter, matching the paper's Fig 8 observation. The scale is
    chosen so typical inter-item distances are O(100), comparable to the
    paper's h = 150 setting.
    """
    rng = np.random.default_rng(seed)
    dirs = rng.standard_normal((n, dim)).astype(np.float32)
    dirs /= np.linalg.norm(dirs, axis=1, keepdims=True)
    if radial == "decreasing":
        radii = rng.gamma(shape=2.0, scale=120.0, size=n).astype(np.float32)
    elif radial == "uniform_ball":
        radii = 400.0 * rng.random(n).astype(np.float32) ** (1.0 / dim)
    else:
        raise ValueError(radial)
    coords = dirs * radii[:, None]
    return Catalog(coords=coords, metric="l2", gamma=gamma,
                   name=f"emb{n}d{dim}")
