"""Analytic hit-rate plane: the Che characteristic-time approximation
generalized to *similarity* caches, composed along forwarding paths.

Every hit-rate number the repo had so far came from simulation — a full
trace replay through ``core/routing.StrategyPlane`` or the serving
engine. This module predicts the same quantities in closed form (one
vectorized fixed point, milliseconds even at 10⁶ objects), following
"Computing the Hit Rate of Similarity Caching" (arXiv 2209.03174) and
the classic Che/TTL toolbox (Icarus ``tools/cacheperf.py``):

**Classic Che (one LRU cache).** Under IRM demand λ, a cache of
capacity ``C`` behaves as if every content were cached for a fixed
*characteristic time* T after its last request: the occupancy
probability is π_o = 1 − exp(−λ_o·T) and T solves Σ_o π_o = C.

**Similarity generalization (SIM-LRU / RND-LRU).** A stored key o
serves any request o′ in its *similarity ball* B(o) = {o′ :
C_a(o, o′) ≤ θ} — with probability q_{o′o} = 1 for SIM-LRU and
q_{o′o} = clamp(1 − C_a/θ, 0, 1) for RND-LRU. Two changes fall out:

* *timer resets are exclusive*: a stored key's LRU position is
  refreshed only by the requests it actually SERVES, and serving picks
  the nearest cached ball member that answers. With each ball sorted
  ascending by C_a and cache-state independence across objects (the
  Che ansatz), request o′ is served by member m with probability
  s_m = π_m·q_m·Π_{l<m}(1 − π_l·q_l), so the reset rate of a stored
  key o is λ̃_o = Σ_{o′: o∈B(o′)} R(o′)·q·Π_{nearer l}(1 − π_l·q_l).
  (The simpler aggregate λ̃_o = Σ q·R credits one request as a reset
  to every cached member at once and under-predicts SIM-LRU badly as
  soon as balls overlap; for SIM-LRU a miss also re-inserts the exact
  object on the whole path, which the q=1 self term carries.)
* *hits are unions*: o′ hits if ANY ball member is cached and answers,
  h_{o′} = Σ_m s_m = 1 − Π_{o∈B(o′)} (1 − π_o·q_{o′o}).

The characteristic-time constraint Σ_o π_o = C is kept per cache and
closes the fixed point: occupancies π = 1 − e^{−λ̃·T_C} feed the serve
shares, which feed the reset rates, which re-solve T_C.

**Network composition.** Caches are composed along the same
per-ingress forwarding paths ``core/routing.py`` serves (finite
``H[i, ·]`` entries in ascending reach-cost order): the cache at path
position p sees the *miss stream* of the positions before it,
R_{i,p}(o) = λ_i(o)·Π_{p′<p}(1 − h_{i,p′}(o)) — the standard
multi-cache (a-NET) thinning — and a cache shared by several ingresses
sums their thinned streams. Eligibility mirrors ``serve_one``: a hit
at cache j for ingress i additionally needs C_a < h_repo[i] − H[i, j],
so each (ingress, cache) pair prunes the ball at its repo-cost slack.
The whole system is solved by damped fixed-point sweeps.

**Validity regime.** The approximation is accurate when (Che) demand
is IRM with many objects relative to cache size, and (similarity) the
balls are small relative to cache capacity with moderate overlap — the
regime the validation bench (benchmarks/hitrate_bench.py) pins: on
Zipf demand over the PR 8 graph families the predicted SIM-LRU /
RND-LRU hit rates track measured ``StrategyPlane`` replays within the
tolerance recorded in results/bench/hitrate.json (≤ 5% absolute).
Known biases outside it: large overlapping balls overestimate the
reset aggregate (T compensates only on average), and serving in
``routing.py`` picks the cost-*minimizing* on-path cache while the
model serves at the first eligible position — they agree exactly for
exact-hit (θ=0) demand and diverge slowly with θ.

**Ball enumeration.** Balls are enumerated either exactly (blocked
O×O distance pass — fine to ~10⁴ objects) or through the existing LSH
candidate machinery of ``kernels/knn/lsh.py`` (PR 3): per-object
candidates from SimHash multi-probe tables, exact C_a filter on the
candidates only — sublinear per object, which is what makes the 10⁶
object path feasible (the HITRATE_BENCH_FULL gate). LSH enumeration
can miss ball members (recall < 1); ``SimilarityBalls.mean_size`` /
``truncated`` report what was kept.

The serving engine uses the same plane as a *surrogate cost oracle*
(``surrogate_cost``): ``serve/engine.request_refresh`` prices the
observed-demand drift analytically (exact-hit balls — the θ=0 model is
demand-shape-only and needs no geometry) and skips the full device
placement solve when the predicted cost moved less than
``EngineConfig.refresh_min_gain``.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costs
from repro.core.topology import CacheNetwork

__all__ = ["SimilarityBalls", "HitRatePrediction", "similarity_balls",
           "exact_hit_balls", "solve_characteristic_time",
           "predict_hitrates", "surrogate_cost"]


# ======================================================================
# similarity balls
# ======================================================================
@dataclasses.dataclass(frozen=True)
class SimilarityBalls:
    """Padded neighbor structure of one catalog at one threshold θ.

    ``idx[o]`` holds the objects o′ with C_a(o, o′) ≤ θ (always
    including o itself, first), padded with ``n_objects``; ``q`` is the
    serve-probability weight q_{o′o} (SIM-LRU: 1 inside the ball;
    RND-LRU: 1 − C_a/θ), exactly 0 on padding; ``dist`` the C_a values
    (0 on padding). C_a is symmetric, so one structure serves both
    directions: "who can serve o" and "whom o refreshes".
    """
    idx: np.ndarray           # (O, M) int32, padded with n_objects
    q: np.ndarray             # (O, M) f32, 0 on padding
    dist: np.ndarray          # (O, M) f32 C_a, 0 on padding
    n_objects: int
    theta: float
    truncated: int = 0        # members dropped by max_ball

    @property
    def max_size(self) -> int:
        return self.idx.shape[1]

    @property
    def sizes(self) -> np.ndarray:
        return (self.q > 0.0).sum(axis=1)

    @property
    def mean_size(self) -> float:
        return float(self.sizes.mean())


def exact_hit_balls(n_objects: int) -> SimilarityBalls:
    """The degenerate θ=0 structure: every ball is {o} with q=1 — the
    classic Che model, no geometry needed (the engine surrogate's
    default)."""
    idx = np.arange(n_objects, dtype=np.int32)[:, None]
    return SimilarityBalls(idx=idx,
                           q=np.ones((n_objects, 1), np.float32),
                           dist=np.zeros((n_objects, 1), np.float32),
                           n_objects=n_objects, theta=0.0)


def _q_weights(dist: np.ndarray, theta: float, q_mode: str) -> np.ndarray:
    if q_mode == "hard":                       # SIM-LRU admission
        return (dist <= theta).astype(np.float32)
    if q_mode == "rnd":                        # RND-LRU serve probability
        return np.clip(1.0 - dist / max(theta, 1e-300), 0.0, 1.0) \
            .astype(np.float32)
    raise ValueError(f"unknown q_mode {q_mode!r} (expected 'hard'|'rnd')")


def _pack_rows(rows_idx: list, rows_d: list, n: int, theta: float,
               q_mode: str, max_ball: int | None) -> SimilarityBalls:
    """Pad per-object (indices, distances) lists into the rectangular
    structure; each row keeps its nearest ``max_ball`` members (self
    first, then ascending C_a — truncation drops the farthest, i.e. the
    lowest-q members first)."""
    sizes = np.fromiter((len(r) for r in rows_idx), np.int64, n)
    m = int(sizes.max()) if n else 1
    truncated = 0
    if max_ball is not None and m > max_ball:
        truncated = int(np.maximum(sizes - max_ball, 0).sum())
        m = max_ball
    m = max(m, 1)
    idx = np.full((n, m), n, np.int32)
    dist = np.zeros((n, m), np.float32)
    for o in range(n):
        ri = np.asarray(rows_idx[o], np.int32)
        rd = np.asarray(rows_d[o], np.float32)
        order = np.argsort(rd, kind="stable")       # self (d=0, first) stays
        ri, rd = ri[order][:m], rd[order][:m]
        idx[o, :ri.size] = ri
        dist[o, :ri.size] = rd
    q = _q_weights(dist, theta, q_mode)
    q[idx >= n] = 0.0
    return SimilarityBalls(idx=idx, q=q, dist=dist, n_objects=n,
                           theta=float(theta), truncated=truncated)


def _block_ca_np(x: np.ndarray, y: np.ndarray, metric: str,
                 gamma: float) -> np.ndarray:
    """(B, O) exact C_a in host f64 via direct differences — the same
    arithmetic as ``routing.StrategyPlane._ca``, NOT the MXU Gram form
    of ``costs.approx_cost_np`` whose |x|²+|y|²−2x·y cancellation
    carries ~|x|²·eps absolute noise (a nonzero self-distance would
    corrupt every ball at small θ)."""
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    out = np.empty((x.shape[0], y.shape[0]), np.float64)
    for s in range(0, y.shape[0], 2048):       # bound the (B, Y, D) temp
        ys = y[s:s + 2048]
        if metric == "l1":
            d = np.abs(x[:, None, :] - ys[None, :, :]).sum(axis=-1)
        elif metric in ("l2", "l2sq"):
            d2 = ((x[:, None, :] - ys[None, :, :]) ** 2).sum(axis=-1)
            d = d2 if metric == "l2sq" else np.sqrt(d2)
        else:
            raise ValueError(f"unknown metric {metric!r}; "
                             f"expected one of {costs.METRICS}")
        out[:, s:s + 2048] = d if gamma == 1.0 else d ** gamma
    return out


@functools.partial(jax.jit, static_argnames=("metric", "gamma"))
def _cand_ca(qs: jax.Array, cs: jax.Array, metric: str,
             gamma: float) -> jax.Array:
    """(B, P) exact C_a between query rows and their gathered candidate
    coordinate rows (the LSH path's exact filter)."""
    if metric == "l1":
        d = jnp.sum(jnp.abs(cs - qs[:, None, :]), axis=-1)
    else:
        d2 = jnp.sum((cs - qs[:, None, :]) ** 2, axis=-1)
        d = d2 if metric == "l2sq" else jnp.sqrt(d2)
    return d if gamma == 1.0 else d ** gamma


def similarity_balls(coords: np.ndarray, theta: float, metric: str = "l2",
                     gamma: float = 1.0, q_mode: str = "hard",
                     mode: str = "auto", policy=None, block: int = 1024,
                     max_ball: int | None = None,
                     seed: int = 0) -> SimilarityBalls:
    """Enumerate B(o) = {o′ : C_a(o, o′) ≤ θ} for every catalog object.

    ``mode='exact'`` runs a blocked O×O distance pass (exhaustive —
    right up to ~10⁴ objects); ``mode='lsh'`` routes each block through
    a :class:`~repro.kernels.knn.lsh.SimHashPolicy` candidate matrix
    and exact-filters only the candidates — sublinear per object, the
    10⁶-key path. ``mode='auto'`` picks exact below 2·10⁴ objects.
    ``q_mode`` sets the stored weights: 'hard' (SIM-LRU indicator) or
    'rnd' (RND-LRU 1 − C_a/θ). θ ≤ 0 degenerates to exact-hit balls.
    """
    coords = np.asarray(coords, np.float32)
    n = coords.shape[0]
    if theta is None or theta <= 0.0:
        return exact_hit_balls(n)
    if mode == "auto":
        mode = "exact" if n <= 20_000 else "lsh"

    rows_idx: list = [None] * n
    rows_d: list = [None] * n
    if mode == "exact":
        for s in range(0, n, block):
            ca = _block_ca_np(coords[s:s + block], coords, metric, gamma)
            for b in range(ca.shape[0]):
                keep = np.nonzero(ca[b] <= theta)[0]
                rows_idx[s + b] = keep
                rows_d[s + b] = ca[b, keep]
    elif mode == "lsh":
        from repro.kernels.knn import lsh as lsh_api
        if policy is None:
            policy = lsh_api.SimHashPolicy(seed=seed)
        tables = policy.build(coords, np.ones(n, bool))
        proj = jnp.asarray(tables.proj)
        buckets = jnp.asarray(tables.buckets)
        cj = jnp.asarray(coords)
        for s in range(0, n, block):
            qs = cj[s:s + block]
            cand = lsh_api.candidate_matrix(tables.kind, proj, buckets,
                                            qs, tables.n_probes)
            safe = jnp.where(cand >= 0, cand, 0)
            ca = _cand_ca(qs, cj[safe], metric, gamma)
            ca = np.asarray(jnp.where(cand >= 0, ca, np.inf))
            cand = np.asarray(cand)
            for b in range(ca.shape[0]):
                o = s + b
                keep = np.nonzero(ca[b] <= theta)[0]
                ci, cd = cand[b, keep], ca[b, keep]
                ci, u = np.unique(ci, return_index=True)
                cd = cd[u]
                if o not in ci:                 # self is always a member
                    ci = np.concatenate([[o], ci])
                    cd = np.concatenate([[0.0], cd])
                else:
                    cd[ci == o] = 0.0
                rows_idx[o], rows_d[o] = ci, cd
    else:
        raise ValueError(f"unknown mode {mode!r} "
                         "(expected 'exact'|'lsh'|'auto')")
    return _pack_rows(rows_idx, rows_d, n, theta, q_mode, max_ball)


# ======================================================================
# characteristic-time solver
# ======================================================================
def _occupancy_np(mu: np.ndarray, nu: np.ndarray, T: float) -> np.ndarray:
    """Host f64 stationary occupancy of the two-rate renewal model:

        π = expm1(μT) / (expm1(μT) + μ/ν)

    — a key enters at rate ν when absent (a global path miss inserts
    it) and is evicted T after its last *serve* (rate μ while present);
    E[busy] = (e^{μT} − 1)/μ against E[idle] = 1/ν gives the form
    above, which is EXACTLY classic Che π = 1 − e^{−λT} when μ = ν = λ
    (plain LRU: every request both inserts and refreshes).
    """
    mu = np.maximum(np.asarray(mu, np.float64), 1e-300)
    nu = np.asarray(nu, np.float64)
    if not np.isfinite(T):
        return (nu > 0.0).astype(np.float64)
    em = np.expm1(np.minimum(mu * T, 700.0))
    pi = em / (em + mu / np.maximum(nu, 1e-300))
    return np.where(nu > 0.0, pi, 0.0)


@functools.partial(jax.jit, static_argnames=("n_iters",))
def _solve_tc(mu: jax.Array, nu: jax.Array, capacity: jax.Array,
              n_iters: int = 64) -> jax.Array:
    """Vectorized Che fixed point: the largest T with Σ_o π_o(T) ≤ C
    per cache row, for the two-rate occupancy of :func:`_occupancy_np`
    (μ = refresh rate while present, ν = entry rate while absent;
    μ = ν recovers the classic Σ (1 − e^{−λT}) = C).

    ``mu``/``nu`` are (J, O), ``capacity`` (J,); runs in the ambient
    jnp float dtype (f32 unless x64 is enabled — plenty for a capacity
    constraint, and the host-side composition stays f64). Σπ(T) is
    monotone increasing from 0 to the number of ν-positive objects, so
    bisection after doubling brackets the root; a capacity at or above
    that count has no finite root and returns +inf (π → 1 for every
    entering object — the cache holds everything it ever sees).
    """
    ftype = jnp.result_type(float)
    mu = jnp.maximum(mu.astype(ftype), 1e-30)
    nu = jnp.asarray(nu).astype(ftype)
    cap = jnp.asarray(capacity).astype(ftype)
    n_pos = jnp.sum(nu > 0.0, axis=1).astype(ftype)
    # small-T slope: π ≈ νT, so the linear-regime guess is C/Σν
    total = jnp.sum(nu, axis=1)

    def occ(T):
        em = jnp.expm1(jnp.minimum(mu * T[:, None], 60.0))
        pi = em / (em + mu / jnp.maximum(nu, 1e-30))
        return jnp.sum(jnp.where(nu > 0.0, pi, 0.0), axis=1)

    # double from the linear-regime guess until f(hi) ≥ C (or give up
    # and report +inf — capacity not reachable)
    hi0 = cap / jnp.maximum(total, 1e-30)

    def dbl(_, hi):
        return jnp.where(occ(hi) < cap, hi * 4.0, hi)

    hi = jax.lax.fori_loop(0, 40, dbl, jnp.maximum(hi0, 1e-12))
    lo = jnp.zeros_like(hi)

    def bis(_, bounds):
        lo, hi = bounds
        mid = 0.5 * (lo + hi)
        below = occ(mid) < cap
        return jnp.where(below, mid, lo), jnp.where(below, hi, mid)

    lo, hi = jax.lax.fori_loop(0, n_iters, bis, (lo, hi))
    T = 0.5 * (lo + hi)
    T = jnp.where(cap >= n_pos, jnp.inf, T)      # holds everything
    return jnp.where(cap <= 0.0, 0.0, T)         # zero-capacity cache


def solve_characteristic_time(lam_eff: np.ndarray, capacities,
                              entry_rates: np.ndarray | None = None,
                              n_iters: int = 64) -> np.ndarray:
    """Che characteristic times T_C, one per cache.

    ``lam_eff`` — (J, O) or (O,) effective (timer-refresh) request
    rates; ``capacities`` — scalar or (J,) slot counts;
    ``entry_rates`` — optional (same shape) insertion rates when an
    object enters the cache on a different stream than it is refreshed
    by (similarity caches insert only on global path misses); defaults
    to ``lam_eff``, which is the classic Che solve
    Σ (1 − e^{−λT}) = C. Returns (J,) (or scalar for 1-D input) f64
    times; +inf when the cache can hold every requested object, 0.0
    for zero-capacity caches.
    """
    lam = np.asarray(lam_eff, np.float64)
    squeeze = lam.ndim == 1
    if squeeze:
        lam = lam[None, :]
    nu = lam if entry_rates is None else \
        np.asarray(entry_rates, np.float64).reshape(lam.shape)
    cap = np.broadcast_to(np.asarray(capacities, np.float64),
                          (lam.shape[0],))
    T = np.asarray(_solve_tc(jnp.asarray(lam), jnp.asarray(nu),
                             jnp.asarray(cap), n_iters=n_iters),
                   np.float64)
    return float(T[0]) if squeeze else T


@jax.jit
def _cache_pass(pi_row: jax.Array, rate_row: jax.Array, idx: jax.Array,
                q: jax.Array, dist: jax.Array):
    """One (ingress, cache) evaluation under *exclusive assignment*.

    A request o′ is served by the NEAREST cached ball member that
    answers (``routing`` serves cost-min; within one cache that is the
    distance argmin), so with the ball sorted ascending by C_a and
    cache-state independence, member m serves o′ with probability

        s_m(o′) = π_m · q_m · reach_m,   reach_m = Π_{l<m} (1 − π_l·q_l)

    (every nearer member is absent or refuses). Returns, per object:

    * ``h[o′]``        = Σ_m s_m — probability o′ is served here;
    * ``lam_eff[o]``   = Σ_{o′: o ∈ B(o′)} R(o′)·q·reach — the timer
      *reset* rate of stored key o: the requests it would serve given
      it is present (no π_o factor — Che's T solves for the sojourn of
      a key that IS in the cache), scatter-added over the balls;
    * ``cost_num[o′]`` = Σ_m s_m·C_a — E[C_a·1{served here}], the
      numerator of the per-request approximation cost;
    * ``s_self[o′]`` = π_{o′}·q_{o′o′} — the self term of h (0 when
      the slack mask removed it), used by the caller to condition the
      hit probability on o′ being absent (entry-rate correction).

    Exclusive assignment is what keeps overlapping balls honest: the
    plain aggregate λ̃ = Σ q·R credits one request as a reset to EVERY
    cached member and badly under-predicts SIM-LRU hit rates once
    balls overlap (each popular key's resets get split across its
    stored neighbors). ``pi_row`` is (O,); padded gathers (idx = O)
    read a trailing π = 0 / rate = 0.
    """
    pi_pad = jnp.concatenate([pi_row, jnp.zeros((1,), pi_row.dtype)])
    pq = jnp.minimum(pi_pad[idx] * q, 1.0 - 1e-6)      # (O, M)
    logs = jnp.log1p(-pq)
    reach = jnp.exp(jnp.cumsum(logs, axis=1) - logs)   # exclusive cumprod
    s = pq * reach
    h = jnp.sum(s, axis=1)
    cost_num = jnp.sum(s * dist, axis=1)
    contrib = rate_row[:, None] * q * reach
    lam_eff = jnp.zeros((pi_row.shape[0] + 1,), rate_row.dtype) \
        .at[idx].add(contrib)[:-1]
    n = pi_row.shape[0]
    s_self = jnp.where(idx[:, 0] == jnp.arange(n), s[:, 0], 0.0)
    return h, lam_eff, cost_num, s_self


# ======================================================================
# network fixed point
# ======================================================================
@dataclasses.dataclass(frozen=True)
class HitRatePrediction:
    """One solved analytic plane (all host f64 numpy).

    ``hit_prob[i, o]`` is the probability a request (o, ingress i) is
    served by *some* on-path cache; ``serve_prob[i, j, o]`` the
    probability it is served by cache j specifically (0 off-path);
    ``occupancy[j, o]`` the stationary π; ``T[j]`` the characteristic
    times. ``mean_cost`` prices eq. (1) on the predicted shares —
    E[C_a] from the exclusive-assignment serve shares plus reach and
    repo-miss costs.
    """
    T: np.ndarray              # (J,)
    occupancy: np.ndarray      # (J, O)
    hit_prob: np.ndarray       # (n_ingress, O)
    serve_prob: np.ndarray     # (n_ingress, J, O)
    hit_rate: float            # λ-weighted aggregate
    ingress_hit_rate: np.ndarray  # (n_ingress,)
    cache_hit_rate: np.ndarray    # (J,) share of all requests served there
    mean_cost: float           # predicted per-request cost, eq. (1)
    n_sweeps: int
    residual: float            # max |Δπ| of the last sweep

    @property
    def miss_rate(self) -> float:
        return 1.0 - self.hit_rate


def _paths(net: CacheNetwork) -> list[np.ndarray]:
    """Per-ingress forwarding paths — the exact rule of
    ``routing.StrategyPlane`` (finite H ascending, stable ties →
    lowest cache id)."""
    H = np.asarray(net.H, np.float64)
    out = []
    for i in range(net.n_ingress):
        fin = np.nonzero(np.isfinite(H[i]))[0]
        out.append(fin[np.argsort(H[i, fin], kind="stable")])
    return out


def predict_hitrates(net: CacheNetwork, lam: np.ndarray,
                     balls: SimilarityBalls, n_sweeps: int = 16,
                     damping: float = 0.6) -> HitRatePrediction:
    """Solve the similarity-Che fixed point over one cache network.

    ``lam`` — (n_ingress, O) request rates (any positive scale; costs
    and hit rates are per-request). ``balls`` — the catalog's
    similarity structure at the serving threshold (q already encodes
    SIM-LRU vs RND-LRU). Each sweep walks every ingress path once:
    per (ingress, cache) it evaluates the exclusive-assignment serve
    shares and reset rates from the current occupancies
    (:func:`_cache_pass`), thins the arrival stream, then re-solves
    T_C per cache and damps the occupancy update (``damping`` = 1 is
    undamped).
    """
    lam = np.asarray(lam, np.float64)
    n_ing, n_obj = lam.shape
    if balls.n_objects != n_obj:
        raise ValueError(f"balls were enumerated over {balls.n_objects} "
                         f"objects but lam has {n_obj}")
    J = net.n_caches
    H = np.asarray(net.H, np.float64)
    h_repo = np.asarray(net.h_repo, np.float64)
    caps = np.asarray(net.capacities, np.float64)
    paths = _paths(net)
    idx = jnp.asarray(balls.idx.astype(np.int32))
    dist = jnp.asarray(balls.dist)
    # per-(ingress, cache) ball pruning at the repo-cost slack: a hit at
    # (i, j) needs C_a < h_repo[i] − H[i, j] (routing.serve_one's
    # eligibility), so members past the slack can't serve or refresh
    q_ij: dict[tuple[int, int], jax.Array] = {}
    q_base = jnp.asarray(balls.q)
    for i in range(n_ing):
        for j in paths[i]:
            slack = h_repo[i] - H[i, j]
            q_ij[(i, int(j))] = q_base * (dist < slack)

    def sweep_passes(pi):
        """One path walk: per-cache refresh (μ) and entry (ν) rates
        plus per-(ingress, cache) serve shares and cost numerators."""
        lam_eff = np.zeros((J, n_obj))
        hs: dict[tuple[int, int], np.ndarray] = {}
        cn: dict[tuple[int, int], np.ndarray] = {}
        s0: dict[tuple[int, int], np.ndarray] = {}
        for i in range(n_ing):
            stream = lam[i].copy()
            for j in paths[i]:
                h, le, cnum, ss = _cache_pass(jnp.asarray(pi[j]),
                                              jnp.asarray(stream), idx,
                                              q_ij[(i, int(j))], dist)
                lam_eff[j] += np.asarray(le, np.float64)
                hs[(i, int(j))] = np.asarray(h, np.float64)
                cn[(i, int(j))] = np.asarray(cnum, np.float64)
                s0[(i, int(j))] = np.asarray(ss, np.float64)
                stream = stream * (1.0 - hs[(i, int(j))])
        # entry rates: SIM/RND-LRU insert o at every traversed cache
        # only on a GLOBAL path miss, so ν_j(o) is the end-of-path miss
        # stream — with the factor at j itself conditioned on o being
        # absent there (h | o absent = (h − π_o·q_oo)/(1 − π_o·q_oo))
        nu = np.zeros((J, n_obj))
        for i in range(n_ing):
            gm = lam[i].copy()
            for j in paths[i]:
                gm = gm * (1.0 - hs[(i, int(j))])
            for j in paths[i]:
                h, ss = hs[(i, int(j))], s0[(i, int(j))]
                h_abs = (h - ss) / np.maximum(1.0 - ss, 1e-12)
                corr = (1.0 - h_abs) / np.maximum(1.0 - h, 1e-12)
                nu[j] += gm * np.minimum(corr, 1e12)
        return lam_eff, nu, hs, cn

    pi = np.zeros((J, n_obj))
    residual = np.inf
    for _ in range(n_sweeps):
        lam_eff, nu, hs, cn = sweep_passes(pi)
        T = solve_characteristic_time(lam_eff, caps, entry_rates=nu)
        pi_new = np.zeros_like(pi)
        for j in range(J):
            if caps[j] <= 0:
                continue
            pi_new[j] = _occupancy_np(lam_eff[j], nu[j], T[j])
        residual = float(np.max(np.abs(pi_new - pi))) if J else 0.0
        pi = damping * pi_new + (1.0 - damping) * pi
        if residual < 1e-9:
            break

    # final serve/hit shares + predicted cost on the converged state
    lam_eff, nu, hs, cn = sweep_passes(pi)
    T = solve_characteristic_time(lam_eff, caps, entry_rates=nu)
    serve = np.zeros((n_ing, J, n_obj))
    hit = np.zeros((n_ing, n_obj))
    cost = 0.0
    total = lam.sum()
    for i in range(n_ing):
        stream = lam[i].copy()
        for j in paths[i]:
            h = hs[(i, int(j))]
            serve[i, j] = stream * h
            # E[C_a·1{served at j}] + the reach cost of served mass
            cost += float(np.sum(stream * cn[(i, int(j))])
                          + np.sum(serve[i, j]) * H[i, j])
            stream = stream * (1.0 - h)
        hit[i] = 1.0 - np.divide(stream, lam[i], out=np.zeros(n_obj),
                                 where=lam[i] > 0)
        cost += float(np.sum(stream) * h_repo[i])

    served_mass = serve.sum(axis=(0, 2))
    ing_mass = lam.sum(axis=1)
    return HitRatePrediction(
        T=np.asarray(T), occupancy=pi, hit_prob=hit, serve_prob=serve,
        hit_rate=float(served_mass.sum() / max(total, 1e-300)),
        ingress_hit_rate=np.divide(
            (lam * hit).sum(axis=1), ing_mass,
            out=np.zeros(n_ing), where=ing_mass > 0),
        cache_hit_rate=served_mass / max(total, 1e-300),
        mean_cost=cost / max(total, 1e-300),
        n_sweeps=n_sweeps, residual=residual)


# ======================================================================
# engine surrogate
# ======================================================================
def surrogate_cost(net: CacheNetwork, lam: np.ndarray,
                   balls: SimilarityBalls | None = None,
                   n_sweeps: int = 8) -> float:
    """Analytic per-request cost of ``net`` under demand ``lam`` — the
    cheap surrogate the streaming engine consults before paying for a
    device placement solve (serve/engine.request_refresh).

    Defaults to exact-hit balls (θ=0): the classic Che plane needs
    only the demand *shape*, runs in O(O·path) per call, and moves
    monotonically with demand drift — which is all the refresh gate
    needs. The engine's static placements are not LRU caches; this is
    a drift thermometer in cost units, not a placement evaluator.
    """
    lam = np.asarray(lam, np.float64)
    if balls is None:
        balls = exact_hit_balls(lam.shape[1])
    return predict_hitrates(net, lam, balls, n_sweeps=n_sweeps).mean_cost
