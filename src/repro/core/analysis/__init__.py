from repro.core.analysis.hitrate import (HitRatePrediction, SimilarityBalls,
                                         exact_hit_balls, predict_hitrates,
                                         similarity_balls,
                                         solve_characteristic_time,
                                         surrogate_cost)

__all__ = ["SimilarityBalls", "HitRatePrediction", "similarity_balls",
           "exact_hit_balls", "solve_characteristic_time",
           "predict_hitrates", "surrogate_cost"]
