"""NETDUEL — online, λ-unaware dynamic policy (paper §5).

Networked extension of DUEL [12]: each *real* cached object is paired
with a *virtual* competitor (metadata only, drawn from the arrival
process). Over an observation window we accumulate, per duel, the cost
saving each contender produces:

* real object in slot y:    saving_r = C(r, A \\ {y}) − C(r, A)
  (positive only for requests whose best approximizer is y; equals
  best2 − best1 for those requests);
* virtual object v at cache j(y): saving_r = max(0, C(r, A) − C_a(o, v)
  − h(i, j(y))) — the cost reduction v *would* have produced.

At the end of the window the virtual replaces the real iff its
accumulated saving exceeds the real's by a relative margin δ; otherwise
it is discarded and the slot is re-armed with a fresh virtual object
taken later from the arrival stream. The policy needs no knowledge of λ.

Two implementations with a shared bit-exact contract:

* :func:`netduel` — the host NumPy reference. All duel bookkeeping
  (savings, the δ-margin settle test, the armed-slot pick) is done in
  float32 with the *same elementary operations in the same order* as
  the device scan, and every random draw the policy consumes is taken
  up front (``_duel_draws``), so a trajectory is a pure function of
  (requests, draws) that replays bit-identically on the accelerator.
* :func:`device_netduel` — the device-resident rewrite: one jitted
  ``lax.scan`` over the whole request window. The carry is a
  :class:`DeviceDuelState` tuple (slots, best1/arg1/best2 serving
  tables, virtual ids, f32 savings, deadlines, promotion count) living
  entirely on the accelerator; per step the virtual contender is priced
  with the gain machinery of kernels/knn/gains.py
  (``duel_virtual_costs`` — the 1-row special case of the gain oracle's
  C_a tiling) and a promotion re-arms the serving tables via the same
  ``best_two`` kernel the offline control plane uses (mesh-sharded over
  the request axis when the DeviceInstance carries the data-plane
  axes). One launch prices a window of 10³–10⁵ requests; nothing
  returns to the host until the scan ends.

:class:`DuelPlane` packages the scan for the serving engine
(serve/engine.py, ``EngineConfig.netduel``): the duel carry persists
across serve() batches and each batch is observed in one scan launch,
optionally priced by the *same fused-lookup costs the data plane just
computed* (``b1_ext``) so a request is priced once for serving and
dueling.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro import tracecount
from repro.core.objective import DeviceInstance, Instance
from repro.core.placement.localswap import SwapState, emulated_stream

F32_ZERO = np.float32(0.0)


def _duel_draws(rng: np.random.Generator, n: int):
    """All randomness NETDUEL consumes, drawn up front: per-request
    arming coin flips and armed-slot picks. Data-independent draw order
    is what lets the device scan replay the host policy bit-identically
    (the old implementation drew the slot choice lazily from the rng,
    coupling the stream position to the trajectory)."""
    return rng.random(n), rng.random(n)


@dataclasses.dataclass
class DuelState:
    sw: SwapState                       # reuse best1/arg1/best2 bookkeeping
    virt: np.ndarray                    # (K,) virtual object id or −1
    real_sav: np.ndarray                # (K,) f32 accumulated real savings
    virt_sav: np.ndarray                # (K,) f32
    deadline: np.ndarray                # (K,) request-count when duel ends
    n_promotions: int = 0
    served_cost: float = 0.0
    n_served: int = 0
    promotions: list = dataclasses.field(default_factory=list)
    # promotions: (t, slot, new_obj, real_sav, virt_sav) per event


def netduel(inst: Instance, n_iters: int = 200000, seed: int = 0,
            window: int = 2000, delta: float = 0.05, arm_prob: float = 0.25,
            slots0: np.ndarray | None = None,
            requests: tuple[np.ndarray, np.ndarray] | None = None,
            record_every: int = 0) -> DuelState:
    """Run NETDUEL over a request stream; returns final state.

    ``delta`` is the relative winning margin: promote iff
    virt_sav > (1+δ)·real_sav. ``window`` is the duel length in requests.

    Duel arithmetic is float32 end to end (savings accumulation, the
    settle comparison ``virt_sav > f32(1+δ)·real_sav``, the armed-slot
    pick ``⌊f32(u)·f32(n_free)⌋``): each operation mirrors the device
    scan of :func:`device_netduel` one-for-one, which is what the
    differential suite (tests/test_netduel_device.py) pins down.
    """
    rng, slots, objs, ings = emulated_stream(inst, n_iters, seed, slots0,
                                             requests)
    K = slots.shape[0]
    st = DuelState(
        sw=SwapState.init(inst, slots),
        virt=np.full(K, -1, dtype=np.int64),
        real_sav=np.zeros(K, dtype=np.float32),
        virt_sav=np.zeros(K, dtype=np.float32),
        deadline=np.zeros(K, dtype=np.int64))
    arm_draws, slot_draws = _duel_draws(rng, len(objs))

    H, ca = inst.net.H, inst.ca
    slot_cache = inst.slot_cache
    h_slots = H[:, slot_cache]                       # (I, K) f32, +inf off-path
    on_path = np.isfinite(h_slots)                   # (I, K)
    one_delta = np.float32(1.0 + delta)
    for t in range(len(objs)):
        o, i = int(objs[t]), int(ings[t])
        b1 = st.sw.best1[i, o]                       # np.float32 scalar
        a1 = int(st.sw.arg1[i, o])
        st.served_cost += float(b1)
        st.n_served += 1

        # -- real savings: only the best slot saves anything for r
        if a1 >= 0:
            st.real_sav[a1] += st.sw.best2[i, o] - b1

        # -- virtual savings for every armed duel on the path of i
        armed = st.virt >= 0
        vcost = ca[o, np.maximum(st.virt, 0)] + h_slots[i]
        st.virt_sav = np.where(
            armed, st.virt_sav + np.maximum(b1 - vcost, F32_ZERO),
            st.virt_sav)

        # -- settle expired duels
        expired = armed & (st.deadline <= t)
        if expired.any():
            promote = expired & (st.virt_sav > one_delta * st.real_sav) \
                & (st.virt_sav > 0.0)
            if promote.any():
                for y in np.nonzero(promote)[0]:
                    st.promotions.append(
                        (t, int(y), int(st.virt[y]),
                         float(st.real_sav[y]), float(st.virt_sav[y])))
                st.sw.slots[promote] = st.virt[promote]
                st.sw.refresh(inst)
                st.n_promotions += int(promote.sum())
            st.virt[expired] = -1
            st.real_sav[expired] = 0.0
            st.virt_sav[expired] = 0.0

        # -- arm a new duel: pair this request's object with a uniformly
        #    random free slot on the path of i
        if arm_draws[t] < arm_prob:
            free = (st.virt < 0) & on_path[i]
            n_free = int(free.sum())
            if n_free:
                m = min(int(np.float32(slot_draws[t]) * np.float32(n_free)),
                        n_free - 1)
                y = int(np.nonzero(free)[0][m])
                st.virt[y] = o
                st.deadline[y] = t + window
                st.real_sav[y] = st.virt_sav[y] = 0.0

        if record_every and t % record_every == 0:
            st.sw.cost_trace.append(st.sw.cost(inst))
    return st


# ==================================================================== device
@dataclasses.dataclass
class DeviceDuelState:
    """Final state of a device NETDUEL run (host-side mirror of the scan
    carry, plus the traces the scan emitted)."""
    slots: np.ndarray                   # (K,) final allocation
    virt: np.ndarray                    # (K,) armed virtual ids or −1
    real_sav: np.ndarray                # (K,) f32
    virt_sav: np.ndarray                # (K,) f32
    deadline: np.ndarray                # (K,)
    n_promotions: int
    served_cost: float
    n_served: int
    promotions: list                    # (t, slot, new_obj, real, virt)
    b1_trace: np.ndarray                # (T,) f32 per-request served cost
    cost_trace: list


# Static unroll width of the incremental re-arm: a settle step promoting
# more than this many slots at once falls back to the full rebuild.
PROMOTE_CAP = 8


def _duel_carry(dinst: DeviceInstance, slots: np.ndarray):
    """Initial scan carry from a host allocation vector. Carries the
    pre-fold best-two tables (b1p/a1p/b2p/a2p — the witnesses the
    incremental re-arm's dirty-row detection keys on) next to the folded
    serving tables."""
    from repro.core.objective import fold_best_two
    slots_d = jnp.asarray(slots, jnp.int32)
    b1p, a1p, b2p, a2p = dinst.best_two_tables(slots_d)
    b1, a1, b2 = fold_best_two(b1p, a1p, b2p, dinst.h_repo)
    K = slots_d.shape[0]
    return (slots_d, b1p, a1p, b2p, a2p, b1, a1, b2,
            jnp.full((K,), -1, jnp.int32),
            jnp.zeros((K,), jnp.float32),
            jnp.zeros((K,), jnp.float32),
            jnp.zeros((K,), jnp.int32),
            jnp.zeros((), jnp.int32))


@functools.partial(jax.jit, static_argnames=(
    "metric", "gamma", "has_ca", "record_events", "external_b1",
    "record_every", "mesh", "axes", "masked", "incremental"))
def _duel_scan(coords, ca, lam, H, h_repo, slot_cache, h_slots, on_path,
               carry, xs, one_delta, window,
               metric: str, gamma: float, has_ca: bool,
               record_events: bool, external_b1: bool, record_every: int,
               mesh, axes, masked: bool = False, incremental: bool = True):
    """One launch over a request window: lax.scan of the NETDUEL step.

    Per step: price the request against the serving tables (or take the
    externally supplied fused-lookup cost ``b1_ext`` — the engine path,
    where the data plane already priced the batch), accumulate real and
    virtual savings in f32, settle expired duels (a promotion re-arms
    the best1/arg1/best2 tables through ``DeviceInstance.best_two``'s
    kernel under ``lax.cond`` — mesh-sharded over the request axis when
    ``mesh`` is set), and arm a new duel from the precomputed draws.
    Emits the per-step served cost (always), promotion events and
    sub-sampled cost-trace points (statically gated).

    ``masked=True`` appends a per-step validity flag to ``xs`` (the
    bucketed engine path: batches padded to power-of-two buckets so the
    scan compiles once per bucket, not once per batch size). An invalid
    step is a complete no-op — no savings, no settle, no arming, no
    promotion count, zero emitted cost — so the carry after a padded
    window is bit-identical to the carry after the unpadded one.
    """
    from repro.core.objective import (_best_two_delta_jit,
                                      _fold_repo_rows, best_two_tables,
                                      default_delta_cap)
    from repro.kernels.knn.gains import duel_virtual_costs

    tracecount.bump("duel_scan")

    K = int(slot_cache.shape[0])
    n_obj = int(lam.shape[1])
    cap = min(default_delta_cap(n_obj), n_obj)

    def full_tables(slots):
        return best_two_tables(coords, ca, slots, slot_cache, H,
                               metric, gamma, has_ca, mesh, axes)

    def rearm(slots_new, promote, pre):
        """Pre-fold + folded tables after a settle wrote ``promote``."""
        if incremental:
            ys = jnp.nonzero(promote, size=PROMOTE_CAP,
                             fill_value=K)[0].astype(jnp.int32)
            n_p = jnp.sum(promote, dtype=jnp.int32)
            npre = jax.lax.cond(
                n_p > PROMOTE_CAP,
                lambda _: full_tables(slots_new),
                lambda _: _best_two_delta_jit(
                    coords, ca, *pre, slots_new, ys, slot_cache, H,
                    metric=metric, gamma=gamma, has_ca=has_ca, cap=cap,
                    n_slots=K, mesh=mesh, axes=axes),
                None)
        else:
            npre = full_tables(slots_new)
        return (*npre, *_fold_repo_rows(npre[0], npre[1], npre[2], h_repo))

    def step(c, x):
        (slots, b1p, a1p, b2p, a2p, best1, arg1, best2,
         virt, rs, vs, deadline, n_prom) = c
        if masked:
            *x, valid = x
        else:
            valid = jnp.bool_(True)
        if external_b1:
            o, i, t, armf, slotu, b1 = x
        else:
            o, i, t, armf, slotu = x
            b1 = best1[i, o]
        a1 = arg1[i, o]

        # real saving — scatter to the winning slot (no-op for repo hits)
        rs = rs.at[jnp.maximum(a1, 0)].add(
            jnp.where(valid & (a1 >= 0), best2[i, o] - b1, jnp.float32(0)))

        # virtual savings — the gain-machinery pricing tile
        armed = virt >= 0
        vcost = duel_virtual_costs(coords, ca, o, jnp.maximum(virt, 0),
                                   h_slots[i], metric, gamma, has_ca)
        vs = jnp.where(valid & armed,
                       vs + jnp.maximum(b1 - vcost, jnp.float32(0)), vs)

        # settle expired duels
        expired = valid & armed & (deadline <= t)
        promote = expired & (vs > one_delta * rs) & (vs > 0.0)
        any_p = jnp.any(promote)
        slots = jnp.where(promote, virt, slots)
        b1p, a1p, b2p, a2p, best1, arg1, best2 = jax.lax.cond(
            any_p, lambda _: rearm(slots, promote, (b1p, a1p, b2p, a2p)),
            lambda _: (b1p, a1p, b2p, a2p, best1, arg1, best2), None)
        n_prom = n_prom + jnp.sum(promote, dtype=jnp.int32)
        ev = (promote, virt, rs, vs) if record_events else ()
        virt = jnp.where(expired, -1, virt)
        rs = jnp.where(expired, jnp.float32(0), rs)
        vs = jnp.where(expired, jnp.float32(0), vs)

        # arm a new duel on a uniformly random free on-path slot
        free = (virt < 0) & on_path[i]
        n_free = jnp.sum(free, dtype=jnp.int32)
        arm = valid & armf & (n_free > 0)
        m = jnp.minimum((slotu * n_free.astype(jnp.float32))
                        .astype(jnp.int32), n_free - 1)
        y_arm = (jnp.cumsum(free) - 1 == m) & free & arm
        virt = jnp.where(y_arm, o, virt)
        deadline = jnp.where(y_arm, t + window, deadline)
        rs = jnp.where(y_arm, jnp.float32(0), rs)
        vs = jnp.where(y_arm, jnp.float32(0), vs)

        out = (jnp.where(valid, b1, jnp.float32(0)),)
        if record_every:
            out += (jax.lax.cond(
                t % record_every == 0,
                lambda b: jnp.sum(lam * b), lambda b: jnp.float32(-1.0),
                best1),)
        if record_events:
            out += ev
        return (slots, b1p, a1p, b2p, a2p, best1, arg1, best2,
                virt, rs, vs, deadline, n_prom), out

    return jax.lax.scan(step, carry, xs)


def _duel_xs(objs, ings, t0, arm_flags, slot_draws, b1_ext=None,
             valid=None):
    """Scan inputs. ``valid`` (bool mask) appends the bucketing validity
    flag; invalid rows reuse the last valid row's ``t`` so the duel
    timeline only advances with real requests (deadlines are measured in
    served requests, not in padded scan steps)."""
    n = len(objs)
    if valid is None:
        ts = np.arange(t0, t0 + n, dtype=np.int32)
    else:
        valid = np.asarray(valid, bool)
        ts = (t0 + np.maximum(np.cumsum(valid) - 1, 0)).astype(np.int32)
    xs = (jnp.asarray(objs, jnp.int32), jnp.asarray(ings, jnp.int32),
          jnp.asarray(ts),
          jnp.asarray(arm_flags), jnp.asarray(slot_draws, jnp.float32))
    if b1_ext is not None:
        xs += (jnp.asarray(b1_ext, jnp.float32),)
    if valid is not None:
        xs += (jnp.asarray(valid),)
    return xs


def _scan_args(dinst: DeviceInstance):
    ca = dinst.ca if dinst.ca is not None else jnp.zeros((0, 0), jnp.float32)
    h_slots = dinst.H[:, dinst.slot_cache]
    on_path = jnp.isfinite(h_slots)
    mesh = dinst.mesh if dinst.n_shards > 1 else None
    axes = dinst.axes if dinst.n_shards > 1 else ()
    return ca, h_slots, on_path, mesh, axes


def _events_from_trace(promote, virt, rs, vs, t0=0):
    """Host-side unpack of the recorded settle tensors into the same
    (t, slot, new_obj, real_sav, virt_sav) event list the host policy
    appends (slots in ascending order within a step)."""
    events = []
    for t in np.nonzero(promote.any(axis=1))[0]:
        for y in np.nonzero(promote[t])[0]:
            events.append((int(t) + t0, int(y), int(virt[t, y]),
                           float(rs[t, y]), float(vs[t, y])))
    return events


def device_netduel(dinst: DeviceInstance, n_iters: int = 200000,
                   seed: int = 0, window: int = 2000, delta: float = 0.05,
                   arm_prob: float = 0.25,
                   slots0: np.ndarray | None = None,
                   requests: tuple[np.ndarray, np.ndarray] | None = None,
                   record_every: int = 0,
                   record_events: bool = False,
                   incremental: bool = True) -> DeviceDuelState:
    """NETDUEL as one device launch: identical rng consumption to
    :func:`netduel` (same seed → same start slots, requests and draws)
    and bit-identical duel decisions on materialized-C_a instances
    (the f32 op-for-op contract of the module docstring).

    ``record_events=True`` additionally stacks the per-step settle
    state (promote mask, virtual ids, both savings — four (T, K)
    tensors) so the promotion-event list can be reconstructed; that is
    what the differential suite compares, but it costs ~13·T·K bytes of
    device memory, so it is opt-in (off, a run emits only the (T,)
    served-cost trace)."""
    rng, slots, objs, ings = emulated_stream(dinst.host, n_iters, seed,
                                             slots0, requests)
    arm_draws, slot_draws = _duel_draws(rng, len(objs))
    arm_flags = arm_draws < arm_prob                 # exact f64 compare

    ca, h_slots, on_path, mesh, axes = _scan_args(dinst)
    carry = _duel_carry(dinst, slots)
    xs = _duel_xs(objs, ings, 0, arm_flags, slot_draws)
    carry, out = _duel_scan(
        dinst.coords, ca, dinst.lam, dinst.H, dinst.h_repo,
        dinst.slot_cache, h_slots, on_path, carry, xs,
        jnp.float32(1.0 + delta), jnp.int32(window),
        dinst.metric, dinst.gamma, dinst.ca is not None,
        record_events, False, record_every, mesh, axes,
        incremental=incremental)

    b1_trace = np.asarray(out[0])
    cost_trace = []
    k = 1
    if record_every:
        costs = np.asarray(out[k]); k += 1
        cost_trace = [float(c) for t, c in enumerate(costs)
                      if t % record_every == 0]
    events = []
    if record_events:
        events = _events_from_trace(*(np.asarray(o) for o in out[k:k + 4]))
    (slots_d, _, _, _, _, _, _, _, virt, rs, vs, deadline, n_prom) = carry
    # cumsum accumulates sequentially in f64 — bit-identical to the
    # host's per-step ``served_cost += float(b1)``
    served = float(np.cumsum(b1_trace, dtype=np.float64)[-1]) \
        if b1_trace.size else 0.0
    return DeviceDuelState(
        slots=np.asarray(slots_d).astype(np.int64),
        virt=np.asarray(virt).astype(np.int64),
        real_sav=np.asarray(rs), virt_sav=np.asarray(vs),
        deadline=np.asarray(deadline).astype(np.int64),
        n_promotions=int(n_prom), served_cost=served,
        n_served=len(b1_trace), promotions=events, b1_trace=b1_trace,
        cost_trace=cost_trace)


class DuelPlane:
    """Persistent online control plane for the serving engine (§5 run
    *inside* the data plane): holds the duel carry on device across
    serve() batches, observing each batch in one scan launch.

    ``observe(objs, b1_ext=...)`` takes the batch's request object ids
    and (optionally) the costs the fused lookup already computed for
    them — the request is then priced once for serving and dueling.
    Returns True iff at least one promotion settled in the batch, i.e.
    the placement changed and the data-plane cache must be rebuilt.

    ``n_valid`` marks a *bucketed* batch (serve/engine.py): only the
    first ``n_valid`` rows are real requests, the tail is power-of-two
    padding. Randomness is drawn for the valid prefix only and the scan
    masks the padded steps into no-ops, so the duel trajectory is
    bit-identical to observing the unpadded batch — while the scan
    compiles once per bucket size instead of once per batch size.
    """

    def __init__(self, dinst: DeviceInstance, slots0: np.ndarray,
                 window: int = 512, delta: float = 0.05,
                 arm_prob: float = 0.25, seed: int = 0,
                 incremental: bool = True):
        self.dinst = dinst
        self.incremental = bool(incremental)
        self.window = int(window)
        self.one_delta = jnp.float32(1.0 + delta)
        self.arm_prob = float(arm_prob)
        self.rng = np.random.default_rng(seed)
        self.carry = _duel_carry(dinst, np.asarray(slots0))
        self.t = 0
        self.n_promotions = 0
        self.served_cost = 0.0
        self._args = _scan_args(dinst)

    def observe(self, objs: np.ndarray, ings: np.ndarray | None = None,
                b1_ext: np.ndarray | None = None,
                n_valid: int | None = None) -> bool:
        objs = np.asarray(objs)
        if ings is None:
            ings = np.zeros(objs.shape[0], np.int64)
        # masked whenever the caller buckets, even with zero padding rows:
        # one compiled scan per bucket size, not two (padded + exact-fit)
        masked = n_valid is not None
        n_real = objs.shape[0] if n_valid is None else int(n_valid)
        # draw only for real requests: the rng stream position after a
        # bucketed observe equals the unpadded one (bit-identical replay)
        arm_flags = np.zeros(objs.shape[0], bool)
        slot_draws = np.zeros(objs.shape[0], np.float64)
        arm_flags[:n_real] = self.rng.random(n_real) < self.arm_prob
        slot_draws[:n_real] = self.rng.random(n_real)
        valid = None
        if masked:
            valid = np.zeros(objs.shape[0], bool)
            valid[:n_real] = True
        ca, h_slots, on_path, mesh, axes = self._args
        xs = _duel_xs(objs, ings, self.t, arm_flags, slot_draws,
                      b1_ext=b1_ext, valid=valid)
        d = self.dinst
        self.carry, out = _duel_scan(
            d.coords, ca, d.lam, d.H, d.h_repo, d.slot_cache, h_slots,
            on_path, self.carry, xs, self.one_delta,
            jnp.int32(self.window), d.metric, d.gamma, d.ca is not None,
            False, b1_ext is not None, 0, mesh, axes, masked=masked,
            incremental=self.incremental)
        self.t += n_real
        self.served_cost += float(np.asarray(out[0], np.float64).sum())
        n_prom = int(self.carry[12])
        changed = n_prom > self.n_promotions
        self.n_promotions = n_prom
        return changed

    @property
    def slots_np(self) -> np.ndarray:
        return np.asarray(self.carry[0]).astype(np.int64)
