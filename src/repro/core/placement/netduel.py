"""NETDUEL — online, λ-unaware dynamic policy (paper §5).

Networked extension of DUEL [12]: each *real* cached object is paired
with a *virtual* competitor (metadata only, drawn from the arrival
process). Over an observation window we accumulate, per duel, the cost
saving each contender produces:

* real object in slot y:    saving_r = C(r, A \\ {y}) − C(r, A)
  (positive only for requests whose best approximizer is y; equals
  best2 − best1 for those requests);
* virtual object v at cache j(y): saving_r = max(0, C(r, A) − C_a(o, v)
  − h(i, j(y))) — the cost reduction v *would* have produced.

At the end of the window the virtual replaces the real iff its
accumulated saving exceeds the real's by a relative margin δ; otherwise
it is discarded and the slot is re-armed with a fresh virtual object
taken later from the arrival stream. The policy needs no knowledge of λ.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.objective import Instance, random_slots
from repro.core.placement.localswap import SwapState


@dataclasses.dataclass
class DuelState:
    sw: SwapState                       # reuse best1/arg1/best2 bookkeeping
    virt: np.ndarray                    # (K,) virtual object id or −1
    real_sav: np.ndarray                # (K,) accumulated real savings
    virt_sav: np.ndarray                # (K,)
    deadline: np.ndarray                # (K,) request-count when duel ends
    n_promotions: int = 0
    served_cost: float = 0.0
    n_served: int = 0


def netduel(inst: Instance, n_iters: int = 200000, seed: int = 0,
            window: int = 2000, delta: float = 0.05, arm_prob: float = 0.25,
            slots0: np.ndarray | None = None,
            requests: tuple[np.ndarray, np.ndarray] | None = None,
            record_every: int = 0) -> DuelState:
    """Run NETDUEL over a request stream; returns final state.

    ``delta`` is the relative winning margin: promote iff
    virt_sav > (1+δ)·real_sav. ``window`` is the duel length in requests.
    """
    rng = np.random.default_rng(seed)
    slots = random_slots(inst, rng) if slots0 is None else slots0.copy()
    K = slots.shape[0]
    st = DuelState(
        sw=SwapState.init(inst, slots),
        virt=np.full(K, -1, dtype=np.int64),
        real_sav=np.zeros(K), virt_sav=np.zeros(K),
        deadline=np.zeros(K, dtype=np.int64))
    if requests is None:
        objs, ings = inst.dem.sample(n_iters, rng)
    else:
        objs, ings = requests
    arm_draws = rng.random(len(objs))
    cost_trace = []

    H, ca = inst.net.H, inst.ca
    slot_cache = inst.slot_cache
    for t in range(len(objs)):
        o, i = int(objs[t]), int(ings[t])
        b1 = float(st.sw.best1[i, o])
        a1 = int(st.sw.arg1[i, o])
        st.served_cost += b1
        st.n_served += 1

        # -- real savings: only the best slot saves anything for r
        if a1 >= 0:
            st.real_sav[a1] += float(st.sw.best2[i, o]) - b1

        # -- virtual savings for every armed duel on the path of i
        armed = np.nonzero(st.virt >= 0)[0]
        if armed.size:
            j = slot_cache[armed]
            vcost = ca[o, st.virt[armed]] + H[i, j]
            st.virt_sav[armed] += np.maximum(b1 - vcost, 0.0)

        # -- settle expired duels
        expired = armed[st.deadline[armed] <= t] if armed.size else armed
        for y in expired:
            y = int(y)
            if st.virt_sav[y] > (1.0 + delta) * st.real_sav[y] and \
                    st.virt_sav[y] > 0.0:
                st.sw.slots[y] = st.virt[y]
                st.sw.refresh(inst)
                st.n_promotions += 1
            st.virt[y] = -1
            st.real_sav[y] = st.virt_sav[y] = 0.0

        # -- arm a new duel: pair this request's object with the slot it
        #    would most plausibly replace (cheapest serving slot on path)
        if arm_draws[t] < arm_prob:
            free = np.nonzero((st.virt < 0)
                              & np.isfinite(H[i])[slot_cache])[0]
            if free.size:
                y = int(rng.choice(free))
                st.virt[y] = o
                st.deadline[y] = t + window
                st.real_sav[y] = st.virt_sav[y] = 0.0

        if record_every and t % record_every == 0:
            cost_trace.append(st.sw.cost(inst))
    st.sw.cost_trace = cost_trace
    return st
