"""Continuous-limit warm starts — §4 as the production placement path.

The discrete control plane (GREEDY over the batched gain oracle,
placement/device.py) pays O(O·J) oracle work per solve: past ~10⁵
objects a refresh no longer fits between serving batches, and at
10⁶–10⁷ the gain table cannot exist at all. The paper's §4 continuous
formulation closes exactly that gap — for every topology it analyses
the *optimal* continuous allocation has threshold/closed form
(Prop 4.2: in a chain each cache serves a contiguous popularity band;
Prop 4.4: equi-depth trees replicate one chain solution per level;
eqs. (14)–(15) for the tandem with arrivals at both nodes), and
solving it costs milliseconds at any catalog size.

Pipeline (near-O(O) end to end):

1. **classify** — :func:`classify_topology` reduces a
   :class:`~repro.core.topology.CacheNetwork` to the continuous program
   it instantiates: any single-ingress net is a chain (caches ordered by
   retrieval cost; covers ``single_cache``/``tandem``/``chain``/
   ``tpu_hierarchy``), the §4.4 two-ingress tandem is matched by its H
   pattern, and leaf-fed equi-depth trees by identical per-ingress cost
   vectors with uniform per-level capacities. Returns ``None`` for
   topologies outside the paper's analysis — callers fall back to the
   discrete solvers.
2. **solve** — :func:`solve_continuous`: Prop 4.2 threshold coordinate
   descent (``solve_chain_thresholds``: O(O) prefix sums + an
   O(N·grid)-evaluation golden-section search) for chains and trees,
   the jitted projected-gradient ``solve_tandem_both`` for the §4.4
   tandem.
3. **map** — :func:`map_solution`: band-partition the λ-descending
   catalog at the solved split points and fill each cache from its band
   by quantile-striding the §4.1 slot density λ^{2/(γ+2)} (each slot
   covers an equal share of its band's density mass — the discrete
   shadow of the optimal tessellation), respecting
   ``CacheNetwork.slot_layout()``.
4. **polish** — a bounded ``device_localswap(scan=True)`` window of
   O(K) steps (K = total slots, independent of O) removes the
   discretization error at band edges.

:func:`warm_start` runs 1–4 and returns a :class:`WarmStartReport`
carrying the allocation plus per-stage wall clock — the numbers
benchmarks/warmstart_bench.py records into results/bench/warmstart.json
and tests/test_warmstart.py locks (measured optimality gap vs
``device_greedy`` where greedy still runs, Prop 4.2 band containment
everywhere).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.objective import DeviceInstance, Instance
from repro.core.placement import continuous as cont
from repro.core.placement.device import SWAP_TOL, device_localswap
from repro.core.placement.localswap import localswap
from repro.core.topology import CacheNetwork


# --------------------------------------------------------------- reductions
@dataclasses.dataclass(frozen=True)
class ChainReduction:
    """Single-ingress net as the chain program (11).

    ``path`` lists cache ids in h-ascending chain order; ``unreachable``
    the caches with +inf retrieval cost (off the forwarding path — they
    can never serve, so the warm start fills them by popularity and the
    polish window is free to repurpose them if the discrete objective
    ever disagrees)."""
    spec: cont.ChainSpec
    path: tuple
    unreachable: tuple = ()
    kind: str = "chain"


@dataclasses.dataclass(frozen=True)
class TreeReduction:
    """Leaf-fed equi-depth tree (§4.3): one chain program, replicated
    across every cache of each level per Prop 4.4 (levels[0] = leaves,
    solved at the leaf-aggregate rate — homogeneity degree 1 makes the
    aggregate chain cost equal the Prop 4.4 tree cost Σ_ℓ β_ℓ·C)."""
    spec: cont.ChainSpec
    levels: tuple                      # tuple[tuple[cache ids], ...]
    kind: str = "tree"


@dataclasses.dataclass(frozen=True)
class TandemBothReduction:
    """The §4.4 tandem with arrivals at both nodes (eqs. 14–15)."""
    leaf: int
    parent: int
    leaf_ingress: int
    parent_ingress: int
    h: float
    gamma: float = 1.0
    kind: str = "tandem_both"


Reduction = ChainReduction | TreeReduction | TandemBothReduction


def classify_topology(net: CacheNetwork, gamma: float = 1.0
                      ) -> Reduction | None:
    """Reduce ``net`` to the §4 continuous program it instantiates.

    Order of attempts: single ingress → chain (always reducible — the
    finite-H caches sorted by retrieval cost are the chain, ties broken
    by cache id); the two-ingress ``tandem_both`` H pattern; leaf-fed
    equi-depth trees. Anything else returns None and the caller falls
    back to the discrete solvers.
    """
    H = np.asarray(net.H, np.float64)
    if net.n_ingress == 1:
        finite = np.isfinite(H[0])
        reach = np.nonzero(finite)[0]
        if reach.size == 0:
            return None
        path = reach[np.argsort(H[0, reach], kind="stable")]
        return ChainReduction(
            spec=cont.ChainSpec(
                ks=tuple(float(net.capacities[j]) for j in path),
                hs=tuple(float(H[0, j]) for j in path),
                h_repo=float(net.h_repo[0]), gamma=gamma),
            path=tuple(int(j) for j in path),
            unreachable=tuple(int(j) for j in np.nonzero(~finite)[0]))
    red = _classify_tandem_both(net, H, gamma)
    if red is not None:
        return red
    return _classify_tree(net, H, gamma)


def _classify_tandem_both(net: CacheNetwork, H: np.ndarray, gamma: float
                          ) -> TandemBothReduction | None:
    if H.shape != (2, 2):
        return None
    nfin = np.isfinite(H).sum(axis=1)
    if sorted(nfin.tolist()) != [1, 2]:
        return None
    a = int(np.argmax(nfin))           # leaf ingress reaches both caches
    b = 1 - a
    parent = int(np.nonzero(np.isfinite(H[b]))[0][0])
    leaf = 1 - parent
    if not np.isfinite(H[a, leaf]) or H[a, leaf] > H[a, parent]:
        return None
    return TandemBothReduction(
        leaf=leaf, parent=parent, leaf_ingress=a, parent_ingress=b,
        h=float(H[a, parent] - H[a, leaf]), gamma=gamma)


def _classify_tree(net: CacheNetwork, H: np.ndarray, gamma: float
                   ) -> TreeReduction | None:
    if net.n_ingress < 2 or not np.allclose(net.h_repo, net.h_repo[0]):
        return None
    paths, hs0 = [], None
    for i in range(net.n_ingress):
        fi = np.nonzero(np.isfinite(H[i]))[0]
        p = fi[np.argsort(H[i, fi], kind="stable")]
        hv = H[i, p]
        if hs0 is None:
            hs0 = hv
        elif hv.shape != hs0.shape or not np.allclose(hv, hs0):
            return None                # unequal depths / unequal hop costs
        paths.append(p)
    level_of = np.full(net.n_caches, -1, np.int64)
    for p in paths:
        for d, j in enumerate(p):
            if level_of[j] not in (-1, d):
                return None            # one cache at two depths: not a tree
            level_of[j] = d
    if np.any(level_of < 0):
        return None                    # cache on no ingress path
    levels = []
    for d in range(hs0.shape[0]):
        ld = np.nonzero(level_of == d)[0]
        caps = net.capacities[ld]
        if ld.size == 0 or not np.all(caps == caps[0]):
            return None                # Prop 4.4 needs uniform level sizes
        levels.append(tuple(int(j) for j in ld))
    return TreeReduction(
        spec=cont.ChainSpec(
            ks=tuple(float(net.capacities[lv[0]]) for lv in levels),
            hs=tuple(float(h) for h in hs0),
            h_repo=float(net.h_repo[0]), gamma=gamma),
        levels=tuple(levels))


# -------------------------------------------------------------------- solve
@dataclasses.dataclass(frozen=True)
class ContinuousSolution:
    """Output of the per-topology continuous solver.

    ``order`` is the λ-descending object permutation the bands live on;
    chains/trees carry ``splits`` (fractional Prop 4.2 split points on
    that axis), the tandem-both carries the per-object leaf-keep
    fraction ``w1`` (natural object order) and the arrival ratio β."""
    kind: str
    cost: float
    order: np.ndarray
    splits: np.ndarray | None = None
    w1: np.ndarray | None = None
    beta: float = 0.0


def solve_continuous(inst: Instance, red: Reduction,
                     md_iters: int = 3000, sweeps: int = 16,
                     grid: int = 48) -> ContinuousSolution:
    """Solve the continuous program ``red`` on ``inst``'s demand rates.

    ``sweeps``/``grid`` are lighter than ``solve_chain_thresholds``'s
    analysis defaults (60/96): measured on 10³–10⁶-region Zipf and grid
    instances the optimal cost agrees to ~1e-9 relative while the solve
    runs ~3× faster — golden section past ~48 halvings only burnishes
    digits far below the discretization error the band map introduces
    anyway."""
    if red.kind == "tandem_both":
        lam0 = np.asarray(inst.lam[red.leaf_ingress], np.float64)
        lam1 = np.asarray(inst.lam[red.parent_ingress], np.float64)
        beta = float(lam1.sum() / max(lam0.sum(), 1e-300))
        w1, c = cont.solve_tandem_both(
            lam0, float(inst.net.capacities[red.leaf]),
            float(inst.net.capacities[red.parent]), red.h, beta,
            gamma=red.gamma, iters=md_iters)
        return ContinuousSolution(
            kind=red.kind, cost=float(c),
            order=np.argsort(-lam0, kind="stable"),
            w1=np.asarray(w1, np.float64), beta=beta)
    lams = inst.lam[0] if red.kind == "chain" else inst.lam.sum(axis=0)
    splits, c, order = cont.solve_chain_thresholds(
        np.asarray(lams, np.float64), red.spec, sweeps=sweeps, grid=grid)
    return ContinuousSolution(kind=red.kind, cost=float(c), order=order,
                              splits=splits)


# ---------------------------------------------------------------------- map
def _quantile_picks(w: np.ndarray, k: int) -> np.ndarray:
    """k distinct indices into ``w`` spread so each pick owns an equal
    share of the cumulative mass — the §4.1 slot density discretized
    (slot i sits at the (i+½)/k mass quantile). Zero total mass falls
    back to an even positional stride. Requires 0 < k ≤ len(w)."""
    m = w.shape[0]
    c = np.cumsum(np.maximum(np.asarray(w, np.float64), 0.0))
    if c[-1] <= 0.0:
        picks = np.floor((np.arange(k) + 0.5) * (m / k)).astype(np.int64)
    else:
        targets = (np.arange(k) + 0.5) * (c[-1] / k)
        picks = np.searchsorted(c, targets).astype(np.int64)
    # dedupe while staying in-range: clamp against the max tail each
    # position can still reach, then push strictly increasing
    picks = np.minimum(picks, m - k + np.arange(k))
    for i in range(1, k):
        if picks[i] <= picks[i - 1]:
            picks[i] = picks[i - 1] + 1
    return picks


def band_bounds(splits: np.ndarray, n_objects: int) -> np.ndarray:
    """Integer rank boundaries of the Prop 4.2 bands: band p covers
    λ-descending ranks [bounds[p], bounds[p+1]); the segment past the
    last bound is the repository's tail."""
    pos = np.concatenate([[0.0], np.asarray(splits, np.float64),
                          [float(n_objects)]])
    pos = np.maximum.accumulate(np.clip(pos, 0.0, float(n_objects)))
    return np.maximum.accumulate(np.rint(pos).astype(np.int64))


def rank_window(n_objects: int, lo: int, hi: int, k: int) -> tuple[int, int]:
    """The contiguous rank window a k-slot cache with band [lo, hi)
    draws from: the band itself when it holds ≥ k objects, otherwise the
    band grown toward the tail (and, at the catalog edge, toward the
    head) until k fit. tests/test_warmstart.py asserts every stored
    object's rank lies inside this window — the discrete Prop 4.2."""
    if k >= n_objects:
        return 0, n_objects
    lo = int(min(lo, n_objects - k))
    hi = int(min(max(hi, lo + k), n_objects))
    return lo, hi


def _fill_band(order: np.ndarray, w_sorted: np.ndarray, lo: int, hi: int,
               k: int) -> np.ndarray:
    """k object ids for one cache whose Prop 4.2 band is ranks [lo, hi):
    the whole band when exactly k wide, a λ^{2/(γ+2)}-quantile stride
    when wider, the :func:`rank_window` extension when narrower. A
    catalog smaller than the cache wraps (duplicate slots are legal —
    the polish pass diversifies them if that ever helps)."""
    n = order.shape[0]
    if k >= n:
        return order[np.resize(np.arange(n), k)]
    lo, hi = rank_window(n, lo, hi, k)
    if hi - lo == k:
        return order[lo:hi]
    return order[lo + _quantile_picks(w_sorted[lo:hi], k)]


def map_solution(inst: Instance, red: Reduction, sol: ContinuousSolution
                 ) -> tuple[np.ndarray, np.ndarray | None]:
    """Discrete allocation from the continuous optimum.

    Returns ``(slots, bounds)``: every slot filled (no −1 — the
    continuous optimum never leaves capacity idle), ``bounds`` the
    integer Prop 4.2 band boundaries (None for the structure-free
    tandem-both, whose allocation is density- not band-shaped)."""
    O = inst.cat.n
    g = inst.cat.gamma
    slot_cache = inst.slot_cache
    caps = inst.net.capacities
    slots = np.empty(inst.net.total_slots, np.int64)
    order = sol.order
    if red.kind == "tandem_both":
        # eq. (14) split as slot densities: leaf ∝ (λ·w1)^{2/(γ+2)} per
        # region → after the regional λ^e factor, leaf mass λ^e·w1-ish;
        # parent serves forwarded border mass plus its own β arrivals.
        e = 2.0 / (2.0 + g)
        lam0 = np.asarray(inst.lam[red.leaf_ingress], np.float64)[order]
        lb = lam0 ** e
        w1s = np.clip(sol.w1[order], 0.0, 1.0)
        dens = {red.leaf: lb * w1s,
                red.parent: lb * (sol.beta +
                                  (1.0 - w1s) ** ((g + 2.0) / 2.0)) ** e}
        for j, w in dens.items():
            k = int(caps[j])
            chosen = order[_quantile_picks(w, k)] if k <= O \
                else order[np.resize(np.arange(O), k)]
            slots[slot_cache == j] = chosen
        return slots, None
    lams = inst.lam[0] if red.kind == "chain" else inst.lam.sum(axis=0)
    w_sorted = np.asarray(lams, np.float64)[order] ** (2.0 / (g + 2.0))
    bounds = band_bounds(sol.splits, O)
    groups = tuple((j,) for j in red.path) if red.kind == "chain" \
        else red.levels
    for p, caches in enumerate(groups):
        for j in caches:
            chosen = _fill_band(order, w_sorted, int(bounds[p]),
                                int(bounds[p + 1]), int(caps[j]))
            slots[slot_cache == j] = chosen
    if red.kind == "chain":
        for j in red.unreachable:       # never served: park the head
            k = int(caps[j])
            slots[slot_cache == j] = _fill_band(order, w_sorted, 0, k, k)
    return slots, bounds


# ----------------------------------------------------------------- pipeline
@dataclasses.dataclass
class WarmStartReport:
    """What :func:`warm_start` produced and what each stage cost."""
    kind: str                          # reduction kind solved
    slots: np.ndarray                  # post-polish allocation (no −1)
    slots_warm: np.ndarray             # analytic map before polish
    cont_cost: float                   # continuous-optimum objective
    order: np.ndarray                  # λ-descending object permutation
    bounds: np.ndarray | None          # integer band boundaries
    groups: tuple                      # caches per chain position
    solve_s: float
    map_s: float
    polish_s: float
    n_swaps: int = 0

    @property
    def total_s(self) -> float:
        return self.solve_s + self.map_s + self.polish_s


def default_polish_iters(n_slots: int) -> int:
    """Polish window ~O(K): long enough for the emulated request stream
    to touch every slot a few times, independent of catalog size — the
    near-O(O) contract of the pipeline."""
    return int(min(max(4 * n_slots, 128), 4096))


def warm_start(inst: Instance, *, reduction: Reduction | None = None,
               polish_iters: int | None = None, seed: int = 0,
               tol: float = SWAP_TOL, device: bool = True,
               dinst: DeviceInstance | None = None,
               md_iters: int = 3000) -> WarmStartReport:
    """Classify → solve → map → polish. Deterministic for fixed inputs
    (the continuous solvers are jitted fixed-iteration descents, the
    map is pure NumPy, the polish replays ``emulated_stream(seed)``) —
    which is what lets warm-started background refreshes stay replayable
    by the trace-replay differential machinery.

    ``device=False`` polishes with the host NumPy LOCALSWAP instead of
    the scanned device window (only sensible at small O). A prebuilt
    ``dinst`` (e.g. the engine's mesh-sharded control-plane twin) is
    reused instead of building one per call.
    """
    t0 = time.perf_counter()
    red = reduction if reduction is not None \
        else classify_topology(inst.net, gamma=inst.cat.gamma)
    if red is None:
        raise ValueError(
            "topology does not reduce to a §4 continuous program; use the "
            "discrete solvers (device_greedy / device_localswap)")
    sol = solve_continuous(inst, red, md_iters=md_iters)
    t1 = time.perf_counter()
    slots_warm, bounds = map_solution(inst, red, sol)
    t2 = time.perf_counter()
    if polish_iters is None:
        polish_iters = default_polish_iters(inst.net.total_slots)
    slots, n_swaps = slots_warm, 0
    if polish_iters > 0:
        if device:
            if dinst is None:
                dinst = DeviceInstance.from_instance(inst,
                                                     materialize_ca=False)
            st = device_localswap(dinst, n_iters=polish_iters, seed=seed,
                                  slots0=slots_warm, tol=tol, scan=True)
            slots, n_swaps = st.slots_np, int(st.n_swaps)
        else:
            st = localswap(inst, n_iters=polish_iters, seed=seed,
                           slots0=slots_warm, tol=tol)
            slots, n_swaps = st.slots, int(st.n_swaps)
    t3 = time.perf_counter()
    if red.kind == "chain":
        groups = tuple((j,) for j in red.path)
    elif red.kind == "tree":
        groups = red.levels
    else:
        groups = ()
    return WarmStartReport(
        kind=red.kind, slots=slots, slots_warm=slots_warm,
        cont_cost=sol.cost, order=sol.order, bounds=bounds, groups=groups,
        solve_s=t1 - t0, map_s=t2 - t1, polish_s=t3 - t2, n_swaps=n_swaps)
