"""GREEDY placement (paper §3.2).

Problem (4) is the maximization of a monotone non-negative submodular
function over a matroid (Prop 3.2), so GREEDY enjoys a 1/2 approximation
ratio [Fisher–Nemhauser–Wolsey '78]. Two implementations:

* ``lazy=True`` (default) — the accelerated/lazy greedy: marginal gains
  can only shrink as the allocation grows (submodularity), so a stale
  max-heap of gains only needs the popped candidate re-evaluated. This is
  the "smart implementation" the paper alludes to in §3.2 and reduces the
  practical complexity by orders of magnitude while returning the exact
  greedy solution.
* ``lazy=False`` — textbook greedy. Instead of recomputing all O·J
  gains from scratch every step (the paper's stated bound
  O_R·N·(O·N·K − K(K−1)/2)), the gain table is updated incrementally
  with ``Instance.add_gain_delta``: a pick only changes the gains
  through the requests whose serving cost it lowered (the same
  vectorized row update ``updated_costs`` applies to ``cur``), so each
  step costs O(changed·O·J). Used to validate the lazy variant — and
  the device control plane (core/placement/device.py) — in tests.

Both host paths are the *differential oracles* of the device
implementations; allocations are tie-broken to the lowest flat (o', j)
index everywhere.

Candidates are (object o', cache j) pairs; a candidate is feasible while
cache j still has a free slot (matroid/cardinality constraint).
"""
from __future__ import annotations

import heapq

import numpy as np

from repro.core.objective import Instance, empty_slots


def greedy(inst: Instance, lazy: bool = True, verbose: bool = False,
           gain_tol: float = 1e-12) -> np.ndarray:
    """Run GREEDY to fill every slot; returns the allocation vector."""
    slots = empty_slots(inst)
    slot_cache = inst.slot_cache
    free = {j: list(np.where(slot_cache == j)[0][::-1])
            for j in range(inst.net.n_caches)}
    cur = np.repeat(inst.net.h_repo[:, None].astype(np.float64),
                    inst.cat.n, axis=1)                       # C(r, ∅)

    n_select = inst.net.total_slots
    if lazy:
        gains = inst.add_gain_all(cur)                        # (O, J)
        heap: list[tuple[float, int, int, int]] = []          # (-gain, ver, o, j)
        for j in range(inst.net.n_caches):
            if not np.isfinite(inst.net.H[:, j]).any():
                continue
            for o in range(inst.cat.n):
                if gains[o, j] > gain_tol:
                    heap.append((-float(gains[o, j]), 0, o, j))
        heapq.heapify(heap)
        version = 0
        picked = 0
        while picked < n_select and heap:
            negg, ver, o, j = heapq.heappop(heap)
            if not free[j]:
                continue
            if ver != version:                                # stale → refresh
                g = inst.add_gain_single(cur, o, j)
                if g <= gain_tol:
                    continue
                if heap and -g > heap[0][0]:                  # no longer top
                    heapq.heappush(heap, (-g, version, o, j))
                    continue
            # accept (o, j)
            s = free[j].pop()
            slots[s] = o
            cur = inst.updated_costs(cur, o, j)
            version += 1
            picked += 1
            if verbose and picked % 50 == 0:
                print(f"[greedy] {picked}/{n_select} cost="
                      f"{float(np.sum(inst.lam * cur)):.4f}")
    else:
        gains = inst.add_gain_all(cur)                        # once, O(O²·J)
        for picked in range(n_select):
            masked = gains.copy()
            for j in range(inst.net.n_caches):                # mask full caches
                if not free[j]:
                    masked[:, j] = -np.inf
            o, j = np.unravel_index(int(np.argmax(masked)), masked.shape)
            if masked[o, j] <= gain_tol:
                break                                         # no positive gain left
            s = free[j].pop()
            slots[s] = o
            new_cur = inst.updated_costs(cur, o, j)
            # incremental gain update: only requests whose cost dropped
            # contribute (satellite of the device refactor; exact up to
            # float association)
            gains += inst.add_gain_delta(cur, new_cur)
            cur = new_cur
    return slots
