"""Greedy → LocalSwap cascade (paper §3.3, Remark 1).

Running LOCALSWAP from the GREEDY solution yields a *locally optimal*
configuration whose gain still satisfies the 1/2 approximation bound:
LocalSwap only ever decreases C(A), hence only increases G(A), so
G(A_cascade) ≥ G(A_greedy) ≥ ½ · max_A G(A).
"""
from __future__ import annotations

import numpy as np

from repro.core.objective import Instance
from repro.core.placement.greedy import greedy
from repro.core.placement.localswap import _EPS, SwapState, localswap_polish


def greedy_then_localswap(inst: Instance, max_passes: int = 50,
                          lazy: bool = True, tol: float = _EPS) -> SwapState:
    slots = greedy(inst, lazy=lazy)
    # fill any slots greedy left empty (zero marginal gain) before polishing
    if np.any(slots < 0):
        slots = slots.copy()
        slots[slots < 0] = 0
    return localswap_polish(inst, slots, max_passes=max_passes, tol=tol)
