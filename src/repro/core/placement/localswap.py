"""LOCALSWAP placement (paper §3.3).

Upon an (emulated) request for object o entering at ingress i, compute
the best decrement in expected cost achievable by replacing one object y
currently stored at some cache *on the forwarding path of i* with o:

    ΔC ≜ min_y C(A ∪ {o@cache(y)} \\ {y}) − C(A)

and perform the swap iff ΔC < 0. Prop 3.3: for long enough request
sequences this converges w.p.1 to a *locally optimal* configuration.

Per-iteration cost is kept at the paper's O(N·O_R) bound via the
best/second-best decomposition:

    ΔC(y) = S_{j(y)} + corr(y)
    S_j      = Σ_r λ_r (min(c_r, a_r(j)) − c_r)        add o at cache j
    corr(y)  = Σ_{r: arg1_r = y} λ_r [min(b2_r, a_r(j(y)))
                                      − min(c_r, a_r(j(y)))]

where c_r = C(r, A), b2_r the second-best server of r, a_r(j) the cost of
serving r with the new (o, j). The correction sums touch each request at
most once, so the whole iteration is O(J·O_R) plus one O(K·O_R) refresh
per accepted swap.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.objective import Instance, random_slots

_EPS = 1e-9


def emulated_stream(inst: Instance, n_iters: int, seed: int,
                    slots0: np.ndarray | None = None,
                    requests: tuple[np.ndarray, np.ndarray] | None = None):
    """(rng, start slots, objs, ings) — the shared stream setup of every
    emulated-request policy (LOCALSWAP, NETDUEL, and their device
    twins). All of them consume the seeded rng in this exact order —
    start allocation first, then the request sample — which is what
    makes host and device trajectories comparable under a single seed.
    """
    rng = np.random.default_rng(seed)
    slots = random_slots(inst, rng) if slots0 is None \
        else np.asarray(slots0).copy()
    if requests is None:
        objs, ings = inst.dem.sample(n_iters, rng)
    else:
        objs, ings = requests
    return rng, slots, objs, ings


@dataclasses.dataclass
class SwapState:
    slots: np.ndarray                  # (K,) object ids, −1 empty
    best1: np.ndarray                  # (I, O) C(r, A)
    arg1: np.ndarray                   # (I, O) best slot or −1 (repository)
    best2: np.ndarray                  # (I, O)
    cost_trace: list = dataclasses.field(default_factory=list)
    n_swaps: int = 0

    @classmethod
    def init(cls, inst: Instance, slots: np.ndarray) -> "SwapState":
        b1, a1, b2 = inst.best_two(slots)
        return cls(slots=slots.copy(), best1=b1, arg1=a1, best2=b2)

    def refresh(self, inst: Instance) -> None:
        self.best1, self.arg1, self.best2 = inst.best_two(self.slots)

    def cost(self, inst: Instance) -> float:
        return float(np.sum(inst.lam * self.best1))


def swap_deltas(inst: Instance, st: SwapState, obj: int,
                ingress: int) -> np.ndarray:
    """ΔC(y) for replacing each slot y with ``obj`` (restricted to caches
    on the forwarding path of ``ingress``); +inf elsewhere. O(J·O_R)."""
    I, O = inst.lam.shape
    K = st.slots.shape[0]
    ca_col = inst.ca[:, obj]                                     # (O,)
    lam = inst.lam
    # a[i, o, j] for the J caches — J is small, keep explicit
    a = ca_col[None, :, None] + inst.net.H[:, None, :]           # (I, O, J)
    min_ca = np.minimum(st.best1[:, :, None], a)                 # (I, O, J)
    S = np.sum(lam[:, :, None] * (min_ca - st.best1[:, :, None]), axis=(0, 1))

    # corrections: requests whose best server is slot y
    delta = np.zeros(K, dtype=np.float64)
    jy = inst.slot_cache                                          # (K,)
    mask = st.arg1 >= 0
    ii, oo = np.nonzero(mask)
    yy = st.arg1[ii, oo]
    j_of_y = jy[yy]
    corr = (np.minimum(st.best2[ii, oo], a[ii, oo, j_of_y])
            - min_ca[ii, oo, j_of_y]) * lam[ii, oo]
    np.add.at(delta, yy, corr)
    delta += S[jy]
    # restrict to caches on the ingress path
    on_path = np.isfinite(inst.net.H[ingress])[jy]
    return np.where(on_path, delta, np.inf)


def _apply_swap(inst: Instance, st: SwapState, y: int, obj: int) -> None:
    st.slots[y] = obj
    st.refresh(inst)
    st.n_swaps += 1


def localswap_step(inst: Instance, st: SwapState, obj: int, ingress: int,
                   tol: float = _EPS) -> bool:
    """One LOCALSWAP iteration; returns True iff a swap occurred."""
    delta = swap_deltas(inst, st, obj, ingress)
    y = int(np.argmin(delta))
    if delta[y] < -tol:
        _apply_swap(inst, st, y, obj)
        return True
    return False


def localswap(inst: Instance, n_iters: int = 20000, seed: int = 0,
              slots0: np.ndarray | None = None,
              requests: tuple[np.ndarray, np.ndarray] | None = None,
              record_every: int = 0, tol: float = _EPS) -> SwapState:
    """Off-line LOCALSWAP driven by emulated requests sampled ∝ λ (§3.3).

    ``requests`` may supply an explicit (object_idx, ingress_idx) stream
    (the *online* mode — e.g. a real trace); otherwise ``n_iters``
    emulated requests are drawn from the instance demand. ``tol`` is the
    swap acceptance threshold (ΔC < −tol), exposed so differential tests
    can run host and device paths at one decision margin.
    """
    _, slots, objs, ings = emulated_stream(inst, n_iters, seed, slots0,
                                           requests)
    st = SwapState.init(inst, slots)
    for t in range(len(objs)):
        localswap_step(inst, st, int(objs[t]), int(ings[t]), tol=tol)
        if record_every and t % record_every == 0:
            st.cost_trace.append(st.cost(inst))
    return st


def localswap_polish(inst: Instance, slots: np.ndarray, max_passes: int = 50,
                     tol: float = _EPS) -> SwapState:
    """Deterministic LOCALSWAP: sweep all requested objects round-robin
    until a full pass makes no swap → certified local optimum.

    Used for (i) the Greedy→LocalSwap cascade of Remark 1, and (ii) tests
    of Prop 3.3's fixed-point property.
    """
    st = SwapState.init(inst, slots)
    active = [(int(o), int(i)) for i, o in zip(*np.nonzero(inst.lam > 0))]
    for _ in range(max_passes):
        swapped = False
        for o, i in active:
            swapped |= localswap_step(inst, st, o, i, tol=tol)
        if not swapped:
            break
    return st


def is_locally_optimal(inst: Instance, slots: np.ndarray,
                       tol: float = 1e-7) -> bool:
    """Brute-force check of the paper's local-optimality definition: no
    single (replace one object in one cache) move lowers C(A)."""
    base = inst.total_cost(slots)
    for y in range(slots.shape[0]):
        for o in range(inst.cat.n):
            trial = slots.copy()
            trial[y] = o
            if inst.total_cost(trial) < base - tol:
                return False
    return True


def constrained_localswap(inst: Instance, allowed: np.ndarray,
                          n_iters: int = 20000, seed: int = 0) -> SwapState:
    """LOCALSWAP with per-slot admission constraints (paper §6.2: leaf
    stores only objects within distance d* of the barycenter, parent only
    beyond). ``allowed[s, o]`` = may object o occupy slot s?"""
    rng = np.random.default_rng(seed)
    # start from a feasible random allocation
    slots = np.empty(inst.net.total_slots, dtype=np.int64)
    for s in range(slots.shape[0]):
        choices = np.nonzero(allowed[s])[0]
        slots[s] = rng.choice(choices) if choices.size else 0
    st = SwapState.init(inst, slots)
    objs, ings = inst.dem.sample(n_iters, rng)
    for t in range(len(objs)):
        o, i = int(objs[t]), int(ings[t])
        delta = swap_deltas(inst, st, o, i)
        delta = np.where(allowed[:, o], delta, np.inf)
        y = int(np.argmin(delta))
        if delta[y] < -_EPS:
            _apply_swap(inst, st, y, o)
    return st
