"""Device-resident GREEDY / LOCALSWAP (paper §3.2–3.3) on the batched
gain oracle.

The NumPy implementations in greedy.py / localswap.py stay as the
differential oracles; the functions here implement the *same decision
rules* — identical lowest-(o', j) / lowest-slot tie-breaks, identical
accept thresholds — while keeping every O(O·J)-sized object (the gain
table, the per-request cost matrix, the swap deltas) on the
accelerator as jitted ops over a
:class:`repro.core.objective.DeviceInstance`. Allocations are
bit-identical to the host oracles whenever decision margins exceed f32
resolution (what tests/test_device_placement.py asserts on its
well-separated instances); on degenerate near-tie instances the f32
device sums and f64 host sums can straddle a threshold and diverge —
see the tolerance note below and the observed-demand caveat in
serve/engine.py.

* :func:`device_greedy` — batched lazy greedy. One full oracle launch
  (``DeviceInstance.gains``; mesh-sharded over the candidate axis when
  configured) seeds an upper-bound table; each step re-evaluates the
  stale top-k candidates in one batched ``gain_at`` call until the
  argmax entry is fresh (submodularity makes stale entries valid upper
  bounds, so this accepts exactly the textbook-greedy candidate —
  including its lowest-flat-index tie-break, since ``jnp.argmax``
  returns the first maximum and a stale tie at a lower index is always
  refreshed before acceptance).
* :func:`device_localswap` / :func:`device_localswap_polish` — the
  ΔC(y) sweep of localswap.py's best/second-best decomposition as one
  jitted launch per emulated request: the S_j term is the negated gain
  oracle restricted to the requested object, the corrections a masked
  segment-sum over each request's best slot.
* :func:`device_greedy_then_localswap` — the Remark-1 cascade.

Decision tolerances: ``GAIN_TOL`` mirrors the host greedy default
(1e-12) so both paths stop on the same nominal threshold — note that
*both* paths see residual rounding gains near zero (the host's f64
sums of f32-rounded costs carry ~1e-8-relative noise, the device's f32
sums ~1e-7), so the stopping boundary is only comparable where real
gains dominate. ``SWAP_TOL`` (LOCALSWAP accept margin) is raised above
the f32 noise floor of normalized-λ instances because a swap decision
compares a full rate-weighted sum against −tol. Differential tests
pass one explicit tol to both paths and use instances whose decision
margins exceed these floors.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.objective import (DeviceInstance, _gain_at_device,
                                  random_slots)

GAIN_TOL = 1e-12        # matches the host greedy default
SWAP_TOL = 1e-6         # f32-safe LOCALSWAP acceptance threshold
DEFAULT_TOPK = 64


# ------------------------------------------------------------------ greedy
@jax.jit
def _select_candidate(ub, fresh, col_open):
    """(argmax index, its masked value, its freshness) over open columns.
    ``jnp.argmax`` keeps the first maximum → lowest flat (o', j) index."""
    mask = col_open[jnp.arange(ub.shape[0]) % col_open.shape[0]]
    masked = jnp.where(mask, ub, -jnp.inf)
    idx = jnp.argmax(masked)
    return idx, masked[idx], fresh[idx]


@functools.partial(jax.jit, static_argnames=("k", "metric", "gamma",
                                             "has_ca"))
def _refresh_topk(coords, ca, lam, cur, H, ub, fresh, col_open, k,
                  metric: str, gamma: float, has_ca: bool):
    """Re-evaluate the k highest stale upper bounds in one batched
    oracle call; entries of closed columns are never refreshed."""
    J = col_open.shape[0]
    stale = col_open[jnp.arange(ub.shape[0]) % J] & ~fresh
    vals, idxs = jax.lax.top_k(jnp.where(stale, ub, -jnp.inf), k)
    g = _gain_at_device(coords, ca, lam, cur, H, idxs // J, idxs % J,
                        metric, gamma, has_ca)
    valid = vals > -jnp.inf
    ub = ub.at[idxs].set(jnp.where(valid, g, ub[idxs]))
    fresh = fresh.at[idxs].set(valid | fresh[idxs])
    return ub, fresh


def device_greedy(dinst: DeviceInstance, topk: int = DEFAULT_TOPK,
                  gain_tol: float = GAIN_TOL,
                  verbose: bool = False) -> np.ndarray:
    """Batched lazy GREEDY on the device gain oracle; returns the same
    allocation vector as ``greedy(inst)`` (slots left at −1 when no
    candidate has gain above ``gain_tol``)."""
    O, J = dinst.n_objects, dinst.n_caches
    K = int(dinst.host.net.total_slots)
    slot_cache = dinst.host.slot_cache
    free = {j: list(np.where(slot_cache == j)[0][::-1]) for j in range(J)}
    slots = np.full(K, -1, dtype=np.int64)

    cur = dinst.initial_costs()
    ub = dinst.gains(cur).astype(jnp.float32).ravel()      # exact → fresh
    fresh = jnp.ones((O * J,), bool)
    col_open = jnp.asarray([bool(free[j]) for j in range(J)])
    ca = dinst.ca if dinst.ca is not None else jnp.zeros((0, 0), jnp.float32)
    k = min(topk, O * J)

    for picked in range(K):
        while True:
            idx, val, is_fresh = _select_candidate(ub, fresh, col_open)
            if float(val) <= gain_tol:
                return slots                               # no gain left
            if bool(is_fresh):
                break
            ub, fresh = _refresh_topk(
                dinst.coords, ca, dinst.lam, cur, dinst.H, ub, fresh,
                col_open, k, dinst.metric, dinst.gamma, dinst.ca is not None)
        o, j = divmod(int(idx), J)
        s = free[j].pop()
        slots[s] = o
        cur = dinst.apply_pick(cur, o, j)
        fresh = jnp.zeros((O * J,), bool)                  # all stale
        if not free[j]:
            col_open = col_open.at[j].set(False)
        if verbose and (picked + 1) % 50 == 0:
            print(f"[device_greedy] {picked + 1}/{K} cost="
                  f"{float(jnp.sum(dinst.lam * cur)):.4f}")
    return slots


# --------------------------------------------------------------- localswap
@functools.partial(jax.jit, static_argnames=("metric", "gamma", "has_ca"))
def _swap_argmin_device(coords, ca, lam, H, slot_cache, best1, arg1, best2,
                        obj, ingress, metric: str, gamma: float,
                        has_ca: bool):
    """(argmin slot y, ΔC(y)) of replacing slot y with ``obj`` for a
    request at ``ingress`` — the device mirror of
    localswap.swap_deltas + np.argmin (lowest-slot tie-break)."""
    if has_ca:
        col = ca[:, obj]
    else:
        from repro.core import costs
        col = costs.approx_cost(coords, coords[obj][None, :],
                                metric, gamma)[:, 0]
    a = col[None, :, None] + H[:, None, :]                 # (I, O, J)
    min_ca = jnp.minimum(best1[:, :, None], a)
    S = jnp.sum(lam[:, :, None] * (min_ca - best1[:, :, None]), axis=(0, 1))
    K = slot_cache.shape[0]
    mask = arg1 >= 0
    yy = jnp.where(mask, arg1, 0)
    j_of_y = slot_cache[yy]                                # (I, O)
    a_sel = jnp.take_along_axis(a, j_of_y[:, :, None], axis=2)[:, :, 0]
    m_sel = jnp.take_along_axis(min_ca, j_of_y[:, :, None], axis=2)[:, :, 0]
    corr = jnp.where(mask, (jnp.minimum(best2, a_sel) - m_sel) * lam, 0.0)
    delta = jnp.zeros((K,), jnp.float32).at[yy.ravel()].add(corr.ravel())
    delta = delta + S[slot_cache]
    on_path = jnp.isfinite(H[ingress])[slot_cache]
    delta = jnp.where(on_path, delta, jnp.inf)
    y = jnp.argmin(delta)
    return y, delta[y]


@dataclasses.dataclass
class DeviceSwapState:
    """Device-resident twin of localswap.SwapState."""
    slots: jax.Array                   # (K,) i32 object ids (no empties)
    best1: jax.Array                   # (I, O)
    arg1: jax.Array                    # (I, O) best slot or −1
    best2: jax.Array                   # (I, O)
    cost_trace: list = dataclasses.field(default_factory=list)
    n_swaps: int = 0

    @classmethod
    def init(cls, dinst: DeviceInstance, slots) -> "DeviceSwapState":
        slots = jnp.asarray(slots, jnp.int32)
        b1, a1, b2 = dinst.best_two(slots)
        return cls(slots=slots, best1=b1, arg1=a1, best2=b2)

    def refresh(self, dinst: DeviceInstance) -> None:
        self.best1, self.arg1, self.best2 = dinst.best_two(self.slots)

    def cost(self, dinst: DeviceInstance) -> float:
        return float(jnp.sum(dinst.lam * self.best1))

    @property
    def slots_np(self) -> np.ndarray:
        return np.asarray(self.slots).astype(np.int64)


def device_localswap_step(dinst: DeviceInstance, st: DeviceSwapState,
                          obj: int, ingress: int,
                          tol: float = SWAP_TOL) -> bool:
    """One LOCALSWAP iteration on device; returns True iff a swap
    occurred (same accept rule ΔC < −tol and lowest-slot tie-break as
    the host step)."""
    ca = dinst.ca if dinst.ca is not None else jnp.zeros((0, 0), jnp.float32)
    y, dy = _swap_argmin_device(
        dinst.coords, ca, dinst.lam, dinst.H, dinst.slot_cache,
        st.best1, st.arg1, st.best2, jnp.asarray(obj, jnp.int32),
        jnp.asarray(ingress, jnp.int32), dinst.metric, dinst.gamma,
        dinst.ca is not None)
    if float(dy) < -tol:
        st.slots = st.slots.at[y].set(obj)
        st.refresh(dinst)
        st.n_swaps += 1
        return True
    return False


def device_localswap(dinst: DeviceInstance, n_iters: int = 20000,
                     seed: int = 0, slots0: np.ndarray | None = None,
                     requests: tuple[np.ndarray, np.ndarray] | None = None,
                     record_every: int = 0,
                     tol: float = SWAP_TOL) -> DeviceSwapState:
    """Off-line LOCALSWAP on device, driven by the same host-sampled
    emulated request stream as ``localswap(inst, …)`` (identical rng →
    identical requests → differential comparability)."""
    rng = np.random.default_rng(seed)
    slots = random_slots(dinst.host, rng) if slots0 is None \
        else np.asarray(slots0).copy()
    st = DeviceSwapState.init(dinst, slots)
    if requests is None:
        objs, ings = dinst.host.dem.sample(n_iters, rng)
    else:
        objs, ings = requests
    for t in range(len(objs)):
        device_localswap_step(dinst, st, int(objs[t]), int(ings[t]), tol=tol)
        if record_every and t % record_every == 0:
            st.cost_trace.append(st.cost(dinst))
    return st


def device_localswap_polish(dinst: DeviceInstance, slots: np.ndarray,
                            max_passes: int = 50,
                            tol: float = SWAP_TOL) -> DeviceSwapState:
    """Deterministic LOCALSWAP sweep (localswap_polish's device twin):
    round-robin over all requested objects until a full pass makes no
    swap."""
    st = DeviceSwapState.init(dinst, slots)
    lam = dinst.host.lam
    active = [(int(o), int(i)) for i, o in zip(*np.nonzero(lam > 0))]
    for _ in range(max_passes):
        swapped = False
        for o, i in active:
            swapped |= device_localswap_step(dinst, st, o, i, tol=tol)
        if not swapped:
            break
    return st


def device_greedy_then_localswap(dinst: DeviceInstance,
                                 max_passes: int = 50,
                                 topk: int = DEFAULT_TOPK,
                                 tol: float = SWAP_TOL) -> DeviceSwapState:
    """GREEDY → LOCALSWAP cascade (Remark 1) entirely on device."""
    slots = device_greedy(dinst, topk=topk)
    if np.any(slots < 0):
        slots = slots.copy()
        slots[slots < 0] = 0
    return device_localswap_polish(dinst, slots, max_passes=max_passes,
                                   tol=tol)
