"""Device-resident GREEDY / LOCALSWAP (paper §3.2–3.3) on the batched
gain oracle.

The NumPy implementations in greedy.py / localswap.py stay as the
differential oracles; the functions here implement the *same decision
rules* — identical lowest-(o', j) / lowest-slot tie-breaks, identical
accept thresholds — while keeping every O(O·J)-sized object (the gain
table, the per-request cost matrix, the swap deltas) on the
accelerator as jitted ops over a
:class:`repro.core.objective.DeviceInstance`. Allocations are
bit-identical to the host oracles whenever decision margins exceed f32
resolution (what tests/test_device_placement.py asserts on its
well-separated instances); on degenerate near-tie instances the f32
device sums and f64 host sums can straddle a threshold and diverge —
see the tolerance note below and the observed-demand caveat in
serve/engine.py.

* :func:`device_greedy` — batched lazy greedy. One full oracle launch
  (``DeviceInstance.gains``; mesh-sharded over the candidate axis when
  configured) seeds an upper-bound table; each step re-evaluates the
  stale top-k candidates in one batched ``gain_at`` call until the
  argmax entry is fresh (submodularity makes stale entries valid upper
  bounds, so this accepts exactly the textbook-greedy candidate —
  including its lowest-flat-index tie-break, since ``jnp.argmax``
  returns the first maximum and a stale tie at a lower index is always
  refreshed before acceptance). With ``scan=True`` (default, PR 5) the
  whole accept loop runs as a single ``lax.while_loop`` launch
  (``_greedy_scan_loop``) with device-resident free-slot bookkeeping —
  no per-pick host sync, so the jit-dispatch bound the per-step path
  hits below ~10³ candidates is gone; ``scan=False`` keeps the
  per-step path as the differential twin (bit-identical by the scan
  property test).
* :func:`device_localswap` / :func:`device_localswap_polish` — the
  ΔC(y) sweep of localswap.py's best/second-best decomposition; with
  ``scan=True`` (default) a whole emulated-request window is one
  ``lax.scan`` launch (``_localswap_scan``; an accepted swap re-arms
  the serving tables under ``lax.cond``, request-axis mesh-sharded via
  ``objective.sharded_best_two`` when the instance carries shard
  axes), with ``scan=False`` one jitted launch per request: the S_j
  term is the negated gain oracle restricted to the requested object,
  the corrections a masked segment-sum over each request's best slot.
* :func:`device_greedy_then_localswap` — the Remark-1 cascade.

C_a consistency: every *incremental* op here (``gain_at``,
``apply_pick``, the swap-delta column, the serving tables) computes
streamed distances with the shape-stable form
(costs.pairwise_distance_stable), so one (request, candidate) pair has
one canonical f32 value across all of them — a candidate already
folded into ``cur`` refreshes to an exact-zero gain and the greedy
stopping point matches the host even in the zero-demand tail (the MXU
form's batch-shape-dependent cancellation used to leave phantom
positive gains there). The full tile oracles (kernels/knn/gains.py)
keep the MXU form: they only seed upper bounds.

Decision tolerances: ``GAIN_TOL`` mirrors the host greedy default
(1e-12) so both paths stop on the same nominal threshold — note that
*both* paths see residual rounding gains near zero (the host's f64
sums of f32-rounded costs carry ~1e-8-relative noise, the device's f32
sums ~1e-7), so the stopping boundary is only comparable where real
gains dominate. ``SWAP_TOL`` (LOCALSWAP accept margin) is raised above
the f32 noise floor of normalized-λ instances because a swap decision
compares a full rate-weighted sum against −tol. Differential tests
pass one explicit tol to both paths and use instances whose decision
margins exceed these floors.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.objective import (DeviceInstance, _apply_pick_device,
                                  _gain_at_device)

GAIN_TOL = 1e-12        # matches the host greedy default
SWAP_TOL = 1e-6         # f32-safe LOCALSWAP acceptance threshold
DEFAULT_TOPK = 64


# ------------------------------------------------------------------ greedy
@jax.jit
def _select_candidate(ub, fresh, col_open):
    """(argmax index, its masked value, its freshness) over open columns.
    ``jnp.argmax`` keeps the first maximum → lowest flat (o', j) index."""
    mask = col_open[jnp.arange(ub.shape[0]) % col_open.shape[0]]
    masked = jnp.where(mask, ub, -jnp.inf)
    idx = jnp.argmax(masked)
    return idx, masked[idx], fresh[idx]


@functools.partial(jax.jit, static_argnames=("k", "metric", "gamma",
                                             "has_ca"))
def _refresh_topk(coords, ca, lam, cur, H, ub, fresh, col_open, k,
                  metric: str, gamma: float, has_ca: bool):
    """Re-evaluate the k highest stale upper bounds in one batched
    oracle call; entries of closed columns are never refreshed."""
    J = col_open.shape[0]
    stale = col_open[jnp.arange(ub.shape[0]) % J] & ~fresh
    vals, idxs = jax.lax.top_k(jnp.where(stale, ub, -jnp.inf), k)
    g = _gain_at_device(coords, ca, lam, cur, H, idxs // J, idxs % J,
                        metric, gamma, has_ca)
    valid = vals > -jnp.inf
    ub = ub.at[idxs].set(jnp.where(valid, g, ub[idxs]))
    fresh = fresh.at[idxs].set(valid | fresh[idxs])
    return ub, fresh


def _slot_fill_tables(dinst: DeviceInstance):
    """(slots_by_cache (J, max_cap) i32, cap (J,) i32): slot ids of each
    cache in ascending order — the exact fill order of the host paths'
    ``free[j].pop()`` (descending list, pop from the end)."""
    slot_cache = dinst.host.slot_cache
    caps = dinst.host.net.capacities
    J = dinst.n_caches
    tbl = np.zeros((J, max(int(caps.max()), 1)), np.int32)
    for j in range(J):
        idx = np.where(slot_cache == j)[0]
        tbl[j, :idx.size] = idx
    return jnp.asarray(tbl), jnp.asarray(caps, jnp.int32)


@functools.partial(jax.jit, static_argnames=("n_slots", "k", "metric",
                                             "gamma", "has_ca"))
def _greedy_scan_loop(coords, ca, lam, H, cur, ub, fresh, col_open,
                      slots_by_cache, cap, n_slots: int, gain_tol,
                      k: int, metric: str, gamma: float, has_ca: bool):
    """The whole GREEDY accept loop as one ``lax.while_loop`` launch: the
    select → (refresh-stale | accept) alternation of the per-step path
    with the free-slot bookkeeping device-resident (``slots_by_cache``
    ascending-fill tables + per-cache counters), so no scalar syncs to
    the host until the final allocation. Decision-for-decision identical
    to the per-step path — same ``_select_candidate``/``_refresh_topk``/
    ``_apply_pick_device`` ops in the same order, ``gain_tol`` compared
    in f32 on both (the scan property test of tests/test_properties.py
    asserts bit-identical allocations at every ``topk``)."""
    J = col_open.shape[0]

    def cond(s):
        return ~s[-1]

    def body(s):
        ub, fresh, col_open, cur, fill, slots, picked, done = s
        idx, val, is_fresh = _select_candidate(ub, fresh, col_open)
        stop = val <= gain_tol

        def do_stop(s):
            ub, fresh, col_open, cur, fill, slots, picked, _ = s
            return (ub, fresh, col_open, cur, fill, slots, picked,
                    jnp.bool_(True))

        def do_refresh(s):
            ub, fresh, col_open, cur, fill, slots, picked, done = s
            ub, fresh = _refresh_topk(coords, ca, lam, cur, H, ub, fresh,
                                      col_open, k, metric, gamma, has_ca)
            return (ub, fresh, col_open, cur, fill, slots, picked, done)

        def do_accept(s):
            ub, fresh, col_open, cur, fill, slots, picked, done = s
            o = (idx // J).astype(jnp.int32)
            j = (idx % J).astype(jnp.int32)
            slot = slots_by_cache[j, fill[j]]
            slots = slots.at[slot].set(o)
            cur = _apply_pick_device(coords, ca, H, cur, o, j,
                                     metric, gamma, has_ca)
            fresh = jnp.zeros_like(fresh)
            fill = fill.at[j].add(1)
            col_open = col_open.at[j].set(fill[j] < cap[j])
            picked = picked + 1
            return (ub, fresh, col_open, cur, fill, slots, picked,
                    picked >= n_slots)

        return jax.lax.cond(
            stop, do_stop,
            lambda s: jax.lax.cond(is_fresh, do_accept, do_refresh, s), s)

    state = (ub, fresh, col_open, cur, jnp.zeros((J,), jnp.int32),
             jnp.full((n_slots,), -1, jnp.int32), jnp.int32(0),
             jnp.bool_(False))
    return jax.lax.while_loop(cond, body, state)[5]


def device_greedy(dinst: DeviceInstance, topk: int = DEFAULT_TOPK,
                  gain_tol: float = GAIN_TOL, scan: bool = True,
                  verbose: bool = False,
                  quantize: bool = False) -> np.ndarray:
    """Batched lazy GREEDY on the device gain oracle; returns the same
    allocation vector as ``greedy(inst)`` (slots left at −1 when no
    candidate has gain above ``gain_tol``).

    ``scan=True`` (default) runs the whole accept loop as a single
    ``lax.while_loop`` launch after the one full-oracle launch — no
    per-pick host sync, which removes the jit-dispatch bound the
    per-step path (``scan=False``, kept as the differential twin) hits
    below ~10³ candidates.

    ``quantize=True`` seeds the upper-bound table from the int8
    lower-bound oracle instead of the exact one. Quantized gains are
    admissible *upper* bounds, so they enter the lazy loop marked stale
    — every accepted candidate is still re-scored exactly
    (``_refresh_topk``'s ``gain_at``) before acceptance, which keeps the
    allocation bit-identical to the exact-seeded run while the seeding
    launch reads 4× fewer candidate bytes."""
    O, J = dinst.n_objects, dinst.n_caches
    K = int(dinst.host.net.total_slots)
    slot_cache = dinst.host.slot_cache
    free = {j: list(np.where(slot_cache == j)[0][::-1]) for j in range(J)}
    slots = np.full(K, -1, dtype=np.int64)

    cur = dinst.initial_costs()
    ub = dinst.gains(cur, quantize=quantize).astype(jnp.float32).ravel()
    # exact seeds are fresh; quantized seeds are stale upper bounds
    fresh = jnp.full((O * J,), not quantize, bool)
    col_open = jnp.asarray([bool(free[j]) for j in range(J)])
    ca = dinst.ca if dinst.ca is not None else jnp.zeros((0, 0), jnp.float32)
    k = min(topk, O * J)

    if scan:
        tbl, cap = _slot_fill_tables(dinst)
        out = _greedy_scan_loop(
            dinst.coords, ca, dinst.lam, dinst.H, cur, ub, fresh, col_open,
            tbl, cap, K, jnp.float32(gain_tol), k, dinst.metric,
            dinst.gamma, dinst.ca is not None)
        return np.asarray(out).astype(np.int64)

    gain_tol = float(np.float32(gain_tol))   # the scanned path's compare
    for picked in range(K):
        while True:
            idx, val, is_fresh = _select_candidate(ub, fresh, col_open)
            if float(val) <= gain_tol:
                return slots                               # no gain left
            if bool(is_fresh):
                break
            ub, fresh = _refresh_topk(
                dinst.coords, ca, dinst.lam, cur, dinst.H, ub, fresh,
                col_open, k, dinst.metric, dinst.gamma, dinst.ca is not None)
        o, j = divmod(int(idx), J)
        s = free[j].pop()
        slots[s] = o
        cur = dinst.apply_pick(cur, o, j)
        fresh = jnp.zeros((O * J,), bool)                  # all stale
        if not free[j]:
            col_open = col_open.at[j].set(False)
        if verbose and (picked + 1) % 50 == 0:
            print(f"[device_greedy] {picked + 1}/{K} cost="
                  f"{float(jnp.sum(dinst.lam * cur)):.4f}")
    return slots


# --------------------------------------------------------------- localswap
@functools.partial(jax.jit, static_argnames=("metric", "gamma", "has_ca"))
def _swap_argmin_device(coords, ca, lam, H, slot_cache, best1, arg1, best2,
                        obj, ingress, metric: str, gamma: float,
                        has_ca: bool):
    """(argmin slot y, ΔC(y)) of replacing slot y with ``obj`` for a
    request at ``ingress`` — the device mirror of
    localswap.swap_deltas + np.argmin (lowest-slot tie-break)."""
    if has_ca:
        col = ca[:, obj]
    else:
        from repro.core import costs
        col = costs.approx_cost_stable(coords, coords[obj][None, :],
                                       metric, gamma)[:, 0]
    a = col[None, :, None] + H[:, None, :]                 # (I, O, J)
    min_ca = jnp.minimum(best1[:, :, None], a)
    S = jnp.sum(lam[:, :, None] * (min_ca - best1[:, :, None]), axis=(0, 1))
    K = slot_cache.shape[0]
    mask = arg1 >= 0
    yy = jnp.where(mask, arg1, 0)
    j_of_y = slot_cache[yy]                                # (I, O)
    a_sel = jnp.take_along_axis(a, j_of_y[:, :, None], axis=2)[:, :, 0]
    m_sel = jnp.take_along_axis(min_ca, j_of_y[:, :, None], axis=2)[:, :, 0]
    corr = jnp.where(mask, (jnp.minimum(best2, a_sel) - m_sel) * lam, 0.0)
    delta = jnp.zeros((K,), jnp.float32).at[yy.ravel()].add(corr.ravel())
    delta = delta + S[slot_cache]
    on_path = jnp.isfinite(H[ingress])[slot_cache]
    delta = jnp.where(on_path, delta, jnp.inf)
    y = jnp.argmin(delta)
    return y, delta[y]


@dataclasses.dataclass
class DeviceSwapState:
    """Device-resident twin of localswap.SwapState.

    Carries the *pre-fold* best-two tables (b1p/a1p/b2p/a2p, over the
    slot axis only) next to the folded serving tables: the pre-fold
    witnesses are what ``objective.best_two_delta`` keys its dirty-row
    detection on, so the scanned paths can re-arm incrementally after a
    swap instead of rebuilding the full (I, O, K) minimum."""
    slots: jax.Array                   # (K,) i32 object ids (no empties)
    best1: jax.Array                   # (I, O)
    arg1: jax.Array                    # (I, O) best slot or −1
    best2: jax.Array                   # (I, O)
    b1p: jax.Array                     # (I, O) pre-fold best
    a1p: jax.Array                     # (I, O) pre-fold best slot
    b2p: jax.Array                     # (I, O) pre-fold second best
    a2p: jax.Array                     # (I, O) pre-fold second-best slot
    cost_trace: list = dataclasses.field(default_factory=list)
    n_swaps: int = 0

    @classmethod
    def init(cls, dinst: DeviceInstance, slots) -> "DeviceSwapState":
        from repro.core.objective import fold_best_two
        slots = jnp.asarray(slots, jnp.int32)
        b1p, a1p, b2p, a2p = dinst.best_two_tables(slots)
        b1, a1, b2 = fold_best_two(b1p, a1p, b2p, dinst.h_repo)
        return cls(slots=slots, best1=b1, arg1=a1, best2=b2,
                   b1p=b1p, a1p=a1p, b2p=b2p, a2p=a2p)

    def refresh(self, dinst: DeviceInstance) -> None:
        from repro.core.objective import fold_best_two
        self.b1p, self.a1p, self.b2p, self.a2p = \
            dinst.best_two_tables(self.slots)
        self.best1, self.arg1, self.best2 = fold_best_two(
            self.b1p, self.a1p, self.b2p, dinst.h_repo)

    def cost(self, dinst: DeviceInstance) -> float:
        return float(jnp.sum(dinst.lam * self.best1))

    @property
    def slots_np(self) -> np.ndarray:
        return np.asarray(self.slots).astype(np.int64)


def device_localswap_step(dinst: DeviceInstance, st: DeviceSwapState,
                          obj: int, ingress: int,
                          tol: float = SWAP_TOL) -> bool:
    """One LOCALSWAP iteration on device; returns True iff a swap
    occurred (same accept rule ΔC < −tol and lowest-slot tie-break as
    the host step)."""
    ca = dinst.ca if dinst.ca is not None else jnp.zeros((0, 0), jnp.float32)
    y, dy = _swap_argmin_device(
        dinst.coords, ca, dinst.lam, dinst.H, dinst.slot_cache,
        st.best1, st.arg1, st.best2, jnp.asarray(obj, jnp.int32),
        jnp.asarray(ingress, jnp.int32), dinst.metric, dinst.gamma,
        dinst.ca is not None)
    # f32 accept compare — the same rule the scanned path applies on
    # device, so per-step and scanned trajectories are bit-identical
    if float(dy) < -float(np.float32(tol)):
        st.slots = st.slots.at[y].set(obj)
        st.refresh(dinst)
        st.n_swaps += 1
        return True
    return False


@functools.partial(jax.jit, static_argnames=("metric", "gamma", "has_ca",
                                             "mesh", "axes", "incremental",
                                             "emit_cost"))
def _localswap_scan(coords, ca, lam, H, h_repo, slot_cache, carry,
                    objs, ings, tol, metric: str, gamma: float,
                    has_ca: bool, mesh, axes, incremental: bool = True,
                    emit_cost: bool = True):
    """A whole emulated-request window as one ``lax.scan`` launch: each
    step is the per-step path's ``_swap_argmin_device`` + f32 accept
    compare, with an accepted swap re-arming the best1/arg1/best2
    tables under ``lax.cond``. Emits (swapped, C(A)) per step (the cost
    emit statically gated by ``emit_cost`` — the (I, O) sum per step
    otherwise dominates once the re-arm is incremental).

    ``incremental=True`` (default) re-arms through
    ``objective.best_two_delta`` on the carried pre-fold tables — only
    rows whose best-two witness touches the swapped slot are recomputed
    — and is bit-identical to the full-rebuild re-arm
    (``incremental=False``, the differential twin, request-axis
    mesh-sharded when the instance carries shard axes)."""
    from repro.core.objective import (_best_two_delta_jit,
                                      _fold_repo_rows, best_two_tables,
                                      default_delta_cap)

    K = int(slot_cache.shape[0])
    cap = default_delta_cap(int(lam.shape[1]))

    def rearm(slots_new, y, pre):
        if incremental:
            npre = _best_two_delta_jit(
                coords, ca, *pre, slots_new, y[None].astype(jnp.int32),
                slot_cache, H, metric=metric, gamma=gamma, has_ca=has_ca,
                cap=min(cap, int(lam.shape[1])), n_slots=K,
                mesh=mesh, axes=axes)
        else:
            npre = best_two_tables(coords, ca, slots_new, slot_cache, H,
                                   metric, gamma, has_ca, mesh, axes)
        return (*npre, *_fold_repo_rows(npre[0], npre[1], npre[2], h_repo))

    def step(c, x):
        slots, b1p, a1p, b2p, a2p, best1, arg1, best2, n_swaps = c
        o, i = x
        y, dy = _swap_argmin_device(coords, ca, lam, H, slot_cache,
                                    best1, arg1, best2, o, i,
                                    metric, gamma, has_ca)
        do = dy < -tol
        slots = jax.lax.cond(do, lambda s: s.at[y].set(o), lambda s: s,
                             slots)
        b1p, a1p, b2p, a2p, best1, arg1, best2 = jax.lax.cond(
            do, lambda _: rearm(slots, y, (b1p, a1p, b2p, a2p)),
            lambda _: (b1p, a1p, b2p, a2p, best1, arg1, best2), None)
        n_swaps = n_swaps + do.astype(jnp.int32)
        cost = jnp.sum(lam * best1) if emit_cost else jnp.float32(0)
        return (slots, b1p, a1p, b2p, a2p, best1, arg1, best2, n_swaps), \
            (do, cost)

    return jax.lax.scan(step, carry, (objs, ings))


def _run_localswap_scan(dinst: DeviceInstance, st: DeviceSwapState,
                        objs: np.ndarray, ings: np.ndarray, tol: float,
                        incremental: bool = True, emit_cost: bool = True):
    """Advance a DeviceSwapState through one scanned request window;
    returns the per-step (swapped, cost) traces."""
    ca = dinst.ca if dinst.ca is not None else jnp.zeros((0, 0), jnp.float32)
    mesh = dinst.mesh if dinst.n_shards > 1 else None
    axes = dinst.axes if dinst.n_shards > 1 else ()
    carry = (jnp.asarray(st.slots, jnp.int32), st.b1p, st.a1p, st.b2p,
             st.a2p, st.best1, st.arg1, st.best2, jnp.int32(st.n_swaps))
    carry, (swapped, costs) = _localswap_scan(
        dinst.coords, ca, dinst.lam, dinst.H, dinst.h_repo,
        dinst.slot_cache, carry, jnp.asarray(objs, jnp.int32),
        jnp.asarray(ings, jnp.int32), jnp.float32(tol), dinst.metric,
        dinst.gamma, dinst.ca is not None, mesh, axes,
        incremental=incremental, emit_cost=emit_cost)
    (st.slots, st.b1p, st.a1p, st.b2p, st.a2p,
     st.best1, st.arg1, st.best2) = carry[:8]
    st.n_swaps = int(carry[8])
    return np.asarray(swapped), np.asarray(costs)


def device_localswap(dinst: DeviceInstance, n_iters: int = 20000,
                     seed: int = 0, slots0: np.ndarray | None = None,
                     requests: tuple[np.ndarray, np.ndarray] | None = None,
                     record_every: int = 0, scan: bool = True,
                     tol: float = SWAP_TOL,
                     incremental: bool = True) -> DeviceSwapState:
    """Off-line LOCALSWAP on device, driven by the same host-sampled
    emulated request stream as ``localswap(inst, …)`` (identical rng →
    identical requests → differential comparability).

    ``scan=True`` (default) runs the whole window as one ``lax.scan``
    launch instead of one jitted step per request — the dispatch-bound
    regime of the per-step path (``scan=False``, kept as the
    differential twin) disappears. Same accept rule and tie-breaks, so
    trajectories are bit-identical between the two paths."""
    from repro.core.placement.localswap import emulated_stream
    _, slots, objs, ings = emulated_stream(dinst.host, n_iters, seed,
                                           slots0, requests)
    st = DeviceSwapState.init(dinst, slots)
    if scan:
        _, costs = _run_localswap_scan(dinst, st, objs, ings, tol,
                                       incremental=incremental,
                                       emit_cost=bool(record_every))
        if record_every:
            st.cost_trace = [float(c) for t, c in enumerate(costs)
                             if t % record_every == 0]
        return st
    for t in range(len(objs)):
        device_localswap_step(dinst, st, int(objs[t]), int(ings[t]), tol=tol)
        if record_every and t % record_every == 0:
            st.cost_trace.append(st.cost(dinst))
    return st


def device_localswap_polish(dinst: DeviceInstance, slots: np.ndarray,
                            max_passes: int = 50, scan: bool = True,
                            tol: float = SWAP_TOL,
                            incremental: bool = True) -> DeviceSwapState:
    """Deterministic LOCALSWAP sweep (localswap_polish's device twin):
    round-robin over all requested objects until a full pass makes no
    swap. ``scan=True`` runs each pass as one scan launch (one host
    sync per pass — the swap counter — instead of one per request)."""
    st = DeviceSwapState.init(dinst, slots)
    lam = dinst.host.lam
    active = [(int(o), int(i)) for i, o in zip(*np.nonzero(lam > 0))]
    if scan and active:
        objs = np.asarray([o for o, _ in active])
        ings = np.asarray([i for _, i in active])
        for _ in range(max_passes):
            before = st.n_swaps
            _run_localswap_scan(dinst, st, objs, ings, tol,
                                incremental=incremental, emit_cost=False)
            if st.n_swaps == before:
                break
        return st
    for _ in range(max_passes):
        swapped = False
        for o, i in active:
            swapped |= device_localswap_step(dinst, st, o, i, tol=tol)
        if not swapped:
            break
    return st


def device_greedy_then_localswap(dinst: DeviceInstance,
                                 max_passes: int = 50,
                                 topk: int = DEFAULT_TOPK,
                                 scan: bool = True,
                                 tol: float = SWAP_TOL) -> DeviceSwapState:
    """GREEDY → LOCALSWAP cascade (Remark 1) entirely on device."""
    slots = device_greedy(dinst, topk=topk, scan=scan)
    if np.any(slots < 0):
        slots = slots.copy()
        slots[slots < 0] = 0
    return device_localswap_polish(dinst, slots, max_passes=max_passes,
                                   scan=scan, tol=tol)
