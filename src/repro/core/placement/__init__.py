from repro.core.placement.greedy import greedy
from repro.core.placement.localswap import localswap, localswap_polish
from repro.core.placement.netduel import netduel
from repro.core.placement.cascade import greedy_then_localswap
from repro.core.placement import continuous

__all__ = [
    "greedy", "localswap", "localswap_polish", "netduel",
    "greedy_then_localswap", "continuous",
]
