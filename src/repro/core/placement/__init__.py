"""Placement algorithms — the *control plane* of the similarity-cache
network (paper §3).

The repo splits eq. (4)'s machinery in two:

* **data plane** (kernels/knn, core/simcache) — serving-path lookups:
  fused segmented-1-NN Pallas kernels, mesh-sharded, LSH-pruned.
* **control plane** (this package) — solving the offline placement
  problem that decides *what* those kernels serve. Two implementations
  of each algorithm:

  - host NumPy (``greedy``, ``localswap``, ``localswap_polish``,
    ``greedy_then_localswap``) — the readable differential oracles;
  - device-resident (``device_greedy``, ``device_localswap``,
    ``device_localswap_polish``, ``device_greedy_then_localswap`` in
    placement/device.py) — the same algorithms over a
    ``core.objective.DeviceInstance`` and the batched gain oracle of
    kernels/knn/gains.py (mesh-sharded over the candidate axis at
    scale), returning **bit-identical allocations** (lowest-(o', j) /
    lowest-slot tie-breaks shared by construction). This is the path
    ``serve.engine.refresh_placement`` takes by default.

``netduel`` (§5) is the online λ-unaware policy; ``continuous`` the
§4 continuous-relaxation analysis; ``warmstart`` turns that analysis
into the near-O(O) production path (classify the topology, solve the
continuous program in milliseconds, band-map per Prop 4.2, polish with
a bounded device-LOCALSWAP window) — the route past 10⁶-object
catalogs where the O(O·J) discrete solvers cannot run.
"""
from repro.core.placement.greedy import greedy
from repro.core.placement.localswap import localswap, localswap_polish
from repro.core.placement.netduel import (DuelPlane, device_netduel,
                                          netduel)
from repro.core.placement.cascade import greedy_then_localswap
from repro.core.placement.device import (device_greedy,
                                         device_greedy_then_localswap,
                                         device_localswap,
                                         device_localswap_polish)
from repro.core.placement import continuous
from repro.core.placement import warmstart
from repro.core.placement.warmstart import (WarmStartReport,
                                            classify_topology, warm_start)

__all__ = [
    "greedy", "localswap", "localswap_polish", "netduel",
    "device_netduel", "DuelPlane",
    "greedy_then_localswap", "continuous", "device_greedy",
    "device_localswap", "device_localswap_polish",
    "device_greedy_then_localswap",
    "warmstart", "warm_start", "classify_topology", "WarmStartReport",
]
