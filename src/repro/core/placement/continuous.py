"""Continuous-limit placement (paper §4).

Implements, faithfully:

* ζ(γ) and the single-cache optimum, eqs. (5)–(8)   → :func:`zeta`,
  :func:`single_cache_cost`, :func:`single_cache_allocation`;
* the chain-topology convex program (11)             → :func:`chain_cost`,
  :func:`solve_chain` (mirror descent / exponentiated gradient in JAX) and
  :func:`solve_chain_thresholds` (exploits the Prop 4.2 threshold
  structure: cache j serves a contiguous popularity band);
* equi-depth trees, Prop 4.4                         → :func:`tree_cost`
  (replicate the chain solution; cost is degree-1 homogeneous in λ);
* the tandem network with arrivals at both nodes, eqs. (14)–(15)
  → :func:`tandem_both_cost`, :func:`solve_tandem_both`,
  :func:`tandem_both_grad` (hand-coded (15), used to cross-check
  autodiff);
* the uniform-λ shifted-tessellation geometry of Fig. 2:
  z = max{0, (r−h)/2}, Δc = (8/3)·z³ for γ=1         → closed form
  :func:`shifted_tessellation_cost` plus a general-γ numerical
  integration :func:`shifted_tessellation_cost_numeric` (validates the
  closed form and extends Fig. 6 beyond γ=1).

Conventions: M regions of unit area with piecewise-constant rates
``lams`` (the paper's discretization); caches 1..N have sizes ``ks`` and
cumulative reach costs ``hs`` (h₁ = 0 at the ingress leaf); the
repository is an extra virtual cache with k = ∞ and cost ``h_repo``.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np


def zeta(gamma: float) -> float:
    """ζ ≜ 2^{(2−γ)/2}/(γ+2) — the norm-1 square-cell constant (§4.1)."""
    return 2.0 ** ((2.0 - gamma) / 2.0) / (gamma + 2.0)


def cell_cost(r: float, lam: float, gamma: float) -> float:
    """c(r) = 4 λ r^{γ+2}/(γ+2): approximation cost inside one square cell
    of radius r under norm-1 (eq. 5, two-dimensional domain)."""
    return 4.0 * lam * r ** (gamma + 2.0) / (gamma + 2.0)


# ------------------------------------------------------------- single cache
def single_cache_allocation(lams: np.ndarray, k: float, gamma: float) -> np.ndarray:
    """Optimal slots per region, k_i ∝ λ_i^{2/(γ+2)} (Lagrange, §4.1)."""
    w = lams ** (2.0 / (gamma + 2.0))
    return k * w / w.sum()


def single_cache_cost(lams: np.ndarray, k: float, gamma: float) -> float:
    """min C(k) = ζ k^{−γ/2} (Σ_i λ_i^{2/(γ+2)})^{(γ+2)/2}  (eq. 7)."""
    s = float(np.sum(lams ** (2.0 / (gamma + 2.0))))
    return zeta(gamma) * k ** (-gamma / 2.0) * s ** ((gamma + 2.0) / 2.0)


# ------------------------------------------------------------------- chains
@dataclasses.dataclass(frozen=True)
class ChainSpec:
    ks: tuple            # (N,) cache sizes
    hs: tuple            # (N,) cumulative costs from the ingress, h[0] = 0
    h_repo: float        # cost of the authoritative repository
    gamma: float = 1.0

    @property
    def n(self) -> int:
        return len(self.ks)


def chain_cost(w: jnp.ndarray, lams: jnp.ndarray, spec: ChainSpec) -> jnp.ndarray:
    """Objective (11). ``w``: (M, N+1) rows on the simplex; column j < N is
    the fraction of region i served by cache j, column N the repository."""
    g = spec.gamma
    beta = 2.0 / (g + 2.0)
    lb = lams ** beta
    cost = 0.0
    for j in range(spec.n):
        wj = w[:, j]
        mass = jnp.sum(wj * lb)
        cost += zeta(g) * spec.ks[j] ** (-g / 2.0) * \
            jnp.maximum(mass, 0.0) ** (1.0 / beta)
        cost += spec.hs[j] * jnp.sum(wj * lams)
    cost += spec.h_repo * jnp.sum(w[:, spec.n] * lams)
    return cost


@functools.partial(jax.jit, static_argnames=("spec", "iters"))
def _solve_chain_md(lams: jnp.ndarray, spec: ChainSpec, iters: int,
                    lr: float) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Exponentiated-gradient (mirror) descent on the per-region simplices.

    (11) is convex over the product of simplices, so mirror descent with a
    modest step count converges to the global optimum; JAX autodiff
    supplies ∇_w of (11) exactly.
    """
    M = lams.shape[0]
    w = jnp.full((M, spec.n + 1), 1.0 / (spec.n + 1))
    grad_fn = jax.grad(chain_cost)

    def body(t, w):
        gradw = grad_fn(w, lams, spec)
        step = lr / jnp.sqrt(1.0 + t / 50.0)
        # per-region gradient normalization: each simplex row gets its own
        # scale, so heterogeneous magnitudes (e.g. huge h_repo) cannot
        # freeze the other coordinates
        gradw = gradw / (jnp.max(jnp.abs(gradw), axis=1, keepdims=True)
                         + 1e-12)
        logw = jnp.log(jnp.maximum(w, 1e-30)) - step * gradw
        logw -= jax.scipy.special.logsumexp(logw, axis=1, keepdims=True)
        return jnp.exp(logw)

    w = jax.lax.fori_loop(0, iters, body, w)
    return w, chain_cost(w, lams, spec)


def solve_chain(lams: np.ndarray, spec: ChainSpec, iters: int = 4000,
                lr: float = 1.0) -> tuple[np.ndarray, float]:
    w, c = _solve_chain_md(jnp.asarray(lams, jnp.float32), spec, iters, lr)
    return np.asarray(w), float(c)


def _interp_prefix(cum: np.ndarray, pos: np.ndarray) -> np.ndarray:
    """Linear interpolation of a prefix-sum array at fractional indices.

    Equals ``np.interp(pos, np.arange(len(cum)), cum)`` for pos clipped
    to [0, len(cum)−1] — but O(1) per point instead of materializing an
    O(M)-sized arange per call, which is what keeps the golden-section
    coordinate descent of :func:`solve_chain_thresholds` at millisecond
    scale on 10⁶–10⁷-region instances (the warm-start regime)."""
    idx = np.clip(np.floor(pos).astype(np.int64), 0, cum.shape[0] - 2)
    frac = pos - idx
    return cum[idx] + frac * (cum[idx + 1] - cum[idx])


def _band_cost(lams_sorted: np.ndarray, cum_lb: np.ndarray, cum_l: np.ndarray,
               splits: np.ndarray, spec: ChainSpec) -> float:
    """Cost of the threshold allocation given fractional split points.

    ``splits`` are N nondecreasing cumulative coordinates in [0, M]; cache
    j serves the (fractional) band [splits[j-1], splits[j]) of the
    λ-descending-sorted regions; the repository serves the tail.
    ``cum_lb``/``cum_l`` are prefix sums of λ^{2/(γ+2)} and λ with a
    leading 0, linearly interpolated for fractional boundaries (a region
    split across caches contributes proportionally — the "portion of a
    region" of Prop 4.2).
    """
    g = spec.gamma
    pos = np.concatenate([[0.0], splits, [float(len(lams_sorted))]])
    pos = np.maximum.accumulate(np.clip(pos, 0.0, len(lams_sorted)))
    ilb = _interp_prefix(cum_lb, pos)
    il = _interp_prefix(cum_l, pos)
    cost = 0.0
    for j in range(spec.n):
        W = max(ilb[j + 1] - ilb[j], 0.0)
        lam_mass = max(il[j + 1] - il[j], 0.0)
        cost += zeta(g) * spec.ks[j] ** (-g / 2.0) * W ** ((g + 2.0) / 2.0)
        cost += spec.hs[j] * lam_mass
    cost += spec.h_repo * max(il[spec.n + 1] - il[spec.n], 0.0)
    return float(cost)


def solve_chain_thresholds(lams: np.ndarray, spec: ChainSpec,
                           sweeps: int = 60, grid: int = 96
                           ) -> tuple[np.ndarray, float, np.ndarray]:
    """Prop 4.2 structure: coordinate descent over N split points of the
    popularity-sorted axis (each 1-D problem solved by golden section).

    Returns (splits, cost, order) with ``order`` the λ-descending region
    permutation; the popularity thresholds λ*_j of Prop 4.2 are
    ``lams[order][ceil(splits)]``.
    """
    order = np.argsort(-lams, kind="stable")
    ls = lams[order].astype(np.float64)
    g = spec.gamma
    cum_lb = np.concatenate([[0.0], np.cumsum(ls ** (2.0 / (g + 2.0)))])
    cum_l = np.concatenate([[0.0], np.cumsum(ls)])
    M = float(len(ls))
    splits = np.linspace(M / (spec.n + 1), M * spec.n / (spec.n + 1), spec.n)

    def cost_at(j, x):
        trial = splits.copy()
        trial[j] = x
        return _band_cost(ls, cum_lb, cum_l, trial, spec)

    gr = (np.sqrt(5.0) - 1.0) / 2.0
    for _ in range(sweeps):
        moved = 0.0
        for j in range(spec.n):
            lo = splits[j - 1] if j > 0 else 0.0
            hi = splits[j + 1] if j + 1 < spec.n else M
            # golden-section over [lo, hi] (cost is unimodal along each
            # coordinate by convexity of (11) restricted to the band line)
            a, b = lo, hi
            c1, c2 = b - gr * (b - a), a + gr * (b - a)
            f1, f2 = cost_at(j, c1), cost_at(j, c2)
            for _ in range(grid):
                if f1 < f2:
                    b, c2, f2 = c2, c1, f1
                    c1 = b - gr * (b - a)
                    f1 = cost_at(j, c1)
                else:
                    a, c1, f1 = c1, c2, f2
                    c2 = a + gr * (b - a)
                    f2 = cost_at(j, c2)
            xnew = 0.5 * (a + b)
            moved = max(moved, abs(xnew - splits[j]))
            splits[j] = xnew
        if moved < 1e-10 * M:
            break
    return splits, _band_cost(ls, cum_lb, cum_l, splits, spec), order


def thresholds_to_w(lams: np.ndarray, splits: np.ndarray, order: np.ndarray,
                    n_caches: int) -> np.ndarray:
    """Convert Prop 4.2 split points into the w matrix of (11).

    Splits are sanitized the same way :func:`_band_cost` evaluates them —
    clipped to [0, M] and made nondecreasing — so out-of-range inputs
    (e.g. total cache capacity exceeding the catalog mass, which pushes
    the unconstrained optimum past M) still yield a row-stochastic w:
    every region row sums to 1 and column j's mass equals band j's width.
    """
    M = len(lams)
    w = np.zeros((M, n_caches + 1))
    pos = np.concatenate([[0.0], np.asarray(splits, np.float64), [float(M)]])
    pos = np.maximum.accumulate(np.clip(pos, 0.0, float(M)))
    for j in range(n_caches + 1):
        lo, hi = pos[j], pos[j + 1]
        for i in range(int(np.floor(lo)), int(np.ceil(hi))):
            frac = min(hi, i + 1.0) - max(lo, float(i))
            if frac > 0:
                w[order[i], j] += frac
    return w


# -------------------------------------------------------- equi-depth trees
def tree_cost(lams: np.ndarray, betas: np.ndarray, spec: ChainSpec,
              use_thresholds: bool = True) -> float:
    """Prop 4.4: optimal equi-depth-tree cost = Σ_ℓ β_ℓ × (chain cost for
    the base rate λ). Each level replicates the chain allocation."""
    if use_thresholds:
        _, c, _ = solve_chain_thresholds(lams, spec)
    else:
        _, c = solve_chain(lams, spec)
    return float(np.sum(betas) * c)


# ------------------------------------- tandem with arrivals at both nodes
def tandem_both_cost(w1: jnp.ndarray, lams: jnp.ndarray, k1: float, k2: float,
                     h: float, beta: float, gamma: float) -> jnp.ndarray:
    """Eq. (14): leaf keeps fraction w1_i of region i, forwards the rest
    (its cell-border requests) to the parent; the parent also serves its
    own arrivals β·λ. No repository (the parent covers the domain)."""
    g = gamma
    e = 2.0 / (2.0 + g)
    lb = lams ** e
    t1 = zeta(g) * k1 ** (-g / 2.0) * \
        jnp.maximum(jnp.sum(lb * w1), 0.0) ** (1.0 / e)
    inner = beta + jnp.maximum(1.0 - w1, 0.0) ** ((g + 2.0) / 2.0)
    t2 = zeta(g) * k2 ** (-g / 2.0) * \
        jnp.sum(lb * inner ** e) ** (1.0 / e)
    t3 = h * jnp.sum(lams * (1.0 - w1))
    return t1 + t2 + t3


def tandem_both_grad(w1: np.ndarray, lams: np.ndarray, k1: float, k2: float,
                     h: float, beta: float, gamma: float) -> np.ndarray:
    """Hand-coded gradient (15) — used to cross-check JAX autodiff."""
    g = gamma
    e = 2.0 / (2.0 + g)
    lb = lams ** e
    A = np.sum(lb * w1)
    term1 = zeta(g) * k1 ** (-g / 2.0) * (1.0 / e) * A ** (g / 2.0) * lb
    inner = beta + (1.0 - w1) ** ((g + 2.0) / 2.0)
    B = np.sum(lb * inner ** e)
    dinner = -((g + 2.0) / 2.0) * (1.0 - w1) ** (g / 2.0)
    term2 = zeta(g) * k2 ** (-g / 2.0) * (1.0 / e) * B ** (g / 2.0) * \
        lb * e * inner ** (e - 1.0) * dinner
    term3 = -h * lams
    return term1 + term2 + term3


@functools.partial(jax.jit, static_argnames=("iters",))
def _solve_tandem_both(lams, k1, k2, h, beta, gamma, iters, lr):
    """Projected gradient on w1 ∈ [0,1]^M (convex in w1 → global opt)."""
    M = lams.shape[0]
    w1 = jnp.full((M,), 0.5)
    grad_fn = jax.grad(tandem_both_cost)

    def body(t, w1):
        gw = grad_fn(w1, lams, k1, k2, h, beta, gamma)
        step = lr / jnp.sqrt(1.0 + t / 100.0)
        gw = gw / (jnp.max(jnp.abs(gw)) + 1e-12)
        # keep strictly below 1: at w1=1 with β=0 the parent term's
        # derivative d(x^e)/dx|_{x→0} = ∞ would poison the next gradient
        return jnp.clip(w1 - step * gw, 0.0, 1.0 - 1e-6)

    w1 = jax.lax.fori_loop(0, iters, body, w1)
    return w1, tandem_both_cost(w1, lams, k1, k2, h, beta, gamma)


def solve_tandem_both(lams: np.ndarray, k1: float, k2: float, h: float,
                      beta: float, gamma: float = 1.0, iters: int = 4000,
                      lr: float = 0.05) -> tuple[np.ndarray, float]:
    w1, c = _solve_tandem_both(jnp.asarray(lams, jnp.float32),
                               float(k1), float(k2), float(h), float(beta),
                               float(gamma), iters, lr)
    return np.asarray(w1), float(c)


# ------------------------------------ Fig 2: shifted regular tessellations
def shifted_tessellation_cost(k: int, h: float, area: float, lam: float,
                              beta: float = 1.0) -> float:
    """Closed-form total cost of the Fig 2 allocation, γ = 1, uniform λ.

    Leaf and parent each hold k slots; leaf cells are norm-1 squares of
    radius r = sqrt(area/(2k)); parent centroids sit at leaf-cell corners.
    z = max{0, (r−h)/2}; each parent slot reduces the leaf-arrival cost by
    Δc = λ·(8/3)·z³ (paper §4.4). Parent arrivals (rate β·λ per unit
    area) are approximated by the parent's own tessellation.
    """
    r = np.sqrt(area / (2.0 * k))
    z = max(0.0, (r - h) / 2.0)
    leaf_cost = k * cell_cost(r, lam, 1.0)            # k·(4/3)λr³
    saving = k * lam * (8.0 / 3.0) * z ** 3
    parent_cost = beta * k * cell_cost(r, lam, 1.0)
    return leaf_cost - saving + parent_cost


def shifted_tessellation_cost_numeric(k: int, h: float, area: float,
                                      lam: float, beta: float = 1.0,
                                      gamma: float = 1.0,
                                      samples: int = 512) -> float:
    """General-γ numerical version (quadrature over one tessellation
    period): leaf arrivals pay min(d_leaf^γ, d_parent^γ + h); parent
    arrivals pay d_parent^γ. Validates the γ=1 closed form and supplies
    the curves of Fig 6 for other γ."""
    r = np.sqrt(area / (2.0 * k))
    # period cell [0, 2r)²; leaf centers at (a·r, b·r), a+b even; parent
    # centers at a+b odd (the corners — maximally shifted, Fig 2)
    xs = (np.arange(samples) + 0.5) * (2.0 * r / samples)
    X, Y = np.meshgrid(xs, xs, indexing="ij")
    d_leaf = np.full_like(X, np.inf)
    d_par = np.full_like(X, np.inf)
    for a in range(-1, 4):
        for b in range(-1, 4):
            d = np.abs(X - a * r) + np.abs(Y - b * r)
            if (a + b) % 2 == 0:
                d_leaf = np.minimum(d_leaf, d)
            else:
                d_par = np.minimum(d_par, d)
    leaf_point = np.minimum(d_leaf ** gamma, d_par ** gamma + h)
    par_point = d_par ** gamma
    cell_area = (2.0 * r) ** 2
    n_cells = area / cell_area
    w = cell_area / X.size
    return float(n_cells * w * lam *
                 (np.sum(leaf_point) + beta * np.sum(par_point)))
