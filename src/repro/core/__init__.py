"""Core library: the paper's contribution (similarity-cache placement).

Public API:
  costs, topology, catalog, demand — problem building blocks
  objective.Instance               — eqs. (1)-(4)
  placement.greedy / localswap / netduel / continuous / cascade
  simcache.SimCacheNetwork         — runtime lookup/forward/serve
  scenarios                        — general-graph scenario generation
  routing.StrategyPlane            — on-path LRU routing strategies
"""
from repro.core import (costs, topology, catalog, demand, objective,
                        scenarios, routing)

__all__ = ["costs", "topology", "catalog", "demand", "objective",
           "scenarios", "routing"]
