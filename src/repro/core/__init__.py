"""Core library: the paper's contribution (similarity-cache placement).

Public API:
  costs, topology, catalog, demand — problem building blocks
  objective.Instance               — eqs. (1)-(4)
  placement.greedy / localswap / netduel / continuous / cascade
  simcache.SimCacheNetwork         — runtime lookup/forward/serve
"""
from repro.core import costs, topology, catalog, demand, objective

__all__ = ["costs", "topology", "catalog", "demand", "objective"]
