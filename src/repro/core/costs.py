"""Dissimilarity and retrieval cost models (paper §2).

A request r = (o, i) served by approximizer α = (o', j) costs

    C(r, α) = C_a(o, o') + h(i, j)

where ``C_a`` is a non-negative dissimilarity cost and ``h`` the retrieval
(network) cost. The paper's two instances are both supported:

* **discrete** — ``C_a`` is an |X|×|X| matrix (here: computed from object
  coordinates on a grid, or given explicitly);
* **continuous** — objects are points of R^p and ``C_a(x, y) = d(x, y)^γ``
  for a metric d (norm-1 or norm-2 here, as in the paper).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

METRICS = ("l1", "l2", "l2sq")


def pairwise_distance(x: Array, y: Array, metric: str = "l1") -> Array:
    """Pairwise distances between rows of ``x`` (n, p) and ``y`` (m, p).

    ``l2sq`` is the squared Euclidean distance (cheaper; monotone in l2 so
    argmins agree — used by lookup paths that only need the argmin).
    """
    if metric == "l1":
        return jnp.sum(jnp.abs(x[:, None, :] - y[None, :, :]), axis=-1)
    if metric in ("l2", "l2sq"):
        # MXU-friendly form: |x|^2 + |y|^2 - 2 x.y  (one matmul).
        x2 = jnp.sum(x * x, axis=-1)[:, None]
        y2 = jnp.sum(y * y, axis=-1)[None, :]
        d2 = x2 + y2 - 2.0 * (x @ y.T)
        d2 = jnp.maximum(d2, 0.0)
        return d2 if metric == "l2sq" else jnp.sqrt(d2)
    raise ValueError(f"unknown metric {metric!r}; expected one of {METRICS}")


def approx_cost_from_distance(dist: Array, gamma: float) -> Array:
    """C_a = f(d) with the paper's power law f(d) = d^γ (γ ≥ 0)."""
    if gamma == 1.0:
        return dist
    return jnp.power(jnp.maximum(dist, 0.0), gamma)


def approx_cost(x: Array, y: Array, metric: str = "l1", gamma: float = 1.0) -> Array:
    """Pairwise approximation-cost matrix C_a(x_r, y_c) = d(x_r, y_c)^γ."""
    return approx_cost_from_distance(pairwise_distance(x, y, metric), gamma)


def pairwise_distance_stable(x: Array, y: Array, metric: str = "l1") -> Array:
    """Shape-stable pairwise distances: the broadcast (no-matmul) form.

    Each (row, col) pair reduces its D differences independently of the
    batch shape, so the same pair yields the *same f32 value* whether
    computed as a single column, a k-candidate batch, a row block, or
    the full matrix. The MXU form of :func:`pairwise_distance` is much
    faster, but its |x|²+|y|²−2x·y cancellation depends on the compiled
    contraction, so the same pair evaluated at different batch shapes
    can differ by ~|x|²·eps — enough to leave phantom positive gains on
    candidates already folded into a running cost vector. The
    incremental control-plane ops (``objective._gain_at_device`` /
    ``_apply_pick_device`` and friends) therefore use this form; the
    data-plane kernels and the full tile oracles keep the MXU form.
    Memory: materializes an (n, m, D) temporary — callers keep one of
    n, m small.
    """
    if metric == "l1":
        return jnp.sum(jnp.abs(x[:, None, :] - y[None, :, :]), axis=-1)
    if metric in ("l2", "l2sq"):
        d2 = jnp.sum((x[:, None, :] - y[None, :, :]) ** 2, axis=-1)
        return d2 if metric == "l2sq" else jnp.sqrt(d2)
    raise ValueError(f"unknown metric {metric!r}; expected one of {METRICS}")


def approx_cost_stable(x: Array, y: Array, metric: str = "l1",
                       gamma: float = 1.0) -> Array:
    """Shape-stable C_a (see :func:`pairwise_distance_stable`)."""
    return approx_cost_from_distance(pairwise_distance_stable(x, y, metric),
                                     gamma)


@functools.partial(jax.jit, static_argnames=("metric", "gamma"))
def _approx_cost_jit(x, y, metric, gamma):
    return approx_cost(x, y, metric, gamma)


def approx_cost_np(x: np.ndarray, y: np.ndarray, metric: str = "l1",
                   gamma: float = 1.0, block: int = 4096) -> np.ndarray:
    """Blocked host-side C_a for large catalogs (avoids one giant jit alloc)."""
    out = np.empty((x.shape[0], y.shape[0]), dtype=np.float32)
    for s in range(0, x.shape[0], block):
        xs = jnp.asarray(x[s:s + block], dtype=jnp.float32)
        out[s:s + block] = np.asarray(
            _approx_cost_jit(xs, jnp.asarray(y, dtype=jnp.float32), metric, gamma))
    return out


CostFn = Callable[[Array, Array], Array]

INF = np.float32(np.inf)
