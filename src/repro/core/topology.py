"""Cache-network topologies (paper §2).

A :class:`CacheNetwork` is a set of cache nodes plus one repository.
Requests enter at *ingress* nodes and may be served by any cache on the
(unique) forwarding path from the ingress to the repository — the paper's
routing constraint, encoded by setting h(i, j) = +inf for j off-path
(cf. the remark after Prop 3.2).

Provided constructors cover every topology the paper analyses:

* ``chain(N)``        — §4.2: requests at cache 1, forwarded along 1..N.
* ``tandem()``        — the 2-cache chain of §3.4 / §6.1 (leaf + parent).
* ``tandem_both()``   — §4.4: same tandem, arrivals at both nodes.
* ``equi_depth_tree`` — §4.3: L leaves at depth D, arrivals at leaves.
* ``star`` / custom   — general networks for the "structure is lost" study.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class CacheNetwork:
    """Static description of a similarity-cache network.

    Attributes:
      n_caches: number of cache nodes (the repository is *not* a cache).
      capacities: (n_caches,) slots per cache, k_i.
      ingress: (n_ingress,) cache node ids where requests enter.
      H: (n_ingress, n_caches) retrieval cost h(i, j); +inf if cache j is
         not on the forwarding path of requests entering at ingress i.
      h_repo: (n_ingress,) cost to the authoritative repository (= C(r, ∅)
         since the repository approximates at zero cost, paper §2).
      name: label used in logs/benchmarks.
    """

    n_caches: int
    capacities: np.ndarray
    ingress: np.ndarray
    H: np.ndarray
    h_repo: np.ndarray
    name: str = "custom"

    def __post_init__(self):
        assert self.capacities.shape == (self.n_caches,)
        assert self.H.shape == (len(self.ingress), self.n_caches)
        assert self.h_repo.shape == (len(self.ingress),)
        assert np.all(self.h_repo > 0), "repository must cost something to reach"

    @property
    def n_ingress(self) -> int:
        return len(self.ingress)

    @property
    def total_slots(self) -> int:
        return int(self.capacities.sum())

    # -- slot layout: slot s belongs to cache slot_cache[s] ---------------
    def slot_layout(self) -> np.ndarray:
        """(total_slots,) cache id owning each slot (contiguous per cache)."""
        return np.repeat(np.arange(self.n_caches), self.capacities)


def chain(n: int, k: int | Sequence[int], h_hop: float | Sequence[float],
          h_repo: float) -> CacheNetwork:
    """Chain of ``n`` caches; requests enter at cache 0 (paper's cache 1).

    ``h_hop`` is either a scalar per-hop cost or the per-node cumulative
    costs h_j (len n, h_0 typically 0). The repository sits after cache
    n-1 at cumulative cost ``h_repo``.
    """
    caps = np.full(n, k, dtype=np.int64) if np.isscalar(k) else np.asarray(k, np.int64)
    if np.isscalar(h_hop):
        h = np.arange(n, dtype=np.float64) * float(h_hop)
    else:
        h = np.asarray(h_hop, dtype=np.float64)
    assert h.shape == (n,) and np.all(np.diff(h) >= 0), "h_j must be nondecreasing"
    return CacheNetwork(
        n_caches=n, capacities=caps,
        ingress=np.array([0]), H=h[None, :].astype(np.float32),
        h_repo=np.array([h_repo], dtype=np.float32), name=f"chain{n}")


def tandem(k_leaf: int, k_parent: int, h: float, h_repo: float) -> CacheNetwork:
    """Two caches in tandem, arrivals at the leaf only (§6.1, Fig 3/4)."""
    net = chain(2, [k_leaf, k_parent], [0.0, h], h_repo)
    return dataclasses.replace(net, name="tandem")


def tandem_both(k_leaf: int, k_parent: int, h: float, h_repo: float) -> CacheNetwork:
    """Tandem with arrivals at both leaf (ingress 0) and parent (ingress 1).

    Paper §4.4 / Fig 5: leaf can forward to parent (cost h); the parent
    cannot forward down, so the leaf cache is off-path for its requests.
    """
    H = np.array([[0.0, h],
                  [np.inf, 0.0]], dtype=np.float32)
    return CacheNetwork(
        n_caches=2, capacities=np.array([k_leaf, k_parent]),
        ingress=np.array([0, 1]), H=H,
        h_repo=np.array([h_repo + h, h_repo], dtype=np.float32),
        name="tandem_both")


def equi_depth_tree(branching: int, depth: int, k_per_level: Sequence[int],
                    h_per_level: Sequence[float], h_repo: float) -> CacheNetwork:
    """Equi-depth tree (§4.3): ``branching**depth`` leaves, arrivals at leaves.

    ``k_per_level[d]``/``h_per_level[d]`` give capacity and cumulative cost
    of the cache met after climbing ``d`` levels from a leaf (d=0 is the
    leaf itself, h_per_level[0] == 0). The root's parent is the repository.
    """
    assert len(k_per_level) == depth + 1 == len(h_per_level)
    assert h_per_level[0] == 0.0
    # enumerate nodes level by level, leaves first
    nodes, level_of = [], []
    counts = [branching ** (depth - d) for d in range(depth + 1)]  # per level
    offsets = np.concatenate([[0], np.cumsum(counts)])
    n_caches = int(offsets[-1])
    caps = np.concatenate([
        np.full(counts[d], k_per_level[d], dtype=np.int64) for d in range(depth + 1)])
    n_leaves = counts[0]
    H = np.full((n_leaves, n_caches), np.inf, dtype=np.float32)
    for leaf in range(n_leaves):
        idx = leaf
        for d in range(depth + 1):
            node = int(offsets[d] + idx)
            H[leaf, node] = h_per_level[d]
            idx //= branching
    return CacheNetwork(
        n_caches=n_caches, capacities=caps,
        ingress=np.arange(n_leaves), H=H,
        h_repo=np.full(n_leaves, h_repo, dtype=np.float32),
        name=f"tree_b{branching}_d{depth}")


def single_cache(k: int, h_repo: float) -> CacheNetwork:
    """Degenerate 1-cache network (the setting of [12], used in tests)."""
    net = chain(1, [k], [0.0], h_repo)
    return dataclasses.replace(net, name="single")


def tpu_hierarchy(k_device: int, k_pod: int, k_global: int,
                  h_ici: float, h_dcn: float, h_model: float) -> CacheNetwork:
    """The hardware-adapted 3-level hierarchy of DESIGN.md §2.

    Level 0: per-device HBM shard (h=0); level 1: pod-level index reached
    over ICI (h_ici); level 2: cross-pod index over DCN (h_dcn); the
    repository is the model itself (h_model = amortized forward cost).
    Costs are in the same unit as C_a after calibration (serve/engine.py).
    """
    net = chain(3, [k_device, k_pod, k_global], [0.0, h_ici, h_dcn], h_model)
    return dataclasses.replace(net, name="tpu_hier")
