"""Training loop with checkpoint/restart (fault tolerance deliverable).

Single-host by design in this container; the same loop drives the pjit
train_step on a real mesh (launch/train.py). Restart semantics: on
startup the trainer resumes from the newest checkpoint and the
deterministic data pipeline replays exactly the batches it owes, so a
crash at any point is invisible in the loss curve (tested in
tests/test_trainer_ft.py by literally killing and resuming mid-run).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax

from repro.checkpoint import latest_step, restore_for_mesh, save
from repro.configs.base import ArchConfig
from repro.data.pipeline import SyntheticLMData
from repro.models import model as model_api
from repro.models.sharding_api import NO_SHARD, ShardPolicy
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 300
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 100
    log_every: int = 20
    seed: int = 0
    opt: AdamWConfig = AdamWConfig(lr=1e-3, weight_decay=0.01)
    warmup: int = 50


def make_step(cfg: ArchConfig, opt: AdamWConfig, warmup: int, total: int,
              shard: ShardPolicy = NO_SHARD) -> Callable:
    fwd = model_api.make_train_forward(cfg, shard)

    def step_fn(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            fwd, has_aux=True)(params, batch)
        lr = cosine_schedule(opt_state["step"], warmup=warmup, total=total)
        params, opt_state = adamw_update(grads, opt_state, params, opt,
                                         lr_scale=lr)
        return params, opt_state, loss, metrics
    return jax.jit(step_fn, donate_argnums=(0, 1))


def train(cfg: ArchConfig, tcfg: TrainConfig, data: SyntheticLMData,
          resume: bool = True, stop_after: int | None = None,
          log: Callable = print) -> dict:
    """Run (or resume) training; returns {'losses': [...], 'step': n}."""
    step0 = latest_step(tcfg.ckpt_dir) if resume else None
    if step0 is not None:
        step0, state = restore_for_mesh(tcfg.ckpt_dir, None)
        params, opt_state = state["params"], state["opt"]
        # npz restores python scalars as 0-d arrays; normalize step dtype
        opt_state["step"] = jax.numpy.asarray(opt_state["step"],
                                              jax.numpy.int32)
        log(f"[train] resumed from step {step0}")
    else:
        step0 = 0
        params = model_api.init_params(cfg, tcfg.seed)
        opt_state = adamw_init(params, tcfg.opt)

    step_fn = make_step(cfg, tcfg.opt, tcfg.warmup, tcfg.steps)
    losses = []
    t0 = time.time()
    end = tcfg.steps if stop_after is None else min(tcfg.steps,
                                                    step0 + stop_after)
    for step in range(step0, end):
        batch = jax.tree.map(jax.numpy.asarray, data.batch_at(step))
        params, opt_state, loss, metrics = step_fn(params, opt_state, batch)
        losses.append(float(loss))
        if step % tcfg.log_every == 0:
            dt = time.time() - t0
            log(f"[train] step {step:5d} loss {float(loss):.4f} "
                f"ce {float(metrics['ce']):.4f} ({dt:.1f}s)")
        if (step + 1) % tcfg.ckpt_every == 0 or step + 1 == end:
            save(tcfg.ckpt_dir, step + 1,
                 {"params": params, "opt": opt_state})
    return {"losses": losses, "step": end, "params": params}
