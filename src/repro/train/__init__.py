from repro.train.trainer import TrainConfig, train

__all__ = ["TrainConfig", "train"]
