"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state. Single pod: 16×16 = 256 chips (v5e pod),
axes (data, model). Multi-pod: 2×16×16 = 512 chips, axes
(pod, data, model); the "pod" axis crosses DCN, so shardings place only
batch parallelism (and compressed gradient reduction) on it.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Tiny mesh for CPU integration tests (requires
    XLA_FLAGS=--xla_force_host_platform_device_count≥n_data·n_model)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def make_lookup_mesh(n_devices: int | None = None):
    """1-axis ("data",) mesh over every visible device, for running the
    mesh-sharded cache lookup standalone (benchmarks, tests; 8-way under
    XLA_FLAGS=--xla_force_host_platform_device_count=8). On a production
    pod the lookup instead rides the axes of the production mesh picked
    by launch.sharding.LookupShardPolicy."""
    n = jax.device_count() if n_devices is None else n_devices
    return jax.make_mesh((n,), ("data",))


# v5e hardware constants used by the roofline analysis (per chip)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link
