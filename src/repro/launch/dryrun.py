import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# The two lines above MUST run before any other import (jax locks the
# device count at first init). Everything below is ordinary code.

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape) cell, on both the single-pod
(16×16) and multi-pod (2×16×16) production meshes:

    lowered  = jit(step, in_shardings=…).lower(*abstract_inputs)
    compiled = lowered.compile()
    print(compiled.memory_analysis(), compiled.cost_analysis())

A cell that fails to lower or compile (sharding mismatch, unsupported
collective) is a bug in the system. Results (memory, FLOPs, collective
schedule, roofline terms) are written to results/dryrun/*.json —
resumable: existing cells are skipped unless --force.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh single multi [--force] [--seq-shard]
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.configs.registry import get_config, list_archs
from repro.launch import roofline as rf
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import SHAPES, build_cell, supported
from repro.launch.sharding import count_devices
from repro.optim import AdamWConfig

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def opt_for(cfg) -> AdamWConfig:
    """8-bit moments for ≥30B models (otherwise f32) — the memory math of
    EXPERIMENTS.md §Dry-run; quality note in DESIGN.md."""
    from repro.models.schema import param_count
    big = param_count(cfg) > 30e9
    return AdamWConfig(moment_dtype="int8" if big else "float32")


def _measure(cfg, shape_name, mesh, n_dev, seq_shard, want_mem=False,
             **pol):
    """Lower + compile one configuration; return cost/collective stats."""
    fn, args, shardings = build_cell(cfg, shape_name, mesh, opt_for(cfg),
                                     seq_shard=seq_shard, **pol)
    with mesh:
        lowered = jax.jit(fn, in_shardings=shardings).lower(*args)
        compiled = lowered.compile()
        cost = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        mem = compiled.memory_analysis() if want_mem else None
    coll = rf.parse_collectives(hlo, n_dev)
    out = {"flops": float(cost.get("flops", 0.0)),
           "bytes": float(cost.get("bytes accessed", 0.0)),
           "coll_bytes": coll["bytes_per_device"],
           "coll": coll, "cost": cost}
    if want_mem and mem is not None:
        out["mem"] = {k: int(getattr(mem, k)) for k in
                      ("argument_size_in_bytes", "output_size_in_bytes",
                       "temp_size_in_bytes", "alias_size_in_bytes")
                      if getattr(mem, k, None) is not None}
    return out


def _probe_cfg(cfg, k: int):
    """k-super-block unrolled variant for scan-cost extrapolation.

    XLA's cost analysis counts while-loop bodies ONCE; measuring unrolled
    1- and 2-super-block probes separates per-block cost (body = m2−m1)
    from the fixed part, and total = fixed + n_super·body. Documented in
    EXPERIMENTS.md §Dry-run (methodology)."""
    from repro.models.schema import block_pattern
    period = len(block_pattern(cfg))
    kw = dict(name=f"{cfg.name}-probe{k}", n_layers=k * period,
              scan_layers=False)
    if cfg.is_encdec:
        kw["n_enc_layers"] = k * max(cfg.n_enc_layers
                                     // (cfg.n_layers // period), 1)
    return dataclasses.replace(cfg, **kw)


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             seq_shard: bool = False, verbose: bool = True,
             ffn_mode: str = "tp", attn_override: str | None = None,
             serve_fsdp: bool = True, moe_dispatch: str | None = None,
             bf16_flows: bool = False, kv_int8: bool = False) -> dict:
    cfg = get_config(arch)
    if moe_dispatch:
        cfg = dataclasses.replace(cfg, moe_dispatch=moe_dispatch)
    if kv_int8:
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    pol = dict(ffn_mode=ffn_mode, attn_override=attn_override,
               serve_fsdp=serve_fsdp, bf16_flows=bf16_flows)
    ok, reason = supported(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": reason}
    from repro.models.schema import block_pattern
    n_super = cfg.n_layers // len(block_pattern(cfg))
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = count_devices(mesh)
    cell = SHAPES[shape_name]

    t0 = time.time()
    full = _measure(cfg, shape_name, mesh, n_dev, seq_shard, want_mem=True,
                    **pol)
    t_full = time.time() - t0
    # scan-body extrapolation probes (1 and 2 unrolled super-blocks)
    m1 = _measure(_probe_cfg(cfg, 1), shape_name, mesh, n_dev, seq_shard,
                  **pol)
    m2 = _measure(_probe_cfg(cfg, 2), shape_name, mesh, n_dev, seq_shard,
                  **pol)
    corr = {}
    for key in ("flops", "bytes", "coll_bytes"):
        body = max(m2[key] - m1[key], 0.0)
        fixed = max(m1[key] - body, 0.0)
        corr[key] = fixed + n_super * body
    # compute term: analytic accounting (inner sequential scans are
    # invisible even to the probes — see roofline.analytic_flops);
    # memory term: analytic HBM model (XLA 'bytes accessed' on the CPU
    # backend over-counts due to weak fusion — reported alongside)
    flops_dev = max(corr["flops"], rf.analytic_flops(cfg, cell) / n_dev)
    bytes_dev = rf.analytic_bytes(cfg, cell, n_dev,
                                  opt_for(cfg).moment_dtype,
                                  ffn_mode=ffn_mode)

    roof = rf.roofline(flops_dev, bytes_dev, corr["coll_bytes"],
                       full["coll"], cfg, cell, n_dev,
                       raw_cost=full["cost"])
    roof["xla_bytes_extrapolated"] = corr["bytes"]
    res = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "status": "ok", "devices": n_dev, "n_super": n_super,
        "wall_s": round(time.time() - t0, 1),
        "compile_s_full": round(t_full, 1),
        "memory_analysis": full.get("mem", {}),
        "raw_flops_per_device": full["flops"],
        "raw_bytes_per_device": full["bytes"],
        "extrapolated": corr,
        "analytic_flops_global": rf.analytic_flops(cfg, cell),
        "roofline": roof,
        "seq_shard": seq_shard,
        "policy": {**pol, "moe_dispatch": cfg.moe_dispatch,
                   "kv_cache_dtype": cfg.kv_cache_dtype},
    }
    if verbose:
        mem = full.get("mem", {})
        ppd = (mem.get("argument_size_in_bytes", 0)) / 2**30
        tmp = (mem.get("temp_size_in_bytes", 0)) / 2**30
        print(f"[dryrun] {arch} × {shape_name} × {mesh_kind}: OK "
              f"({n_dev} dev, args {ppd:.2f} GiB/dev, temp {tmp:.2f} GiB, "
              f"compute {roof['compute_s']:.3e}s, "
              f"mem {roof['memory_s']:.3e}s, "
              f"coll {roof['collective_s']:.3e}s → {roof['dominant']}, "
              f"roofline {roof['roofline_fraction']*100:.1f}%, "
              f"wall {res['wall_s']:.0f}s)")
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", nargs="+", default=["all"])
    ap.add_argument("--shape", nargs="+", default=["all"])
    ap.add_argument("--mesh", nargs="+", default=["single", "multi"],
                    choices=["single", "multi"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--seq-shard", action="store_true",
                    help="sequence-shard prefill activations (perf knob)")
    ap.add_argument("--out", default=RESULTS_DIR)
    ap.add_argument("--tag", default="",
                    help="suffix for result files (perf experiments)")
    ap.add_argument("--ffn-mode", default="tp", choices=["tp", "dp", "dp_batch"])
    ap.add_argument("--attn-strategy", default=None,
                    choices=[None, "heads", "batch", "seq", "kv_seq"])
    ap.add_argument("--no-serve-fsdp", action="store_true")
    ap.add_argument("--moe-dispatch", default=None,
                    choices=[None, "einsum", "gather"])
    ap.add_argument("--bf16-flows", action="store_true")
    ap.add_argument("--kv-int8", action="store_true")
    args = ap.parse_args()

    archs = list_archs() if args.arch == ["all"] else args.arch
    shapes = list(SHAPES) if args.shape == ["all"] else args.shape
    os.makedirs(args.out, exist_ok=True)

    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in args.mesh:
                tag = f"_{args.tag}" if args.tag else ""
                path = os.path.join(args.out,
                                    f"{arch}__{shape}__{mesh_kind}{tag}.json")
                if os.path.exists(path) and not args.force:
                    print(f"[dryrun] {arch} × {shape} × {mesh_kind}: cached")
                    continue
                try:
                    res = run_cell(arch, shape, mesh_kind,
                                   seq_shard=args.seq_shard,
                                   ffn_mode=args.ffn_mode,
                                   attn_override=args.attn_strategy,
                                   serve_fsdp=not args.no_serve_fsdp,
                                   moe_dispatch=args.moe_dispatch,
                                   bf16_flows=args.bf16_flows,
                                   kv_int8=args.kv_int8)
                    if res["status"] == "ok":
                        n_ok += 1
                    else:
                        n_skip += 1
                        print(f"[dryrun] {arch} × {shape} × {mesh_kind}: "
                              f"SKIP ({res['reason']})")
                except Exception as e:           # a failed cell is a bug
                    n_fail += 1
                    res = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                           "status": "failed", "error": str(e),
                           "traceback": traceback.format_exc()}
                    print(f"[dryrun] {arch} × {shape} × {mesh_kind}: "
                          f"FAILED — {e}")
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_fail} FAILED")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
