"""Mesh-aware sharding: logical axes → PartitionSpecs with divisibility
fallbacks (the resolver of DESIGN.md §4).

Strategy summary (per arch × mode, computed in ``MeshShardPolicy``):

  params    — TP: heads/ff/vocab/experts → "model" (when the dim
              divides); FSDP: embed → "data". Optimizer moments inherit
              parameter specs (fully sharded ZeRO-style state).
  train     — batch → (pod, data); MLP/MoE TP over "model";
              attention "heads" strategy when n_heads % model == 0
              (with KV-head repetition to the TP degree for GQA),
              otherwise "batch" strategy: attention activations shard
              batch over (pod, data, model) inside the sublayer.
  prefill   — same as train (+ optional sequence sharding knob).
  decode    — KV caches shard their sequence axis over "model"
              (distributed flash-decode); batch over (pod, data).

Every rule is a *candidate list*; ``_resolve`` keeps the longest prefix
of axes that divides the dim and never reuses a mesh axis across dims,
so any (arch × shape × mesh) combination lowers without manual edits —
the property the 40-cell dry-run matrix exercises.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.kernels.knn import mesh_axes_size
from repro.models.schema import ParamSpec
from repro.models.sharding_api import ShardPolicy


def _resolve(shape: tuple, axes: tuple, rules: dict, mesh: Mesh) -> P:
    """Map logical axis names to mesh axes honoring divisibility."""
    used: set = set()
    out = []
    for dim, name in zip(shape, axes):
        chosen: list = []
        rem = int(dim)
        for ax in rules.get(name, ()):
            if ax in mesh.shape and ax not in used and \
                    rem % mesh.shape[ax] == 0:
                chosen.append(ax)
                used.add(ax)
                rem //= mesh.shape[ax]
        out.append(tuple(chosen) if chosen else None)
    return P(*out)


def attn_strategy_for(cfg: ArchConfig, mesh: Mesh, mode: str) -> str:
    model = mesh.shape.get("model", 1)
    if mode == "decode":
        return "kv_seq"
    if cfg.n_heads % model == 0:
        return "heads"
    return "batch"


def kv_repeat_for(cfg: ArchConfig, mesh: Mesh, strategy: str) -> int:
    """Repeat KV heads to the TP degree under heads-TP (GQA)."""
    model = mesh.shape.get("model", 1)
    if strategy != "heads" or cfg.n_kv_heads >= model:
        return 1
    if model % cfg.n_kv_heads == 0:
        return model // cfg.n_kv_heads
    return 1


@dataclasses.dataclass(frozen=True)
class MeshShardPolicy(ShardPolicy):
    """ShardPolicy backed by a real mesh (models call this).

    Perf knobs (EXPERIMENTS.md §Perf — defaults are the baseline):
      * ffn_mode="dp": no tensor parallelism; activations sequence-shard
        over the model axis (ZeRO-DP + sequence parallelism — the small-
        model recipe; removes all Megatron-style activation all-reduces);
      * attn_override="seq": attention runs with its sequence axis over
        the model axis (context parallelism) instead of the batch
        round-trip, for archs whose head count doesn't divide the TP
        degree;
      * serve_fsdp=False: serving params replicate over the data axis
        (no per-layer weight all-gathers on the decode path).
    """
    cfg: ArchConfig = None
    mesh: Mesh = None
    mode: str = "train"
    seq_shard: bool = False          # prefill sequence parallelism knob
    ffn_mode: str = "tp"             # tp | dp
    serve_fsdp: bool = True

    @classmethod
    def create(cls, cfg: ArchConfig, mesh: Mesh, mode: str,
               seq_shard: bool = False, ffn_mode: str = "tp",
               attn_override: str | None = None,
               serve_fsdp: bool = True) -> "MeshShardPolicy":
        strategy = attn_override or attn_strategy_for(cfg, mesh, mode)
        if ffn_mode == "dp" and mode != "decode":
            strategy = "seq"
        if ffn_mode == "dp_batch" and mode != "decode":
            strategy = "batch"
        return cls(attn_strategy=strategy,
                   kv_repeat=kv_repeat_for(cfg, mesh, strategy),
                   cfg=cfg, mesh=mesh, mode=mode, seq_shard=seq_shard,
                   ffn_mode=ffn_mode, serve_fsdp=serve_fsdp)

    # ------------------------------------------------- activation rules
    def act_rules(self) -> dict:
        dp = self.ffn_mode in ("dp", "dp_batch")
        # dp_batch: pure data parallelism over every axis incl. model —
        # token-local routing (MoE cumsum never crosses shards)
        batch = ("pod", "data", "model") if self.ffn_mode == "dp_batch" \
            else ("pod", "data")
        rules = {
            "batch": batch,
            "attn_batch": batch + (("model",) if self.attn_strategy == "batch"
                                   else ()),
            "seq": ("model",) if (self.seq_shard or self.ffn_mode == "dp")
            else (),
            "attn_seq": ("model",) if self.attn_strategy == "seq" else (),
            "kv_seq": ("model",),
            "heads": ("model",) if self.attn_strategy == "heads" else (),
            "rep_kv_heads": ("model",) if self.attn_strategy == "heads"
            else (),
            "kv_heads": (),
            "head_dim": (),
            "embed": (),
            "ff": () if dp else ("model",),
            "vocab": () if dp else ("model",),
            "experts": () if dp else ("model",),
            # MoE dispatch groups follow the token sharding
            "moe_group": batch + (("model",) if self.ffn_mode == "dp"
                                  else ()),
            "layers": (),
            "state": (),
        }
        return rules

    def spec_for(self, shape: tuple, axes: tuple) -> P:
        return _resolve(shape, axes, self.act_rules(), self.mesh)

    def __call__(self, x, axes):
        spec = self.spec_for(x.shape, axes)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    # ------------------------------------------------------ param rules
    def param_rules(self) -> dict:
        dp = self.ffn_mode in ("dp", "dp_batch")
        heads_tp = ("model",) if not dp \
            and self.attn_strategy in ("heads", "kv_seq") \
            and self.cfg.n_heads % self.mesh.shape.get("model", 1) == 0 \
            else ()
        # serving without FSDP: replicate over data (no per-layer weight
        # all-gathers on the decode path). FSDP stays data-axis-only even
        # in dp modes: 16-way ZeRO-3 fits every config's state and keeps
        # the per-layer gather group narrow (§Perf iteration log).
        fsdp = ("data",) if (self.mode == "train" or self.serve_fsdp) else ()
        # decode with head-indivisible archs (56H/40H/28H ∤ 16): shard
        # attention weights on head_dim instead — q·k contracts the
        # sharded dim into a tiny per-token all-reduce, and the 4×-larger
        # attention param block stops being replicated (§Perf cell C′)
        head_dim_tp = ("model",) if (self.mode == "decode" and not dp
                                     and not heads_tp) else ()
        return {
            "heads": heads_tp,
            "kv_heads": heads_tp,        # divisibility usually drops this
            "head_dim": head_dim_tp,
            "embed": fsdp,
            "ff": () if dp else ("model",),
            "vocab": () if dp else ("model",),
            "experts": () if dp else ("model",),
            "layers": (),
            None: (),
        }

    def param_spec(self, ps: ParamSpec) -> P:
        return _resolve(ps.shape, ps.axes, self.param_rules(), self.mesh)

    def param_sharding_tree(self, schema_tree: Any) -> Any:
        """Nested dict of NamedShardings mirroring param_schema(cfg)."""
        def walk(node):
            if isinstance(node, ParamSpec):
                return NamedSharding(self.mesh, self.param_spec(node))
            return {k: walk(v) for k, v in node.items()}
        return walk(schema_tree)

    def moment_sharding_tree(self, schema_tree: Any, moment_dtype: str
                             ) -> Any:
        """Optimizer-moment shardings: inherit the param spec; int8
        moments carry a per-row scale whose last dim is unsharded."""
        def walk(node):
            if isinstance(node, ParamSpec):
                spec = self.param_spec(node)
                if moment_dtype != "int8":
                    return NamedSharding(self.mesh, spec)
                parts = list(spec) + [None] * (len(node.shape) - len(spec))
                sspec = P(*(parts[:-1] + [None]))
                return {"q": NamedSharding(self.mesh, spec),
                        "s": NamedSharding(self.mesh, sspec)}
            return {k: walk(v) for k, v in node.items()}
        return walk(schema_tree)

    # ------------------------------------------------------ cache rules
    def cache_spec(self, key: str, shape: tuple) -> P:
        batch = ("pod", "data")
        by_key = {
            "k": (None, batch, ("model",), None, None),
            "v": (None, batch, ("model",), None, None),
            "xk": (None, batch, ("model",), None, None),
            "xv": (None, batch, ("model",), None, None),
            "k_s": (None, batch, ("model",), None, None),
            "v_s": (None, batch, ("model",), None, None),
            "h": (None, batch, ("model",), None),          # mamba (Di)
            "conv": (None, batch, None, ("model",)),       # mamba conv buf
            "C": (None, batch, None, ("model",), None),    # mlstm
            "n": (None, batch, None, ("model",)),
            "c": (None, batch, None, ("model",)),          # slstm
        }
        cands = by_key.get(key, (None,) * len(shape))
        used: set = set()
        parts = []
        for dim, cand in zip(shape, cands):
            if cand is None:
                parts.append(None)
                continue
            cand = (cand,) if isinstance(cand, str) else cand
            chosen = []
            rem = int(dim)
            for ax in cand:
                if ax in self.mesh.shape and ax not in used and \
                        rem % self.mesh.shape[ax] == 0:
                    chosen.append(ax)
                    used.add(ax)
                    rem //= self.mesh.shape[ax]
            parts.append(tuple(chosen) if chosen else None)
        return P(*parts)

    def cache_sharding_tree(self, cache_shapes: Any) -> Any:
        def walk(node):
            return {k: (walk(v) if isinstance(v, dict) else
                        NamedSharding(self.mesh, self.cache_spec(k, v.shape)))
                    for k, v in node.items()}
        return walk(cache_shapes)

    # ------------------------------------------------------ batch rules
    def batch_sharding_tree(self, batch_shapes: dict) -> dict:
        out = {}
        for k, v in batch_shapes.items():
            if k == "mrope_positions":              # (3, B, S)
                spec = _resolve(v.shape, (None, "batch", "seq"),
                                self.act_rules(), self.mesh)
            elif v.ndim >= 2:
                axes = ("batch", "seq") + (None,) * (v.ndim - 2)
                spec = _resolve(v.shape, axes, self.act_rules(), self.mesh)
            else:
                spec = P()
            out[k] = NamedSharding(self.mesh, spec)
        return out


@dataclasses.dataclass(frozen=True)
class LookupShardPolicy:
    """Key-axis sharding policy for the similarity-cache fused lookup.

    The SimCacheNetwork data plane shards the segmented key tensor
    (keys, h_key, meta) over mesh axes; this policy decides *which*
    axes, reusing :func:`_resolve`'s divisibility-fallback logic: the
    longest prefix of ``candidates`` present in the mesh is kept (the
    key axis is always padded to a multiple of the resulting shard
    count, so divisibility is guaranteed by construction — we resolve
    against the full candidate product). Preference order puts "model"
    first: lookup shards and tensor-parallel shards then live on the
    same devices, so cache keys sit next to the KV-prefix payloads they
    index.

    ``prune`` additionally selects the per-shard candidate-pruning
    tables (kernels.knn.lsh): each shard of the balanced contiguous key
    layout builds its *own* SimHash / k-means tables over its resident
    chunk, seeded from ``table_seed`` (shard s draws from
    ``policy.for_shard(s)``, so hyperplanes/centroids are independent
    across shards while the whole fleet stays reproducible).

    The *control plane* rides the same axes: the placement gain oracle
    (kernels/knn/gains.py) shard_maps its candidate-object axis over
    ``axes`` (see :meth:`gain_shard_args`), so candidate shards are
    co-resident with the data-plane key shards they would populate —
    one placement decision's gains and its eventual cache keys live on
    the same devices. The *online* control plane (NETDUEL's DuelPlane
    and the scanned LOCALSWAP window, core/placement/netduel.py /
    device.py) rides them too: a DeviceInstance built from
    :meth:`gain_shard_args` routes its serving-table refreshes through
    ``objective.sharded_best_two``, which shard_maps the request axis
    over the same ``axes`` — the duel state of a key shard's content
    is refreshed where the keys live.
    """
    mesh: Mesh
    axes: tuple[str, ...]
    prune: str | None = None
    table_seed: int = 0

    @classmethod
    def create(cls, mesh: Mesh,
               candidates: tuple[str, ...] = ("model", "data", "pod"),
               prune: str | None = None,
               table_seed: int = 0) -> "LookupShardPolicy":
        present = tuple(ax for ax in candidates if ax in mesh.shape)
        if not present:                  # unrecognised axes: use them all
            present = tuple(mesh.axis_names)
        total = mesh_axes_size(mesh, present)
        spec = _resolve((total,), ("keys",), {"keys": present}, mesh)
        axes = spec[0] if spec[0] is not None else ()
        return cls(mesh=mesh, axes=tuple(axes), prune=prune,
                   table_seed=table_seed)

    @property
    def n_shards(self) -> int:
        return mesh_axes_size(self.mesh, self.axes)

    def candidate_policy(self):
        """The base CandidatePolicy for this deployment (None when
        pruning is off); SimCacheNetwork derives per-shard tables from
        it via ``for_shard``."""
        if self.prune is None:
            return None
        from repro.kernels.knn.lsh import default_policy
        return default_policy(self.prune, seed=self.table_seed)

    def gain_shard_args(self) -> tuple[Mesh, tuple[str, ...]] | None:
        """(mesh, axes) for sharding the placement control plane — the
        gain oracle's candidate axis and the online plane's
        serving-table request axis (``sharded_best_two``). None when
        the policy resolves to a single shard (everything then runs
        unsharded, and the shard_maps would only add overhead). Values
        are bit-identical either way (per-candidate/per-request sums
        are shard-count-independent by construction)."""
        if self.n_shards <= 1:
            return None
        return (self.mesh, self.axes)

    def control_plane_args(self, enabled: bool = True
                           ) -> tuple[Mesh, tuple[str, ...]] | None:
        """Single resolution point for every control-plane consumer in
        the serving engine (offline solver, duel plane, background
        refresh): :meth:`gain_shard_args` when the engine's data plane
        is actually sharded (``enabled``), else None — so a policy held
        for pruning-table seeds alone never turns on shard_maps."""
        if not enabled:
            return None
        return self.gain_shard_args()


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def count_devices(mesh: Mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))
