"""Roofline analysis from the compiled dry-run artifact (deliverable g).

Three terms per (arch × shape × mesh), all in seconds:

    compute    = HLO_FLOPs_per_device / peak_FLOP/s
    memory     = HLO_bytes_per_device / HBM_bw
    collective = Σ ring-model bytes of every collective op / link_bw

``compiled.cost_analysis()`` provides per-device FLOPs/bytes (the SPMD
module is per-device). Collective bytes are NOT in cost_analysis: we
parse the optimized HLO and apply ring-model transfer estimates per op
type and group size. MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE)
gives the usefulness ratio (catches remat/redundant compute).
"""
from __future__ import annotations

import re

from repro.configs.base import ArchConfig
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.models import schema as schema_api

DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(txt: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(txt):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        ids = [t for t in m.group(1).split(",") if t.strip()]
        return max(len(ids), 1)
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    return default


def _ring_bytes(op: str, out_bytes: int, n: int) -> float:
    """Per-device bytes moved over links, ring model."""
    if n <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * out_bytes * (n - 1) / n
    if op == "all-gather":
        return out_bytes * (n - 1) / n
    if op == "reduce-scatter":
        return out_bytes * (n - 1)          # output is the 1/n shard
    if op == "all-to-all":
        return out_bytes * (n - 1) / n
    if op == "collective-permute":
        return float(out_bytes)
    return 0.0


def parse_collectives(hlo_text: str, n_devices: int) -> dict:
    """Scan optimized HLO for collective ops; returns totals + per-op."""
    per_op: dict = {}
    total = 0.0
    counts: dict = {}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.search(r"=\s*((?:\([^)]*\)|\S+))\s+(" +
                      "|".join(COLLECTIVES) + r")(?:-start|-done)?\(",
                      stripped)
        if not m:
            continue
        if re.match(r"\s*ROOT", line) and "fusion" in line:
            continue
        op = m.group(2)
        if "-done(" in stripped:
            continue                          # avoid double count start/done
        result_txt = stripped.split("=", 1)[0] + m.group(1)
        out_bytes = _shape_bytes(result_txt)
        n = _group_size(stripped, n_devices)
        moved = _ring_bytes(op, out_bytes, n)
        total += moved
        counts[op] = counts.get(op, 0) + 1
        per_op.setdefault(op, 0.0)
        per_op[op] += moved
    return {"bytes_per_device": total, "per_op_bytes": per_op,
            "counts": counts}


def analytic_flops(cfg: ArchConfig, cell) -> float:
    """Closed-form FLOP accounting per cell (global, all devices).

    Needed because XLA's cost analysis counts while-loop (scan) bodies
    once (see dryrun.py probe extrapolation, which fixes bytes and
    collectives); sequential *inner* scans (mamba/xLSTM chunk loops)
    would still be undercounted, so the compute term uses these explicit
    formulas: dominant matmul terms only, 2·M·N·K per matmul. Training
    ≈ 4× forward (fwd + 2×bwd + ~1× remat recompute); decode = forward
    on 1 token/sequence against a seq_len cache.
    """
    B, S = cell.batch, cell.seq
    d, ff = cfg.d_model, cfg.d_ff
    h, kh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s_dec = max(S // 4, 64) if cfg.is_encdec else S
    if cell.kind == "train":
        T, s_kv, mult = B * s_dec, s_dec, 4.0     # remat on
        causal, s_cross = 0.5, S
    elif cell.kind == "prefill":
        T, s_kv, mult = B * s_dec, s_dec, 1.0
        causal, s_cross = 0.5, S
    else:
        T, s_kv, mult = B * 1, S, 1.0
        causal, s_cross = 1.0, cfg.cross_len      # 1 query, full cache

    def attn_flops(skv=None, cz=None):
        skv = s_kv if skv is None else skv
        cz = causal if cz is None else cz
        proj = 2 * T * d * (h * dh + 2 * kh * dh) + 2 * T * h * dh * d
        qk_v = 2 * 2 * T * skv * h * dh * cz
        return proj + qk_v

    def mlp_flops(f):
        return 2 * T * 3 * d * f if f else 0.0

    def moe_flops():
        slots = T * cfg.moe_topk * max(cfg.capacity_factor, 1.0)
        expert = 2 * slots * 3 * d * ff
        router = 2 * T * d * cfg.moe_experts
        if cfg.moe_dispatch == "einsum":
            # GShard one-hot dispatch+combine: T·(Tg·k·cf)·D each
            tg = min(cfg.moe_group_size, T)
            dispatch = 4 * T * tg * cfg.moe_topk * \
                max(cfg.capacity_factor, 1.0) * d
        else:
            dispatch = 4 * slots * d      # gathers: bytes, not flops
        return expert + router + dispatch

    def mamba_flops():
        di, n = cfg.d_inner, cfg.ssm_state
        proj = 2 * T * d * 2 * di + 2 * T * di * d
        small = 2 * T * di * (cfg.ssm_dt_rank + 2 * n) + \
            2 * T * cfg.ssm_dt_rank * di + 2 * T * cfg.ssm_conv * di
        scan = 8 * T * di * n              # discretize + recurrence + y
        return proj + small + scan

    def mlstm_flops():
        di = cfg.ssm_expand * d
        dhh = di // cfg.n_heads
        csz = min(cfg.xlstm_chunk, S) if cell.kind != "decode" else 0
        proj = 2 * T * d * 3 * di + 2 * T * d * di * 2   # qkv + og + out
        intra = 2 * 2 * T * csz * di * 0.5               # qk + y, causal
        state = 2 * 2 * T * di * dhh                     # C update + read
        return proj + intra + state

    def slstm_flops():
        dhh = d // cfg.n_heads
        return 2 * T * d * 4 * d + 2 * T * 4 * dhh * d + 2 * T * d * d

    total = 0.0
    for i in range(cfg.n_layers):
        if cfg.xlstm:
            total += slstm_flops() if (i + 1) % cfg.slstm_every == 0 \
                else mlstm_flops()
            continue
        total += attn_flops() if cfg.is_attn_layer(i) else mamba_flops()
        if cfg.is_encdec:
            total += attn_flops(skv=s_cross, cz=1.0)   # cross attention
        if cfg.is_moe_layer(i):
            total += moe_flops()
        else:
            f = cfg.dense_ff if cfg.dense_ff else ff
            total += mlp_flops(f)
    if cfg.is_encdec:
        # encoder processes the frame sequence at full length
        T_enc = B * S if cell.kind != "decode" else 0
        enc = cfg.n_enc_layers * (
            2 * T_enc * d * (h * dh + 2 * kh * dh) + 2 * T_enc * h * dh * d
            + 2 * 2 * T_enc * S * h * dh + 2 * T_enc * 2 * d * ff)
        total += enc
    total += 2 * T * d * cfg.padded_vocab          # lm head
    return total * mult


def analytic_bytes(cfg: ArchConfig, cell, n_devices: int,
                   moment_dtype: str = "float32",
                   ffn_mode: str = "tp") -> float:
    """Per-device HBM traffic model (bytes/step), assuming TPU-grade
    fusion (elementwise chains and softmax fuse; attention scores hit HBM
    once per pass in the unfused baseline). Complements XLA's
    'bytes accessed', which on the CPU backend over-counts by 5–10×
    because CPU fusion is much weaker than TPU fusion (both numbers are
    reported in EXPERIMENTS.md §Roofline; this one feeds the terms).

    Methodology per component (train: fwd + remat-fwd + bwd ≈ 3 activation
    passes; params: cast-read + 2 fwd reads + bwd read + grad rw +
    optimizer state rw + write):
    """
    B, S = cell.batch, cell.seq
    d, ff = cfg.d_model, cfg.d_ff
    h, kh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    mdl = 16 if n_devices >= 256 else max(n_devices // 16, 1)
    if ffn_mode in ("dp", "dp_batch"):
        mdl = 1                       # no TP: tokens spread over all axes
    data = n_devices // mdl
    params_dev = schema_api.param_count(cfg, padded=True) / n_devices
    s_dec = max(S // 4, 64) if cfg.is_encdec else S

    if cell.kind == "train":
        t_dev = B * s_dec / data            # tokens per device
        passes = 3.0                        # fwd + remat + bwd
        mom = {"float32": 16, "bfloat16": 8, "int8": 4.2}[moment_dtype]
        param_traffic = params_dev * (4 + 2 + 2 + 2 + 8 + mom + 4)
    elif cell.kind == "prefill":
        t_dev = B * s_dec / data
        passes = 1.0
        param_traffic = params_dev * 2      # bf16 read once
    else:
        t_dev = B / data                    # decode: 1 token per seq
        passes = 1.0
        param_traffic = params_dev * 2

    # per-layer activation flows (residual stream, projections, FFN)
    ff_dev = ff / mdl if ff else 0
    act = 0.0
    for i in range(cfg.n_layers):
        if cfg.xlstm:
            di = cfg.ssm_expand * d
            act += t_dev * (6 * d + 6 * di / mdl) * 2
            continue
        if cfg.is_attn_layer(i):
            h_dev = max(h // mdl, 1) * dh if cfg.n_heads % mdl == 0 \
                else h * dh / mdl
            act += t_dev * (8 * d + 4 * h_dev) * 2
            # score matrices: the unfused baseline writes+reads them in
            # f32 per pass; the flash kernel keeps tiles in VMEM (the
            # kernel is validated in tests/test_kernels_flash.py — the
            # model flag swaps it in on TPU)
            if cell.kind != "decode" and not cfg.use_flash_attention:
                skv = s_dec
                heads_dev = h / mdl
                act += (t_dev * skv * heads_dev) * 4 * 2
        else:
            di_dev = cfg.d_inner / mdl
            act += t_dev * (6 * d + 8 * di_dev
                            + 2 * di_dev * cfg.ssm_state) * 2
        if cfg.is_moe_layer(i):
            slots = t_dev * cfg.moe_topk * max(cfg.capacity_factor, 1.0)
            act += slots * (4 * d + 2 * ff_dev) * 2
        elif ff or cfg.dense_ff:
            f = (cfg.dense_ff if cfg.dense_ff else ff) / mdl
            act += t_dev * (2 * d + 4 * f) * 2
    act *= passes
    if cfg.is_encdec and cell.kind != "decode":
        act += cfg.n_enc_layers * (B * S / data) * (8 * d + 4 * ff / mdl) \
            * 2 * passes

    # logits + embedding
    vp_dev = cfg.padded_vocab / mdl
    logits = t_dev * vp_dev * 4 * (2 if cell.kind == "train" else 1)
    embed = t_dev * d * 2 * passes

    # decode: the KV cache / recurrent state is read once per step
    cache = 0.0
    if cell.kind == "decode":
        b_dev = max(B / data, 1)
        n_attn = sum(1 for i in range(cfg.n_layers)
                     if (not cfg.xlstm) and cfg.is_attn_layer(i))
        kv_b = 1.07 if cfg.kv_cache_dtype == "int8" else 2  # +scales
        cache += n_attn * b_dev * (S / mdl) * kh * dh * 2 * kv_b
        if cfg.is_encdec:
            cache += cfg.n_layers * b_dev * (cfg.cross_len / mdl) * \
                kh * dh * 2 * 2
        n_ssm = sum(1 for i in range(cfg.n_layers)
                    if cfg.xlstm or not cfg.is_attn_layer(i))
        state_sz = (cfg.d_inner / mdl) * cfg.ssm_state * 4 if not cfg.xlstm \
            else (cfg.ssm_expand * d / mdl) * (cfg.ssm_expand * d
                                               / cfg.n_heads) * 4
        cache += n_ssm * b_dev * state_sz * 2
    return param_traffic + act + logits + embed + cache


def model_flops(cfg: ArchConfig, cell, n_tokens: int | None = None) -> float:
    """6·N·D with N = active params; decode cells process batch tokens."""
    n_active = schema_api.active_param_count(cfg)
    if n_tokens is None:
        if cell.kind == "train":
            n_tokens = cell.batch * cell.seq
        elif cell.kind == "prefill":
            n_tokens = cell.batch * cell.seq
        else:
            n_tokens = cell.batch              # one token per sequence
    mult = 6.0 if cell.kind == "train" else 2.0
    return mult * n_active * n_tokens


def roofline(flops_dev: float, bytes_dev: float, coll_bytes_dev: float,
             coll_meta: dict, cfg: ArchConfig, cell,
             n_devices: int, raw_cost: dict | None = None) -> dict:
    """Three-term roofline. ``flops_dev``/``bytes_dev``/``coll_bytes_dev``
    are the corrected per-device numbers (probe-extrapolated scans +
    analytic compute, see dryrun.py); ``raw_cost`` keeps the uncorrected
    cost_analysis() values for reference."""
    compute_s = flops_dev / PEAK_FLOPS_BF16
    memory_s = bytes_dev / HBM_BW
    collective_s = coll_bytes_dev / ICI_BW
    mf = model_flops(cfg, cell)
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    bound = max(compute_s, memory_s, collective_s)
    useful = mf / max(flops_dev * n_devices, 1.0)
    # ideal step time: compute at peak — but decode is weights/KV-
    # bandwidth-bound by nature, so its floor is reading the active
    # params (bf16) + the KV/state cache once per step
    ideal_s = mf / n_devices / PEAK_FLOPS_BF16
    if cell.kind == "decode":
        n_active = schema_api.active_param_count(cfg)
        kv = analytic_bytes(cfg, cell, n_devices) - 2 * n_active / n_devices
        floor_bytes = 2.0 * n_active / n_devices + max(kv, 0.0)
        ideal_s = max(ideal_s, floor_bytes / HBM_BW)
    return {
        **terms,
        "dominant": dominant,
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll_bytes_dev,
        "collective_counts": coll_meta.get("counts", {}),
        "collective_per_op_bytes": coll_meta.get("per_op_bytes", {}),
        "model_flops": mf,
        "useful_flops_ratio": useful,
        "raw_cost_analysis": raw_cost or {},
        # fraction of roofline: the ideal step time (MODEL_FLOPS at peak;
        # for decode: the weights+KV HBM floor) vs the binding term
        "ideal_s": ideal_s,
        "roofline_fraction": ideal_s / max(bound, 1e-12),
    }
