"""Training launcher.

On this container it runs the real loop on CPU with a reduced config
(--smoke, default) or dry-runs the production mesh for the full config
(--dryrun, equivalent to one dryrun.py cell). On a TPU cluster the same
entry point builds the production mesh and runs the pjit step.

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b --smoke \
      --steps 100
"""
from __future__ import annotations

import argparse

from repro.configs.registry import get_config, get_smoke_config, list_archs
from repro.data.pipeline import SyntheticLMData
from repro.optim import AdamWConfig
from repro.train import TrainConfig, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_launch_train")
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    tcfg = TrainConfig(steps=args.steps, ckpt_dir=args.ckpt,
                       ckpt_every=max(args.steps // 3, 1), log_every=10,
                       opt=AdamWConfig(lr=args.lr))
    data = SyntheticLMData(vocab=cfg.vocab, batch=args.batch, seq=args.seq)
    out = train(cfg, tcfg, data)
    print(f"[launch.train] done at step {out['step']}; "
          f"final loss {out['losses'][-1]:.4f}")


if __name__ == "__main__":
    main()
