"""Input specs and sharded step builders for every (arch × shape) cell.

ShapeDtypeStruct stand-ins only — nothing here allocates. The dry-run
lowers ``train_step`` for train shapes and ``serve_step`` (one decoded
token against a seq_len KV cache) for decode shapes, exactly as the
assignment defines the cells.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.launch.sharding import MeshShardPolicy, replicated
from repro.models import model as model_api
from repro.models import schema as schema_api
from repro.models.transformer import init_cache
from repro.optim import AdamWConfig, adamw_update, cosine_schedule

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq: int
    batch: int
    kind: str            # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def supported(cfg: ArchConfig, shape_name: str) -> tuple[bool, str]:
    """long_500k needs sub-quadratic sequence mixing (spec: skip pure
    full-attention archs and note it)."""
    if shape_name == "long_500k" and not cfg.subquadratic:
        return False, ("full-attention arch: long_500k skipped per "
                       "assignment (needs sub-quadratic attention)")
    return True, ""


# -------------------------------------------------------- abstract trees
def abstract_params(cfg: ArchConfig, dtype: str | None = None) -> Any:
    dt = jnp.dtype(dtype or cfg.param_dtype)

    def walk(node):
        if isinstance(node, schema_api.ParamSpec):
            return SDS(node.shape, dt)
        return {k: walk(v) for k, v in node.items()}
    return walk(schema_api.param_schema(cfg))


def abstract_opt_state(cfg: ArchConfig, opt: AdamWConfig) -> Any:
    def moment(node):
        if isinstance(node, schema_api.ParamSpec):
            if opt.moment_dtype == "int8":
                return {"q": SDS(node.shape, jnp.int8),
                        "s": SDS(node.shape[:-1] + (1,), jnp.float32)}
            return SDS(node.shape, jnp.dtype(opt.moment_dtype))
        return {k: moment(v) for k, v in node.items()}
    tree = schema_api.param_schema(cfg)
    return {"m": moment(tree), "v": moment(tree),
            "step": SDS((), jnp.int32)}


def train_batch_shapes(cfg: ArchConfig, cell: ShapeCell,
                       with_labels: bool = True) -> dict:
    B, S = cell.batch, cell.seq
    ct = jnp.dtype(cfg.compute_dtype)
    out: dict = {}
    if cfg.is_encdec:
        s_dec = max(S // 4, 64)
        out["audio_embeds"] = SDS((B, S, 128), ct)
        out["tokens"] = SDS((B, s_dec), jnp.int32)
        if with_labels:
            out["labels"] = SDS((B, s_dec), jnp.int32)
    elif cfg.mrope:
        s_img = S // 4
        out["image_embeds"] = SDS((B, s_img, 1280), ct)
        out["tokens"] = SDS((B, S - s_img), jnp.int32)
        out["mrope_positions"] = SDS((3, B, S), jnp.int32)
        if with_labels:
            out["labels"] = SDS((B, S - s_img), jnp.int32)
    else:
        out["tokens"] = SDS((B, S), jnp.int32)
        if with_labels:
            out["labels"] = SDS((B, S), jnp.int32)
    return out


def abstract_caches(cfg: ArchConfig, B: int, S: int) -> Any:
    return jax.eval_shape(lambda: init_cache(cfg, B, S))


# ----------------------------------------------------------- step fns --
def make_train_step(cfg: ArchConfig, policy: MeshShardPolicy,
                    opt: AdamWConfig, bf16_flows: bool = False,
                    grad_shardings=None):
    """``bf16_flows``: cast the f32 master params to bf16 once per step
    *before* the forward — the FSDP weight all-gathers then move bf16
    (2× fewer bytes) and, because autodiff differentiates w.r.t. the
    bf16 copies, the gradient reduce-scatters are bf16 too. The f32
    master + moments stay in the optimizer (mixed-precision standard;
    §Perf before/after)."""
    fwd = model_api.make_train_forward(cfg, policy)
    ct = jnp.dtype(cfg.compute_dtype)

    def train_step(params, opt_state, batch):
        if bf16_flows:
            def inner(p16, batch):
                return fwd(p16, batch)
            p16 = jax.tree.map(lambda p: p.astype(ct), params)
            (loss, metrics), grads16 = jax.value_and_grad(
                inner, has_aux=True)(p16, batch)
            grads = grads16
        else:
            (loss, metrics), grads = jax.value_and_grad(
                fwd, has_aux=True)(params, batch)
        if grad_shardings is not None:
            # pin grads to the parameter layout BEFORE the global-norm
            # clip: the partial gradients then reduce-scatter (1×) into
            # shards instead of full all-reducing (2×) to satisfy the
            # replicated norm computation (§Perf iteration log)
            grads = jax.tree.map(jax.lax.with_sharding_constraint,
                                 grads, grad_shardings)
        lr = cosine_schedule(opt_state["step"])
        new_params, new_state = adamw_update(grads, opt_state, params, opt,
                                             lr_scale=lr)
        return new_params, new_state, loss, metrics
    return train_step


def make_serve_step(cfg: ArchConfig, policy: MeshShardPolicy):
    return model_api.make_serve_step(cfg, policy)


def make_prefill_step(cfg: ArchConfig, policy: MeshShardPolicy):
    return model_api.make_prefill(cfg, policy)


# ------------------------------------------------- cell assembly (dryrun)
def build_cell(cfg: ArchConfig, shape_name: str, mesh, opt: AdamWConfig,
               seq_shard: bool = False, ffn_mode: str = "tp",
               attn_override: str | None = None, serve_fsdp: bool = True,
               bf16_flows: bool = False):
    """Returns (fn, abstract_args, in_shardings) for one dry-run cell."""
    cell = SHAPES[shape_name]
    schema_tree = schema_api.param_schema(cfg)
    pol = dict(ffn_mode=ffn_mode, attn_override=attn_override,
               serve_fsdp=serve_fsdp)

    if cell.kind == "train":
        policy = MeshShardPolicy.create(cfg, mesh, "train",
                                        seq_shard=seq_shard, **pol)
        pshard = policy.param_sharding_tree(schema_tree)
        fn = make_train_step(cfg, policy, opt, bf16_flows=bf16_flows,
                             grad_shardings=pshard)
        params = abstract_params(cfg)
        opt_state = abstract_opt_state(cfg, opt)
        batch = train_batch_shapes(cfg, cell)
        shardings = (
            pshard,
            {"m": policy.moment_sharding_tree(schema_tree, opt.moment_dtype),
             "v": policy.moment_sharding_tree(schema_tree, opt.moment_dtype),
             "step": replicated(mesh)},
            policy.batch_sharding_tree(batch),
        )
        return fn, (params, opt_state, batch), shardings

    if cell.kind == "prefill":
        policy = MeshShardPolicy.create(cfg, mesh, "prefill",
                                        seq_shard=seq_shard, **pol)
        fn = make_prefill_step(cfg, policy)
        params = abstract_params(cfg, dtype=cfg.compute_dtype)  # serving
        batch = train_batch_shapes(cfg, cell, with_labels=False)
        shardings = (policy.param_sharding_tree(schema_tree),
                     policy.batch_sharding_tree(batch))
        return fn, (params, batch), shardings

    # decode: one new token against a seq_len cache
    policy = MeshShardPolicy.create(cfg, mesh, "decode", **pol)
    fn = make_serve_step(cfg, policy)
    params = abstract_params(cfg, dtype=cfg.compute_dtype)
    B = cell.batch
    tokens = SDS((B, 1), jnp.int32)
    caches = abstract_caches(cfg, B, cell.seq)
    pos = SDS((), jnp.int32)
    shardings = (policy.param_sharding_tree(schema_tree),
                 policy.batch_sharding_tree({"tokens": tokens})["tokens"],
                 policy.cache_sharding_tree(caches),
                 replicated(mesh))
    return fn, (params, tokens, caches, pos), shardings
