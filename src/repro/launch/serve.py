"""Serving launcher: a reduced model behind the similarity-cache network
(the paper's system end-to-end; see examples/serve_simcache.py for the
narrated version).

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b \
      --requests 256

``--streaming`` switches from the fixed-batch replay loop to the async
multi-stream driver (serve/stream.py): N Poisson request streams
multiplexed into bucketed batches, placement refreshed through the
double buffer in the background (cadence via ``--refresh-every``, plus
NETDUEL promotion churn when ``--netduel``) and swapped in atomically
between batches — the loop never blocks on a solve.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b \
      --streaming --streams 4 --requests 1024 --netduel

``--scenario`` swaps the built-in 3-level hierarchy for a generated
general-graph network (core/scenarios.py: isp / scale_free /
watts_strogatz with degree-centrality cache sizing) and serves
multi-ingress traffic through the on-path strategy plane picked by
``--strategy`` (core/routing.py) — the λ-unaware online alternative to
the offline-placement plane:

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b \
      --streaming --scenario scale_free --strategy lce --requests 512
"""
from __future__ import annotations

import argparse

import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_smoke_config, list_archs
from repro.core import catalog as catalog_api
from repro.core import demand as demand_api
from repro.core import scenarios as scenarios_api
from repro.core.routing import STRATEGIES
from repro.models import model as model_api
from repro.serve import (EngineConfig, SimCacheEngine, StreamDriver,
                         StreamSpec)


def run_batch_loop(eng, cfg, dem, args) -> None:
    rng = np.random.default_rng(0)
    n_batches = args.requests // args.batch
    for i in range(n_batches):
        ids, ings = dem.sample(args.batch, rng)
        prompts = jnp.asarray(rng.integers(0, cfg.vocab,
                                           (args.batch, 16)).astype(np.int32))
        eng.serve(ids, prompts, ingress_ids=ings)
        if i == n_batches // 2 and eng.routing is None:
            pred = eng.refresh_placement()
            print(f"[serve] placement refreshed; predicted C(A)={pred:.2f}")


def run_streaming(eng, cat, args) -> None:
    n_ing = eng.net.n_ingress
    streams = [
        StreamSpec(demand=demand_api.zipf(cat, alpha=1.0,
                                          n_ingress=n_ing, seed=s + 1),
                   rate=1.0 + s, seed=s + 1, name=f"stream{s}")
        for s in range(args.streams)]
    drv = StreamDriver(eng, streams, max_batch=args.batch * 4,
                       batch_window=2.0, prompt_len=16,
                       refresh_every=(0 if eng.routing is not None
                                      else args.refresh_every))
    drv.run(max(args.requests // 8, args.batch))   # observe demand cold
    if eng.routing is None:
        pred = eng.refresh_placement()
        print(f"[serve] initial placement; predicted C(A)={pred:.2f}")
    st = drv.run(args.requests)
    drv.drain_refresh()
    print(f"[serve] streaming: {st.n_requests} requests in "
          f"{st.n_batches} batches ({st.distinct_batch_sizes} distinct "
          f"sizes), {st.requests_per_s:.0f} req/s, latency p50/p95/p99 "
          f"{st.p50_ms:.0f}/{st.p95_ms:.0f}/{st.p99_ms:.0f} ms")
    print(f"[serve] refreshes {st.refreshes_started} swaps {st.swaps} "
          f"(max stall {st.max_swap_stall_s*1e3:.1f} ms) duel churn "
          f"{st.placement_events}; placement v{eng.placement.version}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--algo", default="cascade",
                    choices=["greedy", "localswap", "cascade"])
    ap.add_argument("--streaming", action="store_true",
                    help="async multi-stream driver + background refresh")
    ap.add_argument("--streams", type=int, default=4)
    ap.add_argument("--refresh-every", type=int, default=16,
                    help="background re-solve cadence, in batches")
    ap.add_argument("--netduel", action="store_true",
                    help="§5 online duels; churn triggers refreshes too")
    ap.add_argument("--warm-start", action="store_true",
                    help="§4 continuous-limit warm start on every "
                         "refresh (analytic solve + Prop 4.2 band map + "
                         "bounded polish instead of the O(O·J) solver)")
    ap.add_argument("--warm-polish-iters", type=int, default=512,
                    help="LOCALSWAP polish window after the warm start")
    ap.add_argument("--scenario", default=None,
                    choices=sorted(scenarios_api.GENERATORS),
                    help="serve a generated general-graph network "
                         "through the on-path strategy plane instead "
                         "of the built-in 3-level hierarchy")
    ap.add_argument("--strategy", default="lce", choices=STRATEGIES,
                    help="on-path routing strategy (with --scenario)")
    ap.add_argument("--cache-budget", type=int, default=64,
                    help="total cache slots split over the graph by "
                         "degree centrality (with --scenario)")
    ap.add_argument("--ingress", type=int, default=4,
                    help="number of ingress nodes (with --scenario)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    if cfg.is_encdec or cfg.mrope:
        raise SystemExit("serve launcher demo supports decoder-only archs")
    params = model_api.init_params(cfg, 0)
    cat = catalog_api.embedding_catalog(n=1000, dim=32, seed=0)
    if args.scenario:
        sc = scenarios_api.scenario(args.scenario,
                                    cache_budget=args.cache_budget,
                                    placement="degree",
                                    n_ingress=args.ingress, seed=0)
        dem = demand_api.zipf(cat, alpha=1.0,
                              n_ingress=sc.net.n_ingress, seed=1)
        ecfg = EngineConfig(algo=args.algo, strategy=args.strategy)
        # the fused simcache is single-ingress; the strategy plane
        # serves the custom net, so no calibrate() here
        eng = SimCacheEngine(cfg, params, ecfg, cat.coords, net=sc.net)
        print(f"[serve] scenario {args.scenario}: "
              f"{sc.graph.n_nodes} nodes, {sc.net.n_caches} caches "
              f"({sc.net.total_slots} slots), "
              f"{sc.net.n_ingress} ingress, strategy {args.strategy}")
    else:
        dem = demand_api.zipf(cat, alpha=1.0, seed=1)
        ecfg = EngineConfig(algo=args.algo, netduel=args.netduel,
                            refresh_on_promotion=args.netduel,
                            warm_start=args.warm_start,
                            warm_polish_iters=args.warm_polish_iters)
        eng = SimCacheEngine(cfg, params, ecfg, cat.coords)
        eng.calibrate(jnp.zeros((args.batch, 16), jnp.int32))

    if args.streaming:
        run_streaming(eng, cat, args)
    else:
        run_batch_loop(eng, cfg, dem, args)
    s = eng.stats
    print(f"[serve] {s.n_requests} requests, hit-rate {s.hit_rate:.1%}, "
          f"mean cost {s.mean_cost:.2f} ms, model batches {s.model_calls}")


if __name__ == "__main__":
    main()
