"""Serving launcher: a reduced model behind the similarity-cache network
(the paper's system end-to-end; see examples/serve_simcache.py for the
narrated version).

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b \
      --requests 256
"""
from __future__ import annotations

import argparse

import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_smoke_config, list_archs
from repro.core import catalog as catalog_api
from repro.core import demand as demand_api
from repro.models import model as model_api
from repro.serve import EngineConfig, SimCacheEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--algo", default="cascade",
                    choices=["greedy", "localswap", "cascade"])
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    if cfg.is_encdec or cfg.mrope:
        raise SystemExit("serve launcher demo supports decoder-only archs")
    params = model_api.init_params(cfg, 0)
    cat = catalog_api.embedding_catalog(n=1000, dim=32, seed=0)
    dem = demand_api.zipf(cat, alpha=1.0, seed=1)
    eng = SimCacheEngine(cfg, params, EngineConfig(algo=args.algo),
                         cat.coords)
    eng.calibrate(jnp.zeros((args.batch, 16), jnp.int32))

    rng = np.random.default_rng(0)
    n_batches = args.requests // args.batch
    for i in range(n_batches):
        ids, _ = dem.sample(args.batch, rng)
        prompts = jnp.asarray(rng.integers(0, cfg.vocab,
                                           (args.batch, 16)).astype(np.int32))
        eng.serve(ids, prompts)
        if i == n_batches // 2:
            pred = eng.refresh_placement()
            print(f"[serve] placement refreshed; predicted C(A)={pred:.2f}")
    s = eng.stats
    print(f"[serve] {s.n_requests} requests, hit-rate {s.hit_rate:.1%}, "
          f"mean cost {s.mean_cost:.2f} ms, model batches {s.model_calls}")


if __name__ == "__main__":
    main()
