"""Recompute roofline dicts for stored dry-run JSONs (no recompilation).

Used when the roofline *formulas* change (e.g. the decode bandwidth
floor); the measured artifacts (extrapolated flops/bytes/collectives,
memory analysis) are reused as-is.

  PYTHONPATH=src python -m repro.launch.reanalyze
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs.registry import get_config
from repro.launch import roofline as rf
from repro.launch.specs import SHAPES

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "results", "dryrun")


def reanalyze_file(path: str) -> bool:
    with open(path) as f:
        r = json.load(f)
    if r.get("status") != "ok":
        return False
    cfg = get_config(r["arch"])
    if r.get("policy", {}).get("moe_dispatch") == "gather":
        import dataclasses
        cfg = dataclasses.replace(cfg, moe_dispatch="gather")
    cell = SHAPES[r["shape"]]
    n_dev = r["devices"]
    corr = r["extrapolated"]
    flops_dev = max(corr["flops"], rf.analytic_flops(cfg, cell) / n_dev)
    moment = "int8" if r["arch"] in ("jamba-1.5-large-398b", "dbrx-132b",
                                     "deepseek-67b", "deepseek-coder-33b") \
        else "float32"
    bytes_dev = rf.analytic_bytes(
        cfg, cell, n_dev, moment,
        ffn_mode=r.get("policy", {}).get("ffn_mode", "tp"))
    old = r["roofline"]
    roof = rf.roofline(flops_dev, bytes_dev, corr["coll_bytes"],
                       {"counts": old.get("collective_counts", {}),
                        "per_op_bytes": old.get("collective_per_op_bytes",
                                                {})},
                       cfg, cell, n_dev,
                       raw_cost=old.get("raw_cost_analysis", {}))
    roof["xla_bytes_extrapolated"] = corr["bytes"]
    r["roofline"] = roof
    r["analytic_flops_global"] = rf.analytic_flops(cfg, cell)
    with open(path, "w") as f:
        json.dump(r, f, indent=1)
    return True


def main() -> None:
    n = 0
    for path in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        n += reanalyze_file(path)
    print(f"[reanalyze] updated {n} cells")


if __name__ == "__main__":
    main()
