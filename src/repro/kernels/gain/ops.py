"""Jitted public wrapper for the GREEDY gain kernel (padding + transpose)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.gain.gain import (DEFAULT_BO, DEFAULT_BR, H_SENTINEL,
                                     gain_pallas)
from repro.kernels.gain.ref import gain_ref
from repro.kernels.knn.ops import LANE, _on_tpu, _pad_axis


@functools.partial(jax.jit, static_argnames=("metric", "gamma", "br", "bo",
                                              "use_pallas", "interpret"))
def greedy_gain(x: jax.Array, y: jax.Array, lam: jax.Array, cur: jax.Array,
                hreq: jax.Array, metric: str = "l2", gamma: float = 1.0,
                br: int = DEFAULT_BR, bo: int = DEFAULT_BO,
                use_pallas: bool = True, interpret: bool | None = None
                ) -> jax.Array:
    """(O, J) marginal gains for all candidate approximizers.

    x: (R, D) request embeddings; y: (O, D) candidate objects; lam, cur:
    (R,) rates and current serving costs; hreq: (R, J) ingress→cache
    retrieval costs (+inf allowed: mapped to a finite sentinel).
    """
    n_obj = y.shape[0]
    hreq = jnp.where(jnp.isfinite(hreq), hreq, H_SENTINEL)
    if not use_pallas:
        return gain_ref(x, y, lam, cur, hreq, metric, gamma)
    if interpret is None:
        interpret = not _on_tpu()
    xp = _pad_axis(_pad_axis(x.astype(jnp.float32), LANE, 1, "zero"),
                   br, 0, "zero")
    yp = _pad_axis(_pad_axis(y.astype(jnp.float32), LANE, 1, "zero"),
                   bo, 0, "zero")
    lamp = _pad_axis(lam.astype(jnp.float32)[:, None], br, 0, "zero")
    curp = _pad_axis(cur.astype(jnp.float32)[:, None], br, 0, "zero")
    hp = _pad_axis(hreq.astype(jnp.float32), br, 0, "zero")
    out = gain_pallas(xp, yp, lamp, curp, hp, metric=metric, gamma=gamma,
                      br=br, bo=bo, interpret=interpret)
    return out[:, :n_obj].T
