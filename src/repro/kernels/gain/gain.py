"""Pallas TPU kernel: fused GREEDY marginal-gain reduction.

Computes gain[j, o'] = Σ_r λ_r · relu(cur_r − C_a(x_r, y_{o'}) − H[r, j])
without materializing the (R, O) distance matrix in HBM: each grid step
computes one (BR, BO) distance tile on the MXU and immediately folds it
into the (J, BO) accumulator tile, turning GREEDY's dominant cost (§3.2:
O_R·N·O·K evaluations) into a stream of fused matmul+reduce tiles.

  * grid = (O//BO, R//BR); the request axis is minor, so each candidate
    tile accumulates over request tiles sequentially in its VMEM output
    block (same accumulation idiom as kernels/knn).
  * outputs are (J, O) — J (number of caches, small) in sublanes, O in
    lanes — transposed back by ops.py.
  * the per-cache loop over j is a static unroll (J ≤ 16 in practice).

Padding contracts (enforced by ops.py): R padded with λ = 0 rows (their
contribution vanishes), O padded and sliced off afterwards, D zero-padded
(distance-preserving), off-path entries of H use a large finite sentinel
(relu clamps them to zero gain; +inf would generate NaNs via inf−inf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.knn.knn import _distance_block

DEFAULT_BR = 256
DEFAULT_BO = 256
H_SENTINEL = 1.0e30      # "off-path" finite stand-in for +inf


def _gain_kernel(x_ref, y_ref, lam_ref, cur_ref, h_ref, out_ref, *,
                 metric: str, gamma: float, n_caches: int):
    rt = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)          # (BR, D)
    y = y_ref[...].astype(jnp.float32)          # (BO, D)
    lam = lam_ref[...].astype(jnp.float32)      # (BR, 1)
    cur = cur_ref[...].astype(jnp.float32)      # (BR, 1)
    h = h_ref[...].astype(jnp.float32)          # (BR, J)

    ca = _distance_block(x, y, metric)          # (BR, BO)
    if gamma != 1.0:
        ca = jnp.power(jnp.maximum(ca, 0.0), gamma)
    slack = cur - ca                            # (BR, BO)

    @pl.when(rt == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    for j in range(n_caches):                   # static unroll, J small
        contrib = jnp.maximum(slack - h[:, j:j + 1], 0.0)     # (BR, BO)
        out_ref[j, :] += jnp.sum(lam * contrib, axis=0)


@functools.partial(jax.jit, static_argnames=(
    "metric", "gamma", "br", "bo", "interpret"))
def gain_pallas(x: jax.Array, y: jax.Array, lam: jax.Array, cur: jax.Array,
                hreq: jax.Array, metric: str = "l2", gamma: float = 1.0,
                br: int = DEFAULT_BR, bo: int = DEFAULT_BO,
                interpret: bool = True) -> jax.Array:
    """Pre-padded inputs: R % br == 0, O % bo == 0. Returns (J, O) f32."""
    R, D = x.shape
    O, _ = y.shape
    J = hreq.shape[1]
    assert R % br == 0 and O % bo == 0, (R, O, br, bo)
    grid = (O // bo, R // br)
    kernel = functools.partial(_gain_kernel, metric=metric, gamma=gamma,
                               n_caches=J)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, D), lambda ot, rt: (rt, 0)),
            pl.BlockSpec((bo, D), lambda ot, rt: (ot, 0)),
            pl.BlockSpec((br, 1), lambda ot, rt: (rt, 0)),
            pl.BlockSpec((br, 1), lambda ot, rt: (rt, 0)),
            pl.BlockSpec((br, J), lambda ot, rt: (rt, 0)),
        ],
        out_specs=pl.BlockSpec((J, bo), lambda ot, rt: (0, ot)),
        out_shape=jax.ShapeDtypeStruct((J, O), jnp.float32),
        interpret=interpret,
    )(x, y, lam, cur, hreq)
    return out
