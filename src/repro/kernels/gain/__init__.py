from repro.kernels.gain.ops import greedy_gain
from repro.kernels.gain.ref import gain_ref

__all__ = ["greedy_gain", "gain_ref"]
