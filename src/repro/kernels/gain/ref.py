"""Pure-jnp oracle for the GREEDY marginal-gain reduction.

gain[o', j] = Σ_r λ_r · relu(cur_r − C_a(x_r, y_{o'}) − H[r, j])

i.e. the total rate-weighted cost reduction of adding candidate object o'
at cache j, given the current per-request serving costs ``cur`` (paper
§3.2: argmax_α G(A ∪ {α}) − G(A)). ``H[r, j]`` is the retrieval cost
from request r's ingress to cache j (+inf ⇒ off-path ⇒ zero gain).
"""
from __future__ import annotations

import jax.numpy as jnp


def gain_ref(x: jnp.ndarray, y: jnp.ndarray, lam: jnp.ndarray,
             cur: jnp.ndarray, hreq: jnp.ndarray, metric: str = "l2",
             gamma: float = 1.0) -> jnp.ndarray:
    """x: (R, D) requests; y: (O, D) candidates; lam, cur: (R,);
    hreq: (R, J). Returns (O, J) gains, f32."""
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    if metric == "l1":
        d = jnp.sum(jnp.abs(x[:, None, :] - y[None, :, :]), axis=-1)
    elif metric in ("l2", "l2sq"):
        d2 = (jnp.sum(x * x, -1)[:, None] + jnp.sum(y * y, -1)[None, :]
              - 2.0 * x @ y.T)
        d2 = jnp.maximum(d2, 0.0)
        d = d2 if metric == "l2sq" else jnp.sqrt(d2)
    else:
        raise ValueError(metric)
    ca = d if gamma == 1.0 else jnp.power(jnp.maximum(d, 0.0), gamma)
    slack = cur[:, None, None] - ca[:, :, None] - hreq[:, None, :]  # (R,O,J)
    return jnp.sum(lam[:, None, None] * jnp.maximum(slack, 0.0), axis=0)
