"""Pallas TPU kernel: blocked nearest-approximizer (1-NN) lookup.

This is the serving-path hot spot of the similarity cache (paper §2: the
"closest stored object" query, which the paper delegates to LSH; DESIGN.md
§6 explains why a blocked exact scan is the TPU-native equivalent).

Layout / tiling:
  * grid = (Q//BQ, K//BK); the key axis is the minor (fastest) grid dim,
    so each query tile sees key tiles sequentially and accumulates a
    running (min cost, argmin index) pair in its output VMEM block.
  * q tile (BQ, D) and k tile (BK, D) live in VMEM; the L2 path computes
    the (BQ, BK) distance block with one MXU matmul via the
    |q|² + |k|² − 2·q·kᵀ identity (f32 accumulation).
  * the L1 path (the paper's norm-1 experiments) has no matmul form; it
    accumulates |q−k| over D in chunks of ``DC`` to bound the
    (BQ, BK, DC) broadcast temporary — VPU work, still VMEM-resident.
  * D is zero-padded to a lane multiple and K is padded by *repeating
    key 0* — ties break to the lower index, so padded duplicates can
    never win over the genuine entry (see ops.py).

Block defaults keep the working set ≲ 2.5 MB ≪ 16 MB VMEM and the MXU
dims 128-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BQ = 256
DEFAULT_BK = 256
L1_CHUNK = 8
_INF = 3.0e38  # python float: jnp scalars would be captured as consts


def _distance_block(q, k, metric: str):
    """(BQ, BK) distances between f32 tiles q (BQ, D), k (BK, D)."""
    if metric in ("l2", "l2sq"):
        d2 = (jnp.sum(q * q, axis=-1)[:, None]
              + jnp.sum(k * k, axis=-1)[None, :]
              - 2.0 * jnp.dot(q, k.T, preferred_element_type=jnp.float32))
        d2 = jnp.maximum(d2, 0.0)
        return d2 if metric == "l2sq" else jnp.sqrt(d2)
    if metric == "l1":
        bq, d = q.shape
        bk = k.shape[0]
        acc = jnp.zeros((bq, bk), dtype=jnp.float32)
        for c in range(0, d, L1_CHUNK):
            qc = q[:, c:c + L1_CHUNK][:, None, :]      # (BQ, 1, DC)
            kc = k[:, c:c + L1_CHUNK][None, :, :]      # (1, BK, DC)
            acc = acc + jnp.sum(jnp.abs(qc - kc), axis=-1)
        return acc
    raise ValueError(metric)


def _knn_kernel(q_ref, k_ref, mind_ref, argm_ref, *, bk: int, metric: str,
                gamma: float):
    kt = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    cost = _distance_block(q, k, metric)
    if gamma != 1.0:
        cost = jnp.power(jnp.maximum(cost, 0.0), gamma)
    local_min = jnp.min(cost, axis=1, keepdims=True)               # (BQ, 1)
    local_arg = jnp.argmin(cost, axis=1).astype(jnp.int32)[:, None]
    local_arg = local_arg + kt * bk

    @pl.when(kt == 0)
    def _init():
        mind_ref[...] = jnp.full_like(mind_ref, _INF)
        argm_ref[...] = jnp.zeros_like(argm_ref)

    better = local_min < mind_ref[...]
    mind_ref[...] = jnp.where(better, local_min, mind_ref[...])
    argm_ref[...] = jnp.where(better, local_arg, argm_ref[...])


def _select_at(idx_col, block, fill):
    """Per-row pick block[i, idx_col[i]] via a one-hot reduce (MXU/VPU
    friendly; no dynamic gather inside the kernel)."""
    onehot = jax.lax.broadcasted_iota(
        jnp.int32, block.shape, 1) == idx_col          # (BQ, BK)
    return jnp.sum(jnp.where(onehot, block, fill), axis=1, keepdims=True)


def _fused_kernel(q_ref, k_ref, hk_ref, meta_ref,
                  cost_ref, ca_ref, lvl_ref, slot_ref, pay_ref,
                  *, nk: int, metric: str, gamma: float, h_repo: float,
                  repo_level: int, fold_repo: bool):
    """Segmented 1-NN over the concatenation of all cache levels.

    Per key tile we get, besides the (BK, D) key block, a (1, BK) f32 row
    of additive level costs h(level(k)) and a (4, BK) i32 metadata block
    (rows: level id, slot within level, payload id, valid flag). Sentinel
    / padding keys carry valid == 0 and are masked to +INF *explicitly* —
    their distances may be inf/NaN (e.g. an f32-overflowing sentinel
    coordinate under l2sq) and must never reach the min.

    The repository is the virtual key folded in on the last key tile:
    cost h_repo, C_a = 0, level = repo_level, slot = 0, payload = −1. It
    wins only on strict improvement, so a cache tying h_repo serves the
    request — the same tie-break as argmin over [levels…, repo].

    ``fold_repo=False`` skips that last-tile fold: the kernel then
    returns the *local* segment minimum only (cost = +INF, level =
    repo_level, payload = −1 when no valid key exists) — the shard-local
    entry of the mesh-sharded lookup, whose caller folds the repository
    once after the cross-shard reduction.
    """
    kt = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    ca = _distance_block(q, k, metric)
    if gamma != 1.0:
        ca = jnp.power(jnp.maximum(ca, 0.0), gamma)
    meta = meta_ref[...]                               # (4, BK) int32
    valid = (meta[3, :] > 0)[None, :]                  # (1, BK)
    cost = jnp.where(valid, ca + hk_ref[...], _INF)    # (BQ, BK)
    local_min = jnp.min(cost, axis=1, keepdims=True)   # (BQ, 1)
    local_arg = jnp.argmin(cost, axis=1).astype(jnp.int32)[:, None]

    @pl.when(kt == 0)
    def _init():
        cost_ref[...] = jnp.full_like(cost_ref, _INF)
        ca_ref[...] = jnp.zeros_like(ca_ref)
        lvl_ref[...] = jnp.full_like(lvl_ref, repo_level)
        slot_ref[...] = jnp.zeros_like(slot_ref)
        pay_ref[...] = jnp.full_like(pay_ref, -1)

    bcast = jnp.zeros(local_arg.shape, jnp.int32)      # (BQ, 1) index col
    better = local_min < cost_ref[...]
    cost_ref[...] = jnp.where(better, local_min, cost_ref[...])
    ca_ref[...] = jnp.where(
        better, _select_at(local_arg, jnp.where(valid, ca, 0.0), 0.0),
        ca_ref[...])
    lvl_ref[...] = jnp.where(
        better, _select_at(local_arg, meta[0:1, :] + bcast, 0), lvl_ref[...])
    slot_ref[...] = jnp.where(
        better, _select_at(local_arg, meta[1:2, :] + bcast, 0), slot_ref[...])
    pay_ref[...] = jnp.where(
        better, _select_at(local_arg, meta[2:3, :] + bcast, 0), pay_ref[...])

    if fold_repo:
        @pl.when(kt == nk - 1)
        def _repo():
            use_repo = h_repo < cost_ref[...]
            cost_ref[...] = jnp.where(use_repo, h_repo, cost_ref[...])
            ca_ref[...] = jnp.where(use_repo, 0.0, ca_ref[...])
            lvl_ref[...] = jnp.where(use_repo, repo_level, lvl_ref[...])
            slot_ref[...] = jnp.where(use_repo, 0, slot_ref[...])
            pay_ref[...] = jnp.where(use_repo, -1, pay_ref[...])


@functools.partial(jax.jit, static_argnames=(
    "metric", "gamma", "h_repo", "repo_level", "bq", "bk", "interpret",
    "fold_repo"))
def fused_lookup_pallas(queries: jax.Array, keys: jax.Array,
                        h_key: jax.Array, meta: jax.Array,
                        metric: str = "l2", gamma: float = 1.0,
                        h_repo: float = 0.0, repo_level: int = -1,
                        bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                        interpret: bool = True,
                        fold_repo: bool = True) -> tuple[jax.Array, ...]:
    """Fused multi-level 1-NN: one pallas_call over ΣK_j concatenated
    keys, minimizing C_a(q, k)^γ + h(level(k)) with the repository folded
    in as a virtual key. Inputs must be pre-padded (Q % bq == 0,
    K % bk == 0; padding keys carry meta valid == 0).

    ``h_key`` is (1, K) f32; ``meta`` is (4, K) i32 with rows
    (level, slot, payload, valid). Returns per query (cost, approx_cost,
    level, slot, payload). ``fold_repo=False`` is the shard-local entry:
    segment minima only, no repository fold (see _fused_kernel).
    """
    Q, D = queries.shape
    K, _ = keys.shape
    assert Q % bq == 0 and K % bk == 0, (Q, K, bq, bk)
    assert h_key.shape == (1, K) and meta.shape == (4, K), \
        (h_key.shape, meta.shape, K)
    grid = (Q // bq, K // bk)
    kernel = functools.partial(
        _fused_kernel, nk=K // bk, metric=metric, gamma=gamma,
        h_repo=h_repo, repo_level=repo_level, fold_repo=fold_repo)
    out_block = pl.BlockSpec((bq, 1), lambda qt, kt: (qt, 0))
    cost, ca, lvl, slot, pay = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, D), lambda qt, kt: (qt, 0)),
            pl.BlockSpec((bk, D), lambda qt, kt: (kt, 0)),
            pl.BlockSpec((1, bk), lambda qt, kt: (0, kt)),
            pl.BlockSpec((4, bk), lambda qt, kt: (0, kt)),
        ],
        out_specs=[out_block] * 5,
        out_shape=[
            jax.ShapeDtypeStruct((Q, 1), jnp.float32),
            jax.ShapeDtypeStruct((Q, 1), jnp.float32),
            jax.ShapeDtypeStruct((Q, 1), jnp.int32),
            jax.ShapeDtypeStruct((Q, 1), jnp.int32),
            jax.ShapeDtypeStruct((Q, 1), jnp.int32),
        ],
        interpret=interpret,
    )(queries, keys, h_key, meta)
    return cost[:, 0], ca[:, 0], lvl[:, 0], slot[:, 0], pay[:, 0]


@functools.partial(jax.jit, static_argnames=(
    "metric", "gamma", "bq", "bk", "interpret"))
def knn_pallas(queries: jax.Array, keys: jax.Array, metric: str = "l2",
               gamma: float = 1.0, bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
               interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Blocked 1-NN. Inputs must be pre-padded: Q % bq == 0, K % bk == 0,
    with key padding = repeats of keys[0] (see ops.pad_for_knn)."""
    Q, D = queries.shape
    K, _ = keys.shape
    assert Q % bq == 0 and K % bk == 0, (Q, K, bq, bk)
    grid = (Q // bq, K // bk)
    kernel = functools.partial(_knn_kernel, bk=bk, metric=metric, gamma=gamma)
    mind, argm = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, D), lambda qt, kt: (qt, 0)),
            pl.BlockSpec((bk, D), lambda qt, kt: (kt, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, 1), lambda qt, kt: (qt, 0)),
            pl.BlockSpec((bq, 1), lambda qt, kt: (qt, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Q, 1), jnp.float32),
            jax.ShapeDtypeStruct((Q, 1), jnp.int32),
        ],
        interpret=interpret,
    )(queries, keys)
    return mind[:, 0], argm[:, 0]
