"""Pallas TPU kernel: blocked nearest-approximizer (1-NN) lookup.

This is the serving-path hot spot of the similarity cache (paper §2: the
"closest stored object" query, which the paper delegates to LSH; DESIGN.md
§6 explains why a blocked exact scan is the TPU-native equivalent).

Layout / tiling:
  * grid = (Q//BQ, K//BK); the key axis is the minor (fastest) grid dim,
    so each query tile sees key tiles sequentially and accumulates a
    running (min cost, argmin index) pair in its output VMEM block.
  * q tile (BQ, D) and k tile (BK, D) live in VMEM; the L2 path computes
    the (BQ, BK) distance block with one MXU matmul via the
    |q|² + |k|² − 2·q·kᵀ identity (f32 accumulation).
  * the L1 path (the paper's norm-1 experiments) has no matmul form; it
    accumulates |q−k| over D in chunks of ``DC`` to bound the
    (BQ, BK, DC) broadcast temporary — VPU work, still VMEM-resident.
  * D is zero-padded to a lane multiple and K is padded by *repeating
    key 0* — ties break to the lower index, so padded duplicates can
    never win over the genuine entry (see ops.py).

Block defaults keep the working set ≲ 2.5 MB ≪ 16 MB VMEM and the MXU
dims 128-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BQ = 256
DEFAULT_BK = 256
L1_CHUNK = 8
_INF = 3.0e38  # python float: jnp scalars would be captured as consts


def _distance_block(q, k, metric: str):
    """(BQ, BK) distances between f32 tiles q (BQ, D), k (BK, D)."""
    if metric in ("l2", "l2sq"):
        d2 = (jnp.sum(q * q, axis=-1)[:, None]
              + jnp.sum(k * k, axis=-1)[None, :]
              - 2.0 * jnp.dot(q, k.T, preferred_element_type=jnp.float32))
        d2 = jnp.maximum(d2, 0.0)
        return d2 if metric == "l2sq" else jnp.sqrt(d2)
    if metric == "l1":
        bq, d = q.shape
        bk = k.shape[0]
        acc = jnp.zeros((bq, bk), dtype=jnp.float32)
        for c in range(0, d, L1_CHUNK):
            qc = q[:, c:c + L1_CHUNK][:, None, :]      # (BQ, 1, DC)
            kc = k[:, c:c + L1_CHUNK][None, :, :]      # (1, BK, DC)
            acc = acc + jnp.sum(jnp.abs(qc - kc), axis=-1)
        return acc
    raise ValueError(metric)


def _knn_kernel(q_ref, k_ref, mind_ref, argm_ref, *, bk: int, metric: str,
                gamma: float):
    kt = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    cost = _distance_block(q, k, metric)
    if gamma != 1.0:
        cost = jnp.power(jnp.maximum(cost, 0.0), gamma)
    local_min = jnp.min(cost, axis=1, keepdims=True)               # (BQ, 1)
    local_arg = jnp.argmin(cost, axis=1).astype(jnp.int32)[:, None]
    local_arg = local_arg + kt * bk

    @pl.when(kt == 0)
    def _init():
        mind_ref[...] = jnp.full_like(mind_ref, _INF)
        argm_ref[...] = jnp.zeros_like(argm_ref)

    better = local_min < mind_ref[...]
    mind_ref[...] = jnp.where(better, local_min, mind_ref[...])
    argm_ref[...] = jnp.where(better, local_arg, argm_ref[...])


@functools.partial(jax.jit, static_argnames=(
    "metric", "gamma", "bq", "bk", "interpret"))
def knn_pallas(queries: jax.Array, keys: jax.Array, metric: str = "l2",
               gamma: float = 1.0, bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
               interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Blocked 1-NN. Inputs must be pre-padded: Q % bq == 0, K % bk == 0,
    with key padding = repeats of keys[0] (see ops.pad_for_knn)."""
    Q, D = queries.shape
    K, _ = keys.shape
    assert Q % bq == 0 and K % bk == 0, (Q, K, bq, bk)
    grid = (Q // bq, K // bk)
    kernel = functools.partial(_knn_kernel, bk=bk, metric=metric, gamma=gamma)
    mind, argm = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, D), lambda qt, kt: (qt, 0)),
            pl.BlockSpec((bk, D), lambda qt, kt: (kt, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, 1), lambda qt, kt: (qt, 0)),
            pl.BlockSpec((bq, 1), lambda qt, kt: (qt, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Q, 1), jnp.float32),
            jax.ShapeDtypeStruct((Q, 1), jnp.int32),
        ],
        interpret=interpret,
    )(queries, keys)
    return mind[:, 0], argm[:, 0]
