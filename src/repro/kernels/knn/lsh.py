"""LSH / k-means candidate pruning for the fused similarity-cache lookup.

The paper delegates the nearest-approximizer query behind eq. (1) to LSH;
our fused segmented-1-NN kernel is an *exact* O(ΣK_j·d) scan per request.
This module adds the candidate pre-filter in front of it: a
:class:`CandidatePolicy` (SimHash random-hyperplane tables with
multi-probe, or k-means routing) maps a query batch to a per-query
candidate matrix of key indices, the batch union of those candidates is
compacted into one padded, *ascending* index tensor, and the existing
fused kernel is launched over only the gathered rows. Because the
segmented layout's ``meta`` rows (level, slot, payload, valid) travel
with each gathered key, the kernel needs no remapping — and because the
union is sorted ascending, relative concatenated-index order (hence
tie-break order) is exactly the full scan's.

Per-shard table layout
    With the mesh-sharded data plane the tables are built *per shard* of
    the contiguous balanced ``SimCacheNetwork.sharded_layout(n)`` chunks:
    shard ``s`` gets its own tables (hyperplanes / centroids drawn from
    ``policy.for_shard(s)``, bucket member lists holding *shard-local*
    row indices into its resident chunk), stacked on a leading
    ``(n_shards, …)`` axis that shard_map partitions alongside the key
    tensor. Each shard hashes the replicated query batch against its own
    tables, prunes its resident chunk, and runs its ``fold_repo=False``
    fused kernel over the gathered rows only; the per-shard minima then
    flow through the *unchanged* ``reduce_shard_minima`` (ties still to
    the lowest shard = lowest concatenated index). The candidate mask
    only ever shrinks a shard's scan — it never changes the reduction or
    the tie-break order. Bucket-size resolution (n_bits / n_clusters)
    uses the *chunk length*, identical across shards by construction, so
    the stacked tables are rectangular; per-shard bucket capacities are
    padded to the max with −1 sentinels.

Verifier contract (``verify=True``)
    Pruning is admissible — scanning fewer keys can only *raise* the
    winning cost — but an LSH miss can return a suboptimal approximizer.
    Every pruned lookup therefore also returns a **bound**: the minimum
    retrieval cost ``h`` over the valid keys that were *not* scanned
    (+INF when the union covered everything). Any un-scanned key costs at
    least ``C_a ≥ 0`` plus its ``h``, so a pruned result with
    ``cost < bound`` is *provably* the exact winner — same arithmetic,
    same kernel, same tie-break — and is accepted as is. ``verify=True``
    re-scans every query with ``cost ≥ bound`` through the exact path
    (including exact ties, which could break toward an un-scanned lower
    index), making the verified result bit-identical to the exact fused
    lookup by construction, not merely with high probability. The exact
    scan thus remains the fallback/verifier of last resort, as the
    ROADMAP requires.

Staleness: tables are memoized next to the fused/sharded layouts and
dropped by ``SimCacheNetwork.invalidate_layout``. Unlike the plain fused
path (documented to serve the stale concatenation verbatim), a pruned
lookup against mutated-but-not-invalidated levels raises loudly — stale
buckets would silently return candidates into a layout that no longer
exists.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

_INF = 3.0e38


@dataclasses.dataclass(frozen=True)
class CandidateTables:
    """Built lookup tables of one :class:`CandidatePolicy` over one key
    segment (the whole fused layout, or one shard's resident chunk).

    ``proj`` is (T, d, n_bits) hyperplane normals for SimHash, (C, d)
    centroids for k-means routing; ``buckets`` is (T, 2**n_bits, cap) /
    (C, cap) int32 member lists of segment-local key rows, −1-padded,
    each bucket's members in ascending row order. ``n_probes`` is the
    resolved multi-probe count (exact bucket + least-confident bit
    flips, or the n nearest centroids).
    """
    kind: str                 # "lsh" | "kmeans"
    proj: np.ndarray
    buckets: np.ndarray
    n_keys: int
    n_probes: int


@runtime_checkable
class CandidatePolicy(Protocol):
    """One interface in front of the fused kernel: build tables over a
    key segment, later hash query batches into candidate rows."""
    kind: ClassVar[str]
    seed: int

    def build(self, keys: np.ndarray, valid: np.ndarray) -> CandidateTables:
        ...

    def for_shard(self, shard: int) -> "CandidatePolicy":
        ...

    def resolve_cap(self, n_keys: int) -> int:
        ...


def _resolve_cap(max_candidates: int | None, n_keys: int) -> int:
    """Static capacity of the batch-union candidate tensor. Overflowing
    candidates (highest rows) are dropped — admissible, and accounted
    for by the verify bound, which treats dropped rows as un-scanned."""
    if max_candidates is not None:
        return max(1, min(n_keys, max_candidates))
    return max(1, min(n_keys, max(4096, n_keys // 4)))


def _bucket_cap_limit(bucket_cap: int, n_valid: int, n_buckets: int,
                      over: int = 8) -> int:
    """Per-bucket member capacity: ``over``× the mean load by default
    (≥ 16), so one hot bucket of duplicate keys can't inflate the whole
    dense (tables, buckets, cap) tensor to O(hottest·buckets). Members
    past the cap (highest rows, the fill is ascending) are dropped at
    build time — never candidates, i.e. "un-scanned" to the verify
    bound, which keeps ``verify=True`` exact regardless of skew.
    k-means passes a larger ``over``: Lloyd clusters skew naturally
    (dense regions get big clusters) where balanced hash buckets
    don't."""
    if bucket_cap:
        return bucket_cap
    return max(16, over * -(-n_valid // max(n_buckets, 1)))


def _fill_buckets(buckets: np.ndarray, codes: np.ndarray, vi: np.ndarray,
                  cap: int) -> None:
    """Fill one table's (n_buckets, cap) member lists from per-key
    bucket ``codes``; each bucket keeps its first ``cap`` members in
    ascending key order (stable sort over ascending ``vi``)."""
    order = np.argsort(codes, kind="stable")
    cs = codes[order]
    _, start, cnt = np.unique(cs, return_index=True, return_counts=True)
    rank = np.arange(cs.size) - np.repeat(start, cnt)
    keep = rank < cap
    buckets[cs[keep], rank[keep]] = vi[order][keep]


@dataclasses.dataclass(frozen=True)
class SimHashPolicy:
    """Random-hyperplane (SimHash) tables with multi-probe.

    ``n_bits=0`` resolves to log2(segment/32) clamped to [2, 16] (≈32
    keys per bucket); ``n_probes=0`` resolves to 1 + min(n_bits, 3):
    the exact bucket plus flips of the least-confident (smallest
    |margin|) bits, the standard multi-probe sequence.
    """
    kind: ClassVar[str] = "lsh"
    n_tables: int = 8
    n_bits: int = 0
    n_probes: int = 0
    bucket_cap: int = 0
    max_candidates: int | None = None
    seed: int = 0

    def for_shard(self, shard: int) -> "SimHashPolicy":
        return dataclasses.replace(self, seed=self.seed + shard + 1)

    def resolve_bits(self, n_keys: int) -> int:
        if self.n_bits:
            return self.n_bits
        return int(np.clip(round(np.log2(max(n_keys, 1) / 32.0)), 2, 16))

    def resolve_probes(self, n_bits: int) -> int:
        p = self.n_probes or 1 + min(n_bits, 3)
        return int(np.clip(p, 1, n_bits + 1))

    def resolve_cap(self, n_keys: int) -> int:
        return _resolve_cap(self.max_candidates, n_keys)

    def build(self, keys: np.ndarray, valid: np.ndarray) -> CandidateTables:
        keys = np.asarray(keys, np.float32)
        valid = np.asarray(valid, bool)
        n_keys, d = keys.shape
        bits = self.resolve_bits(n_keys)
        rng = np.random.default_rng(self.seed)
        planes = rng.standard_normal((self.n_tables, d, bits)) \
            .astype(np.float32)
        vi = np.nonzero(valid)[0].astype(np.int32)
        # per-table loop keeps the (n_valid, bits) margin temporary small
        codes = np.empty((self.n_tables, vi.size), np.int64)
        for t in range(self.n_tables):
            m = keys[vi] @ planes[t]                      # (n_valid, bits)
            codes[t] = ((m > 0).astype(np.int64)
                        << np.arange(bits)).sum(-1)
        cap = 1
        if vi.size:
            cap = max(int(np.bincount(codes[t], minlength=2 ** bits).max())
                      for t in range(self.n_tables))
            cap = min(cap, _bucket_cap_limit(self.bucket_cap, vi.size,
                                             2 ** bits))
        buckets = np.full((self.n_tables, 2 ** bits, cap), -1, np.int32)
        for t in range(self.n_tables):
            _fill_buckets(buckets[t], codes[t], vi, cap)
        return CandidateTables(kind=self.kind, proj=planes, buckets=buckets,
                               n_keys=n_keys,
                               n_probes=self.resolve_probes(bits))


@dataclasses.dataclass(frozen=True)
class KMeansPolicy:
    """k-means routing alternative: keys cluster under Lloyd's algorithm
    (fit on a subsample, all keys assigned once), a query probes the
    ``n_probes`` nearest centroids and scans their member lists.

    ``n_clusters=0`` resolves to √segment clamped to [4, 1024];
    ``n_probes=0`` to a quarter of the clusters clamped to [2, 64] (the
    generous default that keeps recall ≥ 0.99 on the paper's demands).
    """
    kind: ClassVar[str] = "kmeans"
    n_clusters: int = 0
    n_probes: int = 0
    n_iters: int = 10
    fit_sample: int = 20_000
    bucket_cap: int = 0
    max_candidates: int | None = None
    seed: int = 0

    def for_shard(self, shard: int) -> "KMeansPolicy":
        return dataclasses.replace(self, seed=self.seed + shard + 1)

    def resolve_clusters(self, n_keys: int) -> int:
        if self.n_clusters:
            return self.n_clusters
        return int(np.clip(round(np.sqrt(max(n_keys, 1))), 4, 1024))

    def resolve_probes(self, n_clusters: int) -> int:
        p = self.n_probes or int(np.clip(round(n_clusters / 4), 2, 64))
        return int(np.clip(p, 1, n_clusters))

    def resolve_cap(self, n_keys: int) -> int:
        return _resolve_cap(self.max_candidates, n_keys)

    def build(self, keys: np.ndarray, valid: np.ndarray) -> CandidateTables:
        keys = np.asarray(keys, np.float32)
        valid = np.asarray(valid, bool)
        n_keys, d = keys.shape
        C = self.resolve_clusters(n_keys)
        rng = np.random.default_rng(self.seed)
        vi = np.nonzero(valid)[0].astype(np.int32)
        if vi.size == 0:
            return CandidateTables(
                kind=self.kind, proj=np.zeros((C, d), np.float32),
                buckets=np.full((C, 1), -1, np.int32), n_keys=n_keys,
                n_probes=self.resolve_probes(C))
        x = keys[vi]
        sub = x[rng.choice(vi.size, min(vi.size, self.fit_sample),
                           replace=False)]
        cent = x[rng.choice(vi.size, C, replace=vi.size < C)].copy()
        for _ in range(self.n_iters):
            a = _nearest_centroid(sub, cent)
            for c in range(C):
                m = a == c
                if m.any():
                    cent[c] = sub[m].mean(axis=0)
        assign = _nearest_centroid(x, cent)
        cap = max(1, int(np.bincount(assign, minlength=C).max()))
        cap = min(cap, _bucket_cap_limit(self.bucket_cap, vi.size, C,
                                         over=16))
        buckets = np.full((C, cap), -1, np.int32)
        _fill_buckets(buckets, assign, vi, cap)
        return CandidateTables(kind=self.kind, proj=cent, buckets=buckets,
                               n_keys=n_keys, n_probes=self.resolve_probes(C))


def _nearest_centroid(x: np.ndarray, cent: np.ndarray,
                      chunk: int = 65_536) -> np.ndarray:
    """Chunked argmin over centroids: the (chunk, C) distance block caps
    build-time memory at ~chunk·C f32 however large the key segment."""
    c2 = (cent * cent).sum(-1)[None, :]
    out = np.empty(x.shape[0], np.int64)
    for s in range(0, x.shape[0], chunk):
        xs = x[s:s + chunk]
        d2 = (xs * xs).sum(-1)[:, None] + c2 - 2.0 * xs @ cent.T
        out[s:s + chunk] = np.argmin(d2, axis=1)
    return out


def default_policy(kind: str, seed: int = 0) -> CandidatePolicy:
    if kind == "lsh":
        return SimHashPolicy(seed=seed)
    if kind == "kmeans":
        return KMeansPolicy(seed=seed)
    raise ValueError(f"unknown candidate policy {kind!r} "
                     "(expected 'lsh' or 'kmeans')")


# ------------------------------------------------------------ query side
def candidate_matrix(kind: str, proj: jax.Array, buckets: jax.Array,
                     queries: jax.Array, n_probes: int) -> jax.Array:
    """(B, P) candidate rows per query, −1-padded; jit-traceable.

    SimHash: per table, the query's own bucket plus ``n_probes − 1``
    buckets at Hamming distance 1, flipping the least-confident bits
    (smallest |margin|) first. k-means: the ``n_probes`` nearest
    centroids' member lists.
    """
    q = queries.astype(jnp.float32)
    if kind == "lsh":
        T, _, bits = proj.shape
        margins = jnp.einsum("bd,tdh->bth", q, proj)       # (B, T, bits)
        weights = (1 << jnp.arange(bits, dtype=jnp.int32))
        code = jnp.sum((margins > 0) * weights, axis=-1,
                       dtype=jnp.int32)                    # (B, T)
        if n_probes > 1:
            order = jnp.argsort(jnp.abs(margins), axis=-1)  # least sure 1st
            flips = (1 << order[..., :n_probes - 1].astype(jnp.int32))
            codes = jnp.concatenate(
                [code[..., None], code[..., None] ^ flips], axis=-1)
        else:
            codes = code[..., None]                        # (B, T, P)
        cand = buckets[jnp.arange(T)[None, :, None], codes]
        return cand.reshape(q.shape[0], -1)
    if kind == "kmeans":
        d2 = (jnp.sum(q * q, -1)[:, None]
              + jnp.sum(proj * proj, -1)[None, :]
              - 2.0 * q @ proj.T)                          # (B, C)
        _, idx = jax.lax.top_k(-d2, n_probes)
        return buckets[idx].reshape(q.shape[0], -1)
    raise ValueError(kind)


def candidate_union(cand: jax.Array, n_keys: int, cap: int
                    ) -> tuple[jax.Array, jax.Array]:
    """Batch union of (B, P) candidates → (``kept``, ``kept_mask``).

    ``kept`` is the compact padded index tensor: the first ``cap``
    distinct candidate rows in *ascending* order (preserving the full
    scan's tie-break order), padded with ``n_keys``; ``kept_mask`` (K,)
    marks rows that actually get scanned, so the verify bound can count
    everything else — including overflow drops — as un-scanned.
    """
    c = jnp.where(cand >= 0, cand, n_keys).reshape(-1)
    mask = jnp.zeros((n_keys + 1,), bool).at[c].set(True, mode="drop")
    mask = mask.at[n_keys].set(False)
    kept = jnp.nonzero(mask, size=cap, fill_value=n_keys)[0] \
        .astype(jnp.int32)
    kept_mask = jnp.zeros((n_keys + 1,), bool) \
        .at[kept].set(True, mode="drop")[:n_keys]
    return kept, kept_mask


def gather_candidate_rows(keys: jax.Array, h_key: jax.Array,
                          meta: jax.Array, kept: jax.Array
                          ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Gather the kept rows of the segmented layout; the padding index
    ``n_keys`` resolves to an appended invalid row (valid = 0, payload =
    −1) that the fused kernel masks exactly like shard padding."""
    pad_key = jnp.zeros((1, keys.shape[1]), keys.dtype)
    pad_meta = jnp.array([[0], [0], [-1], [0]], meta.dtype)
    keys_e = jnp.concatenate([keys, pad_key])
    h_e = jnp.concatenate([h_key.astype(jnp.float32), jnp.zeros((1,))])
    meta_e = jnp.concatenate([meta, pad_meta], axis=1)
    return keys_e[kept], h_e[kept], meta_e[:, kept]


def unscanned_h_bound(h_key: jax.Array, meta: jax.Array,
                      kept_mask: jax.Array) -> jax.Array:
    """Scalar verify bound: min h over valid keys *outside* the scanned
    union (+INF when it covered everything). Any un-scanned key costs at
    least this, so ``cost < bound`` proves the pruned winner exact."""
    outside = (meta[3, :] > 0) & ~kept_mask
    return jnp.min(jnp.where(outside, h_key.astype(jnp.float32), _INF))


def stack_shard_tables(tables: list[CandidateTables]
                       ) -> tuple[np.ndarray, np.ndarray, int]:
    """Stack per-shard tables on a leading (n_shards, …) axis for
    shard_map, padding bucket capacities to the max with −1."""
    cap = max(t.buckets.shape[-1] for t in tables)
    padded = [np.concatenate(
        [t.buckets,
         np.full(t.buckets.shape[:-1] + (cap - t.buckets.shape[-1],), -1,
                 np.int32)], axis=-1) for t in tables]
    probes = {t.n_probes for t in tables}
    assert len(probes) == 1, "shards resolved different probe counts"
    return (np.stack([t.proj for t in tables]), np.stack(padded),
            probes.pop())
