"""Pallas TPU kernel: batched placement gain oracle for the control plane.

GREEDY/LOCALSWAP (paper §3.2–3.3) are driven entirely by marginal gains

    gain[o', j] = Σ_i Σ_r λ[i, r] · relu(cur[i, r] − C_a(x_r, y_{o'})
                                          − H[i, j])

over *all* candidate (object o', cache j) pairs, where ``cur`` is the
current per-(ingress, object) serving cost matrix C(r, A).  This module
computes the whole (O, J) gain matrix in one launch, reusing the
segmented distance machinery of the fused lookup (``_distance_block``,
the padding contracts of ops.py): each grid step computes one (BR, BO)
C_a tile on the MXU **once** and folds it into the (J, BO) accumulator
for every (ingress, cache) pair — the ingress axis is the segment axis,
carried as extra sublane rows of the λ/cur blocks instead of flattened
request copies (the kernels/gain kernel's layout), so the dominant
distance work is shared across the whole network.

Entries:

* :func:`placement_gains` — public jitted wrapper (padding + sentinel
  mapping + transpose).  ``use_pallas=None`` resolves to the Pallas
  kernel on TPU and to :func:`_gains_tiles_jnp` (a lax.map-blocked jnp
  path that never materializes the (R, O) distance matrix) elsewhere —
  the same auto-dispatch convention as kernels/knn/ops.py.
* :func:`placement_gains_matrix` — explicit-C_a-matrix variant (the
  paper's first instance, §2): tiles columns of a device-resident
  (R, O) matrix instead of computing distances.
* :func:`sharded_placement_gains` — SPMD entry: the candidate axis is
  shard_mapped over mesh axes (launch.sharding.LookupShardPolicy picks
  them), every shard computes the gains of its resident candidate chunk
  against the replicated request stream, and the (O, J) output comes
  back sharded.  Per-candidate sums are computed with identical request
  tiling whatever the shard count, so the result is bit-identical to
  the single-device oracle by construction.

Padding contracts (mirroring kernels/gain): request rows pad with
λ = 0 (their contribution vanishes), candidate rows pad with zeros and
are sliced off, D zero-pads to a lane multiple (distance-preserving),
off-path +inf entries of H map to the finite ``H_SENTINEL`` (relu
clamps them to zero gain; inf − inf would breed NaNs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.kernels import quant
from repro.kernels.knn.knn import _distance_block
from repro.kernels.knn.ops import LANE, _on_tpu, _pad_axis, mesh_axes_size

DEFAULT_BR = 256
DEFAULT_BO = 256
H_SENTINEL = 1.0e30      # finite stand-in for +inf (off-path) retrieval cost


def _ca_block(x, y, metric: str, gamma: float):
    """(BR, BO) approximation-cost tile C_a = d(x, y)^γ (f32)."""
    ca = _distance_block(x.astype(jnp.float32), y.astype(jnp.float32), metric)
    if gamma != 1.0:
        ca = jnp.power(jnp.maximum(ca, 0.0), gamma)
    return ca


def duel_virtual_costs(coords, ca, obj, virt_safe, h_slots,
                       metric: str, gamma: float, has_ca: bool):
    """(K,) virtual serving cost C_a(x_o, y_v[k]) + h(i, j(k)) for one
    request — NETDUEL's per-step pricing tile (paper §5), the 1-row
    special case of the gain oracle's C_a tiling. On materialized-C_a
    instances the row gather reproduces the host policy's
    ``ca[o, virt]`` bit-for-bit; past ``objective.CA_MATERIALIZE_MAX``
    the tile is computed on the fly by the same :func:`_ca_block` the
    gain kernels use. Traced inside the NETDUEL scan
    (core/placement/netduel.py), so ``has_ca`` must be static there.
    """
    if has_ca:
        cac = ca[obj, virt_safe]
    else:
        from repro.core import costs
        cac = costs.approx_cost_stable(coords[obj][None, :],
                                       coords[virt_safe], metric, gamma)[0]
    return cac + h_slots


def _gains_kernel(x_ref, y_ref, lam_ref, cur_ref, h_ref, out_ref, *,
                  metric: str, gamma: float, n_ingress: int, n_caches: int):
    rt = pl.program_id(1)
    x = x_ref[...]                              # (BR, D) request coords
    y = y_ref[...]                              # (BO, D) candidate coords
    lam = lam_ref[...].astype(jnp.float32)      # (I, BR)
    cur = cur_ref[...].astype(jnp.float32)      # (I, BR)
    h = h_ref[...].astype(jnp.float32)          # (I, J)

    ca = _ca_block(x, y, metric, gamma)         # (BR, BO) — computed once

    @pl.when(rt == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    for i in range(n_ingress):                  # static unroll: segments
        slack_i = cur[i, :][:, None] - ca       # (BR, BO)
        lam_i = lam[i, :][:, None]              # (BR, 1)
        for j in range(n_caches):               # static unroll: J small
            contrib = jnp.maximum(slack_i - h[i, j], 0.0)
            out_ref[j, :] += jnp.sum(lam_i * contrib, axis=0)


@functools.partial(jax.jit, static_argnames=(
    "metric", "gamma", "br", "bo", "interpret"))
def _gains_pallas(x, y, lam, cur, hreq, metric: str, gamma: float,
                  br: int, bo: int, interpret: bool) -> jax.Array:
    """Pre-padded inputs: R % br == 0, O % bo == 0. Returns (J, O) f32."""
    R, D = x.shape
    O, _ = y.shape
    I, J = hreq.shape
    assert R % br == 0 and O % bo == 0, (R, O, br, bo)
    assert lam.shape == cur.shape == (I, R), (lam.shape, cur.shape)
    grid = (O // bo, R // br)
    kernel = functools.partial(_gains_kernel, metric=metric, gamma=gamma,
                               n_ingress=I, n_caches=J)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, D), lambda ot, rt: (rt, 0)),
            pl.BlockSpec((bo, D), lambda ot, rt: (ot, 0)),
            pl.BlockSpec((I, br), lambda ot, rt: (0, rt)),
            pl.BlockSpec((I, br), lambda ot, rt: (0, rt)),
            pl.BlockSpec((I, J), lambda ot, rt: (0, 0)),
        ],
        out_specs=pl.BlockSpec((J, bo), lambda ot, rt: (0, ot)),
        out_shape=jax.ShapeDtypeStruct((J, O), jnp.float32),
        interpret=interpret,
    )(x, y, lam, cur, hreq)


def _fold_tile(ca_t, lam, cur, h):
    """(T, J) gains of one candidate tile given its (R, T) C_a columns."""
    I, J = h.shape
    cols = []
    for j in range(J):
        acc = jnp.zeros((ca_t.shape[1],), jnp.float32)
        for i in range(I):
            m = jnp.maximum(cur[i, :][:, None] - h[i, j] - ca_t, 0.0)
            acc = acc + lam[i, :] @ m
        cols.append(acc)
    return jnp.stack(cols, axis=1)


def _gains_tiles_jnp(x, y, lam, cur, hreq, metric: str, gamma: float,
                     bo: int) -> jax.Array:
    """Blocked jnp oracle: lax.map over candidate tiles — the (R, O)
    distance matrix never materializes, so it scales to catalogs where
    a dense C_a is impossible. Inputs pre-padded to O % bo == 0;
    returns (O, J) f32."""
    O = y.shape[0]
    tiles = y.reshape(O // bo, bo, y.shape[1])

    def tile_fn(y_t):
        return _fold_tile(_ca_block(x, y_t, metric, gamma), lam, cur, hreq)

    return jax.lax.map(tile_fn, tiles).reshape(O, hreq.shape[1])


def _lb_gains_tiles_jnp(x, yp, lam, cur, hreq, metric: str, gamma: float,
                        bo: int) -> jax.Array:
    """Quantized twin of :func:`_gains_tiles_jnp`: per candidate tile the
    C_a block is replaced by quant.py's *certified lower bound* over the
    int8 images (requests quantized once, candidate tiles on the fly).
    lb ≤ C_a elementwise makes every relu slack — hence every gain — an
    **upper bound** on the exact oracle's, which is exactly the
    admissible direction lazy GREEDY needs: seed the stale upper bounds
    with quantized gains, let the top-k refresh re-score candidates
    exactly before any acceptance, and the picked allocation is
    bit-identical to the all-exact run (see placement.device_greedy).
    """
    qx, sx = quant.quantize_int8(x)
    xd = quant.dequantize_int8(qx, sx)
    rx = quant.quant_row_radius(sx[:, 0], x.shape[1], metric)
    x_sq = jnp.sum(xd * xd, -1) if metric in ("l2", "l2sq") else None
    O = yp.shape[0]
    tiles = yp.reshape(O // bo, bo, yp.shape[1])

    def tile_fn(y_t):
        kq = quant.quantize_rows(y_t, metric)
        kd = quant.dequantize_int8(kq.q, kq.scale)
        lb = quant.lb_approx_cost_block(xd, kd, rx, kq.radius, metric,
                                        gamma, q_sq=x_sq, k_sq=kq.sq_norm)
        return _fold_tile(lb, lam, cur, hreq)

    return jax.lax.map(tile_fn, tiles).reshape(O, hreq.shape[1])


@functools.partial(jax.jit, static_argnames=(
    "metric", "gamma", "br", "bo", "use_pallas", "interpret", "quantize"))
def placement_gains(x: jax.Array, y: jax.Array, lam: jax.Array,
                    cur: jax.Array, hreq: jax.Array, metric: str = "l2",
                    gamma: float = 1.0, br: int = DEFAULT_BR,
                    bo: int = DEFAULT_BO, use_pallas: bool | None = None,
                    interpret: bool | None = None,
                    quantize: bool = False) -> jax.Array:
    """(O, J) marginal gains of every candidate approximizer (o', j).

    x: (R, D) request-object coords; y: (O, D) candidate coords;
    lam, cur: (I, R) per-(ingress, object) rates and current serving
    costs; hreq: (I, J) ingress→cache retrieval costs (+inf allowed:
    mapped to ``H_SENTINEL``). ``use_pallas=None`` → Pallas on TPU,
    blocked jnp elsewhere. ``quantize=True`` computes certified gain
    *upper bounds* over int8 images instead (always the blocked jnp
    path — the compressed tables stream through plain XLA matmuls);
    see :func:`_lb_gains_tiles_jnp` for the admissibility contract.
    """
    n_obj = y.shape[0]
    hreq = jnp.where(jnp.isfinite(hreq), hreq, H_SENTINEL).astype(jnp.float32)
    lam = lam.astype(jnp.float32)
    cur = cur.astype(jnp.float32)
    if quantize:
        yp = _pad_axis(y.astype(jnp.float32), bo, 0, "zero")
        out = _lb_gains_tiles_jnp(x.astype(jnp.float32), yp, lam, cur,
                                  hreq, metric, gamma, bo)
        return out[:n_obj]
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas:
        yp = _pad_axis(y.astype(jnp.float32), bo, 0, "zero")
        out = _gains_tiles_jnp(x.astype(jnp.float32), yp, lam, cur, hreq,
                               metric, gamma, bo)
        return out[:n_obj]
    if interpret is None:
        interpret = not _on_tpu()
    xp = _pad_axis(_pad_axis(x.astype(jnp.float32), LANE, 1, "zero"),
                   br, 0, "zero")
    yp = _pad_axis(_pad_axis(y.astype(jnp.float32), LANE, 1, "zero"),
                   bo, 0, "zero")
    lamp = _pad_axis(lam, br, 1, "zero")
    curp = _pad_axis(cur, br, 1, "zero")
    out = _gains_pallas(xp, yp, lamp, curp, hreq, metric=metric, gamma=gamma,
                        br=br, bo=bo, interpret=interpret)
    return out[:, :n_obj].T


@functools.partial(jax.jit, static_argnames=("bo", "quantize"))
def placement_gains_matrix(ca: jax.Array, lam: jax.Array, cur: jax.Array,
                           hreq: jax.Array, bo: int = DEFAULT_BO,
                           quantize: bool = False) -> jax.Array:
    """Gain oracle over an explicit device-resident C_a matrix.

    ca: (R, O) approximation costs C_a[r, o']; lam, cur: (I, R);
    hreq: (I, J). Returns (O, J) f32 — the small-instance twin of
    :func:`placement_gains` for Instances built from a ca_matrix.
    ``quantize=True`` replaces each C_a row by the certified lower bound
    of its int8 image, relu(deq − ELEM_ERR·scale) ≤ ca (the per-element
    error budget of kernels/quant.py, with its safety margin absorbing
    the subtraction's own f32 rounding), making the returned gains
    admissible upper bounds exactly like :func:`placement_gains`'s.
    """
    n_obj = ca.shape[1]
    hreq = jnp.where(jnp.isfinite(hreq), hreq, H_SENTINEL).astype(jnp.float32)
    lam = lam.astype(jnp.float32)
    cur = cur.astype(jnp.float32)
    if quantize:
        qc, sc = quant.quantize_int8(ca.astype(jnp.float32))
        ca = jnp.maximum(quant.dequantize_int8(qc, sc)
                         - quant.ELEM_ERR * sc, 0.0)
    cat = _pad_axis(ca.astype(jnp.float32), bo, 1, "zero").T  # (O_pad, R)
    tiles = cat.reshape(cat.shape[0] // bo, bo, cat.shape[1])

    def tile_fn(ca_t):
        return _fold_tile(ca_t.T, lam, cur, hreq)

    out = jax.lax.map(tile_fn, tiles).reshape(cat.shape[0], hreq.shape[1])
    return out[:n_obj]


@functools.partial(jax.jit, static_argnames=(
    "mesh", "axes", "metric", "gamma", "br", "bo", "use_pallas",
    "interpret", "quantize"))
def sharded_placement_gains(x: jax.Array, y: jax.Array, lam: jax.Array,
                            cur: jax.Array, hreq: jax.Array, mesh,
                            axes: tuple[str, ...], metric: str = "l2",
                            gamma: float = 1.0, br: int = DEFAULT_BR,
                            bo: int = DEFAULT_BO,
                            use_pallas: bool | None = None,
                            interpret: bool | None = None,
                            quantize: bool = False) -> jax.Array:
    """Mesh-sharded gain oracle: one local oracle launch per candidate
    shard.

    The candidate tensor ``y`` is partitioned into contiguous balanced
    chunks over the product of the ``axes`` sizes (requests, rates and
    costs replicated — they are O(I·R) scalars, tiny next to the O×R
    tile stream), each shard folds its own chunk, and the (O, J) gain
    matrix comes back sharded on the candidate axis. Every candidate's
    sum is computed with the same request tiling as the single-device
    entry, so values are bit-identical shard-count-independently — the
    control-plane mirror of ``sharded_fused_lookup``'s contract.
    """
    n_shards = mesh_axes_size(mesh, axes)
    n_obj = y.shape[0]
    yp = _pad_axis(y.astype(jnp.float32), n_shards * bo, 0, "zero")
    spec = P(tuple(axes))

    def shard_fn(xs, ys, lams, curs, hs):
        return placement_gains(xs, ys, lams, curs, hs, metric=metric,
                               gamma=gamma, br=br, bo=bo,
                               use_pallas=use_pallas, interpret=interpret,
                               quantize=quantize)

    out = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(), spec, P(), P(), P()),
        out_specs=P(tuple(axes), None),
        check_rep=False)(x.astype(jnp.float32), yp, lam, cur, hreq)
    return out[:n_obj]
