"""Pure-jnp oracle for the KNN (nearest-approximizer) lookup.

Semantics shared with the Pallas kernel (knn.py) and the jit wrapper
(ops.py): given queries (Q, D) and keys (K, D), return per query the
minimum dissimilarity cost d(q, k)^γ and the argmin key index.
Ties break toward the lowest index (both implementations scan keys in
ascending order and use strict < for updates).
"""
from __future__ import annotations

import jax.numpy as jnp


def knn_ref(queries: jnp.ndarray, keys: jnp.ndarray, metric: str = "l2",
            gamma: float = 1.0) -> tuple[jnp.ndarray, jnp.ndarray]:
    q = queries.astype(jnp.float32)
    k = keys.astype(jnp.float32)
    if metric == "l1":
        d = jnp.sum(jnp.abs(q[:, None, :] - k[None, :, :]), axis=-1)
    elif metric in ("l2", "l2sq"):
        d2 = (jnp.sum(q * q, -1)[:, None] + jnp.sum(k * k, -1)[None, :]
              - 2.0 * q @ k.T)
        d2 = jnp.maximum(d2, 0.0)
        d = d2 if metric == "l2sq" else jnp.sqrt(d2)
    else:
        raise ValueError(metric)
    cost = d if gamma == 1.0 else jnp.power(jnp.maximum(d, 0.0), gamma)
    idx = jnp.argmin(cost, axis=1).astype(jnp.int32)
    return jnp.min(cost, axis=1), idx
